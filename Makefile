PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench-runtime bench-compare example-stream

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# fast perf datapoint: measured zero-loss throughput -> BENCH_runtime.json
bench-smoke:
	$(PYTHON) -m benchmarks.bench_runtime --smoke

# full runtime benchmark (Fig. 5c, measured) — separate output so it never
# clobbers the smoke baseline the bench-compare gate diffs against
bench-runtime:
	$(PYTHON) -m benchmarks.bench_runtime --out results/BENCH_runtime_full.json

# perf gate: fresh smoke run vs committed BENCH_runtime.json
# (fails on >20% median CATO zero_loss_pps regression)
bench-compare:
	$(PYTHON) -m benchmarks.compare_runtime

example-stream:
	$(PYTHON) examples/serve_stream.py

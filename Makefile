PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint format bench-smoke bench-smoke-sharded bench-smoke-zipf \
	bench-smoke-reuse bench-smoke-selftune bench-smoke-slo \
	bench-smoke-multitenant bench-runtime bench-compare tune-smoke \
	trace-smoke example-stream example-control example-tune \
	example-selftune example-multitenant

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# lint gate (ruff config in pyproject.toml). `ruff check` is repo-wide;
# format parity is enforced on the sharded-runtime layer and grows
# file-by-file as modules get normalized.
lint:
	ruff check .
	ruff format --check src/repro/serve/runtime/shard.py tests/test_shard.py

format:
	ruff format src/repro/serve/runtime/shard.py tests/test_shard.py

# fast perf datapoint: measured zero-loss throughput -> BENCH_runtime.json
bench-smoke:
	$(PYTHON) -m benchmarks.bench_runtime --smoke

# sharded smoke: 4 RSS-steered workers, gated >= 2x the committed 1-shard
# median (acceptance floor; measured speedups land nearer n/imbalance)
bench-smoke-sharded:
	$(PYTHON) -m benchmarks.bench_runtime --smoke --shards 4 \
		--out results/BENCH_runtime_sharded.json \
		--single BENCH_runtime.json --min-speedup 2.0

# zipf skew gate: 4 workers under elephant-flow skew, static RETA vs the
# adaptive control plane measured under one calibration — dynamic must
# report strictly lower load_imbalance and no lower median zero-loss pps
bench-smoke-zipf:
	$(PYTHON) -m benchmarks.bench_runtime --smoke --shards 4 \
		--scenario zipf --skew-gate \
		--out results/BENCH_runtime_zipf.json

# prediction-reuse gate (DESIGN.md §12): zipf 4-shard zero-loss A/B with
# the drift-gated reuse path on vs off, same calibration and stream.
# Fails unless reuse wins by >= 1.5x with zero drops on both arms and
# threshold-0 predictions stay bit-identical to the non-reuse path
bench-smoke-reuse:
	$(PYTHON) -m benchmarks.bench_runtime --smoke --scenario zipf \
		--min-reuse-speedup 1.5

# self-optimizing-fleet gate (DESIGN.md §13): drift-scenario controlled
# replay where a drift-triggered reoptimizer re-tunes and hot-swaps the
# knee autonomously — must fire exactly one audited episode, lose zero
# packets through the swap, beat the frozen knee on post-drift macro-F1,
# and stay silent on a uniform control arm
bench-smoke-selftune:
	$(PYTHON) -m benchmarks.bench_runtime --smoke --scenario drift \
		--selftune

# SLO latency gate (DESIGN.md §14): probe the fleet's replayed latency
# distribution, then controlled replays against self-calibrated met and
# violated targets — per-stage p99 decomposition must be consistent with
# the end-to-end total, breaches must be audited (and only when real),
# and the exporter's Prometheus/JSONL output must validate
bench-smoke-slo:
	$(PYTHON) -m benchmarks.bench_runtime --smoke --scenario zipf --slo

# multi-tenant gate (DESIGN.md §15): one 3-tenant shared fleet (merged
# extraction plan, fused multi-model dispatch) vs 3 independent 1-shard
# fleets at equal total shards, zero-loss bisection each arm — fails
# unless shared wins by >= 1.5x with zero drops on both arms and every
# tenant's predictions stay bit-identical to its solo-served baseline
bench-smoke-multitenant:
	$(PYTHON) -m benchmarks.bench_runtime --smoke --tenants 3 \
		--min-tenant-speedup 1.5

# observability smoke (DESIGN.md §11): one instrumented 4-shard zipf
# replay under the control plane — Chrome trace + stage breakdown +
# bit-matched metrics snapshot + audit log from a single run — then the
# overhead gate: tracing-disabled replay must stay within 5% of the
# untraced baseline on this machine
trace-smoke:
	$(PYTHON) -m benchmarks.bench_runtime --trace results/trace_serving.json
	$(PYTHON) -m benchmarks.trace_smoke --gate 5

# multi-fidelity tuner gate: batched cheap->measured optimization vs the
# sequential loop and every baseline, all through one shared memoized
# evaluator; fails unless CATO-MF's measured-fidelity hypervolume is >=
# every method's at equal measurement budget (DESIGN.md §10.3)
tune-smoke:
	$(PYTHON) -m benchmarks.tune_smoke --gate

# full runtime benchmark (Fig. 5c, measured) — separate output so it never
# clobbers the smoke baseline the bench-compare gate diffs against
bench-runtime:
	$(PYTHON) -m benchmarks.bench_runtime --out results/BENCH_runtime_full.json

# perf gate: fresh smoke run vs committed BENCH_runtime.json
# (fails on >20% median CATO zero_loss_pps regression)
bench-compare:
	$(PYTHON) -m benchmarks.compare_runtime

example-stream:
	$(PYTHON) examples/serve_stream.py

example-control:
	$(PYTHON) examples/serve_control.py

# the closed loop: optimize under zipf -> compile the front -> hot-swap
# the knee point into a live sharded replay (DESIGN.md §10)
example-tune:
	$(PYTHON) examples/tune_serving.py

# the loop closing itself: drift-triggered re-optimization with an
# autonomous hot-swap mid-replay (DESIGN.md §13)
example-selftune:
	$(PYTHON) examples/selftune_fleet.py

# the optimizer seeing the sharing: joint multi-tenant tuning where the
# union-plan extraction discount moves the Pareto front relative to
# independently tuned tenants, then a fused deploy (DESIGN.md §15.5)
example-multitenant:
	$(PYTHON) examples/tune_multitenant.py

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench-runtime example-stream

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# fast perf datapoint: measured zero-loss throughput -> BENCH_runtime.json
bench-smoke:
	$(PYTHON) -m benchmarks.bench_runtime --smoke

# full runtime benchmark (Fig. 5c, measured)
bench-runtime:
	$(PYTHON) -m benchmarks.bench_runtime

example-stream:
	$(PYTHON) examples/serve_stream.py

"""Kernel micro-bench: us/call in interpret mode (indicative; real numbers
need a TPU — interpret mode executes the kernel body with XLA-CPU ops)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(verbose=True):
    R = np.random.default_rng(0)
    rows = []

    q = jnp.asarray(R.standard_normal((1, 4, 256, 64)), jnp.float32)
    kv = jnp.asarray(R.standard_normal((1, 2, 256, 64)), jnp.float32)
    rows.append(("flash_attention_256", _time(
        lambda: ops.flash_attention(q, kv, kv, block_q=128, block_k=128))))

    qd = jnp.asarray(R.standard_normal((4, 8, 64)), jnp.float32)
    kc = jnp.asarray(R.standard_normal((4, 512, 2, 64)), jnp.float32)
    lens = jnp.asarray([512, 300, 128, 1], jnp.int32)
    rows.append(("decode_attention_512", _time(
        lambda: ops.decode_attention(qd, kc, kc, lens))))

    from repro.core.forest import train_forest
    X = R.standard_normal((512, 16)).astype(np.float32)
    y = R.integers(0, 4, 512)
    f = train_forest(X, y, n_trees=16, max_depth=6)
    fa = (jnp.asarray(X), jnp.asarray(f.feature), jnp.asarray(f.threshold),
          jnp.asarray(f.leaf))
    rows.append(("forest_infer_512x16", _time(
        lambda: ops.forest_infer(*fa, f.depth))))

    v = jnp.asarray(R.standard_normal((1024, 128)), jnp.float32)
    m = jnp.asarray(R.random((1024, 128)) < 0.5)
    rows.append(("flow_stats_1024", _time(lambda: ops.flow_stats(v, m))))

    x = jnp.asarray(R.standard_normal((1, 256, 2, 32)) * 0.3, jnp.float32)
    dt = jnp.asarray(np.abs(R.standard_normal((1, 256, 2))) * 0.1 + 0.01,
                     jnp.float32)
    A = jnp.asarray(-np.abs(R.standard_normal(2)) - 0.1, jnp.float32)
    Bm = jnp.asarray(R.standard_normal((1, 256, 8)) * 0.3, jnp.float32)
    rows.append(("mamba_scan_256", _time(
        lambda: ops.mamba_scan(x, dt, A, Bm, Bm, chunk=64))))

    if verbose:
        for name, us in rows:
            print(f"{name},{us:.1f},interpret-mode")
    return rows


if __name__ == "__main__":
    run()

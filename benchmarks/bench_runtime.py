"""Streaming-runtime benchmark: measured zero-loss throughput (Fig. 5c).

Drives `fig5_serving_perf.run_replayed` — CATO Pareto points vs the
ALL/MI10/RFE10 baselines, each measured by offered-load replay through
`repro.serve.runtime` with bisection to the highest zero-drop rate — and
records the result as a machine-readable `BENCH_runtime.json` datapoint at
the repo root so the perf trajectory is tracked across PRs.

    python -m benchmarks.bench_runtime --smoke    # CI-sized, ~a minute
    python -m benchmarks.bench_runtime            # full figure
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_runtime.json"


def run(smoke: bool = False, use_case: str = "app", verbose: bool = True,
        out_path: pathlib.Path | None = None):
    from .fig5_serving_perf import REPLAYED_HEADER as HEADER, run_replayed

    out_path = BENCH_PATH if out_path is None else pathlib.Path(out_path)
    cfg = dict(
        use_case=use_case,
        iters=8 if smoke else 25,
        n_flows=600 if smoke else 1500,
        max_pkts=32 if smoke else 48,
        bisect_iters=7 if smoke else 10,
        cost_mode="measured",
        verbose=verbose,
    )
    t0 = time.perf_counter()
    rows = run_replayed(**cfg)
    wall_s = time.perf_counter() - t0

    recs = [dict(zip(HEADER, r)) for r in rows]
    cato_best = max((r["zero_loss_gbps"] for r in recs if r["method"] == "CATO"),
                    default=0.0)
    gains = {
        r["method"]: round(cato_best / r["zero_loss_gbps"], 3)
        for r in recs
        if r["method"] != "CATO" and r["zero_loss_gbps"] > 0
    }
    out = {
        "bench": "runtime_zero_loss",
        "smoke": smoke,
        "config": {k: v for k, v in cfg.items() if k != "verbose"},
        "wall_s": round(wall_s, 2),
        "rows": recs,
        "cato_best_gbps": cato_best,
        "gain_vs_baseline": gains,
        "zero_drops_at_reported_rate": all(r["drops"] == 0 for r in recs),
    }
    out_path.write_text(json.dumps(out, indent=2) + "\n")
    if verbose:
        print(f"# wrote {out_path} (wall {wall_s:.1f}s, "
              f"CATO best {cato_best:.3f} Gbps, gains {gains})")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="CI-sized run")
    p.add_argument("--use-case", default="app", choices=("app", "iot"))
    p.add_argument("--out", default=None, help="output path (default: repo "
                   "root BENCH_runtime.json)")
    args = p.parse_args()
    run(smoke=args.smoke, use_case=args.use_case, out_path=args.out)

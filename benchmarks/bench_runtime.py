"""Streaming-runtime benchmark: measured zero-loss throughput (Fig. 5c).

Drives `fig5_serving_perf.run_replayed` — CATO Pareto points vs the
ALL/MI10/RFE10 baselines, each measured by offered-load replay through
`repro.serve.runtime` with bisection to the highest zero-drop rate — and
records the result as a machine-readable `results/BENCH_runtime.json`
datapoint (with a repo-root symlink alias for legacy readers) so the perf
trajectory is tracked across PRs.

With `--shards N` every point is measured against an RSS-steered
`ShardedRuntime` (DESIGN.md §8): rows carry a `shard` column — "agg" for
the aggregate zero-loss rate, 0..N-1 for the per-worker breakdown — and
`--min-speedup R --single PATH` gates the aggregate median against a
1-shard datapoint measured with the same config (the CI bench job uses
this to enforce that 4 workers actually buy >= 2x).

With `--scenario {uniform,zipf,burst,drift}` the replayed trace is one of
the adversarial workloads (`repro.traffic.synth.SCENARIOS`); rows carry a
`scenario` column so the perf trajectory covers non-uniform load. A
non-uniform scenario with `--shards N` measures every point twice —
static RETA vs the adaptive control plane — and `--skew-gate` asserts
the control plane earns its keep: strictly lower `load_imbalance` than
the static fleet and no lower median zero-loss pps (DESIGN.md §9).

With `--trace PATH` the benchmark instead runs ONE fully instrumented
replay (4-shard zipf under the control plane by default) and writes the
unified observability artifacts from that single run (DESIGN.md §11):
a Chrome-loadable trace at PATH (chrome://tracing / Perfetto), a
per-stage latency-breakdown table and merged fleet metrics snapshot
under `results/`, and the control plane's decision audit log as JSONL.
The snapshot's counter totals are asserted bit-identical to the
runtime's own `RuntimeMetrics` accounting before anything is written.

    python -m benchmarks.bench_runtime --smoke              # CI-sized
    python -m benchmarks.bench_runtime --smoke --shards 4   # sharded
    python -m benchmarks.bench_runtime --smoke --shards 4 \
        --scenario zipf --skew-gate                         # control plane
    python -m benchmarks.bench_runtime --trace results/trace_serving.json
    python -m benchmarks.bench_runtime                      # full figure
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import time

# legacy alias at the repo root: a symlink into results/ maintained by
# `benchmarks.common.write_datapoint` (the canonical artifact home)
BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_runtime.json"


def median_agg_pps(doc: dict, method: str = "CATO",
                   control: str | None = None) -> float:
    """Median aggregate zero_loss_pps of a method's rows.

    Rows predating the `shard` column count as aggregates (a single
    worker's only row *is* its aggregate). `control` filters
    static-vs-dynamic rows of a control-plane comparison run; None
    accepts any (plain runs have no control column)."""
    vals = [r["zero_loss_pps"] for r in doc["rows"]
            if r["method"] == method and r.get("shard", "agg") == "agg"
            and (control is None or r.get("control") == control)]
    if not vals:
        raise SystemExit(f"no {method} aggregate rows in benchmark document")
    return statistics.median(vals)


def run(smoke: bool = False, use_case: str = "app", verbose: bool = True,
        out_path: pathlib.Path | None = None, shards: int = 1,
        scenario: str = "uniform"):
    from .fig5_serving_perf import REPLAYED_HEADER as HEADER, run_replayed

    cfg = dict(
        use_case=use_case,
        iters=8 if smoke else 25,
        n_flows=600 if smoke else 1500,
        max_pkts=32 if smoke else 48,
        bisect_iters=7 if smoke else 10,
        cost_mode="measured",
        shards=shards,
        scenario=scenario,
        verbose=verbose,
    )
    if scenario != "uniform":
        # skewed scenarios need mass concentration: fewer flows, deeper
        # elephants (the held-out split still offers ~n_flows/5 flows)
        cfg["n_flows"] = 600 if smoke else 1000
        cfg["max_pkts"] = 160 if smoke else 256
        # a sharded scenario run measures static AND dynamic control rows
        cfg["control"] = shards > 1
    t0 = time.perf_counter()
    rows = run_replayed(**cfg)
    wall_s = time.perf_counter() - t0

    recs = [dict(zip(HEADER, r)) for r in rows]
    agg = [r for r in recs if r.get("shard", "agg") == "agg"]
    # headline ratios stay like-for-like: static rows only (a control
    # comparison run carries both static and dynamic measurements)
    agg_s = [r for r in agg if r.get("control", "static") == "static"]
    cato_best = max((r["zero_loss_gbps"] for r in agg_s if r["method"] == "CATO"),
                    default=0.0)
    gains = {
        r["method"]: round(cato_best / r["zero_loss_gbps"], 3)
        for r in agg_s
        if r["method"] != "CATO" and r["zero_loss_gbps"] > 0
    }
    out = {
        "bench": "runtime_zero_loss",
        "smoke": smoke,
        "config": {k: v for k, v in cfg.items() if k != "verbose"},
        "wall_s": round(wall_s, 2),
        "rows": recs,
        "cato_best_gbps": cato_best,
        "gain_vs_baseline": gains,
        "zero_drops_at_reported_rate": all(r["drops"] == 0 for r in agg),
    }
    from .common import write_datapoint

    path = write_datapoint(out, out_path, name=BENCH_PATH.name)
    if verbose:
        print(f"# wrote {path} (wall {wall_s:.1f}s, "
              f"CATO best {cato_best:.3f} Gbps, gains {gains})")
    return out


def run_traced(trace_path, shards: int = 4, scenario: str = "zipf",
               sample: float = 1.0, n_flows: int = 120, max_pkts: int = 256,
               offered_pps: float = 2e5, verbose: bool = True) -> dict:
    """One instrumented replay; every §11 artifact from a single run.

    Replays a skewed scenario through a control-plane-managed fleet with
    the full `Observability` bundle attached — flow-lifecycle and stage
    span tracing (at `sample` flow rate), drift sketches, fleet metrics
    registry, and the decision audit log — then writes:

    - the Chrome trace-event file at `trace_path`;
    - `results/trace_stage_breakdown.csv`: per-shard and fleet-level
      ingest / infer / flush service-time shares;
    - `results/obs_snapshot.json`: the merged fleet registry snapshot
      plus control, drift, audit, and trace summaries;
    - `results/audit_log.jsonl`: every rebalance / swap / scale decision
      with before/after load snapshots and rationale.

    Before writing, asserts the registry's counter totals bit-match the
    runtime's own merged `RuntimeMetrics` (the §11.1 exactness claim)
    and that the audit log saw every rebalance the plane counted.
    """
    import numpy as np

    from repro.core.search_space import FeatureRep
    from repro.serve import (
        ControlConfig, DriftMonitor, Observability, PacketStream,
        RuntimeMetrics, ServeSession, ServiceModel, ShardedRuntime, Tracer,
        fleet_registry, replay,
    )
    from repro.traffic import extract_features
    from repro.traffic.models import train_traffic_model
    from repro.traffic.pipeline import build_pipeline
    from repro.traffic.synth import make_scenario_dataset

    from .common import RESULTS, emit

    t0 = time.perf_counter()
    ds = make_scenario_dataset("app-class", scenario, n_flows=n_flows,
                               max_pkts=max_pkts, seed=3)
    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                      "ack_cnt"), depth=8)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    pipe = build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)
    stream = PacketStream.from_dataset(ds, seed=0)
    # deterministic constants at realistic magnitudes (same rationale as
    # the control-plane tests): the trace should show plausible span
    # durations, not calibration jitter
    service = ServiceModel(
        pkt_accum_ns=800.0, pkt_track_ns=200.0,
        bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
        gather_ns_per_flow=200.0, source="synthetic",
    )
    obs = Observability(
        tracer=Tracer(capacity=1 << 16, sample=sample),
        drift=DriftMonitor(),
    )
    created = []

    def make_runtime():
        rt = ShardedRuntime(pipe, n_shards=shards, capacity=2048,
                            max_batch=64, execute=True)
        created.append(rt)
        return rt

    stats = replay(
        stream, make_runtime, offered_pps, service,
        session=ServeSession(
            control=ControlConfig(interval_pkts=512, imbalance_trigger=1.04),
            obs=obs),
    )
    rt = created[-1]

    # §11.1 exactness: the registry path must reproduce the runtime's own
    # accounting bit-for-bit before any artifact is trusted
    rebuilt = RuntimeMetrics.from_registry(fleet_registry(rt, per_shard=False))
    mismatch = [
        f for f in RuntimeMetrics.counter_fields()
        if getattr(rebuilt, f) != getattr(stats.metrics, f)
    ]
    if mismatch:
        raise SystemExit(
            f"registry snapshot does not bit-match RuntimeMetrics: {mismatch}")
    plane_summary = stats.control or {}
    audited = obs.audit.summary()
    if audited.get("rebalance", 0) != plane_summary.get("rebalances", 0):
        raise SystemExit(
            "audit log missed rebalances: "
            f"{audited.get('rebalance', 0)} audited vs "
            f"{plane_summary.get('rebalances', 0)} counted")

    trace_path = pathlib.Path(trace_path)
    obs.tracer.save(trace_path)
    obs.audit.save(RESULTS / "audit_log.jsonl")

    rows = [("agg", *(round(s, 4) for s in _shares(stats.stage_seconds)),
             round(sum(stats.stage_seconds.values()), 6))]
    for p in stats.per_shard:
        ss = p.get("stage_seconds", {})
        rows.append((p["shard"], *(round(s, 4) for s in _shares(ss)),
                     round(sum(ss.values()), 6)))
    emit(rows, ("shard", "share_ingest", "share_infer", "share_flush",
                "busy_s"), "trace_stage_breakdown")

    snapshot = obs.snapshot(rt)
    snapshot["control"] = plane_summary
    doc = {
        "bench": "traced_replay",
        "config": {"shards": shards, "scenario": scenario, "sample": sample,
                   "n_flows": n_flows, "max_pkts": max_pkts,
                   "offered_pps": offered_pps},
        "wall_s": round(time.perf_counter() - t0, 2),
        "drops": stats.drops,
        "stage_shares": stats.stage_shares(),
        "trace_file": str(trace_path),
        "snapshot": snapshot,
    }
    out = pathlib.Path(RESULTS) / "obs_snapshot.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    if verbose:
        tr = obs.tracer.summary()
        print(f"# wrote {trace_path} ({tr['retained']} events, "
              f"{tr['dropped']} dropped), {out}, "
              f"results/audit_log.jsonl ({len(obs.audit)} decisions)")
        print(f"# registry bit-match OK; drops={stats.drops}; "
              f"stage shares {stats.stage_shares()}")
    return doc


REUSE_BENCH = "BENCH_runtime_zipf.json"


def run_reuse_gate(min_reuse_speedup: float = 0.0, smoke: bool = False,
                   shards: int = 4, out_path: pathlib.Path | None = None,
                   verbose: bool = True) -> dict:
    """A/B the drift-gated prediction-reuse fast path under zipf traffic
    (DESIGN.md §12) and write `results/BENCH_runtime_zipf.json`.

    Three measurements against one zipf trace and one 4-shard fleet
    configuration:

    - **off**: reuse disabled — the PR 6 serving path, calibrated with
      the honest warm tracker cost (`calibrate_warm=True`, not the
      legacy 0.25x guess, so the comparison cannot win by flattering
      the baseline);
    - **on**: reuse enabled (drift threshold 0.05, refresh every 64
      packets), same honest calibration — frozen packets charged the
      measured amortized fold cost, refreshes charged per drift check;
    - **parity**: an *executing* replay at drift threshold 0 (every
      refresh re-infers) whose per-flow predictions must be bit-identical
      to an executing reuse-off replay — the semantics guardrail that
      keeps the fast path an optimization, not a model change.

    `min_reuse_speedup` gates on/off zero-loss throughput (0 disables);
    both arms must also report zero drops at their reported rate.
    """
    import numpy as np

    from repro.core.search_space import FeatureRep
    from repro.serve import (
        PacketStream, ReuseConfig, ServiceModel, ShardedRuntime,
        find_zero_loss_rate, replay,
    )
    from repro.traffic import extract_features
    from repro.traffic.models import train_traffic_model
    from repro.traffic.pipeline import build_pipeline
    from repro.traffic.synth import make_scenario_dataset

    t0 = time.perf_counter()
    # smoke shrinks the flow count, not the elephants: reuse pays off on
    # the post-classification tail of long flows, so max_pkts is the one
    # knob that must stay at full scale for the A/B to mean anything
    n_flows, max_pkts = (150, 4000) if smoke else (600, 4000)
    bisect_iters = 6 if smoke else 8
    drift_threshold, refresh_every = 0.1, 256
    ds = make_scenario_dataset("app-class", "zipf", n_flows=n_flows,
                               max_pkts=max_pkts, seed=3)
    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                      "ack_cnt"), depth=8)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    pipe = build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)
    stream = PacketStream.from_dataset(ds, seed=0)
    ring_capacity = max(64, min(6144, stream.n_events // 6))

    # prompt-classification config (both arms, so the A/B stays fair):
    # reuse only pays off once flows are classified and frozen, and at
    # zero-loss rates the whole trace spans ~0.1 virtual seconds — a
    # 64-flow batch with the default 50ms flush timeout would leave most
    # flows READY (tracked at full eager-aggregate cost) for the bulk of
    # the replay, measuring classification latency instead of reuse.
    def make_runtime(ru):
        def mk(execute):
            return ShardedRuntime(pipe, n_shards=shards, capacity=2048,
                                  max_batch=8, flush_timeout_s=2e-4,
                                  execute=execute, reuse=ru)
        return mk

    arms = {}
    for tag, ru in (
        ("off", None),
        ("on", ReuseConfig(enabled=True, drift_threshold=drift_threshold,
                           refresh_every=refresh_every)),
    ):
        mk = make_runtime(ru)
        # reps=5: the warm per-class constants decide the A/B verdict and
        # measure() keeps the best-of-reps minimum, so extra reps strictly
        # tighten the noise floor on shared machines
        service = ServiceModel.measure(mk(True), stream, n_pkt_sample=16000,
                                       reps=5, calibrate_warm=True)
        pps, stats = find_zero_loss_rate(
            stream, mk, service, iters=bisect_iters,
            ring_capacity=ring_capacity)
        m = stats.metrics
        arms[tag] = {
            "zero_loss_pps": round(pps, 1),
            "zero_loss_gbps": round(stats.offered_gbps, 4),
            "drops": stats.drops,
            "pkt_track_ns": round(service.pkt_track_ns, 1),
            "pkt_frozen_ns": (None if service.pkt_frozen_ns is None
                              else round(service.pkt_frozen_ns, 1)),
            "reuse_hits": m.reuse_hits,
            "refreshes": m.refreshes,
            "forced_reinfer": m.forced_reinfer,
        }
        if verbose:
            print(f"# zipf {shards}-shard reuse={tag}: "
                  f"{pps:,.0f} pps ({stats.offered_gbps:.3f} Gbps), "
                  f"drops={stats.drops}, track={service.pkt_track_ns:.0f}ns, "
                  f"frozen={service.pkt_frozen_ns}")

    # parity: threshold 0 forces re-inference at every refresh, and results
    # keep first-prediction-wins — predictions must be bit-identical to the
    # reuse-off executing replay
    svc = ServiceModel(pkt_accum_ns=800.0, pkt_track_ns=200.0,
                       bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
                       gather_ns_per_flow=200.0, pkt_frozen_ns=100.0,
                       source="synthetic")
    base = replay(stream, lambda: make_runtime(None)(True),
                  stream.base_pps, svc, ring_capacity=ring_capacity)
    thr0 = replay(stream, lambda: make_runtime(
        ReuseConfig(enabled=True, drift_threshold=0.0,
                    refresh_every=refresh_every))(True),
        stream.base_pps, svc, ring_capacity=ring_capacity)
    parity_ok = (
        set(base.predictions) == set(thr0.predictions)
        and all(np.array_equal(base.predictions[k], thr0.predictions[k])
                for k in base.predictions)
    )
    if verbose:
        print(f"# threshold-0 bit-parity: {parity_ok} "
              f"({len(base.predictions)} flows)")

    speedup = (arms["on"]["zero_loss_pps"]
               / max(arms["off"]["zero_loss_pps"], 1e-9))
    doc = {
        "bench": "runtime_zero_loss_reuse",
        "smoke": smoke,
        "config": {"scenario": "zipf", "shards": shards, "n_flows": n_flows,
                   "max_pkts": max_pkts, "events": stream.n_events,
                   "bisect_iters": bisect_iters,
                   "ring_capacity": ring_capacity,
                   "drift_threshold": drift_threshold,
                   "refresh_every": refresh_every},
        "wall_s": round(time.perf_counter() - t0, 2),
        "arms": arms,
        "reuse_speedup": round(speedup, 3),
        "threshold0_bit_identical": bool(parity_ok),
        "zero_drops_at_reported_rate": (arms["off"]["drops"] == 0
                                        and arms["on"]["drops"] == 0),
    }
    from .common import write_datapoint

    path = write_datapoint(doc, out_path, name=REUSE_BENCH)
    if verbose:
        print(f"# wrote {path} (wall {doc['wall_s']:.1f}s, "
              f"reuse speedup {speedup:.2f}x)")
    if not parity_ok:
        print("FAIL: threshold-0 predictions diverge from reuse-off",
              file=sys.stderr)
        raise SystemExit(1)
    if not doc["zero_drops_at_reported_rate"]:
        print("FAIL: drops at reported zero-loss rate", file=sys.stderr)
        raise SystemExit(1)
    if min_reuse_speedup > 0 and speedup < min_reuse_speedup:
        print(f"FAIL: reuse speedup {speedup:.2f}x < "
              f"{min_reuse_speedup:.2f}x floor", file=sys.stderr)
        raise SystemExit(1)
    if verbose and min_reuse_speedup > 0:
        print(f"OK: reuse speedup above {min_reuse_speedup:.2f}x floor")
    return doc


MULTITENANT_BENCH = "BENCH_multitenant.json"

# overlapping per-tenant feature plans (DESIGN.md §15.1): heavy shared
# prefix so the merged plan amortizes — the whole point of the A/B
_TENANT_REPS = (
    (("s_bytes_mean", "s_iat_mean", "s_load", "proto"), 8),
    (("s_bytes_mean", "s_iat_mean", "s_load", "dur", "s_bytes_max"), 12),
    (("s_bytes_mean", "s_iat_mean", "dur", "d_pkt_cnt"), 8),
    (("s_bytes_mean", "s_load", "ack_cnt", "psh_cnt"), 8),
)


def run_multitenant_gate(min_tenant_speedup: float = 0.0, smoke: bool = False,
                         tenants: int = 3,
                         out_path: pathlib.Path | None = None,
                         verbose: bool = True) -> dict:
    """A/B multi-tenant white-box serving under zipf traffic (DESIGN.md
    §15) and write `results/BENCH_multitenant.json`.

    Two arms at equal total worker count, one zipf trace:

    - **shared**: one N-shard fleet serving all N tenants through a
      single `MultiTenantPipeline` — the merged extraction plan runs
      once per flow, every tenant's forest reads its column subset;
    - **independent**: N separate 1-shard fleets, one per tenant, each
      replaying the *full* stream (every tenant must classify every
      flow). The arm's zero-loss rate is the min over tenants — the
      slowest fleet caps the rate the stream can be delivered at.

    Both arms are calibrated with `ServiceModel.measure` on their own
    runtime and bisected to the highest zero-drop rate. A parity leg
    (executing replays under a synthetic service model) asserts every
    tenant's shared-fleet predictions are bit-identical to its
    solo-served baseline — sharing is an optimization, not a model
    change. `min_tenant_speedup` gates shared/independent zero-loss
    throughput (0 disables); both arms must report zero drops.
    """
    import numpy as np

    from repro.core.search_space import FeatureRep
    from repro.serve import (
        PacketStream, ServiceModel, ShardedRuntime, build_multi_tenant_pipeline,
        find_zero_loss_rate, replay,
    )
    from repro.traffic import extract_features
    from repro.traffic.models import train_traffic_model
    from repro.traffic.pipeline import build_pipeline
    from repro.traffic.synth import make_scenario_dataset

    if not 2 <= tenants <= len(_TENANT_REPS):
        raise SystemExit(
            f"--tenants must be in [2, {len(_TENANT_REPS)}], got {tenants}")
    t0 = time.perf_counter()
    n_flows, max_pkts = (150, 96) if smoke else (500, 160)
    bisect_iters = 6 if smoke else 8
    ds = make_scenario_dataset("app-class", "zipf", n_flows=n_flows,
                               max_pkts=max_pkts, seed=3)
    reps = [FeatureRep(f, depth=d) for f, d in _TENANT_REPS[:tenants]]
    forests = []
    for t, rep in enumerate(reps):
        X = extract_features(ds, rep.features, rep.depth)
        forests.append(
            train_traffic_model(X, ds.label, model="tree-fast", seed=t)[0])
    solo_pipes = [build_pipeline(r, f, max_pkts=r.depth, use_kernel=False)
                  for r, f in zip(reps, forests)]
    mt_pipe = build_multi_tenant_pipeline(reps, forests, use_kernel=False)
    stream = PacketStream.from_dataset(ds, seed=0)
    ring_capacity = max(64, min(6144, stream.n_events // 6))

    # prompt flushes both arms (small batches, tight timeout) so neither
    # arm's zero-loss rate is gated on classification latency
    def make_runtime(pipe, shards):
        def mk(execute):
            return ShardedRuntime(pipe, n_shards=shards, capacity=2048,
                                  max_batch=32, flush_timeout_s=2e-4,
                                  execute=execute)
        return mk

    def bisect(pipe, shards, tag):
        mk = make_runtime(pipe, shards)
        service = ServiceModel.measure(mk(True), stream, n_pkt_sample=16000,
                                       reps=5)
        pps, stats = find_zero_loss_rate(
            stream, mk, service, iters=bisect_iters,
            ring_capacity=ring_capacity)
        if verbose:
            print(f"# zipf {tag}: {pps:,.0f} pps "
                  f"({stats.offered_gbps:.3f} Gbps), drops={stats.drops}")
        return {"zero_loss_pps": round(pps, 1),
                "zero_loss_gbps": round(stats.offered_gbps, 4),
                "drops": stats.drops, "n_shards": shards}

    shared = bisect(mt_pipe, tenants, f"shared {tenants}-shard fleet")
    indep = [bisect(p, 1, f"independent tenant{t} 1-shard fleet")
             for t, p in enumerate(solo_pipes)]
    # the stream is offered to all N independent fleets at one rate, so
    # the slowest tenant's zero-loss rate is the arm's rate
    indep_pps = min(a["zero_loss_pps"] for a in indep)

    # parity: executing replays at the stream's native rate — tenant t's
    # lane of every fused prediction vector must equal its solo baseline
    svc = ServiceModel(pkt_accum_ns=800.0, pkt_track_ns=200.0,
                       bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
                       gather_ns_per_flow=200.0, source="synthetic")
    sh = replay(stream, lambda: make_runtime(mt_pipe, tenants)(True),
                stream.base_pps, svc, ring_capacity=ring_capacity)
    parity_ok, n_flows_checked = True, 0
    for t, pipe in enumerate(solo_pipes):
        solo = replay(stream, lambda: make_runtime(pipe, 1)(True),
                      stream.base_pps, svc, ring_capacity=ring_capacity)
        keys = sorted(sh.predictions)
        ok = (keys == sorted(solo.predictions)
              and np.array_equal(
                  np.asarray([sh.predictions[k][t] for k in keys]),
                  np.asarray([solo.predictions[k] for k in keys])))
        parity_ok &= ok
        n_flows_checked = len(keys)
        if verbose:
            print(f"# tenant{t} shared-vs-solo bit-parity: {ok}")

    speedup = shared["zero_loss_pps"] / max(indep_pps, 1e-9)
    doc = {
        "bench": "runtime_multitenant",
        "smoke": smoke,
        "config": {"scenario": "zipf", "tenants": tenants,
                   "n_flows": n_flows, "max_pkts": max_pkts,
                   "events": stream.n_events, "bisect_iters": bisect_iters,
                   "ring_capacity": ring_capacity,
                   "tenant_features": [list(r.features) for r in reps],
                   "tenant_depths": [r.depth for r in reps],
                   "union_features": len(mt_pipe.rep.features),
                   "merged_columns": len(mt_pipe.merged),
                   "solo_columns": sum(len(r.features) for r in reps)},
        "wall_s": round(time.perf_counter() - t0, 2),
        "arms": {
            "shared": shared,
            "independent": {"per_tenant": indep,
                            "zero_loss_pps": indep_pps,
                            "drops": sum(a["drops"] for a in indep)},
        },
        "tenant_speedup": round(speedup, 3),
        "per_tenant_bit_identical": bool(parity_ok),
        "flows_checked": n_flows_checked,
        "zero_drops_at_reported_rate": (
            shared["drops"] == 0 and all(a["drops"] == 0 for a in indep)),
    }
    from .common import write_datapoint

    path = write_datapoint(doc, out_path, name=MULTITENANT_BENCH)
    if verbose:
        print(f"# wrote {path} (wall {doc['wall_s']:.1f}s, "
              f"shared/independent speedup {speedup:.2f}x)")
    if not parity_ok:
        print("FAIL: shared-fleet predictions diverge from solo baselines",
              file=sys.stderr)
        raise SystemExit(1)
    if not doc["zero_drops_at_reported_rate"]:
        print("FAIL: drops at reported zero-loss rate", file=sys.stderr)
        raise SystemExit(1)
    if min_tenant_speedup > 0 and speedup < min_tenant_speedup:
        print(f"FAIL: multi-tenant speedup {speedup:.2f}x < "
              f"{min_tenant_speedup:.2f}x floor", file=sys.stderr)
        raise SystemExit(1)
    if verbose and min_tenant_speedup > 0:
        print(f"OK: multi-tenant speedup above {min_tenant_speedup:.2f}x floor")
    return doc


SELFTUNE_BENCH = "BENCH_selftune.json"


def _macro_f1(y_true, y_pred) -> float:
    """Macro-averaged F1 over the classes present in `y_true`/`y_pred`."""
    import numpy as np

    f1s = []
    for c in np.union1d(np.unique(y_true), np.unique(y_pred)):
        tp = float(np.sum((y_pred == c) & (y_true == c)))
        fp = float(np.sum((y_pred == c) & (y_true != c)))
        fn = float(np.sum((y_pred != c) & (y_true == c)))
        if tp + fp + fn == 0:
            continue
        f1s.append(2 * tp / max(2 * tp + fp + fn, 1e-9))
    return float(np.mean(f1s)) if f1s else 0.0


def run_selftune_gate(smoke: bool = False,
                      out_path: pathlib.Path | None = None,
                      verbose: bool = True) -> dict:
    """A/B the self-optimizing fleet on the drift scenario (DESIGN.md §13)
    and write `results/BENCH_selftune.json`.

    The drift scenario reorders flows by class rank, so an in-order
    arrival process sees the class mix slide across the trace. The
    deployed bundle is trained on the *pre-drift window only* (the first
    40% of packets) — the stale knee a fleet optimized yesterday would
    be serving today. Three controlled replays:

    - **frozen**: the stale bundle with the control plane but no
      reoptimizer — what PR 7's fleet would do;
    - **selftuned**: same bundle and stream, plus a `ReoptimizerPolicy`
      whose retune refits on the full corpus — the drift monitor must
      trigger mid-run, the policy must hot-swap the re-optimized knee,
      and post-drift flows must classify through the new pipeline;
    - **uniform control**: the identical policy on a uniform replay —
      zero episodes, or the trigger is noise-driven.

    Gates: >= 1 audited reopt episode on the drift arm, zero episodes
    on the uniform arm, zero drops everywhere (the swap may not lose a
    packet), and the self-tuned arm's macro-F1 over the post-drift
    segment (flows first seen in the trace's last third) strictly above
    the frozen arm's.
    """
    import numpy as np

    from repro.core.search_space import FeatureRep
    from repro.serve import (
        ControlConfig, DriftMonitor, Observability, PacketStream,
        ReoptOutcome, ReoptimizerConfig, ReoptimizerPolicy, ServeSession,
        ServiceModel, ShardedRuntime, replay,
    )
    from repro.serve.deploy import BundlePoint
    from repro.traffic import extract_features
    from repro.traffic.models import train_traffic_model
    from repro.traffic.pipeline import build_pipeline
    from repro.traffic.synth import make_scenario_dataset

    t0 = time.perf_counter()
    n_flows, max_pkts, pps = (600, 32, 2e5)
    rep_a = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                        "ack_cnt"), depth=8)
    rep_b = FeatureRep(("dur", "s_load", "s_pkt_cnt", "d_bytes_med",
                        "psh_cnt"), depth=12)
    service = ServiceModel(pkt_accum_ns=800.0, pkt_track_ns=200.0,
                           bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
                           gather_ns_per_flow=200.0, source="synthetic")
    # threshold 0.35 sits between small-batch mix noise (~0.25 TV at
    # max_batch=16) and the drift excursion (>0.6); max_batch must be
    # small enough that micro-batches resolve (and feed the drift
    # monitor) mid-run rather than at drain
    policy_cfg = ReoptimizerConfig(class_threshold=0.35, min_dwell_pkts=256,
                                   cooldown_pkts=1 << 20, max_episodes=1)

    def fleet(pipe):
        return lambda: ShardedRuntime(pipe, n_shards=2, capacity=2048,
                                      max_batch=16, execute=True)

    def stale_and_retuned(ds, stream):
        """The pre-drift-trained deployed bundle + a full-corpus retune."""
        first_pkt = np.full(ds.n_flows, stream.n_events)
        np.minimum.at(first_pkt, stream.fid, np.arange(stream.n_events))
        pre = np.nonzero(first_pkt < 0.4 * stream.n_events)[0]
        Xa = extract_features(ds, rep_a.features, rep_a.depth)
        fa, _ = train_traffic_model(Xa[pre], ds.label[pre],
                                    model="tree-fast", seed=0)
        stale = build_pipeline(rep_a, fa, max_pkts=rep_a.depth,
                               use_kernel=False)

        def retune(trigger):
            Xb = extract_features(ds, rep_b.features, rep_b.depth)
            fb, _ = train_traffic_model(Xb, ds.label, model="tree-fast",
                                        seed=0)
            pipe_b = build_pipeline(rep_b, fb, max_pkts=rep_b.depth,
                                    use_kernel=False)
            point = BundlePoint(rep=rep_b, cost=1.0, perf=0.95,
                                fidelity="measured", aux={},
                                compile_meta={"fused": False},
                                forest_doc=None, pipeline=pipe_b)
            return ReoptOutcome(point=point, service=service)

        return stale, retune, first_pkt

    def session(retune=None):
        s = ServeSession(obs=Observability(drift=DriftMonitor()),
                         control=ControlConfig(interval_pkts=256,
                                               rebalance=False))
        if retune is not None:
            s.reopt = ReoptimizerPolicy(retune, policy_cfg)
        return s

    ds = make_scenario_dataset("app-class", "drift", n_flows=n_flows,
                               max_pkts=max_pkts, seed=3)
    stream = PacketStream.from_dataset(ds, seed=0)
    stale, retune, first_pkt = stale_and_retuned(ds, stream)
    frozen = replay(stream, fleet(stale), pps, service, session=session())
    tuned_session = session(retune)
    tuned = replay(stream, fleet(stale), pps, service, session=tuned_session)

    # post-drift segment: flows first seen in the trace's last third
    post = np.nonzero(first_pkt >= (2 / 3) * stream.n_events)[0]
    f1 = {
        tag: _macro_f1(ds.label[post],
                       np.array([st.predictions[f] for f in post]))
        for tag, st in (("frozen", frozen), ("selftuned", tuned))
    }
    episodes = tuned.control["reopt"]["episodes"]
    reopt_events = tuned_session.resolve_audit().of_kind("reopt")
    if verbose:
        print(f"# drift 2-shard: post-drift macro-F1 frozen "
              f"{f1['frozen']:.3f} vs selftuned {f1['selftuned']:.3f}, "
              f"episodes={episodes}, "
              f"swap_at={tuned.control['swap_at_pkts']}, "
              f"drops={frozen.drops}/{tuned.drops}")

    # uniform control arm: same policy, stationary mix -> zero episodes
    ds_u = make_scenario_dataset("app-class", "uniform", n_flows=n_flows,
                                 max_pkts=max_pkts, seed=3)
    stream_u = PacketStream.from_dataset(ds_u, seed=0)
    stale_u, retune_u, _ = stale_and_retuned(ds_u, stream_u)
    uniform = replay(stream_u, fleet(stale_u), pps, service,
                     session=session(retune_u))
    if verbose:
        print(f"# uniform control arm: episodes="
              f"{uniform.control['reopt']['episodes']}, "
              f"drops={uniform.drops}")

    doc = {
        "bench": "selftune_drift",
        "smoke": smoke,
        "config": {"scenario": "drift", "shards": 2, "n_flows": n_flows,
                   "max_pkts": max_pkts, "events": stream.n_events,
                   "pps": pps, "class_threshold": 0.35,
                   "min_dwell_pkts": 256, "interval_pkts": 256,
                   "max_batch": 16},
        "wall_s": round(time.perf_counter() - t0, 2),
        "post_drift_f1": {k: round(v, 4) for k, v in f1.items()},
        "episodes": episodes,
        "swap_at_pkts": tuned.control["swap_at_pkts"],
        "reopt_audited": len(reopt_events),
        "uniform_episodes": uniform.control["reopt"]["episodes"],
        "drops": {"frozen": frozen.drops, "selftuned": tuned.drops,
                  "uniform": uniform.drops},
        "reopt_summary": tuned.control["reopt"],
    }
    from .common import write_datapoint

    path = write_datapoint(doc, out_path, name=SELFTUNE_BENCH)
    if verbose:
        print(f"# wrote {path} (wall {doc['wall_s']:.1f}s)")
    if episodes < 1 or len(reopt_events) < 1:
        print("FAIL: drift arm fired no audited reopt episode",
              file=sys.stderr)
        raise SystemExit(1)
    if doc["uniform_episodes"] != 0:
        print("FAIL: uniform arm fired a reopt episode (noise trigger)",
              file=sys.stderr)
        raise SystemExit(1)
    if frozen.drops or tuned.drops or uniform.drops:
        print("FAIL: drops during a gated replay (swap lost packets?)",
              file=sys.stderr)
        raise SystemExit(1)
    if not f1["selftuned"] > f1["frozen"]:
        print(f"FAIL: post-drift F1 selftuned {f1['selftuned']:.3f} not "
              f"above frozen {f1['frozen']:.3f}", file=sys.stderr)
        raise SystemExit(1)
    if verbose:
        print("OK: self-tuned fleet beats the frozen knee post-drift")
    return doc


SLO_BENCH = "BENCH_slo.json"


def run_slo_gate(smoke: bool = False, scenario: str = "zipf",
                 shards: int = 4,
                 out_path: pathlib.Path | None = None,
                 verbose: bool = True) -> dict:
    """Fixed-offered-load SLO smoke (DESIGN.md §14): per-stage latency
    decomposition + burn-rate verdicts, and write `results/BENCH_slo.json`.

    One probe replay measures the fleet's actual latency distribution
    (the sketches' p50/p99), then two controlled arms replay the same
    stream against *self-calibrated* targets:

    - **met**: target = 10x the probed p99 — attainment must be 1.0 and
      the run must produce zero audited ``"slo"`` events;
    - **violated**: target = half the probed *minimum* — unattainable by
      construction (service time floors every flow's total), so the
      tracker must breach and the control plane must audit >= 1
      ``"slo"`` event (edge-triggered: one per episode, not per step).

    Cross-cutting gates on the violated arm's recording: every stage
    sketch saw every charged flow, the integer-ns stage means sum to the
    end-to-end mean, the stage p99s bound the total's tail (Bonferroni,
    within the sketches' alpha), the exporter's JSONL series has one
    line per executed control step, and its Prometheus rendering
    validates. The SLO window is derived from the trace's virtual span
    (smoke traces cover well under a second of virtual time)."""
    import numpy as np

    from repro.core.search_space import FeatureRep
    from repro.serve import (
        ControlConfig, LatencyConfig, MetricsExporter, Observability,
        PacketStream, ServeSession, ServiceModel, ShardedRuntime, SLOConfig,
        SLOTracker, check_prometheus, controlled_replay, replay,
    )
    from repro.serve.obs import COMPONENTS
    from repro.traffic import extract_features
    from repro.traffic.models import train_traffic_model
    from repro.traffic.pipeline import build_pipeline
    from repro.traffic.synth import make_scenario_dataset

    from .common import RESULTS, write_datapoint

    t0 = time.perf_counter()
    n_flows, max_pkts = (400, 64) if smoke else (1200, 128)
    pps = 2e5
    alpha = 0.01
    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                      "ack_cnt"), depth=8)
    ds = make_scenario_dataset("app-class", scenario, n_flows=n_flows,
                               max_pkts=max_pkts, seed=3)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    pipe = build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)
    stream = PacketStream.from_dataset(ds, seed=0)
    service = ServiceModel(pkt_accum_ns=800.0, pkt_track_ns=200.0,
                           bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
                           gather_ns_per_flow=200.0, source="synthetic")
    # the packet clock spans n_events/pps virtual seconds; ~12 windows
    # gives the slow burn several windows to integrate over
    window_s = (stream.n_events / pps) / 12.0

    def mk(created):
        def make():
            rt = ShardedRuntime(pipe, n_shards=shards, capacity=2048,
                                max_batch=64, execute=False)
            created.append(rt)
            return rt
        return make

    def merged_recorder(rt):
        recs = [s.metrics.latency_components for s in rt.shards]
        out = recs[0].fresh()
        for r in recs:
            out.merge_from(r)
        return out

    # -- probe: measure the distribution the targets calibrate against --
    probe_created: list = []
    probe_obs = Observability(latency=LatencyConfig(alpha=alpha))
    replay(stream, mk(probe_created), pps, service,
           session=ServeSession(obs=probe_obs))
    probe = merged_recorder(probe_created[-1]).sketches["total"]
    p50, p99 = probe.percentile(50), probe.percentile(99)
    # the controlled arms batch differently than the probe, but no flow
    # anywhere completes faster than its bucket's service time — half
    # the probed minimum is unattainable by construction
    vio_target = 0.5 * probe.percentile(0)

    def arm(target_s, jsonl_path):
        created: list = []
        slo = SLOTracker(SLOConfig(target_s=target_s, objective=0.99,
                                   window_s=window_s, slow_windows=4))
        obs = Observability(latency=LatencyConfig(alpha=alpha), slo=slo,
                            exporter=MetricsExporter(jsonl_path=jsonl_path))
        session = ServeSession(obs=obs,
                               control=ControlConfig(interval_pkts=512))
        stats = controlled_replay(stream, mk(created), pps, service,
                                  session=session)
        return stats, obs, created[-1]

    jsonl = RESULTS / "slo_timeseries.jsonl"
    jsonl.unlink(missing_ok=True)             # append-only within a run
    met_stats, met_obs, _ = arm(10.0 * p99, None)
    vio_stats, vio_obs, vio_rt = arm(vio_target, str(jsonl))

    rec = merged_recorder(vio_rt)
    stages = {c: {k: (round(v, 9) if isinstance(v, float) else v)
                  for k, v in rec.sketches[c].summary().items()}
              for c in COMPONENTS}
    total = rec.sketches["total"]
    parts_mean = sum(rec.sketches[c].mean_s
                     for c in ("queue_wait", "batch", "service"))
    stage_p99_sum = sum(rec.sketches[c].percentile(99)
                        for c in ("queue_wait", "batch", "service"))
    # per-charge ns rounding on each of 3 components
    mean_tol = 2e-9 + abs(total.mean_s) * 1e-6
    decomposition_ok = (
        len({rec.sketches[c].n for c in COMPONENTS}) == 1
        and abs(parts_mean - total.mean_s) <= mean_tol
        and total.percentile(97) <= stage_p99_sum * (1.0 + 4 * alpha))

    met_events = len(met_obs.audit.of_kind("slo"))
    vio_events = len(vio_obs.audit.of_kind("slo"))
    prom_problems = check_prometheus(vio_obs.exporter.prometheus())
    series_lines = len(jsonl.read_text().splitlines())

    def arm_doc(stats, obs, target_s):
        v = obs.slo.check(stream.n_events / pps)
        return {
            "target_s": round(target_s, 9),
            "attainment": round(obs.slo.attainment, 6),
            "breaches": obs.slo.breaches,
            "audited_slo_events": len(obs.audit.of_kind("slo")),
            "burn_slow": round(v.burn_slow, 3),
            "samples": obs.slo.samples,
            "drops": stats.drops,
            "latency_p99_s": round(stats.latency_p99_s, 9),
        }

    doc = {
        "bench": "slo_latency",
        "smoke": smoke,
        "config": {"scenario": scenario, "shards": shards,
                   "n_flows": n_flows, "max_pkts": max_pkts,
                   "events": int(stream.n_events), "pps": pps,
                   "alpha": alpha, "window_s": round(window_s, 9),
                   "interval_pkts": 512},
        "wall_s": round(time.perf_counter() - t0, 2),
        "probe": {"p50_s": round(p50, 9), "p99_s": round(p99, 9)},
        "stages": stages,
        "decomposition": {
            "stage_mean_sum_s": round(parts_mean, 9),
            "total_mean_s": round(total.mean_s, 9),
            "stage_p99_sum_s": round(stage_p99_sum, 9),
            "total_p99_s": round(total.percentile(99), 9),
            "consistent": decomposition_ok,
        },
        "arms": {"met": arm_doc(met_stats, met_obs, 10.0 * p99),
                 "violated": arm_doc(vio_stats, vio_obs, vio_target)},
        "exporter": {"steps": vio_obs.exporter.steps,
                     "jsonl": str(jsonl), "jsonl_lines": series_lines,
                     "prometheus_problems": prom_problems},
    }
    path = write_datapoint(doc, out_path, name=SLO_BENCH)
    if verbose:
        s = stages
        print(f"# {scenario} {shards}-shard @ {pps:,.0f} pps: total p99 "
              f"{s['total']['p99_s'] * 1e6:.1f}us = queue "
              f"{s['queue_wait']['p99_s'] * 1e6:.1f} + batch "
              f"{s['batch']['p99_s'] * 1e6:.1f} + service "
              f"{s['service']['p99_s'] * 1e6:.1f} (stage p99s, us)")
        print(f"# met arm: attainment {doc['arms']['met']['attainment']}, "
              f"{met_events} audited; violated arm: attainment "
              f"{doc['arms']['violated']['attainment']}, {vio_events} "
              f"audited, burn {doc['arms']['violated']['burn_slow']}x")
        print(f"# wrote {path} (+{series_lines}-line {jsonl.name}, "
              f"wall {doc['wall_s']:.1f}s)")

    if vio_events < 1:
        print("FAIL: violated arm produced no audited slo event",
              file=sys.stderr)
        raise SystemExit(1)
    if met_events != 0 or doc["arms"]["met"]["attainment"] != 1.0:
        print("FAIL: met arm breached a 10x-p99 target", file=sys.stderr)
        raise SystemExit(1)
    if not decomposition_ok:
        print("FAIL: stage decomposition inconsistent with the "
              "end-to-end total", file=sys.stderr)
        raise SystemExit(1)
    if prom_problems:
        for prob in prom_problems:
            print(f"FAIL: prometheus exposition: {prob}", file=sys.stderr)
        raise SystemExit(1)
    if series_lines != vio_obs.exporter.steps or series_lines < 1:
        print(f"FAIL: JSONL series has {series_lines} lines for "
              f"{vio_obs.exporter.steps} control steps", file=sys.stderr)
        raise SystemExit(1)
    if verbose:
        print("OK: stage decomposition consistent, breaches audited, "
              "exporter output validates")
    return doc


def _shares(stage_seconds: dict) -> tuple:
    total = sum(stage_seconds.values()) if stage_seconds else 0.0
    if total <= 0:
        return (0.0, 0.0, 0.0)
    return tuple(stage_seconds.get(k, 0.0) / total
                 for k in ("ingest", "infer", "flush"))


def check_speedup(sharded: dict, single_path: pathlib.Path,
                  min_speedup: float) -> int:
    """Gate: sharded aggregate median vs a same-config 1-shard datapoint."""
    single = json.loads(single_path.read_text())
    cfg_s = {k: v for k, v in sharded["config"].items() if k != "shards"}
    cfg_1 = {k: v for k, v in single["config"].items() if k != "shards"}
    if cfg_s != cfg_1:
        print("config mismatch: sharded and single runs are not comparable\n"
              f"  sharded: {cfg_s}\n  single:  {cfg_1}", file=sys.stderr)
        return 2
    base = median_agg_pps(single)
    now = median_agg_pps(sharded)
    speedup = now / base
    n = sharded["config"].get("shards", 1)
    print(f"1-shard median CATO zero_loss_pps: {base:,.0f}")
    print(f"{n}-shard median CATO zero_loss_pps: {now:,.0f}  "
          f"(speedup {speedup:.2f}x, floor {min_speedup:.2f}x)")
    if speedup < min_speedup:
        print(f"FAIL: {n}-shard speedup {speedup:.2f}x < {min_speedup:.2f}x",
              file=sys.stderr)
        return 1
    print("OK: sharded speedup above floor")
    return 0


def check_skew(doc: dict) -> int:
    """Gate: under a skewed scenario, the adaptive control plane must
    report strictly lower load_imbalance than the static RETA and no
    lower median zero-loss pps (both sides share one service
    calibration, so the comparison is same-constants by construction)."""
    agg = [r for r in doc["rows"]
           if r.get("shard") == "agg" and r["method"] == "CATO"]
    st = [r for r in agg if r.get("control") == "static"]
    dy = [r for r in agg if r.get("control") == "dynamic"]
    if not st or not dy:
        print("skew gate needs a control-plane comparison run "
              "(--scenario <skewed> with --shards > 1)", file=sys.stderr)
        return 2
    imb_st = statistics.median(r["imbalance"] for r in st)
    imb_dy = statistics.median(r["imbalance"] for r in dy)
    pps_st = statistics.median(r["zero_loss_pps"] for r in st)
    pps_dy = statistics.median(r["zero_loss_pps"] for r in dy)
    print(f"static  RETA: median imbalance {imb_st:.3f}, "
          f"median zero_loss_pps {pps_st:,.0f}")
    print(f"dynamic RETA: median imbalance {imb_dy:.3f}, "
          f"median zero_loss_pps {pps_dy:,.0f} "
          f"({pps_dy / pps_st:.2f}x static)")
    if imb_dy >= imb_st:
        print(f"FAIL: dynamic imbalance {imb_dy:.3f} not below static "
              f"{imb_st:.3f}", file=sys.stderr)
        return 1
    if pps_dy < pps_st:
        print(f"FAIL: dynamic median pps {pps_dy:,.0f} below static "
              f"{pps_st:,.0f}", file=sys.stderr)
        return 1
    print("OK: control plane beats static RETA under skew")
    return 0


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="CI-sized run")
    p.add_argument("--use-case", default="app", choices=("app", "iot"))
    p.add_argument("--shards", type=int, default=1,
                   help="worker count (RSS-steered ShardedRuntime when > 1)")
    p.add_argument("--scenario", default="uniform",
                   choices=("uniform", "zipf", "burst", "drift"),
                   help="adversarial traffic scenario (non-uniform + shards "
                   "> 1 also measures the adaptive control plane)")
    p.add_argument("--skew-gate", action="store_true",
                   help="fail unless dynamic rebalancing beats the static "
                   "RETA under the chosen skewed scenario")
    p.add_argument("--out", default=None, help="output path (default: "
                   "results/BENCH_runtime.json + repo-root symlink alias)")
    p.add_argument("--single", default=None,
                   help="1-shard datapoint to compute sharded speedup against")
    p.add_argument("--min-speedup", type=float, default=0.0,
                   help="fail if sharded median speedup vs --single is below "
                   "this (0 disables)")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="run one instrumented replay instead of the figure: "
                   "write a Chrome trace to PATH plus stage-breakdown, "
                   "metrics-snapshot, and audit-log artifacts in results/")
    p.add_argument("--trace-sample", type=float, default=1.0,
                   help="flow sampling rate for --trace (default: all flows)")
    p.add_argument("--min-reuse-speedup", type=float, default=None,
                   metavar="R", help="run the prediction-reuse A/B gate "
                   "instead of the figure (DESIGN.md §12): measure zipf "
                   "zero-loss throughput with reuse off and on, assert "
                   "threshold-0 bit-parity + zero drops, fail if on/off "
                   "speedup < R (0 measures without gating); writes "
                   "results/BENCH_runtime_zipf.json")
    p.add_argument("--slo", action="store_true",
                   help="run the SLO latency gate instead of the figure "
                   "(DESIGN.md §14): probe the fleet's replayed latency "
                   "distribution, then controlled replays against a met "
                   "and a violated self-calibrated target — assert the "
                   "per-stage p99 decomposition is consistent with the "
                   "end-to-end total, >= 1 audited slo event when "
                   "violated and none when met, and the exporter's "
                   "Prometheus/JSONL output validates; writes "
                   "results/BENCH_slo.json + slo_timeseries.jsonl")
    p.add_argument("--tenants", type=int, default=None, metavar="N",
                   help="run the multi-tenant A/B gate instead of the "
                   "figure (DESIGN.md §15): one N-tenant shared fleet "
                   "(merged extraction plan, one fused multi-model launch) "
                   "vs N independent 1-shard fleets at equal total shards, "
                   "zero-loss bisection each arm plus a per-tenant "
                   "bit-parity leg; writes results/BENCH_multitenant.json")
    p.add_argument("--min-tenant-speedup", type=float, default=0.0,
                   metavar="R", help="fail the --tenants gate if the shared "
                   "fleet's zero-loss pps is below R x the independent "
                   "fleets' rate (0 measures without gating)")
    p.add_argument("--selftune", action="store_true",
                   help="run the self-optimizing-fleet gate instead of the "
                   "figure (DESIGN.md §13): drift-scenario controlled replay "
                   "with a drift-triggered reoptimizer vs the frozen knee — "
                   "assert >= 1 audited reopt episode, zero drops through "
                   "the hot-swap, strictly better post-drift macro-F1, and "
                   "zero episodes on a uniform control arm; writes "
                   "results/BENCH_selftune.json")
    args = p.parse_args()
    if args.slo:
        run_slo_gate(smoke=args.smoke,
                     scenario=args.scenario if args.scenario != "uniform"
                     else "zipf",
                     shards=args.shards if args.shards > 1 else 4,
                     out_path=args.out)
        raise SystemExit(0)
    if args.selftune:
        run_selftune_gate(smoke=args.smoke, out_path=args.out)
        raise SystemExit(0)
    if args.tenants is not None:
        run_multitenant_gate(min_tenant_speedup=args.min_tenant_speedup,
                             smoke=args.smoke, tenants=args.tenants,
                             out_path=args.out)
        raise SystemExit(0)
    if args.min_reuse_speedup is not None:
        run_reuse_gate(min_reuse_speedup=args.min_reuse_speedup,
                       smoke=args.smoke,
                       shards=args.shards if args.shards > 1 else 4,
                       out_path=args.out)
        raise SystemExit(0)
    if args.trace is not None:
        run_traced(args.trace,
                   shards=args.shards if args.shards > 1 else 4,
                   scenario=args.scenario if args.scenario != "uniform"
                   else "zipf",
                   sample=args.trace_sample)
        raise SystemExit(0)
    doc = run(smoke=args.smoke, use_case=args.use_case, out_path=args.out,
              shards=args.shards, scenario=args.scenario)
    if args.skew_gate:
        raise SystemExit(check_skew(doc))
    if args.single is not None:
        raise SystemExit(
            check_speedup(doc, pathlib.Path(args.single), args.min_speedup))

"""Shared benchmark infrastructure: datasets, profilers, ground truth cache,
and the single datapoint-artifact writer every benchmark routes through."""
from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from repro.core import FeatureRep, SearchSpace, build_priors
from repro.traffic import (
    FEATURE_NAMES, MINI_FEATURE_NAMES, TrafficProfiler, make_dataset,
)

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"
RESULTS.mkdir(exist_ok=True)
REPO = RESULTS.parent

_CACHE = {}


def datapoint_path(name: str) -> pathlib.Path:
    """Canonical home of a benchmark datapoint artifact: results/<name>."""
    return RESULTS / name


def write_datapoint(doc: dict, out_path=None, *, name: str) -> pathlib.Path:
    """Write a JSON benchmark datapoint through the one canonical path.

    Explicit `out_path` values (a user's ``--out``, CI's artifacts dir)
    are honored verbatim. The default routes to ``results/<name>`` and
    maintains a repo-root *symlink* of the same name, so legacy readers
    — `compare_runtime`'s committed-baseline diff, ``--single
    BENCH_runtime.json``, external tooling tracking the perf trajectory
    — keep resolving without knowing about the move.
    """
    if out_path is not None:
        path = pathlib.Path(out_path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2) + "\n")
        return path
    RESULTS.mkdir(exist_ok=True)
    path = datapoint_path(name)
    path.write_text(json.dumps(doc, indent=2) + "\n")
    alias = REPO / name
    rel = os.path.relpath(path, REPO)
    if alias.is_symlink():
        if os.readlink(alias) != rel:
            alias.unlink()
            alias.symlink_to(rel)
    elif alias.exists():
        # pre-move regular file: migrate it to the alias scheme
        alias.unlink()
        alias.symlink_to(rel)
    else:
        alias.symlink_to(rel)
    return path


def iot_setup(n_flows=3000, max_pkts=128, features="mini", model="rf-fast",
              cost_metric="exec_time", seed=0):
    key = ("iot", n_flows, max_pkts, features, model, cost_metric, seed)
    if key not in _CACHE:
        ds = make_dataset("iot-class", n_flows=n_flows, max_pkts=max_pkts,
                          seed=seed)
        names = MINI_FEATURE_NAMES if features == "mini" else FEATURE_NAMES
        prof = TrafficProfiler(ds, names, model=model,
                               cost_metric=cost_metric, cost_mode="modeled",
                               seed=seed)
        _CACHE[key] = (ds, prof, names)
    return _CACHE[key]


def app_setup(n_flows=3000, max_pkts=64, model="tree",
              cost_metric="exec_time", seed=1):
    key = ("app", n_flows, max_pkts, model, cost_metric, seed)
    if key not in _CACHE:
        ds = make_dataset("app-class", n_flows=n_flows, max_pkts=max_pkts,
                          seed=seed)
        prof = TrafficProfiler(ds, FEATURE_NAMES, model=model,
                               cost_metric=cost_metric, cost_mode="modeled",
                               seed=seed)
        _CACHE[key] = (ds, prof, FEATURE_NAMES)
    return _CACHE[key]


def priors_for(space: SearchSpace, ds, prof, delta=0.4):
    X = prof.matrices_at_depth(space.max_depth)[0]
    idx = [prof.feature_names.index(f) for f in space.feature_names]
    return build_priors(space, X[:, idx], prof.train_ds.label, delta=delta)


def ground_truth(space: SearchSpace, prof, depths=None, cache_name=None):
    """Exhaustively evaluate the space; returns (reps, Y (n,2) [cost, -perf])."""
    cache_file = RESULTS / f"gt_{cache_name}.json" if cache_name else None
    if cache_file and cache_file.exists():
        data = json.loads(cache_file.read_text())
        reps = [FeatureRep(tuple(r["f"]), r["n"]) for r in data["reps"]]
        return reps, np.array(data["Y"])
    reps, Y = [], []
    t0 = time.time()
    for x in space.enumerate_all():
        if depths is not None and x.depth not in depths:
            continue
        r = prof(x)
        reps.append(x)
        Y.append([r.cost, -r.perf])
    Y = np.array(Y)
    if cache_file:
        cache_file.write_text(json.dumps({
            "reps": [{"f": list(x.features), "n": x.depth} for x in reps],
            "Y": Y.tolist(),
        }))
    print(f"# ground truth: {len(reps)} cells in {time.time()-t0:.0f}s")
    return reps, Y


def cached_profiler(prof, reps, Y):
    """Search algorithms query the exhaustive cache (the paper's ground-truth
    protocol: all 3,200 pipelines were measured once, up front)."""
    table = {x.key(): (float(c), float(-negp)) for x, (c, negp) in zip(reps, Y)}

    def profile(x):
        return table[x.key()]

    return profile


def emit(rows, header, name):
    """Print a small CSV block and save it under results/."""
    path = RESULTS / f"{name}.csv"
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(v) for v in r) + "\n")
    print(f"# wrote {path} ({len(rows)} rows)")
    return path

"""Perf gate: fresh `--smoke` run vs a baseline BENCH_runtime.json.

Runs the smoke-sized zero-loss benchmark into a scratch file, compares its
median CATO zero_loss_pps against a baseline datapoint, and exits
non-zero on a regression beyond the threshold (default 20%). Driven by
``make bench-compare``; the committed file is only ever rewritten by an
explicit ``make bench-smoke``.

The baseline defaults to the committed ``BENCH_runtime.json`` (a
repo-root symlink into ``results/``, the canonical datapoint home) —
meaningful when it was measured on the same machine (the local
workflow). Measured constants scale with host speed, so cross-machine
comparisons need one of:

- ``--baseline PATH``: compare against a datapoint measured on *this*
  machine (CI measures the PR base ref and head on the same runner);
- ``--relative``: gate the CATO/baseline-methods ratio instead of raw
  pps — host speed multiplies every method together, so the ratio
  partially cancels it (coarser: per-row calibration noise remains).

    python -m benchmarks.compare_runtime [--threshold 0.2] [--fresh path]
                                         [--baseline path] [--relative]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import tempfile

from .bench_runtime import BENCH_PATH, median_agg_pps, run


def median_cato_pps(doc: dict) -> float:
    """Median aggregate CATO rate (per-shard breakdown rows excluded)."""
    return median_agg_pps(doc, "CATO")


def relative_cato(doc: dict) -> float:
    """CATO median over the same-run non-CATO baseline median.

    Host speed multiplies every method's measured service constants, so
    it cancels in this ratio — comparable across machines."""
    base = [r["zero_loss_pps"] for r in doc["rows"]
            if r["method"] != "CATO" and r.get("shard", "agg") == "agg"]
    if not base:
        raise SystemExit("no baseline rows to normalize against")
    return median_cato_pps(doc) / statistics.median(base)


def comparable_config(doc: dict) -> dict:
    """Config key for apples-to-apples checks: a 1-shard run predating
    the `shards` field equals a modern `shards: 1` run, and a uniform
    run predating the `scenario`/`control` fields equals a modern
    `scenario: "uniform"` run."""
    cfg = dict(doc.get("config") or {})
    if cfg.get("shards") == 1:
        del cfg["shards"]
    if cfg.get("scenario") == "uniform":
        del cfg["scenario"]
    if cfg.get("control") is False:
        del cfg["control"]
    return cfg


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--threshold", type=float, default=0.20,
                   help="max tolerated fractional regression (default 0.20)")
    p.add_argument("--fresh", default=None,
                   help="reuse an existing fresh result instead of re-running")
    p.add_argument("--baseline", default=None,
                   help="baseline datapoint to diff against (default: the "
                   "committed repo-root BENCH_runtime.json)")
    p.add_argument("--relative", action="store_true",
                   help="gate CATO/baseline-methods ratio instead of raw "
                   "pps (partially machine-independent)")
    args = p.parse_args(argv)

    base_path = pathlib.Path(args.baseline) if args.baseline else BENCH_PATH
    if not base_path.exists():
        print(f"no baseline at {base_path}", file=sys.stderr)
        return 2
    committed = json.loads(base_path.read_text())

    if args.fresh:
        fresh = json.loads(pathlib.Path(args.fresh).read_text())
    else:
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            scratch = pathlib.Path(f.name)
        try:
            fresh = run(smoke=True, out_path=scratch)
        finally:
            scratch.unlink(missing_ok=True)

    if (not committed.get("smoke")
            or comparable_config(committed) != comparable_config(fresh)):
        print("config mismatch: baseline is not a smoke run with "
              "the current config — refusing an apples-to-oranges diff.\n"
              f"  baseline: smoke={committed.get('smoke')} {committed.get('config')}\n"
              f"  fresh:    smoke={fresh.get('smoke')} {fresh.get('config')}",
              file=sys.stderr)
        return 2

    if args.relative:
        base = relative_cato(committed)
        now = relative_cato(fresh)
        what = "CATO/baseline zero_loss ratio"
    else:
        base = median_cato_pps(committed)
        now = median_cato_pps(fresh)
        what = "median CATO zero_loss_pps"
    ratio = now / base
    print(f"baseline {what}: {base:,.3f}")
    print(f"fresh    {what}: {now:,.3f}  "
          f"({(ratio - 1) * 100:+.1f}%)")
    if ratio < 1.0 - args.threshold:
        print(f"FAIL: regression beyond {args.threshold:.0%}", file=sys.stderr)
        return 1
    print("OK: within threshold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Perf gate: fresh `--smoke` run vs the committed BENCH_runtime.json.

Runs the smoke-sized zero-loss benchmark into a scratch file, compares its
median CATO zero_loss_pps against the committed datapoint, and exits
non-zero on a regression beyond the threshold (default 20%). Driven by
``make bench-compare``; the committed file is only ever rewritten by an
explicit ``make bench-smoke``.

    python -m benchmarks.compare_runtime [--threshold 0.2] [--fresh path]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import tempfile
import pathlib

from .bench_runtime import BENCH_PATH, run


def median_cato_pps(doc: dict) -> float:
    vals = [r["zero_loss_pps"] for r in doc["rows"] if r["method"] == "CATO"]
    if not vals:
        raise SystemExit("no CATO rows in benchmark document")
    return statistics.median(vals)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--threshold", type=float, default=0.20,
                   help="max tolerated fractional regression (default 0.20)")
    p.add_argument("--fresh", default=None,
                   help="reuse an existing fresh result instead of re-running")
    args = p.parse_args(argv)

    if not BENCH_PATH.exists():
        print(f"no committed baseline at {BENCH_PATH}", file=sys.stderr)
        return 2
    committed = json.loads(BENCH_PATH.read_text())

    if args.fresh:
        fresh = json.loads(pathlib.Path(args.fresh).read_text())
    else:
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            scratch = pathlib.Path(f.name)
        try:
            fresh = run(smoke=True, out_path=scratch)
        finally:
            scratch.unlink(missing_ok=True)

    if not committed.get("smoke") or committed.get("config") != fresh.get("config"):
        print("config mismatch: committed baseline is not a smoke run with "
              "the current config — refusing an apples-to-oranges diff.\n"
              f"  committed: smoke={committed.get('smoke')} {committed.get('config')}\n"
              f"  fresh:     smoke={fresh.get('smoke')} {fresh.get('config')}",
              file=sys.stderr)
        return 2

    base = median_cato_pps(committed)
    now = median_cato_pps(fresh)
    ratio = now / base
    print(f"committed median CATO zero_loss_pps: {base:,.0f}")
    print(f"fresh     median CATO zero_loss_pps: {now:,.0f}  "
          f"({(ratio - 1) * 100:+.1f}%)")
    if ratio < 1.0 - args.threshold:
        print(f"FAIL: regression beyond {args.threshold:.0%}", file=sys.stderr)
        return 1
    print("OK: within threshold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

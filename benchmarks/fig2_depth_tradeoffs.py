"""Fig. 2 — (feature set, packet depth) effects on F1 and execution time.

Reproduces the motivating observation: the best feature set by F1 *changes*
with packet depth, and cheap features at high depth can beat expensive
features at low depth.
"""
from repro.core import FeatureRep

from .common import emit, iot_setup


def run(depths=(1, 2, 3, 5, 7, 10, 15, 20, 30, 50), verbose=True):
    ds, prof, names = iot_setup(features="full", model="rf-fast")
    # F_A: early message-signature stats — peak at shallow depth, then the
    #      stationary traffic dilutes the hello/message signal (paper Fig. 2a:
    #      "the ranking flips at higher packet counts");
    # F_B: long-horizon rates — useless early, improve with depth, cheap ops;
    # F_C: median family — improves with depth but pays sort cost per packet.
    FA = ("s_bytes_mean", "d_bytes_mean", "s_iat_med")
    FB = ("dur", "s_load", "d_load")
    FC = ("s_bytes_med", "d_bytes_med", "d_iat_med", "s_iat_mean")
    rows = []
    for label, feats in (("F_A", FA), ("F_B", FB), ("F_C", FC)):
        for n in depths:
            r = prof(FeatureRep(feats, n))
            rows.append((label, n, round(r.perf, 4), round(r.cost, 4)))
            if verbose:
                print(f"fig2 {label} depth={n:3d} f1={r.perf:.3f} "
                      f"exec={r.cost:.3f}us")
    emit(rows, ("set", "depth", "f1", "exec_us"), "fig2_depth_tradeoffs")
    # the headline claim: the best feature set CHANGES with packet depth
    by = {}
    for label, n, f1, c in rows:
        by.setdefault(n, []).append((f1, label))
    best_at = {n: max(v)[1] for n, v in by.items()}
    informative = {n for n, v in by.items() if max(v)[0] > 0.2}
    winners = {best_at[n] for n in informative}
    return {"best_at_depth": {n: best_at[n] for n in sorted(by)},
            "ranking_flips": len(winners) > 1}


if __name__ == "__main__":
    print(run())

"""Fig. 5 — CATO vs ALL / RFE10 / MI10 at fixed depths {10, 50, all}.

iot-class: end-to-end inference latency (5a) — latency includes packet
inter-arrival waiting, so depth dominates and CATO's shallow Pareto points
win by orders of magnitude. app-class: latency (5b) and zero-loss
throughput (5c).

`run_replayed` is the measured variant of 5c: instead of the profiler's
modeled drain rate, every point's zero-loss throughput comes from
offered-load replay through the streaming runtime (`repro.serve.runtime`)
— flow table, bucketed micro-batch dispatch, bisection to the highest
zero-drop rate. `benchmarks/bench_runtime.py` drives it standalone.
"""
from repro.core import CatoOptimizer, SearchSpace
from repro.traffic import FEATURE_NAMES, TrafficProfiler, make_dataset
from repro.traffic.synth import make_scenario_dataset

from .common import app_setup, emit, iot_setup, priors_for


def _baselines(space, prof, depths):
    from repro.core.baselines import select_all, select_mi_topk, select_rfe_topk

    prof.matrices_at_depth(space.max_depth)  # warm the full-depth cache
    y = prof.train_ds.label
    out = {}
    for n in depths:
        Xd = prof.matrices_at_depth(n)[0]
        out[f"ALL@{n}"] = select_all(space, n)
        out[f"MI10@{n}"] = select_mi_topk(space, n, Xd, y, k=10)
        out[f"RFE10@{n}"] = select_rfe_topk(space, n, Xd, y, k=10)
    return out


def run(use_case="iot", cost_metric="latency", iters=40, verbose=True):
    if use_case == "iot":
        ds, prof, names = iot_setup(features="full", model="rf-fast",
                                    cost_metric=cost_metric)
    else:
        ds, prof, names = app_setup(model="tree-fast", cost_metric=cost_metric)
    space = SearchSpace(names, max_depth=50)
    pri = priors_for(space, ds, prof)

    rows = []
    res = CatoOptimizer(space, prof, pri, seed=0).run(iters)
    for o in res.pareto_observations():
        rows.append(("CATO", o.x.depth, len(o.x.features),
                     round(o.perf, 4), float(o.cost)))
    depths = (10, 50, ds.max_pkts)  # max_pkts stands in for "entire connection"
    for label, rep in _baselines(space_cap(space, ds), prof, depths).items():
        r = prof(rep)
        rows.append((label, rep.depth, len(rep.features),
                     round(r.perf, 4), float(r.cost)))
        if verbose:
            print(f"fig5 {use_case} {label:9s} f1={r.perf:.3f} cost={r.cost:.4g}")
    if verbose:
        for o in res.pareto_observations():
            print(f"fig5 {use_case} CATO d={o.x.depth:3d} |F|={len(o.x.features)} "
                  f"f1={o.perf:.3f} cost={o.cost:.4g}")
    emit(rows, ("method", "depth", "n_features", "f1", "cost"),
         f"fig5_{use_case}_{cost_metric}")
    return rows


def space_cap(space, ds):
    return SearchSpace(space.feature_names, max_depth=ds.max_pkts)


REPLAYED_HEADER = ("method", "depth", "n_features", "f1", "zero_loss_gbps",
                   "zero_loss_pps", "p50_s", "p99_s", "drops", "compiles",
                   "shard", "scenario", "control", "imbalance",
                   "share_ingest", "share_infer", "share_flush")


def _stage_share_cols(stage_seconds: dict) -> tuple:
    """(ingest, infer, flush) service-time shares of one clock's stage
    rollup (DESIGN.md §11.2), each rounded; zeros when the rollup is
    missing (rows predating the stage accounting)."""
    total = sum(stage_seconds.values()) if stage_seconds else 0.0
    if total <= 0:
        return (0.0, 0.0, 0.0)
    return tuple(
        round(stage_seconds.get(k, 0.0) / total, 4)
        for k in ("ingest", "infer", "flush")
    )


def run_replayed(
    use_case="app",
    iters=25,
    n_flows=1500,
    max_pkts=48,
    depths=(10,),
    cost_mode="measured",
    bisect_iters=8,
    model="tree-fast",
    verbose=True,
    seed=1,
    shards=1,
    scenario="uniform",
    control=False,
):
    """Fig. 5c, measured: zero-loss throughput via streaming-runtime replay.

    The optimizer searches against the cheap *modeled* throughput metric;
    the resulting Pareto points and the ALL/MI10/RFE10 baselines are then
    each measured end-to-end: train the model, generate the pipeline, and
    bisect the highest offered load the runtime sustains with zero drops.

    With `shards > 1` every measurement runs against an RSS-steered
    `ShardedRuntime`: the headline row per method (shard="agg") reports
    the aggregate zero-loss rate, followed by one row per worker
    (shard=0..n-1) carrying that shard's steered share, drops, and
    latency tail. Single-worker runs emit only the "agg" row.

    `scenario` replays one of the adversarial workloads
    (`repro.traffic.synth.SCENARIOS`) instead of the uniform trace. With
    `control=True` (sharded runs only) every point is measured twice —
    static RETA vs the adaptive control plane (DESIGN.md §9) under one
    shared service calibration — and rows carry `control` =
    "static"/"dynamic" so the skew gate can diff them.
    """
    name = "app-class" if use_case == "app" else "iot-class"
    ds = make_scenario_dataset(name, scenario, n_flows=n_flows,
                               max_pkts=max_pkts, seed=seed)
    # the search runs against the deterministic modeled metric; cost_mode
    # only selects the replay clock's constants for the measurement phase
    prof = TrafficProfiler(ds, FEATURE_NAMES, model=model,
                           cost_metric="throughput", cost_mode="modeled",
                           scenario=scenario, seed=seed)
    space = SearchSpace(FEATURE_NAMES, max_depth=min(50, max_pkts))
    pri = priors_for(space, ds, prof)
    res = CatoOptimizer(space, prof, pri, seed=0).run(iters)
    prof.cost_mode = cost_mode

    control_cfg = None
    if control:
        if shards < 2:
            raise ValueError("control=True needs shards > 1 (the control "
                             "plane actuates a sharded fleet)")
        from repro.serve import ControlConfig

        control_cfg = ControlConfig(interval_pkts=512, imbalance_trigger=1.04)

    def point_rows(label, rep, f1, gbps, stats, mode):
        out = [(label, rep.depth, len(rep.features), round(f1, 4),
                round(gbps, 4), round(stats.offered_pps, 1),
                round(stats.latency_p50_s, 6), round(stats.latency_p99_s, 6),
                stats.drops, stats.metrics.compile_count(), "agg",
                scenario, mode, round(stats.load_imbalance, 4),
                *_stage_share_cols(stats.stage_seconds))]
        for p in stats.per_shard:
            share = p["pkts_total"] / max(stats.metrics.pkts_total, 1)
            out.append((label, rep.depth, len(rep.features), round(f1, 4),
                        round(gbps * share, 4), round(p["offered_pps"], 1),
                        round(p["latency_p50_s"], 6),
                        round(p["latency_p99_s"], 6),
                        p["drops_ring"] + p["drops_table"],
                        stats.metrics.compile_count(), p["shard"],
                        scenario, mode, round(stats.load_imbalance, 4),
                        *_stage_share_cols(p.get("stage_seconds", {}))))
        if verbose:
            extra = (f" shards={stats.n_shards} "
                     f"imb={stats.load_imbalance:.2f}"
                     if stats.n_shards > 1 else "")
            print(f"fig5r {use_case} {label:9s} [{scenario}/{mode}] "
                  f"f1={f1:.3f} zero-loss={gbps:.3f} Gbps "
                  f"p99={stats.latency_p99_s:.4g}s drops={stats.drops}{extra}")
        return out

    def measure(label, rep):
        f1, forest = prof.perf_f1(rep)
        gbps, stats = prof.replayed_throughput_gbps(
            rep, forest, bisect_iters=bisect_iters, n_shards=shards)
        out = point_rows(label, rep, f1, gbps, stats, "static")
        if control_cfg is not None:
            gbps_d, stats_d = prof.replayed_throughput_gbps(
                rep, forest, bisect_iters=bisect_iters, n_shards=shards,
                control=control_cfg)
            out += point_rows(label, rep, f1, gbps_d, stats_d, "dynamic")
        return out

    rows = []
    # CATO: the Pareto knee points found by the optimizer
    for o in res.pareto_observations():
        rows.extend(measure("CATO", o.x))
    for label, rep in _baselines(space_cap(space, ds), prof, depths).items():
        rows.extend(measure(label, rep))
    suffix = "" if shards == 1 else f"_shards{shards}"
    if scenario != "uniform":
        suffix += f"_{scenario}"
    emit(rows, REPLAYED_HEADER,
         f"fig5_{use_case}_throughput_replayed{suffix}")
    return rows


def summarize(rows):
    """Headline ratios: latency/throughput of CATO's F1-matched point."""
    cato = [(r[4], r[3]) for r in rows if r[0] == "CATO"]
    base = [(r[0], r[4], r[3]) for r in rows if r[0] != "CATO"]
    out = {}
    for label, cost, f1 in base:
        # best CATO point with >= f1 - 0.01
        elig = [c for c, p in cato if p >= f1 - 0.01]
        if elig:
            out[label] = cost / min(elig)
    return out


if __name__ == "__main__":
    rows = run("iot", "latency")
    print("iot latency speedups:", summarize(rows))
    rows = run("app", "latency")
    print("app latency speedups:", summarize(rows))
    rows = run("app", "throughput", iters=40)
    print("app throughput gains:", {k: 1 / v for k, v in summarize(rows).items()})
    rows = run_replayed("app", iters=25)
    best = max(r[4] for r in rows if r[0] == "CATO")
    base = {r[0]: best / r[4] for r in rows if r[0] != "CATO" and r[4] > 0}
    print("app replayed zero-loss gains (CATO-best / baseline):", base)

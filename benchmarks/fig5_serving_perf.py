"""Fig. 5 — CATO vs ALL / RFE10 / MI10 at fixed depths {10, 50, all}.

iot-class: end-to-end inference latency (5a) — latency includes packet
inter-arrival waiting, so depth dominates and CATO's shallow Pareto points
win by orders of magnitude. app-class: latency (5b) and zero-loss
throughput (5c).
"""
import numpy as np

from repro.core import CatoOptimizer, FeatureRep, SearchSpace

from .common import app_setup, emit, iot_setup, priors_for


def _baselines(space, prof, depths):
    from repro.core.baselines import select_all, select_mi_topk, select_rfe_topk

    Xfull = prof.matrices_at_depth(space.max_depth)[0]
    y = prof.train_ds.label
    out = {}
    for n in depths:
        Xd = prof.matrices_at_depth(n)[0]
        out[f"ALL@{n}"] = select_all(space, n)
        out[f"MI10@{n}"] = select_mi_topk(space, n, Xd, y, k=10)
        out[f"RFE10@{n}"] = select_rfe_topk(space, n, Xd, y, k=10)
    return out


def run(use_case="iot", cost_metric="latency", iters=40, verbose=True):
    if use_case == "iot":
        ds, prof, names = iot_setup(features="full", model="rf-fast",
                                    cost_metric=cost_metric)
    else:
        ds, prof, names = app_setup(model="tree-fast", cost_metric=cost_metric)
    space = SearchSpace(names, max_depth=50)
    pri = priors_for(space, ds, prof)

    rows = []
    res = CatoOptimizer(space, prof, pri, seed=0).run(iters)
    for o in res.pareto_observations():
        rows.append(("CATO", o.x.depth, len(o.x.features),
                     round(o.perf, 4), float(o.cost)))
    depths = (10, 50, ds.max_pkts)  # max_pkts stands in for "entire connection"
    for label, rep in _baselines(space_cap(space, ds), prof, depths).items():
        r = prof(rep)
        rows.append((label, rep.depth, len(rep.features),
                     round(r.perf, 4), float(r.cost)))
        if verbose:
            print(f"fig5 {use_case} {label:9s} f1={r.perf:.3f} cost={r.cost:.4g}")
    if verbose:
        for o in res.pareto_observations():
            print(f"fig5 {use_case} CATO d={o.x.depth:3d} |F|={len(o.x.features)} "
                  f"f1={o.perf:.3f} cost={o.cost:.4g}")
    emit(rows, ("method", "depth", "n_features", "f1", "cost"),
         f"fig5_{use_case}_{cost_metric}")
    return rows


def space_cap(space, ds):
    return SearchSpace(space.feature_names, max_depth=ds.max_pkts)


def summarize(rows):
    """Headline ratios: latency/throughput of CATO's F1-matched point."""
    cato = [(r[4], r[3]) for r in rows if r[0] == "CATO"]
    base = [(r[0], r[4], r[3]) for r in rows if r[0] != "CATO"]
    out = {}
    for label, cost, f1 in base:
        # best CATO point with >= f1 - 0.01
        elig = [c for c, p in cato if p >= f1 - 0.01]
        if elig:
            out[label] = cost / min(elig)
    return out


if __name__ == "__main__":
    rows = run("iot", "latency")
    print("iot latency speedups:", summarize(rows))
    rows = run("app", "latency")
    print("app latency speedups:", summarize(rows))
    rows = run("app", "throughput", iters=40)
    print("app throughput gains:", {k: 1 / v for k, v in summarize(rows).items()})

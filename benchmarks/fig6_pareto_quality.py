"""Fig. 6 — Pareto-front quality (HVI) vs alternative search algorithms on
the exhaustively-measured 6-feature ground-truth space."""
import numpy as np

from repro.core import CatoOptimizer, MemoizedEvaluator, SearchSpace, hvi_ratio
from repro.core.baselines import (
    run_iterate_all, run_random_search, run_simulated_annealing,
)

from .common import cached_profiler, emit, ground_truth, iot_setup, priors_for


def run(iters=50, max_depth=50, seed=0, verbose=True):
    ds, prof, names = iot_setup(features="mini", model="rf-fast")
    space = SearchSpace(names, max_depth=max_depth)
    reps, Yt = ground_truth(space, prof, cache_name=f"iot_mini_{max_depth}")
    # ONE memoized evaluator shared by CATO and every baseline: the
    # cost comparison is measured through identical code, and a config
    # any algorithm already evaluated is free for the others
    ev = MemoizedEvaluator(cached_profiler(prof, reps, Yt))
    pri = priors_for(space, ds, prof)

    runs = {
        "CATO": lambda: CatoOptimizer(space, ev, pri, seed=seed).run(iters),
        "CATO-BASE": lambda: CatoOptimizer(space, ev, None, seed=seed).run(iters),
        "SIMANNEAL": lambda: run_simulated_annealing(space, ev, iters, seed=seed),
        "RANDSEARCH": lambda: run_random_search(space, ev, iters, seed=seed),
        "ITERATEALL": lambda: run_iterate_all(space, ev, iters),
    }
    rows = []
    for name, fn in runs.items():
        res = fn()
        Y = np.array([o.objectives for o in res.observations])
        h = hvi_ratio(Y, Yt)
        # high-F1 region only (paper: F1 >= 0.8)
        hi = Yt[Yt[:, 1] <= -0.8 * (-Yt[:, 1]).max()]
        h_hi = hvi_ratio(Y, hi) if len(hi) > 2 else float("nan")
        rows.append((name, iters, round(h, 4), round(h_hi, 4)))
        if verbose:
            print(f"fig6 {name:11s} HVI={h:.3f} HVI(hiF1)={h_hi:.3f}")
    emit(rows, ("method", "iters", "hvi", "hvi_high_f1"), "fig6_pareto_quality")
    return rows


if __name__ == "__main__":
    run()

"""Fig. 7 — convergence speed to the true Pareto front (iterations to HVI
thresholds, mean over seeds; CATO vs CATO-BASE vs SA vs random).

Rows carry a `fallbacks` column — the mean number of iterations whose
surrogate fit failed and silently degraded proposal to random search —
so a CATO convergence curve can be told apart from accidental random."""
import numpy as np

from repro.core import CatoOptimizer, MemoizedEvaluator, SearchSpace, hvi_ratio
from repro.core.baselines import run_random_search, run_simulated_annealing

from .common import cached_profiler, emit, ground_truth, iot_setup, priors_for


def _iters_to(Yt, observations, threshold):
    Y = []
    for i, o in enumerate(observations):
        Y.append(o.objectives)
        if hvi_ratio(np.array(Y), Yt) >= threshold:
            return i + 1
    return None


def run(budget=300, seeds=(0, 1, 2), threshold=0.99, verbose=True):
    ds, prof, names = iot_setup(features="mini", model="rf-fast")
    space = SearchSpace(names, max_depth=50)
    reps, Yt = ground_truth(space, prof, cache_name="iot_mini_50")
    # shared memoized evaluator: every algorithm measures through the
    # same code path, and repeat configs are free across algorithms
    ev = MemoizedEvaluator(cached_profiler(prof, reps, Yt))
    pri = priors_for(space, ds, prof)

    algos = {
        "CATO": lambda s: CatoOptimizer(space, ev, pri, seed=s).run(budget),
        "CATO-BASE": lambda s: CatoOptimizer(space, ev, None, seed=s).run(budget),
        "SIMANNEAL": lambda s: run_simulated_annealing(space, ev, budget, seed=s),
        "RANDSEARCH": lambda s: run_random_search(space, ev, budget, seed=s),
    }
    rows = []
    for name, fn in algos.items():
        its, falls = [], []
        for s in seeds:
            res = fn(s)
            it = _iters_to(Yt, res.observations, threshold)
            its.append(it if it is not None else budget * 2)  # censored
            falls.append(len(res.surrogate_fallbacks))
        mean = float(np.mean(its))
        fb = float(np.mean(falls))
        rows.append((name, threshold, mean, min(its), max(its), fb))
        if verbose:
            print(f"fig7 {name:11s} iters-to-{threshold} HVI: "
                  f"mean={mean:.0f} range=[{min(its)},{max(its)}]"
                  + (" (censored)" if max(its) >= budget * 2 else "")
                  + (f" surrogate-fallbacks={fb:.1f}" if fb else ""))
    emit(rows, ("method", "threshold", "mean_iters", "min", "max",
                "fallbacks"),
         "fig7_convergence")
    return rows


if __name__ == "__main__":
    run()

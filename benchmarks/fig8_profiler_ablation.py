"""Fig. 8 — Profiler ablation: replace measured cost/perf with heuristics,
re-evaluate each variant's sampled points on the TRUE metrics post-hoc."""
import numpy as np

from repro.core import CatoOptimizer, SearchSpace, hvi_ratio

from .common import emit, ground_truth, iot_setup, priors_for


def run(iters=40, verbose=True):
    ds, prof, names = iot_setup(features="mini", model="rf-fast")
    space = SearchSpace(names, max_depth=50)
    reps, Yt = ground_truth(space, prof, cache_name="iot_mini_50")
    pri = priors_for(space, ds, prof)

    variants = {
        "CATO (measured)": "exec_time",
        "w/ naive cost": "naive_cost",
        "w/ model inf cost": "model_inf_cost",
        "w/ pkt depth cost": "pkt_depth_cost",
        "w/ naive perf": "naive_perf",
    }
    rows = []
    for label, metric in variants.items():
        def profile(x, metric=metric):
            return prof(x, metric=metric)

        res = CatoOptimizer(space, profile, pri, seed=0).run(iters)
        # post-hoc: evaluate every sampled point on the TRUE objectives
        Ytrue = []
        for o in res.observations:
            r = prof.true_metrics(o.x)
            Ytrue.append([r.cost, -r.perf])
        h = hvi_ratio(np.array(Ytrue), Yt)
        rows.append((label, iters, round(h, 4)))
        if verbose:
            print(f"fig8 {label:20s} true-HVI={h:.3f}")
    emit(rows, ("variant", "iters", "true_hvi"), "fig8_profiler_ablation")
    return rows


if __name__ == "__main__":
    run()

"""Fig. 9 — sensitivity to the damping coefficient delta and BO init count."""
import numpy as np

from repro.core import CatoOptimizer, SearchSpace, hvi_ratio

from .common import cached_profiler, emit, ground_truth, iot_setup, priors_for


def run(deltas=(0.0, 0.2, 0.4, 0.7, 1.0), inits=(1, 3, 5, 10), iters=40,
        verbose=True):
    ds, prof, names = iot_setup(features="mini", model="rf-fast")
    space = SearchSpace(names, max_depth=50)
    reps, Yt = ground_truth(space, prof, cache_name="iot_mini_50")
    cached = cached_profiler(prof, reps, Yt)

    rows = []
    for d in deltas:
        pri = priors_for(space, ds, prof, delta=d)
        res = CatoOptimizer(space, cached, pri, seed=0).run(iters)
        Y = np.array([o.objectives for o in res.observations])
        h = hvi_ratio(Y, Yt)
        rows.append(("delta", d, round(h, 4)))
        if verbose:
            print(f"fig9 delta={d:.1f} HVI={h:.3f}")
    pri = priors_for(space, ds, prof, delta=0.4)
    for n0 in inits:
        res = CatoOptimizer(space, cached, pri, n_init=n0, seed=0).run(iters)
        Y = np.array([o.objectives for o in res.observations])
        h = hvi_ratio(Y, Yt)
        rows.append(("n_init", n0, round(h, 4)))
        if verbose:
            print(f"fig9 init={n0} HVI={h:.3f}")
    emit(rows, ("knob", "value", "hvi"), "fig9_sensitivity")
    return rows


if __name__ == "__main__":
    run()

"""Assemble EXPERIMENTS.md sections from results/ JSON + CSV artifacts."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def dryrun_table() -> str:
    rows = []
    for p in sorted(RESULTS.glob("dryrun/*__base.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            m = r["main"]["memory"]
            args_gb = m.get("argument_size_in_bytes", 0) / 2 ** 30
            temp_gb = m.get("temp_size_in_bytes", 0) / 2 ** 30
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['mode']} | "
                f"{args_gb:.2f} | {temp_gb:.2f} | "
                f"{r['main']['collectives']['count']} | "
                f"{r['main']['compile_s']:.0f}s |"
            )
        elif r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | - | - | - | "
                f"{r['skip_reason'][:60]} |"
            )
        else:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | - | - | - | "
                f"{r.get('error','')[:60]} |"
            )
    head = ("| arch | shape | mesh | mode | args GiB/dev | temps GiB/dev | "
            "coll ops (scanned HLO) | compile |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def roofline_table(tag="base") -> str:
    p = RESULTS / f"roofline_{tag}.json"
    rows = json.loads(p.read_text())
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful ratio | roofline % | what moves it |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | skipped | - | - | "
                f"{r.get('skip_reason','')[:60]} |"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']*100:.1f} | {r['fix'][:60]} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    import sys

    what = sys.argv[1] if len(sys.argv) > 1 else "all"
    if what in ("dryrun", "all"):
        print("## §Dry-run\n")
        print(dryrun_table())
    if what in ("roofline", "all"):
        print("\n## §Roofline\n")
        print(roofline_table())

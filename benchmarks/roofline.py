"""§Roofline — derive compute/memory/collective terms per (arch × shape).

Reads results/dryrun/*.json (written by repro.launch.dryrun), computes the
three roofline terms on the single-pod mesh per the hardware model:

    compute    = HLO_FLOPs_per_chip / 197e12        (bf16 peak per chip)
    memory     = HLO_bytes_per_chip / 819e9         (HBM bandwidth)
    collective = HLO_collective_bytes_per_chip / 50e9 (per-chip ICI link)

HLO numbers come from the probe-extrapolated per-device HLO analysis
(exact dot FLOPs; byte traffic under the fusion model; collective payloads
with all-reduce 2x and ring (n-1)/n). CAVEATS recorded in EXPERIMENTS.md:
XLA-CPU promotes bf16 arithmetic to f32, so byte/collective terms are ~2x
upper bounds for tensors that are bf16 on TPU; sLSTM's in-loop recurrence
is analytically corrected (+2*T*d*4d per sLSTM layer fwd, x3 with backward).

MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (prefill,
decode) plus the quadratic attention term for attention architectures.
"""
import json
import math
import pathlib

from repro import configs
from repro.models.config import SHAPES

PEAK = 197e12       # bf16 FLOP/s per chip
HBM = 819e9         # bytes/s per chip
LINK = 50e9         # bytes/s per chip ICI (1-link conservative; /4 if all used)
CHIPS = 256

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def model_flops(cfg, shape) -> float:
    """Analytic useful-FLOPs for the whole step (global, fwd+bwd for train)."""
    n = cfg.active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        k = 6.0
        attn_mult = 3.0  # fwd + bwd(2x)
        ctx = shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        k = 2.0
        attn_mult = 1.0
        ctx = shape.seq_len
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        k = 2.0
        attn_mult = 1.0
        ctx = shape.seq_len  # attends over the whole cache
    total = k * n * tokens
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        # QK^T + PV: 2 matmuls, causal ~half for self-attn train/prefill
        L = cfg.n_layers + cfg.encoder_layers
        causal = 0.5 if shape.kind != "decode" else 1.0
        attn = attn_mult * 2 * 2 * tokens * ctx * causal * cfg.n_heads * cfg.hd * L
        total += attn
    if cfg.family == "hybrid":
        n_attn = math.ceil(cfg.n_layers / cfg.shared_attn_every)
        causal = 0.5 if shape.kind != "decode" else 1.0
        total += attn_mult * 4 * tokens * ctx * causal * cfg.n_heads * cfg.hd * n_attn
    return total


def slstm_correction(cfg, shape) -> float:
    """In-loop recurrent matmul not visible to the HLO dot counter."""
    if cfg.family != "ssm":
        return 0.0
    n_slstm = cfg.n_layers // 2
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * n_slstm * tokens * 2 * cfg.d_model * 4 * cfg.d_model / CHIPS


def load_cells(tag="base", mesh="pod"):
    cells = {}
    for p in sorted(RESULTS.glob(f"dryrun/*__{mesh}__{tag}.json")):
        r = json.loads(p.read_text())
        cells[(r["arch"], r["shape"])] = r
    return cells


def roofline_row(rec) -> dict:
    cfg = configs.get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    ext = rec.get("extrapolated")
    if rec.get("status") == "ok" and not ext:
        # no probes: the scanned main compile carries transitive
        # trip-count multipliers (validated within 1-4% of probes)
        ext = rec.get("main")
    if rec.get("status") != "ok" or not ext:
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": rec.get("status"),
                "skip_reason": rec.get("skip_reason", rec.get("error", ""))[:90]}
    fl = ext["flops"] + slstm_correction(cfg, shape)     # per device
    by = ext.get("bytes_hbm", ext["bytes_accessed"])
    coll = ext["collectives"]
    coll_b = sum(coll[k] for k in
                 ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute"))
    t_comp = fl / PEAK
    t_mem = by / HBM
    t_coll = coll_b / LINK
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cfg, shape)
    ratio = mf / (fl * CHIPS) if fl > 0 else float("nan")
    mfu_at_bound = (mf / CHIPS / PEAK) / bound if bound > 0 else float("nan")
    fixes = {
        "compute": "raise useful-FLOP fraction: trim remat policy / fuse "
                   "elementwise into matmuls",
        "memory": "keep activations bf16 end-to-end, fuse attention "
                  "(Pallas flash kernel), larger per-chip tiles",
        "collective": "reshard to cut all-gathers (sequence-parallel norms, "
                      "reduce-scatter instead of all-reduce), overlap with "
                      "compute",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mode": rec.get("mode"),
        "status": "ok",
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf, "hlo_flops_global": fl * CHIPS,
        "useful_ratio": ratio,
        "roofline_fraction": mfu_at_bound,
        "bytes_per_dev": rec["main"]["memory"].get("temp_size_in_bytes", 0)
        + rec["main"]["memory"].get("argument_size_in_bytes", 0),
        "fix": fixes[dom],
    }


def run(tag="base", verbose=True):
    cells = load_cells(tag)
    rows = []
    for (arch, shape), rec in sorted(cells.items()):
        row = roofline_row(rec)
        rows.append(row)
        if verbose and row.get("status") == "ok":
            print(f"roofline {arch:18s} {shape:12s} "
                  f"comp={row['t_compute_s']*1e3:9.2f}ms "
                  f"mem={row['t_memory_s']*1e3:9.2f}ms "
                  f"coll={row['t_collective_s']*1e3:9.2f}ms "
                  f"dom={row['dominant']:10s} "
                  f"useful={row['useful_ratio']:.2f} "
                  f"roofline={row['roofline_fraction']*100:5.1f}%")
        elif verbose:
            print(f"roofline {arch:18s} {shape:12s} -- {row.get('status')}: "
                  f"{row.get('skip_reason','')[:70]}")
    out = RESULTS / f"roofline_{tag}.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"# wrote {out}")
    return rows


if __name__ == "__main__":
    import sys
    run(tag=sys.argv[1] if len(sys.argv) > 1 else "base")

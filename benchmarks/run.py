"""Benchmark runner: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per artifact plus summary rows.
Full experiments: run each module directly (python -m benchmarks.fig6_...).
"""
import time


def main() -> None:
    rows = []

    def timed(name, fn, derived=""):
        t0 = time.perf_counter()
        out = fn()
        us = (time.perf_counter() - t0) * 1e6
        rows.append((name, us, derived(out) if callable(derived) else derived))
        return out

    from . import bench_kernels
    for name, us in bench_kernels.run(verbose=False):
        rows.append((f"kernel/{name}", us, "interpret-mode us/call"))

    from . import fig2_depth_tradeoffs
    timed("fig2_depth_tradeoffs",
          lambda: fig2_depth_tradeoffs.run(depths=(1, 3, 5, 8, 15, 30), verbose=False),
          lambda o: f"ranking_flips={o['ranking_flips']}")

    from . import fig6_pareto_quality
    timed("fig6_pareto_quality", lambda: fig6_pareto_quality.run(verbose=False),
          lambda rows_: ";".join(f"{r[0]}={r[2]}" for r in rows_))

    from . import fig8_profiler_ablation
    timed("fig8_profiler_ablation",
          lambda: fig8_profiler_ablation.run(iters=25, verbose=False),
          lambda rows_: ";".join(f"{r[0]}={r[2]}" for r in rows_))

    from . import table4_wallclock
    timed("table4_wallclock", lambda: table4_wallclock.run(iters=8, verbose=False),
          lambda rows_: f"total_per_iter={rows_[-2][1]}s")

    # roofline summary if the dry-run matrix has results
    try:
        from . import roofline
        rl = roofline.run(verbose=False)
        ok = [r for r in rl if r.get("status") == "ok"]
        if ok:
            worst = min(ok, key=lambda r: r["roofline_fraction"])
            best = max(ok, key=lambda r: r["roofline_fraction"])
            rows.append(("roofline_cells", len(ok) * 1.0,
                         f"best={best['arch']}/{best['shape']}"
                         f"@{best['roofline_fraction']*100:.0f}%;"
                         f"worst={worst['arch']}/{worst['shape']}"
                         f"@{worst['roofline_fraction']*100:.0f}%"))
    except Exception as e:  # dry-run not complete yet
        rows.append(("roofline_cells", 0.0, f"pending: {e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

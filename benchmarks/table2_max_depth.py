"""Table 2 — robustness to the maximum connection depth N (67 features)."""
from repro.core import CatoOptimizer, SearchSpace

from .common import emit, iot_setup, priors_for


def run(max_depths=(3, 5, 10, 25, 50, 100), iters=35, verbose=True):
    ds, prof, names = iot_setup(features="full", model="rf-fast")
    rows = []
    for N in max_depths:
        N_eff = min(N, ds.max_pkts)
        space = SearchSpace(names, max_depth=N_eff)
        pri = priors_for(space, ds, prof)
        res = CatoOptimizer(space, prof, pri, seed=0).run(iters)
        best_f1 = res.best_by_perf()
        best_cost = res.best_by_cost()
        rows.append((N, best_f1.x.depth, round(best_f1.perf, 3),
                     round(best_f1.cost, 3), best_cost.x.depth,
                     round(best_cost.perf, 3), round(best_cost.cost, 3)))
        if verbose:
            print(f"table2 N={N:4d}: bestF1 n={best_f1.x.depth} "
                  f"f1={best_f1.perf:.3f} t={best_f1.cost:.2f}us | "
                  f"minCost n={best_cost.x.depth} f1={best_cost.perf:.3f} "
                  f"t={best_cost.cost:.2f}us")
    emit(rows, ("max_depth", "n_bestf1", "f1_best", "t_bestf1",
                "n_mincost", "f1_mincost", "t_mincost"), "table2_max_depth")
    return rows


if __name__ == "__main__":
    run()

"""Table 4 — optimization wall-clock decomposition (measured cost mode)."""
import time

from repro.core import CatoOptimizer, SearchSpace
from repro.traffic import TrafficProfiler

from .common import emit, iot_setup, priors_for


def run(iters=15, verbose=True):
    ds, _, names = iot_setup(features="mini")
    prof = TrafficProfiler(ds, names, model="rf-fast",
                           cost_metric="exec_time", cost_mode="measured",
                           seed=0, cache=False)
    space = SearchSpace(names, max_depth=50)
    pri = priors_for(space, ds, prof)

    t0 = time.perf_counter()
    opt = CatoOptimizer(space, prof, pri, seed=0)
    opt.run(iters)
    total = time.perf_counter() - t0
    w = prof.wallclock
    bo_sample = total - sum(w.values())
    rows = [
        ("preprocessing+BO sample", round(bo_sample / iters, 3)),
        ("pipeline generation", round(w["pipeline_gen"] / iters, 3)),
        ("measure perf(x) [train+eval]", round(w["train_perf"] / iters, 3)),
        ("measure cost(x)", round(w["measure_cost"] / iters, 3)),
        ("TOTAL per iteration", round(total / iters, 3)),
        ("TOTAL elapsed", round(total, 1)),
    ]
    if verbose:
        for k, v in rows:
            print(f"table4 {k:32s} {v:>8}s")
    emit(rows, ("stage", "seconds"), "table4_wallclock")
    return rows


if __name__ == "__main__":
    run()

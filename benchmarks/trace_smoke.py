"""Observability overhead gate: tracing must be free when it is off.

The §11 contract is that a runtime with no bundle attached — or with a
disabled tracer injected — pays one ``is not None`` test per hook site
and nothing else. This benchmark measures that claim on the actual
replay hot path and fails the build when it stops holding:

- **detached**: plain replay, no `Observability` bundle (the baseline
  every serving measurement in this repo runs as);
- **disabled**: bundle attached with `Tracer(enabled=False)` and *no*
  latency recording — the configuration a fleet runs in production
  when tracing is off; this path crosses every hook site the §14
  latency/SLO instrumentation added, so the gate covers those too;
- **enabled**: full flow-lifecycle + stage tracing at sample=1.0
  (reported for context; never gated — tracing costs what it costs);
- **latency**: per-component sketch recording + SLO tracking attached
  (DESIGN.md §14), tracer disabled (reported for context).

The latency round also binds a `MetricsExporter` and pushes the fleet's
Prometheus rendering through `check_prometheus`; format problems fail
the gate like an overhead regression would.

Each round times all three modes back-to-back (order rotating) and the
reported overhead is the **median over rounds of the same-round
wall-clock ratio** — a slow host stretch inflates every mode of the
round it lands on and cancels in the ratio, which best-of-K minima
cannot do when noise is correlated over seconds. Timing-only runtimes
keep jit jitter out of the measurement. `--gate` fails if
disabled/detached exceeds the threshold on three independent
measurement attempts (a real regression shifts every attempt; a
shared-runner noise stretch does not); the CI bench job runs it with
the default 5%.

    python -m benchmarks.trace_smoke --gate 5
"""
from __future__ import annotations

import argparse
import gc
import statistics
import sys
import time


def _fixture(n_flows: int, max_pkts: int):
    from repro.core.search_space import FeatureRep
    from repro.serve import PacketStream, ServiceModel
    from repro.traffic import extract_features
    from repro.traffic.models import train_traffic_model
    from repro.traffic.pipeline import build_pipeline
    from repro.traffic.synth import make_scenario_dataset

    ds = make_scenario_dataset("app-class", "zipf", n_flows=n_flows,
                               max_pkts=max_pkts, seed=3)
    rep = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                      "ack_cnt"), depth=8)
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    pipe = build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)
    stream = PacketStream.from_dataset(ds, seed=0)
    service = ServiceModel(
        pkt_accum_ns=800.0, pkt_track_ns=200.0,
        bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
        gather_ns_per_flow=200.0, source="synthetic",
    )
    return pipe, stream, service


def run(repeats: int = 5, n_flows: int = 1200, max_pkts: int = 128,
        shards: int = 4, offered_pps: float = 2e5,
        verbose: bool = True) -> dict:
    from repro.serve import (LatencyConfig, MetricsExporter, Observability,
                             ServeSession, ShardedRuntime, SLOConfig,
                             SLOTracker, Tracer, check_prometheus, replay)

    pipe, stream, service = _fixture(n_flows, max_pkts)

    def make_runtime():
        # timing-only (execute=False): the gate measures the ingest /
        # dispatch / clock hot path, not jit execution jitter
        return ShardedRuntime(pipe, n_shards=shards, capacity=2048,
                              max_batch=64, execute=False)

    def bundle(mode: str):
        if mode == "detached":
            return None
        obs = Observability(
            tracer=Tracer(capacity=1 << 15, sample=1.0,
                          enabled=(mode == "enabled")))
        if mode == "latency":
            obs.latency = LatencyConfig()
            obs.slo = SLOTracker(SLOConfig(target_s=1e-3, window_s=0.01))
        return obs

    modes = ("detached", "disabled", "enabled", "latency")

    def one(mode: str) -> float:
        obs = bundle(mode)  # tracer allocation outside the timed region
        gc.collect()  # prior runs' collector debt stays out of the gap
        gc.disable()  # cyclic-GC pauses mid-replay dominate mode deltas
        try:
            t0 = time.perf_counter()
            replay(stream, make_runtime, offered_pps, service,
                   session=None if obs is None else ServeSession(obs=obs))
            return time.perf_counter() - t0
        finally:
            gc.enable()

    # warmup pass (cold caches, lazy imports), then rounds: each round
    # times every mode back-to-back (order rotating so no mode owns a
    # slot) and contributes one same-round ratio per instrumented mode —
    # host-load stretches slower than a round inflate the whole round
    # and cancel in the ratio
    for m in modes:
        one(m)
    walls = {m: float("inf") for m in modes}
    ratios: dict[str, list[float]] = {"disabled": [], "enabled": [],
                                      "latency": []}
    for r in range(repeats):
        t: dict[str, float] = {}
        for m in modes[r % len(modes):] + modes[:r % len(modes)]:
            t[m] = one(m)
            walls[m] = min(walls[m], t[m])
        for m in ratios:
            ratios[m].append(t[m] / t["detached"])
    overhead = {m: statistics.median(rs) - 1.0 for m, rs in ratios.items()}

    # format-validity pass (untimed): a latency-instrumented replay with
    # a bound exporter must render Prometheus text that validates
    obs = bundle("latency")
    obs.exporter = MetricsExporter()
    replay(stream, make_runtime, offered_pps, service,
           session=ServeSession(obs=obs))
    problems = check_prometheus(obs.exporter.prometheus())

    out = {
        "bench": "trace_overhead",
        "config": {"repeats": repeats, "n_flows": n_flows,
                   "max_pkts": max_pkts, "shards": shards,
                   "offered_pps": offered_pps,
                   "events": int(stream.n_events)},
        "wall_s": {m: round(w, 4) for m, w in walls.items()},
        "overhead_pct": {m: round(100 * o, 2) for m, o in overhead.items()},
        "prometheus_problems": problems,
    }
    if verbose:
        for m in modes:
            extra = (f"  ({out['overhead_pct'][m]:+.2f}% median same-round"
                     " vs detached)" if m != "detached" else "")
            print(f"{m:9s} best-of-{repeats}: {walls[m]*1e3:8.2f} ms{extra}")
    return out


def check_gate(doc: dict, gate_pct: float) -> int:
    """Fail when the tracing-*disabled* path regresses replay wall-clock
    beyond `gate_pct` percent of the untraced baseline, or when the
    exporter's Prometheus rendering stops validating. The enabled and
    latency-recording paths are informational only."""
    problems = doc.get("prometheus_problems", [])
    if problems:
        for p in problems:
            print(f"FAIL: prometheus exposition: {p}", file=sys.stderr)
        return 1
    over = doc["overhead_pct"]["disabled"]
    n = len(doc.get("attempts", [over]))
    if over > gate_pct:
        print(f"FAIL: tracing-disabled replay is {over:+.2f}% vs untraced "
              f"baseline (gate {gate_pct:.1f}%, {n} attempts)",
              file=sys.stderr)
        return 1
    print(f"OK: tracing-disabled overhead {over:+.2f}% within "
          f"{gate_pct:.1f}% gate")
    return 0


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--repeats", type=int, default=5,
                   help="paired measurement rounds (each times all modes)")
    p.add_argument("--shards", type=int, default=4)
    p.add_argument("--n-flows", type=int, default=1200)
    p.add_argument("--max-pkts", type=int, default=128)
    p.add_argument("--gate", type=float, default=None, metavar="PCT",
                   help="fail if tracing-disabled wall-clock exceeds the "
                   "untraced baseline by more than PCT percent")
    p.add_argument("--out", default=None, help="output path (default: "
                   "results/BENCH_trace.json)")
    args = p.parse_args()
    # a genuine hot-path regression shifts every measurement; shared-host
    # noise stretches do not survive independent attempts — so the gate
    # re-measures (up to 3x) and fails only on unanimous exceedance
    attempts: list[float] = []
    doc = {}
    for k in range(3 if args.gate is not None else 1):
        if k:
            print(f"# over gate on attempt {k}; re-measuring")
        doc = run(repeats=args.repeats, n_flows=args.n_flows,
                  max_pkts=args.max_pkts, shards=args.shards)
        attempts.append(doc["overhead_pct"]["disabled"])
        if args.gate is None or attempts[-1] <= args.gate:
            break
    doc["attempts"] = attempts
    from .common import write_datapoint

    path = write_datapoint(doc, args.out, name="BENCH_trace.json")
    print(f"# wrote {path}")
    if args.gate is not None:
        raise SystemExit(check_gate(doc, args.gate))

"""Tune-smoke: the multi-fidelity loop vs sequential search, gated on
measured-fidelity hypervolume at equal measurement budget.

Runs the batched multi-fidelity tuner (cheap `modeled` fidelity +
expensive `replayed_sharded` measurements through the serving runtime,
under a zipf elephant-flow scenario) on the smoke fixture, alongside
the sequential single-fidelity CATO loop and the RANDSEARCH /
SIMANNEAL / ITERATEALL baselines — every algorithm spending the *same*
number of measured-fidelity evaluations, and all of them measuring
through ONE shared memoized evaluator (a config any algorithm already
measured is free for the rest, and results are bit-identical across
algorithms — DESIGN.md §10.2).

The budget unit is measured evaluations: one measured evaluation is a
full zero-loss bisection through the sharded runtime (the wall-clock
cost that matters), while a cheap modeled evaluation is ~5 orders of
magnitude cheaper; the artifact records per-fidelity wall-clock so the
"equal wall-clock" reading can be audited.

Gate (`--gate`, the CI `tune-smoke` step): CATO's multi-fidelity
measured-fidelity hypervolume must be >= the sequential loop's and >=
every baseline's. The artifact lands at `results/BENCH_tune.json`
(repo-root symlink alias) like the other datapoints.

    python -m benchmarks.tune_smoke --gate
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import (
    CatoOptimizer, MemoizedEvaluator, SearchSpace, hypervolume_2d,
    pareto_mask,
)
from repro.core.baselines import (
    run_iterate_all, run_random_search, run_simulated_annealing,
)
from repro.core.pareto import normalize_objectives
from repro.traffic import FEATURE_NAMES, TrafficProfiler, backend_suite
from repro.traffic.synth import make_scenario_dataset

from .common import priors_for, write_datapoint

MEASURED = "replayed_sharded"


def measured_hv(per_method: dict[str, list]) -> dict[str, float]:
    """Hypervolume of each method's measured observations, normalized
    jointly over the union so the numbers are comparable."""
    union = np.array(
        [o.objectives for obs in per_method.values() for o in obs],
        dtype=np.float64,
    )
    _, lo, hi = normalize_objectives(union)
    out = {}
    for name, obs in per_method.items():
        Y = np.array([o.objectives for o in obs], dtype=np.float64)
        Yn, _, _ = normalize_objectives(Y, lo, hi)
        out[name] = hypervolume_2d(Yn[pareto_mask(Yn)])
    return out


def run(budget: int = 6, batch_size: int = 4, seed: int = 0,
        n_flows: int = 400, max_pkts: int = 96, shards: int = 2,
        bisect_iters: int = 6, out_path=None, scenario: str = "zipf",
        verbose: bool = True):
    ds = make_scenario_dataset("app-class", scenario, n_flows=n_flows,
                               max_pkts=max_pkts, seed=seed)
    prof = TrafficProfiler(ds, FEATURE_NAMES, model="tree-fast",
                           cost_mode="modeled", scenario=scenario,
                           n_shards=shards, bisect_iters=bisect_iters,
                           seed=seed)
    space = SearchSpace(FEATURE_NAMES, max_depth=min(50, max_pkts))
    pri = priors_for(space, ds, prof)
    ev = MemoizedEvaluator(backend_suite(prof, ("modeled", MEASURED)))

    t_all = time.perf_counter()
    runs = {}
    walls = {}

    def record(name, fn):
        t0 = time.perf_counter()
        runs[name] = fn()
        walls[name] = round(time.perf_counter() - t0, 2)
        if verbose:
            print(f"# tune-smoke {name:10s} done in {walls[name]:.1f}s")

    record("CATO-MF", lambda: CatoOptimizer(
        space, ev, pri, seed=seed, batch_size=batch_size,
    ).run_multi_fidelity(measure_budget=budget))
    record("CATO-SEQ", lambda: CatoOptimizer(
        space, ev, pri, seed=seed,
    ).run(budget, fidelity=MEASURED))
    record("RANDSEARCH", lambda: run_random_search(
        space, ev, budget, seed=seed, fidelity=MEASURED))
    record("SIMANNEAL", lambda: run_simulated_annealing(
        space, ev, budget, seed=seed, fidelity=MEASURED))
    record("ITERATEALL", lambda: run_iterate_all(
        space, ev, budget, fidelity=MEASURED))

    per_method = {
        name: res.observations_at(MEASURED) or res.measured_observations()
        for name, res in runs.items()
    }
    hv = measured_hv(per_method)
    mf = runs["CATO-MF"]
    doc = {
        "bench": "tune_smoke",
        "config": {
            "budget": budget, "batch_size": batch_size, "seed": seed,
            "n_flows": n_flows, "max_pkts": max_pkts, "shards": shards,
            "scenario": scenario, "bisect_iters": bisect_iters,
            "measured_fidelity": MEASURED,
        },
        "wall_s": round(time.perf_counter() - t_all, 2),
        "methods": {
            name: {
                "hv_measured": round(hv[name], 6),
                "measured_evals": len(per_method[name]),
                "total_observations": len(runs[name].observations),
                "surrogate_fallbacks": len(runs[name].surrogate_fallbacks),
                "wall_s": walls[name],
            }
            for name in runs
        },
        "evaluator": ev.budget_summary(),
        "mf_fidelity_counts": mf.fidelity_counts,
    }
    path = write_datapoint(doc, out_path, name="BENCH_tune.json")
    if verbose:
        for name in runs:
            m = doc["methods"][name]
            print(f"# {name:10s} HV={m['hv_measured']:.4f} "
                  f"measured={m['measured_evals']} "
                  f"obs={m['total_observations']}")
        print(f"# wrote {path} (wall {doc['wall_s']:.1f}s)")
    return doc


def check_gate(doc: dict) -> int:
    """CATO-MF measured HV must not lose to any method at equal budget."""
    methods = doc["methods"]
    mf = methods["CATO-MF"]
    budget = doc["config"]["budget"]
    bad = 0
    if mf["measured_evals"] > budget:
        print(f"FAIL: CATO-MF spent {mf['measured_evals']} measured evals "
              f"(budget {budget})", file=sys.stderr)
        bad = 1
    for name, m in methods.items():
        if name == "CATO-MF":
            continue
        rel = "ok" if mf["hv_measured"] >= m["hv_measured"] - 1e-9 else "FAIL"
        print(f"{rel}: CATO-MF HV {mf['hv_measured']:.4f} vs "
              f"{name} {m['hv_measured']:.4f} "
              f"({m['measured_evals']} measured evals each)")
        if rel == "FAIL":
            bad = 1
    if bad:
        print("FAIL: multi-fidelity loop lost measured hypervolume at "
              "equal measurement budget", file=sys.stderr)
        return 1
    print("OK: multi-fidelity >= sequential and every baseline at equal "
          "measurement budget")
    return 0


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--budget", type=int, default=6,
                   help="measured-fidelity evaluations per method")
    p.add_argument("--batch-size", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--shards", type=int, default=2)
    p.add_argument("--scenario", default="zipf",
                   choices=("uniform", "zipf", "burst", "drift"))
    p.add_argument("--out", default=None,
                   help="output path (default: results/BENCH_tune.json "
                   "+ repo-root symlink alias)")
    p.add_argument("--gate", action="store_true",
                   help="fail unless CATO-MF HV >= every method's")
    args = p.parse_args()
    doc = run(budget=args.budget, batch_size=args.batch_size, seed=args.seed,
              shards=args.shards, scenario=args.scenario, out_path=args.out)
    if args.gate:
        raise SystemExit(check_gate(doc))

"""End-to-end driver: optimize the app-class pipeline for latency, then
deploy the best Pareto point as a compiled serving pipeline and classify
a held-out traffic batch with it.

    PYTHONPATH=src python examples/optimize_app_class.py
"""
import numpy as np

from repro.core import CatoOptimizer, SearchSpace, build_priors
from repro.traffic import FEATURE_NAMES, TrafficProfiler, extract_features, make_dataset
from repro.traffic.models import macro_f1, train_traffic_model
from repro.traffic.pipeline import build_pipeline


def main():
    ds = make_dataset("app-class", n_flows=2500, max_pkts=64, seed=1)
    prof = TrafficProfiler(ds, FEATURE_NAMES, model="tree-fast",
                           cost_metric="latency", cost_mode="modeled")
    space = SearchSpace(FEATURE_NAMES, max_depth=50)
    X = extract_features(ds, FEATURE_NAMES, 50)
    priors = build_priors(space, X, ds.label)

    res = CatoOptimizer(space, prof, priors, seed=0).run(30)
    front = res.pareto_observations()
    print("Pareto front (latency s vs F1):")
    for o in front:
        print(f"  {o.cost:8.4f}s  F1={o.perf:.3f}  n={o.x.depth}  "
              f"|F|={len(o.x.features)}")

    # pick the fastest point within 1% of best F1 and deploy it
    best_f1 = max(o.perf for o in front)
    choice = min((o for o in front if o.perf >= best_f1 - 0.01),
                 key=lambda o: o.cost)
    print(f"\ndeploying: depth={choice.x.depth} features={choice.x.features}")

    Xtr, _ = prof.columns(choice.x)
    forest, _ = train_traffic_model(Xtr, prof.train_ds.label, model="tree-fast")
    pipe = build_pipeline(choice.x, forest, ds.max_pkts)
    pred = pipe(prof.test_ds)
    f1 = macro_f1(prof.test_ds.label, pred)
    print(f"deployed pipeline hold-out F1: {f1:.3f} "
          f"(profiler measured {choice.perf:.3f})")
    names = np.array(ds.class_names)
    print("sample predictions:", names[pred[:8]].tolist())


if __name__ == "__main__":
    main()

"""Quickstart: CATO end-to-end on the IoT use case in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import CatoOptimizer, SearchSpace, build_priors
from repro.traffic import (
    MINI_FEATURE_NAMES, TrafficProfiler, extract_features, make_dataset,
)


def main():
    print("== CATO quickstart: iot-class, 6 candidate features ==")
    ds = make_dataset("iot-class", n_flows=2000, max_pkts=64, seed=0)
    prof = TrafficProfiler(ds, MINI_FEATURE_NAMES, model="rf-fast",
                           cost_metric="exec_time", cost_mode="modeled")

    space = SearchSpace(MINI_FEATURE_NAMES, max_depth=50)
    X = extract_features(ds, MINI_FEATURE_NAMES, 50)
    priors = build_priors(space, X, ds.label)
    print("feature MI scores:",
          dict(zip(MINI_FEATURE_NAMES, priors.mi.round(2))))

    result = CatoOptimizer(space, prof, priors, seed=0).run(25, verbose=False)

    print("\nestimated Pareto front (cost = per-flow execution time):")
    for o in result.pareto_observations():
        print(f"  {o.cost:7.3f}us  F1={o.perf:.3f}  depth={o.x.depth:3d}  "
              f"features={list(o.x.features)}")


if __name__ == "__main__":
    main()

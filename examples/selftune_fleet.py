"""The loop closing itself: a self-optimizing serving fleet (DESIGN.md §13).

One drifting trace, two fleets, both deployed on a *stale* knee — a
pipeline optimized for the pre-drift traffic window, exactly what a
fleet tuned yesterday serves today:

1. **Frozen knee** — the PR 7 fleet: control plane, no reoptimizer. As
   the class mix slides away from the training window, the stale model
   keeps predicting the classes it knows and its post-drift accuracy
   collapses.
2. **Self-optimizing** — the same fleet with a `ReoptimizerPolicy`
   subscribed to the `DriftMonitor`: when the fast/slow class-mix gap
   crosses the trigger threshold and dwells, the policy runs a budgeted
   CATO re-tune on a *shadow* evaluator (`cato_retuner`: fresh profiler,
   fresh optimizer — never a cycle on the live fleet), compiles the new
   front, and hot-swaps the re-optimized knee into the running replay.
   Zero drops, every flow predicted exactly once, and the whole episode
   — trigger rationale, drift magnitudes, budget, old vs new knee — is
   one audited `reopt` event.

Everything runs on the deterministic replay clock (`now_pkts`), so the
episode fires at the same packet on every machine.

    PYTHONPATH=src python examples/selftune_fleet.py
"""
import numpy as np

from repro.core import FeatureRep, SearchSpace
from repro.serve import (
    ControlConfig,
    DriftMonitor,
    Observability,
    PacketStream,
    ReoptimizerConfig,
    ReoptimizerPolicy,
    ServeSession,
    ServiceModel,
    ShardedRuntime,
    cato_retuner,
    replay,
)
from repro.serve.deploy import BundlePoint
from repro.traffic import FEATURE_NAMES, TrafficProfiler, extract_features
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.traffic.synth import make_scenario_dataset

REP_FEATURES = ("dur", "s_load", "s_bytes_mean", "s_iat_mean", "ack_cnt")
N_SHARDS = 2


def macro_f1(y_true, y_pred):
    f1s = []
    for c in np.union1d(np.unique(y_true), np.unique(y_pred)):
        tp = float(np.sum((y_pred == c) & (y_true == c)))
        fp = float(np.sum((y_pred == c) & (y_true != c)))
        fn = float(np.sum((y_pred != c) & (y_true == c)))
        if tp + fp + fn:
            f1s.append(2 * tp / max(2 * tp + fp + fn, 1e-9))
    return float(np.mean(f1s)) if f1s else 0.0


def main():
    print("== self-optimizing fleet: drift-triggered re-tune + hot-swap ==")
    ds = make_scenario_dataset("app-class", "drift", n_flows=600,
                               max_pkts=32, seed=3)
    stream = PacketStream.from_dataset(ds, seed=0)
    first_pkt = np.full(ds.n_flows, stream.n_events)
    np.minimum.at(first_pkt, stream.fid, np.arange(stream.n_events))
    print(f"trace: {stream.n_flows} flows, {stream.n_events} packets; "
          f"class mix slides across the replay (drift scenario)")

    # the stale deployed knee: trained on the pre-drift window only —
    # it has barely seen the classes that dominate the trace's tail
    rep_stale = FeatureRep(REP_FEATURES, depth=8)
    pre = np.nonzero(first_pkt < 0.4 * stream.n_events)[0]
    X = extract_features(ds, rep_stale.features, rep_stale.depth)
    forest, _ = train_traffic_model(X[pre], ds.label[pre],
                                    model="tree-fast", seed=0)
    stale_pipe = build_pipeline(rep_stale, forest, max_pkts=rep_stale.depth,
                                use_kernel=False)
    stale_point = BundlePoint(rep=rep_stale, cost=1.0, perf=0.0,
                              fidelity="measured", aux={},
                              compile_meta={"fused": False},
                              forest_doc=None, pipeline=stale_pipe)
    print(f"deployed knee: depth={rep_stale.depth} "
          f"|F|={len(rep_stale.features)}, trained on the first "
          f"{len(pre)} flows (saw {np.unique(ds.label[pre]).size}/"
          f"{len(ds.class_names)} classes)")

    service = ServiceModel(pkt_accum_ns=800.0, pkt_track_ns=200.0,
                           bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
                           gather_ns_per_flow=200.0, source="example")

    def fleet():
        # small micro-batches so predictions resolve (and feed the drift
        # monitor) mid-run, not at drain
        return ShardedRuntime(stale_pipe, n_shards=N_SHARDS, capacity=2048,
                              max_batch=16, execute=True)

    def control():
        return ControlConfig(interval_pkts=256, rebalance=False)

    # -- arm 1: the frozen knee --------------------------------------------
    frozen = replay(stream, fleet, 2e5, service,
                    session=ServeSession(control=control()))

    # -- arm 2: the self-optimizing fleet ----------------------------------
    # the re-tune body: a budgeted CATO optimization on a shadow profiler
    # over the up-to-date corpus, warm-startable, compiled on return
    space = SearchSpace(FEATURE_NAMES, max_depth=min(24, ds.max_pkts))

    def make_profiler(trigger):
        print(f"  [reopt] episode trigger at replay "
              f"t={trigger['now_pkts']:.4f}s after "
              f"{trigger['pkts_ingested']} pkts: class_mix_shift="
              f"{trigger['verdict']['class_mix_shift']:.3f}")
        return TrafficProfiler(ds, FEATURE_NAMES, model="tree-fast",
                               cost_mode="modeled", scenario="drift",
                               n_shards=N_SHARDS, bisect_iters=4, seed=0)

    retune = cato_retuner(make_profiler, space, fidelities=("modeled",),
                          measure_budget=4, batch_size=4, n_init=3, seed=0,
                          baseline=stale_point, use_kernel=False)
    policy = ReoptimizerPolicy(retune, ReoptimizerConfig(
        class_threshold=0.35, min_dwell_pkts=256,
        cooldown_pkts=1 << 20, max_episodes=1))
    session = ServeSession(obs=Observability(drift=DriftMonitor()),
                           control=control(), reopt=policy)
    tuned = replay(stream, fleet, 2e5, service, session=session)

    ep = session.resolve_audit().of_kind("reopt")[0]
    print(f"\naudited episode (seq {ep.seq}, replay t={ep.now_pkts:.4f}s):")
    print(f"  rationale: {ep.rationale}")
    print(f"  old knee (cost, perf): {ep.detail['old_knee']}")
    print(f"  new knee (cost, perf): {ep.detail['new_knee']}")
    print(f"  budget:    {ep.detail['budget']}  "
          f"retune wall {ep.detail['retune_wall_s']:.2f}s")
    print(f"swap executed at pkt {tuned.control['swap_at_pkts']}, "
          f"drops={tuned.drops}, "
          f"{len(tuned.predictions)}/{ds.n_flows} flows predicted")

    # -- scoreboard: post-drift segment (flows first seen in the last
    # third of the trace) ---------------------------------------------------
    post = np.nonzero(first_pkt >= (2 / 3) * stream.n_events)[0]
    f1_frozen = macro_f1(ds.label[post],
                         np.array([frozen.predictions[f] for f in post]))
    f1_tuned = macro_f1(ds.label[post],
                        np.array([tuned.predictions[f] for f in post]))
    print(f"\npost-drift macro-F1 over {len(post)} tail flows:")
    print(f"  frozen knee     : {f1_frozen:.3f}")
    print(f"  self-optimizing : {f1_tuned:.3f}")

    assert tuned.control["reopt"]["episodes"] == 1
    assert tuned.drops == 0 and frozen.drops == 0
    assert len(tuned.predictions) == ds.n_flows
    assert f1_tuned > f1_frozen
    print("\nOK: the fleet noticed the drift, re-tuned itself, and "
          "hot-swapped the fix mid-replay")


if __name__ == "__main__":
    main()

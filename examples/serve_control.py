"""Adaptive serving control plane, end to end (DESIGN.md §9).

Three acts on one Zipf elephant-flow trace (a handful of flows carry
most of the offered packets, so a handful of RETA buckets overload
whatever shard round-robin steering gave them):

1. **Dynamic RETA rebalancing** — measure the 4-shard zero-loss
   throughput twice, static indirection table vs. the closed control
   loop (per-bucket EWMA telemetry -> greedy bucket-migration planner ->
   quiescent flow-state migration), and show the imbalance drop and the
   throughput the static fleet was leaving on the hottest shard's floor.
2. **Zero-downtime pipeline hot-swap** — mid-replay, swap the fleet onto
   a different Pareto-style (F, n) pipeline (compiled and warmed in the
   background) with zero drops and every flow predicted exactly once.
3. **Elastic scale-out/in** — replay the same trace at a high and a low
   offered rate under a target-headroom policy and watch the fleet grow
   and shrink by RETA rewrite + migration.

Everything runs under the deterministic replay clock, so the numbers
reproduce bit-for-bit on any machine.

    PYTHONPATH=src python examples/serve_control.py
"""
import numpy as np

from repro.core import FeatureRep
from repro.serve import (
    ControlConfig,
    HeadroomPolicy,
    PacketStream,
    PipelineSwap,
    ServeSession,
    ServiceModel,
    ShardedRuntime,
    StreamingRuntime,
    find_zero_loss_rate,
    replay,
)
from repro.traffic import extract_features
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.traffic.synth import make_scenario_dataset

N_SHARDS = 4


def build(ds, rep):
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)


def main():
    print("== adaptive serving control plane: zipf elephant-flow trace ==")
    ds = make_scenario_dataset("app-class", "zipf", n_flows=120,
                               max_pkts=256, seed=3)
    rep_a = FeatureRep(("dur", "s_load", "s_bytes_mean", "s_iat_mean",
                        "ack_cnt"), depth=8)
    rep_b = FeatureRep(("dur", "s_load", "s_pkt_cnt", "d_bytes_med",
                        "psh_cnt"), depth=12)
    pipe_a = build(ds, rep_a)
    stream = PacketStream.from_dataset(ds, seed=0)
    top = np.sort(np.bincount(stream.fid))[::-1]
    print(f"trace: {stream.n_flows} flows, {stream.n_events} packets; "
          f"top-5 flows carry {top[:5].sum() / stream.n_events:.0%} "
          "of all packets")

    # deterministic service constants (realistic magnitudes) so the whole
    # example reproduces anywhere; swap in ServiceModel.measure for
    # this-machine numbers
    svc_a = ServiceModel(pkt_accum_ns=800.0, pkt_track_ns=200.0,
                         bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
                         gather_ns_per_flow=200.0, source="example")
    ring = max(64, stream.n_events // 16)

    def fleet(execute=False):
        return ShardedRuntime(pipe_a, n_shards=N_SHARDS, capacity=2048,
                              max_batch=64, execute=execute)

    # -- act 1: static RETA vs dynamic rebalancing -------------------------
    cfg = ControlConfig(interval_pkts=512, imbalance_trigger=1.04)
    r_st, s_st = find_zero_loss_rate(stream, fleet, svc_a, iters=8,
                                     ring_capacity=ring)
    r_dy, s_dy = find_zero_loss_rate(stream, fleet, svc_a, iters=8,
                                     ring_capacity=ring,
                                     session=ServeSession(control=cfg))
    print(f"\nstatic RETA : zero-loss {r_st:12,.0f} pps  "
          f"load imbalance {s_st.load_imbalance:.2f}")
    print(f"dynamic RETA: zero-loss {r_dy:12,.0f} pps  "
          f"load imbalance {s_dy.load_imbalance:.2f}  "
          f"({s_dy.control['buckets_moved']} bucket moves, "
          f"{s_dy.control['flows_migrated']} flows migrated)")
    print(f"  -> {r_dy / r_st:.2f}x the static fleet's throughput, "
          f"zero drops both ways")
    assert s_st.drops == 0 and s_dy.drops == 0
    assert r_dy > r_st

    # -- act 2: zero-downtime pipeline hot-swap ----------------------------
    pipe_b = build(ds, rep_b)
    svc_b = ServiceModel(pkt_accum_ns=900.0, pkt_track_ns=200.0,
                         bucket_ns={8: 4e4, 16: 5e4, 32: 7e4, 64: 1.2e5},
                         gather_ns_per_flow=200.0, source="example")
    pipe_b.warm([8, 16, 32, 64])  # background compile: swap pays no jit
    swap_cfg = ControlConfig(
        interval_pkts=512, imbalance_trigger=1.04,
        swap=PipelineSwap(pipe_b, svc_b,
                          after_pkts=stream.n_events // 2))
    swapped = replay(stream, lambda: fleet(True), stream.base_pps, svc_a,
                     session=ServeSession(control=swap_cfg))
    m = swapped.metrics
    print(f"\nhot-swap at mid-trace: drops {swapped.drops}, "
          f"{len(swapped.predictions)}/{ds.n_flows} flows predicted "
          f"exactly once (duplicates {m.duplicate_predictions}), "
          f"swap flushes {m.flushes_swap}")
    assert swapped.drops == 0
    assert len(swapped.predictions) == ds.n_flows
    assert m.duplicate_predictions == 0

    # flows that finished before the swap match the old pipeline's batch
    # output; flows that started after it match the new pipeline's
    single_b = replay(
        stream,
        lambda: StreamingRuntime(pipe_b, capacity=2048, max_batch=64),
        stream.base_pps, svc_b)
    first_pkt = np.full(ds.n_flows, stream.n_events)
    np.minimum.at(first_pkt, stream.fid, np.arange(stream.n_events))
    post = first_pkt >= stream.n_events // 2
    agree = sum(swapped.predictions[f] == single_b.predictions[f]
                for f in np.nonzero(post)[0])
    print(f"  {agree}/{int(post.sum())} post-swap flows bit-identical to a "
          "new-pipeline-only run")
    assert agree == int(post.sum())

    # -- act 3: elastic scale-out/in ---------------------------------------
    elastic = ControlConfig(interval_pkts=512,
                            headroom=HeadroomPolicy(max_workers=8))

    def small_fleet():
        return ShardedRuntime(pipe_a, n_shards=2, capacity=4096,
                              max_batch=64, execute=False)

    hot = replay(stream, small_fleet, 4e6, svc_a,
                 session=ServeSession(control=elastic))
    cold = replay(stream, small_fleet, 1e5, svc_a,
                  session=ServeSession(control=elastic))
    print(f"\nelastic: at 4.0M pps the 2-worker fleet grew to "
          f"{hot.control['active_workers']} active workers "
          f"(+{hot.control['workers_added']}), zero drops: "
          f"{hot.drops == 0}")
    print(f"elastic: at 0.1M pps it shrank to "
          f"{cold.control['active_workers']} active worker(s) "
          f"(retired {cold.control['workers_retired']})")
    assert hot.control["workers_added"] > 0
    assert cold.control["workers_retired"] > 0
    print("\nOK")


if __name__ == "__main__":
    main()

"""Serve a reduced model: prefill a prompt, decode greedily with KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-1.2b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import init_cache, init_params
from repro.serve import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (B, 8)), jnp.int32
    )

    cache = init_cache(cfg, B, 8 + args.tokens + 1)
    step = jax.jit(make_serve_step(cfg))

    # prefill token-by-token (teacher forcing the prompt into the cache)
    tok = prompt[:, 0]
    for t in range(1, prompt.shape[1]):
        _, cache = step(params, cache, tok)
        tok = prompt[:, t]

    out = []
    for _ in range(args.tokens):
        tok, cache = step(params, cache, tok)
        out.append(np.asarray(tok))
    gen = np.stack(out, 1)
    print(f"{cfg.name}: generated {gen.shape[1]} tokens/seq")
    print("sequences:", gen.tolist())


if __name__ == "__main__":
    main()

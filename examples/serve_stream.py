"""Sustained streaming classification through the online serving runtime.

Replays a synthetic app-class trace as a live packet stream through the
vectorized ingest path (`FlowTable.observe_batch` blocks), micro-batched
dispatch with reused staging arenas, and the single-launch fused
extract+infer Pallas pipeline (DESIGN.md §7); measures the zero-loss
throughput point (highest offered load with zero drops, Fig. 5c), and
checks that the streaming path's predictions are bit-identical to the
batch `ServingPipeline` on the same flows.

With `--shards N` the pipeline is replicated across N workers behind
RSS-style symmetric flow steering (`ShardedRuntime`, DESIGN.md §8): the
zero-loss bisection runs over the aggregate offered load (a drop on any
shard fails a trial), per-shard steering shares and drop counters are
printed, and the prediction-parity check still holds bit-exactly —
sharding only permutes which worker serves a flow.

    PYTHONPATH=src python examples/serve_stream.py
    PYTHONPATH=src python examples/serve_stream.py --shards 4
"""
import argparse

import numpy as np

from repro.core import FeatureRep
from repro.traffic import extract_features, make_dataset
from repro.traffic.models import macro_f1, train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.serve import (
    PacketStream, ServiceModel, ShardedRuntime, StreamingRuntime,
    find_zero_loss_rate,
)


def main(n_shards: int = 1):
    print(f"== streaming serving runtime: app-class ({n_shards} worker(s)) ==")
    ds = make_dataset("app-class", n_flows=1200, max_pkts=48, seed=7)
    train_ds, test_ds = ds.split(test_frac=0.5, seed=0)

    # a CATO-style compact representation: 8 features at depth 12
    rep = FeatureRep(
        ("dur", "s_load", "s_pkt_cnt", "s_bytes_sum", "s_bytes_mean",
         "s_iat_mean", "ack_cnt", "d_bytes_med"),
        depth=12,
    )
    X = extract_features(train_ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, train_ds.label, model="rf-fast", seed=0)
    # fused=True: one Pallas launch per micro-batch (extract+infer in VMEM)
    pipeline = build_pipeline(rep, forest, max_pkts=rep.depth, fused=True)

    stream = PacketStream.from_dataset(test_ds, seed=0)
    print(f"trace: {stream.n_flows} flows, {stream.n_events} packets, "
          f"{stream.total_bytes / 1e6:.1f} MB")

    # hardware-RSS buffer provisioning: every worker queue owns a
    # full-size descriptor ring (DESIGN.md §8.3)
    ring_capacity = max(64, min(4096, stream.n_events // 8))

    def make_runtime(execute: bool = True):
        if n_shards > 1:
            return ShardedRuntime(
                pipeline, n_shards=n_shards, capacity=2048, max_batch=128,
                min_bucket=8, flush_timeout_s=0.05, idle_timeout_s=60.0,
                execute=execute,
            )
        return StreamingRuntime(
            pipeline, capacity=2048, max_batch=128, min_bucket=8,
            flush_timeout_s=0.05, idle_timeout_s=60.0, execute=execute,
        )

    # calibrate the replay clock from real wall-clock timings, then bisect
    print("calibrating service model (measured)...")
    service = ServiceModel.measure(make_runtime(True), stream)
    print(f"  ingest {service.pkt_accum_ns:,.0f} ns/pkt, "
          f"batch-64 {service.bucket_ns.get(64, 0) / 1e3:,.1f} us")

    rate_pps, stats = find_zero_loss_rate(
        stream, make_runtime, service, iters=10,
        ring_capacity=ring_capacity, verbose=False,
    )
    m = stats.metrics
    print(f"\nzero-loss throughput: {stats.offered_gbps:.4f} Gbit/s "
          f"({rate_pps:,.0f} pkts/s offered, aggregate)")
    print(f"  drops at reported rate: {stats.drops} "
          f"(ring {stats.drops_ring}, table {stats.drops_table})")
    print(f"  flow latency p50 {stats.latency_p50_s * 1e3:.3f} ms, "
          f"p99 {stats.latency_p99_s * 1e3:.3f} ms (enqueue -> prediction)")
    if stats.n_shards > 1:
        print(f"  load imbalance {stats.load_imbalance:.3f} "
              f"(max shard share / mean share)")
        for p in stats.per_shard:
            share = p["pkts_total"] / max(m.pkts_total, 1)
            print(f"    shard {p['shard']}: {share * 100:5.1f}% of packets, "
                  f"{p['batches']} batches, drops {p['drops_ring']}+"
                  f"{p['drops_table']}, p99 "
                  f"{p['latency_p99_s'] * 1e3:.3f} ms")
    print("  latency histogram:")
    for lo, hi, n in m.latency.rows():
        print(f"    [{lo * 1e3:9.3f}, {hi * 1e3:9.3f}) ms  {'#' * min(n, 60)} {n}")
    print(f"  batches {m.batches}, occupancy {m.occupancy_stats()['mean']:.2f}, "
          f"distinct compiled shapes {m.compile_count()} "
          f"(buckets {sorted(b for b, _ in m.shapes_seen)})")
    assert stats.drops == 0, "drops at the reported zero-loss rate"

    # --- streaming vs batch parity: bit-identical predictions -------------
    batch_pipe_view = test_ds.truncate(rep.depth)
    batch_preds = pipeline(batch_pipe_view)
    stream_preds = np.array(
        [stats.predictions[i] for i in range(test_ds.n_flows)]
    )
    n_match = int((stream_preds == batch_preds).sum())
    print(f"\nstreaming vs batch predictions: {n_match}/{test_ds.n_flows} identical")
    assert n_match == test_ds.n_flows, "streaming path diverged from batch pipeline"

    f1 = macro_f1(test_ds.label, stream_preds)
    print(f"held-out macro-F1 through the streaming path: {f1:.3f}")
    print("OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shards", type=int, default=1,
                    help="RSS-steered worker count (1 = single runtime)")
    main(n_shards=ap.parse_args().shards)

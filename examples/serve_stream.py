"""Sustained streaming classification through the online serving runtime.

Replays a synthetic app-class trace as a live packet stream through the
vectorized ingest path (`FlowTable.observe_batch` blocks), micro-batched
dispatch with reused staging arenas, and the single-launch fused
extract+infer Pallas pipeline (DESIGN.md §7); measures the zero-loss
throughput point (highest offered load with zero drops, Fig. 5c), and
checks that the streaming path's predictions are bit-identical to the
batch `ServingPipeline` on the same flows.

    PYTHONPATH=src python examples/serve_stream.py
"""
import numpy as np

from repro.core import FeatureRep
from repro.traffic import extract_features, make_dataset
from repro.traffic.models import macro_f1, train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.serve.runtime import (
    PacketStream, ServiceModel, StreamingRuntime, find_zero_loss_rate,
)


def main():
    print("== streaming serving runtime: app-class ==")
    ds = make_dataset("app-class", n_flows=1200, max_pkts=48, seed=7)
    train_ds, test_ds = ds.split(test_frac=0.5, seed=0)

    # a CATO-style compact representation: 8 features at depth 12
    rep = FeatureRep(
        ("dur", "s_load", "s_pkt_cnt", "s_bytes_sum", "s_bytes_mean",
         "s_iat_mean", "ack_cnt", "d_bytes_med"),
        depth=12,
    )
    X = extract_features(train_ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, train_ds.label, model="rf-fast", seed=0)
    # fused=True: one Pallas launch per micro-batch (extract+infer in VMEM)
    pipeline = build_pipeline(rep, forest, max_pkts=rep.depth, fused=True)

    stream = PacketStream.from_dataset(test_ds, seed=0)
    print(f"trace: {stream.n_flows} flows, {stream.n_events} packets, "
          f"{stream.total_bytes / 1e6:.1f} MB")

    def make_runtime(execute: bool = True) -> StreamingRuntime:
        return StreamingRuntime(
            pipeline, capacity=2048, max_batch=128, min_bucket=8,
            flush_timeout_s=0.05, idle_timeout_s=60.0, execute=execute,
        )

    # calibrate the replay clock from real wall-clock timings, then bisect
    print("calibrating service model (measured)...")
    service = ServiceModel.measure(make_runtime(True), stream)
    print(f"  ingest {service.pkt_accum_ns:,.0f} ns/pkt, "
          f"batch-64 {service.bucket_ns.get(64, 0) / 1e3:,.1f} us")

    rate_pps, stats = find_zero_loss_rate(
        stream, make_runtime, service, iters=10, verbose=False,
    )
    m = stats.metrics
    print(f"\nzero-loss throughput: {stats.offered_gbps:.4f} Gbit/s "
          f"({rate_pps:,.0f} pkts/s offered)")
    print(f"  drops at reported rate: {stats.drops} "
          f"(ring {stats.drops_ring}, table {stats.drops_table})")
    print(f"  flow latency p50 {stats.latency_p50_s * 1e3:.3f} ms, "
          f"p99 {stats.latency_p99_s * 1e3:.3f} ms (enqueue -> prediction)")
    print("  latency histogram:")
    for lo, hi, n in m.latency.rows():
        print(f"    [{lo * 1e3:9.3f}, {hi * 1e3:9.3f}) ms  {'#' * min(n, 60)} {n}")
    print(f"  batches {m.batches}, occupancy {m.occupancy_stats()['mean']:.2f}, "
          f"distinct compiled shapes {m.compile_count()} "
          f"(buckets {sorted(b for b, _ in m.shapes_seen)})")
    assert stats.drops == 0, "drops at the reported zero-loss rate"

    # --- streaming vs batch parity: bit-identical predictions -------------
    batch_pipe_view = test_ds.truncate(rep.depth)
    batch_preds = pipeline(batch_pipe_view)
    stream_preds = np.array(
        [stats.predictions[i] for i in range(test_ds.n_flows)]
    )
    n_match = int((stream_preds == batch_preds).sum())
    print(f"\nstreaming vs batch predictions: {n_match}/{test_ds.n_flows} identical")
    assert n_match == test_ds.n_flows, "streaming path diverged from batch pipeline"

    f1 = macro_f1(test_ds.label, stream_preds)
    print(f"held-out macro-F1 through the streaming path: {f1:.3f}")
    print("OK")


if __name__ == "__main__":
    main()

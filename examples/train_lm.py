"""Train a reduced qwen3 config end-to-end on CPU with checkpoint/resume.

    PYTHONPATH=src python examples/train_lm.py
"""
import tempfile

from repro.launch.train import main as train_main


def main():
    with tempfile.TemporaryDirectory() as d:
        losses = train_main([
            "--arch", "qwen3-8b", "--reduced", "--steps", "40",
            "--batch", "8", "--seq", "64", "--lr", "3e-3",
            "--ckpt-dir", d, "--ckpt-every", "20",
        ])
        assert losses[-1] < losses[0], "loss should decrease"
        print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")


if __name__ == "__main__":
    main()

"""CATO beyond the paper: tune an LM serving pipeline's config with the
same multi-objective BO the paper applies to traffic pipelines.

(Previously `examples/tune_serving.py`; that name now drives the traffic
measure -> optimize -> compile -> deploy loop.)

    PYTHONPATH=src python examples/tune_lm_config.py [--arch qwen3-8b]
"""
import argparse

from repro import configs
from repro.core.tuner import PipelineTuner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--iters", type=int, default=40)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    tuner = PipelineTuner(cfg, chips=256)
    res = tuner.tune(args.iters, seed=0)

    print(f"== serving-config Pareto front for {cfg.name} "
          f"(cost = us per generated token on 256 chips, perf = quality proxy) ==")
    for o in res.pareto_observations():
        x = o.x
        print(f"  {o.cost:7.3f}us  q={o.perf:.3f}  kv={x.kv_dtype:4s} "
              f"window={x.window:6d} mb={x.microbatches} remat={x.remat:5s} "
              f"batch={x.decode_batch}")


if __name__ == "__main__":
    main()

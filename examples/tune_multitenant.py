"""Multi-tenant co-optimization: the optimizer sees the sharing (§15.5).

A vantage point serving N tenants from one fleet pays for the *union*
extraction plan once per flow, not for N independent passes — so which
joint configurations are Pareto-optimal depends on how much the tenants
overlap. This example makes that discount optimizer-visible, end to end:

1. **Per-tenant tuning (the baseline)** — each tenant's `(F, n)` space is
   optimized alone with `CatoOptimizer` + `TrafficProfiler`, its front
   compiled with `compile_front`, its knee chosen. This is what N teams
   shipping N independent fleets would deploy.
2. **Joint tuning** — the same tenants as one `MultiTenantSpace` point
   evaluated by `MultiTenantProfiler`: perf is the mean per-tenant
   hold-out macro-F1, cost the union-plan extraction (shared ops counted
   once) plus every tenant's inference. An ablation arm re-bills the
   identical configs as independent fleets (`shared=False`). Rescoring
   every configuration either run evaluated under BOTH cost models shows
   the overlap discount *changes the Pareto set* — configurations whose
   tenants agree on features get cheaper together than apart.
3. **Fused deploy** — the per-tenant knees are fused into one
   `MultiTenantBundlePoint` (`compile_multi_tenant`) and hot-swapped into
   a live sharded replay mid-stream through the same §9.3 quiescence
   path as a solo point: zero drops, every flow answered once for all
   tenants.

    PYTHONPATH=src python examples/tune_multitenant.py
"""
import argparse

import numpy as np

from repro.core import CatoOptimizer, pareto_mask
from repro.core.search_space import SearchSpace
from repro.serve import (
    ControlConfig,
    PacketStream,
    ServeSession,
    ServiceModel,
    ShardedRuntime,
    compile_front,
    compile_multi_tenant,
    make_swap,
    replay,
    warm_buckets_for,
)
from repro.traffic import TrafficProfiler
from repro.traffic.multi_tenant import MultiTenantProfiler, MultiTenantSpace
from repro.traffic.synth import make_scenario_dataset

N_SHARDS = 2
# shared core + per-tenant specialty features: the overlap is the point
_CORE = ("s_bytes_mean", "s_iat_mean", "s_load", "dur")
_POOLS = (
    _CORE + ("proto", "ack_cnt"),
    _CORE + ("s_bytes_max", "psh_cnt"),
    _CORE + ("d_pkt_cnt", "d_iat_std"),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=24,
                    help="joint-space evaluations per cost model")
    ap.add_argument("--solo-iters", type=int, default=16,
                    help="per-tenant evaluations for the baseline fronts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ds = make_scenario_dataset("app-class", "zipf", n_flows=240, max_pkts=64,
                               seed=args.seed)
    spaces = [SearchSpace(pool, max_depth=12) for pool in _POOLS]
    profs = [TrafficProfiler(ds, pool, model="tree-fast",
                             cost_mode="modeled", seed=args.seed)
             for pool in _POOLS]

    # -- 1. per-tenant baselines: N independent optimizations --------------
    print(f"== per-tenant tuning: {len(profs)} independent fronts ==")
    bundles = []
    for t, (space, prof) in enumerate(zip(spaces, profs)):
        res = CatoOptimizer(space, prof, seed=args.seed + t,
                            batch_size=4).run(args.solo_iters)
        bundle = compile_front(res, prof, fused=False, use_kernel=False,
                               warm=False)
        k = bundle.knee()
        print(f"tenant{t}: {len(bundle.points)} front points, knee "
              f"|F|={len(k.rep.features)} n={k.rep.depth} f1={k.perf:.3f}")
        bundles.append(bundle)

    # -- 2. joint tuning: shared vs independent billing --------------------
    joint = MultiTenantSpace(tuple(spaces))
    shared_prof = MultiTenantProfiler(profs, shared=True)
    indep_prof = MultiTenantProfiler(profs, shared=False)
    print(f"\n== joint tuning over {joint.size:.0f} configurations "
          f"(dim {joint.dim}) ==")
    res_shared = CatoOptimizer(joint, shared_prof, seed=args.seed,
                               batch_size=4).run(args.iters)
    res_indep = CatoOptimizer(joint, indep_prof, seed=args.seed,
                              batch_size=4).run(args.iters)

    # rescore every configuration either run visited under BOTH cost
    # models (one call returns both: the per-tenant model caches make
    # this free) and compare the Pareto sets over the same config pool
    xs = list({o.x.key(): o.x for o in
               res_shared.observations + res_indep.observations}.values())
    rows = [shared_prof(x) for x in xs]
    perf = np.array([r.perf for r in rows])
    cost_sh = np.array([r.aux["cost_shared_us"] for r in rows])
    cost_in = np.array([r.aux["cost_independent_us"] for r in rows])
    on_shared = pareto_mask(np.stack([cost_sh, -perf], axis=1))
    on_indep = pareto_mask(np.stack([cost_in, -perf], axis=1))
    moved = on_shared != on_indep
    disc = np.array([r.aux["overlap_discount"] for r in rows])
    print(f"{len(xs)} distinct joint configs rescored; Pareto-optimal: "
          f"{int(on_shared.sum())} shared-billed vs "
          f"{int(on_indep.sum())} independent-billed, "
          f"{int(moved.sum())} configs changed front membership")
    print(f"overlap discount across pool: mean {disc.mean():.1%}, "
          f"max {disc.max():.1%}")
    for i in np.nonzero(moved)[0][:4]:
        tag = "enters" if on_shared[i] else "leaves"
        feats = " | ".join(
            ",".join(r.features) for r in xs[i].reps)
        print(f"  {tag} the front under shared billing "
              f"(discount {disc[i]:.1%}): {feats}")
    assert moved.any(), \
        "union-plan discount changed no Pareto-optimal configuration"

    # -- 3. fused deploy: hot-swap the joint knees into a live fleet -------
    start = compile_multi_tenant([b.best_by_cost() for b in bundles],
                                 fused=False, use_kernel=False, warm=False)
    knees = compile_multi_tenant([b.knee() for b in bundles],
                                 fused=False, use_kernel=False, warm=False)
    stream = PacketStream.from_dataset(ds, seed=args.seed, scenario="zipf")
    svc = ServiceModel.modeled_multi_tenant(start.tenant_reps,
                                            start.tenant_forests())
    start_pipe = start.pipeline

    def fleet():
        return ShardedRuntime(start_pipe, n_shards=N_SHARDS, capacity=2048,
                              max_batch=64, execute=True)

    template = fleet()
    start_pipe.warm(warm_buckets_for(template))
    swap = make_swap(knees, after_pkts=stream.n_events // 2, runtime=template)
    cfg = ControlConfig(interval_pkts=256, rebalance=False, swap=swap)
    stats = replay(stream, fleet, stream.base_pps, svc,
                   session=ServeSession(control=cfg))
    n_t = len(profs)
    widths = {np.asarray(v).shape for v in stats.predictions.values()}
    print(f"\n== deploy: {n_t}-tenant bundle hot-swapped into a live "
          f"{N_SHARDS}-shard replay ==")
    print(f"drops={stats.drops}  predicted {len(stats.predictions)}/"
          f"{ds.n_flows} flows x {n_t} tenants  "
          f"swaps={stats.control['swaps']}")
    assert stats.drops == 0, "deployment dropped packets"
    assert len(stats.predictions) == ds.n_flows, "a flow went unpredicted"
    assert widths == {(n_t,)}, f"prediction vectors not per-tenant: {widths}"
    assert stats.control["swaps"] == 1, "the scheduled swap never fired"
    print("\nOK: tenants tuned jointly, sharing priced in, fleet swapped.")


if __name__ == "__main__":
    main()

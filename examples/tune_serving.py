"""The closed loop: measure -> optimize -> compile -> deploy (DESIGN.md §10).

What the paper's abstract promises, end to end, on the smoke fixture:

1. **Measure/optimize** — batched multi-fidelity Bayesian optimization
   over (features x depth): candidate batches are scored by greedy
   q-EHVI, evaluated at the cheap `modeled` fidelity, and only points
   on the cheap Pareto front are promoted to the expensive
   `replayed_sharded` fidelity — a real zero-loss-throughput bisection
   through the RSS-steered sharded serving runtime under a zipf
   elephant-flow scenario. Both fidelities share one profiler's caches
   through one memoized evaluator.
2. **Compile** — the measured-fidelity Pareto set is compiled into a
   `ParetoBundle`: per point, the exact seeded forest the measurement
   used, a jit-compiled pipeline pre-warmed for the target fleet's
   dispatch buckets, and the measured objectives — serialized to
   `results/pareto_bundle.json` and round-tripped to prove the
   artifact is deployable without retraining.
3. **Deploy** — the bundle's knee point is pushed into a *live* sharded
   replay mid-stream via the control plane's zero-downtime hot-swap
   (§9.3 quiescence protocol): zero drops, every flow predicted
   exactly once, post-swap flows bit-identical to a knee-pipeline-only
   run.

Everything runs under the deterministic replay clock, so the numbers
reproduce bit-for-bit on any machine.

    PYTHONPATH=src python examples/tune_serving.py [--scenario zipf]
"""
import argparse
import pathlib

import numpy as np

from repro.core import CatoOptimizer, MemoizedEvaluator, SearchSpace
from repro.core.priors import build_priors
from repro.serve import (
    ControlConfig,
    PacketStream,
    ParetoBundle,
    ServeSession,
    ServiceModel,
    ShardedRuntime,
    compile_front,
    make_swap,
    replay,
    warm_buckets_for,
)
from repro.traffic import FEATURE_NAMES, TrafficProfiler, backend_suite
from repro.traffic.synth import make_scenario_dataset

N_SHARDS = 4
RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="zipf",
                    choices=("uniform", "zipf", "burst", "drift"))
    ap.add_argument("--budget", type=int, default=5,
                    help="measured-fidelity evaluations (zero-loss bisections)")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # -- fixture: zipf elephant-flow smoke trace ---------------------------
    ds = make_scenario_dataset("app-class", args.scenario, n_flows=240,
                               max_pkts=96, seed=args.seed)
    prof = TrafficProfiler(ds, FEATURE_NAMES, model="tree-fast",
                           cost_mode="modeled", scenario=args.scenario,
                           n_shards=N_SHARDS, bisect_iters=6, seed=args.seed)
    space = SearchSpace(FEATURE_NAMES, max_depth=min(50, ds.max_pkts))
    X = prof.matrices_at_depth(space.max_depth)[0]
    priors = build_priors(space, X, prof.train_ds.label)

    # -- 1. batched multi-fidelity optimization ----------------------------
    suite = backend_suite(prof, ("modeled", "replayed_sharded"))
    ev = MemoizedEvaluator(suite)
    opt = CatoOptimizer(space, ev, priors, seed=args.seed,
                        batch_size=args.batch_size)
    print(f"== optimize: batched multi-fidelity BO under {args.scenario} "
          f"({N_SHARDS}-shard measured fidelity) ==")
    res = opt.run_multi_fidelity(measure_budget=args.budget, verbose=True)
    front = res.pareto_observations()
    print(f"\nfidelity spend: {res.fidelity_counts} "
          f"(surrogate fallbacks: {len(res.surrogate_fallbacks)})")
    print(f"measured Pareto set ({len(front)} points):")
    for o in front:
        print(f"  depth={o.x.depth:3d} |F|={len(o.x.features):2d} "
              f"f1={o.perf:.3f} zero-loss={-o.cost:.3f} Gbps")

    # -- 2. compile the front into a deployable bundle ---------------------
    bundle = compile_front(res, prof, fused=True, use_kernel=False)
    path = bundle.save(RESULTS / "pareto_bundle.json")
    reloaded = ParetoBundle.load(path)
    assert reloaded.to_doc() == bundle.to_doc(), "bundle round-trip drifted"
    knee = reloaded.knee()
    print(f"\n== compile: {len(bundle.points)} front points warmed "
          f"({sum(p.compile_meta['compile_s'] for p in bundle.points):.2f}s "
          f"compile) -> {path} ==")
    print(f"knee point: depth={knee.rep.depth} |F|={len(knee.rep.features)} "
          f"f1={knee.perf:.3f} zero-loss={-knee.cost:.3f} Gbps")

    # -- 3. deploy: hot-swap the knee into a live sharded replay -----------
    # the fleet starts on the bundle's cheapest point (a deliberately
    # lean pipeline) and swaps to the knee mid-trace, zero-downtime
    start = reloaded.best_by_cost()
    start_pipe = start.build(warm=False)
    stream = PacketStream.from_dataset(ds, seed=args.seed,
                                       scenario=args.scenario)
    svc_start = ServiceModel.modeled(start.rep, start.forest())

    def fleet():
        return ShardedRuntime(start_pipe, n_shards=N_SHARDS, capacity=2048,
                              max_batch=64, execute=True)

    # warm both pipelines for the *fleet's* dispatch geometry (a
    # throwaway instance donates min_bucket/max_batch), so neither the
    # serving path nor the swap ever pays an XLA compile
    template = fleet()
    start_pipe.warm(warm_buckets_for(template))
    swap = make_swap(knee, after_pkts=stream.n_events // 2, runtime=template)
    cfg = ControlConfig(interval_pkts=256, rebalance=False, swap=swap)

    stats = replay(stream, fleet, stream.base_pps, svc_start,
                   session=ServeSession(control=cfg))
    m = stats.metrics
    print(f"\n== deploy: knee hot-swapped into a live {N_SHARDS}-shard "
          f"replay at mid-trace ==")
    print(f"drops={stats.drops}  predicted {len(stats.predictions)}/"
          f"{ds.n_flows} flows  duplicates={m.duplicate_predictions}  "
          f"swaps={stats.control['swaps']}")
    assert stats.drops == 0, "deployment dropped packets"
    assert len(stats.predictions) == ds.n_flows, "a flow went unpredicted"
    assert m.duplicate_predictions == 0, "a flow was predicted twice"
    assert stats.control["swaps"] == 1, "the scheduled swap never fired"

    # flows that started after the swap must be bit-identical to a
    # knee-pipeline-only fleet (exactly-once under the new config);
    # flows straddling the swap boundary are the documented §9.3
    # exemption, so the cut uses the *actual* fire point the control
    # plane reports (swaps land on control-step boundaries, not at the
    # requested packet count)
    knee_pipe = knee.pipeline or knee.build()
    svc_knee = ServiceModel.modeled(knee.rep, knee.forest())

    def knee_fleet():
        return ShardedRuntime(knee_pipe, n_shards=N_SHARDS, capacity=2048,
                              max_batch=64, execute=True)

    only_knee = replay(stream, knee_fleet, stream.base_pps, svc_knee)
    first_pkt = np.full(ds.n_flows, stream.n_events)
    np.minimum.at(first_pkt, stream.fid, np.arange(stream.n_events))
    post = np.nonzero(first_pkt >= stats.control["swap_at_pkts"])[0]
    agree = sum(stats.predictions[f] == only_knee.predictions[f] for f in post)
    print(f"{agree}/{len(post)} post-swap flows bit-identical to a "
          f"knee-only fleet")
    assert agree == len(post)
    print("\nOK: measured, optimized, compiled, deployed.")


if __name__ == "__main__":
    main()

"""Architecture registry: one module per assigned architecture.

`get(name)` returns the full published config; `get_reduced(name)` the
smoke-test scale-down of the same family (small layers/width, few experts,
tiny vocab) used by per-arch CPU tests.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "qwen3_8b",
    "starcoder2_7b",
    "phi3_medium_14b",
    "yi_34b",
    "kimi_k2_1t_a32b",
    "qwen2_moe_a2_7b",
    "xlstm_350m",
    "whisper_small",
    "internvl2_26b",
    "zamba2_1_2b",
)

# canonical ids (as given in the assignment) -> module names
ALIASES = {
    "qwen3-8b": "qwen3_8b",
    "starcoder2-7b": "starcoder2_7b",
    "phi3-medium-14b": "phi3_medium_14b",
    "yi-34b": "yi_34b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "xlstm-350m": "xlstm_350m",
    "whisper-small": "whisper_small",
    "internvl2-26b": "internvl2_26b",
    "zamba2-1.2b": "zamba2_1_2b",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get(name: str) -> ModelConfig:
    return _module(name).config()


def get_reduced(name: str) -> ModelConfig:
    return _module(name).reduced()


def all_arch_ids() -> tuple[str, ...]:
    return tuple(ALIASES.keys())

"""internvl2-26b: VLM, LM backbone 48L d6144 48H (GQA kv=8) ff16384
vocab 92553. InternViT frontend is a STUB: input_specs() provides patch
embeddings. [arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92553, head_dim=128,
        act="swiglu", rope_theta=5e6, num_patches=1024,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-reduced", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        act="swiglu", dtype="float32", num_patches=16, attn_chunk=0,
    )

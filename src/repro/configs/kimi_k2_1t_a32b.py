"""kimi-k2-1t-a32b: MoE, 61L d7168 64H (GQA kv=8) expert-ff 2048
vocab 163840, 384 experts top-8 + 1 shared. Trillion-parameter MoE.
[arXiv:2501.kimi2; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=0, vocab_size=163840, head_dim=128,
        n_experts=384, experts_per_tok=8, n_shared_experts=1, moe_d_ff=2048,
        act="swiglu", rope_theta=5e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab_size=256, head_dim=16,
        n_experts=8, experts_per_tok=2, n_shared_experts=1, moe_d_ff=32,
        act="swiglu", dtype="float32", attn_chunk=0,
    )

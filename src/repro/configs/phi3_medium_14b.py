"""phi3-medium-14b: dense, 40L d5120 40H (GQA kv=10) ff17920 vocab 100352.
RoPE + SwiGLU + GQA. [arXiv:2404.14219; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
        d_ff=17920, vocab_size=100352, head_dim=128,
        act="swiglu", rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b-reduced", family="dense",
        n_layers=2, d_model=80, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab_size=256, head_dim=20,
        act="swiglu", dtype="float32", attn_chunk=0,
    )

"""qwen2-moe-a2.7b: MoE, 24L d2048 16H (GQA kv=16) expert-ff 1408
vocab 151936, 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=0, vocab_size=151936, head_dim=128,
        n_experts=60, experts_per_tok=4, n_shared_experts=4, moe_d_ff=1408,
        n_expert_slots=64,  # padded so EP divides 16- and 32-wide meshes
        act="swiglu", rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=256, head_dim=16,
        n_experts=6, experts_per_tok=2, n_shared_experts=2, moe_d_ff=32,
        act="swiglu", dtype="float32", attn_chunk=0,
    )

"""starcoder2-7b: dense, 32L d4608 36H (GQA kv=4) ff18432 vocab 49152.
GQA + RoPE. [arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_ff=18432, vocab_size=49152, head_dim=128,
        act="gelu", rope_theta=1e5,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-reduced", family="dense",
        n_layers=2, d_model=72, n_heads=6, n_kv_heads=2,
        d_ff=144, vocab_size=256, head_dim=12,
        act="gelu", dtype="float32", attn_chunk=0,
    )

"""whisper-small: enc-dec audio, 12L(+12 enc) d768 12H ff3072 vocab 51865.
Conv frontend is a STUB: input_specs() provides precomputed frame
embeddings. [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, encoder_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=51865,
        act="gelu", rope_theta=1e4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-reduced", family="audio",
        n_layers=2, encoder_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        act="gelu", dtype="float32", attn_chunk=0,
    )

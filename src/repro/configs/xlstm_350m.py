"""xlstm-350m: sLSTM + mLSTM blocks, 24L d1024 4H, vocab 50304, no FFN.
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_every=2,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-reduced", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab_size=256,
        slstm_every=2, dtype="float32",
    )

"""yi-34b: dense llama-arch, 60L d7168 56H (GQA kv=8) ff20480 vocab 64000.
[arXiv:2403.04652; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab_size=64000, head_dim=128,
        act="swiglu", rope_theta=5e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-reduced", family="dense",
        n_layers=3, d_model=56, n_heads=7, n_kv_heads=1,
        d_ff=112, vocab_size=256, head_dim=8,
        act="swiglu", dtype="float32", attn_chunk=0,
    )

"""zamba2-1.2b: hybrid, 38 Mamba2 layers d2048 + shared attention block
(32H kv=32, applied every 6 layers, concat skip), ssm_state=64,
vocab 32000, d_ff 8192 unused by mamba blocks (attn block only).
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000, head_dim=64,
        ssm_state=64, ssm_expand=2, shared_attn_every=6,
        rope_theta=1e4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-reduced", family="hybrid",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=256, head_dim=32,
        ssm_state=16, ssm_expand=2, shared_attn_every=2,
        dtype="float32", attn_chunk=0,
    )

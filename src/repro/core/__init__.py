"""CATO core: multi-objective Bayesian optimization of serving pipelines.

The paper's primary contribution — an Optimizer (multi-objective BO with
MI-based dimensionality reduction and πBO prior injection) plus a Profiler
contract (measure, don't model). The traffic-analysis Profiler lives in
`repro.traffic.profiler`; the LM serving-pipeline tuner in `repro.core.tuner`
reuses the same Optimizer against the dry-run roofline cost model.
"""
from .search_space import FeatureRep, SearchSpace
from .optimizer import CatoOptimizer, CatoResult, Observation
from .evaluator import MeasurementBackend, MemoizedEvaluator
from .priors import CatoPriors, build_priors
from .pareto import (
    hvi_ratio, hypervolume_2d, knee_index, pareto_front, pareto_mask,
)
from .surrogate import RFSurrogate
from .forest import DenseForest, train_forest, train_tree

__all__ = [
    "FeatureRep",
    "SearchSpace",
    "CatoOptimizer",
    "CatoResult",
    "Observation",
    "MeasurementBackend",
    "MemoizedEvaluator",
    "CatoPriors",
    "build_priors",
    "hvi_ratio",
    "hypervolume_2d",
    "knee_index",
    "pareto_front",
    "pareto_mask",
    "RFSurrogate",
    "DenseForest",
    "train_forest",
    "train_tree",
]

"""Acquisition: Expected Hypervolume Improvement with πBO prior injection.

EHVI is estimated by Monte-Carlo over the RF surrogate's per-tree joint
posterior samples. The 2-objective hypervolume improvement of a single
candidate against a staircase front is exact and vectorized over candidates
(O(M * |front|) per posterior sample).

Prior injection follows πBO (Hvarfner et al., ICLR'22), which the paper
adapts to the multi-objective setting (§4): the acquisition is multiplied by
``pi(x) ** (beta / (1 + t))`` so prior influence decays with iteration t.
"""
from __future__ import annotations

import numpy as np

from .pareto import pareto_mask

__all__ = ["hvi_contribution", "ehvi", "qehvi_greedy", "apply_pibo"]


def hvi_contribution(
    front: np.ndarray, pts: np.ndarray, ref: tuple[float, float] = (1.0, 1.0)
) -> np.ndarray:
    """Hypervolume gained by adding each of pts (M, 2) to `front` (K, 2).

    Minimization staircase; all values expected ~normalized (ref box (1,1)).
    """
    pts = np.asarray(pts, dtype=np.float64)
    rx, ry = float(ref[0]), float(ref[1])
    if front is None or len(front) == 0:
        w = np.maximum(0.0, rx - np.maximum(pts[:, 0], 0.0))
        h = np.maximum(0.0, ry - np.maximum(pts[:, 1], 0.0))
        # clip to ref box only from above; points beyond ref contribute 0
        w = np.where(pts[:, 0] >= rx, 0.0, rx - pts[:, 0])
        h = np.where(pts[:, 1] >= ry, 0.0, ry - pts[:, 1])
        return np.maximum(w, 0.0) * np.maximum(h, 0.0)

    F = np.asarray(front, dtype=np.float64)
    F = F[pareto_mask(F)]
    F = F[np.argsort(F[:, 0])]
    # intervals over x: [lo_j, r_j) with staircase height bound_j
    lo = np.concatenate([[-np.inf], F[:, 0]])           # (k+1,)
    r = np.concatenate([F[:, 0], [rx]])                 # (k+1,)
    bound = np.concatenate([[ry], F[:, 1]])             # (k+1,)

    a = pts[:, 0:1]  # (M,1)
    b = pts[:, 1:2]
    width = np.minimum(r[None, :], rx) - np.maximum(lo[None, :], a)
    height = np.minimum(bound[None, :], ry) - b
    area = np.maximum(width, 0.0) * np.maximum(height, 0.0)
    return area.sum(axis=1)


def ehvi(
    post_samples: np.ndarray,  # (T, M, 2) posterior draws (normalized objs)
    front: np.ndarray,         # (K, 2) current normalized front
    ref: tuple[float, float] = (1.0, 1.0),
) -> np.ndarray:
    """Monte-Carlo EHVI per candidate, (M,)."""
    T = post_samples.shape[0]
    acc = np.zeros(post_samples.shape[1], dtype=np.float64)
    for t in range(T):
        acc += hvi_contribution(front, post_samples[t], ref)
    return acc / T


def qehvi_greedy(
    post_samples: np.ndarray,  # (T, M, 2) posterior draws (normalized objs)
    front: np.ndarray,         # (K, 2) current normalized front
    q: int,
    *,
    ref: tuple[float, float] = (1.0, 1.0),
    log_prior: np.ndarray | None = None,
    iteration: int = 0,
    beta: float = 0.0,
) -> list[int]:
    """Greedy q-EHVI batch selection: candidate indices, best first.

    Joint q-EHVI is approximated by the standard sequential-greedy
    scheme: pick the EHVI argmax, *fantasize* the pick into every
    posterior sample's front (sample t contributes its own draw of the
    pick, preserving the joint coupling across objectives), rescore the
    remainder against the augmented fronts, repeat. Hypervolume
    improvement is submodular, so greedy keeps the (1 - 1/e)
    approximation guarantee. πBO prior weight (`log_prior`) is applied
    at every pick of the batch — the whole batch belongs to the same
    iteration `t` in the decay schedule.
    """
    T, M, _ = post_samples.shape
    base = np.asarray(front, dtype=np.float64).reshape(-1, 2)
    fronts = [base] * T
    chosen: list[int] = []
    avail = np.ones(M, dtype=bool)
    for _ in range(min(q, M)):
        acc = np.zeros(M, dtype=np.float64)
        for t in range(T):
            acc += hvi_contribution(fronts[t], post_samples[t], ref)
        acq = acc / T
        if log_prior is not None:
            acq = apply_pibo(acq, log_prior, iteration, beta)
        pick = int(np.argmax(np.where(avail, acq, -np.inf)))
        chosen.append(pick)
        avail[pick] = False
        fronts = [
            np.vstack([fronts[t], post_samples[t, pick][None, :]])
            for t in range(T)
        ]
    return chosen


def scalarized_ei(
    post_samples: np.ndarray,  # (T, M, 2) posterior draws (normalized objs)
    Y_obs: np.ndarray,         # (n, 2) normalized observations
    lam: float,
) -> np.ndarray:
    """ParEGO-style expected improvement under a random augmented-Chebyshev
    scalarization — spreads samples across the front (HyperMapper uses random
    scalarizations of the posterior for its multi-objective mode)."""
    w = np.array([lam, 1.0 - lam])

    def scal(Y):
        return np.max(Y * w, axis=-1) + 0.05 * np.sum(Y * w, axis=-1)

    best = scal(Y_obs).min()
    s = scal(post_samples)          # (T, M)
    return np.maximum(0.0, best - s).mean(axis=0)


def apply_pibo(
    acq: np.ndarray, log_prior: np.ndarray, iteration: int, beta: float = 10.0
) -> np.ndarray:
    """acq * pi(x)^(beta/(1+t)), computed stably in log space."""
    w = beta / (1.0 + iteration)
    lp = log_prior - log_prior.max()
    return (acq + 1e-12) * np.exp(w * lp)

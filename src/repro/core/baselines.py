"""Baseline search strategies and point-solution feature selectors.

Pareto-front estimators compared in paper §5.3 (Fig. 6/7):
  - SIMANNEAL   multi-objective simulated annealing (Appendix E)
  - RANDSEARCH  uniform sampling without replacement
  - ITERATEALL  all features, packet depth incremented per iteration

Point-solution selectors compared in §5.2 (Fig. 5), each at a fixed depth:
  - ALL    use every candidate feature
  - RFEk   recursive feature elimination down to k features
  - MIk    top-k features by mutual information
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from .evaluator import MemoizedEvaluator
from .forest import train_forest
from .mutual_info import mi_scores
from .optimizer import CatoResult, Observation
from .search_space import FeatureRep, SearchSpace

__all__ = [
    "run_random_search",
    "run_iterate_all",
    "run_simulated_annealing",
    "select_all",
    "select_mi_topk",
    "select_rfe_topk",
]


def _shared_evaluator(profiler) -> MemoizedEvaluator:
    """Baselines evaluate through the same memoized layer as
    `CatoOptimizer` (pass an existing `MemoizedEvaluator` to share its
    per-fidelity cache across algorithms), so cost comparisons are
    measured through identical code — DESIGN.md §10.2."""
    if isinstance(profiler, MemoizedEvaluator):
        return profiler
    return MemoizedEvaluator(profiler)


def run_random_search(
    space: SearchSpace,
    profiler: Callable | MemoizedEvaluator,
    n_iterations: int,
    seed: int = 0,
    fidelity: str | None = None,
) -> CatoResult:
    ev = _shared_evaluator(profiler)
    rng = np.random.default_rng(seed)
    obs, seen = [], set()
    it = 0
    while len(obs) < n_iterations:
        x = space.sample_uniform(rng, 1)[0]
        if x.key() in seen:
            continue
        seen.add(x.key())
        obs.append(ev.evaluate(x, it, fidelity))
        it += 1
    return CatoResult(obs, space)


def run_iterate_all(
    space: SearchSpace,
    profiler: Callable | MemoizedEvaluator,
    n_iterations: int,
    fidelity: str | None = None,
) -> CatoResult:
    """All features; depth = 1, 2, 3, ... (paper §5.3)."""
    ev = _shared_evaluator(profiler)
    obs = []
    for it in range(n_iterations):
        d = space.min_depth + it
        if d > space.max_depth:
            break
        x = FeatureRep(space.feature_names, d)
        obs.append(ev.evaluate(x, it, fidelity))
    return CatoResult(obs, space)


def run_simulated_annealing(
    space: SearchSpace,
    profiler: Callable | MemoizedEvaluator,
    n_iterations: int,
    seed: int = 0,
    t0: float = 1.0,
    cooling: float = 0.99,
    fidelity: str | None = None,
) -> CatoResult:
    """Multi-objective SA per paper Appendix E.

    Neighbors perturb the feature set or the depth with equal probability;
    the depth step size decays linearly over the run. A dominating neighbor
    is always accepted; otherwise accept with prob exp((f(x)-f(x_i))/T_i)
    where f is the equal-weighted combination of normalized objectives.
    """
    ev = _shared_evaluator(profiler)
    rng = np.random.default_rng(seed)
    obs: list[Observation] = []

    cur = space.sample_uniform(rng, 1)[0]
    cur_obs = ev.evaluate(cur, 0, fidelity)
    obs.append(cur_obs)
    T = t0

    def scalar(o: Observation, lo, hi) -> float:
        span = np.where(hi > lo, hi - lo, 1.0)
        y = (np.array(o.objectives) - lo) / span
        return float(y.mean())

    for it in range(1, n_iterations):
        # linearly decaying max depth step (Appendix E)
        frac = 1.0 - it / max(1, n_iterations)
        step = max(1, int(frac * (space.max_depth - space.min_depth)))
        nb = space.mutate(rng, cur_obs.x, depth_step=step)
        nb_obs = ev.evaluate(nb, it, fidelity)
        obs.append(nb_obs)

        Y = np.array([o.objectives for o in obs])
        lo, hi = Y.min(0), Y.max(0)
        dominates = (
            nb_obs.cost <= cur_obs.cost and nb_obs.perf >= cur_obs.perf
            and (nb_obs.cost < cur_obs.cost or nb_obs.perf > cur_obs.perf)
        )
        if dominates:
            cur_obs = nb_obs
        else:
            p = np.exp(
                (scalar(cur_obs, lo, hi) - scalar(nb_obs, lo, hi)) / max(T, 1e-9)
            )
            if rng.random() < min(1.0, p):
                cur_obs = nb_obs
        T *= cooling
    return CatoResult(obs, space)


# ---------------------------------------------------------------------------
# Point-solution feature selectors (paper §5.2 baselines)
# ---------------------------------------------------------------------------

def select_all(space: SearchSpace, depth: int) -> FeatureRep:
    return FeatureRep(space.feature_names, depth)


def select_mi_topk(
    space: SearchSpace,
    depth: int,
    X_feat: np.ndarray,
    y: np.ndarray,
    k: int = 10,
    seed: int = 0,
) -> FeatureRep:
    """Top-k features by mutual information (columns of X_feat follow
    space.feature_names order, computed at `depth`)."""
    mi = mi_scores(X_feat, y, seed=seed)
    top = np.argsort(-mi)[:k]
    return FeatureRep(tuple(space.feature_names[i] for i in top), depth)


def select_rfe_topk(
    space: SearchSpace,
    depth: int,
    X_feat: np.ndarray,
    y: np.ndarray,
    k: int = 10,
    seed: int = 0,
    n_trees: int = 25,
    max_depth: int = 8,
) -> FeatureRep:
    """Recursive feature elimination with a forest importance ranking.

    Trains on all remaining features, removes the least important, repeats
    until k remain (Guyon et al. [26] wrapper).
    """
    rng = np.random.default_rng(seed)
    remaining = list(range(space.n_features))
    while len(remaining) > k:
        f = train_forest(
            X_feat[:, remaining],
            y,
            n_trees=n_trees,
            max_depth=max_depth,
            classification=True,
            rng=rng,
        )
        imp = f.feature_importance()
        drop = int(np.argmin(imp))
        remaining.pop(drop)
    return FeatureRep(tuple(space.feature_names[i] for i in remaining), depth)

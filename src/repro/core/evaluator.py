"""Shared memoized evaluation layer for every search algorithm.

CATO's cost claims are comparative — "CATO reaches a better front than
SIMANNEAL at the same measurement budget" — so every algorithm must pay
for measurements through *identical* code, and a configuration measured
once must cost nothing the second time, no matter which algorithm asks
(DESIGN.md §10.2). Historically `CatoOptimizer._evaluate` and
`baselines._evaluate` were parallel implementations of the same
profiler-result-to-`Observation` conversion; this module is the single
shared version, with two additions:

- **memoization** keyed on the canonical config key (`x.key()`), per
  fidelity: the underlying profiler runs at most once per distinct
  (config, fidelity) for the evaluator's lifetime, and repeat requests
  return the *same* cached result object bit-for-bit;
- **fidelity routing**: `profile` may be a single callable (the
  historical contract) or an ordered mapping of fidelity name ->
  backend callable, cheap first (see `repro.traffic.backends` for the
  traffic suite). Per-fidelity call/hit/wall-clock accounting backs the
  multi-fidelity optimizer's budget and the tune-smoke CI gate.

Any object with a ``name`` and ``__call__(x) -> result`` works as a
backend (the `MeasurementBackend` protocol); results may be a
`ProfileResult`-shaped object (``.cost``/``.perf``/``.aux``), an
`Observation`, or a plain ``(cost, perf)`` tuple.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping, Protocol, runtime_checkable

from .optimizer import Observation

__all__ = ["MeasurementBackend", "MemoizedEvaluator"]


@runtime_checkable
class MeasurementBackend(Protocol):
    """One fidelity of the measure step: a named profiler callable."""

    name: str

    def __call__(self, x: Any) -> Any:
        ...


def canonical_key(x: Any):
    """The memoization key: `x.key()` when the config defines one."""
    return x.key() if hasattr(x, "key") else x


class MemoizedEvaluator:
    """Memoized `profile(x) -> Observation` shared across algorithms.

    `profile` is either one callable (single fidelity, named "") or an
    ordered mapping fidelity -> callable, **cheapest first** — the last
    entry is the expensive "measured" fidelity that default evaluations
    and budget accounting target.
    """

    def __init__(self, profile: Callable | Mapping[str, Callable]):
        if isinstance(profile, Mapping):
            self._backends = dict(profile)
            if not self._backends:
                raise ValueError("empty backend mapping")
        else:
            self._backends = {"": profile}
        self._cache: dict[tuple, Any] = {}
        self.n_calls = {f: 0 for f in self._backends}   # real measurements
        self.n_hits = {f: 0 for f in self._backends}    # memoized returns
        self.wall_s = {f: 0.0 for f in self._backends}  # measurement wall
        self.n_seeded = {f: 0 for f in self._backends}  # pre-loaded results

    # -- fidelity spectrum ---------------------------------------------------
    @property
    def fidelities(self) -> tuple[str, ...]:
        """Backend names, cheapest first."""
        return tuple(self._backends)

    @property
    def cheapest(self) -> str:
        return next(iter(self._backends))

    @property
    def measured(self) -> str:
        """The expensive fidelity: the last (rightmost) backend."""
        return next(reversed(self._backends))

    @property
    def multi_fidelity(self) -> bool:
        return len(self._backends) > 1

    # -- cache seeding (shadow-evaluation warm start) ------------------------
    def seed(self, x: Any, result: Any, fidelity: str | None = None) -> bool:
        """Pre-load the memo cache with a known (config, fidelity) result.

        The self-optimizing fleet's shadow re-tune seeds its evaluator
        from the deployed bundle's observations so already-paid
        measurements are never re-bought inside an episode. Seeding never
        overwrites: a result this evaluator measured itself wins over an
        imported one. Returns True when the seed was installed."""
        fid = self.measured if fidelity is None else fidelity
        if fid not in self._backends:
            raise KeyError(
                f"unknown fidelity {fid!r}; evaluator has {self.fidelities}")
        key = (canonical_key(x), fid)
        if key in self._cache:
            return False
        self._cache[key] = result
        self.n_seeded[fid] += 1
        return True

    def seed_from(self, observations, fidelity: str | None = None) -> int:
        """Seed the cache from prior `Observation`s (or anything with
        ``.x``/``.cost``/``.perf``). Every observation lands at `fidelity`
        (default: the expensive backend) regardless of the fidelity tag it
        carries — the caller asserts the old measurements are still valid
        at that level. Returns the number of fresh seeds installed."""
        n = 0
        for o in observations:
            if self.seed(o.x, o, fidelity):
                n += 1
        return n

    # -- evaluation ----------------------------------------------------------
    def profile(self, x: Any, fidelity: str | None = None) -> tuple[Any, float]:
        """Memoized raw profiler call -> (result, measurement_seconds).

        Repeat requests for the same (canonical key, fidelity) return the
        cached result object itself — bit-identical across algorithms —
        with zero measurement time charged.
        """
        fid = self.measured if fidelity is None else fidelity
        if fid not in self._backends:
            raise KeyError(
                f"unknown fidelity {fid!r}; evaluator has {self.fidelities}")
        key = (canonical_key(x), fid)
        if key in self._cache:
            self.n_hits[fid] += 1
            return self._cache[key], 0.0
        t0 = time.perf_counter()
        res = self._backends[fid](x)
        dt = time.perf_counter() - t0
        self.n_calls[fid] += 1
        self.wall_s[fid] += dt
        self._cache[key] = res
        return res, dt

    def evaluate(
        self, x: Any, iteration: int = -1, fidelity: str | None = None
    ) -> Observation:
        """Profile `x` and normalize the result into an `Observation`."""
        fid = self.measured if fidelity is None else fidelity
        res, dt = self.profile(x, fid)
        if isinstance(res, Observation):
            obs = dataclasses.replace(res, x=x, aux=dict(res.aux))
        elif hasattr(res, "cost") and hasattr(res, "perf"):
            obs = Observation(
                x, float(res.cost), float(res.perf),
                aux=dict(getattr(res, "aux", {})),
            )
        else:
            cost, perf = res
            obs = Observation(x, float(cost), float(perf))
        obs.iteration = iteration
        obs.elapsed_s = dt
        obs.fidelity = fid
        return obs

    # -- accounting ----------------------------------------------------------
    def budget_summary(self) -> dict:
        """Per-fidelity unique-measurement counts and wall-clock."""
        return {
            f: {
                "measurements": self.n_calls[f],
                "memo_hits": self.n_hits[f],
                "seeded": self.n_seeded[f],
                "wall_s": round(self.wall_s[f], 4),
            }
            for f in self._backends
        }

"""Histogram-based decision trees and random forests (numpy training).

This module backs two distinct users:

1. The CATO Optimizer's *surrogate model* (regression forests over the
   feature-representation search space, as in HyperMapper [50]).
2. The traffic-analysis *models themselves* (decision tree for app-class,
   random forest for iot-class, as in the paper's §4).

There is no sklearn in this environment, so training is implemented here:
level-wise (breadth-first) greedy splitting on quantile-binned features,
vectorized with ``np.bincount`` over (node, feature, bin) keys — the
LightGBM-style histogram algorithm.

Trees are stored in a *dense complete level-order layout*: a tree of
``max_depth`` D is a perfect binary tree with ``2**D - 1`` internal slots and
``2**D`` leaf slots. Traversal is pure index arithmetic —
``node <- 2*node + 1 + (x[feat] > thresh)`` — with no pointer chasing, which
is exactly the representation the TPU Pallas kernel (`repro.kernels.tree_infer`)
consumes. Unused internal slots are pass-through (feature 0, threshold +inf:
always branch left); unused leaves replicate their parent's prediction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "DenseForest",
    "train_tree",
    "train_forest",
    "forest_apply_np",
    "forest_predict_class",
    "forest_predict_value",
]


@dataclasses.dataclass
class DenseForest:
    """A forest in dense complete level-order layout.

    Attributes:
      feature:   (n_trees, 2**D - 1) int32   — split feature per internal node.
      threshold: (n_trees, 2**D - 1) float32 — split threshold (x <= t: left).
      leaf:      (n_trees, 2**D, n_out) float32 — leaf payload (class histogram
                 for classifiers, scalar mean for regressors with n_out == 1).
      depth:     D
      n_features: number of input features the trees were trained on.
      classes:   optional class labels (classification only).
    """

    feature: np.ndarray
    threshold: np.ndarray
    leaf: np.ndarray
    depth: int
    n_features: int
    classes: Optional[np.ndarray] = None

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def n_out(self) -> int:
        return self.leaf.shape[-1]

    def feature_importance(self) -> np.ndarray:
        """Split-count importance over features (cheap RFE driver)."""
        imp = np.zeros(self.n_features, dtype=np.float64)
        live = self.threshold < np.inf  # pass-through slots have +inf
        for t in range(self.n_trees):
            f = self.feature[t][live[t]]
            np.add.at(imp, f, 1.0)
        s = imp.sum()
        return imp / s if s > 0 else imp


# ---------------------------------------------------------------------------
# Binning
# ---------------------------------------------------------------------------

def _quantile_bins(X: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature bin edges from quantiles. Returns (n_feat, n_bins-1)."""
    qs = np.linspace(0, 100, n_bins + 1)[1:-1]
    edges = np.nanpercentile(X, qs, axis=0).T.astype(np.float32)  # (F, B-1)
    return edges


def _digitize(X: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Bin each feature column by its edges. Returns uint8 (n, F)."""
    n, F = X.shape
    out = np.empty((n, F), dtype=np.uint8)
    for f in range(F):
        out[:, f] = np.searchsorted(edges[f], X[:, f], side="left")
    return out


# ---------------------------------------------------------------------------
# Level-wise tree growth
# ---------------------------------------------------------------------------

def _grow_tree(
    binned: np.ndarray,        # (n, F) uint8
    edges: np.ndarray,         # (F, B-1) float32 bin upper-edges
    y_onehot: np.ndarray,      # (n, K) float32 — one-hot labels or y[:, None]
    max_depth: int,
    min_samples_leaf: int,
    feature_subsample: Optional[np.ndarray],  # candidate feature ids or None
    rng: np.random.Generator,
    classification: bool,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Grow one tree level-wise; return dense (feature, threshold, leaf)."""
    n, F = binned.shape
    K = y_onehot.shape[1]
    B = int(edges.shape[1]) + 1
    n_internal = 2 ** max_depth - 1
    n_leaves = 2 ** max_depth

    feat_arr = np.zeros(n_internal, dtype=np.int32)
    thr_arr = np.full(n_internal, np.inf, dtype=np.float32)
    leaf_arr = np.zeros((n_leaves, K), dtype=np.float32)

    # node assignment of each sample within the current level, offset-free:
    # at level d, nodes are numbered 0..2**d-1 (local); global internal index
    # of local node j at level d is (2**d - 1) + j.
    node = np.zeros(n, dtype=np.int64)
    active = np.ones(n, dtype=bool)  # samples in nodes that may still split

    cand_feats = (
        np.arange(F, dtype=np.int64) if feature_subsample is None else feature_subsample
    )

    y_idx_full = y_onehot.argmax(axis=1) if classification else None

    # Track per-node "is frozen" (became leaf early); frozen samples keep
    # propagating left so their final leaf is deterministic.
    for d in range(max_depth):
        base = 2 ** d - 1
        idx = np.nonzero(active)[0]
        if idx.size == 0:
            break
        # compact node renumbering: only populated nodes get histogram slots
        uniq, nd = np.unique(node[idx], return_inverse=True)
        width = uniq.size
        # per (node, feature, bin, class) histogram via ONE fused bincount:
        # keys: (((nd * Fc + fi) * B + bin) * K + class)
        Fc = cand_feats.size
        sub_binned = binned[idx][:, cand_feats]  # (m, Fc)
        key_base = (nd[:, None] * Fc + np.arange(Fc)[None, :]) * B + sub_binned
        size = width * Fc * B
        if classification:
            y_idx = y_idx_full[idx]  # (m,)
            keys_k = key_base * K + y_idx[:, None]
            hist_y = np.bincount(keys_k.ravel(), minlength=size * K).astype(
                np.float64
            ).reshape(width, Fc, B, K)
            hist_cnt = hist_y.sum(axis=-1)
        else:
            hist_cnt = np.bincount(key_base.ravel(), minlength=size).astype(
                np.float64
            ).reshape(width, Fc, B)
            w = np.repeat(y_onehot[idx, 0], Fc)
            hist_y = np.bincount(
                key_base.ravel(), weights=w, minlength=size
            ).reshape(width, Fc, B)[..., None]

        # cumulative left stats over bins (split at bin b => left: bins <= b)
        cnt_l = np.cumsum(hist_cnt, axis=2)                     # (W, Fc, B)
        y_l = np.cumsum(hist_y, axis=2)                         # (W, Fc, B, K)
        cnt_tot = cnt_l[:, :, -1:]                              # (W, Fc, 1)
        y_tot = y_l[:, :, -1:, :]
        cnt_r = cnt_tot - cnt_l
        y_r = y_tot - y_l

        with np.errstate(divide="ignore", invalid="ignore"):
            if classification:
                # gini impurity decrease ∝ sum_k l_k^2 / n_l + r_k^2 / n_r
                score = np.where(cnt_l > 0, (y_l ** 2).sum(-1) / cnt_l, 0.0) + np.where(
                    cnt_r > 0, (y_r ** 2).sum(-1) / cnt_r, 0.0
                )
            else:
                # variance reduction ∝ s_l^2 / n_l + s_r^2 / n_r
                score = np.where(cnt_l > 0, y_l[..., 0] ** 2 / cnt_l, 0.0) + np.where(
                    cnt_r > 0, y_r[..., 0] ** 2 / cnt_r, 0.0
                )

        # forbid splits producing undersized children or at the last bin
        ok = (cnt_l >= min_samples_leaf) & (cnt_r >= min_samples_leaf)
        ok[:, :, -1] = False
        score = np.where(ok, score, -np.inf)

        flat = score.reshape(width, -1)
        best = np.argmax(flat, axis=1)                          # (W,)
        best_score = flat[np.arange(width), best]
        best_f_local = best // B
        best_bin = best % B

        # parent score (no-split baseline)
        node_cnt = cnt_tot[:, 0, 0]
        node_y = y_tot[:, 0, 0, :]
        with np.errstate(divide="ignore", invalid="ignore"):
            if classification:
                parent = np.where(node_cnt > 0, (node_y ** 2).sum(-1) / node_cnt, 0.0)
            else:
                parent = np.where(node_cnt > 0, node_y[:, 0] ** 2 / node_cnt, 0.0)
        do_split = best_score > parent + 1e-12

        f_global = cand_feats[best_f_local]
        thr = edges[f_global, np.minimum(best_bin, B - 2)]
        # scatter compact results back to the level's dense slots
        feat_arr[base + uniq] = np.where(do_split, f_global, 0)
        thr_arr[base + uniq] = np.where(do_split, thr, np.inf)

        # route samples: x goes right iff bin > split_bin *and* node split
        nd_split = do_split[nd]
        go_right = nd_split & (
            binned[idx, f_global[nd]] > best_bin[nd]
        )
        node[idx] = uniq[nd] * 2 + go_right
        # samples in non-split nodes keep flowing left (pass-through)

    # leaves: final node at depth max_depth
    full = node  # every sample ends at depth == number of completed levels
    # If loop broke early, propagate remaining levels as pass-through (left).
    leaf_idx = full
    cnt = np.bincount(leaf_idx, minlength=n_leaves).astype(np.float64)
    for k in range(K):
        leaf_arr[:, k] = np.bincount(
            leaf_idx, weights=y_onehot[:, k], minlength=n_leaves
        )
    nz = cnt > 0
    leaf_arr[nz] /= cnt[nz, None]
    # empty leaves inherit nearest populated ancestor value via parent fill
    if (~nz).any():
        # fill upward: average over populated sibling or global mean
        global_mean = y_onehot.mean(axis=0)
        # walk each empty leaf up through its pass-through chain: since
        # pass-through routes left, an empty leaf's nearest populated
        # relative is its left-walk sibling subtree; fall back to global mean.
        fill = leaf_arr[nz].mean(axis=0) if nz.any() else global_mean
        leaf_arr[~nz] = fill
    return feat_arr, thr_arr, leaf_arr


def train_tree(
    X: np.ndarray,
    y: np.ndarray,
    *,
    max_depth: int = 8,
    min_samples_leaf: int = 1,
    n_bins: int = 32,
    classification: bool = True,
    rng: Optional[np.random.Generator] = None,
) -> DenseForest:
    """Train a single decision tree (no bootstrap, all features)."""
    return train_forest(
        X,
        y,
        n_trees=1,
        max_depth=max_depth,
        min_samples_leaf=min_samples_leaf,
        n_bins=n_bins,
        classification=classification,
        bootstrap=False,
        max_features=None,
        rng=rng,
    )


def train_forest(
    X: np.ndarray,
    y: np.ndarray,
    *,
    n_trees: int = 100,
    max_depth: int = 8,
    min_samples_leaf: int = 1,
    n_bins: int = 32,
    classification: bool = True,
    bootstrap: bool = True,
    max_features: Optional[str | int] = "sqrt",
    rng: Optional[np.random.Generator] = None,
) -> DenseForest:
    """Train a random forest. X: (n, F) float; y: (n,) int labels or float."""
    rng = rng or np.random.default_rng(0)
    X = np.asarray(X, dtype=np.float32)
    n, F = X.shape
    if classification:
        classes, y_enc = np.unique(np.asarray(y), return_inverse=True)
        K = classes.size
        y_onehot = np.zeros((n, K), dtype=np.float32)
        y_onehot[np.arange(n), y_enc] = 1.0
    else:
        classes = None
        y_onehot = np.asarray(y, dtype=np.float64)[:, None]
        K = 1

    edges = _quantile_bins(X, n_bins)
    binned = _digitize(X, edges)

    if max_features is None:
        m_feat = F
    elif max_features == "sqrt":
        m_feat = max(1, int(np.sqrt(F)))
    else:
        m_feat = int(max_features)

    feats, thrs, leaves = [], [], []
    for t in range(n_trees):
        if bootstrap:
            sel = rng.integers(0, n, size=n)
        else:
            sel = np.arange(n)
        sub = rng.choice(F, size=m_feat, replace=False) if m_feat < F else None
        f, th, lf = _grow_tree(
            binned[sel],
            edges,
            y_onehot[sel],
            max_depth,
            min_samples_leaf,
            np.sort(sub) if sub is not None else None,
            rng,
            classification,
        )
        feats.append(f)
        thrs.append(th)
        leaves.append(lf)

    return DenseForest(
        feature=np.stack(feats).astype(np.int32),
        threshold=np.stack(thrs).astype(np.float32),
        leaf=np.stack(leaves).astype(np.float32),
        depth=max_depth,
        n_features=F,
        classes=classes,
    )


# ---------------------------------------------------------------------------
# Inference (numpy reference; the Pallas kernel mirrors this exactly)
# ---------------------------------------------------------------------------

def forest_apply_np(forest: DenseForest, X: np.ndarray) -> np.ndarray:
    """Average leaf payload across trees. Returns (n, n_out)."""
    X = np.asarray(X, dtype=np.float32)
    n = X.shape[0]
    acc = np.zeros((n, forest.n_out), dtype=np.float64)
    for t in range(forest.n_trees):
        node = np.zeros(n, dtype=np.int64)
        for _ in range(forest.depth):
            f = forest.feature[t][node]
            th = forest.threshold[t][node]
            node = 2 * node + 1 + (X[np.arange(n), f] > th)
        leaf = node - (2 ** forest.depth - 1)
        acc += forest.leaf[t][leaf]
    return (acc / forest.n_trees).astype(np.float32)


def forest_predict_class(forest: DenseForest, X: np.ndarray) -> np.ndarray:
    probs = forest_apply_np(forest, X)
    idx = probs.argmax(axis=1)
    return forest.classes[idx] if forest.classes is not None else idx


def forest_predict_value(forest: DenseForest, X: np.ndarray) -> np.ndarray:
    return forest_apply_np(forest, X)[:, 0]


def forest_predict_per_tree(forest: DenseForest, X: np.ndarray) -> np.ndarray:
    """Per-tree regression predictions, (n_trees, n). Surrogate uncertainty."""
    X = np.asarray(X, dtype=np.float32)
    n = X.shape[0]
    out = np.empty((forest.n_trees, n), dtype=np.float32)
    for t in range(forest.n_trees):
        node = np.zeros(n, dtype=np.int64)
        for _ in range(forest.depth):
            f = forest.feature[t][node]
            th = forest.threshold[t][node]
            node = 2 * node + 1 + (X[np.arange(n), f] > th)
        leaf = node - (2 ** forest.depth - 1)
        out[t] = forest.leaf[t][leaf, 0]
    return out

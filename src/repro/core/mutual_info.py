"""Mutual information estimation (feature -> class label).

Used by CATO's dimensionality-reduction preprocessing ("exclude features with
a mutual information score of zero", paper §3.3) and to build the per-feature
priors P(f in F | x in Pareto). Continuous features are quantile-binned; MI is
computed from the joint histogram with a small-sample bias guard (permutation
baseline subtraction so that independent features score ~0).
"""
from __future__ import annotations

import numpy as np

__all__ = ["mutual_information", "mi_scores"]


def _binned(x: np.ndarray, n_bins: int) -> np.ndarray:
    qs = np.linspace(0, 100, n_bins + 1)[1:-1]
    edges = np.unique(np.percentile(x, qs))
    return np.searchsorted(edges, x, side="left")


def mutual_information(
    x: np.ndarray, y: np.ndarray, n_bins: int = 16, rng: np.random.Generator | None = None
) -> float:
    """MI(x; y) in nats; y integer labels; debiased by permutation baseline."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y)
    _, y = np.unique(y, return_inverse=True)
    xb = _binned(x, n_bins)

    def _mi(xb_):
        joint = np.zeros((xb_.max() + 1, y.max() + 1))
        np.add.at(joint, (xb_, y), 1.0)
        joint /= joint.sum()
        px = joint.sum(axis=1, keepdims=True)
        py = joint.sum(axis=0, keepdims=True)
        nz = joint > 0
        return float((joint[nz] * np.log(joint[nz] / (px @ py)[nz])).sum())

    mi = _mi(xb)
    rng = rng or np.random.default_rng(0)
    base = _mi(rng.permutation(xb))
    return max(0.0, mi - base)


def mi_scores(
    X: np.ndarray, y: np.ndarray, n_bins: int = 16, seed: int = 0
) -> np.ndarray:
    """Per-column MI scores for a feature matrix X (n, F)."""
    rng = np.random.default_rng(seed)
    return np.array(
        [mutual_information(X[:, j], y, n_bins, rng) for j in range(X.shape[1])]
    )

"""The CATO Optimizer: multi-objective BO over feature representations.

Loop (paper §3.3 + Fig. 3):
  1. preprocessing — MI dimensionality reduction + automatic prior build
     (done by the caller via `build_priors`; pass priors=None for CATO-BASE);
  2. init — `n_init` points sampled from the priors (random but
     prior-weighted, §5.5);
  3. iterate — fit RF surrogate on observations, draw a candidate pool
     (prior samples + uniform samples + mutations of incumbent Pareto
     points), score with MC-EHVI, inject πBO prior weight, evaluate the
     argmax with the *real* Profiler, update observations.

Evaluation goes through a `MemoizedEvaluator` — the same memoized layer
every baseline uses, so cost comparisons are measured through identical
code and a config is profiled at most once per fidelity (DESIGN.md
§10.2). The raw `profile(x) -> (cost, perf)` / `ProfileResult` callable
contract still works (it is wrapped on construction); both objectives
are minimized internally as ``(cost, -perf)``.

Two loop shapes exist:

- `run` — the paper's sequential loop (batch_size=1 reproduces it
  draw-for-draw); batch_size>1 proposes q-EHVI greedy batches at one
  fidelity.
- `run_multi_fidelity` — the batched **measure → optimize** loop
  (DESIGN.md §10.3): propose a batch, evaluate it at the *cheap*
  fidelity, and promote only candidates on the current cheap front to
  the expensive measured fidelity (successive-halving-style budget
  split). The surrogate is fidelity-aware (a level input column), so
  low-fidelity points inform the posterior without polluting the
  measured front, and the returned `CatoResult` reports the
  measured-fidelity Pareto set.

The optimizer is space-generic: any object implementing the `SearchSpace`
protocol (encode / sample_uniform / sample_from_priors / mutate) works —
`repro.core.tuner` reuses it for LM serving-pipeline configuration search.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional

import numpy as np

from .acquisition import apply_pibo, ehvi, qehvi_greedy, scalarized_ei
from .pareto import normalize_objectives, pareto_mask
from .priors import CatoPriors
from .search_space import SearchSpace
from .surrogate import RFSurrogate

__all__ = ["Observation", "CatoResult", "CatoOptimizer"]


@dataclasses.dataclass
class Observation:
    x: Any                 # FeatureRep (or tuner config)
    cost: float
    perf: float
    aux: dict = dataclasses.field(default_factory=dict)
    iteration: int = -1
    elapsed_s: float = 0.0
    fidelity: str = ""     # which measurement backend produced it

    @property
    def objectives(self) -> tuple[float, float]:
        """(cost, -perf) — both minimized."""
        return (self.cost, -self.perf)


@dataclasses.dataclass
class CatoResult:
    observations: list[Observation]
    space: Any
    # iterations where `surrogate.fit` failed and proposal degraded to
    # random search — convergence plots must be able to tell BO from
    # accidental random (DESIGN.md §10.3)
    surrogate_fallbacks: list[int] = dataclasses.field(default_factory=list)
    fidelity_counts: dict = dataclasses.field(default_factory=dict)
    # set by multi-fidelity runs: the expensive fidelity whose
    # observations form the reported Pareto set
    measured_fidelity: Optional[str] = None
    budget: dict = dataclasses.field(default_factory=dict)

    def observations_at(self, fidelity: str) -> list[Observation]:
        return [o for o in self.observations if o.fidelity == fidelity]

    def measured_observations(self) -> list[Observation]:
        """Observations backing the reported front: the measured-fidelity
        subset of a multi-fidelity run, every observation otherwise."""
        if self.measured_fidelity is None:
            return list(self.observations)
        return self.observations_at(self.measured_fidelity)

    def objective_matrix(self) -> np.ndarray:
        return np.array([o.objectives for o in self.observations], dtype=np.float64)

    def pareto_observations(self) -> list[Observation]:
        obs = self.measured_observations()
        if not obs:
            return []
        Y = np.array([o.objectives for o in obs], dtype=np.float64)
        mask = pareto_mask(Y)
        obs = [o for o, m in zip(obs, mask) if m]
        return sorted(obs, key=lambda o: o.cost)

    def pareto_points(self) -> np.ndarray:
        """(k, 2) array of (cost, perf) on the estimated front."""
        return np.array(
            [(o.cost, o.perf) for o in self.pareto_observations()], dtype=np.float64
        )

    def best_by_perf(self) -> Observation:
        return max(self.measured_observations(), key=lambda o: o.perf)

    def best_by_cost(self) -> Observation:
        return min(self.measured_observations(), key=lambda o: o.cost)


class CatoOptimizer:
    def __init__(
        self,
        space: SearchSpace,
        profiler: Callable[[Any], tuple[float, float] | Any],
        priors: Optional[CatoPriors] = None,
        *,
        n_init: int = 3,
        candidate_pool: int = 512,
        surrogate: Optional[RFSurrogate] = None,
        pibo_beta: float = 3.0,
        seed: int = 0,
        batch_size: int = 1,
    ):
        from .evaluator import MemoizedEvaluator

        self.space = space
        self.profiler = profiler
        self.evaluator = (
            profiler if isinstance(profiler, MemoizedEvaluator)
            else MemoizedEvaluator(profiler)
        )
        self.priors = priors
        self.n_init = n_init
        self.candidate_pool = candidate_pool
        self.surrogate = surrogate or RFSurrogate(seed=seed)
        self.pibo_beta = pibo_beta
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.observations: list[Observation] = []
        self.fallback_iterations: list[int] = []
        self._seen: set = set()

    # -- warm start (shadow re-optimization episodes) ------------------------
    def warm_start(self, observations, *, tag: str = "warm") -> int:
        """Inject prior observations to warm-start the surrogate.

        The self-optimizing fleet's re-tune episodes start from the
        deployed bundle's observations instead of a cold posterior: the
        injected points join `self.observations` (so the surrogate and
        the exploitation pool see them) and mark their configs as seen
        (so proposals spend no budget re-discovering them).

        Each injected observation is re-tagged with fidelity
        ``"{tag}:{original}"`` — a level that matches no live measurement
        backend — so warm points inform the fidelity-aware posterior as
        low-fidelity context but can never pollute the cheap promotion
        front, the measured Pareto set, or the measurement budget
        accounting. Returns the number of observations injected."""
        n = 0
        for o in observations:
            k = self._key(o.x)
            if k in self._seen:
                continue
            self.observations.append(dataclasses.replace(
                o, aux=dict(o.aux), fidelity=f"{tag}:{o.fidelity}"))
            self._seen.add(k)
            n += 1
        return n

    # -- evaluation ----------------------------------------------------------
    def _evaluate(
        self, x: Any, iteration: int, fidelity: Optional[str] = None
    ) -> Observation:
        obs = self.evaluator.evaluate(x, iteration, fidelity)
        self.observations.append(obs)
        self._seen.add(self._key(x))
        return obs

    @staticmethod
    def _key(x: Any):
        return x.key() if hasattr(x, "key") else x

    def _result(self, measured_fidelity: Optional[str] = None) -> CatoResult:
        counts: dict[str, int] = {}
        for o in self.observations:
            counts[o.fidelity] = counts.get(o.fidelity, 0) + 1
        return CatoResult(
            self.observations,
            self.space,
            surrogate_fallbacks=list(self.fallback_iterations),
            fidelity_counts=counts,
            measured_fidelity=measured_fidelity,
            budget=self.evaluator.budget_summary(),
        )

    # -- candidate generation --------------------------------------------------
    def _candidates(self, n: int) -> list[Any]:
        cands: list[Any] = []
        if self.priors is not None and hasattr(self.space, "sample_from_priors"):
            cands += self.space.sample_from_priors(
                self.rng, int(n * 0.6), self.priors.feature_probs, self.priors.depth_pmf
            )
        cands += self.space.sample_uniform(self.rng, n - len(cands))
        # exploit: mutate incumbent Pareto points. Fronts are computed
        # per fidelity — objective scales are incommensurable across
        # fidelities (a measured cost can dominate every cheap cost
        # numerically), so a mixed mask would collapse the exploitation
        # pool to measured-only incumbents. Single-fidelity runs have
        # one group, which is exactly the historical behavior.
        if self.observations:
            groups: dict[str, list[Observation]] = {}
            for o in self.observations:
                groups.setdefault(o.fidelity, []).append(o)
            inc, inc_keys = [], set()
            for grp in groups.values():
                Y = np.array([o.objectives for o in grp])
                for o, m in zip(grp, pareto_mask(Y)):
                    k = self._key(o.x)
                    if m and k not in inc_keys:
                        inc_keys.add(k)
                        inc.append(o.x)
            for x in inc:
                for _ in range(4):
                    cands.append(self.space.mutate(self.rng, x))
        # drop already-evaluated
        fresh, seen = [], set()
        for c in cands:
            k = self._key(c)
            if k in self._seen or k in seen:
                continue
            seen.add(k)
            fresh.append(c)
        return fresh

    def _sample_init(self, n: int) -> list[Any]:
        """Initialization: random but prior-weighted (paper §5.5)."""
        if self.priors is not None and hasattr(self.space, "sample_from_priors"):
            return self.space.sample_from_priors(
                self.rng, n, self.priors.feature_probs, self.priors.depth_pmf
            )
        return self.space.sample_uniform(self.rng, n)

    # -- main loop (single fidelity) -------------------------------------------
    def run(
        self,
        n_iterations: int = 50,
        verbose: bool = False,
        fidelity: Optional[str] = None,
    ) -> CatoResult:
        """Sequential (batch_size=1) or batched single-fidelity loop.

        `fidelity` picks the measurement backend (None = the evaluator's
        expensive default, which for a plain profiler callable is the
        callable itself).
        """
        for i, x in enumerate(self._sample_init(min(self.n_init, n_iterations))):
            self._evaluate(x, i, fidelity)

        it = len(self.observations)
        while it < n_iterations:
            q = min(self.batch_size, n_iterations - it)
            for x in self._propose_batch(it, q):
                obs = self._evaluate(x, it, fidelity)
                it += 1
                if verbose:
                    print(
                        f"[cato] iter {obs.iteration}: cost={obs.cost:.6g} "
                        f"perf={obs.perf:.4f} x={x}"
                    )
        return self._result()

    # -- batched multi-fidelity loop (DESIGN.md §10.3) -------------------------
    def run_multi_fidelity(
        self,
        measure_budget: int = 8,
        *,
        batch_size: Optional[int] = None,
        promote_quota: Optional[int] = None,
        max_rounds: int = 64,
        verbose: bool = False,
    ) -> CatoResult:
        """Propose batches, evaluate cheap, promote front points to measured.

        Each round proposes a q-EHVI greedy batch, evaluates it at the
        *cheapest* fidelity, and promotes at most `promote_quota`
        (default q // 2 — the successive-halving budget split) of the
        batch to the expensive *measured* fidelity. A candidate is only
        ever promoted while non-dominated among all cheap-fidelity
        observations, so the measurement budget is never spent on a
        point the cheap model already rules out. Stops once
        `measure_budget` measured evaluations have been taken (or the
        proposal stream dries up).
        """
        ev = self.evaluator
        if not ev.multi_fidelity:
            raise ValueError(
                "run_multi_fidelity needs a multi-fidelity evaluator: pass "
                "an ordered backend mapping (cheap first) as the profiler, "
                "e.g. repro.traffic.backends.backend_suite(...)"
            )
        cheap, measured = ev.cheapest, ev.measured
        q = batch_size or max(self.batch_size, 1)
        quota = promote_quota if promote_quota is not None else max(1, q // 2)

        def measured_used() -> int:
            return sum(1 for o in self.observations if o.fidelity == measured)

        # init at the cheap fidelity (deduped: prior-weighted sampling can
        # repeat a config, and a repeat would burn budget on a memo hit);
        # promote its front so the measured set is never empty
        init, init_keys = [], set()
        for x in self._sample_init(self.n_init):
            k = self._key(x)
            if k in init_keys:
                continue
            init_keys.add(k)
            init.append(x)
        init_obs = [self._evaluate(x, i, cheap) for i, x in enumerate(init)]
        it = len(self.observations)
        for o in self._promotable(init_obs, min(quota, measure_budget), cheap,
                                  measured):
            self._evaluate(o.x, it, measured)
            it += 1

        rounds = 0
        while measured_used() < measure_budget and rounds < max_rounds:
            rounds += 1
            xs = self._propose_batch(it, q, measured_fidelity=measured)
            # the no-candidates fallback can return already-seen configs
            # (tiny/exhausted spaces): a repeat adds nothing but a memo
            # hit, so drop them — and stop once nothing fresh remains
            fresh, fresh_keys = [], set()
            for x in xs:
                k = self._key(x)
                if k in self._seen or k in fresh_keys:
                    continue
                fresh_keys.add(k)
                fresh.append(x)
            if not fresh:
                break
            batch_obs = []
            for x in fresh:
                batch_obs.append(self._evaluate(x, it, cheap))
                it += 1
            k = min(quota, measure_budget - measured_used())
            promoted = self._promotable(batch_obs, k, cheap, measured)
            for o in promoted:
                m = self._evaluate(o.x, it, measured)
                it += 1
                if verbose:
                    print(
                        f"[cato-mf] round {rounds}: promoted {o.x} "
                        f"cheap=({o.cost:.4g},{o.perf:.3f}) "
                        f"measured=({m.cost:.4g},{m.perf:.3f})"
                    )
            if verbose:
                print(
                    f"[cato-mf] round {rounds}: batch={len(xs)} "
                    f"promoted={len(promoted)} "
                    f"measured {measured_used()}/{measure_budget}"
                )
        return self._result(measured_fidelity=measured)

    def _promotable(
        self, batch_obs: list[Observation], k: int, cheap: str, measured: str
    ) -> list[Observation]:
        """Members of `batch_obs` worth the measured fidelity: never a
        candidate dominated at the cheap fidelity, never one already
        measured (a memoized repeat would burn a budget slot on zero new
        information), ranked by *exclusive* hypervolume contribution to
        the cheap front. Ranking stays inside the cheap objective space
        on purpose: fidelity scales are incommensurable, and a joint
        normalization would compress every cheap cost difference into a
        sliver of the axis, reducing the ranking to perf-only."""
        if k <= 0 or not batch_obs:
            return []
        cheap_obs = [o for o in self.observations if o.fidelity == cheap]
        Y = np.array([o.objectives for o in cheap_obs], dtype=np.float64)
        front_keys = {
            self._key(o.x) for o, m in zip(cheap_obs, pareto_mask(Y)) if m
        }
        measured_keys = {
            self._key(o.x)
            for o in self.observations if o.fidelity == measured
        }
        elig, elig_keys = [], set()
        for o in batch_obs:
            key = self._key(o.x)
            if key not in front_keys or key in measured_keys:
                continue
            if key in elig_keys:  # a batch may repeat a config (fallbacks)
                continue
            elig_keys.add(key)
            elig.append(o)
        if not elig:
            return []
        from .acquisition import hvi_contribution

        Yn, lo, hi = normalize_objectives(Y)
        span = np.where(hi > lo, hi - lo, 1.0)
        front_n = Yn[pareto_mask(Y)]
        contrib = np.empty(len(elig))
        for i, o in enumerate(elig):
            yn = (np.asarray(o.objectives, dtype=np.float64) - lo) / span
            others = front_n[~np.all(front_n == yn, axis=1)]
            contrib[i] = hvi_contribution(others, yn[None, :])[0]
        order = np.argsort(-contrib, kind="stable")
        return [elig[int(i)] for i in order[:k]]

    # -- proposal --------------------------------------------------------------
    def _propose_batch(
        self, iteration: int, q: int, measured_fidelity: Optional[str] = None
    ) -> list[Any]:
        """q proposals. The q=1 single-fidelity path is the paper's
        sequential proposal, draw-for-draw; batches use greedy q-EHVI
        selection over the same posterior samples."""
        if q == 1 and measured_fidelity is None:
            return [self._propose(iteration)]
        cands = self._candidates(self.candidate_pool)
        if not cands:
            return self.space.sample_uniform(self.rng, q)
        Y = np.array([o.objectives for o in self.observations], dtype=np.float64)
        Yn, lo, hi = normalize_objectives(Y)
        X_obs = np.stack([self.space.encode(o.x) for o in self.observations])
        X_cand = np.stack([self.space.encode(c) for c in cands])
        if measured_fidelity is not None:
            # fidelity-aware surrogate: pool every observation, tagged
            # with its level; score candidates at the measured level
            levels = np.array(
                [1.0 if o.fidelity == measured_fidelity else 0.0
                 for o in self.observations], dtype=np.float32)
            X_obs = RFSurrogate.with_fidelity(X_obs, levels)
            X_cand = RFSurrogate.with_fidelity(
                X_cand, np.ones(len(cands), dtype=np.float32))
        if not self._fit_surrogate(X_obs, Yn, iteration):
            sel = self.rng.choice(len(cands), size=min(q, len(cands)),
                                  replace=False)
            return [cands[int(i)] for i in sel]
        post = self.surrogate.posterior_samples(X_cand)  # (T, M, 2)
        if measured_fidelity is not None:
            # EHVI improves the *measured* front; cheap points steer only
            # through the surrogate posterior
            m_mask = np.array(
                [o.fidelity == measured_fidelity for o in self.observations])
            Ym = Yn[m_mask]
            front = Ym[pareto_mask(Ym)] if len(Ym) else np.empty((0, 2))
        else:
            front = Yn[pareto_mask(Yn)]
        lp = None
        if self.priors is not None:
            pl = getattr(self.priors, "pi_log_clipped", self.priors.pi_log)
            lp = np.array([pl(self.space, c) for c in cands])
        idx = qehvi_greedy(
            post, front, q, log_prior=lp, iteration=iteration,
            beta=self.pibo_beta,
        )
        return [cands[i] for i in idx]

    def _fit_surrogate(self, X: np.ndarray, Y: np.ndarray, iteration: int) -> bool:
        """Fit, counting failures: a failed fit degrades the proposal to
        random search, which convergence analysis must see (fig7)."""
        try:
            self.surrogate.fit(X, Y)
            return True
        except Exception as e:  # noqa: BLE001 — any fit failure falls back
            self.fallback_iterations.append(iteration)
            warnings.warn(
                f"[cato] surrogate fit failed at iteration {iteration} "
                f"({e!r}); proposal degrades to random search for this step",
                RuntimeWarning,
                stacklevel=3,
            )
            return False

    def _propose(self, iteration: int) -> Any:
        cands = self._candidates(self.candidate_pool)
        if not cands:
            return self.space.sample_uniform(self.rng, 1)[0]
        Y = np.array([o.objectives for o in self.observations], dtype=np.float64)
        Yn, lo, hi = normalize_objectives(Y)
        X_obs = np.stack([self.space.encode(o.x) for o in self.observations])
        if not self._fit_surrogate(X_obs, Yn, iteration):
            return cands[int(self.rng.integers(len(cands)))]
        X_cand = np.stack([self.space.encode(c) for c in cands])
        post = self.surrogate.posterior_samples(X_cand)  # (T, M, 2)
        front = Yn[pareto_mask(Yn)]
        # alternate EHVI (front-global) with random-scalarization EI
        # (front-local coverage) — HyperMapper-style multi-objective mix
        if iteration % 2 == 0:
            acq = ehvi(post, front)
        else:
            # bathtub-distributed weights: favors the front's extremes
            # (where Fig. 6 shows CATO's edge) while covering the middle
            lam = float(self.rng.beta(0.3, 0.3))
            acq = scalarized_ei(post, Yn, lam)
        if self.priors is not None:
            pl = getattr(self.priors, "pi_log_clipped", self.priors.pi_log)
            lp = np.array([pl(self.space, c) for c in cands])
            acq = apply_pibo(acq, lp, iteration, self.pibo_beta)
        return cands[int(np.argmax(acq))]

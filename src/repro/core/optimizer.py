"""The CATO Optimizer: multi-objective BO over feature representations.

Loop (paper §3.3 + Fig. 3):
  1. preprocessing — MI dimensionality reduction + automatic prior build
     (done by the caller via `build_priors`; pass priors=None for CATO-BASE);
  2. init — `n_init` points sampled from the priors (random but
     prior-weighted, §5.5);
  3. iterate — fit RF surrogate on observations, draw a candidate pool
     (prior samples + uniform samples + mutations of incumbent Pareto
     points), score with MC-EHVI, inject πBO prior weight, evaluate the
     argmax with the *real* Profiler, update observations.

The Profiler is any callable ``profile(x) -> (cost, perf)`` (or a
``ProfileResult``); both objectives are minimized internally as
``(cost, -perf)``.

The optimizer is space-generic: any object implementing the `SearchSpace`
protocol (encode / sample_uniform / sample_from_priors / mutate) works —
`repro.core.tuner` reuses it for LM serving-pipeline configuration search.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

from .acquisition import apply_pibo, ehvi, scalarized_ei
from .pareto import normalize_objectives, pareto_mask
from .priors import CatoPriors
from .search_space import SearchSpace
from .surrogate import RFSurrogate

__all__ = ["Observation", "CatoResult", "CatoOptimizer"]


@dataclasses.dataclass
class Observation:
    x: Any                 # FeatureRep (or tuner config)
    cost: float
    perf: float
    aux: dict = dataclasses.field(default_factory=dict)
    iteration: int = -1
    elapsed_s: float = 0.0

    @property
    def objectives(self) -> tuple[float, float]:
        """(cost, -perf) — both minimized."""
        return (self.cost, -self.perf)


@dataclasses.dataclass
class CatoResult:
    observations: list[Observation]
    space: Any

    def objective_matrix(self) -> np.ndarray:
        return np.array([o.objectives for o in self.observations], dtype=np.float64)

    def pareto_observations(self) -> list[Observation]:
        if not self.observations:
            return []
        Y = self.objective_matrix()
        mask = pareto_mask(Y)
        obs = [o for o, m in zip(self.observations, mask) if m]
        return sorted(obs, key=lambda o: o.cost)

    def pareto_points(self) -> np.ndarray:
        """(k, 2) array of (cost, perf) on the estimated front."""
        return np.array(
            [(o.cost, o.perf) for o in self.pareto_observations()], dtype=np.float64
        )

    def best_by_perf(self) -> Observation:
        return max(self.observations, key=lambda o: o.perf)

    def best_by_cost(self) -> Observation:
        return min(self.observations, key=lambda o: o.cost)


class CatoOptimizer:
    def __init__(
        self,
        space: SearchSpace,
        profiler: Callable[[Any], tuple[float, float] | Any],
        priors: Optional[CatoPriors] = None,
        *,
        n_init: int = 3,
        candidate_pool: int = 512,
        surrogate: Optional[RFSurrogate] = None,
        pibo_beta: float = 3.0,
        seed: int = 0,
    ):
        self.space = space
        self.profiler = profiler
        self.priors = priors
        self.n_init = n_init
        self.candidate_pool = candidate_pool
        self.surrogate = surrogate or RFSurrogate(seed=seed)
        self.pibo_beta = pibo_beta
        self.rng = np.random.default_rng(seed)
        self.observations: list[Observation] = []
        self._seen: set = set()

    # -- evaluation ----------------------------------------------------------
    def _evaluate(self, x: Any, iteration: int) -> Observation:
        t0 = time.perf_counter()
        res = self.profiler(x)
        dt = time.perf_counter() - t0
        if isinstance(res, Observation):
            res.x, res.iteration, res.elapsed_s = x, iteration, dt
            obs = res
        elif hasattr(res, "cost") and hasattr(res, "perf"):
            obs = Observation(
                x, float(res.cost), float(res.perf),
                aux=dict(getattr(res, "aux", {})), iteration=iteration, elapsed_s=dt,
            )
        else:
            cost, perf = res
            obs = Observation(x, float(cost), float(perf), iteration=iteration, elapsed_s=dt)
        self.observations.append(obs)
        self._seen.add(self._key(x))
        return obs

    @staticmethod
    def _key(x: Any):
        return x.key() if hasattr(x, "key") else x

    # -- candidate generation --------------------------------------------------
    def _candidates(self, n: int) -> list[Any]:
        cands: list[Any] = []
        if self.priors is not None and hasattr(self.space, "sample_from_priors"):
            cands += self.space.sample_from_priors(
                self.rng, int(n * 0.6), self.priors.feature_probs, self.priors.depth_pmf
            )
        cands += self.space.sample_uniform(self.rng, n - len(cands))
        # exploit: mutate incumbent Pareto points
        if self.observations:
            Y = np.array([o.objectives for o in self.observations])
            inc = [o.x for o, m in zip(self.observations, pareto_mask(Y)) if m]
            for x in inc:
                for _ in range(4):
                    cands.append(self.space.mutate(self.rng, x))
        # drop already-evaluated
        fresh, seen = [], set()
        for c in cands:
            k = self._key(c)
            if k in self._seen or k in seen:
                continue
            seen.add(k)
            fresh.append(c)
        return fresh

    # -- main loop -------------------------------------------------------------
    def run(self, n_iterations: int = 50, verbose: bool = False) -> CatoResult:
        # initialization: random but prior-weighted (paper §5.5)
        n_init = min(self.n_init, n_iterations)
        if self.priors is not None and hasattr(self.space, "sample_from_priors"):
            init = self.space.sample_from_priors(
                self.rng, n_init, self.priors.feature_probs, self.priors.depth_pmf
            )
        else:
            init = self.space.sample_uniform(self.rng, n_init)
        for i, x in enumerate(init):
            self._evaluate(x, i)

        for it in range(len(self.observations), n_iterations):
            x = self._propose(it)
            obs = self._evaluate(x, it)
            if verbose:
                print(
                    f"[cato] iter {it}: cost={obs.cost:.6g} perf={obs.perf:.4f} x={x}"
                )
        return CatoResult(self.observations, self.space)

    def _propose(self, iteration: int) -> Any:
        cands = self._candidates(self.candidate_pool)
        if not cands:
            return self.space.sample_uniform(self.rng, 1)[0]
        Y = np.array([o.objectives for o in self.observations], dtype=np.float64)
        Yn, lo, hi = normalize_objectives(Y)
        X_obs = np.stack([self.space.encode(o.x) for o in self.observations])
        try:
            self.surrogate.fit(X_obs, Yn)
        except Exception:
            return cands[int(self.rng.integers(len(cands)))]
        X_cand = np.stack([self.space.encode(c) for c in cands])
        post = self.surrogate.posterior_samples(X_cand)  # (T, M, 2)
        front = Yn[pareto_mask(Yn)]
        # alternate EHVI (front-global) with random-scalarization EI
        # (front-local coverage) — HyperMapper-style multi-objective mix
        if iteration % 2 == 0:
            acq = ehvi(post, front)
        else:
            # bathtub-distributed weights: favors the front's extremes
            # (where Fig. 6 shows CATO's edge) while covering the middle
            lam = float(self.rng.beta(0.3, 0.3))
            acq = scalarized_ei(post, Yn, lam)
        if self.priors is not None:
            pl = getattr(self.priors, "pi_log_clipped", self.priors.pi_log)
            lp = np.array([pl(self.space, c) for c in cands])
            acq = apply_pibo(acq, lp, iteration, self.pibo_beta)
        return cands[int(np.argmax(acq))]

"""Pareto-front utilities and the Hypervolume Indicator (2-objective exact).

Convention: ALL objectives are *minimized*. CATO's two objectives are
``(cost(x), -perf(x))`` (paper §3.3). The paper reports HVI against a
worst-case reference point (F1 = 0, normalized cost = 1); we normalize both
objectives to [0, 1] and use ref = (1, 1), reporting the *ratio*
``HV(estimated) / HV(true)`` which matches the paper's 0–1 scale
(e.g. CATO 0.98 vs SIMANNEAL 0.88 in Fig. 6).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "pareto_mask",
    "pareto_front",
    "hypervolume_2d",
    "hvi_ratio",
    "knee_index",
    "normalize_objectives",
]


def pareto_mask(Y: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows of Y (n, m), minimization.

    A point is on the front iff no other point is <= it in every objective
    and < in at least one.
    """
    Y = np.asarray(Y, dtype=np.float64)
    n = Y.shape[0]
    mask = np.ones(n, dtype=bool)
    # O(n^2) vectorized — fine for the n <= few-thousand fronts here.
    for i in range(n):
        if not mask[i]:
            continue
        dominated = np.all(Y <= Y[i], axis=1) & np.any(Y < Y[i], axis=1)
        if dominated.any():
            mask[i] = False
            continue
        # points i dominates can be dropped from future consideration
        kills = np.all(Y[i] <= Y, axis=1) & np.any(Y[i] < Y, axis=1)
        mask &= ~kills
        mask[i] = True
    return mask


def pareto_front(Y: np.ndarray) -> np.ndarray:
    """Return the non-dominated subset of Y, sorted by first objective."""
    P = np.asarray(Y)[pareto_mask(Y)]
    return P[np.argsort(P[:, 0])]


def hypervolume_2d(front: np.ndarray, ref: tuple[float, float] = (1.0, 1.0)) -> float:
    """Exact 2-D hypervolume of a minimization front w.r.t. reference point.

    Points outside the reference box contribute their clipped projection.
    """
    front = np.asarray(front, dtype=np.float64)
    if front.size == 0:
        return 0.0
    front = front[pareto_mask(front)]
    front = front[np.argsort(front[:, 0])]
    rx, ry = float(ref[0]), float(ref[1])
    hv = 0.0
    prev_y = ry
    for x, y in front:
        x = min(x, rx)
        y = min(y, ry)
        if x >= rx or y >= prev_y:
            continue
        hv += (rx - x) * (prev_y - y)
        prev_y = y
    return hv


def knee_index(front: np.ndarray) -> int:
    """Index of the knee of a 2-objective minimization front.

    The knee is the point with the largest perpendicular distance below
    the chord between the front's extremes, after min-max normalization
    (so the pick is scale-invariant). It is the classic
    diminishing-returns operating point: past it, improving one
    objective costs disproportionately in the other — which makes it the
    default point `serve.deploy` pushes into a live runtime. Degenerate
    fronts (fewer than 3 points, or a zero-length chord) fall back to
    the middle point.
    """
    F = np.asarray(front, dtype=np.float64)
    if F.ndim != 2 or F.shape[1] != 2 or len(F) == 0:
        raise ValueError(f"front must be (k, 2), got {F.shape}")
    if len(F) < 3:
        return len(F) // 2
    Fn, _, _ = normalize_objectives(F)
    order = np.argsort(Fn[:, 0], kind="stable")
    Fs = Fn[order]
    a, b = Fs[0], Fs[-1]
    chord = b - a
    norm = float(np.hypot(*chord))
    if norm <= 0.0:
        return int(order[len(order) // 2])
    # signed cross product: positive = below the chord (toward the ideal)
    d = (chord[0] * (a[1] - Fs[:, 1]) - chord[1] * (a[0] - Fs[:, 0])) / norm
    return int(order[int(np.argmax(d))])


def normalize_objectives(
    Y: np.ndarray, lo: np.ndarray | None = None, hi: np.ndarray | None = None
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Min-max normalize objective columns to [0, 1]; returns (Yn, lo, hi)."""
    Y = np.asarray(Y, dtype=np.float64)
    lo = Y.min(axis=0) if lo is None else np.asarray(lo, dtype=np.float64)
    hi = Y.max(axis=0) if hi is None else np.asarray(hi, dtype=np.float64)
    span = np.where(hi > lo, hi - lo, 1.0)
    return (Y - lo) / span, lo, hi


def hvi_ratio(
    est: np.ndarray,
    true: np.ndarray,
    ref: tuple[float, float] = (1.0, 1.0),
    lo: np.ndarray | None = None,
    hi: np.ndarray | None = None,
) -> float:
    """HV(est)/HV(true) after joint normalization by the TRUE front's range.

    This is the Fig. 6 / Fig. 7 metric: 1.0 means the estimated front matches
    the ground-truth front's dominated hypervolume.
    """
    true = np.asarray(true, dtype=np.float64)
    if lo is None or hi is None:
        _, lo, hi = normalize_objectives(true)
    tn, _, _ = normalize_objectives(true, lo, hi)
    en, _, _ = normalize_objectives(np.asarray(est, dtype=np.float64), lo, hi)
    denom = hypervolume_2d(tn, ref)
    if denom <= 0:
        return 0.0
    return float(hypervolume_2d(en, ref) / denom)

"""CATO prior construction (paper §3.3, "Tailoring BO for Traffic Analysis").

Two prior families, both derived automatically (no user knowledge needed):

1. Feature priors — P(f in F | x in Pareto) = (1 - delta) * I(f)/I_max + delta/2,
   with damping coefficient delta (default 0.4, tuned in paper Fig. 9a).
2. Connection-depth prior — a linearly-decaying pmf over [1, N], implemented
   as the paper does with a Beta(alpha=1, beta=2) density discretized over
   the depth range: fewer packets are a priori cheaper.

``pi_value`` evaluates the joint prior density of an encoded representation;
the Optimizer injects it πBO-style by multiplying the acquisition with
``pi(x) ** (beta_pibo / (1 + t))`` so the prior's influence decays over
iterations t (Hvarfner et al., πBO).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .mutual_info import mi_scores
from .search_space import FeatureRep, SearchSpace

__all__ = ["CatoPriors", "build_priors"]


@dataclasses.dataclass
class CatoPriors:
    feature_probs: np.ndarray  # (F,) P(f in Pareto-optimal rep)
    depth_pmf: np.ndarray      # (N - min_depth + 1,) linear decay
    mi: np.ndarray             # raw MI scores (diagnostics / RFE-MI baselines)
    keep_mask: np.ndarray      # dimensionality-reduction mask (MI > 0)

    def pi_log(self, space: SearchSpace, x: FeatureRep) -> float:
        """log prior density of a representation under independent priors."""
        v = space.encode(x)
        m = v[: space.n_features] > 0.5
        p = np.clip(self.feature_probs, 1e-6, 1 - 1e-6)
        lp = float(np.sum(np.where(m, np.log(p), np.log1p(-p))))
        d_idx = int(x.depth - space.min_depth)
        d_idx = min(max(d_idx, 0), len(self.depth_pmf) - 1)
        lp += float(np.log(self.depth_pmf[d_idx] + 1e-12))
        return lp

    def pi_log_clipped(self, space, x, lo: float = -4.0) -> float:
        """Clipped log prior: keeps πBO's suppression of unlikely regions
        bounded so the acquisition can still overrule the prior once the
        surrogate sees real structure (prevents the prior from walling off
        the high-perf / high-depth corner entirely)."""
        return max(self.pi_log(space, x), lo)


def beta12_pmf(n: int) -> np.ndarray:
    """Discretized Beta(1, 2) over n cells: density 2(1-u) — linear decay."""
    # integrate 2(1-u) over each cell [i/n, (i+1)/n]
    edges = np.linspace(0.0, 1.0, n + 1)
    cdf = 2 * edges - edges ** 2  # Beta(1,2) CDF
    pmf = np.diff(cdf)
    return pmf / pmf.sum()


def build_priors(
    space: SearchSpace,
    X_feat: np.ndarray,
    y: np.ndarray,
    delta: float = 0.4,
    mi_bins: int = 16,
    seed: int = 0,
) -> CatoPriors:
    """Derive priors from the training data itself (paper: automatic).

    ``X_feat`` holds one column per candidate feature in ``space`` order,
    computed at the maximum connection depth (cheap, single pass).
    """
    mi = mi_scores(X_feat, y, n_bins=mi_bins, seed=seed)
    keep = mi > 0.0
    i_max = mi.max() if mi.max() > 0 else 1.0
    probs = (1.0 - delta) * (mi / i_max) + delta / 2.0
    # dropped features get ~zero prior (the dimensionality-reduction step)
    probs = np.where(keep, probs, 1e-3)
    n_depth = space.max_depth - space.min_depth + 1
    return CatoPriors(
        feature_probs=probs.astype(np.float64),
        depth_pmf=beta12_pmf(n_depth),
        mi=mi,
        keep_mask=keep,
    )

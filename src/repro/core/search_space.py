"""The CATO search space X = P(F) x N (paper §3.1, Table 1).

A *feature representation* ``x = (F, n)`` is encoded as a flat vector of
``|F| + 1`` floats: binary indicator per candidate feature followed by the
connection depth (integer in [1, N]). This mirrors the paper's BO
formulation (§3.3): "one dimension per feature in F and one for the
connection depth n".
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["FeatureRep", "SearchSpace"]


@dataclasses.dataclass(frozen=True)
class FeatureRep:
    """x = (F, n): selected feature names + connection depth."""

    features: tuple[str, ...]
    depth: int

    def __post_init__(self):
        object.__setattr__(self, "features", tuple(sorted(self.features)))

    def key(self) -> tuple:
        return (self.features, self.depth)


@dataclasses.dataclass
class SearchSpace:
    """Encodes/decodes feature representations and samples them."""

    feature_names: tuple[str, ...]
    max_depth: int  # N — upper bound on connection depth
    min_depth: int = 1

    def __post_init__(self):
        self.feature_names = tuple(self.feature_names)

    @property
    def n_features(self) -> int:
        return len(self.feature_names)

    @property
    def dim(self) -> int:
        return self.n_features + 1

    @property
    def size(self) -> float:
        return float(2 ** self.n_features) * (self.max_depth - self.min_depth + 1)

    # -- encoding ------------------------------------------------------------
    def encode(self, x: FeatureRep) -> np.ndarray:
        v = np.zeros(self.dim, dtype=np.float32)
        name_to_idx = {n: i for i, n in enumerate(self.feature_names)}
        for f in x.features:
            v[name_to_idx[f]] = 1.0
        v[-1] = float(x.depth)
        return v

    def decode(self, v: np.ndarray) -> FeatureRep:
        mask = np.asarray(v[: self.n_features]) > 0.5
        depth = int(np.clip(round(float(v[-1])), self.min_depth, self.max_depth))
        feats = tuple(n for n, m in zip(self.feature_names, mask) if m)
        return FeatureRep(features=feats, depth=depth)

    def encode_batch(self, xs: Sequence[FeatureRep]) -> np.ndarray:
        return np.stack([self.encode(x) for x in xs])

    # -- sampling ------------------------------------------------------------
    def sample_uniform(self, rng: np.random.Generator, n: int) -> list[FeatureRep]:
        out = []
        for _ in range(n):
            mask = rng.random(self.n_features) < 0.5
            if not mask.any():
                mask[rng.integers(self.n_features)] = True
            depth = int(rng.integers(self.min_depth, self.max_depth + 1))
            out.append(
                FeatureRep(
                    tuple(np.array(self.feature_names)[mask].tolist()), depth
                )
            )
        return out

    def sample_from_priors(
        self,
        rng: np.random.Generator,
        n: int,
        feature_probs: np.ndarray,
        depth_pmf: np.ndarray,
    ) -> list[FeatureRep]:
        """Sample reps with per-feature Bernoulli priors + depth pmf."""
        depths = self.min_depth + rng.choice(
            len(depth_pmf), size=n, p=depth_pmf / depth_pmf.sum()
        )
        out = []
        for i in range(n):
            mask = rng.random(self.n_features) < feature_probs
            if not mask.any():
                mask[int(np.argmax(feature_probs))] = True
            out.append(
                FeatureRep(
                    tuple(np.array(self.feature_names)[mask].tolist()),
                    int(depths[i]),
                )
            )
        return out

    def mutate(
        self, rng: np.random.Generator, x: FeatureRep, depth_step: int | None = None
    ) -> FeatureRep:
        """Neighbor move: flip one feature OR perturb depth (equal prob.)."""
        names = list(self.feature_names)
        feats = set(x.features)
        if rng.random() < 0.5 or self.max_depth == self.min_depth:
            f = names[rng.integers(len(names))]
            if f in feats and len(feats) > 1:
                feats.remove(f)
            else:
                feats.add(f)
            return FeatureRep(tuple(feats), x.depth)
        step = depth_step or max(1, (self.max_depth - self.min_depth) // 4)
        d = int(
            np.clip(
                x.depth + rng.integers(-step, step + 1),
                self.min_depth,
                self.max_depth,
            )
        )
        return FeatureRep(tuple(feats), d)

    def enumerate_all(self) -> Iterable[FeatureRep]:
        """Exhaustive iteration — only for ground-truth spaces (paper Fig. 6)."""
        F = self.n_features
        for bits in range(1, 2 ** F):
            feats = tuple(
                self.feature_names[i] for i in range(F) if bits & (1 << i)
            )
            for d in range(self.min_depth, self.max_depth + 1):
                yield FeatureRep(feats, d)

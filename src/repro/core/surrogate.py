"""Random-forest surrogate model for the CATO Optimizer.

The paper (§4) uses HyperMapper's random-forest surrogate, "shown to perform
well compared to more traditional Gaussian processes for highly discontinuous
and non-linear objective functions". One regression forest per objective;
per-tree predictions provide the posterior samples the acquisition function
integrates over (tree t of every objective's forest forms one joint sample,
a cheap quasi-posterior coupling).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .forest import DenseForest, forest_predict_per_tree, train_forest

__all__ = ["RFSurrogate"]


@dataclasses.dataclass
class RFSurrogate:
    n_trees: int = 32
    max_depth: int = 8
    min_samples_leaf: int = 2
    seed: int = 0
    _forests: list[DenseForest] = dataclasses.field(default_factory=list)

    def fit(self, X: np.ndarray, Y: np.ndarray) -> "RFSurrogate":
        """X: (n, d) encoded points; Y: (n, m) objective values (minimize)."""
        X = np.asarray(X, dtype=np.float32)
        Y = np.asarray(Y, dtype=np.float64)
        rng = np.random.default_rng(self.seed)
        self._forests = []
        depth = int(min(self.max_depth, max(2, np.ceil(np.log2(max(2, X.shape[0]))))))
        for j in range(Y.shape[1]):
            f = train_forest(
                X,
                Y[:, j],
                n_trees=self.n_trees,
                max_depth=depth,
                min_samples_leaf=self.min_samples_leaf,
                classification=False,
                bootstrap=True,
                max_features=None,
                rng=rng,
            )
            self._forests.append(f)
        return self

    @staticmethod
    def with_fidelity(X: np.ndarray, levels: np.ndarray) -> np.ndarray:
        """Append a fidelity-level input column (0.0 = cheapest backend,
        1.0 = measured) so one forest pools observations across
        fidelities: low-fidelity points inform the posterior wherever the
        objectives agree, and the level input lets trees split the
        fidelities apart wherever they systematically disagree — cheap
        points inform but never *pollute* measured predictions.
        Candidates are scored with the column pinned to the target
        fidelity (see `CatoOptimizer._propose_batch`)."""
        X = np.asarray(X, dtype=np.float32)
        lv = np.asarray(levels, dtype=np.float32).reshape(len(X), 1)
        return np.concatenate([X, lv], axis=1)

    def posterior_samples(self, X: np.ndarray) -> np.ndarray:
        """(n_trees, n, m) joint posterior draws at X."""
        per_obj = [forest_predict_per_tree(f, X) for f in self._forests]  # m x (T, n)
        return np.stack(per_obj, axis=-1)

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        s = self.posterior_samples(X)
        return s.mean(axis=0), s.std(axis=0)

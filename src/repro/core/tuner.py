"""PipelineTuner: CATO's multi-objective BO applied to LM serving configs.

Beyond-paper integration (DESIGN.md §3): the Optimizer is profiler-agnostic,
so the same BO machinery that searches (feature set × connection depth) for
traffic pipelines searches (serving knobs) for LM pipelines:

    knobs: KV dtype (bf16/int8), attention window (the LM analogue of the
           paper's *connection depth* — how much context the pipeline
           consumes), microbatch count, remat policy, decode batch.

    cost(x) = roofline-model step time for the target cell (same hardware
              constants as §Roofline; or a real dry-run measure_fn when
              compile time is paid);
    perf(x) = quality proxy: fraction of full-quality attention/precision
              retained (window and int8-KV discount it).

`ConfigSpace` implements the SearchSpace protocol (encode / sample_uniform /
mutate), so `CatoOptimizer(space=ConfigSpace(...), profiler=...)` runs
unchanged — including the RF surrogate and EHVI acquisition. Priors are
optional (a `ConfigPriors` with pi_log) mirroring the Beta-depth prior:
smaller windows are a priori cheaper.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .optimizer import CatoOptimizer, CatoResult

__all__ = ["ServingConfig", "ConfigSpace", "ConfigPriors", "PipelineTuner"]

_KV_DTYPES = ("bf16", "int8")
_REMAT = ("none", "block")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    kv_dtype: str = "bf16"
    window: int = 32768         # attention window (context consumed)
    microbatches: int = 1
    remat: str = "block"
    decode_batch: int = 128

    def key(self):
        return dataclasses.astuple(self)


@dataclasses.dataclass
class ConfigSpace:
    max_window: int = 32768
    min_window: int = 1024
    batches: tuple = (32, 64, 128, 256)
    microbatch_opts: tuple = (1, 2, 4, 8)

    @property
    def dim(self) -> int:
        return 5

    def encode(self, x: ServingConfig) -> np.ndarray:
        return np.array([
            _KV_DTYPES.index(x.kv_dtype),
            math.log2(x.window),
            math.log2(x.microbatches),
            _REMAT.index(x.remat),
            math.log2(x.decode_batch),
        ], dtype=np.float32)

    def sample_uniform(self, rng: np.random.Generator, n: int):
        out = []
        for _ in range(n):
            w = 2 ** int(rng.integers(
                int(math.log2(self.min_window)), int(math.log2(self.max_window)) + 1
            ))
            out.append(ServingConfig(
                kv_dtype=_KV_DTYPES[rng.integers(len(_KV_DTYPES))],
                window=w,
                microbatches=int(rng.choice(self.microbatch_opts)),
                remat=_REMAT[rng.integers(len(_REMAT))],
                decode_batch=int(rng.choice(self.batches)),
            ))
        return out

    def mutate(self, rng: np.random.Generator, x: ServingConfig,
               depth_step: int | None = None) -> ServingConfig:
        f = rng.integers(5)
        kw = dataclasses.asdict(x)
        if f == 0:
            kw["kv_dtype"] = _KV_DTYPES[rng.integers(len(_KV_DTYPES))]
        elif f == 1:
            w = kw["window"] * (2 if rng.random() < 0.5 else 0.5)
            kw["window"] = int(np.clip(w, self.min_window, self.max_window))
        elif f == 2:
            kw["microbatches"] = int(rng.choice(self.microbatch_opts))
        elif f == 3:
            kw["remat"] = _REMAT[rng.integers(len(_REMAT))]
        else:
            kw["decode_batch"] = int(rng.choice(self.batches))
        return ServingConfig(**kw)


@dataclasses.dataclass
class ConfigPriors:
    """Smaller windows a priori cheaper (Beta(1,2) over log-window),
    uniform elsewhere — the LM analogue of the paper's depth prior."""

    space: ConfigSpace

    def pi_log(self, space, x: ServingConfig) -> float:
        lo = math.log2(self.space.min_window)
        hi = math.log2(self.space.max_window)
        u = (math.log2(x.window) - lo) / max(hi - lo, 1e-9)
        return float(np.log(max(2 * (1 - u), 1e-3)))


class PipelineTuner:
    """cost(x): analytic roofline step-time for a serving cell;
    perf(x): retained-quality proxy. Swap `profile` for a dry-run-backed
    measure to pay compile time for exactness (the §Perf hillclimb path)."""

    PEAK, HBM, LINK = 197e12, 819e9, 50e9

    def __init__(self, cfg, chips: int = 256, profile=None):
        self.cfg = cfg
        self.chips = chips
        self._external = profile

    def profile(self, x: ServingConfig):
        if self._external is not None:
            return self._external(x)
        c = self.cfg
        kvb = 2 if x.kv_dtype == "bf16" else 1
        L, H, hd, d = c.n_layers, c.n_kv_heads, c.hd, c.d_model
        # decode step: stream params once per token + read KV window
        param_bytes = c.active_params * 2 / self.chips
        kv_bytes = L * x.decode_batch * min(x.window, c.max_seq) * H * hd * 2 \
            * kvb / self.chips
        t_mem = (param_bytes + kv_bytes) / self.HBM
        flops = 2 * c.active_params * x.decode_batch / self.chips
        t_comp = flops / self.PEAK
        # TP all-reduces per layer (2) on (batch, d) activations
        coll = 2 * L * x.decode_batch * d * 2 * 2 / self.chips
        t_coll = coll / self.LINK
        step = max(t_mem, t_comp, t_coll) * (1 + 0.1 * (x.microbatches - 1))
        # cost per *generated token*: batching amortizes weight streaming
        # until the KV reads dominate — the real decode tradeoff
        cost = step / x.decode_batch
        # quality proxy: window truncation + int8 KV discount, normalized to
        # the search space's full window
        max_w = 32768
        q_window = min(1.0, 0.35 + 0.65 * math.log2(max(x.window, 2))
                       / math.log2(max_w)) / 1.0
        q_window = min(1.0, q_window / (0.35 + 0.65))
        q_kv = 1.0 if x.kv_dtype == "bf16" else 0.985
        q_remat = 1.0  # decode-path remat is quality-neutral
        perf = q_window * q_kv * q_remat
        return cost * 1e6, perf  # (us per generated token, quality in [0,1])

    def tune(self, n_iterations: int = 40, seed: int = 0,
             use_priors: bool = True) -> CatoResult:
        space = ConfigSpace(max_window=min(32768, self.cfg.max_seq))
        priors = ConfigPriors(space) if use_priors else None
        opt = CatoOptimizer(space, self.profile, priors, seed=seed)
        return opt.run(n_iterations)

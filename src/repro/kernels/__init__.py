"""Pallas TPU kernels for the pipeline's compute hot-spots.

Kernels (each `<name>.py` is a `pl.pallas_call` + explicit BlockSpec tiling;
`ops.py` holds the jit'd wrappers; `ref.py` the pure-jnp oracles):

  flash_attention  fused online-softmax attention, GQA, causal block skip
  decode_attention single-token GQA decode over a dense KV cache
  tree_infer       dense level-order random-forest inference (model stage)
  feature_extract  masked segmented flow statistics (extraction stage)
  fused_pipeline   single-launch extract+infer over flow tiles (serving)
  mamba_scan       chunked SSD selective scan (SSM/hybrid archs, long ctx)
"""
from . import ops, ref

__all__ = ["ops", "ref"]

"""Single-token GQA decode attention over a paged-dense KV cache (Pallas).

One new query token per sequence attends to a (B, S, Hkv, D) cache with
per-sequence valid lengths. The grid walks (batch, kv_head, kv_block); the
g = Hq/Hkv query heads of a group are processed together as a (g, D) tile —
they share the same cache block, so the cache is streamed HBM→VMEM exactly
once per group (the GQA bandwidth win; decode is memory-bound, see
EXPERIMENTS.md §Roofline).

Lengths arrive as a (B, 1) int32 array; blocks past a sequence's length are
masked (and contribute nothing to the online softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_kernel_call"]

_NEG_INF = -1e30


def _dec_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *, scale: float, bs: int,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]

    @pl.when(ik * bs < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (g, D)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, D)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (g, bs)
        kpos = ik * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        lse = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(lse > 0, lse, 1.0)).astype(o_ref.dtype)


def decode_attention_kernel_call(
    q: jax.Array,        # (B, Hq, D)
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) int32
    *,
    scale: float | None = None,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)

    q4 = q.reshape(B, Hkv, g, D)
    lengths2 = lengths.reshape(B, 1).astype(jnp.int32)
    grid = (B, Hkv, S // bs)
    kern = functools.partial(_dec_kernel, scale=scale, bs=bs)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, g, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths2, q4, k_cache, v_cache)
    return out.reshape(B, Hq, D)

"""Segmented flow-statistics Pallas kernel — the feature-extraction hot path.

The paper's per-packet Rust accumulator loop becomes, on TPU, one pass of
masked reductions over a dense (flows × packets) tile resident in VMEM
(DESIGN.md §3): count / sum / sum-of-squares / min / max per flow in a
single kernel, from which mean, std and load are derived for free at
extract() time — the kernel-level expression of the paper's shared-operation
argument (one traversal serves every accumulator family).

Grid tiles the flow axis; each step reduces a (bn, P) tile to (bn, 5).
Arbitrary flow counts are handled by padding the flow axis up to the block
multiple (padding rows carry an all-zero mask, so they reduce to zeros) and
slicing the result — no block-divisibility precondition on callers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flow_stats_kernel_call"]

_BIG = 3.4e38


def _stats_kernel(v_ref, m_ref, o_ref):
    v = v_ref[...]                       # (bn, P) float32
    m = m_ref[...] != 0                  # (bn, P) bool
    mf = m.astype(jnp.float32)
    cnt = mf.sum(axis=1)
    s = (v * mf).sum(axis=1)
    sq = (v * v * mf).sum(axis=1)
    mn = jnp.min(jnp.where(m, v, _BIG), axis=1)
    mx = jnp.max(jnp.where(m, v, -_BIG), axis=1)
    has = cnt > 0
    mn = jnp.where(has, mn, 0.0)
    mx = jnp.where(has, mx, 0.0)
    o_ref[...] = jnp.stack([cnt, s, sq, mn, mx], axis=1)


def flow_stats_kernel_call(
    values: jax.Array,  # (N, P) float32
    mask: jax.Array,    # (N, P) bool/int
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    N, P = values.shape
    bn = min(block_n, N)
    values = values.astype(jnp.float32)
    mask = mask.astype(jnp.int32)
    rem = (-N) % bn
    if rem:
        # pad the flow axis to the block multiple: padded rows carry an
        # all-zero mask, so every statistic reduces to 0 and is sliced off
        values = jnp.pad(values, ((0, rem), (0, 0)))
        mask = jnp.pad(mask, ((0, rem), (0, 0)))
    out = pl.pallas_call(
        _stats_kernel,
        grid=((N + rem) // bn,),
        in_specs=[
            pl.BlockSpec((bn, P), lambda i: (i, 0)),
            pl.BlockSpec((bn, P), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 5), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N + rem, 5), jnp.float32),
        interpret=interpret,
    )(values, mask)
    return out[:N]

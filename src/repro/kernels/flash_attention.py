"""FlashAttention-style fused attention Pallas kernel (TPU target).

Online-softmax attention with GQA head grouping, tiled for VMEM:
the grid walks (batch, q_head, q_block, kv_block) with the kv axis
innermost; running max / denominator / accumulator live in VMEM scratch.
Block sizes default to MXU-aligned (128) multiples. Causal blocks that are
entirely masked are skipped (`pl.when`), so the causal prefill does half
the work — on hardware this is the difference between 2·T²·D and T²·D
useful FLOPs.

Validated against `ref.flash_attention_ref` in interpret mode (CPU);
the TPU lowering is exercised by the dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, bq: int, bk: int, tq: int, tk: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal offset: query global position iq*bq + r maps to key limit
    # (tk - tq) + global q position (supports tq != tk for chunked prefill)
    offset = tk - tq

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (bq, bk)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos + offset, s, _NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # skip blocks that lie entirely above the causal diagonal
        first_q = iq * bq
        first_k = ik * bk
        pl.when(first_k <= first_q + offset + (bq - 1))(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finalize():
        lse = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(lse > 0, lse, 1.0)).astype(o_ref.dtype)


def flash_attention_kernel_call(
    q: jax.Array,  # (B, Hq, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, bq, Tk, bk)

    grid = (B, Hq, Tq // bq, Tk // bk)
    kern = functools.partial(
        _fa_kernel, scale=scale, causal=causal, bq=bq, bk=bk, tq=Tq, tk=Tk
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

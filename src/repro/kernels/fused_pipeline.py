"""Fused extract+infer Pallas kernel — the single-launch serving hot path.

The unfused pipeline runs two device launches per micro-batch: the XLA
extraction executable materializes the ``(N, F)`` feature matrix in HBM,
then the `tree_infer` Pallas kernel reads it back. This kernel fuses both
stages (DESIGN.md §7): the grid tiles the flow axis, each step loads one
``(bn, P)`` tile of every packet tensor into VMEM, computes the selected
feature columns *in registers* via the shared emitter
(`repro.traffic.extraction.emit_feature_columns`, specialized on the static
stats plan — the paper's conditional compilation, now inside Pallas), and
immediately runs the dense level-order forest traversal on the in-register
feature tile. The feature matrix never touches HBM.

Bit-parity with the unfused path is by construction, not luck:

- feature columns come from the *same* emitter tracing the *same* static
  plan, so the op graphs are identical;
- the traversal unrolls tree blocks of `block_t` and accumulates
  ``votes.sum(axis=1) / n_trees_padded`` per block in the same order as the
  `tree_infer` kernel's grid reduction, with the same pass-through tree
  padding and the same post-hoc vote-mean rescale.

`fused_forest_infer` is the jit'd public entry; the packet tensors are
donated (``donate_argnums``) so XLA can reuse their device buffers across
micro-batches — together with the dispatcher's staging arenas this makes a
flush allocation-free on the host and reuse-friendly on the device.

Swap-safety (DESIGN.md §9.3): the jit cache keys on the static
``(plan, depth, forest_depth, batch shape)`` tuple, so two pipeline
configurations can serve *concurrently* — during a zero-downtime
hot-swap the background-warmed replacement (`ServingPipeline.warm`)
and the still-serving old pipeline never evict or alias each other's
executables, and donation stays per-call (each configuration's arenas
rotate independently).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_forest_infer", "fused_pipeline_call"]


def _fused_kernel(
    ts_ref, size_ref, dir_ref, ttl_ref, win_ref, flags_ref, meta_ref,
    f_ref, t_ref, l_ref, o_ref,
    *, plan, depth: int, forest_depth: int, n_trees: int, block_t: int,
    rescale: float,
):
    from repro.traffic.extraction import emit_feature_columns

    ts = ts_ref[...]            # (bn, P) float32
    meta = meta_ref[...]        # (bn, 4) float32: flow_len, proto, s/d_port
    cols = emit_feature_columns(
        plan,
        ts=ts, size=size_ref[...], direction=dir_ref[...], ttl=ttl_ref[...],
        winsize=win_ref[...], flags=flags_ref[...], flow_len=meta[:, 0],
        proto=meta[:, 1], s_port=meta[:, 2], d_port=meta[:, 3], depth=depth,
    )
    x = jnp.stack(cols, axis=1)                 # (bn, F) — in VMEM only

    feat = f_ref[...]                           # (T, NI)
    thr = t_ref[...]
    leaf = l_ref[...]                           # (T, NL, K)
    bn = x.shape[0]
    K = leaf.shape[2]

    acc = jnp.zeros((bn, K), jnp.float32)
    for j0 in range(0, n_trees, block_t):
        fj = feat[j0:j0 + block_t]              # static slices: (bt, NI)
        tj = thr[j0:j0 + block_t]
        lj = leaf[j0:j0 + block_t]
        bt = fj.shape[0]
        node = jnp.zeros((bn, bt), jnp.int32)
        for _ in range(forest_depth):
            f = jnp.take_along_axis(
                jnp.broadcast_to(fj[None], (bn, bt, fj.shape[1])),
                node[:, :, None], axis=2,
            )[..., 0]
            th = jnp.take_along_axis(
                jnp.broadcast_to(tj[None], (bn, bt, tj.shape[1])),
                node[:, :, None], axis=2,
            )[..., 0]
            xv = jnp.take_along_axis(
                jnp.broadcast_to(x[:, None, :], (bn, bt, x.shape[1])),
                f.astype(jnp.int32)[:, :, None], axis=2,
            )[..., 0]
            node = 2 * node + 1 + (xv > th).astype(jnp.int32)
        leaf_idx = node - (2 ** forest_depth - 1)
        votes = jnp.take_along_axis(
            jnp.broadcast_to(lj[None], (bn,) + lj.shape),
            leaf_idx[:, :, None, None], axis=2,
        )[:, :, 0, :]                           # (bn, bt, K)
        acc = acc + votes.sum(axis=1) / n_trees
    o_ref[...] = acc * rescale


def fused_pipeline_call(
    ts, size, direction, ttl, winsize, flags, meta,
    feature, threshold, leaf,
    *, plan, depth: int, forest_depth: int,
    block_n: int = 256, block_t: int = 8, interpret: bool = False,
):
    """Raw pallas_call: one launch over flow tiles, features never hit HBM.

    Expects float32 packet tensors, int32 `direction`, float32 `flags`
    ``(N, P, 8)``, and ``meta = [flow_len, proto, s_port, d_port]`` as
    ``(N, 4)`` float32. Pads the flow axis to the block multiple (padding
    rows have flow_len 0: every mask is empty) and the tree axis with
    pass-through trees, mirroring `ops.forest_infer` exactly.
    """
    N, P = ts.shape
    T, NI = feature.shape
    NL, K = leaf.shape[1], leaf.shape[2]
    bn = min(block_n, N)
    bt = min(block_t, T)

    rem_n = (-N) % bn
    if rem_n:
        def pad2(a):
            return jnp.pad(a, ((0, rem_n), (0, 0)))

        ts, size, direction, ttl, winsize, meta = map(
            pad2, (ts, size, direction, ttl, winsize, meta))
        flags = jnp.pad(flags, ((0, rem_n), (0, 0), (0, 0)))
    # same pass-through padding + rescale recipe as the unfused tree kernel
    # (shared helper: the bit-parity contract depends on it)
    from .tree_infer import pad_forest_blocks

    feature, threshold, leaf, rem_t = pad_forest_blocks(
        feature, threshold, leaf, bt)
    rescale = (T + rem_t) / T if rem_t else 1.0

    kern = functools.partial(
        _fused_kernel, plan=plan, depth=depth, forest_depth=forest_depth,
        n_trees=T + rem_t, block_t=bt, rescale=rescale,
    )
    def tile(i):
        return (i, 0)

    def whole(i):
        return (0, 0)

    out = pl.pallas_call(
        kern,
        grid=((N + rem_n) // bn,),
        in_specs=[
            pl.BlockSpec((bn, P), tile),            # ts
            pl.BlockSpec((bn, P), tile),            # size
            pl.BlockSpec((bn, P), tile),            # direction
            pl.BlockSpec((bn, P), tile),            # ttl
            pl.BlockSpec((bn, P), tile),            # winsize
            pl.BlockSpec((bn, P, 8), lambda i: (i, 0, 0)),  # flags
            pl.BlockSpec((bn, 4), tile),            # meta
            pl.BlockSpec((T + rem_t, NI), whole),   # forest: resident
            pl.BlockSpec((T + rem_t, NI), whole),
            pl.BlockSpec((T + rem_t, NL, K), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, K), tile),
        out_shape=jax.ShapeDtypeStruct((N + rem_n, K), jnp.float32),
        interpret=interpret,
    )(ts, size, direction, ttl, winsize, flags, meta, feature, threshold, leaf)
    return out[:N]


@functools.partial(
    jax.jit,
    static_argnames=("plan", "depth", "forest_depth", "block_n", "block_t",
                     "interpret"),
    donate_argnums=(0, 1, 2, 3, 4, 5),
)
def fused_forest_infer(
    ts, size, direction, ttl, winsize, flags,
    flow_len, proto, s_port, d_port,
    feature, threshold, leaf,
    *, plan, depth: int, forest_depth: int,
    block_n: int = 256, block_t: int = 8, interpret: bool | None = None,
):
    """Jit'd fused pipeline entry: packets -> class probabilities, one launch.

    The packet tensors (args 0-5) are donated: each micro-batch's device
    buffers are released back to XLA as soon as the launch consumes them,
    so steady-state serving reuses a fixed set of device allocations.
    Accepts uint8 `direction`/`flags` (converted on device, keeping the
    host staging arena copy-free); `plan` comes from
    `repro.traffic.extraction.stats_plan`.
    """
    if interpret is None:
        from .ops import default_interpret
        interpret = default_interpret()
    meta = jnp.stack(
        [flow_len.astype(jnp.float32), proto, s_port, d_port], axis=1)
    return fused_pipeline_call(
        ts, size, direction.astype(jnp.float32), ttl, winsize,
        flags.astype(jnp.float32), meta, feature, threshold, leaf,
        plan=plan, depth=depth, forest_depth=forest_depth,
        block_n=block_n, block_t=block_t, interpret=interpret,
    )

"""Fused extract+infer Pallas kernel — the single-launch serving hot path.

The unfused pipeline runs two device launches per micro-batch: the XLA
extraction executable materializes the ``(N, F)`` feature matrix in HBM,
then the `tree_infer` Pallas kernel reads it back. This kernel fuses both
stages (DESIGN.md §7): the grid tiles the flow axis, each step loads one
``(bn, P)`` tile of every packet tensor into VMEM, computes the selected
feature columns *in registers* via the shared emitter
(`repro.traffic.extraction.emit_feature_columns`, specialized on the static
stats plan — the paper's conditional compilation, now inside Pallas), and
immediately runs the dense level-order forest traversal on the in-register
feature tile. The feature matrix never touches HBM.

Bit-parity with the unfused path is by construction, not luck:

- feature columns come from the *same* emitter tracing the *same* static
  plan, so the op graphs are identical;
- the traversal unrolls tree blocks of `block_t` and accumulates
  ``votes.sum(axis=1) / n_trees_padded`` per block in the same order as the
  `tree_infer` kernel's grid reduction, with the same pass-through tree
  padding and the same post-hoc vote-mean rescale.

`fused_forest_infer` is the jit'd public entry; the packet tensors are
donated (``donate_argnums``) so XLA can reuse their device buffers across
micro-batches — together with the dispatcher's staging arenas this makes a
flush allocation-free on the host and reuse-friendly on the device.

Swap-safety (DESIGN.md §9.3): the jit cache keys on the static
``(plan, depth, forest_depth, batch shape)`` tuple, so two pipeline
configurations can serve *concurrently* — during a zero-downtime
hot-swap the background-warmed replacement (`ServingPipeline.warm`)
and the still-serving old pipeline never evict or alias each other's
executables, and donation stays per-call (each configuration's arenas
rotate independently).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_agg_infer", "fused_forest_infer", "fused_pipeline_call",
           "fused_multi_forest_infer", "fused_multi_forest_call",
           "stack_multi_forests"]


def _traverse(x, feat, thr, leaf, *, forest_depth: int, n_trees: int,
              block_t: int, rescale: float):
    """Dense level-order forest traversal over an in-register feature tile.

    Shared by the window kernel and the aggregate kernel: bit-parity
    between the two entries (and with `ops.forest_infer`) rests on both
    tracing this exact block order, vote normalization, and rescale."""
    bn = x.shape[0]
    K = leaf.shape[2]
    acc = jnp.zeros((bn, K), jnp.float32)
    for j0 in range(0, n_trees, block_t):
        fj = feat[j0:j0 + block_t]              # static slices: (bt, NI)
        tj = thr[j0:j0 + block_t]
        lj = leaf[j0:j0 + block_t]
        bt = fj.shape[0]
        node = jnp.zeros((bn, bt), jnp.int32)
        for _ in range(forest_depth):
            f = jnp.take_along_axis(
                jnp.broadcast_to(fj[None], (bn, bt, fj.shape[1])),
                node[:, :, None], axis=2,
            )[..., 0]
            th = jnp.take_along_axis(
                jnp.broadcast_to(tj[None], (bn, bt, tj.shape[1])),
                node[:, :, None], axis=2,
            )[..., 0]
            xv = jnp.take_along_axis(
                jnp.broadcast_to(x[:, None, :], (bn, bt, x.shape[1])),
                f.astype(jnp.int32)[:, :, None], axis=2,
            )[..., 0]
            node = 2 * node + 1 + (xv > th).astype(jnp.int32)
        leaf_idx = node - (2 ** forest_depth - 1)
        votes = jnp.take_along_axis(
            jnp.broadcast_to(lj[None], (bn,) + lj.shape),
            leaf_idx[:, :, None, None], axis=2,
        )[:, :, 0, :]                           # (bn, bt, K)
        acc = acc + votes.sum(axis=1) / n_trees
    return acc * rescale


def _fused_kernel(
    ts_ref, size_ref, dir_ref, ttl_ref, win_ref, flags_ref, meta_ref,
    f_ref, t_ref, l_ref, o_ref,
    *, plan, depth: int, forest_depth: int, n_trees: int, block_t: int,
    rescale: float,
):
    from repro.traffic.extraction import emit_feature_columns

    ts = ts_ref[...]            # (bn, P) float32
    meta = meta_ref[...]        # (bn, 4) float32: flow_len, proto, s/d_port
    cols = emit_feature_columns(
        plan,
        ts=ts, size=size_ref[...], direction=dir_ref[...], ttl=ttl_ref[...],
        winsize=win_ref[...], flags=flags_ref[...], flow_len=meta[:, 0],
        proto=meta[:, 1], s_port=meta[:, 2], d_port=meta[:, 3], depth=depth,
    )
    x = jnp.stack(cols, axis=1)                 # (bn, F) — in VMEM only
    o_ref[...] = _traverse(
        x, f_ref[...], t_ref[...], l_ref[...],
        forest_depth=forest_depth, n_trees=n_trees, block_t=block_t,
        rescale=rescale,
    )


def _agg_kernel(
    agg_ref, meta_ref, f_ref, t_ref, l_ref, o_ref,
    *, plan, forest_depth: int, n_trees: int, block_t: int, rescale: float,
):
    """Incremental entry (DESIGN.md §12): feature columns from the compact
    per-flow aggregate block instead of the raw packet window — a
    ``(bn, AGG_WIDTH)`` tile replaces six ``(bn, P[, 8])`` packet tensors,
    so a refresh batch moves ~53 floats per flow regardless of how long
    the flow has lived."""
    from repro.traffic.extraction import emit_agg_features

    agg = agg_ref[...]          # (bn, AGG_WIDTH) float32
    meta = meta_ref[...]        # (bn, 3) float32: proto, s_port, d_port
    cols = emit_agg_features(
        plan, agg, proto=meta[:, 0], s_port=meta[:, 1], d_port=meta[:, 2])
    x = jnp.stack(cols, axis=1)
    o_ref[...] = _traverse(
        x, f_ref[...], t_ref[...], l_ref[...],
        forest_depth=forest_depth, n_trees=n_trees, block_t=block_t,
        rescale=rescale,
    )


def fused_pipeline_call(
    ts, size, direction, ttl, winsize, flags, meta,
    feature, threshold, leaf,
    *, plan, depth: int, forest_depth: int,
    block_n: int = 256, block_t: int = 8, interpret: bool = False,
):
    """Raw pallas_call: one launch over flow tiles, features never hit HBM.

    Expects float32 packet tensors, int32 `direction`, float32 `flags`
    ``(N, P, 8)``, and ``meta = [flow_len, proto, s_port, d_port]`` as
    ``(N, 4)`` float32. Pads the flow axis to the block multiple (padding
    rows have flow_len 0: every mask is empty) and the tree axis with
    pass-through trees, mirroring `ops.forest_infer` exactly.
    """
    N, P = ts.shape
    T, NI = feature.shape
    NL, K = leaf.shape[1], leaf.shape[2]
    bn = min(block_n, N)
    bt = min(block_t, T)

    rem_n = (-N) % bn
    if rem_n:
        def pad2(a):
            return jnp.pad(a, ((0, rem_n), (0, 0)))

        ts, size, direction, ttl, winsize, meta = map(
            pad2, (ts, size, direction, ttl, winsize, meta))
        flags = jnp.pad(flags, ((0, rem_n), (0, 0), (0, 0)))
    # same pass-through padding + rescale recipe as the unfused tree kernel
    # (shared helper: the bit-parity contract depends on it)
    from .tree_infer import pad_forest_blocks

    feature, threshold, leaf, rem_t = pad_forest_blocks(
        feature, threshold, leaf, bt)
    rescale = (T + rem_t) / T if rem_t else 1.0

    kern = functools.partial(
        _fused_kernel, plan=plan, depth=depth, forest_depth=forest_depth,
        n_trees=T + rem_t, block_t=bt, rescale=rescale,
    )
    def tile(i):
        return (i, 0)

    def whole(i):
        return (0, 0)

    out = pl.pallas_call(
        kern,
        grid=((N + rem_n) // bn,),
        in_specs=[
            pl.BlockSpec((bn, P), tile),            # ts
            pl.BlockSpec((bn, P), tile),            # size
            pl.BlockSpec((bn, P), tile),            # direction
            pl.BlockSpec((bn, P), tile),            # ttl
            pl.BlockSpec((bn, P), tile),            # winsize
            pl.BlockSpec((bn, P, 8), lambda i: (i, 0, 0)),  # flags
            pl.BlockSpec((bn, 4), tile),            # meta
            pl.BlockSpec((T + rem_t, NI), whole),   # forest: resident
            pl.BlockSpec((T + rem_t, NI), whole),
            pl.BlockSpec((T + rem_t, NL, K), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, K), tile),
        out_shape=jax.ShapeDtypeStruct((N + rem_n, K), jnp.float32),
        interpret=interpret,
    )(ts, size, direction, ttl, winsize, flags, meta, feature, threshold, leaf)
    return out[:N]


@functools.partial(
    jax.jit,
    static_argnames=("plan", "depth", "forest_depth", "block_n", "block_t",
                     "interpret"),
    donate_argnums=(0, 1, 2, 3, 4, 5),
)
def fused_forest_infer(
    ts, size, direction, ttl, winsize, flags,
    flow_len, proto, s_port, d_port,
    feature, threshold, leaf,
    *, plan, depth: int, forest_depth: int,
    block_n: int = 256, block_t: int = 8, interpret: bool | None = None,
):
    """Jit'd fused pipeline entry: packets -> class probabilities, one launch.

    The packet tensors (args 0-5) are donated: each micro-batch's device
    buffers are released back to XLA as soon as the launch consumes them,
    so steady-state serving reuses a fixed set of device allocations.
    Accepts uint8 `direction`/`flags` (converted on device, keeping the
    host staging arena copy-free); `plan` comes from
    `repro.traffic.extraction.stats_plan`.
    """
    if interpret is None:
        from .ops import default_interpret
        interpret = default_interpret()
    meta = jnp.stack(
        [flow_len.astype(jnp.float32), proto, s_port, d_port], axis=1)
    return fused_pipeline_call(
        ts, size, direction.astype(jnp.float32), ttl, winsize,
        flags.astype(jnp.float32), meta, feature, threshold, leaf,
        plan=plan, depth=depth, forest_depth=forest_depth,
        block_n=block_n, block_t=block_t, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# multi-tenant fused kernel (DESIGN.md §15)
# ---------------------------------------------------------------------------
# One Pallas launch serves every tenant of a fleet: the merged plan's
# feature columns are computed once over the in-VMEM packet tile, then each
# tenant's forest — stacked along the tree axis with a static offset, its
# node feature ids pre-remapped into merged-column space — traverses the
# same feature tile via the exact solo `_traverse`, emitting its own
# prediction lanes into a per-tenant slice of the output. Bit-parity with N
# solo launches holds tenant by tenant: the merged emitter slices the
# window to each tenant's depth before reducing, the remapped gather reads
# the same feature values, and the per-tenant block order / vote
# normalization / rescale are the solo recipe verbatim.


def stack_multi_forests(forests, tenant_cols, *, block_t: int = 8):
    """Stack N tenants' forests into tenant-stacked node arrays.

    Each forest is padded with pass-through trees to its own solo block
    multiple (`pad_forest_blocks` — same recipe, same rescale, so the
    per-tenant accumulation order matches a solo launch bit for bit),
    its node feature ids are remapped through `tenant_cols[t]` into
    merged-column space, and node/leaf/class axes are zero-padded to the
    fleet maxima (statically sliced off inside the kernel). Returns
    ``(feature, threshold, leaf, tenants)`` where ``tenants`` is the
    static per-tenant spec tuple
    ``(offset, n_padded, forest_depth, block_t, n_internal, n_leaf,
    n_out, rescale)`` that the kernel specializes on.
    """
    from .tree_infer import pad_forest_blocks

    ni_max = max(int(f.feature.shape[1]) for f in forests)
    nl_max = max(int(f.leaf.shape[1]) for f in forests)
    k_max = max(int(f.leaf.shape[2]) for f in forests)
    feats, thrs, leafs, tenants = [], [], [], []
    off = 0
    for f, cols in zip(forests, tenant_cols):
        T, ni = f.feature.shape
        nl, k = f.leaf.shape[1], f.leaf.shape[2]
        bt = min(block_t, int(T))
        remap = jnp.asarray(cols, jnp.int32)[jnp.asarray(f.feature, jnp.int32)]
        feat, thr, leaf, rem_t = pad_forest_blocks(
            remap, jnp.asarray(f.threshold), jnp.asarray(f.leaf), bt)
        tp = int(T) + rem_t
        feats.append(jnp.pad(feat, ((0, 0), (0, ni_max - ni))))
        thrs.append(jnp.pad(thr, ((0, 0), (0, ni_max - ni))))
        leafs.append(jnp.pad(
            leaf, ((0, 0), (0, nl_max - nl), (0, k_max - k))))
        tenants.append((off, tp, int(f.depth), bt, int(ni), int(nl), int(k),
                        (tp / T) if rem_t else 1.0))
        off += tp
    return (jnp.concatenate(feats, axis=0), jnp.concatenate(thrs, axis=0),
            jnp.concatenate(leafs, axis=0), tuple(tenants))


def _multi_kernel(
    ts_ref, size_ref, dir_ref, ttl_ref, win_ref, flags_ref, meta_ref,
    f_ref, t_ref, l_ref, o_ref,
    *, merged, tenants,
):
    from repro.traffic.extraction import emit_merged_columns

    ts = ts_ref[...]            # (bn, P) float32
    meta = meta_ref[...]        # (bn, 4) float32: flow_len, proto, s/d_port
    cols = emit_merged_columns(
        merged,
        ts=ts, size=size_ref[...], direction=dir_ref[...], ttl=ttl_ref[...],
        winsize=win_ref[...], flags=flags_ref[...], flow_len=meta[:, 0],
        proto=meta[:, 1], s_port=meta[:, 2], d_port=meta[:, 3],
    )
    x = jnp.stack(cols, axis=1)                 # (bn, F_union) — VMEM only
    k0 = 0
    for off, tp, fd, bt, ni, nl, k, rescale in tenants:
        o_ref[:, k0:k0 + k] = _traverse(
            x, f_ref[off:off + tp, :ni], t_ref[off:off + tp, :ni],
            l_ref[off:off + tp, :nl, :k],
            forest_depth=fd, n_trees=tp, block_t=bt, rescale=rescale,
        )
        k0 += k


def fused_multi_forest_call(
    ts, size, direction, ttl, winsize, flags, meta,
    feature, threshold, leaf,
    *, merged, tenants,
    block_n: int = 256, interpret: bool = False,
):
    """Raw pallas_call: one launch, N tenants' prediction lanes.

    `feature`/`threshold`/`leaf` are the tenant-stacked arrays from
    `stack_multi_forests` (already tree-padded and remapped — no further
    padding here); the output is ``(N, sum of per-tenant n_out)`` with
    tenant t's probabilities in its contiguous lane slice. Flow-axis
    padding matches `fused_pipeline_call` (zero rows: every mask empty).
    """
    N, P = ts.shape
    TP, NI = feature.shape
    NL, K = leaf.shape[1], leaf.shape[2]
    k_sum = sum(t[6] for t in tenants)
    bn = min(block_n, N)

    rem_n = (-N) % bn
    if rem_n:
        def pad2(a):
            return jnp.pad(a, ((0, rem_n), (0, 0)))

        ts, size, direction, ttl, winsize, meta = map(
            pad2, (ts, size, direction, ttl, winsize, meta))
        flags = jnp.pad(flags, ((0, rem_n), (0, 0), (0, 0)))

    kern = functools.partial(_multi_kernel, merged=merged, tenants=tenants)

    def tile(i):
        return (i, 0)

    def whole(i):
        return (0, 0)

    out = pl.pallas_call(
        kern,
        grid=((N + rem_n) // bn,),
        in_specs=[
            pl.BlockSpec((bn, P), tile),            # ts
            pl.BlockSpec((bn, P), tile),            # size
            pl.BlockSpec((bn, P), tile),            # direction
            pl.BlockSpec((bn, P), tile),            # ttl
            pl.BlockSpec((bn, P), tile),            # winsize
            pl.BlockSpec((bn, P, 8), lambda i: (i, 0, 0)),  # flags
            pl.BlockSpec((bn, 4), tile),            # meta
            pl.BlockSpec((TP, NI), whole),          # stacked forest: resident
            pl.BlockSpec((TP, NI), whole),
            pl.BlockSpec((TP, NL, K), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, k_sum), tile),
        out_shape=jax.ShapeDtypeStruct((N + rem_n, k_sum), jnp.float32),
        interpret=interpret,
    )(ts, size, direction, ttl, winsize, flags, meta, feature, threshold, leaf)
    return out[:N]


@functools.partial(
    jax.jit,
    static_argnames=("merged", "tenants", "block_n", "interpret"),
    donate_argnums=(0, 1, 2, 3, 4, 5),
)
def fused_multi_forest_infer(
    ts, size, direction, ttl, winsize, flags,
    flow_len, proto, s_port, d_port,
    feature, threshold, leaf,
    *, merged, tenants,
    block_n: int = 256, interpret: bool | None = None,
):
    """Jit'd multi-tenant fused entry: packets -> stacked per-tenant
    probability lanes, one launch. Donation and dtype conventions match
    `fused_forest_infer`; the jit cache keys on the static
    ``(merged, tenants, batch shape)`` tuple, so a multi-tenant bundle
    hot-swap coexists with whatever it replaces (DESIGN.md §9.3)."""
    if interpret is None:
        from .ops import default_interpret
        interpret = default_interpret()
    meta = jnp.stack(
        [flow_len.astype(jnp.float32), proto, s_port, d_port], axis=1)
    return fused_multi_forest_call(
        ts, size, direction.astype(jnp.float32), ttl, winsize,
        flags.astype(jnp.float32), meta, feature, threshold, leaf,
        merged=merged, tenants=tenants, block_n=block_n, interpret=interpret,
    )


def fused_agg_call(
    agg, meta, feature, threshold, leaf,
    *, plan, forest_depth: int,
    block_n: int = 256, block_t: int = 8, interpret: bool = False,
):
    """Raw pallas_call for the aggregate entry: one launch over flow tiles
    of the compact ``(N, AGG_WIDTH)`` running-statistic block. Pads the
    flow axis with all-zero rows (a zero aggregate has every count at 0,
    so the emitter's masked reductions yield a defined all-zero feature
    row) and the tree axis with pass-through trees, exactly as the window
    entry does."""
    N, W = agg.shape
    T, NI = feature.shape
    NL, K = leaf.shape[1], leaf.shape[2]
    bn = min(block_n, N)
    bt = min(block_t, T)

    rem_n = (-N) % bn
    if rem_n:
        agg = jnp.pad(agg, ((0, rem_n), (0, 0)))
        meta = jnp.pad(meta, ((0, rem_n), (0, 0)))
    from .tree_infer import pad_forest_blocks

    feature, threshold, leaf, rem_t = pad_forest_blocks(
        feature, threshold, leaf, bt)
    rescale = (T + rem_t) / T if rem_t else 1.0

    kern = functools.partial(
        _agg_kernel, plan=plan, forest_depth=forest_depth,
        n_trees=T + rem_t, block_t=bt, rescale=rescale,
    )

    def tile(i):
        return (i, 0)

    def whole(i):
        return (0, 0)

    out = pl.pallas_call(
        kern,
        grid=((N + rem_n) // bn,),
        in_specs=[
            pl.BlockSpec((bn, W), tile),            # aggregate block
            pl.BlockSpec((bn, 3), tile),            # proto, s_port, d_port
            pl.BlockSpec((T + rem_t, NI), whole),   # forest: resident
            pl.BlockSpec((T + rem_t, NI), whole),
            pl.BlockSpec((T + rem_t, NL, K), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, K), tile),
        out_shape=jax.ShapeDtypeStruct((N + rem_n, K), jnp.float32),
        interpret=interpret,
    )(agg, meta, feature, threshold, leaf)
    return out[:N]


@functools.partial(
    jax.jit,
    static_argnames=("plan", "forest_depth", "block_n", "block_t",
                     "interpret"),
)
def fused_agg_infer(
    agg, proto, s_port, d_port,
    feature, threshold, leaf,
    *, plan, forest_depth: int,
    block_n: int = 256, block_t: int = 8, interpret: bool | None = None,
):
    """Jit'd incremental pipeline entry: aggregate rows -> class
    probabilities, one launch. The refresh path is low-rate (one batch per
    `refresh_every` packets of frozen traffic), so inputs are not donated:
    the host-side staging block is reused synchronously by the dispatcher.
    """
    if interpret is None:
        from .ops import default_interpret
        interpret = default_interpret()
    meta = jnp.stack([proto, s_port, d_port], axis=1)
    return fused_agg_call(
        agg.astype(jnp.float32), meta, feature, threshold, leaf,
        plan=plan, forest_depth=forest_depth,
        block_n=block_n, block_t=block_t, interpret=interpret,
    )

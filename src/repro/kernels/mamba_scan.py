"""Chunked Mamba-2 / SSD selective-scan Pallas kernel.

The GPU Mamba kernel is a warp-level sequential scan; the TPU-native form
(DESIGN.md §3) is the *chunked SSD decomposition*, which converts the
recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t x_t ⊗ B_t,     y_t = C_t · h_t

into MXU matmuls per chunk of length c. With L_t = Σ_{τ<=t} dt_τ A the
cumulative log-decay inside a chunk:

    intra:  Y  += (tril(exp(L_t - L_τ)) ∘ (C Bᵀ)) @ (dt ∘ x)      (c×c matmul)
    inter:  Y  += exp(L_t) ∘ (C @ h₀ᵀ)                            (c×S matmul)
    carry:  h' = exp(L_c) h₀ + ((dt ∘ x) ∘ exp(L_c - L_t))ᵀ @ B   (P×c @ c×S)

The grid walks (batch, head, chunk) with the chunk axis innermost and the
(P, S) state carried in VMEM scratch — the sequential dependency is one
scalar-decay chain per chunk rather than per step, so arithmetic intensity
is MXU-bound instead of latency-bound. This is the long_500k serving path
for the SSM/hybrid architectures (zamba2, xlstm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba_scan_kernel_call"]


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[0]                                   # scalar decay rate (this head)
    x = x_ref[0, :, 0].astype(jnp.float32)         # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (c,)
    Bm = b_ref[0].astype(jnp.float32)              # (c, S)
    Cm = c_ref[0].astype(jnp.float32)              # (c, S)

    L = jnp.cumsum(dt * A)                         # (c,) cumulative log decay
    # intra-chunk: G[t, tau] = exp(L_t - L_tau) for tau <= t, else 0
    Lt = L[:, None]
    Ltau = L[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    G = jnp.where(tril, jnp.exp(Lt - Ltau), 0.0)   # (c, c)
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (c, c)
    dx = dt[:, None] * x                            # (c, P)
    y_intra = jax.lax.dot_general(
        G * CB, dx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (c, P)

    # inter-chunk: contribution of carried state h0 (P, S)
    h0 = h_ref[...]
    Ch = jax.lax.dot_general(
        Cm, h0, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (c, P)
    y = y_intra + jnp.exp(L)[:, None] * Ch

    # carry state to next chunk
    w = jnp.exp(L[-1] - L)[:, None] * dx            # (c, P)
    h_new = jnp.exp(L[-1]) * h0 + jax.lax.dot_general(
        w, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (P, S)
    h_ref[...] = h_new
    y_ref[0, :, 0] = y.astype(y_ref.dtype)


def mamba_scan_kernel_call(
    x: jax.Array,   # (B, T, H, P)
    dt: jax.Array,  # (B, T, H)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, T, S)
    Cm: jax.Array,  # (B, T, S)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, T, H, P = x.shape
    S = Bm.shape[-1]
    c = min(chunk, T)
    assert T % c == 0, (T, c)

    kern = functools.partial(_ssd_kernel, chunk=c)
    return pl.pallas_call(
        kern,
        grid=(B, H, T // c),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i: (h,)),
            pl.BlockSpec((1, c, 1, P), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, c, 1), lambda b, h, i: (b, i, h)),
            pl.BlockSpec((1, c, S), lambda b, h, i: (b, i, 0)),
            pl.BlockSpec((1, c, S), lambda b, h, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, P), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, S), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt, Bm, Cm)

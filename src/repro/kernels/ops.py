"""Public jit'd wrappers for the Pallas kernels.

Each wrapper pads inputs to block multiples, dispatches the kernel, and
slices the result. `interpret` defaults to auto: Pallas interpret mode on
CPU (this container), compiled Mosaic on real TPUs. Pure-jnp fallbacks
(`use_kernel=False`) route to the ref implementations — the dry-run can
lower either path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_kernel_call
from .feature_extract import flow_stats_kernel_call
from .flash_attention import flash_attention_kernel_call
from .mamba_scan import mamba_scan_kernel_call
from .tree_infer import forest_infer_kernel_call

__all__ = [
    "default_interpret",
    "flash_attention",
    "decode_attention",
    "forest_infer",
    "flow_stats",
    "mamba_scan",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x, n
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value), n


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret")
)
def flash_attention(
    q, k, v, *, causal=True, scale=None, block_q=128, block_k=128, interpret=None
):
    interpret = default_interpret() if interpret is None else interpret
    Tq, Tk = q.shape[2], k.shape[2]
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    if Tq % bq or Tk % bk:
        # pad sequence dims; padded keys are masked out by causality only if
        # they sit past the end — safest to pad both to block multiples and
        # mask via an explicit causal offset, so restrict padding to q here
        q_p, tq0 = _pad_to(q, 2, bq)
        out = flash_attention_kernel_call(
            q_p, k, v, causal=causal, scale=scale,
            block_q=bq, block_k=bk, interpret=interpret,
        )
        return out[:, :, :tq0]
    return flash_attention_kernel_call(
        q, k, v, causal=causal, scale=scale,
        block_q=bq, block_k=bk, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, scale=None, block_s=256,
                     interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    S = k_cache.shape[1]
    bs = min(block_s, S)
    k_p, _ = _pad_to(k_cache, 1, bs)
    v_p, _ = _pad_to(v_cache, 1, bs)
    # padded cache positions are masked by `lengths`
    return decode_attention_kernel_call(
        q, k_p, v_p, lengths, scale=scale, block_s=bs, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("depth", "block_n", "block_t", "interpret"))
def forest_infer(x, feature, threshold, leaf, depth, *, block_n=256, block_t=8,
                 interpret=None):
    # flow/tree padding, pass-through trees, and the vote-mean rescale all
    # live in the kernel call (shared with the fused pipeline via
    # tree_infer.pad_forest_blocks — the bit-parity contract)
    interpret = default_interpret() if interpret is None else interpret
    return forest_infer_kernel_call(
        x, feature, threshold, leaf, depth,
        block_n=block_n, block_t=block_t, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def flow_stats(values, mask, *, block_n=512, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    return flow_stats_kernel_call(
        values, mask, block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    T = x.shape[1]
    c = min(chunk, T)
    if T % c:
        x, t0 = _pad_to(x, 1, c)
        dt, _ = _pad_to(dt, 1, c)
        Bm, _ = _pad_to(Bm, 1, c)
        Cm, _ = _pad_to(Cm, 1, c)
        out = mamba_scan_kernel_call(x, dt, A, Bm, Cm, chunk=c, interpret=interpret)
        return out[:, :t0]
    return mamba_scan_kernel_call(x, dt, A, Bm, Cm, chunk=c, interpret=interpret)

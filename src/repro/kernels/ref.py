"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "flash_attention_ref",
    "decode_attention_ref",
    "forest_infer_ref",
    "flow_stats_ref",
    "mamba_scan_ref",
]


def flash_attention_ref(
    q: jax.Array,  # (B, Hq, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Reference multi-head attention with GQA head grouping."""
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    logits = logits * scale
    if causal:
        Tk = k.shape[2]
        mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,        # (B, Hq, D) — one new token per sequence
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) valid cache lengths
    *,
    scale: float | None = None,
) -> jax.Array:
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, g, D).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, kf) * scale
    mask = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None, :]
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


def forest_infer_ref(
    x: jax.Array,         # (N, F) float32 feature matrix
    feature: jax.Array,   # (T, 2**depth - 1) int32
    threshold: jax.Array, # (T, 2**depth - 1) float32 (+inf = pass-through)
    leaf: jax.Array,      # (T, 2**depth, K) float32
    depth: int,
) -> jax.Array:
    """Mean leaf payload over trees, (N, K). Matches forest_apply_np."""
    N = x.shape[0]
    T = feature.shape[0]
    node = jnp.zeros((N, T), dtype=jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(feature[None, :, :], node[:, :, None], axis=2)[..., 0]
        th = jnp.take_along_axis(threshold[None, :, :], node[:, :, None], axis=2)[..., 0]
        xv = jnp.take_along_axis(x[:, None, :], f[:, :, None].astype(jnp.int32), axis=2)[..., 0]
        node = 2 * node + 1 + (xv > th).astype(jnp.int32)
    leaf_idx = node - (2 ** depth - 1)
    gathered = jnp.take_along_axis(
        leaf[None], leaf_idx[:, :, None, None], axis=2
    )[:, :, 0, :]  # (N, T, K)
    return gathered.mean(axis=1)


def flow_stats_ref(values: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked per-flow stats over packets: (N, 5) = count, sum, sumsq, min, max."""
    m = mask.astype(jnp.float32)
    cnt = m.sum(axis=1)
    s = (values * m).sum(axis=1)
    sq = (values * values * m).sum(axis=1)
    big = jnp.float32(3.4e38)
    mn = jnp.where(cnt > 0, jnp.min(jnp.where(mask, values, big), axis=1), 0.0)
    mx = jnp.where(cnt > 0, jnp.max(jnp.where(mask, values, -big), axis=1), 0.0)
    return jnp.stack([cnt, s, sq, mn, mx], axis=1)


def mamba_scan_ref(
    x: jax.Array,   # (B, T, H, P)  inputs
    dt: jax.Array,  # (B, T, H)     softplus'd step sizes (>0)
    A: jax.Array,   # (H,)          negative decay rates
    Bm: jax.Array,  # (B, T, S)     input projections (state dim S)
    Cm: jax.Array,  # (B, T, S)     output projections
) -> jax.Array:
    """Sequential SSD/Mamba-2 recurrence oracle.

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (B_t ⊗ x_t);   y_t = C_t · h_t
    State h has shape (H, P, S) per sequence.
    """
    Bsz, T, H, P = x.shape
    S = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * A)[:, None, None]              # (H,1,1)
        upd = (dtt[:, None] * xt)[:, :, None] * bt[None, None, :]  # (H,P,S)
        h = decay * h + upd
        y = (h * ct[None, None, :]).sum(-1)                  # (H,P)
        return h, y

    def per_seq(xb, dtb, bb, cb):
        h0 = jnp.zeros((H, P, S), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xb, dtb, bb, cb))
        return ys

    return jax.vmap(per_seq)(
        x.astype(jnp.float32), dt.astype(jnp.float32),
        Bm.astype(jnp.float32), Cm.astype(jnp.float32),
    ).astype(x.dtype)

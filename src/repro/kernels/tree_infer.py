"""Random-forest inference Pallas kernel — the model stage of the pipeline.

TPU adaptation of the paper's SmartCore/Rust tree inference (DESIGN.md §3):
a TPU has no pointer chasing, so trees live in the *dense complete
level-order layout* produced by `repro.core.forest` and traversal is pure
index arithmetic, unrolled over the (static) depth:

    node <- 2*node + 1 + (x[feat[node]] > thresh[node])

The grid tiles (flow_block × tree_block); each step keeps a (bn, F) tile of
flows and a tree block's node/leaf tables in VMEM, updates a (bn, bt) vector
of node cursors per level with VREG gathers, and accumulates class votes
into the output tile across tree blocks (the output block index only
depends on the flow axis, so Pallas keeps it resident while the tree axis
iterates — a reduction without HBM round-trips).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["forest_infer_kernel_call", "pad_forest_blocks"]


def pad_forest_blocks(feature, threshold, leaf, block_t: int):
    """Pad the tree axis to a `block_t` multiple with pass-through trees.

    Padding trees have +inf thresholds (every comparison goes left) and
    all-zero leaves, so they contribute nothing to the vote sum; callers
    divide by the padded count and rescale by ``(T + rem) / T`` afterwards.
    The single source of this recipe: `forest_infer_kernel_call` and the
    fused pipeline kernel must pad identically or their bit-parity breaks.
    Returns ``(feature, threshold, leaf, rem_t)``.
    """
    T = feature.shape[0]
    rem_t = (-T) % block_t
    if rem_t:
        feature = jnp.pad(feature, ((0, rem_t), (0, 0)))
        threshold = jnp.pad(threshold, ((0, rem_t), (0, 0)),
                            constant_values=jnp.inf)
        leaf = jnp.pad(leaf, ((0, rem_t), (0, 0), (0, 0)))
    return feature, threshold, leaf, rem_t


def _tree_kernel(x_ref, f_ref, t_ref, l_ref, o_ref, *, depth: int, n_trees: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                      # (bn, F)
    feat = f_ref[...]                   # (bt, NI)
    thr = t_ref[...]                    # (bt, NI)
    leaf = l_ref[...]                   # (bt, NL, K)
    bn = x.shape[0]
    bt = feat.shape[0]

    node = jnp.zeros((bn, bt), jnp.int32)
    for _ in range(depth):
        # gather per (flow, tree): feature id + threshold at current node
        f = jnp.take_along_axis(
            jnp.broadcast_to(feat[None], (bn, bt, feat.shape[1])),
            node[:, :, None], axis=2,
        )[..., 0]
        th = jnp.take_along_axis(
            jnp.broadcast_to(thr[None], (bn, bt, thr.shape[1])),
            node[:, :, None], axis=2,
        )[..., 0]
        xv = jnp.take_along_axis(
            jnp.broadcast_to(x[:, None, :], (bn, bt, x.shape[1])),
            f.astype(jnp.int32)[:, :, None], axis=2,
        )[..., 0]
        node = 2 * node + 1 + (xv > th).astype(jnp.int32)

    leaf_idx = node - (2 ** depth - 1)                     # (bn, bt)
    votes = jnp.take_along_axis(
        jnp.broadcast_to(leaf[None], (bn,) + leaf.shape),
        leaf_idx[:, :, None, None], axis=2,
    )[:, :, 0, :]                                           # (bn, bt, K)
    o_ref[...] += votes.sum(axis=1) / n_trees


def forest_infer_kernel_call(
    x: jax.Array,         # (N, F) float32
    feature: jax.Array,   # (T, NI) int32
    threshold: jax.Array, # (T, NI) float32
    leaf: jax.Array,      # (T, NL, K) float32
    depth: int,
    *,
    block_n: int = 256,
    block_t: int = 8,
    interpret: bool = False,
) -> jax.Array:
    N, F = x.shape
    T, NI = feature.shape
    NL, K = leaf.shape[1], leaf.shape[2]
    bn = min(block_n, N)
    bt = min(block_t, T)
    # pad both grid axes up to their block multiples so arbitrary batch and
    # forest sizes work (and the path has no asserts to lose under -O):
    # padded flows are zero rows whose output is sliced off; padded trees
    # are pass-through (+inf threshold, zero leaves) and the vote mean is
    # rescaled back to the true tree count afterwards.
    rem_n = (-N) % bn
    if rem_n:
        x = jnp.pad(x, ((0, rem_n), (0, 0)))
    feature, threshold, leaf, rem_t = pad_forest_blocks(
        feature, threshold, leaf, bt)

    kern = functools.partial(_tree_kernel, depth=depth, n_trees=T + rem_t)
    out = pl.pallas_call(
        kern,
        grid=((N + rem_n) // bn, (T + rem_t) // bt),
        in_specs=[
            pl.BlockSpec((bn, F), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, NI), lambda i, j: (j, 0)),
            pl.BlockSpec((bt, NI), lambda i, j: (j, 0)),
            pl.BlockSpec((bt, NL, K), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, K), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N + rem_n, K), jnp.float32),
        interpret=interpret,
    )(x, feature, threshold, leaf)
    if rem_t:
        # the kernel averaged over the padded tree count; restore true mean
        out = out * ((T + rem_t) / T)
    return out[:N]

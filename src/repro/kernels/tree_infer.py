"""Random-forest inference Pallas kernel — the model stage of the pipeline.

TPU adaptation of the paper's SmartCore/Rust tree inference (DESIGN.md §3):
a TPU has no pointer chasing, so trees live in the *dense complete
level-order layout* produced by `repro.core.forest` and traversal is pure
index arithmetic, unrolled over the (static) depth:

    node <- 2*node + 1 + (x[feat[node]] > thresh[node])

The grid tiles (flow_block × tree_block); each step keeps a (bn, F) tile of
flows and a tree block's node/leaf tables in VMEM, updates a (bn, bt) vector
of node cursors per level with VREG gathers, and accumulates class votes
into the output tile across tree blocks (the output block index only
depends on the flow axis, so Pallas keeps it resident while the tree axis
iterates — a reduction without HBM round-trips).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["forest_infer_kernel_call"]


def _tree_kernel(x_ref, f_ref, t_ref, l_ref, o_ref, *, depth: int, n_trees: int):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                      # (bn, F)
    feat = f_ref[...]                   # (bt, NI)
    thr = t_ref[...]                    # (bt, NI)
    leaf = l_ref[...]                   # (bt, NL, K)
    bn = x.shape[0]
    bt = feat.shape[0]

    node = jnp.zeros((bn, bt), jnp.int32)
    for _ in range(depth):
        # gather per (flow, tree): feature id + threshold at current node
        f = jnp.take_along_axis(
            jnp.broadcast_to(feat[None], (bn, bt, feat.shape[1])),
            node[:, :, None], axis=2,
        )[..., 0]
        th = jnp.take_along_axis(
            jnp.broadcast_to(thr[None], (bn, bt, thr.shape[1])),
            node[:, :, None], axis=2,
        )[..., 0]
        xv = jnp.take_along_axis(
            jnp.broadcast_to(x[:, None, :], (bn, bt, x.shape[1])),
            f.astype(jnp.int32)[:, :, None], axis=2,
        )[..., 0]
        node = 2 * node + 1 + (xv > th).astype(jnp.int32)

    leaf_idx = node - (2 ** depth - 1)                     # (bn, bt)
    votes = jnp.take_along_axis(
        jnp.broadcast_to(leaf[None], (bn,) + leaf.shape),
        leaf_idx[:, :, None, None], axis=2,
    )[:, :, 0, :]                                           # (bn, bt, K)
    o_ref[...] += votes.sum(axis=1) / n_trees


def forest_infer_kernel_call(
    x: jax.Array,         # (N, F) float32
    feature: jax.Array,   # (T, NI) int32
    threshold: jax.Array, # (T, NI) float32
    leaf: jax.Array,      # (T, NL, K) float32
    depth: int,
    *,
    block_n: int = 256,
    block_t: int = 8,
    interpret: bool = False,
) -> jax.Array:
    N, F = x.shape
    T, NI = feature.shape
    NL, K = leaf.shape[1], leaf.shape[2]
    bn = min(block_n, N)
    bt = min(block_t, T)
    assert N % bn == 0 and T % bt == 0, (N, bn, T, bt)

    kern = functools.partial(_tree_kernel, depth=depth, n_trees=T)
    return pl.pallas_call(
        kern,
        grid=(N // bn, T // bt),
        in_specs=[
            pl.BlockSpec((bn, F), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, NI), lambda i, j: (j, 0)),
            pl.BlockSpec((bt, NI), lambda i, j: (j, 0)),
            pl.BlockSpec((bt, NL, K), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, K), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, K), jnp.float32),
        interpret=interpret,
    )(x, feature, threshold, leaf)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 pods × 256 chips. For every cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(*abstract)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Cost accounting: the production artifact scans over layers, and XLA's
cost_analysis does not multiply while-bodies by trip count. So in addition
to the real (scanned) compile — which provides memory_analysis and the
sharding proof — we compile two *probe* variants with 1 and 2 layer-units
and every scan unrolled (`cfg.probe`), and extrapolate

    total ≈ f(1) + (units - 1) · (f(2) - f(1))

for FLOPs, bytes and per-collective bytes. Per-collective bytes come from
the post-SPMD HLO text (all-reduce counted 2×, ring-(n-1)/n factors applied
by the roofline benchmark). Results are cached as JSON under results/dryrun/.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--tag base] [--force]
"""
import argparse
import dataclasses
import json
import pathlib
import re
import time
import traceback

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2,
}

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")


def _tensor_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo: str) -> dict:
    """Per-collective payload bytes (per device) from post-SPMD HLO.

    all-reduce counts 2× (reduce-scatter + all-gather phases). Numbers are
    payload-sized; the roofline term applies ring (n-1)/n scaling.
    """
    out = {k: 0 for k in COLL_KINDS}
    out["count"] = 0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        b = _tensor_bytes(shapes)
        mult = 2.0 if kind == "all-reduce" else 1.0
        out[kind] += int(b * mult)
        out["count"] += 1
    return out


# ---------------------------------------------------------------------------
# layer-unit probes
# ---------------------------------------------------------------------------

def layer_units(cfg) -> float:
    if cfg.family == "ssm":
        return cfg.n_layers / 2          # pairs
    if cfg.family == "hybrid":
        return cfg.n_layers / cfg.shared_attn_every
    return float(cfg.n_layers)           # audio: enc+dec shrink together


def probe_cfg(cfg, n_units: int):
    common = dict(probe=True, attn_chunk=0, remat=cfg.remat)
    if cfg.family == "audio":
        return dataclasses.replace(
            cfg, n_layers=n_units, encoder_layers=n_units, **common
        )
    if cfg.family == "ssm":
        return dataclasses.replace(cfg, n_layers=2 * n_units, **common)
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, n_layers=n_units * cfg.shared_attn_every, **common
        )
    return dataclasses.replace(cfg, n_layers=n_units, **common)


def _measure(cfg, shape, mesh, microbatches, zero1):
    from repro.launch.specs import build_cell

    cell = build_cell(cfg, shape, mesh, microbatches=microbatches, zero1=zero1)
    t0 = time.time()
    lowered = cell.fn.lower(*cell.abstract)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    mem = compiled.memory_analysis()
    from repro.launch.hlo_stats import hlo_stats

    st = hlo_stats(hlo)
    return {
        "mode": cell.mode,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(st["flops"]),
        "bytes_accessed": float(st["bytes"]),
        "bytes_hbm": float(st.get("bytes_hbm", st["bytes"])),
        "n_dots": int(st["n_dots"]),
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "collectives": {k: float(v) for k, v in st["collectives"].items()},
        "memory": {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
        "hlo_lines": hlo.count("\n"),
    }


def _extrapolate(f1: dict, f2: dict, units: float) -> dict:
    def ext(a, b):
        return a + (units - 1.0) * (b - a)

    out = {
        "flops": ext(f1["flops"], f2["flops"]),
        "bytes_accessed": ext(f1["bytes_accessed"], f2["bytes_accessed"]),
        "bytes_hbm": ext(f1.get("bytes_hbm", f1["bytes_accessed"]),
                         f2.get("bytes_hbm", f2["bytes_accessed"])),
        "transcendentals": ext(f1["transcendentals"], f2["transcendentals"]),
        "collectives": {
            k: ext(f1["collectives"][k], f2["collectives"][k])
            for k in COLL_KINDS
        },
        "units": units,
    }
    out["collectives"]["count"] = ext(
        f1["collectives"]["count"], f2["collectives"]["count"]
    )
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, tag: str = "base",
             microbatches: int = 1, zero1: bool = True, force: bool = False,
             probes: bool = True, overrides: dict | None = None):
    from repro import configs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import skip_reason
    from repro.models.config import SHAPES

    RESULTS.mkdir(parents=True, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "pod"
    out_path = RESULTS / f"{arch}__{shape_name}__{mesh_tag}__{tag}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("status") in ("ok", "skipped"):
            print(f"[dryrun] cached: {out_path.name}")
            return rec

    cfg = configs.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag, "tag": tag,
        "overrides": overrides or {},
        "microbatches": microbatches, "zero1": zero1, "family": cfg.family,
        "params_total": cfg.total_params, "params_active": cfg.active_params,
    }
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", skip_reason=reason)
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] SKIP {arch} {shape_name} ({mesh_tag}): {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["n_devices"] = int(np.prod(list(mesh.shape.values())))
    try:
        main = _measure(cfg, shape, mesh, microbatches, zero1)
        rec.update(status="ok", main=main, mode=main["mode"])
        print(
            f"[dryrun] OK {arch} {shape_name} ({mesh_tag},{tag}) "
            f"mode={main['mode']} compile={main['compile_s']:.0f}s "
            f"coll_ops={main['collectives']['count']}"
        )
        if probes and not multi_pod:
            u = layer_units(cfg)
            f1 = _measure(probe_cfg(cfg, 1), shape, mesh, microbatches, zero1)
            f2 = _measure(probe_cfg(cfg, 2), shape, mesh, microbatches, zero1)
            rec["probe1"], rec["probe2"] = f1, f2
            rec["extrapolated"] = _extrapolate(f1, f2, u)
            print(
                f"[dryrun]    probes: flops/dev={rec['extrapolated']['flops']:.3g} "
                f"coll(AR/AG/RS/A2A)="
                + "/".join(
                    f"{rec['extrapolated']['collectives'][k]/1e9:.2f}G"
                    for k in ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all")
                )
            )
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {arch} {shape_name} ({mesh_tag}): "
              f"{type(e).__name__}: {str(e)[:300]}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="base")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--residual", default=None, choices=("tp", "replicated"))
    ap.add_argument("--remat", default=None, choices=("none", "block", "dots"))
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--pad-heads", type=int, default=None)
    args = ap.parse_args()
    overrides = {}
    if args.residual:
        overrides["residual"] = args.residual
    if args.remat:
        overrides["remat"] = args.remat
    if args.attn_chunk is not None:
        overrides["attn_chunk"] = args.attn_chunk
    if args.pad_heads is not None:
        overrides["n_heads_padded"] = args.pad_heads

    from repro import configs
    from repro.models.config import SHAPES

    archs = [args.arch] if args.arch else list(configs.all_arch_ids())
    shapes = [args.shape] if args.shape else list(SHAPES)
    n_fail = 0
    for a in archs:
        for s in shapes:
            rec = run_cell(
                a, s, args.multi_pod, tag=args.tag,
                microbatches=args.microbatches, zero1=not args.no_zero1,
                force=args.force, probes=not args.no_probes,
                overrides=overrides or None,
            )
            n_fail += rec.get("status") == "error"
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""HLO text analyzer: exact dot FLOPs, byte traffic, collective payloads.

XLA's `compiled.cost_analysis()` proved unreliable on large multi-computation
SPMD modules in this environment (it undercounts dots that sit in non-entry
computations), so the dry-run derives its §Roofline terms from the
post-optimization HLO text directly:

  * dot FLOPs: 2 × |out| × (contracted extent), operand shapes resolved from
    the defining instruction — exact for every `dot` in every computation.
  * convolution FLOPs: 2 × |out| × (kernel spatial × input features / groups).
  * byte traffic: Σ over instructions of (operand bytes + output bytes) for
    compute/fusion/copy ops — a proxy for HBM traffic under the "fusions keep
    internals in VREGs" model.
  * collective payloads: per-kind byte totals (all-reduce 2×).

Computations reached through `while` bodies are multiplied by the loop trip
count when XLA annotates it; the dry-run's probe variants unroll every scan
so probes have no whiles at all.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["hlo_stats"]

_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "c64": 8, "c128": 16,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],]+(?:\{[^}]*\})?))\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_DIMS_RE = {
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "lhs_b": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
}

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")


def _parse_shape(text):
    """First dtype[dims] in text -> (dtype, [dims]); tuples -> list of both."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d]
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _BYTES[dt]
    return total


def _nelems(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def hlo_stats(hlo: str) -> dict:
    """Analyze post-optimization HLO text. Returns flops/bytes/collectives."""
    # pass 1: computation membership + instruction shapes
    shape_of: dict[str, list] = {}
    comp_of: dict[str, str] = {}
    instrs = []  # (comp, name, op, shapes, line)
    comp = "entry"
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if s.startswith("ENTRY ") or (s.startswith("%") and s.endswith("{")):
            comp = s.split(" ")[0].lstrip("%")
            continue
        m = _DEF_RE.match(line)
        if not m or "=" not in line:
            continue
        name, rest = m.group(1), m.group(2)
        om = _OP_RE.match(rest)
        if not om:
            continue
        shapes_txt, op = om.group(1), om.group(2)
        shapes = _parse_shape(shapes_txt)
        shape_of[name] = shapes
        comp_of[name] = comp
        instrs.append((comp, name, op, shapes, rest))

    # pass 2: computation multipliers.
    #  - while bodies inherit caller multiplier × trip count (transitive —
    #    nested scans multiply), caller resolved through the call graph;
    #  - fusion/reduce sub-computations ("calls="/"to_apply=") are costed at
    #    their call site: bytes/collectives inside them are skipped, dots
    #    inside them count with the caller's multiplier.
    while_edges = []   # (caller_comp, body_comp, trip)
    fused_comps: dict[str, str] = {}  # comp -> caller comp
    for comp, name, op, shapes, rest in instrs:
        if op == "while":
            body = re.search(r"body=%?([\w.\-]+)", rest)
            tc = re.search(r"trip_count[^0-9]*([0-9]+)", rest)
            trip = float(tc.group(1)) if tc else 1.0
            if body:
                while_edges.append((comp, body.group(1), trip))
        for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", rest):
            fused_comps.setdefault(m.group(1), comp)

    mult_of: dict[str, float] = defaultdict(lambda: 1.0)
    # fixed-point over nested while chains (depth is small)
    for _ in range(6):
        changed = False
        for caller, body, t in while_edges:
            want = mult_of[caller] * t
            if mult_of[body] != want:
                mult_of[body] = want
                changed = True
        for comp, caller in fused_comps.items():
            want = mult_of[caller]
            if comp not in while_edges and mult_of[comp] != want and \
                    comp not in [b for _, b, _ in while_edges]:
                mult_of[comp] = want
                changed = True
        if not changed:
            break

    def trip(comp_name: str) -> float:
        return mult_of[comp_name]

    flops = 0.0
    bytes_traffic = 0.0
    bytes_hbm = 0.0
    coll = {k: 0.0 for k in COLL_KINDS}
    coll["count"] = 0
    n_dots = 0

    for comp, name, op, shapes, rest in instrs:
        mult = trip(comp)
        if not shapes:
            continue
        out_bytes = _nbytes(shapes)

        if op == "dot":
            ops_named = _OPERAND_RE.findall(rest.split("metadata")[0])
            lhs = shape_of.get(ops_named[0], []) if ops_named else []
            cdims = _DIMS_RE["lhs_c"].search(rest)
            contr = 1
            if lhs and cdims and cdims.group(1):
                lhs_shape = lhs[0][1]
                for i in [int(x) for x in cdims.group(1).split(",") if x]:
                    if i < len(lhs_shape):
                        contr *= lhs_shape[i]
            out_elems = sum(_nelems(s) for _, s in shapes)
            flops += mult * 2.0 * out_elems * contr
            n_dots += 1
        elif op == "convolution":
            ops_named = _OPERAND_RE.findall(rest.split("metadata")[0])
            rhs = shape_of.get(ops_named[1], []) if len(ops_named) > 1 else []
            k_elems = _nelems(rhs[0][1]) if rhs else 1
            out_elems = sum(_nelems(s) for _, s in shapes)
            # per output element: 2 * (kernel elems / output features)
            out_feat = shapes[0][1][-1] if shapes[0][1] else 1
            flops += mult * 2.0 * out_elems * max(k_elems // max(out_feat, 1), 1)

        in_fused = comp in fused_comps

        if (op in COLL_KINDS or any(
            op == f"{k}-start" for k in COLL_KINDS
        )) and not in_fused:
            kind = op.replace("-start", "")
            payload = out_bytes if kind != "all-gather" else out_bytes
            factor = 2.0 if kind == "all-reduce" else 1.0
            coll[kind] += mult * payload * factor
            coll["count"] += int(mult)

        if op in ("fusion", "dot", "convolution", "copy", "reduce",
                  "transpose", "broadcast", "concatenate", "scatter",
                  "gather", "dynamic-slice", "dynamic-update-slice", "sort") \
                and not in_fused:
            ops_named = _OPERAND_RE.findall(rest.split("metadata")[0])
            in_bytes = sum(_nbytes(shape_of.get(o, [])) for o in ops_named)
            bytes_traffic += mult * (out_bytes + in_bytes)
            # v2 "HBM traffic": exclude bare copies/transposes/broadcasts/
            # concats — XLA-CPU emits them profusely where the TPU backend
            # fuses them away, so they inflate the memory term
            if op in ("fusion", "dot", "convolution", "reduce", "scatter",
                      "gather", "dynamic-slice", "dynamic-update-slice",
                      "sort"):
                bytes_hbm += mult * (out_bytes + in_bytes)

    return {
        "flops": flops,
        "bytes": bytes_traffic,
        "bytes_hbm": bytes_hbm,
        "collectives": coll,
        "n_dots": n_dots,
    }

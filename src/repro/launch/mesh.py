"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
touches no jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )

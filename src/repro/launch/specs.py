"""Input specs + sharding assembly for every (arch × shape × mesh) cell.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input — weak-type-correct, shardable, no device allocation. The modality
frontends are stubs: whisper receives precomputed frame embeddings,
internvl2 precomputed patch embeddings (per the assignment).

`build_cell(cfg, shape, mesh, ...)` assembles the jit'd step function for a
cell with in/out shardings and returns (fn, abstract args, shardings) ready
for `.lower().compile()` — shared by the dry-run, the trainer and the
server.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import init_cache, init_params
from repro.models.config import ModelConfig, ShapeSpec
from repro.parallel import ParallelCtx, maybe_axis, param_pspecs, parallel_ctx
from repro.parallel.sharding import default_rules
from repro.train import AdamW, make_train_step
from repro.serve import make_prefill, make_serve_step

__all__ = [
    "input_specs", "cache_pspecs", "batch_pspecs", "build_cell", "skip_reason",
]

_DT = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """Cells excluded by the assignment rules (recorded in DESIGN.md §4)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is full-attention ({cfg.family})"
        )
    return None


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract batch for train/prefill shapes ({tokens, targets, ...})."""
    B, T = shape.global_batch, shape.seq_len
    def tok(*s):
        return jax.ShapeDtypeStruct(s, jnp.int32)

    def emb(*s):
        return jax.ShapeDtypeStruct(s, _DT[cfg.dtype])

    if cfg.family == "audio":
        Te = Td = T // 2
        batch = {"frames": emb(B, Te, cfg.d_model), "tokens": tok(B, Td)}
        tgt_len = Td
    elif cfg.family == "vlm":
        Np = cfg.num_patches
        Tt = max(T - Np, 1)
        batch = {"patches": emb(B, Np, cfg.d_model), "tokens": tok(B, Tt)}
        tgt_len = Tt
    else:
        batch = {"tokens": tok(B, T)}
        tgt_len = T
    if shape.kind == "train":
        batch["targets"] = tok(B, tgt_len)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(cache sds, tokens sds) for decode shapes — cache holds `seq_len`."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    return cache, tokens


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def batch_pspecs(batch, ctx: ParallelCtx):
    def spec(x):
        if x.ndim == 1:
            return P(maybe_axis(ctx, "dp", x.shape[0]))
        if x.ndim == 2:
            return P(maybe_axis(ctx, "dp", x.shape[0]), None)
        return P(maybe_axis(ctx, "dp", x.shape[0]), None,
                 maybe_axis(ctx, "tp", x.shape[-1]))
    return jax.tree_util.tree_map(spec, batch)


def cache_pspecs(cache, ctx: ParallelCtx, cfg: ModelConfig):
    """KV caches: batch->dp; heads->tp when divisible, else sequence->tp
    (sequence-parallel KV; GSPMD turns softmax reductions into all-reduces).
    SSM states: heads/channels->tp, batch->dp."""

    def spec(path, x):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("k", "v", "xk", "xv", "attn_k", "attn_v"):
            L, B, S, H, hd = x.shape
            dp = maybe_axis(ctx, "dp", B)
            tp_h = maybe_axis(ctx, "tp", H)
            if tp_h is not None:
                return P(None, dp, None, tp_h, None)
            return P(None, dp, maybe_axis(ctx, "tp", S), None, None)
        if name == "ssm":
            return P(None, maybe_axis(ctx, "dp", x.shape[1]),
                     maybe_axis(ctx, "tp", x.shape[2]), None, None)
        if name == "conv":
            return P(None, maybe_axis(ctx, "dp", x.shape[1]), None,
                     maybe_axis(ctx, "tp", x.shape[3]))
        if name == "mlstm":
            return P(None, maybe_axis(ctx, "dp", x.shape[1]),
                     maybe_axis(ctx, "tp", x.shape[2]), None, None)
        if name.startswith("slstm"):
            return P(None, maybe_axis(ctx, "dp", x.shape[1]),
                     maybe_axis(ctx, "tp", x.shape[2]))
        if name in ("pos", "mem_len"):
            return P(maybe_axis(ctx, "dp", x.shape[0]))
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def _shardings(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# cell assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    fn: object          # jit'd function, ready to .lower(*abstract)
    abstract: tuple     # abstract args
    mode: str           # train | prefill | decode


def build_cell(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh: Mesh,
    *,
    microbatches: int = 1,
    zero1: bool = True,
    donate: bool = True,
) -> Cell:
    rules = default_rules(mesh)
    with parallel_ctx(mesh, rules) as ctx:
        params_sds = jax.eval_shape(
            functools.partial(init_params, cfg), jax.random.PRNGKey(0)
        )
        p_specs = param_pspecs(params_sds, ctx)
        p_shard = _shardings(p_specs, mesh)

        if shape.kind in ("train",):
            opt = AdamW(zero1=zero1)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            opt_specs = opt.opt_state_pspecs(p_specs, params_sds)
            state_sds = {"params": params_sds, "opt": opt_sds}
            state_shard = {"params": p_shard, "opt": _shardings(opt_specs, mesh)}
            batch_sds = input_specs(cfg, shape)
            b_shard = _shardings(batch_pspecs(batch_sds, ctx), mesh)
            step = make_train_step(cfg, opt, microbatches)

            def wrapped(state, batch):
                with parallel_ctx(mesh, rules):
                    return step(state, batch)

            fn = jax.jit(
                wrapped,
                in_shardings=(state_shard, b_shard),
                out_shardings=(state_shard, None),
                donate_argnums=(0,) if donate else (),
            )
            return Cell(fn, (state_sds, batch_sds), "train")

        if shape.kind == "prefill":
            batch_sds = input_specs(cfg, shape)
            b_shard = _shardings(batch_pspecs(batch_sds, ctx), mesh)
            prefill = make_prefill(cfg)

            def wrapped(params, batch):
                with parallel_ctx(mesh, rules):
                    return prefill(params, batch)

            fn = jax.jit(wrapped, in_shardings=(p_shard, b_shard))
            return Cell(fn, (params_sds, batch_sds), "prefill")

        # decode
        cache_sds, tok_sds = decode_input_specs(cfg, shape)
        c_shard = _shardings(cache_pspecs(cache_sds, ctx, cfg), mesh)
        t_shard = _shardings(batch_pspecs(tok_sds, ctx), mesh)
        sstep = make_serve_step(cfg)

        def wrapped(params, cache, tokens):
            with parallel_ctx(mesh, rules):
                return sstep(params, cache, tokens)

        fn = jax.jit(
            wrapped,
            in_shardings=(p_shard, c_shard, t_shard),
            out_shardings=(t_shard, c_shard),
            donate_argnums=(1,) if donate else (),
        )
        return Cell(fn, (params_sds, cache_sds, tok_sds), "decode")

"""Training launcher: end-to-end driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs the full loop on whatever devices exist (use the dry-run for the
production mesh): data pipeline → pjit'd train step → metrics → async
checkpoints; resumes from the latest checkpoint on restart (crash/preempt
recovery), and re-shards the restored state if the device count changed
since the checkpoint was written (elastic restart).

Straggler mitigation: per-step wall times feed an EWMA; steps slower than
`--straggler-factor` × EWMA are counted and logged — on a real cluster this
signal drives the backup-worker dispatch in the job controller (here:
observability + the counter in the final report).
"""
from __future__ import annotations

import argparse
import signal
import time

import jax

from repro import configs
from repro.launch.mesh import make_local_mesh
from repro.models.config import ShapeSpec
from repro.parallel import parallel_ctx, param_pspecs
from repro.parallel.sharding import default_rules
from repro.train import AdamW, cosine_schedule, init_state, make_train_step
from repro.train.checkpoint import Checkpointer, latest_step, restore
from repro.train.data import SyntheticTokens


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=0, help="data-mesh size (0=auto)")
    ap.add_argument("--model", type=int, default=1, help="model-mesh size")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    n_dev = jax.device_count()
    data_size = args.data or max(1, n_dev // args.model)
    mesh = make_local_mesh(data_size, args.model)
    rules = default_rules(mesh)
    print(f"[train] {cfg.name} devices={n_dev} mesh={dict(mesh.shape)}")

    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps), zero1=True)
    step_fn = make_train_step(cfg, opt, args.microbatches)

    with parallel_ctx(mesh, rules) as ctx:
        state = init_state(cfg, jax.random.PRNGKey(args.seed), opt)
        p_specs = param_pspecs(state["params"], ctx)
        opt_specs = opt.opt_state_pspecs(p_specs, state["params"])
        from jax.sharding import NamedSharding
        def to_sh(specs):
            return jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            )

        state_sh = {"params": to_sh(p_specs), "opt": to_sh(opt_specs)}
        state = jax.tree_util.tree_map(jax.device_put, state, state_sh)

        start = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = Checkpointer(args.ckpt_dir)
            last = latest_step(args.ckpt_dir)
            if last is not None:
                print(f"[train] resuming from step {last} "
                      f"(elastic re-shard onto {n_dev} devices)")
                state = restore(args.ckpt_dir, last, state, state_sh)
                start = last

        def wrapped(state, batch):
            with parallel_ctx(mesh, rules):
                return step_fn(state, batch)

        jstep = jax.jit(wrapped, donate_argnums=(0,))

        stop = {"flag": False}
        signal.signal(signal.SIGTERM, lambda *_: stop.update(flag=True))

        data = iter(SyntheticTokens(cfg, shape, args.seed, start_step=start))
        ewma, stragglers = None, 0
        losses = []
        for i in range(start, args.steps):
            batch = next(data)
            t0 = time.perf_counter()
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > args.straggler_factor * ewma:
                stragglers += 1
                print(f"[train] straggler step {i}: {dt:.2f}s vs ewma {ewma:.2f}s")
            losses.append(loss)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"[train] step {i:5d} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save_async(i + 1, state)
            if stop["flag"]:
                print("[train] SIGTERM — checkpointing and exiting")
                if ckpt:
                    ckpt.save_async(i + 1, state)
                break
        if ckpt:
            ckpt.wait()
        print(f"[train] done. first loss={losses[0]:.4f} last={losses[-1]:.4f} "
              f"stragglers={stragglers}")
        return losses


if __name__ == "__main__":
    main()

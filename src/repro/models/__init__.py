"""Model zoo: the 10 assigned architectures as composable JAX modules."""
from .config import ModelConfig, SHAPES, ShapeSpec
from .zoo import decode_step, forward, init_cache, init_params, loss_fn

__all__ = [
    "ModelConfig",
    "SHAPES",
    "ShapeSpec",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
]

"""Model configuration schema covering all 10 assigned architectures.

One frozen dataclass describes every family (dense / moe / ssm / audio /
vlm / hybrid); `src/repro/configs/<arch>.py` instantiates the exact
published dimensions plus a `reduced()` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    act: str = "swiglu"         # swiglu | gelu
    rope_theta: float = 1e6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim (d_ff column in table)
    capacity_factor: float = 1.25
    n_expert_slots: int = 0     # weight-storage slots (>= n_experts, padded
                                # so expert parallelism divides the mesh;
                                # slots beyond n_experts are never routed to)

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    shared_attn_every: int = 0  # zamba2: shared attention block period
    slstm_every: int = 0        # xlstm: sLSTM block period (else mLSTM)

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    # vlm
    num_patches: int = 1024     # stub ViT patch count per image

    # numerics / serving
    dtype: str = "bfloat16"
    max_seq: int = 524_288
    attn_chunk: int = 2048      # q-chunked attention block (XLA path)
    remat: str = "block"        # none | block | dots
    n_heads_padded: int = 0     # pad query heads to this count (0 = off) so
                                # head counts that don't divide the model
                                # axis (36, 40) still shard instead of
                                # replicating attention; padded heads have
                                # zeroed output rows
    residual: str = "tp"        # residual-stream layout: "tp" shards d_model
                                # over the model axis (lower memory, extra
                                # norm collectives); "replicated" keeps the
                                # residual full (classic Megatron: collectives
                                # only after row-parallel projections)
    ssd_chunk: int = 128        # SSD chunk length (mamba2 / mLSTM)
    # probe mode: unroll every scan so compiled.cost_analysis() counts true
    # FLOPs/bytes/collectives (used by the dry-run's per-layer cost probes;
    # the real artifact keeps scans rolled)
    probe: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def heads_eff(self) -> int:
        return max(self.n_heads_padded, self.n_heads)

    @property
    def expert_slots(self) -> int:
        return self.n_expert_slots or self.n_experts

    @property
    def active_params(self) -> int:
        """Approximate active parameter count (MoE counts top-k experts)."""
        return count_params(self, active_only=True)

    @property
    def total_params(self) -> int:
        return count_params(self, active_only=False)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    d, hd = cfg.d_model, cfg.hd
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) + (cfg.n_heads * hd) * d
    if cfg.family == "moe":
        e = cfg.experts_per_tok if active_only else cfg.n_experts
        ffn = (e + cfg.n_shared_experts) * 3 * d * cfg.moe_d_ff
        router = d * cfg.n_experts
        block = attn + ffn + router + 2 * d
    elif cfg.family in ("ssm",):
        di = cfg.ssm_expand * d
        # mLSTM-ish block: in/out proj + qkv + gates
        block = 2 * d * di + 3 * di * di // 4 + 2 * d
    elif cfg.family == "hybrid":
        di = cfg.ssm_expand * d
        block = 2 * d * di + di * (2 * cfg.ssm_state) + 2 * d
    else:
        mult = 3 if cfg.act == "swiglu" else 2
        ffn = mult * d * cfg.d_ff
        block = attn + ffn + 2 * d
    layers = cfg.n_layers + cfg.encoder_layers
    return emb + layers * block + d


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

"""Shared transformer layers: RMSNorm, RoPE, GQA attention, gated MLPs.

Functional style: ``init_*`` builds parameter pytrees (or their
eval_shape'd ShapeDtypeStructs for the dry-run), ``apply`` functions are
pure. Activations carry `with_sharding_constraint` hints at layer
boundaries; parameter shardings come from `repro.parallel.sharding`.

Attention uses a *query-chunked* XLA path by default (lax.scan over query
blocks, full-softmax per block) so prefill never materializes a T×T logits
tensor — peak temp is (B, H, chunk, T). On TPU the fused Pallas kernel
(`repro.kernels.flash_attention`) replaces it via ``attn_impl='pallas'``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "rope_cos_sin",
    "apply_rope",
    "attention",
    "decode_attention_xla",
    "mlp",
    "init_dense",
    "init_norm",
]


def init_dense(key, d_in: int, d_out: int, dtype=jnp.bfloat16, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_norm(d: int, dtype=jnp.bfloat16):
    return jnp.ones((d,), dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim//2), float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., T, H, D); cos/sin (..., T, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


def _sdpa_block(q, k, v, scale, causal, q_offset, kv_len):
    """Full-softmax attention for one query chunk. q (B,H,cq,D), k/v (B,H,T,D)."""
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        cq = q.shape[2]
        qpos = q_offset + jnp.arange(cq)[:, None]
        kpos = jnp.arange(kv_len)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


def attention(
    q: jax.Array,  # (B, T, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,  # (B, T, Hkv, D)
    *,
    causal: bool = True,
    chunk: int = 0,
    attn_impl: str = "xla",
) -> jax.Array:
    """GQA attention over a full sequence (training / prefill)."""
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    scale = D ** -0.5

    if attn_impl == "pallas":
        from repro.kernels import ops

        out = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal,
        )
        return out.transpose(0, 2, 1, 3)

    qh = q.transpose(0, 2, 1, 3)                       # (B,Hq,T,D)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)

    if chunk and T > chunk and T % chunk == 0:
        nq = T // chunk

        def body(carry, i):
            qc = jax.lax.dynamic_slice_in_dim(qh, i * chunk, chunk, axis=2)
            oc = _sdpa_block(qc, kh, vh, scale, causal, i * chunk, T)
            return carry, oc

        _, chunks = jax.lax.scan(body, None, jnp.arange(nq))
        out = jnp.moveaxis(chunks, 0, 2).reshape(B, Hq, T, D)
    else:
        out = _sdpa_block(qh, kh, vh, scale, causal, 0, T)
    return out.transpose(0, 2, 1, 3)


def decode_attention_xla(
    q: jax.Array,        # (B, Hq, D) single new token
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,)
) -> jax.Array:
    """XLA decode attention; S may be sharded — max/sum reductions over the
    sequence axis become all-reduces under GSPMD (sequence-parallel KV)."""
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = Hq // Hkv
    scale = D ** -0.5
    qg = q.reshape(B, Hkv, g, D).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32)) * scale
    mask = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None, :]
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


def mlp(x: jax.Array, w: dict, act: str) -> jax.Array:
    if act == "swiglu":
        gate = jnp.einsum("btd,df->btf", x, w["w_gate"])
        up = jnp.einsum("btd,df->btf", x, w["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:  # gelu
        h = jnp.einsum("btd,df->btf", x, w["w_up"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("btf,fd->btd", h, w["w_down"])


def init_mlp(key, d: int, d_ff: int, act: str, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    w = {"w_up": init_dense(ks[0], d, d_ff, dtype),
         "w_down": init_dense(ks[1], d_ff, d, dtype)}
    if act == "swiglu":
        w["w_gate"] = init_dense(ks[2], d, d_ff, dtype)
    return w

"""Mixture-of-Experts layer: top-k routing, shared experts, expert parallelism.

Two implementations with identical routing semantics:

`moe_ref`      — single-logical-device capacity dispatch (sort-based, pure
                 jnp). Used by smoke tests and as the numerical oracle.

`moe_sharded`  — the production path (DeepSeek/Kimi-style EP × TP), written
                 in `shard_map`:
                   tokens sharded over ("pod","data"), d_model over "model";
                   experts sharded over EP groups = pod×data;
                   1. router logits: partial matmul + psum("model")
                   2. capacity dispatch to a (groups, C, d_loc) buffer
                   3. all_to_all over ("pod","data")  — tokens → experts
                   4. per-expert FFN with row-parallel up-proj and
                      psum_scatter("model") (never materializes the full
                      hidden dim), row-parallel down-proj + psum_scatter
                   5. all_to_all back, weighted combine at the sender.
                 Dropped tokens (over capacity) fall through the residual,
                 exactly like the reference.

Capacity C = ceil(tokens·k / E · capacity_factor) is static, so the whole
block lowers to fixed-shape matmuls + two all_to_alls — no dynamic shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import init_dense

__all__ = ["init_moe", "moe_ref", "moe_sharded", "router_topk"]


def init_moe(key, d: int, cfg, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.moe_d_ff
    ES = cfg.expert_slots  # storage slots (padded for EP divisibility)
    p = {
        "w_router": init_dense(ks[0], d, E, jnp.float32),
        "w_gate": init_dense(ks[1], d, F, dtype)[None].repeat(ES, 0) * 1.0,
        "w_up": init_dense(ks[2], d, F, dtype)[None].repeat(ES, 0) * 1.0,
        "w_down": init_dense(ks[3], F, d, dtype)[None].repeat(ES, 0) * 1.0,
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": init_dense(kk[0], d, Fs, dtype),
            "w_up": init_dense(kk[1], d, Fs, dtype),
            "w_down": init_dense(kk[2], Fs, d, dtype),
        }
    return p


def router_topk(x2d: jax.Array, w_router: jax.Array, k: int):
    """(N, d) tokens -> (weights (N,k) f32, sel (N,k) i32)."""
    logits = x2d.astype(jnp.float32) @ w_router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, sel.astype(jnp.int32)


def _capacity(n_slots: int, n_buckets: int, cf: float) -> int:
    return int(np.ceil(n_slots / n_buckets * cf))


def _dispatch_indices(sel_flat: jax.Array, n_buckets: int, capacity: int):
    """Sort token-slots by bucket; return (order, bucket_sorted, pos, keep)."""
    order = jnp.argsort(sel_flat, stable=True)
    sorted_b = sel_flat[order]
    counts = jnp.bincount(sel_flat, length=n_buckets)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(sel_flat.shape[0]) - starts[sorted_b]
    keep = pos < capacity
    return order, sorted_b, pos, keep


def _expert_ffn(buf: jax.Array, w_gate, w_up, w_down, act: str = "swiglu"):
    """buf (E, C, d) -> (E, C, d); per-expert gated FFN."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _shared_expert(x: jax.Array, w: dict) -> jax.Array:
    g = x @ w["w_gate"]
    u = x @ w["w_up"]
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return h @ w["w_down"]


def moe_ref(x: jax.Array, params: dict, cfg) -> jax.Array:
    """Reference MoE. x (B, T, d) -> (B, T, d)."""
    B, T, d = x.shape
    E, k, cf = cfg.expert_slots, cfg.experts_per_tok, cfg.capacity_factor
    xt = x.reshape(-1, d)
    N = xt.shape[0]
    weights, sel = router_topk(xt, params["w_router"], k)

    C = _capacity(N * k, cfg.n_experts, cf)
    sel_flat = sel.reshape(-1)
    tok_of_slot = jnp.repeat(jnp.arange(N), k)
    w_flat = weights.reshape(-1)

    order, sorted_e, pos, keep = _dispatch_indices(sel_flat, E, C)
    src_tok = tok_of_slot[order]

    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[sorted_e, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xt[src_tok], 0)
    )
    out_buf = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])

    y_slot = out_buf[sorted_e, jnp.where(keep, pos, 0)]
    y_slot = jnp.where(keep[:, None], y_slot, 0) * w_flat[order][:, None].astype(x.dtype)
    y = jnp.zeros((N, d), x.dtype).at[src_tok].add(y_slot)

    if "shared" in params:
        y = y + _shared_expert(xt, params["shared"])
    return y.reshape(B, T, d)


# ---------------------------------------------------------------------------
# Sharded expert-parallel MoE (shard_map)
# ---------------------------------------------------------------------------

def moe_sharded(
    x: jax.Array,        # (B, T, d) global
    params: dict,
    cfg,
    mesh: jax.sharding.Mesh,
    *,
    ep_axes: tuple[str, ...],   # e.g. ("pod", "data")
    tp_axis: str = "model",
) -> jax.Array:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    E, k, cf = cfg.expert_slots, cfg.experts_per_tok, cfg.capacity_factor
    G = int(np.prod([mesh.shape[a] for a in ep_axes]))   # EP group count
    assert E % G == 0, (E, G, "pad n_expert_slots to a multiple of EP size")
    E_loc = E // G
    d = x.shape[-1]
    B, T = x.shape[0], x.shape[1]
    N_loc = B * T // G                    # tokens per EP shard
    C = _capacity(N_loc * k, G, cf)       # per-destination-group capacity
    C2 = _capacity(G * C, E_loc, cf)      # per-expert capacity after a2a

    def local(x_loc, w_router, w_gate, w_up, w_down):
        # x_loc: (B_loc, T, d_loc); experts weights are EP+TP shards:
        # w_gate (E_loc, d_loc, F) / w_down (E_loc, F_loc, d)… see specs below
        d_loc = x_loc.shape[-1]
        xt = x_loc.reshape(-1, d_loc)

        # --- router: partial logits + psum over TP
        part = xt.astype(jnp.float32) @ w_router.astype(jnp.float32)
        logits = jax.lax.psum(part, tp_axis)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, sel = jax.lax.top_k(probs, k)
        weights = (weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9))
        sel = sel.astype(jnp.int32)

        # --- first-stage dispatch: destination EP group = expert // E_loc
        sel_flat = sel.reshape(-1)
        grp = sel_flat // E_loc
        tok_of_slot = jnp.repeat(jnp.arange(xt.shape[0]), k)
        order, sorted_g, pos, keep = _dispatch_indices(grp, G, C)
        src_tok = tok_of_slot[order]
        safe_pos = jnp.where(keep, pos, 0)

        send = jnp.zeros((G, C, d_loc), x_loc.dtype)
        send = send.at[sorted_g, safe_pos].add(
            jnp.where(keep[:, None], xt[src_tok], 0)
        )
        send_eid = jnp.full((G, C), E_loc, jnp.int32)  # E_loc = invalid slot
        send_eid = send_eid.at[sorted_g, safe_pos].set(
            jnp.where(keep, sel_flat[order] % E_loc, E_loc)
        )

        # --- all_to_all: tokens to the group owning their expert
        recv = jax.lax.all_to_all(send, ep_axes, 0, 0, tiled=True)
        recv_eid = jax.lax.all_to_all(send_eid, ep_axes, 0, 0, tiled=True)

        # --- second-stage dispatch to per-expert buffers (invalid -> bucket E_loc)
        flat_tok = recv.reshape(G * C, d_loc)
        flat_eid = recv_eid.reshape(G * C)
        order2, sorted_e, pos2, keep2 = _dispatch_indices(flat_eid, E_loc + 1, C2)
        keep2 = keep2 & (sorted_e < E_loc)
        safe_e = jnp.where(keep2, sorted_e, 0)
        safe_p2 = jnp.where(keep2, pos2, 0)
        ebuf = jnp.zeros((E_loc, C2, d_loc), x_loc.dtype)
        ebuf = ebuf.at[safe_e, safe_p2].add(
            jnp.where(keep2[:, None], flat_tok[order2], 0)
        )

        # --- expert FFN: row-parallel over d_loc, psum_scatter to F_loc
        g_part = jnp.einsum("ecd,edf->ecf", ebuf, w_gate)   # partial (E,C2,F)
        u_part = jnp.einsum("ecd,edf->ecf", ebuf, w_up)
        g_loc = jax.lax.psum_scatter(g_part, tp_axis, scatter_dimension=2, tiled=True)
        u_loc = jax.lax.psum_scatter(u_part, tp_axis, scatter_dimension=2, tiled=True)
        h_loc = jax.nn.silu(g_loc.astype(jnp.float32)).astype(x_loc.dtype) * u_loc
        o_part = jnp.einsum("ecf,efd->ecd", h_loc, w_down)  # partial (E,C2,d)
        o_loc = jax.lax.psum_scatter(o_part, tp_axis, scatter_dimension=2, tiled=True)

        # --- gather back to a2a slots, return trip, weighted combine
        y_slots = jnp.zeros((G * C, d_loc), x_loc.dtype)
        vals = o_loc[safe_e, safe_p2]
        y_slots = y_slots.at[order2].add(jnp.where(keep2[:, None], vals, 0))
        y_back = jax.lax.all_to_all(
            y_slots.reshape(G, C, d_loc), ep_axes, 0, 0, tiled=True
        )

        w_flat = weights.reshape(-1)[order].astype(x_loc.dtype)
        y_tok = jnp.zeros_like(xt)
        contrib = y_back[sorted_g, safe_pos] * w_flat[:, None]
        y_tok = y_tok.at[src_tok].add(jnp.where(keep[:, None], contrib, 0))
        return y_tok.reshape(x_loc.shape)

    specs_in = (
        P(ep_axes, None, tp_axis),                    # x (B, T, d)
        P(tp_axis, None),                             # w_router (d, E): d sharded
        P(ep_axes, tp_axis, None),                    # w_gate (E, d, F): d sharded
        P(ep_axes, tp_axis, None),                    # w_up   (E, d, F): d sharded
        P(ep_axes, tp_axis, None),                    # w_down (E, F, d): F sharded
    )
    out_spec = P(ep_axes, None, tp_axis)

    y = shard_map(
        local, mesh=mesh, in_specs=specs_in, out_specs=out_spec, check_rep=False,
    )(x, params["w_router"], params["w_gate"], params["w_up"], params["w_down"])

    if "shared" in params:
        y = y + _shared_expert(x.reshape(-1, d), params["shared"]).reshape(x.shape)
    return y

"""SSM-family mixers: Mamba-2 (SSD) and xLSTM (mLSTM / sLSTM).

Training/prefill uses the chunked SSD decomposition (lax.scan over chunks of
the sequence; intra-chunk work is MXU matmuls — same math as the
`mamba_scan` Pallas kernel, vectorized over batch and heads). Decode is the
O(1)-per-token state recurrence, which is why the SSM/hybrid architectures
are the ones that run the long_500k shape (DESIGN.md §4).

The mLSTM is implemented as gated linear attention in the same chunked form
(per-head keys/values; sigmoid input/forget gates — the stabilized
exponential-gating variant of the paper is simplified to sigmoid gates,
which preserves the compute/memory profile; recorded in DESIGN.md). The
sLSTM is a per-unit scalar recurrence scanned over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import init_dense, init_norm, rms_norm

__all__ = [
    "chunked_ssd",
    "init_mamba2", "mamba2_forward", "mamba2_init_state", "mamba2_decode_step",
    "init_mlstm", "mlstm_forward", "mlstm_decode_step",
    "init_slstm", "slstm_forward", "slstm_decode_step",
]


# ---------------------------------------------------------------------------
# Generalized chunked SSD: h_t = exp(ld_t) h_{t-1} + s_t x_t ⊗ B_t ; y = C·h
# ---------------------------------------------------------------------------

def chunked_ssd(
    x: jax.Array,         # (B, T, H, P) values
    log_decay: jax.Array, # (B, T, H)
    scale: jax.Array,     # (B, T, H) input scale (dt or input gate)
    Bm: jax.Array,        # (B, T, G, S) keys; G == 1 (shared) or H (per-head)
    Cm: jax.Array,        # (B, T, G, S) queries
    chunk: int = 128,
    unroll: bool = False,
) -> jax.Array:
    B, T, H, P = x.shape
    G, S = Bm.shape[2], Bm.shape[3]
    c = min(chunk, T)
    assert T % c == 0
    nc = T // c

    xr = x.reshape(B, nc, c, H, P)
    ldr = log_decay.reshape(B, nc, c, H).astype(jnp.float32)
    sr = scale.reshape(B, nc, c, H).astype(jnp.float32)
    Br = Bm.reshape(B, nc, c, G, S).astype(jnp.float32)
    Cr = Cm.reshape(B, nc, c, G, S).astype(jnp.float32)

    tril = np.tril(np.ones((c, c), np.float32))

    def step(h, inp):
        xc, ldc, sc, bc, cc = inp         # (B,c,H,P) (B,c,H) (B,c,H) (B,c,G,S)
        L = jnp.cumsum(ldc, axis=1)       # (B,c,H)
        # intra-chunk
        CB = jnp.einsum("bcgs,bkgs->bckg", cc, bc)          # (B,c,c,G)
        if G == 1:
            CB = jnp.broadcast_to(CB, (B, c, c, 1))
        decay = jnp.exp(L[:, :, None, :] - L[:, None, :, :])  # (B,c,c,H)
        gmat = jnp.where(tril[None, :, :, None] > 0, decay, 0.0)
        if G == 1:
            attn = gmat * CB                                 # (B,c,c,H)
        else:
            attn = gmat * CB
        dx = sc[..., None] * xc.astype(jnp.float32)          # (B,c,H,P)
        y_intra = jnp.einsum("bckh,bkhp->bchp", attn, dx)
        # inter-chunk (carried state h: (B,H,P,S) for G==1 / (B,H,P,S))
        if G == 1:
            y_inter = jnp.einsum("bcs,bhps->bchp", cc[:, :, 0], h)
        else:
            y_inter = jnp.einsum("bchs,bhps->bchp", cc, h)
        y = y_intra + jnp.exp(L)[..., None] * y_inter
        # state update
        w = jnp.exp(L[:, -1:, :] - L)[..., None] * dx        # (B,c,H,P)
        if G == 1:
            dh = jnp.einsum("bkhp,bks->bhps", w, bc[:, :, 0])
        else:
            dh = jnp.einsum("bkhp,bkhs->bhps", w, bc)
        h = jnp.exp(L[:, -1])[..., None, None] * h + dh
        return h, y

    h0 = jnp.zeros((B, H, P, S), jnp.float32)
    xs = (
        jnp.moveaxis(xr, 1, 0), jnp.moveaxis(ldr, 1, 0),
        jnp.moveaxis(sr, 1, 0), jnp.moveaxis(Br, 1, 0), jnp.moveaxis(Cr, 1, 0),
    )
    h_last, ys = jax.lax.scan(step, h0, xs, unroll=nc if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P)
    return y.astype(x.dtype), h_last


# ---------------------------------------------------------------------------
# Mamba-2 mixer
# ---------------------------------------------------------------------------

_CONV_K = 4
_HEAD_P = 64


def _mamba_dims(d: int, cfg):
    di = cfg.ssm_expand * d
    H = di // _HEAD_P
    S = cfg.ssm_state
    return di, H, S


def init_mamba2(key, d: int, cfg, dtype=jnp.bfloat16) -> dict:
    di, H, S = _mamba_dims(d, cfg)
    ks = jax.random.split(key, 4)
    zxbcdt = 2 * di + 2 * S + H
    conv_ch = di + 2 * S
    return {
        "w_in": init_dense(ks[0], d, zxbcdt, dtype),
        "conv_w": (jax.random.normal(ks[1], (_CONV_K, conv_ch), jnp.float32)
                   * 0.2).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": init_norm(di, dtype),
        "w_out": init_dense(ks[2], di, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv along T. x (B,T,C), w (K,C). Returns (y, tail)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    tail = xp[:, -(K - 1):]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), tail


def _mamba_split(zxbcdt, di, H, S):
    z, xs, B_, C_, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + S, 2 * di + 2 * S], axis=-1
    )
    return z, xs, B_, C_, dt


def mamba2_forward(x: jax.Array, p: dict, cfg, chunk: int | None = None):
    """x (B,T,d) -> (B,T,d); returns (out, final_state dict)."""
    B, T, d = x.shape
    di, H, S = _mamba_dims(d, cfg)
    zxbcdt = jnp.einsum("btd,dz->btz", x, p["w_in"])
    z, xs, B_, C_, dt = _mamba_split(zxbcdt, di, H, S)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)
    conv_out, conv_tail = _causal_conv(conv_in, p["conv_w"])
    xs, B_, C_ = jnp.split(conv_out, [di, di + S], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, T, H, _HEAD_P)
    c = chunk or cfg.ssd_chunk
    y, h_last = chunked_ssd(
        xh, dt * A, dt, B_[:, :, None, :], C_[:, :, None, :], chunk=c,
        unroll=getattr(cfg, "probe", False),
    )
    y = (y + p["D"][None, None, :, None] * xh).astype(x.dtype)
    y = y.reshape(B, T, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["norm"])
    out = jnp.einsum("bti,id->btd", y, p["w_out"]).astype(x.dtype)
    return out, {"ssm": h_last, "conv": conv_tail}


def mamba2_init_state(batch: int, d: int, cfg, dtype=jnp.bfloat16) -> dict:
    di, H, S = _mamba_dims(d, cfg)
    return {
        "ssm": jnp.zeros((batch, H, _HEAD_P, S), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_K - 1, di + 2 * S), dtype),
    }


def mamba2_decode_step(x: jax.Array, state: dict, p: dict, cfg):
    """x (B, d) single token; returns (out (B, d), new state)."""
    B, d = x.shape
    di, H, S = _mamba_dims(d, cfg)
    zxbcdt = x @ p["w_in"]
    z, xs, B_, C_, dt = _mamba_split(zxbcdt, di, H, S)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)[:, None, :]
    window = jnp.concatenate([state["conv"], conv_in], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, p["conv_w"])
    y = jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype)
    xs, B_, C_ = jnp.split(y, [di, di + S], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                           # (B,H)
    xh = xs.reshape(B, H, _HEAD_P).astype(jnp.float32)
    upd = (dt[..., None] * xh)[..., None] * B_.astype(jnp.float32)[:, None, None, :]
    h = decay[..., None, None] * state["ssm"] + upd                   # (B,H,P,S)
    yh = (h * C_.astype(jnp.float32)[:, None, None, :]).sum(-1)       # (B,H,P)
    yh = yh + p["D"][None, :, None] * xh
    yv = yh.reshape(B, di).astype(x.dtype)
    yv = rms_norm(yv * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["norm"])
    out = yv @ p["w_out"]
    return out, {"ssm": h, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (chunked gated linear attention) and sLSTM (scalar recurrence)
# ---------------------------------------------------------------------------

def init_mlstm(key, d: int, n_heads: int, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "w_q": init_dense(ks[0], d, d, dtype),
        "w_k": init_dense(ks[1], d, d, dtype),
        "w_v": init_dense(ks[2], d, d, dtype),
        "w_gates": init_dense(ks[3], d, 2 * n_heads, jnp.float32),
        "norm": init_norm(d, dtype),
        "w_out": init_dense(ks[4], d, d, dtype),
    }


def mlstm_forward(x: jax.Array, p: dict, n_heads: int, chunk: int = 128,
                  unroll: bool = False):
    B, T, d = x.shape
    hd = d // n_heads
    q = jnp.einsum("btd,de->bte", x, p["w_q"]).reshape(B, T, n_heads, hd)
    k = jnp.einsum("btd,de->bte", x, p["w_k"]).reshape(B, T, n_heads, hd)
    v = jnp.einsum("btd,de->bte", x, p["w_v"]).reshape(B, T, n_heads, hd)
    gates = jnp.einsum("btd,dg->btg", x.astype(jnp.float32), p["w_gates"])
    i_g, f_g = jnp.split(gates, 2, axis=-1)                 # (B,T,H)
    log_f = jax.nn.log_sigmoid(f_g)
    i_s = jax.nn.sigmoid(i_g)
    y, h_last = chunked_ssd(
        v, log_f, i_s, k * (hd ** -0.5), q, chunk=chunk, unroll=unroll
    )
    y = rms_norm(y.reshape(B, T, d), p["norm"])
    return jnp.einsum("btd,de->bte", y, p["w_out"]), h_last


def mlstm_decode_step(x: jax.Array, state: jax.Array, p: dict, n_heads: int):
    """x (B,d); state (B,H,hd_v,hd_k)."""
    B, d = x.shape
    hd = d // n_heads
    q = (x @ p["w_q"]).reshape(B, n_heads, hd)
    k = (x @ p["w_k"]).reshape(B, n_heads, hd) * (hd ** -0.5)
    v = (x @ p["w_v"]).reshape(B, n_heads, hd)
    gates = x.astype(jnp.float32) @ p["w_gates"]
    i_g, f_g = jnp.split(gates, 2, axis=-1)                 # (B,H)
    f_s = jax.nn.sigmoid(f_g)
    i_s = jax.nn.sigmoid(i_g)
    upd = (i_s[..., None] * v.astype(jnp.float32))[..., None] * \
        k.astype(jnp.float32)[:, :, None, :]
    h = f_s[..., None, None] * state + upd                  # (B,H,hd,hd)
    y = (h * q.astype(jnp.float32)[:, :, None, :]).sum(-1)  # (B,H,hd)
    y = rms_norm(y.reshape(B, d).astype(x.dtype), p["norm"])
    return y @ p["w_out"], h


def init_slstm(key, d: int, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_x": init_dense(ks[0], d, 4 * d, dtype),
        "w_h": init_dense(ks[1], d, 4 * d, dtype),
        "norm": init_norm(d, dtype),
        "w_out": init_dense(ks[2], d, d, dtype),
    }


def slstm_forward(x: jax.Array, p: dict):
    """Scalar LSTM scanned over time. x (B,T,d)."""
    B, T, d = x.shape
    gx = jnp.einsum("btd,dg->btg", x, p["w_x"])             # (B,T,4d)

    def step(carry, gxt):
        c, n, h = carry
        g = gxt + h @ p["w_h"]
        i, f, z, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        z = jnp.tanh(z)
        c = f * c + i * z
        n = f * n + i
        h_new = (o * c / jnp.maximum(n, 1.0)).astype(gxt.dtype)
        return (c, n, h_new), h_new

    c0 = jnp.zeros((B, d), jnp.float32)
    n0 = jnp.zeros((B, d), jnp.float32)
    h0 = jnp.zeros((B, d), x.dtype)
    (c, n, h), ys = jax.lax.scan(step, (c0, n0, h0), jnp.moveaxis(gx, 1, 0))
    y = rms_norm(jnp.moveaxis(ys, 0, 1), p["norm"])
    return jnp.einsum("btd,de->bte", y, p["w_out"]), (c, n, h)


def slstm_decode_step(x: jax.Array, state, p: dict):
    c, n, h = state
    g = x @ p["w_x"] + h @ p["w_h"]
    i, f, z, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    z = jnp.tanh(z)
    c = f * c + i * z
    n = f * n + i
    h_new = (o * c / jnp.maximum(n, 1.0)).astype(x.dtype)
    y = rms_norm(h_new, p["norm"])
    return y @ p["w_out"], (c, n, h_new)

"""Transformer block assembly: GQA attention blocks, scan-over-layers LMs.

Everything is functional: `init_*` builds parameter pytrees (materialized
for smoke tests, `jax.eval_shape`'d for the dry-run), `*_forward` are pure.
Layers are stacked along a leading axis and applied with `jax.lax.scan`
(keeps the HLO one-block-sized at 61 layers) with optional block-level
remat. Activation sharding hints come from `repro.parallel.constrain`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import constrain, current_ctx

from .layers import (
    apply_rope,
    attention,
    decode_attention_xla,
    init_dense,
    init_mlp,
    init_norm,
    mlp,
    rms_norm,
    rope_cos_sin,
)
from .moe import init_moe, moe_ref, moe_sharded

__all__ = [
    "init_attn", "attn_forward", "attn_decode",
    "init_block", "block_forward",
    "scan_layers", "stacked_init",
]


# ---------------------------------------------------------------------------
# Attention sublayer
# ---------------------------------------------------------------------------

def init_attn(key, cfg, dtype=jnp.bfloat16, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    He = cfg.heads_eff
    ks = jax.random.split(key, 4)
    w_q = init_dense(ks[0], d, He * hd, dtype)
    w_o = init_dense(ks[3], He * hd, d, dtype)
    if He > cfg.n_heads:
        # padded heads: zero their projections so they are numerically inert
        w_q = w_q.at[:, cfg.n_heads * hd:].set(0)
        w_o = w_o.at[cfg.n_heads * hd:, :].set(0)
    p = {
        "w_q": w_q,
        "w_k": init_dense(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "w_v": init_dense(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "w_o": w_o,
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = init_norm(hd, dtype)
        p["k_norm"] = init_norm(hd, dtype)
    return p


def _qkv(x, p, cfg, kv_src=None):
    B, T, d = x.shape
    hd = cfg.hd
    kv_in = x if kv_src is None else kv_src
    q = jnp.einsum("btd,de->bte", x, p["w_q"]).reshape(B, T, cfg.heads_eff, hd)
    k = jnp.einsum("btd,de->bte", kv_in, p["w_k"]).reshape(
        B, kv_in.shape[1], cfg.n_kv_heads, hd
    )
    v = jnp.einsum("btd,de->bte", kv_in, p["w_v"]).reshape(
        B, kv_in.shape[1], cfg.n_kv_heads, hd
    )
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def attn_forward(
    x: jax.Array,
    p: dict,
    cfg,
    *,
    causal: bool = True,
    use_rope: bool = True,
    kv_src: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    B, T, d = x.shape
    q, k, v = _qkv(x, p, cfg, kv_src)
    if use_rope and kv_src is None:
        pos = positions if positions is not None else jnp.arange(T)[None, :]
        cos, sin = rope_cos_sin(pos, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    o = attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    o = o.reshape(B, T, cfg.heads_eff * cfg.hd)
    return jnp.einsum("bte,ed->btd", o, p["w_o"])


def attn_decode(
    x: jax.Array,          # (B, d) one token
    p: dict,
    cfg,
    k_cache: jax.Array,    # (B, S, Hkv, hd)
    v_cache: jax.Array,
    pos: jax.Array,        # (B,) write/attend position per sequence
    *,
    use_rope: bool = True,
):
    B, d = x.shape
    hd = cfg.hd
    q = (x @ p["w_q"]).reshape(B, cfg.heads_eff, hd)
    k = (x @ p["w_k"]).reshape(B, cfg.n_kv_heads, hd)
    v = (x @ p["w_v"]).reshape(B, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if use_rope:
        cos, sin = rope_cos_sin(pos, hd, cfg.rope_theta)  # (B, hd/2)
        q = apply_rope(q[:, None], cos[:, None], sin[:, None])[:, 0]
        k = apply_rope(k[:, None], cos[:, None], sin[:, None])[:, 0]
    k_cache = k_cache.at[jnp.arange(B), pos].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[jnp.arange(B), pos].set(v.astype(v_cache.dtype))
    o = decode_attention_xla(q, k_cache, v_cache, pos + 1)
    return (o.reshape(B, cfg.heads_eff * hd) @ p["w_o"]), k_cache, v_cache


# ---------------------------------------------------------------------------
# Dense / MoE decoder block
# ---------------------------------------------------------------------------

def init_block(key, cfg, dtype=jnp.bfloat16, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_norm(cfg.d_model, dtype),
        "attn": init_attn(ks[0], cfg, dtype),
        "ln2": init_norm(cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    if cross:
        p["ln_x"] = init_norm(cfg.d_model, dtype)
        p["xattn"] = init_attn(ks[2], cfg, dtype, cross=True)
    return p


def _ffn(x, p, cfg):
    if cfg.family == "moe":
        ctx = current_ctx()
        if ctx.active and ctx.axes("ep"):
            return moe_sharded(
                x, p["moe"], cfg, ctx.mesh,
                ep_axes=ctx.axes("ep"), tp_axis=ctx.axes("tp")[0],
            )
        return moe_ref(x, p["moe"], cfg)
    return mlp(x, p["mlp"], cfg.act)


def _res(x, cfg):
    axis = "tp" if getattr(cfg, "residual", "tp") == "tp" else None
    return constrain(x, "dp", None, axis)


def block_forward(
    x: jax.Array, p: dict, cfg, *, causal=True, use_rope=True, memory=None
) -> jax.Array:
    x = _res(x, cfg)
    h = attn_forward(rms_norm(x, p["ln1"]), p["attn"], cfg,
                     causal=causal, use_rope=use_rope)
    x = x + h
    if memory is not None and "xattn" in p:
        hx = attn_forward(
            rms_norm(x, p["ln_x"]), p["xattn"], cfg,
            causal=False, use_rope=False, kv_src=memory,
        )
        x = x + hx
    h = _ffn(rms_norm(x, p["ln2"]), p, cfg)
    x = x + h
    return _res(x, cfg)


# ---------------------------------------------------------------------------
# Layer stacking
# ---------------------------------------------------------------------------

def stacked_init(init_fn, key, n: int):
    """vmap an init over layer keys -> params stacked on a leading axis."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def scan_layers(x, stacked, body, remat: str = "block", extra_xs=None,
                unroll: bool = False):
    """Apply `body(h, per_layer_params, per_layer_xs)` over stacked layers."""
    fn = body
    if remat == "block":
        fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names()
        )
    elif remat == "dots":
        fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )

    def f(h, xs):
        layer_params, extra = xs
        return fn(h, layer_params, extra), None

    n_layers = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    extra = extra_xs if extra_xs is not None else jnp.arange(n_layers)
    out, _ = jax.lax.scan(
        f, x, (stacked, extra), unroll=n_layers if unroll else 1
    )
    return out

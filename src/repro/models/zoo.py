"""Family-level model assembly: init / forward / cache / decode per family.

Public API (used by launch, tests and benchmarks):

  init_params(cfg, key)              -> params pytree
  forward(params, batch, cfg)        -> logits (train / prefill)
  init_cache(cfg, batch, max_len)    -> decode cache pytree
  decode_step(params, cache, tokens, cfg) -> (logits, new cache)

`batch` is a dict: LM families use {"tokens"}; whisper {"frames", "tokens"};
internvl {"patches", "tokens"}. The modality frontends are stubs per the
assignment: frames/patches arrive as precomputed embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import constrain

from .config import ModelConfig
from .layers import init_dense, init_norm, rms_norm
from .moe import moe_ref
from .ssm import (
    init_mamba2, init_mlstm, init_slstm,
    mamba2_decode_step, mamba2_forward,
    mlstm_decode_step, mlstm_forward,
    slstm_decode_step, slstm_forward,
)
from .transformer import (
    attn_decode, attn_forward, block_forward, init_attn, init_block,
    scan_layers, stacked_init,
)

__all__ = ["init_params", "forward", "init_cache", "decode_step", "loss_fn"]

_DT = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array):
    dt = _DT[cfg.dtype]
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict = {
        "tok_emb": (jax.random.normal(ks[0], (cfg.vocab_size, d), jnp.float32)
                    * 0.02).astype(dt),
        "ln_f": init_norm(d, dt),
        "lm_head": init_dense(ks[1], d, cfg.vocab_size, dt),
    }
    if cfg.family in ("dense", "moe", "vlm"):
        p["blocks"] = stacked_init(
            lambda k: init_block(k, cfg, dt), ks[2], cfg.n_layers
        )
    elif cfg.family == "audio":
        p["enc_blocks"] = stacked_init(
            lambda k: init_block(k, cfg, dt), ks[2], cfg.encoder_layers
        )
        p["dec_blocks"] = stacked_init(
            lambda k: init_block(k, cfg, dt, cross=True), ks[3], cfg.n_layers
        )
        p["ln_enc"] = init_norm(d, dt)
    elif cfg.family == "ssm":  # xLSTM: alternating mLSTM / sLSTM pairs
        n_pairs = cfg.n_layers // 2
        def pair_init(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln_m": init_norm(d, dt), "mlstm": init_mlstm(k1, d, cfg.n_heads, dt),
                "ln_s": init_norm(d, dt), "slstm": init_slstm(k2, d, dt),
            }
        p["pairs"] = stacked_init(pair_init, ks[2], n_pairs)
    elif cfg.family == "hybrid":  # zamba2: mamba2 stack + one shared attn block
        def m_init(k):
            return {"ln": init_norm(d, dt), "mamba": init_mamba2(k, d, cfg, dt)}
        p["blocks"] = stacked_init(m_init, ks[2], cfg.n_layers)
        p["shared"] = {
            "ln": init_norm(d, dt),
            "attn": init_attn(ks[3], cfg, dt),
            "w_concat": init_dense(ks[4], 2 * d, d, dt),
        }
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(p, tokens, cfg):
    x = jnp.take(p["tok_emb"], tokens, axis=0)
    axis = "tp" if getattr(cfg, "residual", "tp") == "tp" else None
    return constrain(x, "dp", None, axis)


def _head(p, x, cfg):
    x = rms_norm(x, p["ln_f"])
    logits = jnp.einsum("btd,dv->btv", x, p["lm_head"])
    return constrain(logits, "dp", None, "tp")


def forward(params, batch: dict, cfg: ModelConfig):
    fam = cfg.family
    if fam in ("dense", "moe"):
        x = _embed(params, batch["tokens"], cfg)
        def body(h, pl, i):
            return block_forward(h, pl, cfg)

        x = scan_layers(x, params["blocks"], body, cfg.remat, unroll=cfg.probe)
        return _head(params, x, cfg)

    if fam == "vlm":
        x_txt = _embed(params, batch["tokens"], cfg)
        x = jnp.concatenate([batch["patches"].astype(x_txt.dtype), x_txt], axis=1)
        x = constrain(x, "dp", None, "tp")
        def body(h, pl, i):
            return block_forward(h, pl, cfg)

        x = scan_layers(x, params["blocks"], body, cfg.remat, unroll=cfg.probe)
        return _head(params, x, cfg)

    if fam == "audio":
        enc = constrain(batch["frames"].astype(_DT[cfg.dtype]), "dp", None, "tp")
        def enc_body(h, pl, i):
            return block_forward(h, pl, cfg, causal=False)

        enc = scan_layers(enc, params["enc_blocks"], enc_body, cfg.remat, unroll=cfg.probe)
        enc = rms_norm(enc, params["ln_enc"])
        x = _embed(params, batch["tokens"], cfg)
        def dec_body(h, pl, i):
            return block_forward(h, pl, cfg, memory=enc)

        x = scan_layers(x, params["dec_blocks"], dec_body, cfg.remat, unroll=cfg.probe)
        return _head(params, x, cfg)

    if fam == "ssm":
        x = _embed(params, batch["tokens"], cfg)

        def body(h, pl, i):
            h = h + mlstm_forward(rms_norm(h, pl["ln_m"]), pl["mlstm"], cfg.n_heads,
                                  chunk=cfg.ssd_chunk, unroll=cfg.probe)[0]
            h = h + slstm_forward(rms_norm(h, pl["ln_s"]), pl["slstm"])[0]
            axis = "tp" if cfg.residual == "tp" else None
            return constrain(h, "dp", None, axis)

        x = scan_layers(x, params["pairs"], body, cfg.remat, unroll=cfg.probe)
        return _head(params, x, cfg)

    if fam == "hybrid":
        x = _embed(params, batch["tokens"], cfg)
        emb0 = x
        shared = params["shared"]
        every = cfg.shared_attn_every

        def body(h, pl, i):
            def with_attn(h):
                a_in = jnp.concatenate([h, emb0], axis=-1) @ shared["w_concat"]
                a = attn_forward(rms_norm(a_in, shared["ln"]), shared["attn"], cfg)
                return h + a
            h = jax.lax.cond(i % every == 0, with_attn, lambda h: h, h)
            h = h + mamba2_forward(rms_norm(h, pl["ln"]), pl["mamba"], cfg)[0]
            axis = "tp" if cfg.residual == "tp" else None
            return constrain(h, "dp", None, axis)

        x = scan_layers(x, params["blocks"], body, cfg.remat, unroll=cfg.probe)
        return _head(params, x, cfg)

    raise ValueError(fam)


def loss_fn(params, batch: dict, cfg: ModelConfig):
    """Mean next-token cross-entropy (sharded-vocab-safe: no full gather)."""
    logits = forward(params, batch, cfg).astype(jnp.float32)
    targets = batch["targets"]
    if cfg.family == "vlm":  # loss only over the text tail
        logits = logits[:, -targets.shape[1]:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.sum(logits * onehot, axis=-1)
    mask = (targets >= 0).astype(jnp.float32)
    return jnp.sum((lse - picked) * mask) / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# decode: caches + single-token step
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = _DT[cfg.dtype]
    hd = cfg.hd
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    if fam == "audio":
        mem_len = min(max_len, 1500)
        return {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
            "xk": jnp.zeros((cfg.n_layers, batch, mem_len, cfg.n_kv_heads, hd), dt),
            "xv": jnp.zeros((cfg.n_layers, batch, mem_len, cfg.n_kv_heads, hd), dt),
            "mem_len": jnp.full((batch,), mem_len, jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    if fam == "ssm":
        n_pairs = cfg.n_layers // 2
        d = cfg.d_model
        hd_m = d // cfg.n_heads
        return {
            "mlstm": jnp.zeros((n_pairs, batch, cfg.n_heads, hd_m, hd_m), jnp.float32),
            "slstm_c": jnp.zeros((n_pairs, batch, d), jnp.float32),
            "slstm_n": jnp.zeros((n_pairs, batch, d), jnp.float32),
            "slstm_h": jnp.zeros((n_pairs, batch, d), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    if fam == "hybrid":
        from .ssm import _CONV_K, _HEAD_P, _mamba_dims

        di, H, S = _mamba_dims(cfg.d_model, cfg)
        n_app = int(np.ceil(cfg.n_layers / cfg.shared_attn_every))
        return {
            "ssm": jnp.zeros((cfg.n_layers, batch, H, _HEAD_P, S), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, _CONV_K - 1, di + 2 * S), dt),
            "attn_k": jnp.zeros((n_app, batch, max_len, cfg.n_kv_heads, hd), dt),
            "attn_v": jnp.zeros((n_app, batch, max_len, cfg.n_kv_heads, hd), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    raise ValueError(fam)


def decode_step(params, cache, tokens: jax.Array, cfg: ModelConfig):
    """One decode step. tokens (B,) int32 -> (logits (B, V), new cache)."""
    fam = cfg.family
    pos = cache["pos"]
    x = jnp.take(params["tok_emb"], tokens, axis=0)  # (B, d)
    x = constrain(x, "dp", "tp")

    if fam in ("dense", "moe", "vlm"):
        def body(h, xs):
            pl, kc, vc = xs
            a, kc, vc = attn_decode(
                rms_norm(h, pl["ln1"]), pl["attn"], cfg, kc, vc, pos
            )
            h = h + a
            if cfg.family == "moe":
                f = moe_ref(rms_norm(h, pl["ln2"])[:, None, :], pl["moe"], cfg)[:, 0]
            else:
                from .layers import mlp
                f = mlp(rms_norm(h, pl["ln2"])[:, None, :], pl["mlp"], cfg.act)[:, 0]
            return h + f, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]),
            unroll=cfg.n_layers if cfg.probe else 1,
        )
        new_cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)

    elif fam == "audio":
        from .layers import decode_attention_xla

        def body(h, xs):
            pl, kc, vc, xk, xv = xs
            a, kc, vc = attn_decode(
                rms_norm(h, pl["ln1"]), pl["attn"], cfg, kc, vc, pos, use_rope=True
            )
            h = h + a
            # cross attention against the (precomputed) encoder memory
            hd = cfg.hd
            B = h.shape[0]
            qx = (rms_norm(h, pl["ln_x"]) @ pl["xattn"]["w_q"]).reshape(
                B, cfg.heads_eff, hd
            )
            ax = decode_attention_xla(qx, xk, xv, cache["mem_len"])
            h = h + ax.reshape(B, cfg.heads_eff * hd) @ pl["xattn"]["w_o"]
            from .layers import mlp
            f = mlp(rms_norm(h, pl["ln2"])[:, None, :], pl["mlp"], cfg.act)[:, 0]
            return h + f, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x,
            (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
            unroll=cfg.n_layers if cfg.probe else 1,
        )
        new_cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)

    elif fam == "ssm":
        def body(h, xs):
            pl, m_st, c_st, n_st, h_st = xs
            y, m_new = mlstm_decode_step(
                rms_norm(h, pl["ln_m"]), m_st, pl["mlstm"], cfg.n_heads
            )
            h = h + y
            y, (c2, n2, h2) = slstm_decode_step(
                rms_norm(h, pl["ln_s"]), (c_st, n_st, h_st), pl["slstm"]
            )
            return h + y, (m_new, c2, n2, h2)

        x, (m_new, c_new, n_new, h_new) = jax.lax.scan(
            body, x,
            (params["pairs"], cache["mlstm"], cache["slstm_c"],
             cache["slstm_n"], cache["slstm_h"]),
            unroll=(cfg.n_layers // 2) if cfg.probe else 1,
        )
        new_cache = dict(
            cache, mlstm=m_new, slstm_c=c_new, slstm_n=n_new, slstm_h=h_new,
            pos=pos + 1,
        )

    elif fam == "hybrid":
        # Python-unrolled: the shared-attn KV cache has one slot per
        # *application point* (L/every slots, statically indexed), not per
        # layer — 38 copies of a 32k cache would be a 5× memory regression.
        shared = params["shared"]
        every = cfg.shared_attn_every
        emb0 = x  # zamba2 concat-skip uses the original embedding
        ssm_new, conv_new, ak_new, av_new = [], [], [], []
        h = x
        for i in range(cfg.n_layers):
            pl = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            if i % every == 0:
                slot = i // every
                a_in = jnp.concatenate([h, emb0], axis=-1) @ shared["w_concat"]
                a, ak, av = attn_decode(
                    rms_norm(a_in, shared["ln"]), shared["attn"], cfg,
                    cache["attn_k"][slot], cache["attn_v"][slot], pos,
                )
                h = h + a
                ak_new.append(ak)
                av_new.append(av)
            y, st = mamba2_decode_step(
                rms_norm(h, pl["ln"]),
                {"ssm": cache["ssm"][i], "conv": cache["conv"][i]},
                pl["mamba"], cfg,
            )
            h = h + y
            ssm_new.append(st["ssm"])
            conv_new.append(st["conv"])
        x = h
        new_cache = dict(
            cache,
            ssm=jnp.stack(ssm_new), conv=jnp.stack(conv_new),
            attn_k=jnp.stack(ak_new), attn_v=jnp.stack(av_new), pos=pos + 1,
        )
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["ln_f"])
    logits = x @ params["lm_head"]
    return constrain(logits, "dp", "tp"), new_cache

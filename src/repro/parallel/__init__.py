"""Distribution layer: logical-axis sharding rules and parallel context."""
from .sharding import (
    ParallelCtx,
    constrain,
    current_ctx,
    maybe_axis,
    param_pspecs,
    parallel_ctx,
)

__all__ = [
    "ParallelCtx",
    "constrain",
    "current_ctx",
    "maybe_axis",
    "param_pspecs",
    "parallel_ctx",
]

"""Collective-communication helpers: hierarchical reductions, compression.

`hierarchical_psum` — two-phase gradient reduction for multi-pod meshes:
reduce-scatter inside the pod (fast ICI), all-reduce of the 1/N-sized shards
across pods (slow DCN), all-gather back inside the pod. Cuts cross-pod
traffic by the intra-pod world size — the standard topology-aware schedule
for 1000+ node jobs.

`compressed_pod_psum` — optional int8 gradient compression for the
cross-pod hop (per-tensor absmax scaling): trades ~0.4% gradient SNR for 4×
less DCN traffic. Used by the trainer when `--compress-pods` is set; error
feedback is left to the caller (documented limitation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["hierarchical_psum", "compressed_pod_psum", "int8_encode", "int8_decode"]


def int8_encode(x: jax.Array):
    absmax = jnp.max(jnp.abs(x)) + 1e-12
    q = jnp.clip(jnp.round(x / absmax * 127.0), -127, 127).astype(jnp.int8)
    return q, absmax


def int8_decode(q: jax.Array, absmax: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * (absmax / 127.0)).astype(dtype)


def hierarchical_psum(x: jax.Array, pod_axis: str, inner_axis: str) -> jax.Array:
    """psum over (pod, inner) with pod-traffic = 1/|inner| of the naive AR.

    Must run inside shard_map with both axes present.
    """
    # phase 1: reduce-scatter within the pod (shards the tensor 1/N)
    shard = jax.lax.psum_scatter(x, inner_axis, scatter_dimension=0, tiled=True)
    # phase 2: small all-reduce across pods
    shard = jax.lax.psum(shard, pod_axis)
    # phase 3: all-gather within the pod
    return jax.lax.all_gather(shard, inner_axis, axis=0, tiled=True)


def compressed_pod_psum(x: jax.Array, pod_axis: str, inner_axis: str) -> jax.Array:
    """Hierarchical psum with int8-compressed cross-pod traffic."""
    shard = jax.lax.psum_scatter(x, inner_axis, scatter_dimension=0, tiled=True)
    q, absmax = int8_encode(shard)
    # all-gather int8 shards + scales across pods, decode, sum locally
    qs = jax.lax.all_gather(q, pod_axis)            # (pods, ...)
    scales = jax.lax.all_gather(absmax, pod_axis)   # (pods,)
    dec = jax.vmap(int8_decode)(qs, scales)
    shard = jnp.sum(dec, axis=0).astype(x.dtype)
    return jax.lax.all_gather(shard, inner_axis, axis=0, tiled=True)

"""Logical-axis sharding: rules, divisibility-aware mapping, param specs.

Logical axes:
  dp  — data parallel      -> ("pod", "data") when multi-pod, else ("data",)
  tp  — tensor parallel    -> ("model",)
  ep  — expert parallel    -> same mesh axes as dp (experts across pods+data)
  sp  — sequence parallel  -> ("model",) (KV-cache sequence sharding, decode)

Mapping is *divisibility-aware*: if a dimension doesn't divide the mesh axis
size (e.g. 8 KV heads on a 16-wide model axis), the axis is dropped for that
dimension and the tensor is replicated along it instead of erroring — the
rule that makes one config system serve all 10 architectures.

Parameter PartitionSpecs are derived from pytree paths by `param_pspecs`
(rules keyed on leaf names, validated against leaf shapes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ParallelCtx",
    "parallel_ctx",
    "current_ctx",
    "constrain",
    "maybe_axis",
    "param_pspecs",
]

_STATE = threading.local()


@dataclasses.dataclass
class ParallelCtx:
    mesh: Optional[Mesh]
    rules: dict

    @property
    def active(self) -> bool:
        return self.mesh is not None and np.prod(list(self.mesh.shape.values())) > 1

    def axes(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.rules.get(logical)

    def axis_size(self, logical: str) -> int:
        axes = self.rules.get(logical)
        if not axes or self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def default_rules(mesh: Optional[Mesh]) -> dict:
    if mesh is None:
        return {}
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    tp = ("model",) if "model" in names else ()
    return {"dp": dp, "tp": tp, "ep": dp, "sp": tp}


@contextlib.contextmanager
def parallel_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ParallelCtx(mesh, rules or default_rules(mesh))
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def current_ctx() -> ParallelCtx:
    ctx = getattr(_STATE, "ctx", None)
    return ctx if ctx is not None else ParallelCtx(None, {})


def maybe_axis(ctx: ParallelCtx, logical: Optional[str], dim: int):
    """Mesh axes for `logical` if `dim` divides their product, else None."""
    axes = ctx.axes(logical)
    if not axes:
        return None
    size = int(np.prod([ctx.mesh.shape[a] for a in axes]))
    if size <= 1 or dim % size != 0:
        # try a prefix of the axes (e.g. ("pod","data") -> ("pod",))
        for cut in range(len(axes) - 1, 0, -1):
            sub = axes[:cut]
            s = int(np.prod([ctx.mesh.shape[a] for a in sub]))
            if s > 1 and dim % s == 0:
                return sub
        return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    ctx = current_ctx()
    if not ctx.active:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = P(*[maybe_axis(ctx, ax, d) for ax, d in zip(logical, x.shape)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs from pytree paths
# ---------------------------------------------------------------------------

# leaf-name -> logical axes, aligned to the LAST ndim of the leaf
# (leading layer-stack axes are replicated). None = replicated dim.
_PARAM_RULES: dict[str, tuple] = {
    "tok_emb": ("tp", None),          # (V, d) vocab-sharded
    "pos_emb": (None, None),
    "lm_head": (None, "tp"),          # (d, V)
    "w_q": (None, "tp"),
    "w_k": (None, "tp"),
    "w_v": (None, "tp"),
    "w_o": ("tp", None),
    "w_gate": (None, "tp"),
    "w_up": (None, "tp"),
    "w_down": ("tp", None),
    "w_router": ("tp", None),
    # MoE experts: (E, d, F) / (E, F, d) — E over ep, contraction over tp
    "moe_w_gate": ("ep", "tp", None),
    "moe_w_up": ("ep", "tp", None),
    "moe_w_down": ("ep", "tp", None),
    # mamba / xlstm
    "w_in": (None, "tp"),
    "w_out": ("tp", None),
    "conv_w": (None, "tp"),
    "A_log": ("tp",),
    "D": ("tp",),
    "dt_bias": ("tp",),
    "w_gates": (None, "tp"),
    "w_x": (None, "tp"),
    "w_h": (None, "tp"),
    # concat-skip projections (hybrid)
    "w_concat": (None, None),
}


def _leaf_name(path) -> str:
    parts = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    return str(parts[-1]), parts


def param_pspecs(params_tree, ctx: Optional[ParallelCtx] = None):
    """PartitionSpec pytree for a parameter pytree (shape-validated)."""
    ctx = ctx or current_ctx()

    def spec_for(path, leaf):
        name, parts = _leaf_name(path)
        # expert weights are nested under a 'moe' / 'experts' key
        # (shared experts are plain MLPs — plain rules)
        in_moe = any(str(p) in ("moe", "experts") for p in parts) and not any(
            str(p) == "shared" for p in parts
        )
        key = f"moe_{name}" if in_moe and f"moe_{name}" in _PARAM_RULES else name
        rule = _PARAM_RULES.get(key)
        if rule is None or ctx.mesh is None:
            return P()
        shape = leaf.shape
        ndim = len(shape)
        k = len(rule)
        logical = (None,) * (ndim - k) + tuple(rule) if ndim >= k else rule[-ndim:]
        return P(*[maybe_axis(ctx, ax, d) for ax, d in zip(logical, shape)])

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)

"""Serving substrate.

Two serving stacks live here:

- LM serving steps (`serve_step`): prefill (full-sequence forward) and
  per-token decode against the KV cache — consumed by `launch.specs` when
  assembling decode-shape cells.
- the streaming traffic runtime (`runtime/`): online flow table with
  vectorized block ingest (`observe_batch`), micro-batched shape-bucketed
  dispatch staged in preallocated arenas, and offered-load replay with
  zero-loss throughput measurement — the continuous-serving layer over the
  jit-specialized CATO pipelines, fused single-launch by default
  (DESIGN.md §6, §7), horizontally sharded behind RSS-style steering
  (§8) with an adaptive control plane (`control/`, §9): dynamic RETA
  rebalancing, zero-downtime pipeline hot-swap, elastic worker sizing —
  plus the compile-to-deploy layer (`deploy.py`, §10.4) that turns an
  optimized Pareto front into warmed pipelines, a serializable
  `ParetoBundle`, and a live hot-swap into the fleet.

The runtime/control re-exports resolve lazily (PEP 562): `from repro.serve
import make_serve_step` must not drag in the traffic/extraction stack, and
the traffic package must stay importable without touching this one.
"""
from .serve_step import make_serve_step, make_prefill

_RUNTIME_EXPORTS = (
    "BatchRecord",
    "FlowStatus",
    "FlowTable",
    "LatencyHistogram",
    "MicroBatchDispatcher",
    "PacketStream",
    "ReplayStats",
    "RuntimeMetrics",
    "ServiceModel",
    "ShardedRuntime",
    "StreamingRuntime",
    "find_zero_loss_rate",
    "replay",
    "tuple_hash64",
)

_CONTROL_EXPORTS = (
    "BucketTelemetry",
    "ControlConfig",
    "ControlPlane",
    "HeadroomPolicy",
    "PipelineSwap",
    "controlled_replay",
)

# compile-to-deploy layer (DESIGN.md §10.4): CatoResult front ->
# warmed pipelines -> serializable ParetoBundle -> live hot-swap
_DEPLOY_EXPORTS = (
    "BundlePoint",
    "ParetoBundle",
    "compile_front",
    "deploy",
    "make_swap",
)

# unified serving observability (DESIGN.md §11): fleet-wide metrics
# registry, flow/stage span tracing on the replay clock, control-plane
# audit log, online drift signals
_OBS_EXPORTS = (
    "AuditLog",
    "DriftMonitor",
    "MetricsRegistry",
    "Observability",
    "Tracer",
    "fleet_registry",
)

__all__ = ["make_serve_step", "make_prefill", *_RUNTIME_EXPORTS,
           *_CONTROL_EXPORTS, *_DEPLOY_EXPORTS, *_OBS_EXPORTS]


def __getattr__(name):
    if name in _RUNTIME_EXPORTS:
        from . import runtime

        return getattr(runtime, name)
    if name in _CONTROL_EXPORTS:
        from . import control

        return getattr(control, name)
    if name in _DEPLOY_EXPORTS:
        from . import deploy

        return getattr(deploy, name)
    if name in _OBS_EXPORTS:
        from . import obs

        return getattr(obs, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

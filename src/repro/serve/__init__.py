"""Serving substrate.

Two serving stacks live here:

- LM serving steps (`serve_step`): prefill (full-sequence forward) and
  per-token decode against the KV cache — consumed by `launch.specs` when
  assembling decode-shape cells.
- the streaming traffic runtime (`runtime/`): online flow table with
  vectorized block ingest (`observe_batch`), micro-batched shape-bucketed
  dispatch staged in preallocated arenas, and offered-load replay with
  zero-loss throughput measurement — the continuous-serving layer over the
  jit-specialized CATO pipelines, fused single-launch by default
  (DESIGN.md §6, §7), horizontally sharded behind RSS-style steering
  (§8) with an adaptive control plane (`control/`, §9): dynamic RETA
  rebalancing, zero-downtime pipeline hot-swap, elastic worker sizing —
  plus the compile-to-deploy layer (`deploy.py`, §10.4) that turns an
  optimized Pareto front into warmed pipelines, a serializable
  `ParetoBundle`, and a live hot-swap into the fleet, and the
  drift-triggered re-optimization policy (`control/reoptimizer.py`,
  §13) that closes the measure → optimize → compile → deploy → adapt
  loop autonomously.

This module is the **public serving namespace**: everything a serving
consumer (examples, benchmarks, downstream users) needs is re-exported
here, threaded through one attachment carrier (`ServeSession`) — reach
into submodules only for internals. The re-exports resolve lazily
(PEP 562): `from repro.serve import make_serve_step` must not drag in
the traffic/extraction stack, and the traffic package must stay
importable without touching this one.
"""
from .serve_step import make_serve_step, make_prefill

_SESSION_EXPORTS = (
    "ServeSession",
)

_RUNTIME_EXPORTS = (
    "BatchRecord",
    "FlowStatus",
    "FlowTable",
    "LatencyHistogram",
    "MicroBatchDispatcher",
    "MultiTenantPipeline",
    "PacketStream",
    "ReplayStats",
    "ReuseConfig",
    "RuntimeMetrics",
    "ServiceModel",
    "ShardedRuntime",
    "StreamingRuntime",
    "build_multi_tenant_pipeline",
    "find_zero_loss_rate",
    "replay",
    "tuple_hash64",
)

_CONTROL_EXPORTS = (
    "BucketTelemetry",
    "ControlConfig",
    "ControlPlane",
    "HeadroomPolicy",
    "PipelineSwap",
    "ReoptOutcome",
    "ReoptimizerConfig",
    "ReoptimizerPolicy",
    "cato_retuner",
    "controlled_replay",
)

# compile-to-deploy layer (DESIGN.md §10.4): CatoResult front ->
# warmed pipelines -> serializable ParetoBundle -> live hot-swap
_DEPLOY_EXPORTS = (
    "BundlePoint",
    "MultiTenantBundlePoint",
    "ParetoBundle",
    "compile_front",
    "compile_multi_tenant",
    "deploy",
    "make_swap",
    "warm_buckets_for",
)

# unified serving observability (DESIGN.md §11, §14): fleet-wide metrics
# registry, flow/stage span tracing on the replay clock, control-plane
# audit log, online drift signals, per-component latency sketches,
# windowed SLO burn-rate tracking, Prometheus/JSONL export
_OBS_EXPORTS = (
    "AuditLog",
    "DriftMonitor",
    "DriftVerdict",
    "LatencyConfig",
    "LatencyRecorder",
    "LatencySketch",
    "MetricsExporter",
    "MetricsRegistry",
    "Observability",
    "SLOConfig",
    "SLOTracker",
    "SLOVerdict",
    "Tracer",
    "check_prometheus",
    "fleet_registry",
    "render_prometheus",
)

__all__ = sorted(["make_serve_step", "make_prefill", *_SESSION_EXPORTS,
                  *_RUNTIME_EXPORTS, *_CONTROL_EXPORTS, *_DEPLOY_EXPORTS,
                  *_OBS_EXPORTS])


_EXPORT_HOMES = {
    **{n: "session" for n in _SESSION_EXPORTS},
    **{n: "runtime" for n in _RUNTIME_EXPORTS},
    **{n: "control" for n in _CONTROL_EXPORTS},
    **{n: "deploy" for n in _DEPLOY_EXPORTS},
    **{n: "obs" for n in _OBS_EXPORTS},
}


def __getattr__(name):
    # importlib (not ``from . import x``): an export sharing its
    # submodule's name (``deploy``) would recurse through the
    # fromlist's hasattr probe otherwise
    home = _EXPORT_HOMES.get(name)
    if home is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f"{__name__}.{home}"), name)


def __dir__():
    return sorted(set(globals()) | set(__all__))


# The ``deploy`` *function* shares its submodule's name. Whenever any
# import touches the ``repro.serve.deploy`` submodule, the import system
# binds that submodule as an attribute of this package — which would
# shadow the lazy export and make ``from repro.serve import deploy``
# yield the module. Bind the function eagerly; the ``from`` rebind runs
# after the submodule's setattr, so the function wins and stays won.
from .deploy import deploy as deploy  # noqa: E402

"""Adaptive serving control plane (DESIGN.md §9).

Closes the loop between runtime telemetry and runtime configuration for
the sharded serving fleet:

- **telemetry** (`BucketTelemetry`): per-RETA-bucket EWMA load, fed by
  the steered ingest path at one vector op per block;
- **planning** (`plan_rebalance`, `plan_retirement`, `HeadroomPolicy`):
  pure functions from telemetry to indirection rewrites and fleet sizes;
- **actuation** (`ControlPlane` + the runtime's `migrate_buckets` /
  `hot_swap`): quiescent flow-state migration so rewritten RETA entries
  never misroute a mid-flight flow, and per-shard drain-and-swap so a
  new Pareto-optimal (F, n) pipeline deploys with zero drops;
- **measurement** (`controlled_replay`): the offered-load replay driver
  for the adaptive fleet — interleaved per-shard clocks, control steps
  between blocks, zero-loss bisection compatible;
- **re-optimization** (`ReoptimizerPolicy` + `cato_retuner`): the
  drift-triggered episode state machine (DESIGN.md §13) that closes the
  outer loop — drift excursion → budgeted shadow re-tune → audited
  hot-swap through the same `schedule_swap` path as operator deploys.

The invariant every piece preserves: control actions permute *where* and
*when* work happens, never *what* is predicted — flows that complete
under a single pipeline configuration classify bit-identically to an
oracle single-worker run (tests/test_control.py).
"""
from .plane import ControlConfig, ControlPlane, PipelineSwap, StepReport
from .planner import HeadroomPolicy, plan_rebalance, plan_retirement
from .reoptimizer import (
    ReoptimizerConfig,
    ReoptimizerPolicy,
    ReoptOutcome,
    cato_retuner,
)
from .replay import controlled_replay
from .telemetry import BucketTelemetry

__all__ = [
    "BucketTelemetry",
    "ControlConfig",
    "ControlPlane",
    "HeadroomPolicy",
    "PipelineSwap",
    "ReoptOutcome",
    "ReoptimizerConfig",
    "ReoptimizerPolicy",
    "StepReport",
    "cato_retuner",
    "controlled_replay",
    "plan_rebalance",
    "plan_retirement",
]

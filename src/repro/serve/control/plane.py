"""The serving control plane: telemetry -> planner -> actuation
(DESIGN.md §9).

`ControlPlane` closes the loop the static `ShardedRuntime` leaves open:
it watches per-RETA-bucket load (`BucketTelemetry`), and every
`interval_pkts` ingested packets it may

1. **hot-swap** the pipeline (a scheduled `PipelineSwap` — e.g. a new
   Pareto-optimal (F, n) from `CatoOptimizer` compiled in the
   background) via the per-shard drain-and-swap protocol;
2. **resize the fleet** under a `HeadroomPolicy` (add workers when the
   offered load crowds the utilization target, retire the coldest one —
   after migrating its buckets away — when the load would comfortably
   fit on fewer);
3. **rebalance the RETA** (greedy bucket-migration plan, applied through
   the quiescent flow-state migration protocol so no flow is lost,
   double-predicted, or misrouted mid-flow).

The plane is clock-agnostic: it mutates the runtime and returns a
`StepReport` describing what happened; the replay driver (or a live
serving loop) interprets the report — charging flush records and
migration costs to the right worker's lanes, retargeting service
constants after a swap. Control cadence is counted in *packets*, not
seconds, so decisions are invariant under replay clock compression and
zero-loss bisection probes stay comparable across offered rates.

**The clock argument (`now_pkts`) — canonical definition.** Every time
value crossing the control surface (`maybe_step`, audit events, tracer
instants, `deploy`) is the *replay packet clock*: virtual time, in
seconds at the offered rate, advanced only by packet deliveries — never
wall time. The name carries the provenance (the packet stream drives
it), the unit stays seconds so durations and rates divide out naturally.
Workers' internal lane clocks (`dispatch.py`, `flow_table.py`) keep
their own `now` — they never cross this surface. Under a `ReoptimizerPolicy`
(`reoptimizer.py`) the plane also closes the adaptation loop: after its
own actuations each step, it lets the policy threshold the run's drift
signal, and a fired episode schedules its re-optimized pipeline through
`schedule_swap` — so autonomous re-deployments ride the same audited,
packet-counted swap path as operator-scheduled ones.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serve.runtime.dispatch import BatchRecord
from repro.serve.runtime.replay import ServiceModel
from repro.serve.runtime.shard import ShardedRuntime

from .planner import HeadroomPolicy, plan_rebalance, plan_retirement
from .telemetry import BucketTelemetry

__all__ = ["ControlConfig", "ControlPlane", "PipelineSwap", "StepReport"]


@dataclasses.dataclass
class PipelineSwap:
    """A scheduled zero-downtime pipeline replacement.

    `pipeline` is the new compiled artifact (warm it with
    `ServingPipeline.warm` so the swap never pays a compile on the
    serving path); `service` carries the replay-clock constants of the
    new configuration (its feature set and depth change both per-packet
    and per-batch costs); `after_pkts` triggers the swap once the fleet
    has ingested that many packets."""

    pipeline: object
    service: ServiceModel
    after_pkts: int = 0

    @classmethod
    def build(
        cls,
        rep,
        forest,
        *,
        after_pkts: int = 0,
        service: Optional[ServiceModel] = None,
        fused: bool = True,
        use_kernel: bool = True,
        runtime=None,
        warm_buckets: Optional[tuple] = None,
    ) -> "PipelineSwap":
        """Optimizer handoff: turn a Pareto-optimal (F, n) into a ready
        swap.

        `rep`/`forest` come straight from a `CatoOptimizer` observation
        (`o.x` and the profiler's trained model for it); this compiles
        the serving pipeline, pre-warms every dispatch bucket so the
        swap pays no jit on the serving path, and derives modeled clock
        constants unless measured ones are supplied. Pass the target
        `runtime` (sharded or single) so the warm set is *its*
        dispatcher's actual bucket geometry — a hard-coded default
        would leave a non-default `max_batch`/`min_bucket` fleet paying
        a compile on the serving path at swap time."""
        from repro.serve.deploy import warm_buckets_for
        from repro.traffic.pipeline import build_pipeline

        if warm_buckets is None:
            warm_buckets = warm_buckets_for(runtime)
        pipeline = build_pipeline(rep, forest, max_pkts=rep.depth,
                                  fused=fused, use_kernel=use_kernel)
        pipeline.warm(list(warm_buckets))
        if service is None:
            service = ServiceModel.modeled(rep, forest)
        return cls(pipeline=pipeline, service=service, after_pkts=after_pkts)


@dataclasses.dataclass
class ControlConfig:
    """Knobs for one control loop instance."""

    interval_pkts: int = 1024          # control period, in ingested packets
    ewma_alpha: float = 0.4            # telemetry smoothing
    rebalance: bool = True
    imbalance_trigger: float = 1.10    # act when max/mean EWMA load above this
    max_moves_per_step: int = 8
    # state-copy cost charged per migrated flow, in accumulated-packet
    # service-time equivalents: a flow's dense state is one ~KB row copy
    # plus two index updates — about what one packet accumulate costs
    # (which includes its own hash probe and row write). Scaling by the
    # service model keeps the charge honest under both modeled (ns-scale)
    # and measured (µs-scale) clock constants.
    migrate_cost_pkts: float = 1.0
    headroom: Optional[HeadroomPolicy] = None
    swap: Optional[PipelineSwap] = None


@dataclasses.dataclass
class StepReport:
    """What one control step did — the driver's charging manifest."""

    t: float
    records: dict[int, list[BatchRecord]] = dataclasses.field(
        default_factory=dict)
    ingest_charge_s: dict[int, float] = dataclasses.field(default_factory=dict)
    service_switch: dict[int, ServiceModel] = dataclasses.field(
        default_factory=dict)
    buckets_moved: int = 0
    flows_migrated: int = 0
    swapped: bool = False
    workers_added: list[int] = dataclasses.field(default_factory=list)
    workers_retired: list[int] = dataclasses.field(default_factory=list)


class ControlPlane:
    def __init__(
        self,
        runtime: ShardedRuntime,
        config: ControlConfig,
        service: ServiceModel,
        *,
        audit=None,
        tracer=None,
        session=None,
    ):
        from repro.serve.session import ServeSession

        session = ServeSession.coerce(session, audit=audit, tracer=tracer,
                                      warn=False)
        self.rt = runtime
        self.cfg = config
        self.service = service  # current constants (retargeted on swap)
        self.telemetry = BucketTelemetry(alpha=config.ewma_alpha)
        # decision audit log (DESIGN.md §11.3): every actuation below is
        # recorded with its rationale and before/after load snapshot; an
        # external Observability bundle (via the session) passes its own
        # log in so one run yields one audit stream
        audit = session.resolve_audit()
        if audit is None:
            from repro.serve.obs.audit import AuditLog

            audit = AuditLog()
        self.audit = audit
        self.tracer = session.tracer
        # drift-triggered re-optimization (DESIGN.md §13): the policy is
        # reset per plane (one plane = one run), bound to the session's
        # drift monitor — the same sketches the dispatchers feed
        self.reopt = session.reopt
        if self.reopt is not None:
            self.reopt.reset(drift=session.drift)
        # SLO verdicts + export (DESIGN.md §14): the shared tracker the
        # worker clocks feed is *checked* here at control-step cadence —
        # breach edges are audited (kind "slo") and the verdict gauges
        # published through the telemetry registry; a bound exporter
        # appends one JSONL record per executed step
        self.slo = session.slo
        self.n_slo_breaches = 0
        self.exporter = session.exporter
        if self.exporter is not None:
            self.exporter.bind(self._export_registry, slo=self.slo)
        self._pending_swap: Optional[PipelineSwap] = config.swap
        self._pkts_since = 0
        self._last_step_t: Optional[float] = None
        self._pps_ewma = 0.0
        # counters for the run summary
        self.n_steps = 0
        self.n_rebalances = 0
        self.buckets_moved = 0
        self.flows_migrated = 0
        self.buckets_skipped = 0
        self.n_swaps = 0
        # packets ingested fleet-wide when the scheduled swap actually
        # fired (control steps run on block cadence, so this is >= the
        # requested after_pkts): callers checking post-swap invariants
        # need the real boundary, not the requested one
        self.swap_at_pkts: Optional[int] = None
        self.workers_added = 0
        self.workers_retired = 0
        self.log: list[dict] = []

    # -- data-path hooks -----------------------------------------------------

    def note(self, keys: np.ndarray, buckets: np.ndarray) -> None:
        """Account one ingest block: steering ledger + bucket telemetry."""
        self.rt.note_steering(keys, buckets)
        self.telemetry.note(buckets)
        self._pkts_since += len(buckets)

    def schedule_swap(self, swap: PipelineSwap) -> None:
        """Arm a pipeline swap to fire once the fleet's ingested-packet
        count reaches ``swap.after_pkts`` (checked on control-step
        cadence, so the actual fire point lands on the next step
        boundary at or after it). One swap may be pending at a time —
        the plane refuses to silently drop an armed deployment."""
        if self._pending_swap is not None:
            raise RuntimeError(
                "a pipeline swap is already pending (after_pkts="
                f"{self._pending_swap.after_pkts}); the armed deployment "
                "must fire or be cleared before another is scheduled")
        self._pending_swap = swap

    def maybe_step(self, now_pkts: float) -> Optional[StepReport]:
        """Run a control step if a full interval of packets has arrived.

        `now_pkts` is the replay packet clock (module docstring) — the
        virtual time of the block edge that completed the interval."""
        if self._pkts_since < self.cfg.interval_pkts:
            return None
        cfg = self.cfg
        rt = self.rt
        window_pkts = self._pkts_since
        rates = self.telemetry.roll()
        self._pkts_since = 0
        report = StepReport(t=now_pkts)
        self.n_steps += 1

        # offered-rate estimate for the headroom policy (EWMA of pps over
        # the interval wall time; first step has no baseline interval)
        if self._last_step_t is not None and now_pkts > self._last_step_t:
            win_pps = window_pkts / (now_pkts - self._last_step_t)
            self._pps_ewma = (cfg.ewma_alpha * win_pps
                              + (1 - cfg.ewma_alpha) * self._pps_ewma
                              if self._pps_ewma > 0 else win_pps)
        self._last_step_t = now_pkts

        # 1. pending pipeline hot-swap (operator-scheduled via the config,
        # or armed mid-run by the reoptimizer through schedule_swap)
        swap = self._pending_swap
        if swap is not None and self.telemetry.total_pkts >= swap.after_pkts:
            before = self._loads_doc()
            recs = rt.hot_swap(swap.pipeline, now_pkts)
            self._merge_records(report, recs)
            for i in range(len(rt.shards)):
                report.service_switch[i] = swap.service
            self.service = swap.service
            self._pending_swap = None
            report.swapped = True
            self.n_swaps += 1
            self.swap_at_pkts = int(self.telemetry.total_pkts)
            self._audit(
                "hot_swap", now_pkts,
                f"scheduled swap armed at {swap.after_pkts} pkts; fleet "
                f"has ingested {self.swap_at_pkts}",
                {
                    "quiesce_flushes": sum(len(r) for r in recs.values()),
                    "shards": len(rt.shards),
                    "new_service": swap.service.source,
                },
                before=before,
            )

        # 2. elastic fleet sizing
        if cfg.headroom is not None and self._pps_ewma > 0:
            from repro.serve.runtime.shard import INDIRECTION_SIZE

            cap_pps = 1e9 / max(self.service.pkt_accum_ns, 1e-3)
            n_active = sum(rt.active)
            desired = cfg.headroom.desired_workers(
                self._pps_ewma, cap_pps, n_active)
            # the RETA is the steering quantum: more workers than entries
            # can never receive load (add_worker enforces the same bound)
            desired = min(desired, INDIRECTION_SIZE)
            n_before = sum(rt.active)
            size_before = (self._loads_doc() if desired != n_before else None)
            while desired > sum(rt.active):
                # reactivate a drained retired worker before minting a new
                # replica: flapping load must not grow the shard list
                retired = [i for i, a in enumerate(rt.active) if not a]
                if retired:
                    i = retired[0]
                    rt.active[i] = True
                elif len(rt.shards) < INDIRECTION_SIZE:
                    i = rt.add_worker()
                else:
                    break
                report.workers_added.append(i)
                self.workers_added += 1
            if report.workers_added:
                self._audit(
                    "scale_out", now_pkts,
                    f"offered {self._pps_ewma:.0f} pps vs {cap_pps:.0f} "
                    f"pps/worker capacity wants {desired} workers "
                    f"(had {n_before})",
                    {
                        "workers_added": list(report.workers_added),
                        "pps_ewma": round(self._pps_ewma, 1),
                        "cap_pps": round(cap_pps, 1),
                        "desired": desired,
                    },
                    before=size_before,
                )
            if desired < sum(rt.active):
                # one retirement per step: pick the coldest active worker,
                # evacuate its buckets, then mark it inactive
                loads = self.telemetry.shard_loads(rt.indirection,
                                                   len(rt.shards))
                act = [i for i, a in enumerate(rt.active) if a]
                coldest = min(act, key=lambda i: loads[i])
                moves = plan_retirement(rates, rt.indirection, coldest,
                                        rt.active)
                pre_fm = report.flows_migrated
                self._apply_moves(report, moves, now_pkts)
                if not np.any(rt.indirection == coldest):
                    rt.active[coldest] = False
                    report.workers_retired.append(coldest)
                    self.workers_retired += 1
                    self._audit(
                        "retire", now_pkts,
                        f"load fits {desired} workers; evacuated coldest "
                        f"worker {coldest} "
                        f"(ewma load {float(loads[coldest]):.1f})",
                        {
                            "worker": coldest,
                            "buckets_evacuated": len(moves),
                            "flows_migrated":
                                report.flows_migrated - pre_fm,
                            "pps_ewma": round(self._pps_ewma, 1),
                            "desired": desired,
                        },
                        before=size_before,
                    )

        # 3. RETA rebalancing
        if cfg.rebalance:
            moves = plan_rebalance(
                rates, rt.indirection, rt.active,
                max_moves=cfg.max_moves_per_step,
                trigger=cfg.imbalance_trigger,
            )
            if moves:
                before_rb = self._loads_doc()
                pre_bm = report.buckets_moved
                pre_fm = report.flows_migrated
                self.n_rebalances += 1
                self._apply_moves(report, moves, now_pkts)
                self._audit(
                    "rebalance", now_pkts,
                    f"imbalance {before_rb['imbalance']:.3f} over trigger "
                    f"{cfg.imbalance_trigger:.3f}; planned "
                    f"{len(moves)} bucket moves",
                    {
                        "moves_planned": len(moves),
                        "buckets_moved": report.buckets_moved - pre_bm,
                        "flows_migrated": report.flows_migrated - pre_fm,
                        "trigger": cfg.imbalance_trigger,
                    },
                    before=before_rb,
                )

        # 4. drift-triggered re-optimization (DESIGN.md §13): after this
        # step's actuations, let the policy read the drift sketches and —
        # when an excursion has dwelt long enough — run its shadow
        # re-tune and arm the resulting swap. The swap itself fires
        # through section 1 on a *later* step, so episodes interleave
        # with the replay packet clock exactly like operator swaps.
        if self.reopt is not None:
            self.reopt.maybe_step(self, now_pkts)

        # 5. SLO verdict (DESIGN.md §14.2): fold the shared tracker's
        # windows at this step's clock edge, publish the verdict into the
        # telemetry registry projection, and audit breach *edges* — one
        # "slo" event per breach episode, zero when the objective is met.
        if self.slo is not None:
            v = self.slo.check(now_pkts)
            self.telemetry.publish("slo_attainment_fast", v.attainment_fast)
            self.telemetry.publish("slo_attainment_slow", v.attainment_slow)
            self.telemetry.publish("slo_burn_fast", v.burn_fast)
            self.telemetry.publish("slo_burn_slow", v.burn_slow)
            self.telemetry.publish("slo_breached", 1.0 if v.breached else 0.0)
            if v.new_breach:
                self.n_slo_breaches += 1
                self._audit(
                    "slo", now_pkts,
                    f"attainment {v.attainment_fast:.4f} under objective "
                    f"{v.objective:.4f} for target {v.target_s * 1e6:.0f}µs; "
                    f"burn fast {v.burn_fast:.1f}x / slow {v.burn_slow:.1f}x "
                    f"of error budget",
                    v.to_doc(),
                )

        # 6. export tick: one JSONL record per executed control step
        if self.exporter is not None:
            self.exporter.step(now_pkts)

        if (report.buckets_moved or report.swapped or report.workers_added
                or report.workers_retired):
            self.log.append({
                "now_pkts": now_pkts,
                "buckets_moved": report.buckets_moved,
                "flows_migrated": report.flows_migrated,
                "swapped": report.swapped,
                "workers_added": list(report.workers_added),
                "workers_retired": list(report.workers_retired),
            })
        return report

    # -- internals -----------------------------------------------------------

    def _export_registry(self):
        """The exporter's pull view: the merged fleet registry plus the
        telemetry and SLO projections, one namespace per pull."""
        from repro.serve.obs import fleet_registry

        reg = fleet_registry(self.rt)
        self.telemetry.to_registry(registry=reg)
        if self.slo is not None:
            self.slo.to_registry(registry=reg)
        return reg

    def _loads_doc(self) -> dict:
        """Snapshot of the planner's view: per-shard EWMA load projected
        through the current RETA, plus the imbalance statistic it acts
        on. Attached to audit events as the before/after state."""
        rt = self.rt
        loads = self.telemetry.shard_loads(rt.indirection, len(rt.shards))
        act = [i for i, a in enumerate(rt.active) if a]
        mean = float(loads[act].mean()) if act else 0.0
        return {
            "shard_loads_ewma": [round(float(x), 3) for x in loads],
            "active_workers": act,
            "imbalance": round(float(loads[act].max() / mean), 4)
            if act and mean > 0 else 1.0,
        }

    def _audit(self, kind: str, now_pkts: float, rationale: str,
               detail: Optional[dict] = None, *, before=None,
               after=None) -> None:
        if after is None and before is not None:
            after = self._loads_doc()
        self.audit.record(kind, now_pkts, rationale, detail,
                          before=before, after=after)
        if self.tracer is not None and self.tracer.enabled:
            from repro.serve.obs.trace import TID_CONTROL

            self.tracer.instant(f"control.{kind}", now_pkts, pid=0,
                                tid=TID_CONTROL)

    def _apply_moves(self, report: StepReport, moves: dict,
                     now_pkts: float) -> None:
        rep = self.rt.migrate_buckets(moves, now_pkts)
        for shard, recs in rep["records"].items():
            report.records.setdefault(shard, []).extend(recs)
        cost = (self.cfg.migrate_cost_pkts
                * self.service.pkt_accum_ns * 1e-9)
        for shard, n in rep["flows_out"].items():
            report.ingest_charge_s[shard] = (
                report.ingest_charge_s.get(shard, 0.0) + n * cost)
        for shard, n in rep["flows_in"].items():
            report.ingest_charge_s[shard] = (
                report.ingest_charge_s.get(shard, 0.0) + n * cost)
        report.buckets_moved += rep["buckets_moved"]
        report.flows_migrated += rep["flows_migrated"]
        self.buckets_moved += rep["buckets_moved"]
        self.buckets_skipped += rep["buckets_skipped"]
        self.flows_migrated += rep["flows_migrated"]

    @staticmethod
    def _merge_records(report: StepReport, recs: dict) -> None:
        for shard, rs in recs.items():
            report.records.setdefault(shard, []).extend(rs)

    def summary(self) -> dict:
        out = {
            "steps": self.n_steps,
            "rebalances": self.n_rebalances,
            "buckets_moved": self.buckets_moved,
            "buckets_skipped": self.buckets_skipped,
            "flows_migrated": self.flows_migrated,
            "swaps": self.n_swaps,
            "swap_at_pkts": self.swap_at_pkts,
            "workers_added": self.workers_added,
            "workers_retired": self.workers_retired,
            "active_workers": sum(self.rt.active),
        }
        if self.reopt is not None:
            out["reopt"] = self.reopt.summary()
        if self.slo is not None:
            out["slo_breaches"] = self.n_slo_breaches
            out["slo_attainment"] = round(self.slo.attainment, 6)
        return out

"""RETA rebalancing planner + elastic headroom policy (DESIGN.md §9.2).

Both planners are pure functions over telemetry: they propose indirection
rewrites, the runtime's migration protocol applies them (or skips a move
whose destination table cannot absorb the stranded flows). Keeping
planning side-effect-free makes every decision unit-testable and replay
deterministic.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["HeadroomPolicy", "plan_rebalance", "plan_retirement"]


def plan_rebalance(
    rates: np.ndarray,
    indirection: np.ndarray,
    active: list[bool],
    *,
    max_moves: int = 8,
    trigger: float = 1.05,
) -> dict[int, int]:
    """Greedy bucket-migration plan: move load from hot shards to cold.

    Classic longest-processing-time repair: while the hottest active
    shard exceeds `trigger` times the mean, move its largest bucket that
    still fits under half the hot/cold gap (so the move cannot overshoot
    and invert the imbalance); if every owned bucket is larger, fall back
    to the smallest one when it still strictly improves. Loads update
    locally after each planned move, so one step can plan several
    coordinated moves. Returns {bucket: destination shard}; empty when
    balanced.

    Buckets are the steering quantum: a single bucket hotter than the
    mean shard load is an unsplittable elephant herd — the planner parks
    it alone on the coldest shard, which is the best any RETA-granular
    steering can do.
    """
    rates = np.asarray(rates, np.float64)
    act = np.flatnonzero(np.asarray(active, bool))
    if act.size < 2 or rates.sum() <= 0:
        return {}
    n_shards = len(active)
    ind = np.array(indirection, np.int64, copy=True)
    loads = np.bincount(ind, weights=rates, minlength=n_shards)
    mean = loads[act].sum() / act.size
    moves: dict[int, int] = {}
    for _ in range(max_moves):
        h = int(act[np.argmax(loads[act])])
        c = int(act[np.argmin(loads[act])])
        gap = loads[h] - loads[c]
        if mean <= 0 or loads[h] / mean < trigger or gap <= 0:
            break
        owned = np.flatnonzero(ind == h)
        if owned.size == 0:
            break
        r = rates[owned]
        fit = r <= gap / 2.0
        if fit.any():
            b = int(owned[fit][np.argmax(r[fit])])
        else:
            b = int(owned[np.argmin(r)])
            if rates[b] >= gap:
                break  # any move would make things worse
        moves[b] = c
        loads[h] -= rates[b]
        loads[c] += rates[b]
        ind[b] = c
    return moves


def plan_retirement(
    rates: np.ndarray,
    indirection: np.ndarray,
    worker: int,
    active: list[bool],
) -> dict[int, int]:
    """Spread every bucket of a retiring worker over the remaining fleet.

    Greedy least-loaded placement, heaviest bucket first — the standard
    LPT heuristic, which keeps the post-retirement imbalance within a
    constant factor of optimal. Returns {bucket: destination shard}.
    """
    rates = np.asarray(rates, np.float64)
    targets = [i for i, a in enumerate(active) if a and i != worker]
    if not targets:
        raise ValueError("cannot retire the last active worker")
    ind = np.asarray(indirection, np.int64)
    n_shards = len(active)
    loads = np.bincount(ind, weights=rates, minlength=n_shards)
    owned = np.flatnonzero(ind == worker)
    moves: dict[int, int] = {}
    for b in owned[np.argsort(rates[owned])[::-1]]:
        t = targets[int(np.argmin(loads[targets]))]
        moves[int(b)] = t
        loads[t] += rates[b]
    return moves


@dataclasses.dataclass
class HeadroomPolicy:
    """Target-utilization worker sizing for elastic scale-out/in.

    `desired_workers` sizes the fleet so the offered load fits under
    `target_util` of aggregate worker capacity; `scale_in_util` adds
    hysteresis (only shrink when the smaller fleet would still sit below
    it), so the fleet does not flap at a utilization boundary.
    """

    target_util: float = 0.7
    scale_in_util: float = 0.5
    min_workers: int = 1
    max_workers: int = 8

    def desired_workers(
        self, offered_pps: float, per_worker_pps: float, current: int
    ) -> int:
        if per_worker_pps <= 0:
            return current
        need = math.ceil(offered_pps / (per_worker_pps * self.target_util))
        need = max(self.min_workers, min(self.max_workers, max(need, 1)))
        if need < current:
            # hysteresis: only shrink if the smaller fleet stays comfortable
            util_after = offered_pps / (need * per_worker_pps)
            if util_after > self.scale_in_util:
                need = current
        return need

"""Drift-triggered background re-optimization: the self-optimizing fleet
(DESIGN.md §13).

The deployment story through PR 7 ends at "compile the Pareto front and
hot-swap the knee" — a fleet that is optimal for the traffic it was
tuned on and frozen thereafter. Real traffic drifts. This module closes
the last loop: it consumes the `DriftMonitor`'s signal → trigger API
(`check()`), and when drift holds above threshold long enough, it runs a
budgeted *shadow* re-optimization (a fresh `CatoOptimizer` over a
profiler built from the traffic seen so far, warm-started from the
deployed bundle's observations) and pushes the new knee through the
existing zero-downtime `make_swap` path — so the whole measure →
optimize → compile → deploy → adapt cycle runs as one system, on the
deterministic replay packet clock.

Episode state machine (thrash-proof by construction)::

    IDLE --trigger--> DWELL --min_dwell_pkts held--> FIRE --> COOLDOWN
      ^                 | signal released                        |
      +--(hysteresis)---+            (cooldown_pkts elapsed) ----+

- **IDLE → DWELL** when `DriftVerdict.triggered` (a signal crossed its
  threshold, EWMAs warmed up).
- **DWELL → IDLE** when the verdict disarms — the signal fell below
  ``threshold * release_frac`` (hysteresis: one quiet batch inside the
  band does not release).
- **DWELL → FIRE** once the signal has held for `min_dwell_pkts`
  ingested packets: run the re-tune, schedule the swap for the next
  control step, audit the episode, `rebaseline()` the monitor (so the
  fix does not re-trigger on itself).
- **FIRE → COOLDOWN** for `cooldown_pkts` packets: back-to-back swaps
  are structurally impossible regardless of what the signal does.

The re-tune is *shadow-evaluated*: it runs against its own runtimes and
datasets, never the live fleet. `ReoptimizerPolicy` enforces this at
runtime — the live fleet's packet and prediction counters are snapshotted
around the re-tune callable, and any movement raises. Every episode is
recorded in the PR 6 audit log (kind ``"reopt"``: trigger rationale,
drift magnitudes, budget spent, old-vs-new knee objectives) and exposed
as ``reopt.*`` registry metrics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

__all__ = ["ReoptOutcome", "ReoptimizerConfig", "ReoptimizerPolicy",
           "cato_retuner"]


@dataclasses.dataclass
class ReoptimizerConfig:
    """Knobs for the drift → re-tune → deploy policy."""

    class_threshold: float = 0.25       # class-mix TV distance trigger
    feature_threshold: float = float("inf")  # feature shift (σ units), off
    release_frac: float = 0.5           # hysteresis release band
    min_dwell_pkts: int = 2048          # signal must hold this long to fire
    cooldown_pkts: int = 1 << 16        # refractory period after a fire
    max_episodes: int = 1               # episodes per run
    swap_delay_pkts: int = 0            # extra packets before the swap arms


@dataclasses.dataclass
class ReoptOutcome:
    """What one re-tune produced: the point to deploy, plus its receipts."""

    point: object                       # BundlePoint — the new knee
    service: Optional[object] = None    # ServiceModel (modeled if None)
    budget: dict = dataclasses.field(default_factory=dict)
    old_objectives: Optional[tuple] = None  # (cost, perf) of the old knee
    new_objectives: Optional[tuple] = None  # (cost, perf) of the new knee
    detail: dict = dataclasses.field(default_factory=dict)


class ReoptimizerPolicy:
    """Threshold drift signals into audited re-optimization episodes.

    `retune` is the episode body: a callable taking one trigger document
    (drift verdict + signal, packet clock, episode index) and returning a
    `ReoptOutcome`. `cato_retuner` builds the standard one (warm-started
    multi-fidelity BO → `compile_front` → knee); tests substitute
    cheaper bodies. `drift` binds the monitored `DriftMonitor` — usually
    injected by the `ControlPlane` from the run's session, so one policy
    object can serve repeated replays (each plane construction calls
    `reset()`).
    """

    def __init__(
        self,
        retune: Callable[[dict], ReoptOutcome],
        config: Optional[ReoptimizerConfig] = None,
        *,
        drift=None,
    ):
        self.retune = retune
        self.cfg = config or ReoptimizerConfig()
        self.drift = drift
        self.reset()

    def reset(self, drift=None) -> None:
        """Start a fresh run: state machine to IDLE, counters to zero.

        The policy object itself is reusable across replays (zero-loss
        bisection probes build a fresh plane per probe); per-run episode
        history does not leak between them."""
        if drift is not None:
            self.drift = drift
        self.state = "idle"
        self.episodes: list[dict] = []
        self.n_checks = 0
        self.n_triggers = 0
        self.n_disarmed = 0
        self.n_suppressed_cooldown = 0
        self.retune_wall_s = 0.0
        self.last_verdict = None
        self._dwell_start_pkts = 0
        self._cooldown_until_pkts = 0

    # -- the control-step hook ----------------------------------------------

    def maybe_step(self, plane, now_pkts: float) -> Optional[dict]:
        """Advance the episode state machine one control step.

        Called by `ControlPlane.maybe_step` after its own actuations, so
        episodes interleave deterministically with the replay packet
        clock: a fired episode's swap is scheduled here and executes on
        the *next* control step through the plane's normal swap path.
        Returns the episode record when one fired, else None."""
        if self.drift is None:
            return None
        cfg = self.cfg
        pkts = int(plane.telemetry.total_pkts)
        self.n_checks += 1
        if self.state == "cooldown":
            if pkts < self._cooldown_until_pkts:
                self.n_suppressed_cooldown += 1
                return None
            self.state = "idle"
        if len(self.episodes) >= cfg.max_episodes:
            return None
        verdict = self.drift.check(
            cfg.class_threshold, cfg.feature_threshold,
            release_frac=cfg.release_frac)
        self.last_verdict = verdict
        if self.state == "idle" and verdict.triggered:
            self.state = "dwell"
            self._dwell_start_pkts = pkts
            self.n_triggers += 1
        if self.state == "dwell":
            if not verdict.armed:
                # hysteresis release: the excursion ended before the
                # dwell filled — no episode, back to watching
                self.state = "idle"
                self.n_disarmed += 1
                return None
            if pkts - self._dwell_start_pkts >= cfg.min_dwell_pkts:
                return self._fire(plane, now_pkts, pkts, verdict)
        return None

    # -- episode body --------------------------------------------------------

    def _fire(self, plane, now_pkts: float, pkts: int, verdict) -> dict:
        """One audited episode: shadow re-tune, schedule swap, cool down."""
        from repro.serve.deploy import make_swap

        cfg = self.cfg
        guard_before = self._live_counters(plane.rt)
        t0 = time.perf_counter()
        outcome = self.retune({
            "episode": len(self.episodes),
            "now_pkts": float(now_pkts),
            "pkts_ingested": pkts,
            "verdict": verdict.to_doc(),
            "signal": self.drift.signal(),
        })
        wall = time.perf_counter() - t0
        self.retune_wall_s += wall
        guard_after = self._live_counters(plane.rt)
        if guard_after != guard_before:
            raise RuntimeError(
                "shadow re-tune evaluated on the live fleet: packet/"
                f"prediction counters moved {guard_before} -> {guard_after} "
                "during the episode. Re-tune bodies must profile through "
                "their own runtimes (DESIGN.md §13.2).")

        after_pkts = pkts + cfg.swap_delay_pkts
        swap = make_swap(
            outcome.point, after_pkts=after_pkts, runtime=plane.rt,
            service=outcome.service, audit=plane.audit, now_pkts=now_pkts)
        plane.schedule_swap(swap)

        detail = {
            "episode": len(self.episodes),
            "pkts_ingested": pkts,
            "drift": verdict.to_doc(),
            "budget": outcome.budget,
            "old_knee": outcome.old_objectives,
            "new_knee": outcome.new_objectives,
            "retune_wall_s": round(wall, 4),
            "swap_after_pkts": after_pkts,
            "cooldown_until_pkts": pkts + cfg.cooldown_pkts,
        }
        detail.update(outcome.detail)
        plane._audit(
            "reopt", now_pkts,
            f"class-mix shift {verdict.class_mix_shift:.3f} >= "
            f"{cfg.class_threshold:.3f} held {pkts - self._dwell_start_pkts} "
            f"pkts (dwell floor {cfg.min_dwell_pkts}); re-tuned and "
            f"scheduled the new knee after {after_pkts} pkts",
            detail,
        )
        # the new pipeline's prediction mix is *supposed* to differ:
        # re-anchor the baseline so the fix cannot re-trigger on itself
        self.drift.rebaseline()
        self.state = "cooldown"
        self._cooldown_until_pkts = pkts + cfg.cooldown_pkts
        record = dict(detail)
        self.episodes.append(record)
        return record

    @staticmethod
    def _live_counters(rt) -> tuple:
        """The shadow-evaluation guard's snapshot of the live fleet."""
        m = rt.metrics
        if hasattr(m, "merged"):  # AggregateMetrics (sharded fleet)
            m = m.merged()
        return (m.pkts_total, m.flows_predicted, m.batches)

    # -- reporting -----------------------------------------------------------

    def summary(self) -> dict:
        return {
            "state": self.state,
            "episodes": len(self.episodes),
            "checks": self.n_checks,
            "triggers": self.n_triggers,
            "disarmed": self.n_disarmed,
            "suppressed_cooldown": self.n_suppressed_cooldown,
            "retune_wall_s": round(self.retune_wall_s, 4),
        }

    def to_registry(self, reg=None):
        """Project the policy's counters as ``reopt.*`` metrics."""
        if reg is None:
            from repro.serve.obs.registry import MetricsRegistry

            reg = MetricsRegistry()
        reg.set_counter("reopt.episodes", len(self.episodes))
        reg.set_counter("reopt.checks", self.n_checks)
        reg.set_counter("reopt.triggers", self.n_triggers)
        reg.set_counter("reopt.disarmed", self.n_disarmed)
        reg.set_counter("reopt.suppressed_cooldown",
                        self.n_suppressed_cooldown)
        reg.set_gauge("reopt.retune_wall_s", self.retune_wall_s,
                      reduce="sum")
        if self.last_verdict is not None:
            reg.set_gauge("reopt.last_class_shift",
                          self.last_verdict.class_mix_shift, reduce="max")
            reg.set_gauge("reopt.last_feature_shift",
                          self.last_verdict.feature_shift, reduce="max")
        return reg


def cato_retuner(
    make_profiler: Callable[[dict], object],
    space,
    *,
    priors=None,
    fidelities: tuple = ("modeled",),
    measure_budget: int = 4,
    batch_size: int = 4,
    n_init: int = 3,
    seed: int = 0,
    warm_from=None,
    baseline=None,
    max_points: int = 4,
    fused: bool = True,
    use_kernel: bool = False,
    runtime=None,
) -> Callable[[dict], ReoptOutcome]:
    """Build the standard CATO re-tune body for `ReoptimizerPolicy`.

    Per episode it constructs a *shadow* profiler via
    ``make_profiler(trigger)`` — typically over the traffic observed up
    to the trigger (the trigger document carries ``pkts_ingested`` and
    the drift signal so the caller can cut the window) — then runs a
    budgeted optimization warm-started from `warm_from` (a
    `ParetoBundle`, `CatoResult`, or observation list — usually the
    deployed bundle, so the surrogate starts from everything the last
    tune learned), compiles the front with `compile_front`, and returns
    the knee. `baseline` (a `BundlePoint`, usually the currently deployed
    knee) fills the episode audit's old-vs-new objective comparison.
    Everything the body touches is its own: fresh profiler, fresh
    evaluator, fresh optimizer — the policy's live-fleet guard holds by
    construction."""
    from repro.core import CatoOptimizer, MemoizedEvaluator
    from repro.core.optimizer import Observation
    from repro.serve.deploy import compile_front
    from repro.traffic.backends import backend_suite

    def _warm_observations() -> list:
        if warm_from is None:
            return []
        if hasattr(warm_from, "points"):        # ParetoBundle
            return [
                Observation(x=p.rep, cost=float(p.cost), perf=float(p.perf),
                            aux=dict(p.aux), fidelity=p.fidelity)
                for p in warm_from.points
            ]
        if hasattr(warm_from, "observations"):  # CatoResult
            return list(warm_from.observations)
        return list(warm_from)

    def retune(trigger: dict) -> ReoptOutcome:
        prof = make_profiler(trigger)
        ev = MemoizedEvaluator(backend_suite(prof, fidelities))
        opt = CatoOptimizer(space, ev, priors, n_init=n_init, seed=seed,
                            batch_size=batch_size)
        n_warm = opt.warm_start(_warm_observations())
        if ev.multi_fidelity:
            res = opt.run_multi_fidelity(measure_budget=measure_budget,
                                         batch_size=batch_size)
        else:
            res = opt.run(n_iterations=n_init + measure_budget)
            # warm-started observations carry a "warm:" fidelity tag; pin
            # the measured fidelity so the reported front is live-only
            res.measured_fidelity = ev.measured
        bundle = compile_front(res, prof, runtime=runtime, fused=fused,
                               use_kernel=use_kernel, max_points=max_points)
        knee = bundle.knee()
        old = (None if baseline is None
               else (float(baseline.cost), float(baseline.perf)))
        return ReoptOutcome(
            point=knee,
            budget=res.budget,
            old_objectives=old,
            new_objectives=(float(knee.cost), float(knee.perf)),
            detail={
                "warm_started": n_warm,
                "front_points": len(bundle.points),
                "fidelity_counts": res.fidelity_counts,
            },
        )

    return retune

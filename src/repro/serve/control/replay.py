"""Controlled offered-load replay: the adaptive fleet under the virtual
clock (DESIGN.md §9.4).

The static sharded replay precomputes steering once and drives each shard
sequentially — valid because shards never interact. Under the control
plane, steering *changes mid-run*, so this driver interleaves: the global
event stream advances in delivery-ordered blocks, each block is steered
by the RETA as it stands, and between blocks the control plane may
rebalance, swap, or resize. Each worker keeps a persistent `_WorkerClock`
(its two serving lanes and bounded ring survive across blocks), so the
clock semantics per worker are identical to the static replay; the only
new costs are the ones the control plane explicitly charges (quiesce
flushes and per-flow migration copies).

Control cadence counts packets, so a zero-loss bisection over this
driver probes the same adaptation trajectory at every offered rate —
the reported rate is the closed-loop system's, transients included.
"""
from __future__ import annotations

import numpy as np

from repro.serve.runtime.replay import (
    ReplayStats,
    ServiceModel,
    PacketStream,
    _gather_events,
    _WorkerClock,
)
from repro.serve.runtime.shard import ShardedRuntime, stream_buckets

from .plane import ControlConfig, ControlPlane

__all__ = ["controlled_replay"]


def controlled_replay(
    stream: PacketStream,
    make_runtime,
    offered_pps: float,
    service: ServiceModel,
    *,
    control: ControlConfig = None,
    ring_capacity: int = 4096,
    evict_every: int = 512,
    obs=None,
    session=None,
) -> ReplayStats:
    """Replay `stream` at `offered_pps` through a control-plane-managed
    sharded fleet. Same contract as `repro.serve.runtime.replay` (drops
    aggregate across shards; predictions bit-identical to an oracle
    single-worker run for every flow that completes under one pipeline
    configuration), plus a `control` activity summary on the stats.

    `session` (a `repro.serve.ServeSession`) carries the attachments: a
    `ControlConfig` (required here — the control plane is this driver's
    point), an `Observability` bundle to trace flow lifecycles and worker
    stage spans on the same virtual clock, feed the drift monitor from
    dispatch outputs, and collect the control plane's audit log in one
    stream (DESIGN.md §11), and optionally a `ReoptimizerPolicy` for
    drift-triggered background re-optimization (DESIGN.md §13). The
    bare `control=` / `obs=` keywords are the deprecated pre-session
    spellings of the same thing.
    """
    from repro.serve.session import ServeSession

    session = ServeSession.coerce(session, control=control, obs=obs)
    if session.control is None:
        raise TypeError(
            "controlled_replay needs a ControlConfig on the session: "
            "without one, use repro.serve.replay")
    obs = session.obs
    rt = make_runtime()
    if not isinstance(rt, ShardedRuntime):
        raise TypeError(
            "controlled_replay needs a ShardedRuntime: the control plane "
            "actuates RETA entries and per-shard state, which a single "
            "worker does not have"
        )
    tracer = slo = None
    if obs is not None:
        obs.attach(rt)
        tracer = obs.tracer
        slo = obs.slo
    plane = ControlPlane(rt, session.control, service, session=session)
    t_e = stream.base_t * (stream.base_pps / offered_pps)
    t_end = float(t_e[-1]) + rt.flush_timeout_s if len(t_e) else 0.0
    duration = float(t_e[-1] - t_e[0]) if stream.n_events > 1 else 1.0
    gbps = stream.total_bytes * 8.0 / max(duration, 1e-9) / 1e9

    # a flow's bucket is fixed for life; only the entry above it moves
    ev_bucket = stream_buckets(stream)[stream.fid]
    ev_key = stream.key[stream.fid]

    clocks = [
        _WorkerClock(srt, service, ring_capacity, evict_every,
                     pid=i, tracer=tracer, slo=slo)
        for i, srt in enumerate(rt.shards)
    ]
    E = stream.n_events
    pos = 0
    while pos < E:
        hi = min(pos + evict_every, E)
        bk = ev_bucket[pos:hi]
        plane.note(ev_key[pos:hi], bk)
        shard = rt.indirection[bk]
        for i in np.unique(shard):
            sel = np.flatnonzero(shard == i) + pos
            clocks[int(i)].feed(_gather_events(stream, t_e, sel))
        step = plane.maybe_step(float(t_e[hi - 1]))
        if step is not None:
            # elastic scale-out: every new worker gets its own lanes
            while len(clocks) < len(rt.shards):
                clocks.append(_WorkerClock(
                    rt.shards[len(clocks)], plane.service,
                    ring_capacity, evict_every,
                    pid=len(clocks), tracer=tracer, slo=slo))
            # quiesce/swap flushes ran on the configuration that produced
            # them: charge before retargeting service constants
            for i, recs in step.records.items():
                clocks[i].charge(recs)
            for i, sec in step.ingest_charge_s.items():
                clocks[i].charge_ingest(sec)
            for i, svc in step.service_switch.items():
                clocks[i].service = svc
        pos = hi

    for clock in clocks:
        clock.finish(t_end)

    stage_seconds: dict[str, float] = {}
    shard_stages: dict[int, dict[str, float]] = {}
    for i, clock in enumerate(clocks):
        shard_stages[i] = dict(clock.stage_s)
        for k, v in clock.stage_s.items():
            stage_seconds[k] = stage_seconds.get(k, 0.0) + v

    agg = rt.metrics
    m = agg.merged()
    per_shard = [
        {
            "shard": i,
            "offered_pps": offered_pps * p.pkts_total / max(m.pkts_total, 1),
            "pkts_total": p.pkts_total,
            "drops_ring": p.drops_ring,
            "drops_table": p.drops_table,
            "flows_predicted": p.flows_predicted,
            "flows_migrated_in": p.flows_migrated_in,
            "flows_migrated_out": p.flows_migrated_out,
            "batches": p.batches,
            "occupancy_mean": p.occupancy_stats()["mean"],
            "latency_p50_s": p.latency.percentile(50),
            "latency_p99_s": p.latency.percentile(99),
            "active": bool(rt.active[i]),
            "stage_seconds": shard_stages.get(i, {}),
        }
        for i, p in enumerate(agg.parts)
    ]
    return ReplayStats(
        offered_pps=offered_pps,
        offered_gbps=gbps,
        duration_s=duration,
        drops=m.drops,
        drops_ring=m.drops_ring,
        drops_table=m.drops_table,
        metrics=m,
        predictions=dict(rt.results),
        latency_p50_s=m.latency.percentile(50),
        latency_p99_s=m.latency.percentile(99),
        n_shards=rt.n_shards,
        load_imbalance=agg.load_imbalance(),
        per_shard=per_shard,
        control=plane.summary(),
        stage_seconds=stage_seconds,
    )

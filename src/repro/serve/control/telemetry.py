"""Per-RETA-bucket load telemetry (DESIGN.md §9.1).

The rebalancing planner reasons at the steering granularity it can act
on: indirection-table buckets, not flows. `BucketTelemetry` keeps one
counter per bucket, windowed per control interval, and folds windows
into an EWMA so the planner sees sustained load rather than one block's
burst. Counters are plain `np.bincount` adds on arrays the ingest path
already materializes — telemetry costs one vector op per block.
"""
from __future__ import annotations

import numpy as np

from repro.serve.runtime.shard import INDIRECTION_SIZE

__all__ = ["BucketTelemetry"]


class BucketTelemetry:
    """EWMA of per-bucket packet counts, rolled once per control interval.

    `note` accumulates the current window; `roll` folds it into the EWMA
    and resets. Units are packets per interval — the planner only needs
    *relative* bucket weights, so no division by wall time happens here
    (which also makes the signal invariant under replay clock
    compression: the same trace rebalances the same way at every offered
    rate, keeping zero-loss bisection probes comparable).
    """

    def __init__(self, n_buckets: int = INDIRECTION_SIZE, alpha: float = 0.5):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.n_buckets = n_buckets
        self.alpha = alpha
        self.window = np.zeros(n_buckets, np.int64)
        self.ewma = np.zeros(n_buckets, np.float64)
        self.rolls = 0
        self.total_pkts = 0
        # published control signals (DESIGN.md §14.2): point-in-time
        # verdict values the plane pushes each step (SLO attainment/burn),
        # exported as gauges alongside the load statistics
        self.signals: dict[str, float] = {}

    def publish(self, name: str, value: float) -> None:
        """Publish one named control signal (latest value wins; the
        per-step history belongs to the exporter's JSONL series)."""
        self.signals[name] = float(value)

    def note(self, buckets: np.ndarray) -> None:
        """Account one ingest block's packets by bucket id."""
        self.window += np.bincount(
            np.asarray(buckets, np.int64), minlength=self.n_buckets
        )
        self.total_pkts += len(buckets)

    def roll(self) -> np.ndarray:
        """Fold the window into the EWMA; returns the updated rates.

        The first roll seeds the EWMA with the raw window (an empty prior
        would make every early plan chase a half-faded signal)."""
        w = self.window.astype(np.float64)
        if self.rolls == 0:
            self.ewma = w
        else:
            self.ewma = self.alpha * w + (1.0 - self.alpha) * self.ewma
        self.window[:] = 0
        self.rolls += 1
        return self.ewma

    def shard_loads(self, indirection: np.ndarray, n_shards: int) -> np.ndarray:
        """Project bucket EWMA onto shards under an indirection table."""
        return np.bincount(
            np.asarray(indirection, np.int64), weights=self.ewma,
            minlength=n_shards,
        )

    def to_registry(self, prefix: str = "control.telemetry.", registry=None):
        """Project the telemetry view into the unified metrics namespace
        (DESIGN.md §11.1): counters for rolls/packets, gauges for the
        EWMA balance statistics the planner acts on."""
        from repro.serve.obs.registry import MetricsRegistry

        reg = registry if registry is not None else MetricsRegistry()
        reg.set_counter(prefix + "total_pkts", self.total_pkts)
        reg.set_counter(prefix + "rolls", self.rolls)
        mean = float(self.ewma.mean())
        reg.set_gauge(prefix + "ewma_max", float(self.ewma.max()), reduce="max")
        reg.set_gauge(prefix + "ewma_mean", mean, reduce="mean")
        reg.set_gauge(
            prefix + "imbalance",
            float(self.ewma.max() / mean) if mean > 0 else 1.0,
            reduce="max",
        )
        for name, value in self.signals.items():
            reg.set_gauge(prefix + name, value, reduce="mean")
        return reg

"""Compile-to-deploy: turn an optimized Pareto front into running pipelines.

The paper's pitch is that CATO "compiles end-to-end optimized serving
pipelines that can be deployed in real networks" — discovery is only
half the loop. This module is the other half (DESIGN.md §10.4):

1. `compile_front` takes a `CatoResult` (its measured-fidelity Pareto
   set) and the profiler that measured it, rebuilds each front point's
   trained model from the profiler's cache (the *same* seeded forest the
   measurement used), compiles the serving pipeline, and pre-warms every
   dispatch bucket geometry of the target runtime so deployment never
   pays an XLA compile on the serving path (`ServingPipeline.warm`; the
   jit cache is keyed on static config, so coexisting pipelines never
   alias).
2. `ParetoBundle` is the serializable artifact: configs, measured
   objectives, compile metadata, and the full dense-forest payload per
   point — `save`/`load` round-trips through JSON, so a bundle built on
   the optimization host can be deployed elsewhere without retraining.
3. `make_swap` / `deploy` push a chosen point (`knee()` by default —
   the diminishing-returns operating point) into a *live* runtime:
   `make_swap` schedules a zero-downtime `PipelineSwap` through the
   control plane, `deploy` hot-swaps immediately via the §9.3
   drain-and-swap quiescence protocol (zero drops, exactly-once
   predictions — the same argument, reused).

`examples/tune_serving.py` drives the full measure → optimize →
compile → deploy loop.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Optional

import numpy as np

from repro.core.forest import DenseForest
from repro.core.optimizer import CatoResult, Observation
from repro.core.pareto import knee_index
from repro.core.search_space import FeatureRep

__all__ = ["BundlePoint", "MultiTenantBundlePoint", "ParetoBundle",
           "compile_front", "compile_multi_tenant", "deploy", "make_swap",
           "warm_buckets_for"]


def warm_buckets_for(runtime=None, lo: int = 8, hi: int = 256) -> list[int]:
    """Power-of-two dispatch buckets a runtime's dispatcher can submit.

    Warming must cover the target fleet's *actual* batch geometry
    (`min_bucket..max_batch`); the defaults only apply when no runtime
    is given (matching `StreamingRuntime`'s own defaults)."""
    if runtime is not None:
        worker = getattr(runtime, "shards", [runtime])[0]
        lo, hi = worker.dispatcher.min_bucket, worker.dispatcher.max_batch
    buckets, b = [], lo
    while b <= hi:
        buckets.append(b)
        b *= 2
    return buckets


def _forest_to_doc(f: DenseForest) -> dict:
    return {
        "feature": f.feature.tolist(),
        "threshold": f.threshold.tolist(),
        "leaf": f.leaf.tolist(),
        "depth": int(f.depth),
        "n_features": int(f.n_features),
        "classes": None if f.classes is None else f.classes.tolist(),
    }


def _forest_from_doc(d: dict) -> DenseForest:
    return DenseForest(
        feature=np.asarray(d["feature"], dtype=np.int32),
        threshold=np.asarray(d["threshold"], dtype=np.float32),
        leaf=np.asarray(d["leaf"], dtype=np.float32),
        depth=int(d["depth"]),
        n_features=int(d["n_features"]),
        classes=(None if d["classes"] is None
                 else np.asarray(d["classes"])),
    )


@dataclasses.dataclass
class BundlePoint:
    """One compiled Pareto point: config + measured objectives + model."""

    rep: FeatureRep
    cost: float
    perf: float
    fidelity: str
    aux: dict
    compile_meta: dict        # buckets warmed, compile wall, fusion mode
    forest_doc: dict          # serialized DenseForest (deploy payload)
    # live warm handle — process-local, never serialized
    pipeline: object = dataclasses.field(default=None, repr=False,
                                         compare=False)

    def forest(self) -> DenseForest:
        return _forest_from_doc(self.forest_doc)

    def build(self, *, runtime=None, warm: bool = True):
        """(Re)compile this point's serving pipeline; warm it for the
        target runtime's bucket geometry unless told not to."""
        from repro.traffic.pipeline import build_pipeline

        pipe = build_pipeline(
            self.rep, self.forest(), max_pkts=self.rep.depth,
            fused=bool(self.compile_meta.get("fused", True)),
            use_kernel=bool(self.compile_meta.get("use_kernel", False)),
        )
        if warm:
            pipe.warm(warm_buckets_for(runtime))
        self.pipeline = pipe
        return pipe

    def to_doc(self) -> dict:
        return {
            "features": list(self.rep.features),
            "depth": int(self.rep.depth),
            "cost": float(self.cost),
            "perf": float(self.perf),
            "fidelity": self.fidelity,
            "aux": self.aux,
            "compile_meta": self.compile_meta,
            "forest": self.forest_doc,
        }

    @classmethod
    def from_doc(cls, d: dict) -> "BundlePoint":
        return cls(
            rep=FeatureRep(tuple(d["features"]), int(d["depth"])),
            cost=float(d["cost"]),
            perf=float(d["perf"]),
            fidelity=d["fidelity"],
            aux=dict(d["aux"]),
            compile_meta=dict(d["compile_meta"]),
            forest_doc=d["forest"],
        )


@dataclasses.dataclass
class MultiTenantBundlePoint(BundlePoint):
    """N tenants' compiled points fused into one deployable unit
    (DESIGN.md §15).

    `rep` is the *union* FeatureRep (what the shared `FlowTable` is sized
    by), `cost` the sum of the per-tenant measured costs (the independent
    upper bound — the shared fleet's discount is what deployment buys),
    `perf` the mean per-tenant perf. `build()` compiles the shared
    `MultiTenantPipeline`, so `make_swap`/`deploy` hot-swap it into a
    live fleet through the same §9.3 quiescence path as a solo point."""

    # per-tenant {features, depth, forest} docs, deploy order == lane order
    tenant_docs: list = dataclasses.field(default_factory=list)

    @property
    def tenant_reps(self) -> tuple:
        return tuple(FeatureRep(tuple(d["features"]), int(d["depth"]))
                     for d in self.tenant_docs)

    def tenant_forests(self) -> tuple:
        return tuple(_forest_from_doc(d["forest"]) for d in self.tenant_docs)

    def build(self, *, runtime=None, warm: bool = True):
        from repro.traffic.multi_tenant import build_multi_tenant_pipeline

        pipe = build_multi_tenant_pipeline(
            self.tenant_reps, self.tenant_forests(),
            fused=bool(self.compile_meta.get("fused", True)),
            use_kernel=bool(self.compile_meta.get("use_kernel", False)),
        )
        if warm:
            pipe.warm(warm_buckets_for(runtime))
        self.pipeline = pipe
        return pipe

    def to_doc(self) -> dict:
        d = super().to_doc()
        d["kind"] = "cato_multi_tenant_point"
        d["tenants"] = self.tenant_docs
        return d

    @classmethod
    def from_doc(cls, d: dict) -> "MultiTenantBundlePoint":
        return cls(
            rep=FeatureRep(tuple(d["features"]), int(d["depth"])),
            cost=float(d["cost"]),
            perf=float(d["perf"]),
            fidelity=d["fidelity"],
            aux=dict(d["aux"]),
            compile_meta=dict(d["compile_meta"]),
            forest_doc=d["forest"],
            tenant_docs=list(d["tenants"]),
        )


def compile_multi_tenant(
    points,
    *,
    runtime=None,
    fused: bool = True,
    use_kernel: bool = False,
    warm: bool = True,
    meta: Optional[dict] = None,
) -> MultiTenantBundlePoint:
    """Fuse per-tenant bundle points (each tenant front's chosen operating
    point — e.g. its `knee()`) into one multi-tenant deployable.

    The per-tenant points carry the exact measured forests, so the fused
    pipeline's lanes are bit-identical to each tenant's solo deployment;
    the union plan and the stacked-forest kernel are what change the
    cost. `deploy`/`make_swap` accept the result like any bundle point."""
    points = list(points)
    if not points:
        raise ValueError("need >= 1 tenant bundle point")
    from repro.traffic.multi_tenant import union_rep

    reps = tuple(p.rep for p in points)
    fids = {p.fidelity for p in points}
    mt = MultiTenantBundlePoint(
        rep=union_rep(reps),
        cost=float(sum(p.cost for p in points)),
        perf=float(np.mean([p.perf for p in points])),
        fidelity=fids.pop() if len(fids) == 1 else "mixed",
        aux={
            "tenant_costs": [float(p.cost) for p in points],
            "tenant_perfs": [float(p.perf) for p in points],
        },
        compile_meta={"fused": fused, "use_kernel": use_kernel,
                      "n_tenants": len(points)},
        forest_doc=points[0].forest_doc,
        tenant_docs=[{
            "features": list(p.rep.features),
            "depth": int(p.rep.depth),
            "forest": p.forest_doc,
        } for p in points],
    )
    t0 = time.perf_counter()
    mt.build(runtime=runtime, warm=warm)
    mt.compile_meta.update({
        "buckets": list(warm_buckets_for(runtime)) if warm else [],
        "compile_s": round(time.perf_counter() - t0, 4),
    })
    if meta:
        mt.aux.update(meta)
    return mt


@dataclasses.dataclass
class ParetoBundle:
    """The deployable artifact: a measured Pareto front, compiled.

    `points` are sorted by cost ascending. `meta` records where the
    front came from (fidelity, scenario, shard count, measurement
    budget, surrogate fallbacks) so an operator can audit what a bundle
    claims before pushing it at traffic."""

    points: list[BundlePoint]
    meta: dict = dataclasses.field(default_factory=dict)

    # -- selection -----------------------------------------------------------
    def knee(self) -> BundlePoint:
        """The diminishing-returns point of the (cost, -perf) front."""
        Y = np.array([(p.cost, -p.perf) for p in self.points])
        return self.points[knee_index(Y)]

    def best_by_perf(self) -> BundlePoint:
        return max(self.points, key=lambda p: p.perf)

    def best_by_cost(self) -> BundlePoint:
        return min(self.points, key=lambda p: p.cost)

    # -- serialization -------------------------------------------------------
    def to_doc(self) -> dict:
        return {
            "kind": "cato_pareto_bundle",
            "version": 1,
            "meta": self.meta,
            "points": [p.to_doc() for p in self.points],
        }

    @classmethod
    def from_doc(cls, d: dict) -> "ParetoBundle":
        if d.get("kind") != "cato_pareto_bundle":
            raise ValueError(f"not a ParetoBundle document: {d.get('kind')!r}")
        return cls(
            points=[BundlePoint.from_doc(p) for p in d["points"]],
            meta=dict(d["meta"]),
        )

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_doc()) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "ParetoBundle":
        return cls.from_doc(json.loads(pathlib.Path(path).read_text()))


def compile_front(
    result: CatoResult,
    profiler,
    *,
    runtime=None,
    fused: bool = True,
    use_kernel: bool = False,
    warm: bool = True,
    max_points: Optional[int] = None,
    meta: Optional[dict] = None,
) -> ParetoBundle:
    """Compile the measured-fidelity Pareto set of `result` into a bundle.

    `profiler` must be the `TrafficProfiler` the optimization evaluated
    through: its `perf_f1` cache returns the exact seeded forest each
    front point was measured with, so the deployed model *is* the
    measured model. `runtime` (optional) fixes the warm-bucket geometry
    to the deployment fleet's dispatcher; `warm=False` skips bucket
    pre-compilation (the pipeline still compiles lazily on first use).
    `max_points` keeps only the front's best-spread subset — both
    extremes and the knee always survive, so the result has
    max(max_points, 3) points — when compiling every point would be
    wasteful.
    """
    front: list[Observation] = result.pareto_observations()
    if not front:
        raise ValueError("result has no measured observations to compile")
    if max_points is not None and len(front) > max_points:
        # both extremes and the knee are always kept (so the bundle is
        # never smaller than 3 points, even for max_points < 3); the
        # remaining quota fills with an even spread over the front
        keep = {0, len(front) - 1,
                knee_index(np.array([o.objectives for o in front]))}
        for i in np.linspace(0, len(front) - 1, max_points).round():
            if len(keep) >= max_points:
                break
            keep.add(int(i))
        front = [front[i] for i in sorted(keep)]
    buckets = warm_buckets_for(runtime)
    points = []
    for o in front:
        f1, forest = profiler.perf_f1(o.x)  # cache hit: the measured model
        from repro.traffic.pipeline import build_pipeline

        t0 = time.perf_counter()
        pipe = build_pipeline(o.x, forest, max_pkts=o.x.depth, fused=fused,
                              use_kernel=use_kernel)
        if warm:
            pipe.warm(buckets)
        compile_s = time.perf_counter() - t0
        points.append(BundlePoint(
            rep=o.x,
            cost=o.cost,
            perf=o.perf,
            fidelity=o.fidelity,
            aux=dict(o.aux),
            compile_meta={
                "buckets": list(buckets) if warm else [],
                "compile_s": round(compile_s, 4),
                "fused": fused,
                "use_kernel": use_kernel,
                "n_trees": forest.n_trees,
                "forest_depth": forest.depth,
            },
            forest_doc=_forest_to_doc(forest),
            pipeline=pipe,
        ))
    points.sort(key=lambda p: p.cost)
    bundle_meta = {
        "measured_fidelity": result.measured_fidelity,
        "fidelity_counts": result.fidelity_counts,
        "surrogate_fallbacks": len(result.surrogate_fallbacks),
        "budget": result.budget,
        "scenario": getattr(profiler, "scenario", None),
        "n_shards": getattr(profiler, "n_shards", None),
        "cost_mode": getattr(profiler, "cost_mode", None),
    }
    if meta:
        bundle_meta.update(meta)
    return ParetoBundle(points=points, meta=bundle_meta)


def make_swap(
    point: BundlePoint,
    *,
    after_pkts: int = 0,
    runtime=None,
    service=None,
    audit=None,
    session=None,
    now_pkts: float = 0.0,
):
    """Schedule `point` as a zero-downtime `PipelineSwap` (DESIGN.md §9.3).

    Reuses the bundle's compiled pipeline handle when present
    (compile-once), but always (re-)warms it for the *target* runtime's
    bucket geometry: a handle warmed elsewhere for a smaller `max_batch`
    would pay a first-use XLA compile on the serving path mid-swap —
    exactly the stall the warm protocol exists to prevent. Re-warming an
    already-compiled bucket only replays a zero batch through the jit
    cache, so the ensure is cheap. `service` defaults to the modeled
    clock constants for the point's (F, n) — pass measured constants
    for calibrated replay. A `session` (or the deprecated bare
    ``audit=``) records the scheduling decision against `now_pkts` — the
    replay packet clock (canonical definition in
    `repro.serve.control.plane`) at which the decision was made."""
    from repro.serve.control.plane import PipelineSwap
    from repro.serve.runtime.replay import ServiceModel
    from repro.serve.session import ServeSession

    audit = ServeSession.coerce(session, audit=audit,
                                warn=False).resolve_audit()
    pipe = point.pipeline or point.build(runtime=runtime, warm=False)
    pipe.warm(warm_buckets_for(runtime))
    if service is None:
        t_reps = getattr(point, "tenant_reps", None)
        if t_reps:
            service = ServiceModel.modeled_multi_tenant(
                t_reps, point.tenant_forests())
        else:
            service = ServiceModel.modeled(point.rep, point.forest())
    if audit is not None:
        audit.record(
            "swap_scheduled", now_pkts,
            f"bundle point (|F|={len(point.rep.features)}, "
            f"n={point.rep.depth}) armed to swap after "
            f"{after_pkts} pkts",
            {
                "features": list(point.rep.features),
                "depth": int(point.rep.depth),
                "cost": float(point.cost),
                "perf": float(point.perf),
                "fidelity": point.fidelity,
                "after_pkts": int(after_pkts),
                "service": service.source,
            },
        )
    return PipelineSwap(pipeline=pipe, service=service, after_pkts=after_pkts)


def deploy(point: BundlePoint, runtime, now_pkts: float, *, audit=None,
           session=None):
    """Hot-swap `point` into a live runtime immediately.

    `runtime` is a `StreamingRuntime` or `ShardedRuntime`; the swap goes
    through the §9.3 drain-and-swap quiescence protocol, so in-flight
    flows resolve under the old pipeline and no flow is dropped or
    predicted twice. `now_pkts` is the replay packet clock (canonical
    definition in `repro.serve.control.plane`) at the swap edge. Warm
    coverage for `runtime`'s bucket geometry is ensured first (see
    `make_swap`), so the swap pays no compile on the serving path.
    Returns the quiesce flush records (list for a single worker,
    {shard: records} for a fleet) so a replay clock can charge them to
    the right lanes. Pass a `session` (or the deprecated bare
    ``audit=``) to record the deployment (DESIGN.md §11.3)."""
    from repro.serve.session import ServeSession

    audit = ServeSession.coerce(session, audit=audit).resolve_audit()
    pipe = point.pipeline or point.build(runtime=runtime, warm=False)
    pipe.warm(warm_buckets_for(runtime))
    recs = runtime.hot_swap(pipe, now_pkts)
    if audit is not None:
        flushes = (sum(len(r) for r in recs.values())
                   if isinstance(recs, dict) else len(recs))
        audit.record(
            "deploy", now_pkts,
            f"immediate hot-swap of bundle point "
            f"(|F|={len(point.rep.features)}, n={point.rep.depth})",
            {
                "features": list(point.rep.features),
                "depth": int(point.rep.depth),
                "cost": float(point.cost),
                "perf": float(point.perf),
                "fidelity": point.fidelity,
                "quiesce_flushes": flushes,
            },
        )
    return recs

"""Unified serving observability (DESIGN.md §11).

One subsystem spanning the serving stack, four pieces:

- `registry` — the fleet-wide `MetricsRegistry`: every ad-hoc counter,
  histogram, and telemetry view behind one dotted namespace with exact
  snapshot/delta semantics and order-independent cross-shard merge.
- `trace` — the bounded ring-buffer `Tracer`: per-flow lifecycle spans
  and per-worker stage spans on the replay packet clock, sampled,
  off by default, exported as Chrome trace-event JSON.
- `audit` — the control-plane `AuditLog`: every rebalance / retire /
  scale / hot-swap decision as a structured event with before/after
  EWMA snapshots and the planner's rationale.
- `drift` — the online `DriftMonitor`: class-mix and confidence EWMAs
  plus streaming feature moments from dispatch outputs — the signal the
  ROADMAP's self-optimizing fleet will threshold.
- `latency` — per-component `LatencySketch` recording (queue-wait /
  batch-residency / service / total) with bounded relative error and
  order-independent merges (DESIGN.md §14.1).
- `slo` — windowed attainment + multi-window burn-rate tracking on the
  packet clock, audited as kind ``"slo"`` (DESIGN.md §14.2).
- `export` — Prometheus text exposition + JSONL time series over any
  registry view, at control-step cadence (DESIGN.md §14.3).

`Observability` bundles the live hooks and knows how to attach them to
a runtime (single or sharded): attachment is attribute injection on the
dispatchers and metrics blocks, so a runtime with no bundle attached
pays exactly one ``is not None`` test per hook site.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .audit import AuditEvent, AuditLog
from .drift import DriftMonitor, DriftVerdict, StreamingMoments
from .export import MetricsExporter, check_prometheus, render_prometheus
from .latency import COMPONENTS, LatencyConfig, LatencyRecorder, LatencySketch
from .registry import MetricsRegistry
from .slo import SLOConfig, SLOTracker, SLOVerdict
from .trace import Tracer, TID_CONTROL, TID_INFER, TID_INGEST

__all__ = [
    "AuditEvent",
    "AuditLog",
    "COMPONENTS",
    "DriftMonitor",
    "DriftVerdict",
    "LatencyConfig",
    "LatencyRecorder",
    "LatencySketch",
    "MetricsExporter",
    "MetricsRegistry",
    "Observability",
    "SLOConfig",
    "SLOTracker",
    "SLOVerdict",
    "StreamingMoments",
    "Tracer",
    "TID_CONTROL",
    "TID_INFER",
    "TID_INGEST",
    "check_prometheus",
    "fleet_registry",
    "render_prometheus",
]


def fleet_registry(runtime, per_shard: bool = True) -> MetricsRegistry:
    """The runtime's metrics as one registry — `ShardedRuntime` merges
    its shards (with ``shard{i}.`` columns), a single `StreamingRuntime`
    projects its one block — plus the live flow-table occupancy gauges
    (point-in-time state the cumulative counters cannot carry)."""
    agg = runtime.metrics
    if hasattr(agg, "registry"):  # AggregateMetrics
        reg = agg.registry(per_shard=per_shard)
    else:
        reg = agg.to_registry()
    workers = getattr(runtime, "shards", [runtime])
    occs = [w.table.occupancy() for w in workers]
    reg.set_gauge("flow_table.n_active",
                  float(sum(o["n_active"] for o in occs)), reduce="sum")
    reg.set_gauge("flow_table.load_factor",
                  max(o["load_factor"] for o in occs), reduce="max")
    reg.set_gauge("flow_table.tombstones",
                  float(sum(o["tombstones"] for o in occs)), reduce="sum")
    if per_shard and len(workers) > 1:
        for i, o in enumerate(occs):
            reg.set_gauge(f"shard{i}.flow_table.load_factor",
                          o["load_factor"], reduce="max")
    return reg


@dataclasses.dataclass
class Observability:
    """The attachable observability bundle for one runtime/replay.

    Any piece may be None (and the tracer defaults to None — tracing is
    opt-in); the audit log always exists because recording a decision is
    cheap and losing one is not.
    """

    tracer: Optional[Tracer] = None
    drift: Optional[DriftMonitor] = None
    audit: AuditLog = dataclasses.field(default_factory=AuditLog)
    # latency-component sketches: a config, not a recorder — one fresh
    # `LatencyRecorder` is minted per worker so sketches merge per shard
    latency: Optional[LatencyConfig] = None
    # a single shared tracker: window counts are integer adds, so every
    # shard's `_WorkerClock` can feed the same one
    slo: Optional[SLOTracker] = None
    exporter: Optional[MetricsExporter] = None

    def attach(self, runtime) -> "Observability":
        """Inject the hooks into every worker's dispatcher. Idempotent;
        returns self so ``Observability(...).attach(rt)`` chains."""
        workers = getattr(runtime, "shards", [runtime])
        for i, w in enumerate(workers):
            self.attach_worker(w, i)
        return self

    def attach_worker(self, worker, shard_id: int) -> None:
        """Hook one `StreamingRuntime` (elastic scale-out attaches late
        workers through here so their spans carry the right shard pid)."""
        disp = worker.dispatcher
        disp.tracer = self.tracer
        disp.drift = self.drift
        disp.trace_pid = shard_id
        if self.latency is not None and worker.metrics.latency_components is None:
            worker.metrics.enable_latency_components(self.latency.make())

    def snapshot(self, runtime, control=None) -> dict:
        """One frozen document for the whole run: the merged fleet
        registry snapshot plus whatever else is live (control summary,
        drift signal, audit and trace summaries)."""
        out = {"registry": fleet_registry(runtime).snapshot()}
        if control is not None:
            out["control"] = control.summary()
            out["control_registry"] = control.telemetry.to_registry().snapshot()
        if self.drift is not None:
            out["drift"] = self.drift.signal()
        if self.slo is not None:
            out["slo"] = self.slo.signal()
        if self.audit is not None and len(self.audit):
            out["audit"] = self.audit.summary()
        if self.tracer is not None:
            out["trace"] = self.tracer.summary()
        return out

"""Unified serving observability (DESIGN.md §11).

One subsystem spanning the serving stack, four pieces:

- `registry` — the fleet-wide `MetricsRegistry`: every ad-hoc counter,
  histogram, and telemetry view behind one dotted namespace with exact
  snapshot/delta semantics and order-independent cross-shard merge.
- `trace` — the bounded ring-buffer `Tracer`: per-flow lifecycle spans
  and per-worker stage spans on the replay packet clock, sampled,
  off by default, exported as Chrome trace-event JSON.
- `audit` — the control-plane `AuditLog`: every rebalance / retire /
  scale / hot-swap decision as a structured event with before/after
  EWMA snapshots and the planner's rationale.
- `drift` — the online `DriftMonitor`: class-mix and confidence EWMAs
  plus streaming feature moments from dispatch outputs — the signal the
  ROADMAP's self-optimizing fleet will threshold.

`Observability` bundles the three live hooks and knows how to attach
them to a runtime (single or sharded): attachment is attribute
injection on the dispatchers, so a runtime with no bundle attached pays
exactly one ``is not None`` test per hook site.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .audit import AuditEvent, AuditLog
from .drift import DriftMonitor, DriftVerdict, StreamingMoments
from .registry import MetricsRegistry
from .trace import Tracer, TID_CONTROL, TID_INFER, TID_INGEST

__all__ = [
    "AuditEvent",
    "AuditLog",
    "DriftMonitor",
    "DriftVerdict",
    "MetricsRegistry",
    "Observability",
    "StreamingMoments",
    "Tracer",
    "TID_CONTROL",
    "TID_INFER",
    "TID_INGEST",
    "fleet_registry",
]


def fleet_registry(runtime, per_shard: bool = True) -> MetricsRegistry:
    """The runtime's metrics as one registry — `ShardedRuntime` merges
    its shards (with ``shard{i}.`` columns), a single `StreamingRuntime`
    projects its one block — plus the live flow-table occupancy gauges
    (point-in-time state the cumulative counters cannot carry)."""
    agg = runtime.metrics
    if hasattr(agg, "registry"):  # AggregateMetrics
        reg = agg.registry(per_shard=per_shard)
    else:
        reg = agg.to_registry()
    workers = getattr(runtime, "shards", [runtime])
    occs = [w.table.occupancy() for w in workers]
    reg.set_gauge("flow_table.n_active",
                  float(sum(o["n_active"] for o in occs)), reduce="sum")
    reg.set_gauge("flow_table.load_factor",
                  max(o["load_factor"] for o in occs), reduce="max")
    reg.set_gauge("flow_table.tombstones",
                  float(sum(o["tombstones"] for o in occs)), reduce="sum")
    if per_shard and len(workers) > 1:
        for i, o in enumerate(occs):
            reg.set_gauge(f"shard{i}.flow_table.load_factor",
                          o["load_factor"], reduce="max")
    return reg


@dataclasses.dataclass
class Observability:
    """The attachable observability bundle for one runtime/replay.

    Any piece may be None (and the tracer defaults to None — tracing is
    opt-in); the audit log always exists because recording a decision is
    cheap and losing one is not.
    """

    tracer: Optional[Tracer] = None
    drift: Optional[DriftMonitor] = None
    audit: AuditLog = dataclasses.field(default_factory=AuditLog)

    def attach(self, runtime) -> "Observability":
        """Inject the hooks into every worker's dispatcher. Idempotent;
        returns self so ``Observability(...).attach(rt)`` chains."""
        workers = getattr(runtime, "shards", [runtime])
        for i, w in enumerate(workers):
            self.attach_worker(w, i)
        return self

    def attach_worker(self, worker, shard_id: int) -> None:
        """Hook one `StreamingRuntime` (elastic scale-out attaches late
        workers through here so their spans carry the right shard pid)."""
        disp = worker.dispatcher
        disp.tracer = self.tracer
        disp.drift = self.drift
        disp.trace_pid = shard_id

    def snapshot(self, runtime, control=None) -> dict:
        """One frozen document for the whole run: the merged fleet
        registry snapshot plus whatever else is live (control summary,
        drift signal, audit and trace summaries)."""
        out = {"registry": fleet_registry(runtime).snapshot()}
        if control is not None:
            out["control"] = control.summary()
            out["control_registry"] = control.telemetry.to_registry().snapshot()
        if self.drift is not None:
            out["drift"] = self.drift.signal()
        if self.audit is not None and len(self.audit):
            out["audit"] = self.audit.summary()
        if self.tracer is not None:
            out["trace"] = self.tracer.summary()
        return out

"""Structured control-plane audit log (DESIGN.md §11.3).

Every actuation the control plane performs — RETA rebalance, worker
scale-out/retirement, pipeline hot-swap, compile-to-deploy push — is
recorded as one `AuditEvent`: what was done, *why* the planner did it
(its rationale, stated against the numbers it saw), and the before/after
per-shard EWMA load snapshot. The log makes fleet behavior replayable
and explainable: an operator can line audit events up against the trace
timeline and the metrics deltas and reconstruct every decision.

Events are plain data (JSONL round-trip via ``save``/``load``), appended
in decision order with a monotone sequence number — the control plane is
single-threaded per fleet, so the sequence *is* the causal order.

Event timestamps are `now_pkts` — the replay packet clock (see
`repro.serve.control.plane` for the unit's one canonical definition) —
never wall time. Documents written before the rename carried the key
``"t"``; `AuditEvent.from_doc` still reads it.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional

import numpy as np

__all__ = ["AuditEvent", "AuditLog"]

KINDS = ("rebalance", "scale_out", "retire", "hot_swap", "swap_scheduled",
         "deploy", "reopt", "slo")


def _jsonable(x):
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return x


@dataclasses.dataclass
class AuditEvent:
    """One control-plane decision, with its evidence."""

    seq: int                    # monotone per-log decision order
    now_pkts: float             # replay packet clock at the decision
    kind: str                   # one of KINDS
    rationale: str              # the planner's reason, in its own numbers
    detail: dict                # action-specific payload (moves, shard ids …)
    before: Optional[dict] = None  # shard-load EWMA snapshot pre-actuation
    after: Optional[dict] = None   # same, post-actuation

    @property
    def t(self) -> float:
        """Pre-rename alias for `now_pkts` (deprecated)."""
        return self.now_pkts

    def to_doc(self) -> dict:
        return _jsonable(dataclasses.asdict(self))

    @classmethod
    def from_doc(cls, d: dict) -> "AuditEvent":
        now_pkts = d["now_pkts"] if "now_pkts" in d else d["t"]
        return cls(
            seq=int(d["seq"]), now_pkts=float(now_pkts), kind=d["kind"],
            rationale=d["rationale"], detail=dict(d["detail"]),
            before=d.get("before"), after=d.get("after"),
        )


class AuditLog:
    def __init__(self) -> None:
        self.events: list[AuditEvent] = []

    def __len__(self) -> int:
        return len(self.events)

    def record(
        self,
        kind: str,
        now_pkts: float,
        rationale: str,
        detail: Optional[dict] = None,
        *,
        before: Optional[dict] = None,
        after: Optional[dict] = None,
    ) -> AuditEvent:
        if kind not in KINDS:
            raise ValueError(f"unknown audit kind {kind!r} (one of {KINDS})")
        ev = AuditEvent(
            seq=len(self.events), now_pkts=float(now_pkts), kind=kind,
            rationale=rationale, detail=_jsonable(detail or {}),
            before=_jsonable(before), after=_jsonable(after),
        )
        self.events.append(ev)
        return ev

    def of_kind(self, kind: str) -> list[AuditEvent]:
        return [e for e in self.events if e.kind == kind]

    def summary(self) -> dict:
        out: dict = {"events": len(self.events)}
        for k in KINDS:
            n = sum(1 for e in self.events if e.kind == k)
            if n:
                out[k] = n
        return out

    # -- persistence ---------------------------------------------------------

    def save(self, path) -> pathlib.Path:
        """One JSON document per line, in decision order."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("w") as f:
            for e in self.events:
                f.write(json.dumps(e.to_doc()) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "AuditLog":
        log = cls()
        for line in pathlib.Path(path).read_text().splitlines():
            if line.strip():
                log.events.append(AuditEvent.from_doc(json.loads(line)))
        return log

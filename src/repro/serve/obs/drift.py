"""Online drift signals from dispatch outputs (DESIGN.md §11.4).

The ROADMAP's self-optimizing fleet needs a trigger: a signal, computed
*from serving telemetry alone*, that the traffic the pipeline classifies
today no longer looks like the traffic it was optimized for. Two sketches
feed it, both updated per resolved micro-batch (one vectorized reduction
per batch — `BucketTelemetry.note` cost discipline):

- **class-mix EWMAs** over the predicted labels: a fast EWMA tracks the
  recent mix, a slow EWMA the long-run mix; the drift score is the total
  variation distance ``0.5 * |fast - slow|_1`` between them. Under a
  stationary mix both converge to the same point and the score decays to
  ~0; under the `drift` scenario (class mix shifts along the replay) the
  fast mix runs ahead of the slow one and the score moves.
- **per-class confidence EWMAs** over the winning class's vote share
  (the forest's top-class probability mass): a pipeline whose inputs
  wander off its training manifold gets less confident before it gets
  *wrong*, so confidence decay is the earlier warning.
- **per-feature streaming moments** (parallel Welford) over cheap
  batch-level feature summaries (flow length, mean packet size, flow
  duration): fast/slow mean gap in slow-σ units flags covariate shift
  even when the label mix holds still.

`DriftMonitor` is pure observation — it never actuates. `check()` is the
signal → trigger API the self-optimizing fleet consumes: it folds the
sketches into one `DriftVerdict` against caller-supplied thresholds, and
`rebaseline()` re-anchors the slow sketches after a corrective action
(e.g. a re-optimized pipeline hot-swap) so the monitor measures drift
*since the fix*, not since the start of time. The thresholding policy
itself — hysteresis, dwell, cooldown — lives in
`repro.serve.control.reoptimizer`, which builds on top.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["DriftMonitor", "DriftVerdict", "StreamingMoments"]

FEATURE_SUMMARY_NAMES = ("flow_len", "mean_pkt_size", "duration_s")


@dataclasses.dataclass(frozen=True)
class DriftVerdict:
    """One thresholded read of the drift sketches (the trigger API).

    `triggered` is the arm edge: some signal crossed its threshold.
    `armed` is the hysteresis hold: signals are still above
    ``threshold * release_frac``, so a dwell window opened on a trigger
    should stay open. `warmed_up` gates both — below `min_batches` the
    EWMAs are still seeding and every score is startup noise."""

    triggered: bool
    armed: bool
    warmed_up: bool
    class_mix_shift: float
    feature_shift: float
    class_threshold: float
    feature_threshold: float

    def to_doc(self) -> dict:
        return {
            "triggered": self.triggered,
            "armed": self.armed,
            "warmed_up": self.warmed_up,
            "class_mix_shift": round(self.class_mix_shift, 6),
            "feature_shift": round(self.feature_shift, 6),
            "class_threshold": self.class_threshold,
            "feature_threshold": self.feature_threshold,
        }


class StreamingMoments:
    """Parallel Welford: exact streaming mean/variance per column."""

    def __init__(self, n_cols: int):
        self.n = 0.0
        self.mean = np.zeros(n_cols)
        self._m2 = np.zeros(n_cols)

    def update(self, x: np.ndarray) -> None:
        """Fold a (n, n_cols) batch in (Chan's parallel combine)."""
        x = np.asarray(x, np.float64)
        nb = float(len(x))
        if nb == 0.0:
            return
        bmean = x.mean(axis=0)
        bm2 = ((x - bmean) ** 2).sum(axis=0)
        delta = bmean - self.mean
        n = self.n + nb
        self.mean = self.mean + delta * (nb / n)
        self._m2 = self._m2 + bm2 + delta**2 * (self.n * nb / n)
        self.n = n

    def var(self) -> np.ndarray:
        if self.n < 2:
            return np.zeros_like(self._m2)
        return self._m2 / (self.n - 1.0)

    def std(self) -> np.ndarray:
        return np.sqrt(self.var())


class DriftMonitor:
    """Fast/slow sketches over predictions, confidence, and features.

    `alpha_fast` >> `alpha_slow`: the fast EWMA is the "now" estimate,
    the slow one the baseline. `min_batches` suppresses the startup
    transient (both EWMAs seed from the first batches, so early scores
    are noise, not drift).
    """

    def __init__(
        self,
        alpha_fast: float = 0.25,
        alpha_slow: float = 0.02,
        min_batches: int = 8,
        history_cap: int = 4096,
    ):
        if not 0 < alpha_slow <= alpha_fast <= 1:
            raise ValueError("need 0 < alpha_slow <= alpha_fast <= 1")
        self.alpha_fast = alpha_fast
        self.alpha_slow = alpha_slow
        self.min_batches = min_batches
        self.history_cap = history_cap
        self.n_batches = 0
        self.n_flows = 0
        # class sketches size themselves to the label space lazily
        self._fast_mix: Optional[np.ndarray] = None
        self._slow_mix: Optional[np.ndarray] = None
        self._conf_ewma: Optional[np.ndarray] = None
        self._conf_seen: Optional[np.ndarray] = None
        # feature sketches
        self._feat_fast: Optional[np.ndarray] = None
        self._feat_slow: Optional[StreamingMoments] = None
        self.max_class_shift = 0.0
        self.max_feature_shift = 0.0
        self.history: list[dict] = []

    # -- sketch updates (one vectorized reduction per batch) -----------------

    def _grow_classes(self, n: int) -> None:
        if self._fast_mix is not None and n <= len(self._fast_mix):
            return

        def grow(a):
            out = np.zeros(n)
            if a is not None:
                out[: len(a)] = a
            return out

        self._fast_mix = grow(self._fast_mix)
        self._slow_mix = grow(self._slow_mix)
        self._conf_ewma = grow(self._conf_ewma)
        seen = np.zeros(n, bool)
        if self._conf_seen is not None:
            seen[: len(self._conf_seen)] = self._conf_seen
        self._conf_seen = seen

    def note_predictions(self, preds: np.ndarray,
                         confidence: Optional[np.ndarray] = None) -> None:
        """Fold one resolved batch's class labels (+ top-class vote share)."""
        preds = np.asarray(preds, np.int64).ravel()
        if preds.size == 0:
            return
        self._grow_classes(int(preds.max()) + 1)
        k = len(self._fast_mix)
        mix = np.bincount(preds, minlength=k) / preds.size
        if self.n_batches == 0:
            self._fast_mix = mix.astype(np.float64)
            self._slow_mix = mix.astype(np.float64)
        else:
            af, asl = self.alpha_fast, self.alpha_slow
            self._fast_mix = af * mix + (1 - af) * self._fast_mix
            self._slow_mix = asl * mix + (1 - asl) * self._slow_mix
        if confidence is not None:
            conf = np.asarray(confidence, np.float64).ravel()
            # per-class mean confidence this batch, EWMA'd where present
            csum = np.bincount(preds, weights=conf, minlength=k)
            ccnt = np.bincount(preds, minlength=k)
            present = ccnt > 0
            cmean = np.where(present, csum / np.maximum(ccnt, 1), 0.0)
            fresh = present & ~self._conf_seen
            self._conf_ewma[fresh] = cmean[fresh]
            upd = present & self._conf_seen
            af = self.alpha_fast
            self._conf_ewma[upd] = (af * cmean[upd]
                                    + (1 - af) * self._conf_ewma[upd])
            self._conf_seen |= present
        self.n_batches += 1
        self.n_flows += preds.size
        score = self.class_mix_shift()
        if self.n_batches >= self.min_batches:
            self.max_class_shift = max(self.max_class_shift, score)
        if len(self.history) < self.history_cap:
            self.history.append({
                "n_flows": self.n_flows,
                "class_mix_shift": score,
                "feature_shift": self.feature_shift(),
            })

    def note_features(self, summaries: np.ndarray) -> None:
        """Fold one batch's (n, k) feature summary columns."""
        x = np.asarray(summaries, np.float64)
        if x.size == 0:
            return
        if self._feat_slow is None:
            self._feat_slow = StreamingMoments(x.shape[1])
            self._feat_fast = x.mean(axis=0)
        else:
            af = self.alpha_fast
            self._feat_fast = af * x.mean(axis=0) + (1 - af) * self._feat_fast
        self._feat_slow.update(x)
        if self.n_batches >= self.min_batches:
            self.max_feature_shift = max(self.max_feature_shift,
                                         self.feature_shift())

    # -- signals -------------------------------------------------------------

    def class_mix_shift(self) -> float:
        """Total variation distance between fast and slow class mixes."""
        if self._fast_mix is None:
            return 0.0
        return float(0.5 * np.abs(self._fast_mix - self._slow_mix).sum())

    def feature_shift(self) -> float:
        """Max per-feature |fast mean - slow mean| in slow-σ units."""
        if self._feat_slow is None or self._feat_slow.n < 2:
            return 0.0
        gap = np.abs(self._feat_fast - self._feat_slow.mean)
        return float((gap / (self._feat_slow.std() + 1e-9)).max())

    def confidence(self) -> dict[int, float]:
        """Per-class prediction-confidence EWMA (observed classes only)."""
        if self._conf_ewma is None:
            return {}
        return {int(c): float(self._conf_ewma[c])
                for c in np.flatnonzero(self._conf_seen)}

    def check(
        self,
        class_threshold: float = 0.25,
        feature_threshold: float = float("inf"),
        *,
        release_frac: float = 0.5,
    ) -> DriftVerdict:
        """Threshold the current sketches into one `DriftVerdict`.

        `triggered` when the instantaneous class-mix TV distance crosses
        `class_threshold` or the feature shift crosses
        `feature_threshold` (default off); `armed` while either signal
        holds above ``threshold * release_frac`` — the hysteresis band a
        dwell window uses so a trigger is not disarmed by one quiet
        batch. Both are False until `min_batches` batches have seeded
        the EWMAs."""
        if not 0.0 <= release_frac <= 1.0:
            raise ValueError("release_frac must be in [0, 1]")
        warmed = self.n_batches >= self.min_batches
        cls = self.class_mix_shift()
        feat = self.feature_shift()
        trig = warmed and (cls >= class_threshold
                           or feat >= feature_threshold)
        armed = warmed and (cls >= class_threshold * release_frac
                            or feat >= feature_threshold * release_frac)
        return DriftVerdict(
            triggered=trig, armed=armed, warmed_up=warmed,
            class_mix_shift=cls, feature_shift=feat,
            class_threshold=class_threshold,
            feature_threshold=feature_threshold,
        )

    def rebaseline(self) -> None:
        """Re-anchor the slow sketches at the fast ones' current state.

        Called after a corrective actuation (a re-optimized pipeline was
        swapped in): the new pipeline's prediction mix *will* differ from
        the old baseline — that is the point — so without re-anchoring
        the monitor would immediately re-trigger on its own fix. The fast
        sketches and flow/batch counts survive; running maxima reset so
        post-fix excursions are measured against the new baseline."""
        if self._fast_mix is not None:
            self._slow_mix = self._fast_mix.copy()
        if self._feat_slow is not None and self._feat_fast is not None:
            # restart the slow moments centered on the recent mean: the
            # variance re-seeds from post-fix batches
            fresh = StreamingMoments(len(self._feat_fast))
            fresh.update(self._feat_fast[None, :])
            self._feat_slow = fresh
        self.max_class_shift = 0.0
        self.max_feature_shift = 0.0

    def signal(self) -> dict:
        return {
            "n_batches": self.n_batches,
            "n_flows": self.n_flows,
            "class_mix_shift": self.class_mix_shift(),
            "max_class_shift": self.max_class_shift,
            "feature_shift": self.feature_shift(),
            "max_feature_shift": self.max_feature_shift,
            "confidence": self.confidence(),
        }

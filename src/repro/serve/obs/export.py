"""Pull-based metrics export (DESIGN.md §14.3).

Two render targets over the same `MetricsRegistry` view:

- **Prometheus text exposition** (`render_prometheus`): counters and
  gauges become ``counter``/``gauge`` families; histograms and sketches
  become ``summary`` families (``{quantile="…"}`` samples plus
  ``_sum``/``_count``), since their native percentile reads are exactly
  the summary contract; sets and sample lists export their cardinality.
  A ``shardN.`` name prefix becomes a ``{shard="N"}`` label on the base
  family, so per-shard columns from `fleet_registry` land as one labeled
  family instead of N mangled names. `check_prometheus` validates the
  output (parseable lines, no duplicate or late HELP/TYPE) and is wired
  into the trace_smoke gate.
- **JSONL time series** (`MetricsExporter.step`): one append-only line
  per control step — the full registry snapshot (exact ints, sparse
  sketch docs) plus the SLO signal, stamped with ``now_pkts``. Replay
  determinism makes consecutive runs produce identical series, so the
  artifact is diffable.

`MetricsExporter` is the attachment object: `ControlPlane` binds it to
the fleet registry + telemetry + SLO tracker at construction and calls
`step` at control-step cadence; standalone runtimes can bind it to any
zero-arg registry factory.
"""
from __future__ import annotations

import json
import re

__all__ = ["MetricsExporter", "check_prometheus", "render_prometheus"]

_QUANTILES = (50.0, 90.0, 99.0)
# scope prefixes rendered as labels, outermost first: a fleet registry can
# carry `shard3.tenant1.dispatch.flows_predicted` (multi-tenant pipeline on
# shard 3) and both prefixes must land as labels of ONE base family
_LABEL_RES = (
    ("shard", re.compile(r"^shard(\d+)\.(.+)$")),
    ("tenant", re.compile(r"^tenant(\d+)\.(.+)$")),
)
_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
# value: int/float/scientific/±Inf/NaN
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"            # metric name
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""   # optional {label="v",...}
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?"
    r" (-?(\d+(\.\d+)?([eE][+-]?\d+)?|Inf|NaN))$")
_HELP_RE = re.compile(r"^# HELP ([a-zA-Z_:][a-zA-Z0-9_:]*) .*$")
_TYPE_RE = re.compile(
    r"^# TYPE ([a-zA-Z_:][a-zA-Z0-9_:]*) (counter|gauge|summary|histogram|untyped)$")


def _sanitize(name: str) -> str:
    """Dotted registry path -> legal Prometheus metric name."""
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not out or not re.match(r"[a-zA-Z_:]", out[0]):
        out = "_" + out
    return out


def _split_labels(name: str) -> tuple[str, str]:
    """Strip leading scope prefixes into Prometheus labels:
    ``shard3.ingest.drops`` -> ``('ingest.drops', '{shard="3"}')``,
    ``shard3.tenant1.x`` -> ``('x', '{shard="3",tenant="1"}')``.
    Each label key is consumed at most once, so a metric that legitimately
    *names* a tenant deeper in its path is left alone."""
    labels: list[tuple[str, str]] = []
    changed = True
    while changed:
        changed = False
        for key, rx in _LABEL_RES:
            m = rx.match(name)
            if m and all(k != key for k, _ in labels):
                labels.append((key, m.group(1)))
                name = m.group(2)
                changed = True
    if not labels:
        return name, ""
    return name, "{" + ",".join('%s="%s"' % kv for kv in labels) + "}"


def _fmt(v: float) -> str:
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def render_prometheus(reg, *, namespace: str = "cato") -> str:
    """Render a live `MetricsRegistry` as Prometheus text exposition.

    Families are emitted in sorted name order, HELP/TYPE exactly once
    per family, per-shard columns as ``{shard="N"}`` labeled samples of
    the base family. Output always passes `check_prometheus`."""
    # family name -> (type, help, [(labels, value_str), ...])
    fams: dict[str, tuple[str, str, list]] = {}

    def add(raw: str, kind: str, value, help_suffix: str = "",
            suffix: str = ""):
        base, labels = _split_labels(raw)
        fam = f"{namespace}_{_sanitize(base)}{suffix}"
        if fam not in fams:
            fams[fam] = (kind, f"registry {kind} {base}{help_suffix}", [])
        fams[fam][2].append((labels, _fmt(value)))

    for k, v in reg._counters.items():
        add(k, "counter", v)
    for k, (v, r, _w) in reg._gauges.items():
        add(k, "gauge", v, help_suffix=f" (merge: {r})")
    for dists, sum_attr in ((reg._hists, "_sum"), (reg._sketches, None)):
        for k, h in dists.items():
            base, shard = _split_labels(k)
            fam = f"{namespace}_{_sanitize(base)}"
            if fam not in fams:
                fams[fam] = ("summary", f"registry summary {base}", [])
            rows = fams[fam][2]
            for q in _QUANTILES:
                lbl = '{quantile="%s"}' % (q / 100.0)
                if shard:
                    lbl = shard[:-1] + "," + lbl[1:]
                rows.append((lbl, _fmt(float(h.percentile(q)))))
            total = h._sum if sum_attr else h.sum_s
            rows.append(("\x00_sum" + shard, _fmt(float(total))))
            rows.append(("\x00_count" + shard, _fmt(int(h.n))))
    for k, s in reg._sets.items():
        add(k, "gauge", len(s), suffix="_cardinality")
    for k, v in reg._samples.items():
        add(k, "gauge", len(v), suffix="_samples")

    lines = []
    for fam in sorted(fams):
        kind, help_text, rows = fams[fam]
        lines.append(f"# HELP {fam} {help_text}")
        lines.append(f"# TYPE {fam} {kind}")
        for labels, value in rows:
            if labels.startswith("\x00"):
                # summary _sum/_count sub-series: suffix goes on the name
                suffix, shard = labels[1:].split("{", 1) if "{" in labels \
                    else (labels[1:], "")
                shard = "{" + shard if shard else ""
                lines.append(f"{fam}{suffix}{shard} {value}")
            else:
                lines.append(f"{fam}{labels} {value}")
    return "\n".join(lines) + "\n"


def check_prometheus(text: str) -> list[str]:
    """Validate text-exposition output; returns a list of problems
    (empty == valid). Checks: every line parses, HELP/TYPE appear at
    most once per family and never after that family's samples, and no
    sample repeats a label name (a ``shard``/``tenant`` prefix folded
    twice would silently shadow one of the two in Prometheus)."""
    problems: list[str] = []
    helped: set[str] = set()
    typed: dict[str, str] = {}
    sampled: set[str] = set()
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            mh, mt = _HELP_RE.match(line), _TYPE_RE.match(line)
            if mh:
                name = mh.group(1)
                if name in helped:
                    problems.append(f"line {i}: duplicate HELP for {name}")
                if name in sampled:
                    problems.append(f"line {i}: HELP after samples of {name}")
                helped.add(name)
            elif mt:
                name = mt.group(1)
                if name in typed:
                    problems.append(f"line {i}: duplicate TYPE for {name}")
                if name in sampled:
                    problems.append(f"line {i}: TYPE after samples of {name}")
                typed[name] = mt.group(2)
            else:
                problems.append(f"line {i}: unparseable comment: {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            problems.append(f"line {i}: unparseable sample: {line!r}")
            continue
        name = m.group(1)
        labels = m.group(2)
        if labels:
            keys = re.findall(r'([a-zA-Z_][a-zA-Z0-9_]*)="', labels)
            if len(keys) != len(set(keys)):
                problems.append(
                    f"line {i}: duplicate label name on {name}: {labels}")
        # summary sub-series attach to their base family
        base = name
        for suffix in ("_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in typed \
                    and typed[name[: -len(suffix)]] in ("summary", "histogram"):
                base = name[: -len(suffix)]
        if base not in typed:
            problems.append(f"line {i}: sample {name} has no TYPE")
        sampled.add(base)
    return problems


class MetricsExporter:
    """Bindable pull exporter: Prometheus text on demand, JSONL series
    at control-step cadence.

    `bind` takes a zero-arg callable producing the registry view to
    export (the control plane passes the fleet registry + telemetry +
    SLO projection); `step` is called by `ControlPlane.maybe_step` after
    each executed control step."""

    def __init__(self, jsonl_path=None, *, namespace: str = "cato"):
        self.jsonl_path = jsonl_path
        self.namespace = namespace
        self._source = None
        self._slo = None
        self.steps = 0
        self.last: dict | None = None

    def bind(self, source, *, slo=None) -> None:
        self._source = source
        self._slo = slo

    def registry(self):
        if self._source is None:
            raise RuntimeError("MetricsExporter.bind was never called")
        return self._source()

    def collect(self, now_pkts: float = 0.0) -> dict:
        """One frozen export record: registry snapshot + SLO signal,
        stamped with the packet clock."""
        doc = {"now_pkts": round(float(now_pkts), 9),
               "step": self.steps,
               "registry": self.registry().snapshot()}
        if self._slo is not None:
            doc["slo"] = self._slo.signal()
        return doc

    def step(self, now_pkts: float) -> dict:
        """Collect and (when a path is configured) append one JSONL
        line. Append-only: a run's series is its full control history."""
        doc = self.collect(now_pkts)
        if self.jsonl_path is not None:
            with open(self.jsonl_path, "a") as fh:
                fh.write(json.dumps(doc, separators=(",", ":")) + "\n")
        self.steps += 1
        self.last = doc
        return doc

    def prometheus(self) -> str:
        return render_prometheus(self.registry(), namespace=self.namespace)

"""Mergeable bounded-relative-error latency sketches (DESIGN.md §14.1).

`LatencyHistogram` answers "what is the latency distribution" with exact
log-bucket counts, but its *percentiles* degrade once the raw-sample
reservoir saturates: the bucket-interpolation fallback is only bounded
by the bucket width (coarse: 8 buckets per decade), and the reservoir
itself is order-sensitive, so it can never cross a shard merge. This
module is the tail-latency-grade replacement:

- `LatencySketch` — a DDSketch-style log-bucketed quantile sketch with a
  *relative* accuracy guarantee: every reported percentile is within
  ``alpha`` (default 1%) of the exact rank statistic, at any stream
  length. Buckets are preallocated (one int64 vector, no per-record
  allocation), recording is one vectorized bincount, and merging is an
  integer bucket add — **order-independent and bit-identical under shard
  permutation**, the same merge law `MetricsRegistry` counters obey. The
  running sum is kept in integer nanoseconds so even the mean survives a
  permuted merge bit-for-bit.
- `LatencyRecorder` — one sketch per latency *component*: the
  enqueue→prediction total that `_WorkerClock.charge` always recorded,
  decomposed into queue-wait (ready→flush), batch-residency
  (flush→service start, the inference lane's backlog) and service time
  (the batch's own execution). The per-sample identity
  ``total = queue_wait + batch + service`` holds exactly, so a p99
  regression is attributable to a stage, not just observed.
- `LatencyConfig` — the attachment knob carried by `Observability`: one
  recorder is minted per worker, so per-shard sketches merge through the
  fleet registry like every other metric.

Sketch math: with ``gamma = (1 + alpha) / (1 - alpha)``, bucket ``i``
covers ``(lo_s * gamma**(i-1), lo_s * gamma**i]`` and reports the value
``2 * lo_s * gamma**i / (gamma + 1)`` — the point whose worst-case
relative distance to both bucket edges is exactly ``alpha``. Values at
or below ``lo_s`` land in an underflow bucket reported as the exact
running min; values above ``hi_s`` land in an overflow bucket reported
as the exact running max (the relative bound holds on ``(lo_s, hi_s]``).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.serve.runtime.metrics import METRIC_NAMESPACE

__all__ = [
    "COMPONENTS",
    "LatencyConfig",
    "LatencyRecorder",
    "LatencySketch",
]

# the decomposition of one flow's enqueue->prediction latency, in causal
# order; "total" is the sum of the other three per sample, by identity
COMPONENTS = ("queue_wait", "batch", "service", "total")


class LatencySketch:
    """DDSketch-style streaming quantile sketch with relative error
    <= `alpha` on ``(lo_s, hi_s]`` and order-independent merge.

    Storage is one preallocated int64 count per log bucket (underflow +
    ``ceil(log(hi/lo) / log(gamma))`` buckets + overflow; ~1.5k buckets
    at the defaults) plus five exact scalars; recording a block is one
    vectorized log + bincount. All merge state is integers and
    commutative scalar folds, so `merge_from` across shards is
    bit-identical under any permutation — asserted by tests.
    """

    def __init__(self, alpha: float = 0.01, lo_s: float = 1e-9,
                 hi_s: float = 1e4):
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        if not 0.0 < lo_s < hi_s:
            raise ValueError(f"need 0 < lo_s < hi_s, got {lo_s}, {hi_s}")
        self.alpha = alpha
        self.lo_s = lo_s
        self.hi_s = hi_s
        self.gamma = (1.0 + alpha) / (1.0 - alpha)
        self._lng = math.log(self.gamma)
        self.n_buckets = int(math.ceil(math.log(hi_s / lo_s) / self._lng))
        # [underflow] + 1..n_buckets + [overflow]
        self._counts = np.zeros(self.n_buckets + 2, np.int64)
        self._n = 0
        self._min = math.inf
        self._max = 0.0
        # integer nanoseconds: merge-order-invariant, unlike a float sum
        self._sum_ns = 0

    # -- writes --------------------------------------------------------------

    def record_many(self, seconds: np.ndarray) -> None:
        x = np.asarray(seconds, np.float64).ravel()
        if x.size == 0:
            return
        self._min = min(self._min, float(x.min()))
        self._max = max(self._max, float(x.max()))
        self._sum_ns += int(round(float(x.sum()) * 1e9))
        self._n += x.size
        k = np.zeros(x.size, np.int64)  # default: underflow
        mid = x > self.lo_s
        over = x > self.hi_s
        k[over] = self.n_buckets + 1
        body = mid & ~over
        if body.any():
            k[body] = np.clip(
                np.ceil(np.log(x[body] / self.lo_s) / self._lng),
                1, self.n_buckets,
            ).astype(np.int64)
        self._counts += np.bincount(k, minlength=len(self._counts))

    def record(self, value: float, count: int = 1) -> None:
        """Record `count` identical samples (the per-batch scalar path:
        batch-residency and service time are one value per batch shared
        by every flow in it — one bucket add, not an n-vector)."""
        if count <= 0:
            return
        v = float(value)
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        self._sum_ns += int(round(v * count * 1e9))
        self._n += count
        if v <= self.lo_s:
            b = 0
        elif v > self.hi_s:
            b = self.n_buckets + 1
        else:
            b = min(max(int(math.ceil(math.log(v / self.lo_s) / self._lng)),
                        1), self.n_buckets)
        self._counts[b] += count

    def merge_from(self, other: "LatencySketch") -> None:
        """Integer bucket add + commutative scalar folds: exact,
        order-independent, never aliases `other`."""
        if (other.alpha, other.lo_s, other.hi_s) != (
                self.alpha, self.lo_s, self.hi_s):
            raise ValueError(
                "sketch layout mismatch: "
                f"(alpha={other.alpha}, lo={other.lo_s}, hi={other.hi_s}) "
                f"vs (alpha={self.alpha}, lo={self.lo_s}, hi={self.hi_s})")
        if other._n == 0:
            return
        self._counts += other._counts
        self._n += other._n
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        self._sum_ns += other._sum_ns

    # -- reads ---------------------------------------------------------------

    @property
    def n(self) -> int:
        return self._n

    @property
    def sum_s(self) -> float:
        return self._sum_ns * 1e-9

    @property
    def mean_s(self) -> float:
        return self._sum_ns * 1e-9 / self._n if self._n else 0.0

    def _bucket_value(self, b: int) -> float:
        if b <= 0:
            return self._min
        if b > self.n_buckets:
            return self._max
        return 2.0 * self.lo_s * self.gamma ** b / (self.gamma + 1.0)

    def percentile(self, q: float) -> float:
        """The q-th percentile (q in [0, 100]), within relative error
        `alpha` of the exact rank statistic ``sorted(x)[ceil(q/100*n)-1]``
        whenever that value lies in ``(lo_s, hi_s]`` (under/overflow
        report the exact running min/max instead). 0.0 when empty."""
        if self._n == 0:
            return 0.0
        rank = min(max(int(math.ceil(q / 100.0 * self._n)), 1), self._n)
        cum = np.cumsum(self._counts)
        b = int(np.searchsorted(cum, rank, side="left"))
        val = self._bucket_value(b)
        return float(min(max(val, self._min), self._max))

    def counts(self) -> np.ndarray:
        return self._counts.copy()

    def summary(self) -> dict:
        return {
            "n": self._n,
            "p50_s": self.percentile(50),
            "p90_s": self.percentile(90),
            "p99_s": self.percentile(99),
            "mean_s": self.mean_s,
            "max_s": self._max if self._n else 0.0,
        }

    # -- snapshot ------------------------------------------------------------

    def to_doc(self) -> dict:
        """JSON-able frozen view. Counts are sparse sorted [index, count]
        pairs and the sum is integer ns, so two docs of identically
        merged sketches compare equal regardless of merge order."""
        nz = np.nonzero(self._counts)[0]
        return {
            "alpha": self.alpha,
            "lo_s": self.lo_s,
            "hi_s": self.hi_s,
            "n": int(self._n),
            "min_s": float(self._min) if self._n else 0.0,
            "max_s": float(self._max),
            "sum_ns": int(self._sum_ns),
            "counts": [[int(i), int(self._counts[i])] for i in nz],
        }

    @classmethod
    def from_doc(cls, d: dict) -> "LatencySketch":
        sk = cls(alpha=d["alpha"], lo_s=d["lo_s"], hi_s=d["hi_s"])
        for i, c in d["counts"]:
            sk._counts[i] = c
        sk._n = int(d["n"])
        sk._min = d["min_s"] if sk._n else math.inf
        sk._max = d["max_s"]
        sk._sum_ns = int(d["sum_ns"])
        return sk


class LatencyRecorder:
    """Per-component latency sketches for one worker.

    `_WorkerClock.charge` calls `record_batch` once per resolved batch
    with the clock's own decomposition points; each flow in the batch
    contributes one sample to every component, and the per-sample
    identity ``total = queue_wait + batch + service`` is exact (the
    integer-ns sums agree to rounding — asserted by tests). Registry
    names come from `METRIC_NAMESPACE` (``latency.queue_wait`` …), so
    the namespace test covers them like any counter.
    """

    def __init__(self, alpha: float = 0.01, lo_s: float = 1e-9,
                 hi_s: float = 1e4):
        self.alpha, self.lo_s, self.hi_s = alpha, lo_s, hi_s
        self.sketches = {
            c: LatencySketch(alpha=alpha, lo_s=lo_s, hi_s=hi_s)
            for c in COMPONENTS
        }

    def fresh(self) -> "LatencyRecorder":
        """An empty recorder with this one's sketch layout (elastic
        scale-out mints one per late worker)."""
        return LatencyRecorder(alpha=self.alpha, lo_s=self.lo_s,
                               hi_s=self.hi_s)

    def record_batch(self, ready_ts: np.ndarray, flush_ts: float,
                     start: float, done: float) -> None:
        """One resolved batch on the inference lane: per-flow queue-wait
        (ready→flush), shared batch-residency (flush→start) and service
        (start→done) weighted by the batch size, per-flow totals."""
        ready = np.asarray(ready_ts, np.float64)
        n = ready.size
        if n == 0:
            return
        s = self.sketches
        s["queue_wait"].record_many(flush_ts - ready)
        s["batch"].record(start - flush_ts, count=n)
        s["service"].record(done - start, count=n)
        s["total"].record_many(done - ready)

    def merge_from(self, other: "LatencyRecorder") -> None:
        for c in COMPONENTS:
            self.sketches[c].merge_from(other.sketches[c])

    @property
    def n(self) -> int:
        return self.sketches["total"].n

    def to_registry(self, registry=None, prefix: str = ""):
        from repro.serve.obs.registry import MetricsRegistry

        reg = registry if registry is not None else MetricsRegistry()
        for c in COMPONENTS:
            reg.attach_sketch(prefix + METRIC_NAMESPACE[f"latency_{c}"],
                              self.sketches[c])
        return reg

    @classmethod
    def from_registry(cls, reg, prefix: str = "") -> "LatencyRecorder":
        """Adopt a registry's latency sketches (`MetricsRegistry.merge`
        constructs fresh ones, so adoption never aliases a shard's)."""
        total = reg.sketch(prefix + METRIC_NAMESPACE["latency_total"])
        rec = cls(alpha=total.alpha, lo_s=total.lo_s, hi_s=total.hi_s)
        for c in COMPONENTS:
            rec.sketches[c] = reg.sketch(
                prefix + METRIC_NAMESPACE[f"latency_{c}"])
        return rec

    def summary(self) -> dict:
        return {c: self.sketches[c].summary() for c in COMPONENTS}


@dataclasses.dataclass
class LatencyConfig:
    """`Observability` attachment knob: per-component latency recording.

    One `LatencyRecorder` is minted *per worker* at attach time (sketch
    merges across shards are exact, so per-worker recording costs
    nothing in fidelity) and linked onto the worker's metrics block;
    the worker's `LatencyHistogram` reads the total sketch for
    exact-bound percentiles past its reservoir cap."""

    alpha: float = 0.01
    lo_s: float = 1e-9
    hi_s: float = 1e4

    def make(self) -> LatencyRecorder:
        return LatencyRecorder(alpha=self.alpha, lo_s=self.lo_s,
                               hi_s=self.hi_s)

"""Fleet-wide metrics registry (DESIGN.md §11.1).

One namespace over everything the serving stack counts. The hot paths keep
mutating plain ints (`RuntimeMetrics`) and numpy arrays (`BucketTelemetry`)
— the registry is the *reporting* layer built from them on demand, never
the mutation layer, so instrumenting costs the hot path nothing.

Metric kinds:

- **counters** — monotone ints. Snapshot/delta are exact integer
  arithmetic; merge is a sum, so it is order-independent by construction.
- **gauges** — point-in-time floats with a declared merge reduction
  (``sum`` | ``max`` | ``min`` | ``mean``). A gauge merged under ``mean``
  carries its weight so the merge stays order-independent.
- **histograms** — `LatencyHistogram` blocks. Merge folds via
  `merge_from`, the single histogram-merge primitive: bucket counts,
  min/max/sum merge exactly (commutative integer/scalar ops); only the
  capped raw-sample reservoir is order-sensitive, and snapshots therefore
  expose counts + exact scalars, never the reservoir.
- **sketches** — `LatencySketch` quantile sketches (DESIGN.md §14.1).
  Like histograms but with a bounded-relative-error percentile read and
  *no* order-sensitive state at all: counts, n and the integer-ns sum
  merge by integer addition, so merged snapshots are bit-identical under
  shard permutation.
- **sets** — e.g. dispatch shapes seen; merge is set union.
- **samples** — bounded append-only observations (batch occupancy);
  merge concatenates, and every derived statistic is permutation-
  invariant.

Names are dotted paths: ``flow_table.evictions``, ``dispatch.batches``,
``control.telemetry.rolls`` … A per-shard view prefixes ``shard3.``; the
fleet merge strips nothing — parts are merged *positionally* on equal
names, which is why `ShardedRuntime` and `controlled_replay` can report
through one path instead of three hand-rolled aggregations.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

from repro.serve.obs.latency import LatencySketch
from repro.serve.runtime.metrics import LatencyHistogram

__all__ = ["MetricsRegistry"]

_GAUGE_REDUCES = ("sum", "max", "min", "mean")


class MetricsRegistry:
    """Named counters/gauges/histograms/sets/samples with exact
    snapshot/delta semantics and order-independent cross-shard merge."""

    def __init__(self) -> None:
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, tuple[float, str, float]] = {}  # (v, reduce, w)
        self._hists: dict[str, LatencyHistogram] = {}
        self._sketches: dict[str, LatencySketch] = {}
        self._sets: dict[str, set] = {}
        self._samples: dict[str, list] = {}

    # -- writes --------------------------------------------------------------

    def inc(self, name: str, by: int = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + int(by)

    def set_counter(self, name: str, value: int) -> None:
        self._counters[name] = int(value)

    def set_gauge(self, name: str, value: float, *, reduce: str = "sum",
                  weight: float = 1.0) -> None:
        if reduce not in _GAUGE_REDUCES:
            raise ValueError(f"unknown gauge reduce {reduce!r}")
        self._gauges[name] = (float(value), reduce, float(weight))

    def attach_hist(self, name: str, hist: LatencyHistogram) -> None:
        """Register a live histogram block (not copied: snapshots copy)."""
        self._hists[name] = hist

    def attach_sketch(self, name: str, sketch: LatencySketch) -> None:
        """Register a live quantile sketch (not copied: snapshots copy)."""
        self._sketches[name] = sketch

    def union(self, name: str, items: Iterable) -> None:
        self._sets.setdefault(name, set()).update(items)

    def extend_samples(self, name: str, values: Sequence) -> None:
        self._samples.setdefault(name, []).extend(values)

    # -- reads ---------------------------------------------------------------

    def counter(self, name: str) -> int:
        return self._counters.get(name, 0)

    def gauge(self, name: str) -> float:
        return self._gauges[name][0]

    def hist(self, name: str) -> LatencyHistogram:
        return self._hists[name]

    def sketch(self, name: str) -> LatencySketch:
        return self._sketches[name]

    def sketch_names(self) -> list[str]:
        return sorted(self._sketches)

    def names(self) -> list[str]:
        return sorted(
            set(self._counters) | set(self._gauges) | set(self._hists)
            | set(self._sketches) | set(self._sets) | set(self._samples)
        )

    # -- snapshot / delta ----------------------------------------------------

    def snapshot(self) -> dict:
        """Frozen, JSON-friendly view: exact ints, gauge floats, histogram
        counts + exact scalars (never the reservoir), sorted set members,
        copied sample lists. Two snapshots of an untouched registry are
        equal; `delta` between snapshots is exact."""
        hists = {}
        for name, h in self._hists.items():
            hists[name] = {
                "n": int(h.n),
                "counts": h.counts().tolist(),
                "min_s": float(h._min) if h.n else 0.0,
                "max_s": float(h._max),
                "sum_s": float(h._sum),
            }
        return {
            "counters": dict(self._counters),
            "gauges": {k: {"value": v, "reduce": r, "weight": w}
                       for k, (v, r, w) in self._gauges.items()},
            "hists": hists,
            "sketches": {k: sk.to_doc() for k, sk in self._sketches.items()},
            "sets": {k: sorted(map(_set_key, v)) for k, v in self._sets.items()},
            "samples": {k: list(v) for k, v in self._samples.items()},
        }

    @staticmethod
    def delta(cur: dict, prev: dict) -> dict:
        """Exact difference between two snapshots of the same registry.

        Counters and histogram counts subtract (ints, so the delta over an
        interval is exactly the interval's activity); samples return the
        appended tail; sets return the new members; gauges report
        (cur - prev) of the value. ``delta(snap, snap)`` is all-zero."""
        out = {
            "counters": {
                k: v - prev.get("counters", {}).get(k, 0)
                for k, v in cur.get("counters", {}).items()
            },
            "gauges": {
                k: g["value"] - prev.get("gauges", {}).get(k, {}).get("value", 0.0)
                for k, g in cur.get("gauges", {}).items()
            },
            "hists": {},
            "sketches": {},
            "sets": {},
            "samples": {},
        }
        for k, h in cur.get("hists", {}).items():
            p = prev.get("hists", {}).get(k)
            if p is None:
                out["hists"][k] = dict(h)
            else:
                out["hists"][k] = {
                    "n": h["n"] - p["n"],
                    "counts": (np.asarray(h["counts"])
                               - np.asarray(p["counts"])).tolist(),
                    "sum_s": h["sum_s"] - p["sum_s"],
                    # min/max are lifetime extrema, not interval ones
                    "min_s": h["min_s"],
                    "max_s": h["max_s"],
                }
        for k, s in cur.get("sketches", {}).items():
            p = prev.get("sketches", {}).get(k)
            if p is None:
                out["sketches"][k] = dict(s)
            else:
                diff = dict(s.get("counts", []))
                for i, c in p.get("counts", []):
                    diff[i] = diff.get(i, 0) - c
                out["sketches"][k] = {
                    **{f: s[f] for f in ("alpha", "lo_s", "hi_s")},
                    "n": s["n"] - p["n"],
                    "sum_ns": s["sum_ns"] - p["sum_ns"],
                    # min/max are lifetime extrema, not interval ones
                    "min_s": s["min_s"],
                    "max_s": s["max_s"],
                    "counts": [[i, c] for i, c in sorted(diff.items()) if c],
                }
        for k, s in cur.get("sets", {}).items():
            before = set(map(tuple_or_id, prev.get("sets", {}).get(k, [])))
            out["sets"][k] = [x for x in s if tuple_or_id(x) not in before]
        for k, v in cur.get("samples", {}).items():
            n_prev = len(prev.get("samples", {}).get(k, []))
            out["samples"][k] = list(v[n_prev:])
        return out

    # -- merge ---------------------------------------------------------------

    @classmethod
    def merge(cls, parts: "Sequence[MetricsRegistry]",
              prefixes: Optional[Sequence[str]] = None) -> "MetricsRegistry":
        """Order-independent cross-shard merge.

        Counters sum, gauges fold under their declared reduction, sets
        union, samples concatenate (statistics over them are permutation-
        invariant), histograms fold into a *fresh* block via `merge_from`
        — the parts are never aliased or mutated, so merging is a pure
        read. With `prefixes` (one per part), each part's metrics are
        *additionally* kept under ``{prefix}{name}`` so the merged
        registry carries both the fleet totals and the per-shard columns
        (``shard3.ingest.drops_ring`` …) in one namespace."""
        if prefixes is not None and len(prefixes) != len(parts):
            raise ValueError("prefixes must match parts 1:1")
        agg = cls()
        for idx, part in enumerate(parts):
            for k, v in part._counters.items():
                agg._counters[k] = agg._counters.get(k, 0) + v
            for k, (v, r, w) in part._gauges.items():
                agg._gauges[k] = _fold_gauge(agg._gauges.get(k), v, r, w)
            for k, h in part._hists.items():
                if k not in agg._hists:
                    agg._hists[k] = LatencyHistogram(
                        lo_s=h.lo_s, hi_s=h.hi_s, max_samples=h.max_samples)
                agg._hists[k].merge_from(h)
            for k, sk in part._sketches.items():
                if k not in agg._sketches:
                    agg._sketches[k] = LatencySketch(
                        alpha=sk.alpha, lo_s=sk.lo_s, hi_s=sk.hi_s)
                agg._sketches[k].merge_from(sk)
            for k, s in part._sets.items():
                agg._sets.setdefault(k, set()).update(s)
            for k, v in part._samples.items():
                agg._samples.setdefault(k, []).extend(v)
            if prefixes is not None:
                p = prefixes[idx]
                for k, v in part._counters.items():
                    agg._counters[p + k] = agg._counters.get(p + k, 0) + v
                for k, (v, r, w) in part._gauges.items():
                    agg._gauges[p + k] = _fold_gauge(
                        agg._gauges.get(p + k), v, r, w)
        return agg


def _fold_gauge(cur: Optional[tuple], v: float, r: str, w: float) -> tuple:
    if cur is None:
        return (v, r, w)
    cv, cr, cw = cur
    if cr != r:
        raise ValueError(f"gauge reduce mismatch: {cr!r} vs {r!r}")
    if r == "sum":
        return (cv + v, r, cw + w)
    if r == "max":
        return (max(cv, v), r, cw + w)
    if r == "min":
        return (min(cv, v), r, cw + w)
    # weighted mean: commutative + associative, so order-independent
    return ((cv * cw + v * w) / max(cw + w, 1e-300), r, cw + w)


def _set_key(x):
    """Sortable JSON-friendly form of a set member (tuples -> lists)."""
    return list(x) if isinstance(x, tuple) else x


def tuple_or_id(x):
    """Hashable identity for snapshot set members (lists -> tuples)."""
    return tuple(x) if isinstance(x, list) else x

"""Windowed SLO tracking on the replay packet clock (DESIGN.md §14.2).

An SLO here is "fraction of flows whose enqueue→prediction latency is
within ``target_s`` must be at least ``objective``" — attainment, not a
single percentile, so it composes across windows and shards by integer
addition. `SLOTracker` buckets every charged flow into fixed windows of
the *virtual* packet clock (the same `now_pkts` timeline the control
plane steps on), and `check` folds them into the two-window burn-rate
form of error-budget accounting:

- the **fast** window (the current window) catches an ongoing breach
  quickly;
- the **slow** window (the last `slow_windows` windows) filters
  one-window blips.

``burn = violation_fraction / (1 - objective)`` — burn 1.0 means the
error budget is being spent exactly at the rate that would exhaust it,
sustained. A breach verdict requires *both* burns at or above
``burn_threshold`` with at least one sample in the fast window; the
tracker reports rising edges (``new_breach``) so `ControlPlane` audits
one ``"slo"`` event per episode, not one per control step.

All mutable state is per-window integer pairs ``(total, violations)``
plus lifetime counters, so a single tracker can be shared by every
shard's `_WorkerClock` and `merge_from` is order-independent like the
rest of the registry.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.serve.runtime.metrics import METRIC_NAMESPACE

__all__ = ["SLOConfig", "SLOTracker", "SLOVerdict"]


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Latency objective on the replay packet clock.

    `target_s` is the per-flow latency bound; `objective` the required
    attainment (0.99 = "p99 within target"); `window_s` the fast-window
    length in *virtual* seconds — size it to the replayed trace span
    (smoke traces cover well under a second of virtual time)."""

    target_s: float
    objective: float = 0.99
    window_s: float = 0.05
    slow_windows: int = 8
    burn_threshold: float = 1.0

    def __post_init__(self):
        if self.target_s <= 0:
            raise ValueError(f"target_s must be > 0, got {self.target_s}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError(f"objective must be in (0, 1), got {self.objective}")
        if self.window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {self.window_s}")
        if self.slow_windows < 1:
            raise ValueError(f"slow_windows must be >= 1, got {self.slow_windows}")


@dataclasses.dataclass(frozen=True)
class SLOVerdict:
    """One `check` result. `new_breach` is True only on the rising edge
    into breach, so audit consumers fire once per episode."""

    breached: bool
    new_breach: bool
    attainment_fast: float
    attainment_slow: float
    burn_fast: float
    burn_slow: float
    samples_fast: int
    samples_slow: int
    target_s: float
    objective: float

    def to_doc(self) -> dict:
        d = dataclasses.asdict(self)
        for k, v in d.items():
            if isinstance(v, float):
                d[k] = round(v, 6)
        return d


class SLOTracker:
    """Shared, mergeable attainment/burn-rate accountant.

    `note` is the hot-path write: one float compare + two dict adds per
    charged batch. `check` (control-step cadence) is the only reader
    and the only place breach state transitions."""

    def __init__(self, config: SLOConfig):
        self.config = config
        self._total: dict[int, int] = {}
        self._viol: dict[int, int] = {}
        self.samples = 0
        self.violations = 0
        self.checks = 0
        self.breaches = 0          # rising edges seen by check()
        self._breached = False

    # -- writes --------------------------------------------------------------

    def note(self, done_s: float, latency_s: np.ndarray) -> None:
        """Account one resolved batch: all flows in it complete at
        `done_s` on the packet clock, so they share a window."""
        lat = np.asarray(latency_s, np.float64)
        n = int(lat.size)
        if n == 0:
            return
        v = int((lat > self.config.target_s).sum())
        w = int(math.floor(done_s / self.config.window_s))
        self._total[w] = self._total.get(w, 0) + n
        self.samples += n
        if v:
            self._viol[w] = self._viol.get(w, 0) + v
            self.violations += v

    def merge_from(self, other: "SLOTracker") -> None:
        """Integer window adds — order-independent. Breach edge state is
        deliberately not merged; merged trackers are reporting views."""
        if other.config != self.config:
            raise ValueError(
                f"SLO config mismatch: {other.config} vs {self.config}")
        for w, n in other._total.items():
            self._total[w] = self._total.get(w, 0) + n
        for w, v in other._viol.items():
            self._viol[w] = self._viol.get(w, 0) + v
        self.samples += other.samples
        self.violations += other.violations

    # -- reads ---------------------------------------------------------------

    def _span(self, w_hi: int, k: int) -> tuple[int, int]:
        """(total, violations) over window indices [w_hi - k + 1, w_hi]."""
        lo = w_hi - k + 1
        tot = sum(n for w, n in self._total.items() if lo <= w <= w_hi)
        if tot == 0:
            return 0, 0
        bad = sum(v for w, v in self._viol.items() if lo <= w <= w_hi)
        return tot, bad

    def check(self, now_s: float) -> SLOVerdict:
        """Fold windows ending at `now_s` into a burn-rate verdict and
        advance the breach edge state."""
        cfg = self.config
        w_hi = int(math.floor(now_s / cfg.window_s))
        tot_f, bad_f = self._span(w_hi, 1)
        tot_s, bad_s = self._span(w_hi, cfg.slow_windows)
        budget = 1.0 - cfg.objective
        frac_f = bad_f / tot_f if tot_f else 0.0
        frac_s = bad_s / tot_s if tot_s else 0.0
        burn_f = frac_f / budget
        burn_s = frac_s / budget
        breached = (tot_f > 0 and burn_f >= cfg.burn_threshold
                    and burn_s >= cfg.burn_threshold)
        new = breached and not self._breached
        if new:
            self.breaches += 1
        self._breached = breached
        self.checks += 1
        return SLOVerdict(
            breached=breached,
            new_breach=new,
            attainment_fast=1.0 - frac_f,
            attainment_slow=1.0 - frac_s,
            burn_fast=burn_f,
            burn_slow=burn_s,
            samples_fast=tot_f,
            samples_slow=tot_s,
            target_s=cfg.target_s,
            objective=cfg.objective,
        )

    @property
    def attainment(self) -> float:
        """Lifetime attainment across all windows."""
        return 1.0 - self.violations / self.samples if self.samples else 1.0

    def windows(self) -> list[tuple[int, int, int]]:
        """Sorted (window_index, total, violations) rows."""
        return [(w, n, self._viol.get(w, 0))
                for w, n in sorted(self._total.items())]

    def signal(self) -> dict:
        """Compact JSON-able state for snapshots and JSONL export."""
        return {
            "target_s": self.config.target_s,
            "objective": self.config.objective,
            "window_s": self.config.window_s,
            "samples": self.samples,
            "violations": self.violations,
            "attainment": round(self.attainment, 6),
            "breaches": self.breaches,
            "breached": self._breached,
            "windows": [[w, n, v] for w, n, v in self.windows()],
        }

    def to_registry(self, registry=None, prefix: str = ""):
        """Project lifetime counters + current verdict-shape gauges into
        a `MetricsRegistry` under the `slo.*` namespace."""
        from repro.serve.obs.registry import MetricsRegistry

        reg = registry if registry is not None else MetricsRegistry()
        ns = METRIC_NAMESPACE
        reg.inc(prefix + ns["slo_samples"], self.samples)
        reg.inc(prefix + ns["slo_violations"], self.violations)
        reg.inc(prefix + ns["slo_breaches"], self.breaches)
        reg.set_gauge(prefix + ns["slo_attainment"], self.attainment,
                      reduce="min")
        reg.set_gauge(prefix + ns["slo_breached"],
                      1.0 if self._breached else 0.0, reduce="max")
        return reg

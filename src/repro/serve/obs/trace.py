"""Bounded ring-buffer span tracer on the replay packet clock
(DESIGN.md §11.2).

Spans are recorded against *virtual* time — the same two-lane
`_WorkerClock` seconds every latency number already uses — so a trace of
a replay is exactly as deterministic as the replay itself. Two span
families:

- **worker stage spans** (Chrome ``ph: "X"`` complete events): per-block
  ingest service envelopes and per-batch inference service, charged by
  `_WorkerClock` on the lane that served them. ``pid`` is the shard,
  ``tid`` the lane (0 = ingest, 1 = inference, 2 = control).
- **flow lifecycle spans** (Chrome async ``b``/``n``/``e`` events keyed
  by flow id): ingest (first packet) → ready → flush (with reason) →
  emit (prediction resolved at the inference-lane completion edge).

Storage is a preallocated numpy ring of `capacity` events — recording
never allocates per event on the vectorized path and never grows; once
the ring wraps, the oldest events fall off (``dropped`` counts them).
Flows are sampled at `sample` by a deterministic hash threshold on the
flow id, so a 1% trace keeps *whole* lifecycles, never partial ones, and
two replays of the same stream sample the same flows.

`chrome()` exports the Chrome trace-event JSON (``chrome://tracing`` /
Perfetto load it directly); timestamps are exported in microseconds.

Tracing is **off by default** everywhere: every hook site guards on
``tracer is not None`` and the tracer itself no-ops when
``enabled=False``, so the untraced hot path pays one attribute test.
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional

import numpy as np

__all__ = ["Tracer", "TID_INGEST", "TID_INFER", "TID_CONTROL", "TID_TENANT0"]

TID_INGEST = 0
TID_INFER = 1
TID_CONTROL = 2
# multi-tenant serving (DESIGN.md §15): per-tenant infer sub-lanes start
# here — tenant t's share of each fused batch lands on tid TID_TENANT0 + t
TID_TENANT0 = 3

_TID_NAMES = {TID_INGEST: "ingest lane", TID_INFER: "inference lane",
              TID_CONTROL: "control plane"}

# event phases, packed as u1
_PH_X, _PH_B, _PH_E, _PH_N, _PH_I = 0, 1, 2, 3, 4
_PH_CHR = {_PH_X: "X", _PH_B: "b", _PH_E: "e", _PH_N: "n", _PH_I: "i"}


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer: uniform u64 from flow ids (sampling hash)."""
    x = np.asarray(x).astype(np.uint64, copy=True)
    x += np.uint64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


class Tracer:
    def __init__(
        self,
        capacity: int = 1 << 16,
        sample: float = 1.0,
        enabled: bool = True,
        seed: int = 0,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if not 0.0 <= sample <= 1.0:
            raise ValueError("sample must be in [0, 1]")
        self.capacity = int(capacity)
        self.sample = float(sample)
        self.enabled = bool(enabled)
        # threshold comparison against the mixed id; seed shifts the hash
        # so distinct tracers can sample distinct flow subsets
        self._seed = np.uint64(seed)
        self._thresh = np.uint64(min(int(sample * float(2**64)), 2**64 - 1))
        self._sample_all = sample >= 1.0
        cap = self.capacity
        self._ph = np.zeros(cap, np.uint8)
        self._name = np.zeros(cap, np.int32)
        self._ts = np.zeros(cap, np.float64)    # virtual seconds
        self._dur = np.zeros(cap, np.float64)
        self._pid = np.zeros(cap, np.int32)
        self._tid = np.zeros(cap, np.int32)
        self._id = np.zeros(cap, np.int64)      # flow id for async events
        self._names: list[str] = []
        self._intern: dict[str, int] = {}
        self.total = 0                           # events ever recorded

    # -- bookkeeping ---------------------------------------------------------

    def __len__(self) -> int:
        return min(self.total, self.capacity)

    @property
    def dropped(self) -> int:
        """Events that fell off the ring (oldest-first)."""
        return max(0, self.total - self.capacity)

    def _name_id(self, name: str) -> int:
        i = self._intern.get(name)
        if i is None:
            i = len(self._names)
            self._names.append(name)
            self._intern[name] = i
        return i

    def _slots(self, k: int) -> np.ndarray:
        idx = (self.total + np.arange(k)) % self.capacity
        self.total += k
        return idx

    # -- sampling ------------------------------------------------------------

    def sample_mask(self, flow_ids: np.ndarray) -> np.ndarray:
        """Deterministic per-flow keep mask at the configured rate."""
        if self._sample_all:
            return np.ones(len(flow_ids), bool)
        if self.sample <= 0.0:
            return np.zeros(len(flow_ids), bool)
        return _mix64(np.asarray(flow_ids, np.int64) + np.int64(self._seed)) \
            < self._thresh

    # -- recording (vectorized; every method no-ops when disabled) -----------

    def span(self, name: str, ts: float, dur: float, *, pid: int = 0,
             tid: int = 0) -> None:
        if not self.enabled:
            return
        self.span_many(name, np.asarray([ts]), np.asarray([dur]),
                       pid=pid, tid=tid)

    def span_many(self, name: str, ts: np.ndarray, dur: np.ndarray, *,
                  pid: int = 0, tid: int = 0) -> None:
        """One ``X`` complete event per (ts, dur) pair."""
        if not self.enabled or len(ts) == 0:
            return
        idx = self._slots(len(ts))
        self._ph[idx] = _PH_X
        self._name[idx] = self._name_id(name)
        self._ts[idx] = ts
        self._dur[idx] = np.maximum(dur, 0.0)
        self._pid[idx] = pid
        self._tid[idx] = tid
        self._id[idx] = -1

    def instant(self, name: str, now_pkts: float, *, pid: int = 0,
                tid: int = 0) -> None:
        """One point event at `now_pkts` on the replay packet clock (the
        canonical unit definition lives in `repro.serve.control.plane`)."""
        if not self.enabled:
            return
        idx = self._slots(1)
        self._ph[idx] = _PH_I
        self._name[idx] = self._name_id(name)
        self._ts[idx] = now_pkts
        self._dur[idx] = 0.0
        self._pid[idx] = pid
        self._tid[idx] = tid
        self._id[idx] = -1

    def _flow_event(self, ph: int, name: str, ids: np.ndarray,
                    ts: np.ndarray, pid: int) -> None:
        if not self.enabled or len(ids) == 0:
            return
        idx = self._slots(len(ids))
        self._ph[idx] = ph
        self._name[idx] = self._name_id(name)
        self._ts[idx] = ts
        self._dur[idx] = 0.0
        self._pid[idx] = pid
        self._tid[idx] = TID_INGEST
        self._id[idx] = np.asarray(ids, np.int64)

    def flow_begin(self, ids: np.ndarray, ts: np.ndarray, *,
                   pid: int = 0) -> None:
        """Open one async lifecycle span per flow at its first-packet time."""
        self._flow_event(_PH_B, "flow", ids, ts, pid)

    def flow_mark(self, name: str, ids: np.ndarray, ts: np.ndarray, *,
                  pid: int = 0) -> None:
        """Milestone inside open lifecycles (ready / flush.reason / ...)."""
        self._flow_event(_PH_N, name, ids, ts, pid)

    def flow_end(self, ids: np.ndarray, ts: np.ndarray, *,
                 pid: int = 0) -> None:
        """Close lifecycles at the prediction-emit edge."""
        self._flow_event(_PH_E, "flow", ids, ts, pid)

    # -- export --------------------------------------------------------------

    def events(self) -> list[dict]:
        """Ring contents in record order as Chrome trace-event dicts."""
        n = len(self)
        if n == 0:
            return []
        if self.total <= self.capacity:
            order = np.arange(n)
        else:  # wrapped: oldest surviving event first
            order = (self.total + np.arange(self.capacity)) % self.capacity
        out = []
        for i in order:
            ph = int(self._ph[i])
            ev = {
                "name": self._names[int(self._name[i])],
                "ph": _PH_CHR[ph],
                "ts": float(self._ts[i]) * 1e6,   # Chrome wants microseconds
                "pid": int(self._pid[i]),
                "tid": int(self._tid[i]),
            }
            if ph == _PH_X:
                ev["dur"] = float(self._dur[i]) * 1e6
            elif ph == _PH_I:
                ev["s"] = "t"
            else:  # async lifecycle event
                ev["cat"] = "flow"
                ev["id"] = int(self._id[i])
            out.append(ev)
        return out

    def chrome(self) -> dict:
        """Full Chrome trace-event document (with lane/shard labels)."""
        meta = []
        pids = sorted({int(p) for p in
                       self._pid[: len(self)].tolist()}) if len(self) else []
        tids = sorted({int(t) for t in self._tid[: len(self)].tolist()}) \
            if len(self) else []
        for pid in pids:
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "args": {"name": f"shard {pid}"}})
            for tid, label in _TID_NAMES.items():
                meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                             "tid": tid, "args": {"name": label}})
            for tid in tids:
                if tid >= TID_TENANT0:
                    meta.append({
                        "ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid,
                        "args": {"name": f"tenant {tid - TID_TENANT0} infer"},
                    })
        return {
            "traceEvents": meta + self.events(),
            "displayTimeUnit": "ms",
            "otherData": {
                "clock": "virtual (replay packet clock)",
                "sample_rate": self.sample,
                "events_recorded": self.total,
                "events_dropped": self.dropped,
            },
        }

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.chrome()) + "\n")
        return path

    def summary(self) -> Optional[dict]:
        if self.total == 0:
            return None
        return {
            "events": self.total,
            "retained": len(self),
            "dropped": self.dropped,
            "capacity": self.capacity,
            "sample": self.sample,
        }

"""Streaming serving runtime: online flow table, micro-batched dispatch,
offered-load replay, and zero-loss throughput measurement (DESIGN.md §6).

Turns the batch `ServingPipeline` into a continuous online service:

    packet blocks -> FlowTable.observe_batch -> MicroBatchDispatcher
                  -> staging arenas -> fused Pallas pipeline -> labels

Ingest is vectorized (`StreamingRuntime.ingest_packets` drives whole
delivery-ordered blocks through numpy fast paths, bit-equivalent to the
scalar cadence — DESIGN.md §7), dispatch stages batches in preallocated
per-bucket arenas, and `replay`/`find_zero_loss_rate` reproduce the
paper's Fig. 5c zero-loss throughput as a measurement over live packet
streams rather than a modeled drain rate.
"""
from .dispatch import BatchRecord, MicroBatchDispatcher, StreamingRuntime, next_bucket
from .flow_table import FlowStatus, FlowTable, tuple_hash64
from .metrics import LatencyHistogram, RuntimeMetrics
from .replay import (
    PacketStream,
    ReplayStats,
    ServiceModel,
    find_zero_loss_rate,
    replay,
)

__all__ = [
    "BatchRecord",
    "FlowStatus",
    "FlowTable",
    "LatencyHistogram",
    "MicroBatchDispatcher",
    "PacketStream",
    "ReplayStats",
    "RuntimeMetrics",
    "ServiceModel",
    "StreamingRuntime",
    "find_zero_loss_rate",
    "next_bucket",
    "replay",
    "tuple_hash64",
]

"""Streaming serving runtime: online flow table, micro-batched dispatch,
offered-load replay, and zero-loss throughput measurement (DESIGN.md §6).

Turns the batch `ServingPipeline` into a continuous online service:

    packet blocks -> FlowTable.observe_batch -> MicroBatchDispatcher
                  -> staging arenas -> fused Pallas pipeline -> labels

Ingest is vectorized (`StreamingRuntime.ingest_packets` drives whole
delivery-ordered blocks through numpy fast paths, bit-equivalent to the
scalar cadence — DESIGN.md §7), dispatch stages batches in preallocated
per-bucket arenas, and `replay`/`find_zero_loss_rate` reproduce the
paper's Fig. 5c zero-loss throughput as a measurement over live packet
streams rather than a modeled drain rate.

Horizontal scale is `ShardedRuntime` (DESIGN.md §8): n independent
workers behind RSS-style symmetric 5-tuple steering, per-shard
tables/dispatch/metrics with an aggregate view, and sharded zero-loss
replay where a drop on any shard fails the trial — bit-identical
predictions to the single-worker path by construction.
"""
from .dispatch import (
    BatchRecord,
    MicroBatchDispatcher,
    ReuseConfig,
    StreamingRuntime,
    next_bucket,
)
from .flow_table import (
    FlowStatus,
    FlowTable,
    move_slot,
    symmetric_tuple_hash64,
    tuple_hash64,
)
from .metrics import LatencyHistogram, RuntimeMetrics
from .shard import AggregateMetrics, ShardedRuntime, stream_buckets
from .replay import (
    PacketStream,
    ReplayStats,
    ServiceModel,
    find_zero_loss_rate,
    replay,
)
# multi-tenant white-box serving (DESIGN.md §15): the shared pipeline is
# built by the traffic layer but served by this runtime, so the runtime
# namespace re-exports it alongside the single-tenant machinery
from repro.traffic.multi_tenant import (
    MultiTenantPipeline,
    build_multi_tenant_pipeline,
)

__all__ = [
    "AggregateMetrics",
    "BatchRecord",
    "FlowStatus",
    "FlowTable",
    "LatencyHistogram",
    "MicroBatchDispatcher",
    "MultiTenantPipeline",
    "PacketStream",
    "ReplayStats",
    "ReuseConfig",
    "RuntimeMetrics",
    "ServiceModel",
    "ShardedRuntime",
    "StreamingRuntime",
    "build_multi_tenant_pipeline",
    "find_zero_loss_rate",
    "move_slot",
    "next_bucket",
    "replay",
    "stream_buckets",
    "symmetric_tuple_hash64",
    "tuple_hash64",
]

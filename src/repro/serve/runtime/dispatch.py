"""Micro-batched dispatch: shape-bucketed, double-buffered (DESIGN.md §6).

InferLine's lesson applies unchanged to traffic pipelines: the model is not
the serving system — between the flow table and the jit-specialized
pipeline there has to be a queueing/batching layer with explicit policies.

Two policies matter here:

- **Shape bucketing.** ``jax.jit`` specializes on input *shape*; if every
  micro-batch were submitted at its natural size, a replay would compile a
  fresh XLA executable per distinct batch size. Batches are therefore
  padded up to power-of-two buckets in ``[min_bucket, max_batch]``: at most
  ``log2(max_batch / min_bucket) + 1`` executables exist over any run, and
  every one is compiled at most once (jit specialization as conditional
  compilation, DESIGN.md §3 — here specialized over *batch geometry*
  instead of feature sets). Padding rows have ``flow_len == 0`` so every
  masked reduction sees an empty flow; their predictions are discarded.

- **Double-buffered async submit.** ``predict_async`` returns an
  unresolved device array; the dispatcher keeps up to ``max_pending``
  batches in flight and only blocks (``finalize``) when the window is
  full. Extraction + inference of batch *k* overlap accumulation of batch
  *k+1* — the ingest thread never waits for the accelerator unless it is
  more than a full batch ahead.

The flush hot path is allocation-free (DESIGN.md §7): the ready queue is an
array-backed FIFO drained by slicing (no per-item popleft), and each shape
bucket owns ``max_pending + 1`` preallocated **staging arenas** —
`TrafficDataset`s whose tensors are reused round-robin across flushes
(flags staged as float32, so the extraction engine never converts on the
hot path). The rotation depth is the donation-safety contract: the XLA CPU
client may alias host buffers zero-copy at submit, so an arena is only
reused once its batch has provably left the pending window.

Flushes trigger on depth (``max_batch`` flows ready), on timeout (oldest
ready flow waited ``flush_timeout_s``), or on drain.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.traffic.extraction import (
    AGG_WIDTH,
    emit_agg_features,
    plan_is_incremental,
    stats_plan,
)
from repro.traffic.pipeline import ServingPipeline
from repro.traffic.synth import TrafficDataset

from .flow_table import FlowStatus, FlowTable
from .metrics import RuntimeMetrics

__all__ = [
    "BatchRecord",
    "MicroBatchDispatcher",
    "ReuseConfig",
    "StreamingRuntime",
    "next_bucket",
]


@dataclasses.dataclass(frozen=True)
class ReuseConfig:
    """Drift-gated prediction reuse for long-lived flows (DESIGN.md §12).

    A PREDICTED flow that keeps receiving packets is *frozen*: ingest
    updates only its incremental aggregates, and every ``refresh_every``
    packets the dispatcher re-emits its feature vector from those
    aggregates and compares it against the anchor snapped at
    classification time. The flow is re-inferred only when the relative
    drift of any feature exceeds ``drift_threshold``; otherwise the cached
    prediction is reused. ``drift_threshold == 0`` forces re-inference at
    every refresh — predictions stay bit-identical to the non-reuse path
    (first prediction wins either way; refreshes land in
    ``live_predictions``, never in ``results``).
    """

    enabled: bool = True
    drift_threshold: float = 0.05
    refresh_every: int = 64


def next_bucket(n: int, min_bucket: int, max_batch: int) -> int:
    """Smallest power-of-two >= n, clamped to [min_bucket, max_batch]."""
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_batch)


def _timeout_boundary(t: np.ndarray, lo: int, hi: int, ref: float,
                      timeout: float) -> int:
    """First index k in [lo, hi) where the scalar flush predicate
    ``t[k] - ref >= timeout`` holds, or hi if none.

    searchsorted locates ~the threshold, then two nudges land on the exact
    float boundary of the *subtraction* form the per-packet cadence
    evaluates (which can differ from ``t >= ref + timeout`` by one ulp).
    The single source of this boundary: both the flush scan and the
    sub-block bound must agree on it or block ingest loses bit-exactness.
    """
    k = lo + int(np.searchsorted(t[lo:hi], ref + timeout, side="left"))
    while k > lo and t[k - 1] - ref >= timeout:
        k -= 1
    while k < hi and t[k] - ref < timeout:
        k += 1
    return k


class _ReadyQueue:
    """Array-backed FIFO of (slot, ready_ts): bulk push, sliced drain.

    Replaces the deque of tuples: a flush drains n entries with two slice
    copies instead of n poplefts, and enqueue accepts whole blocks. The
    backing arrays grow geometrically and compact in place when the live
    span has drifted to the tail.
    """

    __slots__ = ("_slot", "_ready", "_head", "_tail")

    def __init__(self, cap: int = 1024):
        self._slot = np.empty(cap, np.int64)
        self._ready = np.empty(cap, np.float64)
        self._head = 0
        self._tail = 0

    def __len__(self) -> int:
        return self._tail - self._head

    def head_ready(self) -> float:
        return float(self._ready[self._head])

    def _reserve(self, k: int) -> None:
        cap = self._slot.size
        n = self._tail - self._head
        if self._tail + k <= cap:
            return
        if n + k <= cap // 2:  # plenty of room once compacted
            new_cap = cap
        else:
            new_cap = cap
            while new_cap < 2 * (n + k):
                new_cap *= 2
        slot = np.empty(new_cap, np.int64)
        ready = np.empty(new_cap, np.float64)
        slot[:n] = self._slot[self._head:self._tail]
        ready[:n] = self._ready[self._head:self._tail]
        self._slot, self._ready = slot, ready
        self._head, self._tail = 0, n

    def push(self, slot: int, ready_ts: float) -> None:
        self._reserve(1)
        self._slot[self._tail] = slot
        self._ready[self._tail] = ready_ts
        self._tail += 1

    def push_many(self, slots: np.ndarray, ready_ts: np.ndarray) -> None:
        k = len(slots)
        self._reserve(k)
        self._slot[self._tail:self._tail + k] = slots
        self._ready[self._tail:self._tail + k] = ready_ts
        self._tail += k

    def pop_many(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        h = self._head
        slots = self._slot[h:h + k].copy()
        ready = self._ready[h:h + k].copy()
        self._head = h + k
        if self._head == self._tail:
            self._head = self._tail = 0
        return slots, ready


@dataclasses.dataclass
class BatchRecord:
    """One flushed micro-batch; `preds` is filled when the batch resolves."""

    flow_ids: np.ndarray       # (n_real,) external flow ids
    ready_ts: np.ndarray       # (n_real,) when each flow became dispatchable
    flush_ts: float            # when the batch left the queue
    bucket: int                # padded batch size actually submitted
    n_real: int
    reason: str                # "full" | "timeout" | "drain" | "migrate" | "swap" | "refresh"
    flush_idx: int = -1        # triggering packet index within an ingest block
    shard: int = 0             # owning worker under a ShardedRuntime
    n_checked: int = 0         # reuse: frozen flows whose drift was evaluated
    n_anchor: int = 0          # reuse: anchors snapped/re-snapped by this batch
    probs: Optional[object] = None   # in-flight device array
    preds: Optional[np.ndarray] = None
    # flow ids sampled into the trace (the replay clock closes their
    # lifecycle spans at this batch's service-completion edge); None when
    # tracing is off or no flow in the batch was sampled
    trace_ids: Optional[np.ndarray] = None


class MicroBatchDispatcher:
    def __init__(
        self,
        table: FlowTable,
        pipeline: ServingPipeline,
        *,
        max_batch: int = 256,
        min_bucket: int = 8,
        flush_timeout_s: float = 0.05,
        max_pending: int = 2,
        execute: bool = True,
        metrics: RuntimeMetrics | None = None,
        reuse: ReuseConfig | None = None,
    ):
        if max_batch & (max_batch - 1) or min_bucket & (min_bucket - 1):
            raise ValueError("max_batch and min_bucket must be powers of two")
        self.table = table
        self.pipeline = pipeline
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.flush_timeout_s = flush_timeout_s
        self.max_pending = max_pending
        self.execute = execute
        self.metrics = metrics if metrics is not None else table.metrics
        self.reuse = reuse  # active (already plan-gated) config, or None
        self._agg_plan = (
            stats_plan(pipeline.rep.features) if reuse is not None else None)
        self._agg_arenas: dict[int, tuple] = {}
        self._queue = _ReadyQueue()
        self._pending: deque[BatchRecord] = deque()
        self._arenas: dict[int, list[TrafficDataset]] = {}
        self._arena_turn: dict[int, int] = {}
        self._flag_scratch: dict[int, np.ndarray] = {}
        self.results: dict[int, object] = {}  # flow_id -> predicted class
        # refreshed predictions for still-live frozen flows: `results` keeps
        # first-prediction-wins semantics (bit-identical to non-reuse runs),
        # so drift-triggered re-inferences land here instead
        self.live_predictions: dict[int, object] = {}
        self.records: list[BatchRecord] = []
        # observability hooks (repro.serve.obs): attribute injection, off
        # by default — the untraced hot path pays one `is not None` test
        self.tracer = None          # obs.Tracer
        self.drift = None           # obs.DriftMonitor
        self.trace_pid = 0          # shard id for trace process grouping

    # -- queue ---------------------------------------------------------------

    def enqueue(self, slot: int, ready_ts: float) -> None:
        self._queue.push(slot, ready_ts)

    def maybe_flush(self, now: float) -> list[BatchRecord]:
        """Flush every full batch, then at most one timeout batch."""
        out = []
        while len(self._queue) >= self.max_batch:
            out.append(self._flush(now, "full"))
        if len(self._queue) and now - self._queue.head_ready() >= self.flush_timeout_s:
            out.append(self._flush(now, "timeout"))
        return out

    def ingest_ready(
        self, statuses: np.ndarray, slots: np.ndarray, t: np.ndarray
    ) -> list[BatchRecord]:
        """Bulk equivalent of per-packet enqueue + `maybe_flush` over an
        ingest block: enqueues READY flows at their packet times and fires
        exactly the flushes (same order, reasons, and `now` values) the
        scalar cadence would. `t` must be nondecreasing (delivery order);
        each record carries `flush_idx`, the in-block index of the packet
        whose arrival triggered it (the replay clock charges the submit
        there)."""
        recs: list[BatchRecord] = []
        ready = (statuses == int(FlowStatus.READY)) | (
            statuses == int(FlowStatus.READY_EOF))
        lo = 0
        for j in np.flatnonzero(ready):
            j = int(j)
            self._timeout_scan(t, lo, j, recs)
            self._queue.push(int(slots[j]), float(t[j]))
            tj = float(t[j])
            while len(self._queue) >= self.max_batch:
                recs.append(self._flush(tj, "full", flush_idx=j))
            if len(self._queue) and tj - self._queue.head_ready() >= self.flush_timeout_s:
                recs.append(self._flush(tj, "timeout", flush_idx=j))
            lo = j + 1
        self._timeout_scan(t, lo, len(t), recs)
        return recs

    def _timeout_scan(self, t, lo: int, hi: int, recs: list) -> None:
        """Fire the timeout flushes that packets [lo, hi) would trigger:
        per packet, at most one flush of the oldest-ready batch."""
        while lo < hi and len(self._queue):
            k = _timeout_boundary(t, lo, hi, self._queue.head_ready(),
                                  self.flush_timeout_s)
            if k >= hi:
                return
            recs.append(self._flush(float(t[k]), "timeout", flush_idx=k))
            lo = k + 1

    def drain(self, now: float) -> list[BatchRecord]:
        out = []
        while len(self._queue):
            out.append(self._flush(now, "drain"))
        while self._pending:
            self._resolve(self._pending.popleft())
        return out

    def flush_queue(self, now: float, reason: str) -> list[BatchRecord]:
        """Quiesce the ready queue: flush everything queued, keep running.

        The control plane calls this before a RETA migration ("migrate")
        or a pipeline hot-swap ("swap"): afterwards no table slot is
        referenced by the queue, so flow state can move between tables
        without dangling slot ids. Unlike `drain` the pending window stays
        open — in-flight batches hold no table references (flow ids are
        copied at flush) and resolve on their own schedule.
        """
        out = []
        while len(self._queue):
            out.append(self._flush(now, reason))
        return out

    def resolve_pending(self) -> None:
        """Block until every in-flight batch has resolved (hot-swap: the
        old pipeline must finish its submitted work before it is dropped,
        or its staging arenas could be retired while XLA still reads
        them)."""
        while self._pending:
            self._resolve(self._pending.popleft())

    # -- flush mechanics -----------------------------------------------------

    def _flush(self, now: float, reason: str, flush_idx: int = -1) -> BatchRecord:
        n = min(len(self._queue), self.max_batch)
        slots, ready = self._queue.pop_many(n)
        bucket = next_bucket(n, self.min_bucket, self.max_batch)

        m = self.metrics
        m.batches += 1
        m.batch_occupancy.append(n / bucket)
        m.shapes_seen.add((bucket, self.table.pkt_depth))
        m.flows_predicted += n
        tn = getattr(self.pipeline, "n_tenants", 0)
        if tn:
            # one fused batch answers every tenant: each tenant's series
            # advances by the full batch (per-model attribution, §15.4)
            for t_i in range(tn):
                m.tenant_predictions[t_i] = (
                    m.tenant_predictions.get(t_i, 0) + n)
        if reason == "full":
            m.flushes_full += 1
        elif reason == "timeout":
            m.flushes_timeout += 1
        elif reason == "migrate":
            m.flushes_migrate += 1
        elif reason == "swap":
            m.flushes_swap += 1
        else:
            m.flushes_drain += 1

        rec = BatchRecord(
            flow_ids=self.table.ctrl["flow_id"][slots].copy(),
            ready_ts=ready,
            flush_ts=now,
            bucket=bucket,
            n_real=n,
            reason=reason,
            flush_idx=flush_idx,
        )
        tr = self.tracer
        if tr is not None and tr.enabled:
            # sampled flow lifecycles: begin at first packet, milestones at
            # ready and flush (vectorized per batch; slots still hold their
            # ctrl rows — mark_predicted below may recycle them). The
            # replay clock closes these spans at the batch's service edge.
            keep = tr.sample_mask(rec.flow_ids)
            if keep.any():
                ids = rec.flow_ids[keep]
                pid = self.trace_pid
                tr.flow_begin(ids, self.table.ctrl["first_ts"][slots[keep]],
                              pid=pid)
                tr.flow_mark("ready", ids, ready[keep], pid=pid)
                tr.flow_mark(f"flush.{reason}", ids,
                             np.full(len(ids), now), pid=pid)
                rec.trace_ids = ids
        if self.execute:
            ds = self.gather(slots, bucket)
            if self.drift is not None:
                # covariate-shift sketch: three cheap per-flow summaries
                # reduced batch-at-once from the staged arena (obs.drift)
                L = np.asarray(ds.flow_len[:n], np.float64)
                Lc = np.maximum(L, 1.0)
                self.drift.note_features(np.stack([
                    L,
                    ds.size[:n].sum(axis=1, dtype=np.float64) / Lc,
                    ds.ts[:n].max(axis=1).astype(np.float64),
                ], axis=1))
            # retire the oldest in-flight batch before submitting a new one:
            # at most `max_pending` batches overlap ingest at any time
            while len(self._pending) >= self.max_pending:
                self._resolve(self._pending.popleft())
            rec.probs = self.pipeline.predict_async(ds)
            self._pending.append(rec)
        if self.reuse is not None and n:
            # snap the drift anchor at classification time, before
            # mark_predicted: slots that recycle (FIN already seen) get the
            # anchor cleared again by `_clear_slot`, so only flows that
            # actually stay resident carry one
            self._snap_anchors(slots)
            rec.n_anchor = n
        # slots are safe to reuse once gathered (or immediately in timing-only
        # mode): finished flows recycle now, the rest become PREDICTED
        self.table.mark_predicted(slots)
        self.records.append(rec)
        return rec

    # -- drift-gated prediction reuse (DESIGN.md §12) ------------------------

    def _agg_features(self, slots: np.ndarray) -> np.ndarray:
        """Feature matrix (n, F) float32 emitted from the incremental
        aggregates — same `stats_plan` columns the window path computes."""
        t = self.table
        if t._abuf_n and t._ab_has[slots].any():
            # packets of these slots may still be staged in the fold arena
            # (every packet of a reuse table defers): their aggregates must
            # be current before anchoring or drift-checking against them
            t.flush_agg()
        cols = emit_agg_features(
            self._agg_plan, t.agg[slots],
            proto=t.proto[slots], s_port=t.s_port[slots],
            d_port=t.d_port[slots],
        )
        return np.stack([np.asarray(c, np.float32) for c in cols], axis=1)

    def _snap_anchors(self, slots: np.ndarray) -> np.ndarray:
        feats = self._agg_features(slots)
        t = self.table
        t.anchor[slots] = feats
        t.anchor_valid[slots] = True
        return feats

    def _agg_arena(self, bucket: int) -> tuple:
        """Padded staging block for `predict_agg`. Pad rows stay all-zero:
        a zero aggregate row has every count at 0, so the emitter's masked
        reductions produce a well-defined all-zero feature row (discarded
        after finalize). No rotation: refresh batches resolve synchronously."""
        ar = self._agg_arenas.get(bucket)
        if ar is None:
            ar = (
                np.zeros((bucket, AGG_WIDTH), np.float64),
                np.zeros(bucket, np.float32),
                np.zeros(bucket, np.float32),
                np.zeros(bucket, np.float32),
            )
            self._agg_arenas[bucket] = ar
        return ar

    def flush_refresh_all(
        self, slots: np.ndarray, now: float
    ) -> list[BatchRecord]:
        """Chunk a refresh backlog to `max_batch`-sized batches. The drift
        decision is per-slot, so splitting never changes which flows
        re-infer — it only keeps each batch inside the arena/bucket bound
        (a cadence burst can make more flows due than one batch holds)."""
        return [
            self.flush_refresh(slots[i:i + self.max_batch], now)
            for i in range(0, len(slots), self.max_batch)
        ]

    def flush_refresh(self, slots: np.ndarray, now: float) -> BatchRecord:
        """Evaluate drift for frozen flows whose refresh cadence fired and
        re-infer only the ones past the threshold (threshold 0 ⇒ all).

        Refreshed predictions go to `live_predictions` — `results` keeps
        first-prediction-wins, so predictions are bit-identical to the
        non-reuse path at any threshold. Anchors re-snap for every
        re-inferred flow in both execute modes, keeping the drift decision
        sequence execute-invariant (the replay's timing-only admission
        probe must walk the same refresh schedule as the executing run)."""
        cfg = self.reuse
        t = self.table
        k = len(slots)
        feats = self._agg_features(slots)
        anc = t.anchor[slots]
        valid = t.anchor_valid[slots]
        denom = np.maximum(np.abs(anc, dtype=np.float64), 1e-6)
        drift = (np.abs(feats.astype(np.float64) - anc) / denom).max(axis=1)
        re_inf = (~valid) | (drift >= cfg.drift_threshold)
        n_re = int(re_inf.sum())

        m = self.metrics
        m.reuse_hits += k - n_re
        if cfg.drift_threshold <= 0.0:
            m.forced_reinfer += n_re
        else:
            m.refreshes += n_re

        fids = t.ctrl["flow_id"][slots].copy()
        tr = self.tracer
        if tr is not None and tr.enabled:
            keep = tr.sample_mask(fids)
            pid = self.trace_pid
            for name, mask in (("reuse", keep & ~re_inf), ("refresh", keep & re_inf)):
                if mask.any():
                    tr.flow_mark(name, fids[mask],
                                 np.full(int(mask.sum()), now), pid=pid)

        bucket = next_bucket(n_re, self.min_bucket, self.max_batch) if n_re else 0
        rec = BatchRecord(
            flow_ids=fids[re_inf],
            ready_ts=np.full(n_re, now),
            flush_ts=now,
            bucket=bucket,
            n_real=n_re,
            reason="refresh",
            n_checked=k,
            n_anchor=n_re,
        )
        if n_re:
            sl_re = slots[re_inf]
            if self.execute and self.pipeline.supports_agg:
                agg, proto, sp, dp = self._agg_arena(bucket)
                agg[:n_re] = t.agg[sl_re]
                agg[n_re:] = 0.0
                proto[:n_re] = t.proto[sl_re]
                proto[n_re:] = 0.0
                sp[:n_re] = t.s_port[sl_re]
                sp[n_re:] = 0.0
                dp[:n_re] = t.d_port[sl_re]
                dp[n_re:] = 0.0
                probs = self.pipeline.predict_agg(agg, proto, sp, dp)
                preds = self.pipeline.finalize(probs)[:n_re]
                rec.preds = preds
                for fid, p in zip(rec.flow_ids, preds):
                    self.live_predictions[int(fid)] = p
            # re-anchor at the refreshed state so the next drift comparison
            # is against what was (or would have been) classified now
            self._snap_anchors(sl_re)
        self.records.append(rec)
        return rec

    def _arena(self, bucket: int) -> TrafficDataset:
        """Preallocated staging batch for this shape bucket, reused across
        flushes. Flags are staged as float32 so `extraction_fn` skips its
        per-batch convert.

        ``max_pending + 1`` arenas rotate per bucket: the XLA CPU client may
        alias host numpy buffers zero-copy instead of copying at submit, so
        a single arena could be overwritten while its batch is still in
        flight. An arena comes up for reuse only after `max_pending` further
        submissions, by which point the dispatcher has necessarily resolved
        (blocked on) its batch — no live computation can still read it."""
        ring = self._arenas.get(bucket)
        if ring is None:
            P = self.table.pkt_depth
            ring = [
                TrafficDataset(
                    ts=np.zeros((bucket, P), np.float32),
                    size=np.zeros((bucket, P), np.float32),
                    direction=np.zeros((bucket, P), np.uint8),
                    ttl=np.zeros((bucket, P), np.float32),
                    winsize=np.zeros((bucket, P), np.float32),
                    flags=np.zeros((bucket, P, 8), np.float32),
                    flow_len=np.zeros(bucket, np.int32),
                    proto=np.zeros(bucket, np.float32),
                    s_port=np.zeros(bucket, np.float32),
                    d_port=np.zeros(bucket, np.float32),
                    label=np.zeros(bucket, np.int32),
                    name="stream-arena",
                )
                for _ in range(self.max_pending + 1)
            ]
            self._arenas[bucket] = ring
            self._arena_turn[bucket] = 0
        turn = self._arena_turn[bucket]
        self._arena_turn[bucket] = (turn + 1) % len(ring)
        return ring[turn]

    def gather(self, slots: np.ndarray, bucket: int) -> TrafficDataset:
        """Fill this bucket's staging arena from table rows (allocation-free:
        every destination, including the uint8 flags scratch the float32
        cast reads through, is preallocated per bucket)."""
        t = self.table
        n = len(slots)
        ds = self._arena(bucket)
        for dst, src in (
            (ds.ts, t.ts), (ds.size, t.size), (ds.direction, t.direction),
            (ds.ttl, t.ttl), (ds.winsize, t.winsize),
        ):
            np.take(src, slots, axis=0, out=dst[:n])
            dst[n:] = 0
        scratch = self._flag_scratch.get(bucket)
        if scratch is None:
            scratch = np.zeros((bucket, t.pkt_depth, 8), np.uint8)
            self._flag_scratch[bucket] = scratch
        np.take(t.flags, slots, axis=0, out=scratch[:n])
        ds.flags[:n] = scratch[:n]     # casting copy into the staged float32
        ds.flags[n:] = 0
        ds.flow_len[:n] = t.ctrl["count"][slots]
        ds.flow_len[n:] = 0
        for dst, src in (
            (ds.proto, t.proto), (ds.s_port, t.s_port), (ds.d_port, t.d_port),
        ):
            np.take(src, slots, out=dst[:n])
            dst[n:] = 0
        return ds

    def _resolve(self, rec: BatchRecord) -> None:
        dm = self.drift
        conf = None
        if dm is not None:
            # top-class vote share = prediction confidence; materialized
            # here (one host copy per batch) only when drift is attached
            pnp = np.asarray(rec.probs)[: rec.n_real]
            sl = getattr(self.pipeline, "drift_prob_slice", None)
            if sl is not None:
                # multi-tenant lanes: confidence over tenant 0's lane only
                # — mixing per-tenant class spaces in one histogram would
                # make the drift signal meaningless (DESIGN.md §15.4)
                pnp = pnp[:, sl]
            conf = pnp.max(axis=1) / np.maximum(
                pnp.sum(axis=1), 1e-12)
        preds = self.pipeline.finalize(rec.probs)[: rec.n_real]
        rec.preds = preds
        rec.probs = None
        if dm is not None:
            dm.note_predictions(
                preds[:, 0] if preds.ndim == 2 else preds, conf)
        for fid, p in zip(rec.flow_ids, preds):
            # first prediction wins: a re-tenancy of the same 5-tuple (e.g.
            # a stray final ACK after close) must not overwrite the real
            # classification with a tail-fragment one
            if int(fid) in self.results:
                self.metrics.duplicate_predictions += 1
            else:
                self.results[int(fid)] = p


class StreamingRuntime:
    """Facade: flow table + dispatcher behind block and per-packet ingest.

    `ingest_packets` is the primary API: it feeds a delivery-ordered packet
    block through `FlowTable.observe_batch` and fires exactly the flushes
    the per-packet cadence would. `ingest_packet` is the scalar
    compatibility wrapper over the same queue/flush machinery.

    Owns no clock — callers pass `now` (wall time in live use, virtual time
    under the replay driver), which is what makes zero-loss search
    deterministic and replayable.
    """

    def __init__(
        self,
        pipeline: ServingPipeline,
        *,
        capacity: int = 2048,
        max_batch: int = 256,
        min_bucket: int = 8,
        flush_timeout_s: float = 0.05,
        idle_timeout_s: float = 60.0,
        max_pending: int = 2,
        execute: bool = True,
        pkt_depth: Optional[int] = None,
        load_factor: float = 0.5,
        rebuild_tombstone_frac: float = 0.25,
        reuse: ReuseConfig | None = None,
    ):
        self.pipeline = pipeline
        depth = pkt_depth if pkt_depth is not None else pipeline.rep.depth
        self.metrics = RuntimeMetrics()
        # the requested config is kept verbatim (hot_swap re-gates it on the
        # new plan); the *active* config additionally requires every feature
        # to be incrementally maintainable (no median-style stats)
        self.reuse_cfg = reuse
        active = self._gate_reuse(pipeline, reuse)
        self.table = FlowTable(
            capacity, depth, idle_timeout_s=idle_timeout_s,
            load_factor=load_factor,
            rebuild_tombstone_frac=rebuild_tombstone_frac,
            metrics=self.metrics,
            track_agg=active is not None,
            reuse=active is not None,
            refresh_every=active.refresh_every if active is not None else 0,
            anchor_dim=len(pipeline.rep.features) if active is not None else 0,
        )
        self.dispatcher = MicroBatchDispatcher(
            self.table,
            pipeline,
            max_batch=max_batch,
            min_bucket=min_bucket,
            flush_timeout_s=flush_timeout_s,
            max_pending=max_pending,
            execute=execute,
            metrics=self.metrics,
            reuse=active,
        )
        # per-packet frozen-fast-path mask of the last `ingest_packets`
        # block (None when reuse is off): the replay clock reads it to
        # charge frozen packets their cheaper aggregate-update cost
        self.last_frozen_mask: Optional[np.ndarray] = None

    @staticmethod
    def _gate_reuse(pipeline: ServingPipeline,
                    reuse: ReuseConfig | None) -> ReuseConfig | None:
        if reuse is None or not reuse.enabled:
            return None
        if not plan_is_incremental(stats_plan(pipeline.rep.features)):
            return None
        return reuse

    @property
    def results(self) -> dict:
        return self.dispatcher.results

    @property
    def flush_timeout_s(self) -> float:
        return self.dispatcher.flush_timeout_s

    def _sub_block_end(self, now: np.ndarray, lo: int) -> int:
        """Largest `hi` such that no flush can trigger before packet hi-1.

        A full flush needs the ready queue to reach `max_batch`, which takes
        at least (max_batch - len(queue)) READY packets; a timeout flush
        needs an arrival past head_ready + flush_timeout_s (head cannot get
        older mid-block, and a flow enqueued at t[p] >= t[lo] cannot time
        out before t[lo] + timeout does). Bounding sub-blocks this way pins
        every flush — and its table side effects (`mark_predicted`
        recycling) — to a sub-block's final packet, which is exactly where
        the per-packet cadence applies them."""
        disp = self.dispatcher
        B = len(now)
        hi = min(B, lo + (disp.max_batch - len(disp._queue)))
        ref = disp._queue.head_ready() if len(disp._queue) else float(now[lo])
        k = _timeout_boundary(now, lo, B, ref, disp.flush_timeout_s)
        return max(lo + 1, min(hi, k + 1))

    def ingest_packets(
        self, key, now, rel_ts, size, direction, ttl, winsize, flags_byte,
        proto, s_port, d_port, flow_id, fin,
    ) -> tuple[np.ndarray, np.ndarray, list[BatchRecord]]:
        """Ingest a delivery-ordered packet block (arrays of equal length).

        The block is processed in sub-blocks bounded so that a flush can
        only fire at a sub-block's final packet (`_sub_block_end`): flush
        side effects — PREDICTED marking and the slot recycling of closed
        flows — are therefore applied before any later packet is observed,
        keeping block ingest exact-equivalent to the per-packet cadence
        even under table pressure and same-block re-tenancy.

        Returns ``(statuses, accumulated, records)``: per-packet
        `FlowStatus` values, the per-packet payload/tracker cost class, and
        the micro-batches flushed while the block streamed in (each stamped
        with the triggering in-block packet index)."""
        now = np.asarray(now, np.float64)
        B = len(now)
        statuses = np.full(B, int(FlowStatus.TRACKED), np.uint8)
        accumulated = np.zeros(B, bool)
        frozen = np.zeros(B, bool) if self.table.reuse else None
        recs: list[BatchRecord] = []
        lo = 0
        while lo < B:
            hi = self._sub_block_end(now, lo)
            st, slots, acc = self.table.observe_batch(
                key[lo:hi], now[lo:hi], rel_ts[lo:hi], size[lo:hi],
                direction[lo:hi], ttl[lo:hi], winsize[lo:hi],
                flags_byte[lo:hi], proto[lo:hi], s_port[lo:hi],
                d_port[lo:hi], flow_id[lo:hi], fin[lo:hi],
            )
            statuses[lo:hi] = st
            accumulated[lo:hi] = acc
            if frozen is not None and self.table.last_frozen is not None:
                frozen[lo:hi] = self.table.last_frozen
            for rec in self.dispatcher.ingest_ready(st, slots, now[lo:hi]):
                rec.flush_idx += lo
                recs.append(rec)
            lo = hi
        self.last_frozen_mask = frozen
        if self.table.reuse and B:
            due = self.table.take_refresh_due()
            if due:
                for rec in self.dispatcher.flush_refresh_all(
                        np.asarray(due, np.int64), float(now[B - 1])):
                    rec.flush_idx = B - 1
                    recs.append(rec)
        return statuses, accumulated, recs

    def ingest_packet(
        self, key, now, rel_ts, size, direction, ttl, winsize, flags_byte,
        proto, s_port, d_port, flow_id, fin,
    ) -> tuple[FlowStatus, list[BatchRecord]]:
        status, slot = self.table.observe(
            key, now, rel_ts, size, direction, ttl, winsize, flags_byte,
            proto, s_port, d_port, flow_id, fin,
        )
        if status in (FlowStatus.READY, FlowStatus.READY_EOF):
            self.dispatcher.enqueue(slot, now)
        recs = self.dispatcher.maybe_flush(now)
        if self.table.reuse and self.table._refresh_due:
            due = self.table.take_refresh_due()
            if due:
                recs.extend(self.dispatcher.flush_refresh_all(
                    np.asarray(due, np.int64), now))
        return status, recs

    def poll(self, now: float) -> list[BatchRecord]:
        """Periodic maintenance: idle eviction + timeout flushes."""
        for slot in self.table.evict_idle(now):
            self.dispatcher.enqueue(slot, now)
        return self.dispatcher.maybe_flush(now)

    def hot_swap(self, pipeline: ServingPipeline, now: float) -> list[BatchRecord]:
        """Drain-and-swap to a new pipeline without dropping a packet
        (DESIGN.md §9.3).

        Protocol: (1) quiesce — every READY flow flushes through the *old*
        pipeline (it completed under the old configuration, so that is the
        configuration that classifies it) and the pending window resolves,
        so no computation still references the old table or arenas; (2) a
        fresh `FlowTable` + dispatcher are built at the new connection
        depth, sharing this runtime's metrics block so counters and
        latency history continue across the swap; (3) every live flow
        migrates via `move_slot` — ACTIVE flows keep accumulating into the
        new table (a flow whose accumulated prefix already meets the new
        depth becomes READY immediately), PREDICTED flows keep their
        close-tracking state so re-tenancy accounting survives the swap.

        The caller compiles/warm-ups `pipeline` beforehand (background
        compile — `ServingPipeline.warm`); this method is pure state
        motion plus at most one round of quiesce flushes.
        """
        disp = self.dispatcher
        recs = disp.flush_queue(now, "swap")
        disp.resolve_pending()
        old = self.table
        depth = pipeline.rep.depth
        # reuse re-gates on the *new* plan: a swap onto a median-bearing
        # feature set silently degrades to full recomputation
        active = self._gate_reuse(pipeline, self.reuse_cfg)
        table = FlowTable(
            old.capacity, depth, idle_timeout_s=old.idle_timeout_s,
            load_factor=old.load_factor,
            rebuild_tombstone_frac=old.rebuild_tombstone_frac,
            metrics=self.metrics,
            track_agg=active is not None,
            reuse=active is not None,
            refresh_every=active.refresh_every if active is not None else 0,
            anchor_dim=len(pipeline.rep.features) if active is not None else 0,
        )
        from .flow_table import move_slot

        new_disp = MicroBatchDispatcher(
            table, pipeline, max_batch=disp.max_batch,
            min_bucket=disp.min_bucket, flush_timeout_s=disp.flush_timeout_s,
            max_pending=disp.max_pending, execute=disp.execute,
            metrics=self.metrics, reuse=active,
        )
        # predictions, the flush log, and the observability hooks are
        # runtime-lifetime, not pipeline-lifetime: carry them over
        new_disp.results = disp.results
        new_disp.live_predictions = disp.live_predictions
        new_disp.records = disp.records
        new_disp.tracer = disp.tracer
        new_disp.drift = disp.drift
        new_disp.trace_pid = disp.trace_pid
        ready = []
        for s in np.nonzero(old.ctrl["state"] != 0)[0]:
            ns = move_slot(old, table, int(s))
            c = table.ctrl[ns]
            if c["state"] == 1 and c["count"] >= depth:
                c["state"] = 2  # READY under the new (deeper-or-equal) prefix
                c["ready_ts"] = now
                ready.append(ns)
        for ns in ready:
            new_disp.enqueue(ns, now)
        if table.anchor is not None:
            # anchors are feature vectors under the *old* plan: invalidate
            # them all so the first post-swap refresh re-infers and
            # re-snaps against the new feature set
            table.anchor_valid[:] = False
        self.table, self.dispatcher, self.pipeline = table, new_disp, pipeline
        recs.extend(new_disp.maybe_flush(now))
        return recs

    def drain(self, now: float) -> list[BatchRecord]:
        """End of stream: classify every flow still holding packets."""
        for slot in self.table.flush_all(now):
            self.dispatcher.enqueue(slot, now)
        return self.dispatcher.drain(now)

"""Micro-batched dispatch: shape-bucketed, double-buffered (DESIGN.md §6).

InferLine's lesson applies unchanged to traffic pipelines: the model is not
the serving system — between the flow table and the jit-specialized
pipeline there has to be a queueing/batching layer with explicit policies.

Two policies matter here:

- **Shape bucketing.** ``jax.jit`` specializes on input *shape*; if every
  micro-batch were submitted at its natural size, a replay would compile a
  fresh XLA executable per distinct batch size. Batches are therefore
  padded up to power-of-two buckets in ``[min_bucket, max_batch]``: at most
  ``log2(max_batch / min_bucket) + 1`` executables exist over any run, and
  every one is compiled at most once (jit specialization as conditional
  compilation, DESIGN.md §3 — here specialized over *batch geometry*
  instead of feature sets). Padding rows have ``flow_len == 0`` so every
  masked reduction sees an empty flow; their predictions are discarded.

- **Double-buffered async submit.** ``predict_async`` returns an
  unresolved device array; the dispatcher keeps up to ``max_pending``
  batches in flight and only blocks (``finalize``) when the window is
  full. Extraction + inference of batch *k* overlap accumulation of batch
  *k+1* — the ingest thread never waits for the accelerator unless it is
  more than a full batch ahead.

Flushes trigger on depth (``max_batch`` flows ready), on timeout (oldest
ready flow waited ``flush_timeout_s``), or on drain.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.traffic.pipeline import ServingPipeline
from repro.traffic.synth import TrafficDataset

from .flow_table import FlowStatus, FlowTable
from .metrics import RuntimeMetrics

__all__ = ["BatchRecord", "MicroBatchDispatcher", "StreamingRuntime", "next_bucket"]


def next_bucket(n: int, min_bucket: int, max_batch: int) -> int:
    """Smallest power-of-two >= n, clamped to [min_bucket, max_batch]."""
    b = min_bucket
    while b < n:
        b *= 2
    return min(b, max_batch)


@dataclasses.dataclass
class BatchRecord:
    """One flushed micro-batch; `preds` is filled when the batch resolves."""

    flow_ids: np.ndarray       # (n_real,) external flow ids
    ready_ts: np.ndarray       # (n_real,) when each flow became dispatchable
    flush_ts: float            # when the batch left the queue
    bucket: int                # padded batch size actually submitted
    n_real: int
    reason: str                # "full" | "timeout" | "drain"
    probs: Optional[object] = None   # in-flight device array
    preds: Optional[np.ndarray] = None


class MicroBatchDispatcher:
    def __init__(
        self,
        table: FlowTable,
        pipeline: ServingPipeline,
        *,
        max_batch: int = 256,
        min_bucket: int = 8,
        flush_timeout_s: float = 0.05,
        max_pending: int = 2,
        execute: bool = True,
        metrics: RuntimeMetrics | None = None,
    ):
        if max_batch & (max_batch - 1) or min_bucket & (min_bucket - 1):
            raise ValueError("max_batch and min_bucket must be powers of two")
        self.table = table
        self.pipeline = pipeline
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.flush_timeout_s = flush_timeout_s
        self.max_pending = max_pending
        self.execute = execute
        self.metrics = metrics if metrics is not None else table.metrics
        self._queue: deque[tuple[int, float]] = deque()  # (slot, ready_ts)
        self._pending: deque[BatchRecord] = deque()
        self.results: dict[int, object] = {}  # flow_id -> predicted class
        self.records: list[BatchRecord] = []

    # -- queue ---------------------------------------------------------------

    def enqueue(self, slot: int, ready_ts: float) -> None:
        self._queue.append((slot, ready_ts))

    def maybe_flush(self, now: float) -> list[BatchRecord]:
        """Flush every full batch, then at most one timeout batch."""
        out = []
        while len(self._queue) >= self.max_batch:
            out.append(self._flush(now, "full"))
        if self._queue and now - self._queue[0][1] >= self.flush_timeout_s:
            out.append(self._flush(now, "timeout"))
        return out

    def drain(self, now: float) -> list[BatchRecord]:
        out = []
        while self._queue:
            out.append(self._flush(now, "drain"))
        while self._pending:
            self._resolve(self._pending.popleft())
        return out

    # -- flush mechanics -----------------------------------------------------

    def _flush(self, now: float, reason: str) -> BatchRecord:
        n = min(len(self._queue), self.max_batch)
        slots = np.empty(n, dtype=np.int64)
        ready = np.empty(n, dtype=np.float64)
        for i in range(n):
            slots[i], ready[i] = self._queue.popleft()
        bucket = next_bucket(n, self.min_bucket, self.max_batch)

        m = self.metrics
        m.batches += 1
        m.batch_occupancy.append(n / bucket)
        m.shapes_seen.add((bucket, self.table.pkt_depth))
        m.flows_predicted += n
        if reason == "full":
            m.flushes_full += 1
        elif reason == "timeout":
            m.flushes_timeout += 1
        else:
            m.flushes_drain += 1

        rec = BatchRecord(
            flow_ids=self.table.ctrl["flow_id"][slots].copy(),
            ready_ts=ready,
            flush_ts=now,
            bucket=bucket,
            n_real=n,
            reason=reason,
        )
        if self.execute:
            ds = self.gather(slots, bucket)
            # retire the oldest in-flight batch before submitting a new one:
            # at most `max_pending` batches overlap ingest at any time
            while len(self._pending) >= self.max_pending:
                self._resolve(self._pending.popleft())
            rec.probs = self.pipeline.predict_async(ds)
            self._pending.append(rec)
        # slots are safe to reuse once gathered (or immediately in timing-only
        # mode): finished flows recycle now, the rest become PREDICTED
        self.table.mark_predicted(slots)
        self.records.append(rec)
        return rec

    def gather(self, slots: np.ndarray, bucket: int) -> TrafficDataset:
        """Copy table rows into a padded, dense TrafficDataset batch."""
        t = self.table
        n = len(slots)
        P = t.pkt_depth

        def pad2(a, dtype):
            out = np.zeros((bucket, P), dtype=dtype)
            out[:n] = a[slots]
            return out

        flags = np.zeros((bucket, P, 8), dtype=np.uint8)
        flags[:n] = t.flags[slots]
        meta = lambda a: np.pad(a[slots].astype(np.float32), (0, bucket - n))
        return TrafficDataset(
            ts=pad2(t.ts, np.float32),
            size=pad2(t.size, np.float32),
            direction=pad2(t.direction, np.uint8),
            ttl=pad2(t.ttl, np.float32),
            winsize=pad2(t.winsize, np.float32),
            flags=flags,
            flow_len=np.pad(t.ctrl["count"][slots], (0, bucket - n)).astype(np.int32),
            proto=meta(t.proto),
            s_port=meta(t.s_port),
            d_port=meta(t.d_port),
            label=np.zeros(bucket, dtype=np.int32),
            name="stream-batch",
        )

    def _resolve(self, rec: BatchRecord) -> None:
        preds = self.pipeline.finalize(rec.probs)[: rec.n_real]
        rec.preds = preds
        rec.probs = None
        for fid, p in zip(rec.flow_ids, preds):
            # first prediction wins: a re-tenancy of the same 5-tuple (e.g.
            # a stray final ACK after close) must not overwrite the real
            # classification with a tail-fragment one
            if int(fid) in self.results:
                self.metrics.duplicate_predictions += 1
            else:
                self.results[int(fid)] = p


class StreamingRuntime:
    """Facade: flow table + dispatcher behind a per-packet ingest call.

    Owns no clock — callers pass `now` (wall time in live use, virtual time
    under the replay driver), which is what makes zero-loss search
    deterministic and replayable.
    """

    def __init__(
        self,
        pipeline: ServingPipeline,
        *,
        capacity: int = 2048,
        max_batch: int = 256,
        min_bucket: int = 8,
        flush_timeout_s: float = 0.05,
        idle_timeout_s: float = 60.0,
        max_pending: int = 2,
        execute: bool = True,
        pkt_depth: Optional[int] = None,
    ):
        self.pipeline = pipeline
        depth = pkt_depth if pkt_depth is not None else pipeline.rep.depth
        self.metrics = RuntimeMetrics()
        self.table = FlowTable(
            capacity, depth, idle_timeout_s=idle_timeout_s, metrics=self.metrics
        )
        self.dispatcher = MicroBatchDispatcher(
            self.table,
            pipeline,
            max_batch=max_batch,
            min_bucket=min_bucket,
            flush_timeout_s=flush_timeout_s,
            max_pending=max_pending,
            execute=execute,
            metrics=self.metrics,
        )

    @property
    def results(self) -> dict:
        return self.dispatcher.results

    def ingest_packet(
        self, key, now, rel_ts, size, direction, ttl, winsize, flags_byte,
        proto, s_port, d_port, flow_id, fin,
    ) -> tuple[FlowStatus, list[BatchRecord]]:
        status, slot = self.table.observe(
            key, now, rel_ts, size, direction, ttl, winsize, flags_byte,
            proto, s_port, d_port, flow_id, fin,
        )
        if status in (FlowStatus.READY, FlowStatus.READY_EOF):
            self.dispatcher.enqueue(slot, now)
        return status, self.dispatcher.maybe_flush(now)

    def poll(self, now: float) -> list[BatchRecord]:
        """Periodic maintenance: idle eviction + timeout flushes."""
        for slot in self.table.evict_idle(now):
            self.dispatcher.enqueue(slot, now)
        return self.dispatcher.maybe_flush(now)

    def drain(self, now: float) -> list[BatchRecord]:
        """End of stream: classify every flow still holding packets."""
        for slot in self.table.flush_all(now):
            self.dispatcher.enqueue(slot, now)
        return self.dispatcher.drain(now)

"""Online flow table: preallocated dense per-flow packet state (DESIGN.md §6).

Traffic Refinery's measurement holds here too: per-flow state management is
the dominant systems cost of a network-ML pipeline, so the table is laid
out for the extractor, not for the tracker. All packet payload lives in
preallocated dense ``(capacity, pkt_depth)`` arrays — the *same* layout the
batch ``TrafficDataset`` uses (DESIGN.md §3) — so dispatch is a row gather
with zero per-flow reshaping, and the jit-specialized extraction executable
runs unchanged on streaming state.

Components:

- a NumPy structured *control block* (key, state, counts, timestamps) —
  one row per slot;
- dense payload arrays (ts/size/direction/ttl/winsize/flags + 5-tuple
  metadata) capped at ``pkt_depth`` packets: CATO classifies at connection
  depth n, so packets past n never touch the payload, only the tracker;
- an open-addressed hash index (linear probing, stored-key verification,
  tombstone deletion) mapping 64-bit 5-tuple hashes to slots;
- a free list for O(1) slot recycling, idle-timeout eviction, and overflow
  (drop) accounting when the preallocated capacity is exhausted.

Timestamps stored in the payload are *flow-relative* float32 (first packet
= 0.0): absolute epoch seconds in float32 would lose the microsecond bits
the IAT features are made of.
"""
from __future__ import annotations

import enum

import numpy as np

from repro.serve.runtime.metrics import RuntimeMetrics
from repro.traffic.extraction import (
    AGG_CNT,
    AGG_DIR_STRIDE,
    AGG_FAM_BASE,
    AGG_FIRST_TS,
    AGG_FLAGS,
    AGG_HS_ACK,
    AGG_HS_SYN,
    AGG_HS_SYNACK,
    AGG_IAT_CNT,
    AGG_IAT_M2,
    AGG_IAT_MAX,
    AGG_IAT_MIN,
    AGG_IAT_SUM,
    AGG_LAST_TS,
    AGG_TS_MAX,
    AGG_TS_MIN,
    AGG_WIDTH,
    agg_init,
)
from repro.traffic.synth import FLAG_NAMES

__all__ = [
    "FlowStatus",
    "FlowTable",
    "move_slot",
    "symmetric_tuple_hash64",
    "tuple_hash64",
]


_CTRL_DTYPE = np.dtype([
    ("key", np.uint64),        # 5-tuple hash (verified on probe)
    ("state", np.uint8),       # FREE / ACTIVE / READY / PREDICTED
    ("fin_mask", np.uint8),    # bit per direction; flow closed when == 0b11
    ("count", np.int32),       # packets accumulated into the payload (<= depth)
    ("seen", np.int32),        # all packets observed for the flow
    ("first_ts", np.float64),  # absolute arrival of first packet
    ("last_ts", np.float64),   # absolute arrival of latest packet
    ("ready_ts", np.float64),  # when the flow was queued for dispatch
    ("flow_id", np.int32),     # external id (dataset row) for result join
])


class FlowStatus(enum.IntEnum):
    """Outcome of `FlowTable.observe` for one packet."""

    TRACKED = 0        # payload or tracker updated, nothing to dispatch
    READY = 1          # flow just reached depth n -> queue for inference
    READY_EOF = 2      # flow closed (FIN both ways) before depth n -> queue
    CLOSED = 3         # close completed on a predicted flow -> slot recycled
    DROPPED = 4        # table full: packet of an untracked flow lost


# (256, 8) lookup: packed TCP-flag byte -> FLAG_NAMES-ordered uint8 vector.
_FLAG_LUT = ((np.arange(256, dtype=np.uint16)[:, None] >> np.arange(8)) & 1).astype(
    np.uint8
)

_SYN_BIT = FLAG_NAMES.index("syn")
_ACK_BIT = FLAG_NAMES.index("ack")
_AGG_BIG = 3.4e38  # same sentinel as the extraction emitter's _BIG

# stacked-row layouts for the block aggregate fold (`_agg_update_sorted`):
# handshake min-timestamp columns and per-direction family SUM offsets, in
# the row order the fold stacks values (bytes, winsize, ttl)
_HS_COLS = np.array([AGG_HS_SYN, AGG_HS_SYNACK, AGG_HS_ACK], dtype=np.int64)
_FAM_COLS = np.array(
    [AGG_FAM_BASE["bytes"], AGG_FAM_BASE["winsize"], AGG_FAM_BASE["ttl"]],
    dtype=np.int64,
)


_M64 = 0xFFFFFFFFFFFFFFFF


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = (x ^ (x >> 30)) * 0xBF58476D1CE4E5B9 & _M64
    x = (x ^ (x >> 27)) * 0x94D049BB133111EB & _M64
    return x ^ (x >> 31)


def tuple_hash64(s_ip: int, d_ip: int, s_port: int, d_port: int, proto: int) -> int:
    """64-bit 5-tuple hash: splitmix64 chained over two lossless words.

    Each word packs its fields without overlap (ips: 32+32 bits; ports +
    proto: 16+16+8 bits), so distinct 5-tuples collide only at the generic
    ~2^-64 hash-collision rate — never structurally.
    """
    w1 = ((s_ip & 0xFFFFFFFF) << 32) | (d_ip & 0xFFFFFFFF)
    w2 = ((proto & 0xFF) << 32) | ((s_port & 0xFFFF) << 16) | (d_port & 0xFFFF)
    h = _splitmix64(_splitmix64(w1) ^ w2)
    return h or 1  # 0 is reserved for "empty bucket"


def _splitmix64_np(x: np.ndarray) -> np.ndarray:
    """Vectorized `_splitmix64` over uint64 arrays (wrapping arithmetic)."""
    with np.errstate(over="ignore"):  # mod-2^64 wrap is the algorithm
        x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


def symmetric_tuple_hash64(
    s_ip, d_ip, s_port, d_port, proto
) -> np.ndarray:
    """Direction-invariant 5-tuple hash: RSS-style symmetric steering key.

    The two endpoints are sorted (ip, then port) before packing, so the
    forward and reverse directions of a flow hash identically — the
    property NIC symmetric-RSS needs so both halves of a connection land
    on the same queue/worker. Accepts scalars or equal-length arrays;
    always returns a uint64 ndarray. Distinct from `tuple_hash64`, which
    is intentionally asymmetric (it is the flow-table identity key and
    must separate A->B from B->A when both are tracked)."""
    s_ip = np.asarray(s_ip, np.uint64)
    d_ip = np.asarray(d_ip, np.uint64)
    s_port = np.asarray(s_port, np.uint64)
    d_port = np.asarray(d_port, np.uint64)
    proto = np.asarray(proto, np.uint64)
    swap = (s_ip > d_ip) | ((s_ip == d_ip) & (s_port > d_port))
    lo_ip = np.where(swap, d_ip, s_ip)
    hi_ip = np.where(swap, s_ip, d_ip)
    lo_port = np.where(swap, d_port, s_port)
    hi_port = np.where(swap, s_port, d_port)
    w1 = ((lo_ip & np.uint64(0xFFFFFFFF)) << np.uint64(32)) | (
        hi_ip & np.uint64(0xFFFFFFFF)
    )
    w2 = (
        ((proto & np.uint64(0xFF)) << np.uint64(32))
        | ((lo_port & np.uint64(0xFFFF)) << np.uint64(16))
        | (hi_port & np.uint64(0xFFFF))
    )
    h = _splitmix64_np(_splitmix64_np(w1) ^ w2)
    return np.where(h == 0, np.uint64(1), h)


_EMPTY = -1      # bucket sentinel: never used
_TOMBSTONE = -2  # bucket sentinel: deleted, keep probing


class FlowTable:
    """Preallocated flow table; all storage is allocated once in __init__."""

    def __init__(
        self,
        capacity: int,
        pkt_depth: int,
        *,
        idle_timeout_s: float = 60.0,
        load_factor: float = 0.5,
        rebuild_tombstone_frac: float = 0.25,
        metrics: RuntimeMetrics | None = None,
        track_agg: bool = False,
        reuse: bool = False,
        refresh_every: int = 0,
        anchor_dim: int = 0,
        agg_buffer: int = 4096,
    ):
        if capacity <= 0 or pkt_depth <= 0:
            raise ValueError("capacity and pkt_depth must be positive")
        if not 0.0 < load_factor < 1.0:
            raise ValueError("load_factor must be in (0, 1)")
        if rebuild_tombstone_frac < 0.0:
            raise ValueError("rebuild_tombstone_frac must be >= 0")
        if load_factor + rebuild_tombstone_frac >= 1.0:
            # probe termination proof: live slots (<= n_buckets *
            # load_factor) plus un-rebuilt tombstones (<= n_buckets *
            # rebuild_tombstone_frac) must leave at least one EMPTY
            # bucket, or a probe miss on a full table never terminates
            raise ValueError(
                "load_factor + rebuild_tombstone_frac must be < 1.0 "
                "(open addressing needs a guaranteed empty bucket)"
            )
        self.capacity = capacity
        self.pkt_depth = pkt_depth
        self.idle_timeout_s = idle_timeout_s
        self.load_factor = load_factor
        self.rebuild_tombstone_frac = rebuild_tombstone_frac
        self.metrics = metrics if metrics is not None else RuntimeMetrics()

        self.ctrl = np.zeros(capacity, dtype=_CTRL_DTYPE)
        # dense payload, TrafficDataset layout (DESIGN.md §3)
        self.ts = np.zeros((capacity, pkt_depth), dtype=np.float32)
        self.size = np.zeros((capacity, pkt_depth), dtype=np.float32)
        self.direction = np.zeros((capacity, pkt_depth), dtype=np.uint8)
        self.ttl = np.zeros((capacity, pkt_depth), dtype=np.float32)
        self.winsize = np.zeros((capacity, pkt_depth), dtype=np.float32)
        self.flags = np.zeros((capacity, pkt_depth, 8), dtype=np.uint8)
        self.proto = np.zeros(capacity, dtype=np.float32)
        self.s_port = np.zeros(capacity, dtype=np.float32)
        self.d_port = np.zeros(capacity, dtype=np.float32)

        # incremental aggregate state (DESIGN.md §12): one float64 row of
        # running statistics per slot, updated on every ingest when enabled.
        # `reuse` additionally activates the frozen fast path for PREDICTED
        # flows and the seen-counter refresh cadence.
        self.track_agg = bool(track_agg or reuse)
        self.reuse = bool(reuse)
        self.refresh_every = int(refresh_every)
        self.anchor_dim = int(anchor_dim)
        if self.track_agg:
            self._agg_init = agg_init()
            self.agg = np.tile(self._agg_init, (capacity, 1))
        else:
            self._agg_init = None
            self.agg = None
        self.anchor = (
            np.zeros((capacity, anchor_dim), np.float32) if anchor_dim else None
        )
        self.anchor_valid = np.zeros(capacity, bool)
        # per-slot re-tenancy generation: a refresh scheduled for (slot, gen)
        # is dropped if the slot was cleared (gen bumped) before it drains
        self.gen = np.zeros(capacity, np.int64)
        self.refresh_pending = np.zeros(capacity, bool)
        self._refresh_due: list[tuple[int, int]] = []
        # per-packet frozen-class mask of the last observe_batch (reuse on):
        # the replay cost model charges these packets the frozen-path rate
        self.last_frozen: np.ndarray | None = None
        self.last1_frozen = False
        # deferred-fold arena for the frozen fast path (DESIGN.md §12): a
        # frozen packet costs one buffer append at ingest; the ~hundred-op
        # aggregate fold runs once per `agg_buffer` packets (chunk-invariant
        # fold boundaries — appends split exactly at capacity), amortizing
        # numpy per-op overhead that would otherwise dominate small blocks.
        # Any reader of a frozen slot's aggregates/tracker fields drains it
        # first (`flush_agg`): refresh discovery, close, eviction, migration.
        self._ab_cap = max(1, int(agg_buffer)) if reuse else 0
        if self.reuse:
            cap_b = self._ab_cap
            self._ab_slot = np.zeros(cap_b, np.int64)
            self._ab_t = np.zeros(cap_b, np.float64)
            self._ab_rel = np.zeros(cap_b, np.float64)
            self._ab_size = np.zeros(cap_b, np.float64)
            self._ab_dir = np.zeros(cap_b, np.int64)
            self._ab_ttl = np.zeros(cap_b, np.float64)
            self._ab_win = np.zeros(cap_b, np.float64)
            self._ab_fb = np.zeros(cap_b, np.int64)
            self._ab_has = np.zeros(capacity, bool)  # slot has buffered pkts
        self._abuf_n = 0

        # open-addressed index: power-of-two bucket array sized so a full
        # table stays at load <= load_factor (default 0.5)
        n_buckets = 1
        while n_buckets * load_factor < capacity:
            n_buckets *= 2
        self._n_buckets = n_buckets
        self._mask = n_buckets - 1
        self._buckets = np.full(n_buckets, _EMPTY, dtype=np.int64)
        self._tombstones = 0
        self._rebuild_at = int(n_buckets * rebuild_tombstone_frac)

        self._free = list(range(capacity - 1, -1, -1))  # pop() -> slot 0 first

    # -- hash index ----------------------------------------------------------

    def _probe_many(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized read-only probe: slot per key, -1 on miss.

        Replicates `_probe`'s traversal (linear probing, stored-key
        verification, tombstones skipped) with one numpy step per probe
        distance across all still-unresolved keys. Probe distance 0 is
        unrolled without the pending-index machinery: at sane load
        factors nearly every key resolves in its home bucket, and this
        probe sits on the frozen fast path's per-block budget.
        """
        U = len(keys)
        if U == 0:
            return np.full(U, -1, np.int64)
        b = (keys & np.uint64(self._mask)).astype(np.int64)
        s = self._buckets[b]
        live = s >= 0
        match = live.copy()
        if live.any():
            match[live] = self.ctrl["key"][s[live]] == keys[live]
        res = np.where(match, s, -1)
        keep = ~match & (s != _EMPTY)  # tombstone / live mismatch: probe on
        if not keep.any():
            return res
        pending = np.flatnonzero(keep)
        b[pending] = (b[pending] + 1) & self._mask
        while pending.size:
            s = self._buckets[b[pending]]
            empty = s == _EMPTY
            live = s >= 0
            match = np.zeros(pending.size, bool)
            if live.any():
                match[live] = self.ctrl["key"][s[live]] == keys[pending[live]]
            res[pending[match]] = s[match]
            keep = ~(empty | match)
            pending = pending[keep]
            b[pending] = (b[pending] + 1) & self._mask
        return res

    def _probe(self, key: int) -> tuple[int, int]:
        """Return (slot, first_usable_bucket). slot is -1 on miss."""
        b = key & self._mask
        first_usable = -1
        while True:
            s = self._buckets[b]
            if s == _EMPTY:
                return -1, (b if first_usable < 0 else first_usable)
            if s == _TOMBSTONE:
                if first_usable < 0:
                    first_usable = b
            elif self.ctrl["key"][s] == key:
                return int(s), b
            b = (b + 1) & self._mask

    def _index_insert(self, key: int, slot: int, bucket: int) -> None:
        if self._buckets[bucket] == _TOMBSTONE:
            self._tombstones -= 1
        self._buckets[bucket] = slot

    def _index_remove(self, key: int) -> None:
        b = key & self._mask
        while True:
            s = self._buckets[b]
            if s == _EMPTY:
                return  # not present (already removed)
            if s >= 0 and self.ctrl["key"][s] == key:
                self._buckets[b] = _TOMBSTONE
                self._tombstones += 1
                if self._tombstones > self._rebuild_at:
                    self._rebuild_index()
                return
            b = (b + 1) & self._mask

    def _rebuild_index(self) -> None:
        self._buckets.fill(_EMPTY)
        self._tombstones = 0
        for s in np.nonzero(self.ctrl["state"] != 0)[0]:
            key = int(self.ctrl["key"][s])
            b = key & self._mask
            while self._buckets[b] >= 0:
                b = (b + 1) & self._mask
            self._buckets[b] = s

    # -- slot lifecycle ------------------------------------------------------

    @property
    def n_active(self) -> int:
        return self.capacity - len(self._free)

    def occupancy(self) -> dict:
        """Point-in-time table pressure, for the metrics registry's gauge
        namespace (DESIGN.md §11.1). Gauges only — the cumulative story
        (flows_seen, evictions, drops) lives in `RuntimeMetrics`."""
        return {
            "n_active": self.n_active,
            "capacity": self.capacity,
            "load_factor": self.n_active / self.capacity,
            "tombstones": int(self._tombstones),
        }

    def _alloc(self, key: int, t: float, flow_id: int) -> int:
        slot = self._free.pop()
        c = self.ctrl[slot]
        c["key"] = key
        c["state"] = 1  # ACTIVE
        c["fin_mask"] = 0
        c["count"] = 0
        c["seen"] = 0
        c["first_ts"] = t
        c["last_ts"] = t
        c["ready_ts"] = 0.0
        c["flow_id"] = flow_id
        self.metrics.flows_seen += 1
        return slot

    def _clear_slot(self, slot: int) -> None:
        """Detach a slot from the index and zero its state + payload.

        The one slot-clearing sequence, shared by `recycle` (flow ended)
        and `detach_slot` (flow migrating) so the two can never diverge.
        State must clear BEFORE the index removal: removal can trigger a
        rebuild, and the rebuild must not re-insert the departing slot.
        Payload rows are zeroed so the next tenant starts from padding.
        """
        if self.reuse and self._abuf_n and self._ab_has[slot]:
            # fold pending frozen-path packets before the row resets, or a
            # later drain would resurrect the departed tenant's statistics
            # into whatever tenant holds the slot then
            self.flush_agg()
        key = int(self.ctrl["key"][slot])
        self.ctrl["state"][slot] = 0
        self._index_remove(key)
        # zero the whole control row, not just key/state: a slot on the
        # free list holds no trace of its previous tenant, so the audit can
        # compare recycled slots bitwise against never-used ones
        self.ctrl[slot] = np.zeros((), dtype=self.ctrl.dtype)[()]
        self.ts[slot] = 0.0
        self.size[slot] = 0.0
        self.direction[slot] = 0
        self.ttl[slot] = 0.0
        self.winsize[slot] = 0.0
        self.flags[slot] = 0
        # 5-tuple metadata resets too: alloc happens to overwrite these, but
        # a slot on the free list must hold NO previous tenant's state — the
        # invariant the aggregate columns below depend on, audited by
        # tests/test_reuse.py::test_recycle_resets_every_column
        self.proto[slot] = 0.0
        self.s_port[slot] = 0.0
        self.d_port[slot] = 0.0
        if self.agg is not None:
            self.agg[slot] = self._agg_init
        if self.anchor is not None:
            self.anchor[slot] = 0.0
        self.anchor_valid[slot] = False
        self.refresh_pending[slot] = False
        self.gen[slot] += 1
        self._free.append(slot)

    def recycle(self, slot: int) -> None:
        """Return a slot to the free list and clear its payload row."""
        self._clear_slot(slot)
        self.metrics.slots_recycled += 1

    # -- incremental aggregates (DESIGN.md §12) ------------------------------

    def _agg_update1(
        self, slot, rel_ts, size, direction, ttl, winsize, flags_byte
    ) -> None:
        """Scalar Welford update of one slot's aggregate row.

        The reference semantics: the block path (`_agg_update_sorted`,
        Chan merges) must match this exactly for count/sum/min/max and to
        ~1e-6 relative for the M2 cells (reassociation only).
        """
        a = self.agg[slot]
        ts = float(rel_ts)
        b = AGG_DIR_STRIDE * (int(direction) & 1)
        if ts < a[AGG_TS_MIN]:
            a[AGG_TS_MIN] = ts
        if ts > a[AGG_TS_MAX]:
            a[AGG_TS_MAX] = ts
        fb = int(flags_byte)
        a[AGG_FLAGS:AGG_FLAGS + 8] += _FLAG_LUT[fb]
        syn = (fb >> _SYN_BIT) & 1
        ack = (fb >> _ACK_BIT) & 1
        if syn and not ack and ts < a[AGG_HS_SYN]:
            a[AGG_HS_SYN] = ts
        if syn and ack and ts < a[AGG_HS_SYNACK]:
            a[AGG_HS_SYNACK] = ts
        if ack and not syn and ts < a[AGG_HS_ACK]:
            a[AGG_HS_ACK] = ts
        # same-direction inter-arrival (uses the previous LAST_TS, so this
        # runs before the timestamp cells are advanced). The stored sum
        # telescopes to last - first: exact by construction, never drifts.
        prev = a[b + AGG_LAST_TS]
        if prev > -_AGG_BIG / 2:
            x = ts - prev
            n0 = a[b + AGG_IAT_CNT]
            mean0 = a[b + AGG_IAT_SUM] / n0 if n0 > 0 else 0.0
            delta = x - mean0
            n1 = n0 + 1.0
            a[b + AGG_IAT_CNT] = n1
            if x < a[b + AGG_IAT_MIN]:
                a[b + AGG_IAT_MIN] = x
            if x > a[b + AGG_IAT_MAX]:
                a[b + AGG_IAT_MAX] = x
            a[b + AGG_IAT_SUM] = ts - a[b + AGG_FIRST_TS]
            a[b + AGG_IAT_M2] += delta * (x - a[b + AGG_IAT_SUM] / n1)
        else:
            a[b + AGG_FIRST_TS] = ts
        a[b + AGG_LAST_TS] = ts
        n0 = a[b + AGG_CNT]
        n1 = n0 + 1.0
        a[b + AGG_CNT] = n1
        for val, fam in (
            (float(size), AGG_FAM_BASE["bytes"]),
            (float(winsize), AGG_FAM_BASE["winsize"]),
            (float(ttl), AGG_FAM_BASE["ttl"]),
        ):
            base = b + fam
            s_old = a[base]
            mean0 = s_old / n0 if n0 > 0 else 0.0
            delta = val - mean0
            s_new = s_old + val
            a[base] = s_new
            if val < a[base + 1]:
                a[base + 1] = val
            if val > a[base + 2]:
                a[base + 2] = val
            a[base + 3] += delta * (val - s_new / n1)

    def _agg_update_sorted(
        self, fs, g, uniq_g, start, counts, slots_g,
        rel_ts, size, direction, ttl, winsize, flags_byte,
    ) -> None:
        """Block aggregate update over key-sorted packet positions `fs`.

        `fs` must be time-ascending within each key group (the stable sort
        `fast_apply` already produces). Per-(slot, direction) segment
        statistics are computed two-pass and folded in with Chan's merge;
        count/sum/min/max cells are exact vs the scalar path (integer-valued
        payload fields sum exactly in float64, the iat sum telescopes), M2
        differs only by reassociation.
        """
        agg = self.agg
        flat = agg.reshape(-1)  # flat view: cell (slot, col) -> slot*W + col
        W = AGG_WIDTH
        rel = np.asarray(rel_ts, np.float64)[fs]
        fb = flags_byte[fs]
        ends = start + counts - 1
        agg[slots_g, AGG_TS_MIN] = np.minimum(agg[slots_g, AGG_TS_MIN],
                                              rel[start])
        agg[slots_g, AGG_TS_MAX] = np.maximum(agg[slots_g, AGG_TS_MAX],
                                              rel[ends])
        flv = _FLAG_LUT[fb].astype(np.float64)
        agg[slots_g, AGG_FLAGS:AGG_FLAGS + 8] += np.add.reduceat(
            flv, start, axis=0)
        syn = (fb >> _SYN_BIT) & 1
        ack = (fb >> _ACK_BIT) & 1
        conds = np.stack(((syn == 1) & (ack == 0),
                          (syn == 1) & (ack == 1),
                          (ack == 1) & (syn == 0)))
        seg = np.minimum.reduceat(np.where(conds, rel[None, :], _AGG_BIG),
                                  start, axis=1)
        fi_hs = slots_g[None, :] * W + _HS_COLS[:, None]
        flat[fi_hs] = np.minimum(flat[fi_hs], seg)

        # (slot, direction) segments: stable re-sort keeps time order.
        # Segment structure is derived from sorted-boundary masks + a
        # cumsum segment index instead of np.unique/np.repeat, and the
        # three payload families fold in one stacked (3, n) pass with
        # flat-index gathers — per-op numpy overhead dominates small
        # blocks, and this fold IS the frozen fast path.
        dirb = direction[fs].astype(np.int64) & 1
        g2 = g * 2 + dirb
        o2 = np.argsort(g2, kind="stable")
        g2s = g2[o2]
        r2 = rel[o2]
        n2 = g2s.size
        bnd2 = np.empty(n2, bool)
        bnd2[0] = True
        np.not_equal(g2s[1:], g2s[:-1], out=bnd2[1:])
        s2 = np.flatnonzero(bnd2)
        c2 = np.diff(np.append(s2, n2))
        seg2 = np.cumsum(bnd2) - 1  # per-element segment id
        u2 = g2s[s2]
        slots2 = slots_g[np.searchsorted(uniq_g, u2 >> 1)]
        fiB = slots2 * W + (u2 & 1) * AGG_DIR_STRIDE  # flat base per segment
        nb = c2.astype(np.float64)
        n_old = flat[fiB + AGG_CNT]
        n_new = n_old + nb
        flat[fiB + AGG_CNT] = n_new
        idx2 = fs[o2]
        V = np.stack((np.asarray(size, np.float64)[idx2],
                      np.asarray(winsize, np.float64)[idx2],
                      np.asarray(ttl, np.float64)[idx2]))
        sum_b = np.add.reduceat(V, s2, axis=1)
        mean_b = sum_b / nb[None, :]
        dif = V - mean_b[:, seg2]
        m2_b = np.add.reduceat(dif * dif, s2, axis=1)
        fi = fiB[None, :] + _FAM_COLS[:, None]  # (3, G2) flat SUM-cell index
        s_old = flat[fi]
        mean_old = s_old / np.maximum(n_old, 1.0)[None, :]
        delta = mean_b - mean_old
        flat[fi] = s_old + sum_b
        flat[fi + 1] = np.minimum(flat[fi + 1],
                                  np.minimum.reduceat(V, s2, axis=1))
        flat[fi + 2] = np.maximum(flat[fi + 2],
                                  np.maximum.reduceat(V, s2, axis=1))
        flat[fi + 3] += m2_b + delta * delta * (n_old * nb / n_new)[None, :]

        # inter-arrival: the segment's first sample bridges from the stored
        # LAST_TS (when one exists); the rest are in-segment diffs
        prev_last = flat[fiB + AGG_LAST_TS]
        first_old = flat[fiB + AGG_FIRST_TS]
        has_prev = prev_last > -_AGG_BIG / 2
        seg_first = r2[s2]
        seg_last = r2[s2 + c2 - 1]
        iv = np.empty(r2.size, np.float64)
        iv[1:] = r2[1:] - r2[:-1]
        iv[s2] = seg_first - prev_last
        validm = np.ones(r2.size, bool)
        validm[s2] = has_prev
        nbi = (c2 - 1 + has_prev).astype(np.float64)
        prev_eff = np.where(has_prev, prev_last, seg_first)
        # block mean telescopes exactly: (last - effective first) / count
        mean_b = np.where(nbi > 0, (seg_last - prev_eff) / np.maximum(nbi, 1.0),
                          0.0)
        dif = np.where(validm, iv - mean_b[seg2], 0.0)
        m2_b = np.add.reduceat(dif * dif, s2)
        n_old_i = flat[fiB + AGG_IAT_CNT]
        mean_old_i = flat[fiB + AGG_IAT_SUM] / np.maximum(n_old_i, 1.0)
        n_new_i = n_old_i + nbi
        delta = mean_b - mean_old_i
        flat[fiB + AGG_IAT_M2] += np.where(
            nbi > 0,
            m2_b + delta * delta * n_old_i * nbi / np.maximum(n_new_i, 1.0),
            0.0,
        )
        flat[fiB + AGG_IAT_CNT] = n_new_i
        flat[fiB + AGG_IAT_MIN] = np.minimum(
            flat[fiB + AGG_IAT_MIN],
            np.minimum.reduceat(np.where(validm, iv, _AGG_BIG), s2))
        flat[fiB + AGG_IAT_MAX] = np.maximum(
            flat[fiB + AGG_IAT_MAX],
            np.maximum.reduceat(np.where(validm, iv, -_AGG_BIG), s2))
        first_new = np.minimum(first_old, seg_first)
        flat[fiB + AGG_FIRST_TS] = first_new
        flat[fiB + AGG_LAST_TS] = seg_last
        flat[fiB + AGG_IAT_SUM] = np.where(
            n_new_i > 0, seg_last - first_new, 0.0)

    def _note_refresh(self, slots, old_seen, new_seen) -> None:
        """Schedule drift checks for slots whose seen counter crossed a
        refresh_every boundary — chunk-invariant: any split of the same
        packet sequence schedules the same refreshes."""
        K = self.refresh_every
        cross = (old_seen // K) != (new_seen // K)
        sel = cross & ~self.refresh_pending[slots]
        for s in slots[sel].tolist():
            self._refresh_due.append((s, int(self.gen[s])))
        self.refresh_pending[slots[sel]] = True

    def take_refresh_due(self) -> list[int]:
        """Drain scheduled drift checks. Entries whose slot was cleared or
        re-tenanted since scheduling (generation mismatch) or is no longer
        PREDICTED are dropped — a refresh must never touch another flow."""
        if not self._refresh_due:
            return []
        out = []
        for s, gen in self._refresh_due:
            self.refresh_pending[s] = False
            if self.gen[s] == gen and self.ctrl["state"][s] == 3:
                out.append(s)
        self._refresh_due.clear()
        return out

    # -- hot path ------------------------------------------------------------

    def observe(
        self,
        key: int,
        t: float,
        rel_ts: float,
        size: float,
        direction: int,
        ttl: float,
        winsize: float,
        flags_byte: int,
        proto: float,
        s_port: float,
        d_port: float,
        flow_id: int,
        fin: bool,
    ) -> tuple[FlowStatus, int]:
        """Account one packet; returns (status, slot) — slot is -1 on drop."""
        self.metrics.pkts_total += 1
        return self._observe1(
            key, t, rel_ts, size, direction, ttl, winsize, flags_byte,
            proto, s_port, d_port, flow_id, fin,
        )

    def _observe1(
        self, key, t, rel_ts, size, direction, ttl, winsize, flags_byte,
        proto, s_port, d_port, flow_id, fin,
    ) -> tuple[FlowStatus, int]:
        """`observe` body without the pkts_total bump (observe_batch adds
        the whole block's count up front)."""
        m = self.metrics
        self.last1_frozen = False
        slot, bucket = self._probe(key)
        if slot < 0:
            if not self._free:
                m.drops_table += 1
                return FlowStatus.DROPPED, -1
            slot = self._alloc(key, t, flow_id)
            self._index_insert(key, slot, bucket)
            self.proto[slot] = proto
            self.s_port[slot] = s_port
            self.d_port[slot] = d_port
        elif self.reuse and self.ctrl["state"][slot] == 3 and not fin:
            # frozen fast path, scalar cadence: defer the tracker touch
            # and aggregate update to the shared fold arena
            m.pkts_tracked += 1
            self.last1_frozen = True
            self._ab_append1(slot, t, rel_ts, size, direction, ttl,
                             winsize, flags_byte)
            return FlowStatus.TRACKED, slot
        if self.reuse and self._abuf_n and self._ab_has[slot]:
            # the eager path below writes seen/last_ts/agg directly: any
            # staged packets of this slot must fold first or the updates
            # would land out of arrival order
            self.flush_agg()

        c = self.ctrl[slot]
        c["last_ts"] = t
        c["seen"] += 1
        if self.track_agg:
            self._agg_update1(slot, rel_ts, size, direction, ttl, winsize,
                              flags_byte)
        state = int(c["state"])
        if fin:
            # per-direction FIN: a half-close (one side done, the other
            # still sending) must NOT end the flow, or trailing packets
            # would re-tenant the 5-tuple and get classified twice
            c["fin_mask"] |= np.uint8(1 << (direction & 1))
        closed = c["fin_mask"] == 3

        if state == 1 and c["count"] < self.pkt_depth:  # ACTIVE, accumulating
            i = int(c["count"])
            self.ts[slot, i] = rel_ts
            self.size[slot, i] = size
            self.direction[slot, i] = direction
            self.ttl[slot, i] = ttl
            self.winsize[slot, i] = winsize
            self.flags[slot, i] = _FLAG_LUT[flags_byte]
            c["count"] = i + 1
            m.pkts_accumulated += 1
            if c["count"] == self.pkt_depth:
                c["state"] = 2  # READY
                c["ready_ts"] = t
                return FlowStatus.READY, slot
            if closed:
                c["state"] = 2
                c["ready_ts"] = t
                return FlowStatus.READY_EOF, slot
            return FlowStatus.TRACKED, slot

        # past depth / already queued / already predicted: tracker only
        m.pkts_tracked += 1
        if closed and state == 3:  # PREDICTED: flow over, reclaim now
            self.recycle(slot)
            return FlowStatus.CLOSED, slot
        if state == 3 and self.reuse and self.refresh_every > 0:
            # only FIN-bearing packets of a PREDICTED flow reach here (the
            # frozen carve above returns early otherwise): keep the eager
            # seen bump's refresh crossing, matching `fast_apply`'s noting
            sn = int(c["seen"])
            K = self.refresh_every
            if (sn - 1) // K != sn // K and not self.refresh_pending[slot]:
                self._refresh_due.append((slot, int(self.gen[slot])))
                self.refresh_pending[slot] = True
        return FlowStatus.TRACKED, slot

    def observe_batch(
        self,
        key: np.ndarray,        # (B,) uint64
        t: np.ndarray,          # (B,) float64 arrival clock
        rel_ts: np.ndarray,     # (B,) float32 payload timestamp
        size: np.ndarray,
        direction: np.ndarray,
        ttl: np.ndarray,
        winsize: np.ndarray,
        flags_byte: np.ndarray,
        proto: np.ndarray,
        s_port: np.ndarray,
        d_port: np.ndarray,
        flow_id: np.ndarray,
        fin: np.ndarray,        # (B,) bool
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized `observe` over a packet block, exact-equivalent to the
        scalar loop in delivery order (DESIGN.md §7).

        Packets are partitioned per 5-tuple key into three phases that
        reproduce the scalar interleaving exactly:

        1. **Vector prefix** — resident keys with a FIN later in the block
           apply their pre-FIN payload writes in bulk (no structural effect,
           and within the key they precede the FIN suffix).
        2. **Ordered scalar pass** — everything structural runs through
           `_observe1` per packet in original order: the *first* packet of
           each new key (allocation order decides slot identity and drops),
           every packet from a key's first FIN onward (close accounting,
           PREDICTED recycling, re-tenancy), all packets of new keys that
           also FIN in the block, and all new-key packets when the free
           list could run out (allocation vs. recycle order then matters).
        3. **Vector bulk** — all remaining packets: resident FIN-free keys
           in full, plus new keys' packets after their (already allocated)
           first. Per-direction payload writes, seen/last_ts, and READY
           transitions are numpy fancy-indexing over the whole block;
           within a key they follow its scalar-phase packets and ordering
           across keys is immaterial (disjoint slots).

        Returns ``(statuses, slots, accumulated)`` — per-packet FlowStatus
        values, slot ids (-1 on drop), and whether the packet landed in the
        dense payload (the replay clock's per-packet cost class).

        Under reuse (DESIGN.md §12) the block is first split by a
        per-packet probe: packets of resident PREDICTED keys with no FIN
        in the block take the *frozen fast path* — they are staged in the
        deferred fold arena (`_ab_append`) and their seen/last_ts and
        aggregate updates land at the next `flush_agg`, amortizing the
        numpy fold over ~`agg_buffer` packets — and never enter the
        three-phase machinery; only the remainder pays the general path's
        per-key partitioning. A PREDICTED key cannot change state
        mid-block except through a FIN (those keys are excluded whole, and
        drain any staged state for their slot first), so the split
        decision at block start is exact, and frozen slots are disjoint
        from every slot the remainder can touch (no allocation lands on
        an occupied slot), so processing the carve first preserves the
        scalar cadence.
        """
        key = np.asarray(key, np.uint64)
        B = len(key)
        self.metrics.pkts_total += B
        self.last_frozen = None
        if B == 0:
            return (np.full(0, int(FlowStatus.TRACKED), np.uint8),
                    np.full(0, -1, np.int64), np.zeros(0, bool))
        if not self.reuse:
            return self._observe_general(
                key, t, rel_ts, size, direction, ttl, winsize, flags_byte,
                proto, s_port, d_port, flow_id, fin)
        slots_pp = self._probe_many(key)
        miss = slots_pp < 0
        if not miss.any():
            frzm = self.ctrl["state"][slots_pp] == 3
            if frzm.all() and not np.asarray(fin, bool).any():
                # all-frozen lane: the steady state under skewed traffic.
                # Every packet is a buffer append (slice copies, no
                # gathers); slots_pp is freshly allocated so it doubles
                # as the returned slot array
                self.metrics.pkts_tracked += B
                self._ab_append_all(slots_pp, t, rel_ts, size, direction,
                                    ttl, winsize, flags_byte)
                self.last_frozen = frzm
                return (np.full(B, int(FlowStatus.TRACKED), np.uint8),
                        slots_pp, np.zeros(B, bool))
        else:
            frzm = ~miss
            res = np.flatnonzero(frzm)
            frzm[res] = self.ctrl["state"][slots_pp[res]] == 3
        if frzm.any():
            bad = frzm & np.asarray(fin, bool)
            if bad.any():
                # a FIN on a predicted key: the whole key group goes to
                # the general path (close accounting, recycling)
                badslot = np.zeros(self.capacity, bool)
                badslot[slots_pp[bad]] = True
                res = np.flatnonzero(~miss)
                excl = np.zeros(B, bool)
                excl[res] = badslot[slots_pp[res]]
                frzm &= ~excl
                if self._abuf_n and self._ab_has[slots_pp[bad]].any():
                    # close accounting needs these slots' statistics current
                    self.flush_agg()
        if not frzm.any():
            out = self._observe_general(
                key, t, rel_ts, size, direction, ttl, winsize, flags_byte,
                proto, s_port, d_port, flow_id, fin)
            self.last_frozen = frzm
            return out
        statuses = np.full(B, int(FlowStatus.TRACKED), np.uint8)
        slots_out = np.full(B, -1, np.int64)
        accumulated = np.zeros(B, bool)
        frz = np.flatnonzero(frzm)
        slots_out[frz] = slots_pp[frz]
        self.metrics.pkts_tracked += frz.size
        self._ab_append(frz, slots_pp[frz], t, rel_ts, size, direction,
                        ttl, winsize, flags_byte)
        rem = np.flatnonzero(~frzm)
        if rem.size:
            st, sl, acc = self._observe_general(
                key[rem], t[rem], rel_ts[rem], size[rem], direction[rem],
                ttl[rem], winsize[rem], flags_byte[rem], proto[rem],
                s_port[rem], d_port[rem], flow_id[rem], fin[rem])
            statuses[rem] = st
            slots_out[rem] = sl
            accumulated[rem] = acc
        self.last_frozen = frzm
        return statuses, slots_out, accumulated

    def _ab_append1(self, slot, t, rel_ts, size, direction, ttl, winsize,
                    flags_byte) -> None:
        """Stage one frozen-path packet in the fold arena (scalar cadence)."""
        i = self._abuf_n
        self._ab_slot[i] = slot
        self._ab_t[i] = t
        self._ab_rel[i] = rel_ts
        self._ab_size[i] = size
        self._ab_dir[i] = direction
        self._ab_ttl[i] = ttl
        self._ab_win[i] = winsize
        self._ab_fb[i] = flags_byte
        self._ab_has[slot] = True
        self._abuf_n = i + 1
        if self._abuf_n == self._ab_cap:
            self.flush_agg()

    def _ab_append(self, frz, sl, t, rel_ts, size, direction, ttl, winsize,
                   flags_byte) -> None:
        """Stage a block's frozen carve in the fold arena.

        Appends split exactly at arena capacity so fold boundaries land on
        the same packet positions regardless of how the stream was chunked
        — the scalar cadence and any block cadence stage and fold the same
        packet sequence at the same points (refresh scheduling and the
        buffered/current split stay chunk-invariant)."""
        n = frz.size
        off = 0
        while off < n:
            take = min(n - off, self._ab_cap - self._abuf_n)
            i = self._abuf_n
            sel = frz[off:off + take]
            sls = sl[off:off + take]
            self._ab_slot[i:i + take] = sls
            self._ab_t[i:i + take] = t[sel]
            self._ab_rel[i:i + take] = rel_ts[sel]
            self._ab_size[i:i + take] = size[sel]
            self._ab_dir[i:i + take] = direction[sel]
            self._ab_ttl[i:i + take] = ttl[sel]
            self._ab_win[i:i + take] = winsize[sel]
            self._ab_fb[i:i + take] = flags_byte[sel]
            self._ab_has[sls] = True
            self._abuf_n = i + take
            off += take
            if self._abuf_n == self._ab_cap:
                self.flush_agg()

    def _ab_append_all(self, sl, t, rel_ts, size, direction, ttl, winsize,
                       flags_byte) -> None:
        """`_ab_append` when the whole block is frozen: contiguous slice
        copies instead of fancy gathers (the steady-state hot path)."""
        n = sl.size
        off = 0
        while off < n:
            take = min(n - off, self._ab_cap - self._abuf_n)
            i = self._abuf_n
            j = i + take
            p = off + take
            sls = sl[off:p]
            self._ab_slot[i:j] = sls
            self._ab_t[i:j] = t[off:p]
            self._ab_rel[i:j] = rel_ts[off:p]
            self._ab_size[i:j] = size[off:p]
            self._ab_dir[i:j] = direction[off:p]
            self._ab_ttl[i:j] = ttl[off:p]
            self._ab_win[i:j] = winsize[off:p]
            self._ab_fb[i:j] = flags_byte[off:p]
            self._ab_has[sls] = True
            self._abuf_n = j
            off = p
            if j == self._ab_cap:
                self.flush_agg()

    def flush_agg(self) -> None:
        """Fold every arena-staged packet into seen/last_ts and the
        aggregate columns, in arrival order.

        One stable sort groups the arena by slot (time order preserved
        within each group); the fold is the same Chan-merge
        `_agg_update_sorted` the general path uses, so a table that drains
        here is bit-comparable to one that folded eagerly — exact on every
        count/sum/min/max cell, with M2 differing only by float merge
        order (~1e-15 rel). Refresh crossings are detected at fold time
        from the per-slot seen span."""
        n = self._abuf_n
        if not n:
            return
        self._abuf_n = 0
        sl = self._ab_slot[:n]
        order = np.argsort(sl, kind="stable")
        sls = sl[order]
        bnd = np.empty(n, bool)
        bnd[0] = True
        np.not_equal(sls[1:], sls[:-1], out=bnd[1:])
        start = np.flatnonzero(bnd)
        counts = np.diff(np.append(start, n))
        slots_g = sls[start]
        segidx = np.cumsum(bnd) - 1
        old_seen = self.ctrl["seen"][slots_g].astype(np.int64)
        new_seen = old_seen + counts
        self.ctrl["seen"][slots_g] = new_seen
        self.ctrl["last_ts"][slots_g] = self._ab_t[order[start + counts - 1]]
        self._agg_update_sorted(
            order, segidx, np.arange(len(start)), start, counts, slots_g,
            self._ab_rel[:n], self._ab_size[:n], self._ab_dir[:n],
            self._ab_ttl[:n], self._ab_win[:n], self._ab_fb[:n])
        self._ab_has.fill(False)
        if self.refresh_every > 0:
            # the arena stages packets of any live flow, but only
            # PREDICTED flows are on a drift-refresh cadence
            pred = self.ctrl["state"][slots_g] == 3
            if pred.any():
                self._note_refresh(slots_g[pred], old_seen[pred],
                                   new_seen[pred])

    def _observe_general(
        self, key, t, rel_ts, size, direction, ttl, winsize, flags_byte,
        proto, s_port, d_port, flow_id, fin,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The three-phase block machinery (`observe_batch`'s docstring);
        under reuse it runs on the non-frozen remainder only."""
        B = len(key)
        m = self.metrics
        statuses = np.full(B, int(FlowStatus.TRACKED), np.uint8)
        slots_out = np.full(B, -1, np.int64)
        accumulated = np.zeros(B, bool)

        uk, firstpos, inv = np.unique(key, return_index=True,
                                      return_inverse=True)
        U = len(uk)
        uslot = self._probe_many(uk)
        # first FIN position per key (B = no FIN in this block); fidx is
        # ascending, so unique's first occurrence is the minimum position
        finpos = np.full(U, B, np.int64)
        fidx = np.flatnonzero(fin)
        if fidx.size:
            uf, ufirst = np.unique(inv[fidx], return_index=True)
            finpos[uf] = fidx[ufirst]

        new_u = uslot < 0
        has_fin_u = finpos < B
        # conservative: if allocations could exhaust the free list, the
        # alloc/recycle interleaving decides slots and drops — keep every
        # new-key packet in the ordered scalar pass
        tight = len(self._free) < int(new_u.sum())
        scalar_all_u = new_u & (has_fin_u | tight)

        pos = np.arange(B)
        pinv = inv  # per-packet key index
        # phase-2 membership per packet
        in_scalar = scalar_all_u[pinv] \
            | (has_fin_u[pinv] & (pos >= finpos[pinv])) \
            | (new_u[pinv] & (pos == firstpos[pinv]))
        # phase-1 membership: resident FIN-key packets before the first FIN
        in_prefix = (~new_u[pinv]) & has_fin_u[pinv] & (pos < finpos[pinv])

        def fast_apply(fsel: np.ndarray, slot_of_key: np.ndarray) -> None:
            """Vectorized observe for packets with no structural effects.

            `fsel` holds block positions (ascending); `slot_of_key` maps
            unique-key index -> resolved slot."""
            if not fsel.size:
                return
            order = np.argsort(pinv[fsel], kind="stable")
            fs = fsel[order]
            g = pinv[fs]
            uniq_g, start = np.unique(g, return_index=True)
            counts = np.diff(np.append(start, g.size))
            slots_g = slot_of_key[uniq_g]
            rank = np.arange(g.size) - np.repeat(start, counts)
            slots_out[fs] = np.repeat(slots_g, counts)

            # tracker touch: every packet updates seen/last_ts
            if self.reuse:
                # deferred-fold lane for every non-structural packet of a
                # reuse table, not just frozen ones: seen/last_ts and the
                # aggregate columns fold in arena order (the structural
                # scalar path and every agg reader flush first, so per-slot
                # ordering stays exact). This keeps the pre-classification
                # phase as cheap as plain tracking — the eager per-chunk
                # Chan fold is what the arena exists to amortize.
                self._ab_append(fs, np.repeat(slots_g, counts), t, rel_ts,
                                size, direction, ttl, winsize, flags_byte)
            else:
                self.ctrl["seen"][slots_g] += counts
                self.ctrl["last_ts"][slots_g] = t[fs[start + counts - 1]]
                if self.track_agg:
                    self._agg_update_sorted(fs, g, uniq_g, start, counts,
                                            slots_g, rel_ts, size, direction,
                                            ttl, winsize, flags_byte)

            # ACTIVE flows accumulate their first (pkt_depth - count) packets
            c0 = self.ctrl["count"][slots_g].astype(np.int64)
            active = self.ctrl["state"][slots_g] == 1
            n_acc = np.where(active, np.minimum(counts, self.pkt_depth - c0), 0)
            acc_mask = rank < np.repeat(n_acc, counts)
            apk = fs[acc_mask]
            rows = np.repeat(slots_g, n_acc)
            cols = np.repeat(c0, n_acc) + rank[acc_mask]
            self.ts[rows, cols] = rel_ts[apk]
            self.size[rows, cols] = size[apk]
            self.direction[rows, cols] = direction[apk]
            self.ttl[rows, cols] = ttl[apk]
            self.winsize[rows, cols] = winsize[apk]
            self.flags[rows, cols] = _FLAG_LUT[flags_byte[apk]]
            self.ctrl["count"][slots_g] = c0 + n_acc
            accumulated[apk] = True
            m.pkts_accumulated += int(n_acc.sum())
            m.pkts_tracked += int(fs.size - n_acc.sum())

            # depth reached inside the block -> READY at the triggering pkt
            now_ready = np.flatnonzero(active & (c0 + n_acc == self.pkt_depth))
            if now_ready.size:
                rdy_slots = slots_g[now_ready]
                trig = fs[start[now_ready] + n_acc[now_ready] - 1]
                self.ctrl["state"][rdy_slots] = 2
                self.ctrl["ready_ts"][rdy_slots] = t[trig]
                statuses[trig] = int(FlowStatus.READY)

        # phase 1: pre-FIN prefixes of resident FIN-bearing keys
        fast_apply(np.flatnonzero(in_prefix), uslot)

        # phase 2: structural events in original packet order (bulk-convert
        # the scalar subset to python values once — ~10x cheaper than
        # per-field numpy scalar conversion inside the loop)
        sc = np.flatnonzero(in_scalar)
        if sc.size:
            obs = self._observe1
            for i, k_, t_, rts, sz, dr, tl, ws, fb, pr, sp_, dp_, fl, fn in zip(
                sc.tolist(), key[sc].tolist(), t[sc].tolist(),
                rel_ts[sc].tolist(), size[sc].tolist(),
                direction[sc].tolist(), ttl[sc].tolist(),
                winsize[sc].tolist(), flags_byte[sc].tolist(),
                proto[sc].tolist(), s_port[sc].tolist(), d_port[sc].tolist(),
                flow_id[sc].tolist(), fin[sc].tolist(),
            ):
                a0 = m.pkts_accumulated
                st, sl = obs(k_, t_, rts, sz, dr, tl, ws, fb, pr, sp_, dp_,
                             fl, bool(fn))
                statuses[i] = int(st)
                slots_out[i] = sl
                accumulated[i] = m.pkts_accumulated > a0

        # phase 3: the fin-free bulk (now-allocated new keys re-resolved)
        bulk = ~(in_scalar | in_prefix)
        if bulk.any():
            slot_of_key = uslot
            if new_u.any() and not tight:
                nk = np.flatnonzero(new_u & ~scalar_all_u)
                if nk.size:
                    slot_of_key = uslot.copy()
                    slot_of_key[nk] = self._probe_many(uk[nk])
            fast_apply(np.flatnonzero(bulk), slot_of_key)

        return statuses, slots_out, accumulated

    # -- maintenance ---------------------------------------------------------

    def detach_slot(self, slot: int) -> None:
        """Remove a slot from this table *without* recycle accounting.

        Used by migration (`move_slot`): the flow is not ending, it is
        moving to another table, so `slots_recycled` must not count it —
        the migration counters do."""
        self._clear_slot(slot)

    def mark_predicted(self, slots: np.ndarray) -> list[int]:
        """Dispatch flushed these slots: recycle fully-closed flows, keep
        the rest as PREDICTED (tracked until both FINs or idle timeout)."""
        recycled = []
        for s in np.asarray(slots, dtype=np.int64):
            if self.ctrl["fin_mask"][s] == 3:
                self.recycle(int(s))
                recycled.append(int(s))
            else:
                self.ctrl["state"][s] = 3  # PREDICTED
        return recycled

    def evict_idle(self, now: float) -> list[int]:
        """Timeout flows idle for > idle_timeout_s.

        PREDICTED flows are recycled; ACTIVE flows (never reached depth n,
        never saw FIN) are transitioned to READY and returned so the caller
        can enqueue them for a late flush. READY flows are left to the
        dispatcher's flush timeout.
        """
        if self.reuse and self._abuf_n:
            # idleness reads last_ts, which may still be staged in the arena
            self.flush_agg()
        state = self.ctrl["state"]
        idle = (now - self.ctrl["last_ts"]) > self.idle_timeout_s
        for s in np.nonzero((state == 3) & idle)[0]:
            self.recycle(int(s))
        late = []
        for s in np.nonzero((state == 1) & idle)[0]:
            if self.ctrl["count"][s] > 0:
                self.ctrl["state"][s] = 2
                self.ctrl["ready_ts"][s] = now
                late.append(int(s))
                self.metrics.flows_evicted_idle += 1
            else:
                self.recycle(int(s))
        return late

    def flush_all(self, now: float) -> list[int]:
        """End-of-stream drain: queue every still-active flow with data."""
        if self.reuse and self._abuf_n:
            self.flush_agg()
        late = []
        for s in np.nonzero(self.ctrl["state"] == 1)[0]:
            if self.ctrl["count"][s] > 0:
                self.ctrl["state"][s] = 2
                self.ctrl["ready_ts"][s] = now
                late.append(int(s))
            else:
                self.recycle(int(s))
        return late


def move_slot(src: FlowTable, dst: FlowTable, slot: int) -> int:
    """Migrate one live flow's state from `src` to `dst` (DESIGN.md §9).

    The transfer is a pure relocation: identity (5-tuple key), control
    fields (state, fin_mask, counts, timestamps, flow_id) and the dense
    payload move bit-exactly, so extraction on the destination produces
    exactly what it would have produced on the source. Lifecycle counters
    are *not* bumped — a migrated flow is the same flow, not a new one
    (`flows_seen`) nor a finished one (`slots_recycled`); only the
    `flows_migrated_out/in` counters record the transfer.

    Tables may differ in `pkt_depth` (pipeline hot-swap): the payload
    prefix up to `min(src.pkt_depth, dst.pkt_depth)` is copied and
    `count` clamps to the destination depth. The caller decides what a
    clamped ACTIVE flow becomes (a flow with `count == dst.pkt_depth`
    is dispatchable under the new configuration).

    Returns the destination slot, or -1 if `dst` has no free slot — the
    flow then stays where it is, and the caller must leave its steering
    entry unchanged (a misrouted continuation would re-tenant the
    5-tuple on the destination and classify the flow twice).
    """
    if not dst._free:
        return -1
    if src.reuse and src._abuf_n and src._ab_has[slot]:
        # the migrating flow has staged frozen-path packets: fold them on
        # the source first so ctrl/agg copy the complete statistics
        src.flush_agg()
    key = int(src.ctrl["key"][slot])
    found, bucket = dst._probe(key)
    if found >= 0:
        # the key already lives in dst (should be impossible while a flow
        # is owned by exactly one shard); refuse rather than double-track
        return -1
    dslot = int(dst._free.pop())
    dst.ctrl[dslot] = src.ctrl[slot]
    d = min(src.pkt_depth, dst.pkt_depth)
    cnt = min(int(src.ctrl["count"][slot]), d)
    dst.ctrl["count"][dslot] = cnt
    # destination payload rows are zero (init or recycle), so copying the
    # overlapping prefix leaves the rest as padding — the batch layout
    dst.ts[dslot, :d] = src.ts[slot, :d]
    dst.size[dslot, :d] = src.size[slot, :d]
    dst.direction[dslot, :d] = src.direction[slot, :d]
    dst.ttl[dslot, :d] = src.ttl[slot, :d]
    dst.winsize[dslot, :d] = src.winsize[slot, :d]
    dst.flags[dslot, :d] = src.flags[slot, :d]
    dst.proto[dslot] = src.proto[slot]
    dst.s_port[dslot] = src.s_port[slot]
    dst.d_port[dslot] = src.d_port[slot]
    # incremental aggregates are depth-independent whole-lifetime state:
    # they migrate bit-exactly. Anchors only transfer between same-plan
    # tables (matching anchor width) — a hot-swap to a different feature
    # plan clears them on the caller's side instead.
    if src.agg is not None and dst.agg is not None:
        dst.agg[dslot] = src.agg[slot]
    if (src.anchor is not None and dst.anchor is not None
            and src.anchor.shape[1] == dst.anchor.shape[1]):
        dst.anchor[dslot] = src.anchor[slot]
        dst.anchor_valid[dslot] = src.anchor_valid[slot]
    dst._index_insert(key, dslot, bucket)
    src.detach_slot(slot)
    src.metrics.flows_migrated_out += 1
    dst.metrics.flows_migrated_in += 1
    return dslot

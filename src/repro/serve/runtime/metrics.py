"""Observability for the streaming runtime (DESIGN.md §6).

Everything the replay driver and the dispatcher want to report lives here:

- `LatencyHistogram` — log-bucketed enqueue→prediction flow latencies with
  exact percentiles (raw samples are kept; flow counts are small enough
  that the histogram is a *view*, not the storage).
- `RuntimeMetrics`  — drop/evict/recycle counters, batch-occupancy stats
  and the compile-count probe the shape-bucketing tests assert against.

The counters are deliberately plain ints mutated by the flow table and the
dispatcher: the hot ingest path must not pay for abstraction.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["LatencyHistogram", "RuntimeMetrics"]


class LatencyHistogram:
    """Flow-latency samples with exact quantiles + a log-bucketed view.

    Raw samples are the storage (flow counts are small — thousands, not
    billions); the log-spaced histogram is computed on demand for display,
    so the record path is just an append.
    """

    def __init__(self, lo_s: float = 1e-6, hi_s: float = 1e3, per_decade: int = 8):
        self.lo_s = lo_s
        self.hi_s = hi_s
        n_dec = math.log10(hi_s / lo_s)
        self.edges = np.logspace(
            math.log10(lo_s), math.log10(hi_s), int(round(n_dec * per_decade)) + 1
        )
        self._samples: list[float] = []

    def record_many(self, seconds: np.ndarray) -> None:
        self._samples.extend(np.asarray(seconds, dtype=np.float64).ravel().tolist())

    def counts(self) -> np.ndarray:
        """Log-bucket counts (len(edges)+1: underflow ... overflow)."""
        idx = np.searchsorted(self.edges, np.asarray(self._samples), side="right")
        return np.bincount(idx, minlength=len(self.edges) + 1).astype(np.int64)

    def rows(self) -> list[tuple[float, float, int]]:
        """Occupied buckets as (lo_s, hi_s, count) — the display view."""
        c = self.counts()
        lo = np.concatenate([[0.0], self.edges])
        hi = np.concatenate([self.edges, [np.inf]])
        return [(float(lo[i]), float(hi[i]), int(c[i]))
                for i in np.nonzero(c)[0]]

    @property
    def n(self) -> int:
        return len(self._samples)

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        return float(np.percentile(np.asarray(self._samples), q))

    def summary(self) -> dict:
        return {
            "n": self.n,
            "p50_s": self.percentile(50),
            "p90_s": self.percentile(90),
            "p99_s": self.percentile(99),
            "max_s": float(max(self._samples)) if self._samples else 0.0,
        }


@dataclasses.dataclass
class RuntimeMetrics:
    """Shared counter block for one runtime instance / one replay run."""

    # ingest-side
    pkts_total: int = 0
    pkts_accumulated: int = 0      # packets that updated the dense payload
    pkts_tracked: int = 0          # connection-tracking-only packets (past depth)
    drops_ring: int = 0            # offered load exceeded ingest capacity
    drops_table: int = 0           # flow table full, new flow rejected
    # flow-table lifecycle
    flows_seen: int = 0
    flows_evicted_idle: int = 0    # evicted before reaching depth (late flush)
    slots_recycled: int = 0
    # dispatch-side
    batches: int = 0
    flushes_full: int = 0          # flushed because depth-n batch filled
    flushes_timeout: int = 0       # flushed because oldest flow waited too long
    flushes_drain: int = 0         # flushed at end-of-stream drain
    flows_predicted: int = 0
    duplicate_predictions: int = 0  # re-tenancy fragments, first wins
    batch_occupancy: list = dataclasses.field(default_factory=list)
    shapes_seen: set = dataclasses.field(default_factory=set)
    latency: LatencyHistogram = dataclasses.field(default_factory=LatencyHistogram)

    @property
    def drops(self) -> int:
        """All loss sources combined — the zero-loss criterion counts both."""
        return self.drops_ring + self.drops_table

    @classmethod
    def merged(cls, parts: "list[RuntimeMetrics]") -> "RuntimeMetrics":
        """Aggregate view over per-shard metric blocks (DESIGN.md §8).

        Counters sum (every int field, by introspection, so counters
        added later are aggregated automatically), occupancy samples
        concatenate (in shard order — the aggregate cares about the
        distribution, not the interleaving), shape sets union (the jit
        cache is shared across shards, so the union *is* the compile
        bound), and latency samples merge into one histogram. The parts
        are copied out, not aliased: mutating the merged block never
        writes back into a shard."""
        agg = cls()
        counter_names = [
            f.name for f in dataclasses.fields(cls) if f.type in (int, "int")
        ]
        for p in parts:
            for name in counter_names:
                setattr(agg, name, getattr(agg, name) + getattr(p, name))
            agg.batch_occupancy.extend(p.batch_occupancy)
            agg.shapes_seen |= p.shapes_seen
            agg.latency._samples.extend(p.latency._samples)
        return agg

    def compile_count(self) -> int:
        """Distinct dispatch shapes == upper bound on new XLA executables."""
        return len(self.shapes_seen)

    def occupancy_stats(self) -> dict:
        if not self.batch_occupancy:
            return {"mean": 0.0, "min": 0.0, "max": 0.0}
        occ = np.asarray(self.batch_occupancy)
        return {
            "mean": float(occ.mean()),
            "min": float(occ.min()),
            "max": float(occ.max()),
        }

    def summary(self) -> dict:
        return {
            "pkts_total": self.pkts_total,
            "pkts_accumulated": self.pkts_accumulated,
            "pkts_tracked": self.pkts_tracked,
            "drops": self.drops,
            "drops_ring": self.drops_ring,
            "drops_table": self.drops_table,
            "flows_seen": self.flows_seen,
            "flows_predicted": self.flows_predicted,
            "duplicate_predictions": self.duplicate_predictions,
            "flows_evicted_idle": self.flows_evicted_idle,
            "slots_recycled": self.slots_recycled,
            "batches": self.batches,
            "flushes_full": self.flushes_full,
            "flushes_timeout": self.flushes_timeout,
            "flushes_drain": self.flushes_drain,
            "compile_count": self.compile_count(),
            "batch_occupancy": self.occupancy_stats(),
            "latency": self.latency.summary(),
        }

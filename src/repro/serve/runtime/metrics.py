"""Observability for the streaming runtime (DESIGN.md §6).

Everything the replay driver and the dispatcher want to report lives here:

- `LatencyHistogram` — log-bucketed enqueue→prediction flow latencies with
  *bounded* memory: bucket counts are exact and updated incrementally, raw
  samples are capped by reservoir sampling, and percentiles are exact while
  every sample is still retained, falling back to bucket interpolation
  (error bounded by the bucket width) once the reservoir saturates.
- `RuntimeMetrics`  — drop/evict/recycle counters, batch-occupancy stats
  and the compile-count probe the shape-bucketing tests assert against.

The counters are deliberately plain ints mutated by the flow table and the
dispatcher: the hot ingest path must not pay for abstraction.
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

__all__ = ["LatencyHistogram", "RuntimeMetrics", "METRIC_NAMESPACE"]

# un-shard-prefixed tenant-scoped counter names ("tenant2.dispatch....");
# anchored so the fleet aggregate never double-counts the `shardN.tenantM.`
# per-shard copies the prefixed merge also carries
_TENANT_RE = re.compile(r"^tenant(\d+)\.(.+)$")


class LatencyHistogram:
    """Latency distribution with exact log-bucket counts + capped raw samples.

    A serving runtime records one sample per predicted flow, forever; keeping
    every raw float (as this class originally did) grows without bound and
    `RuntimeMetrics.merged` used to concatenate the leak across shards. The
    storage contract is now:

    - **bucket counts are exact**: `_counts` is updated incrementally on
      every record, so `rows()` and bucket-based percentiles never degrade;
    - **raw samples are a reservoir**: at most `max_samples` floats are kept
      (Algorithm R with a deterministic generator, so replays reproduce);
    - **percentiles** are exact (`np.percentile` over the raw samples) while
      the reservoir still holds *every* sample, and interpolate within the
      exact bucket counts afterwards — the absolute error is bounded by the
      width of the bucket containing the requested rank;
    - min/max/sum stay exact running scalars regardless of the cap.

    Past the cap the bucket-width bound is coarse (at the default 8
    buckets per decade a bucket spans ~33% relative width), so a
    `LatencySketch` (serve/obs/latency.py, DESIGN.md §14.1) can be
    attached: `attach_sketch` creates one fed by every `record_many`,
    `link_sketch` points at an externally fed one recording the same
    sample population (the per-worker `LatencyRecorder`'s total sketch).
    When the attached sketch has seen every sample this histogram has,
    `percentile` reads it instead of interpolating — relative error
    <= the sketch's ``alpha`` (1% by default) at any stream length.
    """

    def __init__(
        self,
        lo_s: float = 1e-6,
        hi_s: float = 1e3,
        per_decade: int = 8,
        max_samples: int = 8192,
        seed: int = 0,
    ):
        self.lo_s = lo_s
        self.hi_s = hi_s
        n_dec = math.log10(hi_s / lo_s)
        self.edges = np.logspace(
            math.log10(lo_s), math.log10(hi_s), int(round(n_dec * per_decade)) + 1
        )
        self.max_samples = max_samples
        self._counts = np.zeros(len(self.edges) + 1, np.int64)
        self._reservoir = np.empty(max_samples, np.float64)
        self._n_res = 0
        self._n = 0
        self._min = math.inf
        self._max = 0.0
        self._sum = 0.0
        self._rng = np.random.default_rng(seed)
        self._sketch = None        # bounded-relative-error percentile source
        self._sketch_fed = False   # True: record_many feeds it (owned)

    def attach_sketch(self, alpha: float = 0.01):
        """Create and own a `LatencySketch` fed by every subsequent
        `record_many`, upgrading post-cap percentiles from the
        bucket-width bound to relative error <= `alpha`. Attach before
        recording: the sketch only covers samples recorded after it."""
        from repro.serve.obs.latency import LatencySketch  # avoid cycle

        self._sketch = LatencySketch(alpha=alpha)
        self._sketch_fed = True
        return self._sketch

    def link_sketch(self, sketch) -> None:
        """Read percentiles from an *externally fed* sketch covering the
        same sample population (e.g. a `LatencyRecorder`'s total sketch,
        written at the same charge site). Never fed by `record_many` —
        that would double-count."""
        self._sketch = sketch
        self._sketch_fed = False

    def record_many(self, seconds: np.ndarray) -> None:
        x = np.asarray(seconds, dtype=np.float64).ravel()
        if x.size == 0:
            return
        if self._sketch_fed:
            self._sketch.record_many(x)
        idx = np.searchsorted(self.edges, x, side="right")
        self._counts += np.bincount(idx, minlength=len(self._counts))
        self._min = min(self._min, float(x.min()))
        self._max = max(self._max, float(x.max()))
        self._sum += float(x.sum())
        # reservoir: fill to capacity, then Algorithm R over the overflow
        k = self.max_samples
        fill = min(x.size, k - self._n_res)
        if fill > 0:
            self._reservoir[self._n_res : self._n_res + fill] = x[:fill]
            self._n_res += fill
        if fill < x.size:
            tail = x[fill:]
            # global index (1-based stream position) of each overflow sample
            pos = self._n + fill + 1 + np.arange(tail.size)
            j = self._rng.integers(0, pos)  # uniform in [0, pos)
            hit = j < k
            self._reservoir[j[hit]] = tail[hit]
        self._n += x.size

    def counts(self) -> np.ndarray:
        """Exact log-bucket counts (len(edges)+1: underflow ... overflow)."""
        return self._counts.copy()

    def rows(self) -> list[tuple[float, float, int]]:
        """Occupied buckets as (lo_s, hi_s, count) — the display view."""
        c = self._counts
        lo = np.concatenate([[0.0], self.edges])
        hi = np.concatenate([self.edges, [np.inf]])
        return [(float(lo[i]), float(hi[i]), int(c[i]))
                for i in np.nonzero(c)[0]]

    @property
    def n(self) -> int:
        """Total samples recorded (not the retained reservoir size)."""
        return self._n

    def percentile(self, q: float) -> float:
        """The q-th percentile (q in [0, 100]).

        Accuracy contract, in order of preference:

        1. **exact** while the reservoir still holds every sample
           (`np.percentile` over the raw floats);
        2. **sketch-backed** past the cap when an attached/linked sketch
           has seen the same population: relative error <= its `alpha`;
        3. **bucket interpolation** over the exact counts otherwise: the
           true rank statistic lies in the same bucket as the returned
           value, so the absolute error is bounded by that bucket's
           width — at `per_decade` log buckets, a relative width of
           ``10**(1/per_decade) - 1`` (~33% at the default 8/decade).
           Deterministic, but coarse: attach a sketch for tail reads.
        """
        if self._n == 0:
            return 0.0
        if self._n == self._n_res:
            # reservoir still holds every sample: exact
            return float(np.percentile(self._reservoir[: self._n_res], q))
        if self._sketch is not None and self._sketch.n == self._n:
            # sketch covers the same population: relative error <= alpha
            return self._sketch.percentile(q)
        # bucket interpolation over the exact counts: rank the q-th sample,
        # find its bucket, interpolate linearly inside it. The true value is
        # somewhere in the same bucket, so the error <= bucket width — a
        # *deterministic* bound, which is why the saturated reservoir is
        # deliberately not consulted here (reservoir quantiles are tighter
        # on average but only statistically; the reservoir stays maintained
        # for the exact-merge path and raw-sample diagnostics).
        rank = min(max(int(math.ceil(q / 100.0 * self._n)), 1), self._n)
        cum = np.cumsum(self._counts)
        b = int(np.searchsorted(cum, rank, side="left"))
        lo = self._min if b == 0 else float(self.edges[b - 1])
        hi = float(self.edges[b]) if b < len(self.edges) else self._max
        prev = 0 if b == 0 else int(cum[b - 1])
        frac = (rank - prev) / max(int(self._counts[b]), 1)
        val = lo + frac * (max(hi, lo) - lo)
        return float(min(max(val, self._min), self._max))

    def merge_from(self, other: "LatencyHistogram") -> None:
        """Fold another histogram in (aggregate views over shards).

        Counts/min/max/sum merge exactly. Reservoirs concatenate while the
        union still fits (keeping percentiles exact for small fleets) and
        are re-sampled proportionally to each side's true population
        otherwise — consistent with the per-histogram error contract.
        """
        if other._n == 0:
            return
        if self._sketch_fed and other._sketch is not None:
            # owned sketches fold too (linked ones merge via the registry's
            # sketch kind — merging here would double-count them)
            self._sketch.merge_from(other._sketch)
        self._counts += other._counts
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        self._sum += other._sum
        mine = self._reservoir[: self._n_res]
        theirs = other._reservoir[: other._n_res]
        n_total = self._n + other._n
        exact = (self._n == self._n_res and other._n == other._n_res
                 and n_total <= self.max_samples)
        if exact:
            self._reservoir[self._n_res : self._n_res + other._n_res] = theirs
            self._n_res += other._n_res
        else:
            pool = np.concatenate([mine, theirs])
            w = np.concatenate([
                np.full(len(mine), self._n / max(len(mine), 1)),
                np.full(len(theirs), other._n / max(len(theirs), 1)),
            ])
            k = min(self.max_samples, len(pool))
            pick = self._rng.choice(len(pool), size=k, replace=False,
                                    p=w / w.sum())
            self._reservoir[:k] = pool[pick]
            self._n_res = k
        self._n = n_total

    def summary(self) -> dict:
        return {
            "n": self.n,
            "p50_s": self.percentile(50),
            "p90_s": self.percentile(90),
            "p99_s": self.percentile(99),
            "max_s": self._max if self._n else 0.0,
        }


# canonical registry names for the counter fields below (DESIGN.md §11.1).
# Fields added later without an entry here still aggregate — they fall
# back to ``runtime.<field>`` — but the curated names are the public
# namespace dashboards and tests key on.
METRIC_NAMESPACE = {
    "pkts_total": "ingest.pkts_total",
    "pkts_accumulated": "ingest.pkts_accumulated",
    "pkts_tracked": "ingest.pkts_tracked",
    "drops_ring": "ingest.drops_ring",
    "drops_table": "flow_table.drops",
    "flows_seen": "flow_table.flows_seen",
    "flows_evicted_idle": "flow_table.evictions",
    "slots_recycled": "flow_table.slots_recycled",
    "flows_migrated_out": "flow_table.migrated_out",
    "flows_migrated_in": "flow_table.migrated_in",
    "batches": "dispatch.batches",
    "flushes_full": "dispatch.flushes_full",
    "flushes_timeout": "dispatch.flushes_timeout",
    "flushes_drain": "dispatch.flushes_drain",
    "flushes_migrate": "dispatch.flushes_migrate",
    "flushes_swap": "dispatch.flushes_swap",
    "flows_predicted": "dispatch.flows_predicted",
    "duplicate_predictions": "dispatch.duplicates",
    "reuse_hits": "cache.reuse_hits",
    "refreshes": "cache.refreshes",
    "forced_reinfer": "cache.forced_reinfer",
    # latency-component sketches (serve/obs/latency.py, DESIGN.md §14.1) —
    # not counter fields, but registered here so the namespace test covers
    # them and `LatencyRecorder` can't invent registry names ad hoc
    "latency_queue_wait": "latency.queue_wait",
    "latency_batch": "latency.batch",
    "latency_service": "latency.service",
    "latency_total": "latency.total",
    # SLO tracker projections (serve/obs/slo.py, DESIGN.md §14.2)
    "slo_samples": "slo.samples",
    "slo_violations": "slo.violations",
    "slo_breaches": "slo.breaches",
    "slo_attainment": "slo.attainment",
    "slo_breached": "slo.breached",
}


@dataclasses.dataclass
class RuntimeMetrics:
    """Shared counter block for one runtime instance / one replay run."""

    # ingest-side
    pkts_total: int = 0
    pkts_accumulated: int = 0      # packets that updated the dense payload
    pkts_tracked: int = 0          # connection-tracking-only packets (past depth)
    drops_ring: int = 0            # offered load exceeded ingest capacity
    drops_table: int = 0           # flow table full, new flow rejected
    # flow-table lifecycle
    flows_seen: int = 0
    flows_evicted_idle: int = 0    # evicted before reaching depth (late flush)
    slots_recycled: int = 0
    # control plane (DESIGN.md §9)
    flows_migrated_out: int = 0    # slots exported to another shard's table
    flows_migrated_in: int = 0     # slots imported from another shard's table
    # dispatch-side
    batches: int = 0
    flushes_full: int = 0          # flushed because depth-n batch filled
    flushes_timeout: int = 0       # flushed because oldest flow waited too long
    flushes_drain: int = 0         # flushed at end-of-stream drain
    flushes_migrate: int = 0       # quiesce flush ahead of a RETA migration
    flushes_swap: int = 0          # quiesce flush ahead of a pipeline hot-swap
    flows_predicted: int = 0
    duplicate_predictions: int = 0  # re-tenancy fragments, first wins
    # prediction reuse (DESIGN.md §12)
    reuse_hits: int = 0            # refresh checks that kept the cached pred
    refreshes: int = 0             # drift-triggered re-inferences
    forced_reinfer: int = 0        # threshold-0 re-inferences (parity mode)
    # multi-tenant serving (DESIGN.md §15): per-tenant prediction counts,
    # keyed by tenant index — empty for single-tenant pipelines
    tenant_predictions: dict = dataclasses.field(default_factory=dict)
    batch_occupancy: list = dataclasses.field(default_factory=list)
    shapes_seen: set = dataclasses.field(default_factory=set)
    latency: LatencyHistogram = dataclasses.field(default_factory=LatencyHistogram)
    # per-component latency sketches (DESIGN.md §14.1), minted by
    # `Observability.attach_worker` when latency recording is on; None
    # keeps the disabled path at one attr load per charged batch
    latency_components: object = None

    @property
    def drops(self) -> int:
        """All loss sources combined — the zero-loss criterion counts both."""
        return self.drops_ring + self.drops_table

    @classmethod
    def counter_fields(cls) -> list[str]:
        """Every plain-int counter field, by introspection — counters
        added later are picked up by the registry bridge automatically."""
        return [f.name for f in dataclasses.fields(cls)
                if f.type in (int, "int")]

    def enable_latency_components(self, recorder) -> None:
        """Install a per-component `LatencyRecorder` and point the total
        histogram at its total sketch, so `latency.percentile` keeps its
        bounded relative error past the reservoir cap."""
        self.latency_components = recorder
        self.latency.link_sketch(recorder.sketches["total"])

    def to_registry(self, prefix: str = "", registry=None):
        """Project this block into a `MetricsRegistry` namespace
        (DESIGN.md §11.1): counters under their `METRIC_NAMESPACE` names
        (``runtime.<field>`` fallback for unmapped ones), occupancy as
        samples, the shape set as a set, and the latency histogram
        attached live (snapshots copy; `MetricsRegistry.merge` folds via
        `merge_from` into a fresh block, never aliasing this one)."""
        from repro.serve.obs.registry import MetricsRegistry

        reg = registry if registry is not None else MetricsRegistry()
        for name in self.counter_fields():
            canon = METRIC_NAMESPACE.get(name, f"runtime.{name}")
            reg.set_counter(prefix + canon, getattr(self, name))
        for t_i, v in self.tenant_predictions.items():
            # tenant-prefixed like the shard prefix: the exporter renders
            # both as labels, so per-model series never collide (§15.4)
            reg.set_counter(
                f"{prefix}tenant{int(t_i)}.dispatch.flows_predicted", v)
        reg.extend_samples(prefix + "dispatch.batch_occupancy",
                           self.batch_occupancy)
        reg.union(prefix + "dispatch.shapes_seen", self.shapes_seen)
        reg.attach_hist(prefix + "dispatch.latency", self.latency)
        if self.latency_components is not None:
            self.latency_components.to_registry(registry=reg, prefix=prefix)
        return reg

    @classmethod
    def from_registry(cls, reg) -> "RuntimeMetrics":
        """Rebuild a metrics block from an (unprefixed) registry view —
        the inverse of `to_registry`, used by the fleet aggregate so the
        operator API keeps returning `RuntimeMetrics`. Adopts the
        registry's histogram object: `MetricsRegistry.merge` constructs
        fresh blocks, so the adopted histogram never aliases a shard's."""
        m = cls()
        for name in cls.counter_fields():
            canon = METRIC_NAMESPACE.get(name, f"runtime.{name}")
            setattr(m, name, reg.counter(canon))
        for k, v in reg._counters.items():
            t = _TENANT_RE.match(k)
            if t and t.group(2) == "dispatch.flows_predicted":
                idx = int(t.group(1))
                m.tenant_predictions[idx] = (
                    m.tenant_predictions.get(idx, 0) + v)
        m.batch_occupancy = list(
            reg._samples.get("dispatch.batch_occupancy", []))
        m.shapes_seen = set(reg._sets.get("dispatch.shapes_seen", set()))
        if "dispatch.latency" in reg._hists:
            m.latency = reg.hist("dispatch.latency")
        if METRIC_NAMESPACE["latency_total"] in reg._sketches:
            from repro.serve.obs.latency import LatencyRecorder  # avoid cycle

            m.enable_latency_components(LatencyRecorder.from_registry(reg))
        return m

    def compile_count(self) -> int:
        """Distinct dispatch shapes == upper bound on new XLA executables."""
        return len(self.shapes_seen)

    def occupancy_stats(self) -> dict:
        if not self.batch_occupancy:
            return {"mean": 0.0, "min": 0.0, "max": 0.0}
        occ = np.asarray(self.batch_occupancy)
        return {
            "mean": float(occ.mean()),
            "min": float(occ.min()),
            "max": float(occ.max()),
        }

    def summary(self) -> dict:
        return {
            "pkts_total": self.pkts_total,
            "pkts_accumulated": self.pkts_accumulated,
            "pkts_tracked": self.pkts_tracked,
            "drops": self.drops,
            "drops_ring": self.drops_ring,
            "drops_table": self.drops_table,
            "flows_seen": self.flows_seen,
            "flows_predicted": self.flows_predicted,
            "duplicate_predictions": self.duplicate_predictions,
            "flows_evicted_idle": self.flows_evicted_idle,
            "slots_recycled": self.slots_recycled,
            "flows_migrated_out": self.flows_migrated_out,
            "flows_migrated_in": self.flows_migrated_in,
            "batches": self.batches,
            "flushes_full": self.flushes_full,
            "flushes_timeout": self.flushes_timeout,
            "flushes_drain": self.flushes_drain,
            "flushes_migrate": self.flushes_migrate,
            "flushes_swap": self.flushes_swap,
            "reuse_hits": self.reuse_hits,
            "refreshes": self.refreshes,
            "forced_reinfer": self.forced_reinfer,
            **({"tenant_predictions": dict(self.tenant_predictions)}
               if self.tenant_predictions else {}),
            "compile_count": self.compile_count(),
            "batch_occupancy": self.occupancy_stats(),
            "latency": self.latency.summary(),
            **({"latency_components": self.latency_components.summary()}
               if self.latency_components is not None else {}),
        }

"""Offered-load replay + zero-loss throughput measurement (DESIGN.md §6).

The paper's Fig. 5c metric — *zero-loss throughput*, the highest offered
load the pipeline sustains without dropping a single packet — is an
RFC-2544-style measurement, not a model. This module measures it:

1. `PacketStream.from_dataset` flattens a `TrafficDataset` into a packet
   event stream: flows start along a Poisson arrival process (overlapping
   `avg_active_flows` deep), packets follow their flow-relative trace
   timing. Offered load is scaled tcpreplay-style: one clock-compression
   factor on *delivery* times. The payload timestamps the feature path
   consumes stay the trace's own (they are what the original capture
   recorded), so predictions are rate-invariant — which is also what makes
   probing rates without re-running inference sound.
2. `replay` drives the event stream through a `StreamingRuntime` under a
   deterministic two-lane clock model whose constants come from a
   `ServiceModel`:
     - the *ingest lane* is a single server with a bounded ring
       (NIC-style): packets arriving while `ring_capacity` packets are
       already waiting are lost — plus flow-table overflow, these are the
       only loss sources;
     - the *inference lane* runs micro-batches; because dispatch is
       double-buffered, it overlaps ingest and only its own backlog delays
       predictions.
   Real extraction + inference still execute (`execute=True`) so the run
   yields actual predictions; `execute=False` replays timing only, which
   is what the bisection uses (predictions are rate-invariant).
3. `ServiceModel.measure` calibrates the clock constants from wall-clock
   timings of the *actual* ingest loop and jit executables on this
   machine, once per bucket; `ServiceModel.modeled` derives them from the
   feature registry's op DAG (Table-2 magnitudes) for deterministic
   cross-machine runs.
4. `find_zero_loss_rate` brackets and bisects the offered rate to the
   highest zero-drop point, then re-verifies it with a full executing
   replay.

Calibrated-constant clocking keeps the measurement honest (the constants
are measured) while making the search reproducible (the simulation is
exact), which is what lets tests assert "zero drops below the reported
rate" without flaking on scheduler noise.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.traffic.features import per_flow_ops_ns, per_packet_ops, FEATURES
from repro.traffic.synth import FLAG_NAMES, TrafficDataset, scenario_flow_starts

from repro.serve.obs.trace import TID_INFER, TID_INGEST, TID_TENANT0

from .dispatch import BatchRecord, StreamingRuntime
from .flow_table import FlowTable, tuple_hash64
from .metrics import RuntimeMetrics
from .shard import ShardedRuntime

__all__ = [
    "PacketStream",
    "ServiceModel",
    "ReplayStats",
    "replay",
    "find_zero_loss_rate",
]


# ---------------------------------------------------------------------------
# packet event stream
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PacketStream:
    """Flattened per-packet event arrays (delivery-time order) + metadata.

    `base_t` is the float64 delivery time at the stream's base rate
    (`base_pps`); replaying at `offered_pps` multiplies it by
    `base_pps / offered_pps`. `rel_ts32` is the exact float32 payload value
    the flow table stores, so streaming extraction sees bit-identical
    inputs to the batch path.
    """

    fid: np.ndarray        # (E,) int32 flow id (dataset row)
    pidx: np.ndarray       # (E,) int32 packet index within flow
    base_t: np.ndarray     # (E,) float64 delivery time at base rate (sorted)
    rel_ts32: np.ndarray   # (E,) float32 flow-relative payload timestamp
    size: np.ndarray       # (E,) float32
    direction: np.ndarray  # (E,) uint8
    ttl: np.ndarray        # (E,) float32
    winsize: np.ndarray    # (E,) float32
    flags_byte: np.ndarray # (E,) uint8 packed TCP flags
    fin: np.ndarray        # (E,) bool
    # per-flow
    key: np.ndarray        # (n_flows,) uint64 5-tuple hash
    proto: np.ndarray
    s_port: np.ndarray
    d_port: np.ndarray
    label: np.ndarray
    base_pps: float = 0.0  # offered packet rate of the unscaled stream
    class_names: tuple = ()
    # raw 5-tuple endpoints (per flow): what RSS-style symmetric steering
    # hashes over. Optional for streams built before sharding existed.
    s_ip: Optional[np.ndarray] = None   # (n_flows,) int64
    d_ip: Optional[np.ndarray] = None   # (n_flows,) int64

    @property
    def n_events(self) -> int:
        return len(self.fid)

    @property
    def n_flows(self) -> int:
        return len(self.key)

    @property
    def mean_pkts_per_flow(self) -> float:
        return self.n_events / self.n_flows

    @property
    def total_bytes(self) -> float:
        return float(self.size.sum())

    @classmethod
    def from_dataset(
        cls,
        ds: TrafficDataset,
        seed: int = 0,
        avg_active_flows: int = 64,
        scenario: str = "uniform",
    ) -> "PacketStream":
        """Flatten `ds` into a delivery-ordered packet stream.

        `scenario` selects the flow *arrival process* (see
        `repro.traffic.synth.scenario_flow_starts`): "uniform" is the
        historical Poisson process, "burst" modulates it with MMPP on/off
        phases. Dataset-level scenario structure (Zipf flow-mass skew,
        drifting class mix) is applied earlier, by
        `make_scenario_dataset`."""
        rows, cols = np.nonzero(ds.valid_mask())
        flags = ds.flags[rows, cols]  # (E, 8)
        flags_byte = (flags.astype(np.uint16) << np.arange(8)).sum(1).astype(np.uint8)
        fin = flags[:, FLAG_NAMES.index("fin")] > 0
        rng = np.random.default_rng(seed)
        # synthetic 5-tuples: unique src ip/port per flow, shared dst per class
        s_ip = 0x0A000000 + np.arange(ds.n_flows, dtype=np.int64)
        d_ip = 0xC0A80000 + ds.label.astype(np.int64)
        key = np.array(
            [
                tuple_hash64(
                    int(s_ip[i]), int(d_ip[i]), int(ds.s_port[i]),
                    int(ds.d_port[i]), int(ds.proto[i]),
                )
                for i in range(ds.n_flows)
            ],
            dtype=np.uint64,
        )
        # Poisson flow arrivals spaced so ~avg_active_flows overlap; the
        # overlap *structure* is fixed, clock compression scales the speed
        rel64 = ds.ts[rows, cols].astype(np.float64)
        last = np.minimum(ds.flow_len, ds.max_pkts) - 1
        mean_dur = float(ds.ts[np.arange(ds.n_flows), last].mean())
        spacing = max(mean_dur, 1e-3) / max(avg_active_flows, 1)
        starts = scenario_flow_starts(rng, ds.n_flows, spacing, scenario)
        base_t = starts[rows] + rel64
        order = np.argsort(base_t, kind="stable")
        span = float(base_t[order[-1]] - base_t[order[0]])
        return cls(
            fid=rows[order].astype(np.int32),
            pidx=cols[order].astype(np.int32),
            base_t=base_t[order],
            rel_ts32=ds.ts[rows, cols].astype(np.float32)[order],
            size=ds.size[rows, cols].astype(np.float32)[order],
            direction=ds.direction[rows, cols][order],
            ttl=ds.ttl[rows, cols].astype(np.float32)[order],
            winsize=ds.winsize[rows, cols].astype(np.float32)[order],
            flags_byte=flags_byte[order],
            fin=fin[order],
            key=key,
            proto=ds.proto.astype(np.float32),
            s_port=ds.s_port.astype(np.float32),
            d_port=ds.d_port.astype(np.float32),
            label=ds.label.copy(),
            base_pps=len(rows) / max(span, 1e-9),
            class_names=ds.class_names,
            s_ip=s_ip,
            d_ip=d_ip,
        )


# ---------------------------------------------------------------------------
# service models (the replay clock's constants)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServiceModel:
    """Per-operation service times (ns) driving the virtual clock."""

    pkt_accum_ns: float                 # ingest: packet into the dense payload
    pkt_track_ns: float                 # ingest: connection tracking only
    bucket_ns: dict[int, float]         # inference lane: per padded batch
    gather_ns_per_flow: float = 200.0   # ingest lane: row gather at flush
    # prediction reuse (DESIGN.md §12): frozen-path packet cost (aggregate
    # update only; falls back to pkt_track_ns when uncalibrated), per-flow
    # drift-check cost at a refresh, and per-flow anchor snap cost
    pkt_frozen_ns: Optional[float] = None
    reuse_check_ns: float = 0.0
    anchor_ns_per_flow: float = 0.0
    # multi-tenant serving (DESIGN.md §15): tenant t's fraction of each
    # inference-lane span — attribution only, the clock charges the fused
    # batch once; None for single-tenant models
    tenant_fracs: Optional[tuple] = None
    source: str = "modeled"

    def packet_ns(self, accumulated: bool, frozen: bool = False) -> float:
        if frozen:
            return self.frozen_ns
        return self.pkt_accum_ns if accumulated else self.pkt_track_ns

    @property
    def frozen_ns(self) -> float:
        return (self.pkt_frozen_ns if self.pkt_frozen_ns is not None
                else self.pkt_track_ns)

    def batch_ns(self, bucket: int) -> float:
        if bucket in self.bucket_ns:
            return self.bucket_ns[bucket]
        # extrapolate linearly from the largest calibrated bucket
        b_max = max(self.bucket_ns)
        return self.bucket_ns[b_max] * bucket / b_max

    def submit_ns(self, n_real: int) -> float:
        return self.gather_ns_per_flow * n_real

    # -- constructors --------------------------------------------------------

    @classmethod
    def modeled(cls, rep, forest, *, overhead_ns: float = 500.0,
                reuse_discount: float = 1.0) -> "ServiceModel":
        """Derive constants from the feature-op DAG (Table-2 magnitudes).

        `reuse_discount` < 1 models drift-gated prediction reuse: frozen
        packets are charged that fraction of the tracked cost (the caller
        supplies the ratio — `TrafficProfiler.reuse_discount` learns it
        from measured calibrations when any exist), and the drift check
        is one feature emission from the aggregate block per flow."""
        per_pkt = per_packet_ops(rep.features)
        per_flow = per_flow_ops_ns(rep.features)
        n_sort = sum(1 for f in rep.features if FEATURES[f].sorting)
        sort_ns = n_sort * 0.8 * rep.depth * np.log2(max(rep.depth, 2.0))
        infer_ns = forest.n_trees * forest.depth * 1.2 + 2.0 * forest.n_out
        flow_ns = per_flow + sort_ns + infer_ns
        buckets = {b: overhead_ns + flow_ns * b for b in (8, 16, 32, 64, 128, 256, 512)}
        track_ns = 2.0  # capture + tracker touch, past depth n
        frozen_ns = None
        check_ns = 0.0
        if reuse_discount < 1.0:
            frozen_ns = track_ns * reuse_discount
            check_ns = 50.0 + 5.0 * len(rep.features)
        return cls(
            pkt_accum_ns=per_pkt,
            pkt_track_ns=track_ns,
            bucket_ns=buckets,
            pkt_frozen_ns=frozen_ns,
            reuse_check_ns=check_ns,
            anchor_ns_per_flow=check_ns,
            source="modeled",
        )

    @classmethod
    def modeled_multi_tenant(
        cls, reps, forests, *, overhead_ns: float = 500.0
    ) -> "ServiceModel":
        """Constants for a shared multi-tenant fleet (DESIGN.md §15).

        The white-box sharing shows up as the cost asymmetry: ingest and
        extraction are charged ONCE over the *union* feature plan (shared
        ops deduped across tenants), while inference sums every tenant's
        forest — exactly what the merged `FlowTable` + fused multi-forest
        kernel execute. `tenant_fracs` carries each tenant's share of the
        inference term so the tracer can attribute the fused span."""
        feats = sorted({f for r in reps for f in r.features})
        depth = max(int(r.depth) for r in reps)
        per_pkt = per_packet_ops(feats)
        per_flow = per_flow_ops_ns(feats)
        n_sort = sum(1 for f in feats if FEATURES[f].sorting)
        sort_ns = n_sort * 0.8 * depth * np.log2(max(depth, 2.0))
        infer = [f.n_trees * f.depth * 1.2 + 2.0 * f.n_out for f in forests]
        flow_ns = per_flow + sort_ns + sum(infer)
        buckets = {b: overhead_ns + flow_ns * b
                   for b in (8, 16, 32, 64, 128, 256, 512)}
        total_inf = max(sum(infer), 1e-9)
        return cls(
            pkt_accum_ns=per_pkt,
            pkt_track_ns=2.0,
            bucket_ns=buckets,
            tenant_fracs=tuple(v / total_inf for v in infer),
            source="modeled",
        )

    @classmethod
    def measure(
        cls,
        runtime: StreamingRuntime,
        stream: PacketStream,
        *,
        n_pkt_sample: int = 8000,
        reps: int = 3,
        ingest_chunk: int = 128,
        calibrate_warm: bool = False,
    ) -> "ServiceModel":
        """Calibrate from wall-clock timings of the real code paths.

        `calibrate_warm=True` additionally measures the steady-state
        per-packet classes on a *populated* table — the tracking touch of
        a flow past its window and the frozen aggregate-only touch of a
        PREDICTED flow under reuse — plus the per-flow drift-check cost.
        Without it the legacy estimate (`pkt_track_ns = 0.25 ×` the cold
        per-packet cost) is kept, so existing calibrations reproduce."""
        # a sharded fleet is homogeneous: calibrate on its first worker
        runtime = getattr(runtime, "shards", [runtime])[0]
        # -- ingest cost: run the actual vectorized observe_batch path
        # (the path the replay drives) on a scratch table, block by block.
        # The default block matches the flush-bounded sub-blocks
        # (~max_batch) the runtime actually feeds it at measured rates.
        # Mirrors the runtime table's reuse layout so aggregate-update
        # work is part of the charged per-packet cost when reuse is on.
        rtab = runtime.table
        tab_kw = dict(
            metrics=None, track_agg=rtab.track_agg, reuse=rtab.reuse,
            refresh_every=rtab.refresh_every, anchor_dim=rtab.anchor_dim,
            agg_buffer=rtab._ab_cap or 1024,
        )

        def fresh_table():
            kw = dict(tab_kw)
            kw["metrics"] = RuntimeMetrics()
            return FlowTable(rtab.capacity, rtab.pkt_depth, **kw)

        table = fresh_table()
        n = min(n_pkt_sample, stream.n_events)
        fid = stream.fid[:n]
        keys = stream.key[fid]
        proto, s_port, d_port = (
            stream.proto[fid], stream.s_port[fid], stream.d_port[fid])

        def feed(tbl, fin):
            for c0 in range(0, n, ingest_chunk):
                c1 = min(c0 + ingest_chunk, n)
                tbl.observe_batch(
                    keys[c0:c1], stream.base_t[c0:c1], stream.rel_ts32[c0:c1],
                    stream.size[c0:c1], stream.direction[c0:c1],
                    stream.ttl[c0:c1], stream.winsize[c0:c1],
                    stream.flags_byte[c0:c1], proto[c0:c1], s_port[c0:c1],
                    d_port[c0:c1], fid[c0:c1], fin[c0:c1],
                )
        # best-of-reps: a single timing pass is at the mercy of scheduler
        # noise on shared machines, and this one constant dominates the
        # ingest lane — jitter here scatters whole benchmark rows
        pkt_ns = np.inf
        for _ in range(reps):
            scratch = fresh_table()
            t0 = time.perf_counter()
            feed(scratch, stream.fin)
            pkt_ns = min(pkt_ns, (time.perf_counter() - t0) / n * 1e9)
            table = scratch

        pkt_track_ns = pkt_ns * 0.25  # legacy guess: tracker skips payload
        pkt_frozen_ns = None
        reuse_check_ns = 0.0
        anchor_ns = 0.0
        if calibrate_warm:
            # steady-state tracking: re-feed the same packets into the
            # populated table — every flow is past its window, every
            # packet takes the tracked path (fin suppressed so no flow
            # closes mid-measurement)
            no_fin = np.zeros(n, bool)
            best = np.inf
            for _ in range(reps):
                t0 = time.perf_counter()
                feed(table, no_fin)
                best = min(best, (time.perf_counter() - t0) / n * 1e9)
            pkt_track_ns = best
            if table.reuse:
                # frozen fast path: mark every live flow PREDICTED, so the
                # re-fed packets all take the aggregate-only carve-out
                live = table.ctrl["state"] != 0
                table.ctrl["state"][live] = 3
                best = np.inf
                for _ in range(reps):
                    t0 = time.perf_counter()
                    feed(table, no_fin)
                    best = min(best, (time.perf_counter() - t0) / n * 1e9)
                pkt_frozen_ns = best
                # drift check / anchor snap: one feature emission from the
                # aggregate block per flow (the compare itself is noise)
                from repro.traffic.extraction import (
                    emit_agg_features, stats_plan)

                plan = stats_plan(runtime.pipeline.rep.features)
                slots = np.nonzero(live)[0][:256]
                if slots.size:
                    best = np.inf
                    for _ in range(max(reps, 3)):
                        t0 = time.perf_counter()
                        cols = emit_agg_features(
                            plan, table.agg[slots],
                            proto=table.proto[slots],
                            s_port=table.s_port[slots],
                            d_port=table.d_port[slots])
                        np.stack(cols, axis=1)
                        best = min(
                            best,
                            (time.perf_counter() - t0) / slots.size * 1e9)
                    reuse_check_ns = best
                    anchor_ns = best
                table.ctrl["state"][live] = 2  # restore READY for gather

        # -- inference lane: time the jit'd pipeline once per bucket
        # (a scratch dispatcher bound to the populated scratch table, so the
        # gathered batches hold real flow rows)
        from .dispatch import MicroBatchDispatcher

        disp = runtime.dispatcher
        disp_s = MicroBatchDispatcher(
            table, runtime.pipeline, max_batch=disp.max_batch,
            min_bucket=disp.min_bucket, execute=False, metrics=table.metrics,
        )
        buckets, b = [], disp.min_bucket
        while b <= disp.max_batch:
            buckets.append(b)
            b *= 2
        slots = np.nonzero(table.ctrl["state"] != 0)[0]
        bucket_ns = {}
        gather_ns = []
        for b in buckets:
            sl = slots[: min(len(slots), b)]
            disp_s.gather(sl, b)  # warm: allocates this bucket's arena
            t0 = time.perf_counter()
            ds = disp_s.gather(sl, b)
            gather_ns.append((time.perf_counter() - t0) / max(len(sl), 1) * 1e9)
            runtime.pipeline.finalize(runtime.pipeline.predict_async(ds))  # compile
            best = np.inf
            for _ in range(reps):
                t0 = time.perf_counter()
                runtime.pipeline.finalize(runtime.pipeline.predict_async(ds))
                best = min(best, time.perf_counter() - t0)
            bucket_ns[b] = best * 1e9
        return cls(
            pkt_accum_ns=pkt_ns,
            pkt_track_ns=pkt_track_ns,
            bucket_ns=bucket_ns,
            gather_ns_per_flow=float(np.median(gather_ns)),
            pkt_frozen_ns=pkt_frozen_ns,
            reuse_check_ns=reuse_check_ns,
            anchor_ns_per_flow=anchor_ns,
            source="measured",
        )


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplayStats:
    offered_pps: float
    offered_gbps: float
    duration_s: float
    drops: int
    drops_ring: int
    drops_table: int
    metrics: RuntimeMetrics
    predictions: dict
    latency_p50_s: float
    latency_p99_s: float
    # sharded replay: worker count, steering balance, per-worker rollups
    n_shards: int = 1
    load_imbalance: float = 1.0
    per_shard: list = dataclasses.field(default_factory=list)
    # control-plane replay: rebalance/swap/elastic activity summary
    control: dict = dataclasses.field(default_factory=dict)
    # virtual service seconds per stage, summed over workers (ingest =
    # packet accumulation/tracking, infer = batched extract+inference,
    # flush = gather/submit) — where a packet's time goes (DESIGN.md §11)
    stage_seconds: dict = dataclasses.field(default_factory=dict)

    def stage_shares(self) -> dict:
        """Each stage's share of total charged service time (sums to 1
        whenever any service was charged)."""
        total = sum(self.stage_seconds.values())
        if total <= 0:
            return {k: 0.0 for k in self.stage_seconds}
        return {k: v / total for k, v in self.stage_seconds.items()}

    def summary(self) -> dict:
        out = {
            "offered_pps": self.offered_pps,
            "offered_gbps": self.offered_gbps,
            "duration_s": self.duration_s,
            "drops": self.drops,
            "latency_p50_s": self.latency_p50_s,
            "latency_p99_s": self.latency_p99_s,
            **{f"rt_{k}": v for k, v in self.metrics.summary().items()
               if not isinstance(v, dict)},
        }
        if self.stage_seconds:
            out["stage_seconds"] = dict(self.stage_seconds)
            out["stage_shares"] = self.stage_shares()
        if self.n_shards > 1:
            out["n_shards"] = self.n_shards
            out["load_imbalance"] = self.load_imbalance
            out["per_shard"] = self.per_shard
        if self.control:
            out["control"] = self.control
        return out


def _lindley(t: np.ndarray, s: np.ndarray, busy: float) -> np.ndarray:
    """Vectorized single-server queue recurrence b_i = max(t_i, b_{i-1}) + s_i.

    Standard Lindley unrolling: with S_i = cumsum(s) inclusive,
    b_i = S_i + max(busy, max_{j<=i}(t_j - S_{j-1})).
    """
    cs = np.cumsum(s)
    return cs + np.maximum(np.maximum.accumulate(t - (cs - s)), busy)


@dataclasses.dataclass
class _Events:
    """Per-packet event columns for one worker, in delivery order.

    Per-flow attributes (key, 5-tuple floats) are pre-gathered to
    per-packet columns so the drive loop and the per-shard splitter are
    plain slices/fancy-indexing with no indirection left."""

    t: np.ndarray          # scaled delivery times (float64, sorted)
    fid: np.ndarray
    key: np.ndarray
    rel32: np.ndarray
    size: np.ndarray
    direction: np.ndarray
    ttl: np.ndarray
    winsize: np.ndarray
    flags_byte: np.ndarray
    fin: np.ndarray
    proto: np.ndarray
    s_port: np.ndarray
    d_port: np.ndarray


def _gather_events(
    stream: PacketStream, t_e: np.ndarray, sel: Optional[np.ndarray] = None
) -> _Events:
    """Flatten `stream` (optionally the `sel` event subset) to `_Events`."""
    if sel is None:
        fid = stream.fid
        t, rel32 = t_e, stream.rel_ts32
        size, direction, ttl = stream.size, stream.direction, stream.ttl
        winsize, flags_byte, fin = stream.winsize, stream.flags_byte, stream.fin
    else:
        fid = stream.fid[sel]
        t, rel32 = t_e[sel], stream.rel_ts32[sel]
        size, direction, ttl = (
            stream.size[sel], stream.direction[sel], stream.ttl[sel])
        winsize, flags_byte, fin = (
            stream.winsize[sel], stream.flags_byte[sel], stream.fin[sel])
    return _Events(
        t=t, fid=fid, key=stream.key[fid], rel32=rel32, size=size,
        direction=direction, ttl=ttl, winsize=winsize,
        flags_byte=flags_byte, fin=fin, proto=stream.proto[fid],
        s_port=stream.s_port[fid], d_port=stream.d_port[fid],
    )


class _WorkerClock:
    """Persistent two-lane virtual clock for one worker (one NIC queue).

    Holds the lane state (`busy_ingest`, `busy_infer`, the bounded ring of
    outstanding ingest completions) *across* `feed` calls, so a worker can
    be driven incrementally: the static replay feeds the whole steered
    sub-stream in one call, while the control-plane driver interleaves all
    shards block by block, pausing between blocks for telemetry/rebalance
    steps (DESIGN.md §9). The clock semantics per feed are unchanged from
    the original drive loop: vectorized blocks whenever a conservative
    admission bound proves the ring cannot overflow (service charged at
    the worst per-packet rate plus the whole block's possible flush-submit
    cost), an order-exact per-packet fallback otherwise — DESIGN.md
    §6.3/§7.

    `service` is a plain attribute: a pipeline hot-swap retargets the
    worker's constants mid-run by assigning it.
    """

    def __init__(
        self,
        rt: StreamingRuntime,
        service: ServiceModel,
        ring_capacity: int,
        evict_every: int,
        *,
        pid: int = 0,
        tracer=None,
        slo=None,
    ):
        self.rt = rt
        self.service = service
        self.ring_capacity = ring_capacity
        self.evict_every = evict_every
        self.busy_ingest = 0.0
        self.busy_infer = 0.0
        self.ring = np.empty(0, np.float64)  # outstanding completions (sorted)
        self._since_poll = 0
        self.t = 0.0
        # observability (repro.serve.obs): shard pid for trace grouping,
        # optional span tracer, the always-on per-stage service-time
        # rollup (three float adds per block/batch — DESIGN.md §11), and
        # the optional shared SLO tracker (DESIGN.md §14.2) — window
        # counts are integer adds, so all shards feed one tracker
        self.pid = pid
        self.tracer = tracer
        self.slo = slo
        self.stage_s = {"ingest": 0.0, "infer": 0.0, "flush": 0.0}

    def charge(self, recs: list[BatchRecord], charge_submit: bool = True) -> None:
        """Inference-lane accounting; optionally charge the ingest-lane
        submit cost (the vectorized path charges it inside the recurrence
        at the triggering packet instead). Public so the control plane can
        charge quiesce/swap flushes to the worker that fired them."""
        service = self.service
        m = self.rt.metrics
        tr = self.tracer
        for rec in recs:
            if rec.reason == "refresh":
                # reuse refresh (DESIGN.md §12): the drift check is charged
                # per frozen flow examined, the padded re-inference batch
                # only when drift actually sent flows back through the
                # forest, the anchor re-snap per re-anchored flow. No
                # latency sample — a refresh never produces a flow's first
                # prediction (first-prediction-wins keeps `results`
                # bit-identical to the non-reuse path).
                svc = (service.reuse_check_ns * rec.n_checked
                       + service.anchor_ns_per_flow * rec.n_anchor) * 1e-9
                if rec.n_real:
                    svc += service.batch_ns(rec.bucket) * 1e-9
                start = max(rec.flush_ts, self.busy_infer)
                self.busy_infer = start + svc
                self.stage_s["infer"] += svc
                if tr is not None and tr.enabled:
                    tr.span("infer.refresh", start, svc,
                            pid=self.pid, tid=TID_INFER)
                continue
            if charge_submit:
                sub = service.submit_ns(rec.n_real) * 1e-9
                self.busy_ingest += sub
                self.stage_s["flush"] += sub
            svc = (service.batch_ns(rec.bucket)
                   + service.anchor_ns_per_flow * rec.n_anchor) * 1e-9
            start = max(rec.flush_ts, self.busy_infer)
            done = start + svc
            self.busy_infer = done
            self.stage_s["infer"] += svc
            total = done - rec.ready_ts
            m.latency.record_many(total)
            # latency decomposition + SLO accounting (DESIGN.md §14): the
            # enqueue→prediction total splits exactly into queue-wait
            # (ready→flush, per flow), batch-residency (flush→start, the
            # inference lane's backlog) and service (start→done)
            lat = m.latency_components
            if lat is not None:
                lat.record_batch(rec.ready_ts, rec.flush_ts, start, done)
            if self.slo is not None:
                self.slo.note(done, total)
            if tr is not None and tr.enabled:
                # one X span per batch on the inference lane; sampled flow
                # lifecycles close at the same service-completion edge
                tr.span(f"infer.{rec.reason}", start, svc,
                        pid=self.pid, tid=TID_INFER)
                if service.tenant_fracs:
                    # multi-tenant attribution (DESIGN.md §15): partition
                    # the fused span across per-tenant sub-lanes so one
                    # traced replay shows which tenant dominates the
                    # kernel budget; the clock still charges it once
                    t0 = start
                    for t_i, frac in enumerate(service.tenant_fracs):
                        d = svc * frac
                        tr.span(f"infer.tenant{t_i}", t0, d,
                                pid=self.pid, tid=TID_TENANT0 + t_i)
                        t0 += d
                if rec.trace_ids is not None:
                    tr.flow_end(rec.trace_ids,
                                np.full(len(rec.trace_ids), done),
                                pid=self.pid)

    def charge_ingest(self, seconds: float) -> None:
        """Serialize extra work into the ingest lane (e.g. the per-flow
        state-copy cost of a RETA migration)."""
        self.busy_ingest += seconds
        self.stage_s["ingest"] += seconds

    def feed(self, ev: _Events) -> None:
        """Drive one delivery-ordered event block through the worker."""
        rt = self.rt
        service = self.service
        m = rt.metrics
        E = len(ev.t)

        s_acc = service.pkt_accum_ns * 1e-9
        s_trk = service.pkt_track_ns * 1e-9
        s_frz = service.frozen_ns * 1e-9
        s_max = max(s_acc, s_trk, s_frz)
        sub_flow = service.gather_ns_per_flow * 1e-9
        evict_every = self.evict_every

        tr = self.tracer
        pos = 0
        while pos < E:
            hi = min(pos + evict_every, E)
            tc = ev.t[pos:hi]
            n = hi - pos
            busy_at_entry = self.busy_ingest
            # retire completed service (the scalar loop's per-arrival popleft)
            ring = self.ring[np.searchsorted(self.ring, tc[0], side="right"):]

            # conservative no-drop proof for this block: every packet at the
            # slowest service class, all possible flush submits front-loaded
            b_w = _lindley(tc, np.full(n, s_max), self.busy_ingest) \
                + sub_flow * (len(rt.dispatcher._queue) + n)
            carry = ring.size - np.searchsorted(ring, tc, side="right")
            own = np.arange(n) - np.searchsorted(b_w, tc, side="right")
            if int((carry + own).max()) < self.ring_capacity:
                # -- vectorized block: admission proven, ingest in one call
                _, accumulated, recs = rt.ingest_packets(
                    ev.key[pos:hi], tc, ev.rel32[pos:hi], ev.size[pos:hi],
                    ev.direction[pos:hi], ev.ttl[pos:hi], ev.winsize[pos:hi],
                    ev.flags_byte[pos:hi], ev.proto[pos:hi], ev.s_port[pos:hi],
                    ev.d_port[pos:hi], ev.fid[pos:hi], ev.fin[pos:hi],
                )
                s_i = np.where(accumulated, s_acc, s_trk)
                fz = getattr(rt, "last_frozen_mask", None)
                if fz is not None:
                    # frozen PREDICTED flows bypass the 3-phase path: their
                    # packets cost an aggregate-only touch
                    s_i = np.where(fz, s_frz, s_i)
                self.stage_s["ingest"] += float(s_i.sum())
                # exact lane recurrence, segmented at flush submits
                b = np.empty(n)
                seg_lo = 0
                for rec in recs:
                    if rec.reason == "refresh":
                        continue  # infer-lane only (charged below)
                    k = rec.flush_idx
                    if k >= seg_lo:
                        b[seg_lo:k + 1] = _lindley(
                            tc[seg_lo:k + 1], s_i[seg_lo:k + 1],
                            self.busy_ingest)
                        self.busy_ingest = b[k]
                        seg_lo = k + 1
                    sub = service.submit_ns(rec.n_real) * 1e-9
                    self.busy_ingest += sub
                    self.stage_s["flush"] += sub
                if seg_lo < n:
                    b[seg_lo:] = _lindley(tc[seg_lo:], s_i[seg_lo:],
                                          self.busy_ingest)
                    self.busy_ingest = b[n - 1]
                self.ring = np.concatenate([ring, b])
                self.charge(recs, charge_submit=False)
                self.t = tc[-1]
                self._since_poll += n
                if self._since_poll >= evict_every:
                    self.charge(rt.poll(self.t))
                    self._since_poll = 0
            else:
                # -- fallback: per-packet loop, order-exact admission
                rq: deque[float] = deque(ring.tolist())
                ingest = rt.ingest_packet
                ing_s = 0.0
                for i in range(pos, hi):
                    t = self.t = ev.t[i]
                    while rq and rq[0] <= t:
                        rq.popleft()
                    self._since_poll += 1
                    poll_due = self._since_poll >= evict_every
                    if poll_due:
                        self._since_poll = 0
                    if len(rq) >= self.ring_capacity:
                        # drop; a poll boundary landing here is skipped,
                        # matching the scalar cadence (`continue` first)
                        m.pkts_total += 1
                        m.drops_ring += 1
                        continue
                    acc0 = m.pkts_accumulated
                    _, recs = ingest(
                        int(ev.key[i]), t, float(ev.rel32[i]),
                        float(ev.size[i]), int(ev.direction[i]),
                        float(ev.ttl[i]), float(ev.winsize[i]),
                        int(ev.flags_byte[i]), float(ev.proto[i]),
                        float(ev.s_port[i]), float(ev.d_port[i]),
                        int(ev.fid[i]), bool(ev.fin[i]),
                    )
                    start_srv = max(t, self.busy_ingest)
                    svc = service.packet_ns(
                        m.pkts_accumulated > acc0,
                        bool(getattr(rt.table, "last1_frozen", False)),
                    ) * 1e-9
                    ing_s += svc
                    self.busy_ingest = start_srv + svc
                    rq.append(self.busy_ingest)
                    if recs:
                        self.charge(recs)
                    if poll_due:
                        self.charge(rt.poll(t))
                self.ring = np.asarray(rq, np.float64)
                self.stage_s["ingest"] += ing_s
            if tr is not None and tr.enabled and self.busy_ingest > busy_at_entry:
                # ingest-lane busy envelope for this block: one X span from
                # the lane's first possible service instant to its new busy
                # edge (an envelope, not per-packet slices — block cost
                # discipline; idle gaps inside a block are subsumed)
                start = max(busy_at_entry, float(tc[0]))
                tr.span("ingest.block", start, self.busy_ingest - start,
                        pid=self.pid, tid=TID_INGEST)
            pos = hi

    def finish(self, t_end: float) -> None:
        """End of stream: drain the worker at the global clock edge."""
        self.charge(self.rt.drain(t_end))


def _drive(
    rt: StreamingRuntime,
    ev: _Events,
    service: ServiceModel,
    ring_capacity: int,
    evict_every: int,
    t_end: float,
    *,
    pid: int = 0,
    tracer=None,
    slo=None,
) -> _WorkerClock:
    """Drive one worker's whole event stream: feed + drain (the static
    single-owner path; the control plane drives `_WorkerClock` directly).

    Each worker is one core with one NIC queue: its own ingest lane,
    bounded ring of `ring_capacity`, and inference lane. Under a static
    `ShardedRuntime` this runs once per shard over the steered sub-stream;
    lanes never interact across shards (DESIGN.md §8). All effects
    accumulate in `rt` and its metrics; the final drain is clocked at the
    caller's `t_end` so every shard of a fleet stops on the same global
    clock edge. Returns the clock (its stage rollup outlives the drive).
    """
    clock = _WorkerClock(rt, service, ring_capacity, evict_every,
                         pid=pid, tracer=tracer, slo=slo)
    clock.feed(ev)
    clock.finish(t_end)
    return clock


def replay(
    stream: PacketStream,
    make_runtime: Callable[[], "StreamingRuntime | ShardedRuntime"],
    offered_pps: float,
    service: ServiceModel,
    *,
    ring_capacity: int = 4096,
    evict_every: int = 512,
    control=None,
    obs=None,
    session=None,
) -> ReplayStats:
    """Replay `stream` at `offered_pps` through a fresh runtime.

    `make_runtime` may build either a single `StreamingRuntime` or a
    `ShardedRuntime`; the sharded case steers the offered load across
    workers by the symmetric 5-tuple hash and replays each shard's
    sub-stream under its own two-lane clock (per-shard ingest lane, NIC
    ring of `ring_capacity` *per queue*, and inference lane — RSS
    semantics). Shards are causally independent, so replaying them in
    sequence is exactly the concurrent execution. Aggregate drops sum
    over shards: a drop on *any* shard breaks the zero-loss property.

    The clock semantics per worker are `_drive`'s (vectorized
    admission-proven blocks with an order-exact per-packet fallback —
    DESIGN.md §6.3/§7).

    `session` (a `repro.serve.ServeSession`) carries every attachment in
    one object: the observability bundle, the control-loop config, and
    the reoptimizer policy. With a control config (and a sharded
    runtime) the replay runs under the adaptive control plane instead:
    shards are driven interleaved in global time, and telemetry-driven
    RETA rebalancing / hot-swap / elastic / re-optimization actions fire
    between blocks (DESIGN.md §9, §13). Steering is then dynamic, so
    that path delegates to `repro.serve.control.replay.controlled_replay`.

    `control` (a `repro.serve.control.ControlConfig`) and `obs` (a
    `repro.serve.obs.Observability`) are the pre-session spellings of
    the same attachments — still accepted, deprecated (they fold into a
    session via `ServeSession.coerce`).
    """
    from repro.serve.session import ServeSession

    session = ServeSession.coerce(session, control=control, obs=obs)
    if session.control is not None:
        from repro.serve.control.replay import controlled_replay

        return controlled_replay(
            stream, make_runtime, offered_pps, service,
            ring_capacity=ring_capacity, evict_every=evict_every,
            session=session,
        )
    if session.reopt is not None:
        raise TypeError(
            "a ReoptimizerPolicy needs the control plane (episodes run on "
            "control-step cadence): add a ControlConfig to the session")
    obs = session.obs
    rt = make_runtime()
    tracer = slo = None
    if obs is not None:
        obs.attach(rt)
        tracer = obs.tracer
        slo = obs.slo
        if obs.exporter is not None:
            from repro.serve.obs import fleet_registry

            obs.exporter.bind(lambda: fleet_registry(rt), slo=slo)
    # tcpreplay-style clock compression: one factor scales delivery times
    t_e = stream.base_t * (stream.base_pps / offered_pps)
    # stop the clock one flush-timeout after the last packet: flows still
    # queued would have flushed by then anyway, flows short of depth n get
    # their late (end-of-capture) classification. Sharded fleets stop on
    # the same global edge regardless of where their last packet landed.
    t_end = float(t_e[-1]) + rt.flush_timeout_s if len(t_e) else 0.0
    duration = float(t_e[-1] - t_e[0]) if stream.n_events > 1 else 1.0
    gbps = stream.total_bytes * 8.0 / max(duration, 1e-9) / 1e9

    stage_seconds = {"ingest": 0.0, "infer": 0.0, "flush": 0.0}

    def fold_stages(clock: _WorkerClock) -> dict:
        for k, v in clock.stage_s.items():
            stage_seconds[k] += v
        return dict(clock.stage_s)

    if isinstance(rt, ShardedRuntime):
        shard_of_pkt = rt.steer_stream(stream)[stream.fid]
        shard_stages: dict[int, dict] = {}
        for i, srt in enumerate(rt.shards):
            sel = np.flatnonzero(shard_of_pkt == i)
            if sel.size:
                shard_stages[i] = fold_stages(_drive(
                    srt, _gather_events(stream, t_e, sel), service,
                    ring_capacity, evict_every, t_end,
                    pid=i, tracer=tracer, slo=slo))
            else:
                srt.drain(t_end)
        agg = rt.metrics
        m = agg.merged()
        per_shard = [
            {
                "shard": i,
                "offered_pps": offered_pps * p.pkts_total / max(m.pkts_total, 1),
                "pkts_total": p.pkts_total,
                "drops_ring": p.drops_ring,
                "drops_table": p.drops_table,
                "flows_predicted": p.flows_predicted,
                "batches": p.batches,
                "occupancy_mean": p.occupancy_stats()["mean"],
                "latency_p50_s": p.latency.percentile(50),
                "latency_p99_s": p.latency.percentile(99),
                "stage_seconds": shard_stages.get(i, {}),
            }
            for i, p in enumerate(agg.parts)
        ]
        n_shards, imbalance = rt.n_shards, agg.load_imbalance()
    else:
        fold_stages(_drive(rt, _gather_events(stream, t_e), service,
                           ring_capacity, evict_every, t_end, tracer=tracer,
                           slo=slo))
        m = rt.metrics
        per_shard, n_shards, imbalance = [], 1, 1.0

    if obs is not None and obs.exporter is not None:
        # no control plane to pace it: one end-of-run export record
        obs.exporter.step(t_end)

    return ReplayStats(
        offered_pps=offered_pps,
        offered_gbps=gbps,
        duration_s=duration,
        drops=m.drops,
        drops_ring=m.drops_ring,
        drops_table=m.drops_table,
        metrics=m,
        predictions=dict(rt.results),
        latency_p50_s=m.latency.percentile(50),
        latency_p99_s=m.latency.percentile(99),
        n_shards=n_shards,
        load_imbalance=imbalance,
        per_shard=per_shard,
        stage_seconds=stage_seconds,
    )


def find_zero_loss_rate(
    stream: PacketStream,
    make_runtime: Callable[[bool], StreamingRuntime],
    service: ServiceModel,
    *,
    lo_pps: Optional[float] = None,
    hi_pps: Optional[float] = None,
    iters: int = 12,
    ring_capacity: int = 4096,
    verbose: bool = False,
    control=None,
    obs=None,
    session=None,
) -> tuple[float, ReplayStats]:
    """Bisect the highest offered rate with zero drops (Fig. 5c protocol).

    `make_runtime(execute)` builds a fresh runtime — a `StreamingRuntime`
    or a `ShardedRuntime` (the bisection is over the *aggregate* offered
    load either way, and `ReplayStats.drops` sums every shard, so one
    dropping shard fails the trial); bisection probes run with
    `execute=False` (timing only — predictions are rate-invariant), and
    the returned stats come from a final *executing* verification replay
    at the found rate. `ring_capacity` is per worker queue.

    `session` (or the deprecated `control=`) measures the *adaptive*
    fleet: every probe replays under the control plane (fresh runtime,
    fresh telemetry), so the reported rate is the zero-loss throughput
    of the closed-loop system — rebalancing transients included.

    The session's observability bundle attaches only to the final
    *executing* verification replay — the bisection probes stay untraced
    (tracing a probe would record thousands of spans for runs whose only
    output is a drop count). The reoptimizer policy likewise rides only
    the final replay: probes run `execute=False`, which produces no
    predictions to drift on.
    """
    from repro.serve.session import ServeSession

    session = ServeSession.coerce(session, control=control, obs=obs)
    # probes: control plane yes, observability/reoptimizer no
    probe_session = ServeSession(control=session.control)
    def ring_guard(events_bound: int, scope: str) -> None:
        """The ring is per worker queue: the (sub-)trace offered to a
        queue must exceed it, or that queue can absorb its whole offered
        load and the measurement never saturates."""
        if ring_capacity >= events_bound:
            raise ValueError(
                f"ring_capacity ({ring_capacity}) >= {scope} events "
                f"({events_bound}): the ring can absorb the whole trace, so "
                "no offered rate can ever drop. Shrink ring_capacity (it is "
                "the DUT's per-queue buffer, and must be small relative to "
                "the trace)."
            )

    # static pre-check (no probe needed): the whole trace upper-bounds
    # any shard's sub-trace, so this catches the single-runtime case —
    # and the grossest sharded misconfigurations — before any work
    ring_guard(stream.n_events, "stream")

    def probe(r):
        return replay(
            stream, lambda: make_runtime(False), r, service,
            ring_capacity=ring_capacity, session=probe_session,
        )

    # bracket from the stream's own base rate unless told otherwise: every
    # probe is a full-trace replay, so starting orders of magnitude below
    # the interesting region wastes real work
    lo = lo_pps if lo_pps is not None else stream.base_pps
    first = probe(lo)
    if first.n_shards > 1:
        # exact per-queue bound: the first probe's per-shard packet
        # totals are the steered sub-trace sizes (every offered packet
        # is counted, dropped or not)
        ring_guard(max(p["pkts_total"] for p in first.per_shard),
                   f"hottest of {first.n_shards} shards")
    for _ in range(24):
        if first.drops == 0:
            break
        lo /= 4.0
        first = probe(lo)
    else:
        raise RuntimeError("no zero-loss rate found: lower bound keeps dropping")
    # bracket: grow hi until it drops
    hi = hi_pps or lo * 2
    for _ in range(30):
        if probe(hi).drops > 0:
            break
        lo, hi = hi, hi * 2
    else:
        raise RuntimeError("offered load never saturated the pipeline")
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        d = probe(mid).drops
        if verbose:
            print(f"  bisect {mid:12.0f} pps -> drops={d}")
        if d == 0:
            lo = mid
        else:
            hi = mid
    final = replay(
        stream, lambda: make_runtime(True), lo, service,
        ring_capacity=ring_capacity, session=session,
    )
    return lo, final

"""Sharded multi-worker runtime: RSS-style flow steering (DESIGN.md §8).

Real deployments do not scale a traffic pipeline by making one core
faster — they replicate the per-core pipeline and let NIC receive-side
scaling (RSS) spread flows across queues. This module is that layer:

- `symmetric_tuple_hash64` (flow_table) gives the steering key: both
  directions of a connection hash identically, so a flow's entire packet
  history lands on exactly one worker;
- a 128-entry **indirection table** maps hash -> shard, exactly like the
  NIC's RETA: steering policy is a table rewrite, not a rehash;
- `ShardedRuntime` owns `n_shards` fully independent `StreamingRuntime`
  workers — per-shard `FlowTable`, dispatcher, staging arenas, and
  metrics block — behind the same block-ingest facade, with per-shard
  table sizing (`capacity` is the *aggregate* budget unless
  `capacity_per_shard` overrides it);
- `AggregateMetrics` is the operator view: summed drop/evict counters,
  per-shard occupancy, and the load-imbalance factor (max shard packet
  share over the mean — 1.0 is a perfectly balanced hash).

Sharding only permutes *which* worker serves a flow, never what it
predicts: flows are independent in extraction and inference, so the
sharded runtime is bit-identical to a single worker fed the same
packets (asserted by tests/test_shard.py).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.traffic.pipeline import ServingPipeline

from .dispatch import BatchRecord, StreamingRuntime
from .flow_table import move_slot, symmetric_tuple_hash64
from .metrics import RuntimeMetrics

__all__ = [
    "AggregateMetrics",
    "ShardedRuntime",
    "INDIRECTION_SIZE",
    "steer_flows",
    "stream_buckets",
]


# RETA size: NICs commonly expose 128 indirection entries. Steering is
# `table[sym_hash & 127]`, so rebalancing = rewriting table entries.
INDIRECTION_SIZE = 128


def steer_flows(stream, n_shards: int, indirection=None) -> np.ndarray:
    """Per-flow shard assignment for a `PacketStream` — the pure steering
    function (no runtime needed; callers sizing per-queue buffers use it
    to find the hottest shard before building anything).

    Uses the stream's recorded 5-tuple endpoints when present; streams
    predating the endpoint fields fall back to steering on the
    flow-identity hash (stable, but not direction-symmetric).
    """
    if indirection is None:
        indirection = np.arange(INDIRECTION_SIZE, dtype=np.int64) % n_shards
    return indirection[stream_buckets(stream)]


def stream_buckets(stream) -> np.ndarray:
    """Per-flow RETA bucket ids for a `PacketStream` — the steering stage
    *before* the indirection lookup. Buckets are a pure function of the
    flow's 5-tuple, so they are fixed for a flow's lifetime no matter how
    the control plane rewrites the table entries above them."""
    if getattr(stream, "s_ip", None) is not None:
        sym = symmetric_tuple_hash64(
            stream.s_ip,
            stream.d_ip,
            stream.s_port.astype(np.int64),
            stream.d_port.astype(np.int64),
            stream.proto.astype(np.int64),
        )
    else:
        sym = np.asarray(stream.key, np.uint64)
    return (sym & np.uint64(INDIRECTION_SIZE - 1)).astype(np.int64)


class AggregateMetrics:
    """Cross-shard metrics view: per-shard blocks + merged aggregate.

    The per-shard `RuntimeMetrics` stay the single source of truth (the
    hot paths keep mutating plain ints); this object derives the summed
    aggregate and the balance statistics on demand.
    """

    def __init__(self, parts: list[RuntimeMetrics], active: list[bool] | None = None):
        self.parts = parts
        self.active = active if active is not None else [True] * len(parts)

    def registry(self, per_shard: bool = True):
        """The fleet `MetricsRegistry` (DESIGN.md §11.1): every shard's
        block merged order-independently into one namespace, with
        ``shard{i}.``-prefixed per-shard columns alongside the fleet
        totals unless `per_shard=False`."""
        from repro.serve.obs.registry import MetricsRegistry

        return MetricsRegistry.merge(
            [p.to_registry() for p in self.parts],
            prefixes=[f"shard{i}." for i in range(len(self.parts))]
            if per_shard else None,
        )

    def merged(self) -> RuntimeMetrics:
        """Summed fleet block, derived through the one merge path: the
        per-shard registries fold via `MetricsRegistry.merge` and project
        back to a `RuntimeMetrics` (bit-identical to per-field sums —
        asserted by tests/test_obs.py)."""
        return RuntimeMetrics.from_registry(self.registry(per_shard=False))

    @property
    def drops(self) -> int:
        return sum(p.drops for p in self.parts)

    @property
    def drops_ring(self) -> int:
        return sum(p.drops_ring for p in self.parts)

    @property
    def drops_table(self) -> int:
        return sum(p.drops_table for p in self.parts)

    @property
    def flows_evicted_idle(self) -> int:
        return sum(p.flows_evicted_idle for p in self.parts)

    def per_shard_occupancy(self) -> list[dict]:
        return [p.occupancy_stats() for p in self.parts]

    def load_imbalance(self) -> float:
        """Max shard packet share over the mean share (>= 1.0).

        1.0 means the steering split the offered load perfectly; the
        aggregate zero-loss rate degrades by roughly this factor because
        the hottest shard saturates first. Only *active* workers count:
        a retired worker's small historical total (or a late-added
        worker's near-zero one) would drag the mean down and overstate
        the imbalance of the serving fleet.
        """
        pkts = np.array(
            [p.pkts_total for p, a in zip(self.parts, self.active) if a],
            np.float64,
        )
        if pkts.size == 0 or pkts.sum() == 0:
            return 1.0
        return float(pkts.max() / pkts.mean())

    def summary(self) -> dict:
        return {
            "n_shards": len(self.parts),
            "load_imbalance": self.load_imbalance(),
            "aggregate": self.merged().summary(),
            "per_shard": [
                {
                    "pkts_total": p.pkts_total,
                    "drops_ring": p.drops_ring,
                    "drops_table": p.drops_table,
                    "flows_seen": p.flows_seen,
                    "flows_predicted": p.flows_predicted,
                    "flows_evicted_idle": p.flows_evicted_idle,
                    "batches": p.batches,
                    "occupancy": p.occupancy_stats(),
                }
                for p in self.parts
            ],
        }


class ShardedRuntime:
    """`n_shards` independent streaming workers behind one ingest facade.

    Steering is the only coupling between shards: a packet's shard is a
    pure function of its symmetric 5-tuple hash, so per-shard state
    (flow table, ready queue, staging arenas, pending window) never
    synchronizes. The pipeline object is shared — jit executables are
    compiled once per shape bucket for the whole fleet, and per-shard
    arenas keep the zero-copy submit lifecycle private to each worker.
    """

    def __init__(
        self,
        pipeline: ServingPipeline,
        *,
        n_shards: int,
        capacity: int = 2048,
        capacity_per_shard: Optional[int] = None,
        max_batch: int = 256,
        min_bucket: int = 8,
        flush_timeout_s: float = 0.05,
        idle_timeout_s: float = 60.0,
        max_pending: int = 2,
        execute: bool = True,
        pkt_depth: Optional[int] = None,
        load_factor: float = 0.5,
        rebuild_tombstone_frac: float = 0.25,
        reuse=None,
    ):
        if n_shards < 1:
            raise ValueError(
                f"n_shards must be >= 1, got {n_shards}: a sharded runtime "
                "needs at least one worker to steer to"
            )
        if n_shards > INDIRECTION_SIZE:
            raise ValueError(
                f"n_shards ({n_shards}) exceeds the {INDIRECTION_SIZE}-entry "
                "RETA: steering is indirection-table entries -> shards, so "
                "any shard past the entry count could never receive a "
                "packet (silent dead workers). Grow INDIRECTION_SIZE or "
                "shard less."
            )
        self.n_shards = n_shards
        self.pipeline = pipeline
        # aggregate table budget split evenly unless sized explicitly
        per_shard = (
            capacity_per_shard
            if capacity_per_shard is not None
            else -(-capacity // n_shards)
        )
        if per_shard < 1:
            raise ValueError(
                f"per-shard flow-table capacity must be >= 1, got {per_shard} "
                f"(capacity={capacity}, capacity_per_shard={capacity_per_shard}, "
                f"n_shards={n_shards})"
            )
        self.capacity_per_shard = per_shard
        self.flush_timeout_s = flush_timeout_s
        # one worker's construction recipe — elastic scale-out
        # (`add_worker`) must mint bit-compatible replicas
        self._worker_kwargs = dict(
            capacity=per_shard,
            max_batch=max_batch,
            min_bucket=min_bucket,
            flush_timeout_s=flush_timeout_s,
            idle_timeout_s=idle_timeout_s,
            max_pending=max_pending,
            execute=execute,
            pkt_depth=pkt_depth,
            load_factor=load_factor,
            rebuild_tombstone_frac=rebuild_tombstone_frac,
            reuse=reuse,
        )
        self.shards = [
            StreamingRuntime(pipeline, **self._worker_kwargs)
            for _ in range(n_shards)
        ]
        # workers stay list-stable for their lifetime (records carry shard
        # ids); scale-in marks a worker inactive instead of deleting it
        self.active = [True] * n_shards
        # RSS indirection table (RETA): round-robin fill spreads the
        # hash space evenly; rebalancing rewrites entries, not the hash
        self.indirection = np.arange(INDIRECTION_SIZE, dtype=np.int64) % n_shards
        # steering ledger for migration: 5-tuple key -> RETA bucket, fed by
        # `note_steering` (the control-plane ingest path), pruned to live
        # flows on every migration. The table itself cannot recover the
        # bucket (it stores the asymmetric identity hash, and the raw
        # endpoints needed for the symmetric hash are not payload).
        self._bucket_of_key: dict[int, int] = {}
        # frozen-fast-path mask of the last facade `ingest_packets` block
        # (scattered from the per-worker masks; None when reuse is off)
        self.last_frozen_mask: Optional[np.ndarray] = None

    # -- steering ------------------------------------------------------------

    def steer_hash(self, sym_key) -> np.ndarray:
        """Symmetric hash -> shard id via the indirection table."""
        sym_key = np.asarray(sym_key, np.uint64)
        return self.indirection[sym_key & np.uint64(INDIRECTION_SIZE - 1)]

    def steer(self, s_ip, d_ip, s_port, d_port, proto) -> np.ndarray:
        """5-tuple -> shard id; invariant under direction reversal."""
        return self.steer_hash(
            symmetric_tuple_hash64(s_ip, d_ip, s_port, d_port, proto)
        )

    def steer_stream(self, stream) -> np.ndarray:
        """Per-flow shard assignment for a `PacketStream` under this
        fleet's indirection table (see module-level `steer_flows`)."""
        return steer_flows(stream, self.n_shards, self.indirection)

    def note_steering(self, key: np.ndarray, bucket: np.ndarray) -> None:
        """Record which RETA bucket each 5-tuple key steered through.

        The migration protocol needs slot -> bucket to find the flows a
        rewritten entry strands; the ingest arrays carry exactly that
        pairing, so the control path ledgers it here (one dict write per
        *new* flow per block, vectorized dedup). The ledger is pruned to
        live flows whenever it outgrows a multiple of the fleet's table
        budget — migration also prunes, but a balanced run that never
        migrates must not accumulate an entry per flow ever seen."""
        uk, first = np.unique(np.asarray(key, np.uint64), return_index=True)
        bk = np.asarray(bucket)[first]
        ledger = self._bucket_of_key
        for k, b in zip(uk.tolist(), bk.tolist()):
            ledger[k] = b
        cap = max(4096, 4 * self.capacity_per_shard * len(self.shards))
        if len(ledger) > cap:
            self._prune_ledger()

    def _prune_ledger(self) -> None:
        """Drop ledger entries for flows no longer live in any table."""
        live_keys: set[int] = set()
        for rt in self.shards:
            state = rt.table.ctrl["state"]
            live_keys.update(
                int(k) for k in rt.table.ctrl["key"][state != 0].tolist()
            )
        self._bucket_of_key = {
            k: v for k, v in self._bucket_of_key.items() if k in live_keys
        }

    # -- control plane: RETA rewrite + flow migration (DESIGN.md §9) ---------

    def add_worker(self) -> int:
        """Elastic scale-out: mint one more worker replica.

        The new worker owns no RETA entries until the planner migrates
        buckets onto it, so adding is instantaneous and invisible to the
        data path. Returns the new shard id."""
        if self.n_shards >= INDIRECTION_SIZE:
            # same bound the constructor enforces: a worker past the RETA
            # entry count could never be steered to
            raise ValueError(
                f"cannot grow past {INDIRECTION_SIZE} workers: the RETA "
                "has one entry per steering quantum, so extra workers "
                "would be silently dead"
            )
        self.shards.append(StreamingRuntime(self.pipeline, **self._worker_kwargs))
        self.active.append(True)
        self.n_shards += 1
        # late workers inherit the fleet's observability hooks (their
        # spans must carry their own shard pid)
        d0, dn = self.shards[0].dispatcher, self.shards[-1].dispatcher
        dn.tracer = d0.tracer
        dn.drift = d0.drift
        dn.trace_pid = self.n_shards - 1
        # ... including latency-component recording (DESIGN.md §14.1):
        # an empty recorder with shard 0's sketch layout, so the fleet
        # merge keeps folding identically-configured sketches
        rec0 = self.shards[0].metrics.latency_components
        if rec0 is not None:
            self.shards[-1].metrics.enable_latency_components(rec0.fresh())
        return self.n_shards - 1

    def migrate_buckets(self, moves: dict, now: float) -> dict:
        """Rewrite RETA entries and move the stranded flow state with them.

        `moves` maps bucket id -> destination shard. Per source shard the
        protocol is: (1) **quiesce** — flush its ready queue ("migrate"
        flushes through its own pipeline: every READY flow is classified
        by the worker that accumulated it, so batching geometry changes
        but predictions cannot); afterwards the table holds only ACTIVE
        and PREDICTED slots, none referenced by any queue; (2) **move** —
        each live slot whose ledgered bucket is migrating relocates via
        `move_slot` (bit-exact payload, no lifecycle double-counting);
        (3) **rewrite** — only then does the indirection entry flip, so a
        packet that would arrive "next" finds its flow already resident
        on the destination. A bucket whose destination table cannot hold
        the incoming flows is skipped entirely (entry unchanged) — a
        misrouted continuation would re-tenant the 5-tuple and classify
        the flow twice, which is the one unacceptable outcome.

        Returns a report dict: buckets moved/skipped, flows migrated, and
        the per-shard quiesce flush records (the replay clock charges
        them to the right worker's lanes).
        """
        moves = {
            int(b): int(d)
            for b, d in moves.items()
            if int(self.indirection[int(b)]) != int(d)
        }
        records: dict[int, list[BatchRecord]] = {}
        report = {
            "buckets_moved": 0,
            "buckets_skipped": 0,
            "flows_migrated": 0,
            "flows_out": {},   # shard -> slots exported (clock charging)
            "flows_in": {},    # shard -> slots imported
            "records": records,
        }
        if not moves:
            return report
        by_src: dict[int, list[int]] = {}
        for b, d in moves.items():
            by_src.setdefault(int(self.indirection[b]), []).append(b)
        # prune the steering ledger to flows still alive anywhere: dead
        # keys can never migrate (note_steering also prunes on a size cap
        # for runs that never reach this path)
        self._prune_ledger()
        for src, buckets in by_src.items():
            src_rt = self.shards[src]
            table = src_rt.table

            def live_buckets():
                live = np.nonzero(table.ctrl["state"] != 0)[0]
                slot_bucket = np.array(
                    [
                        self._bucket_of_key.get(int(k), -1)
                        for k in table.ctrl["key"][live].tolist()
                    ],
                    dtype=np.int64,
                )
                return live, slot_bucket

            live, slot_bucket = live_buckets()
            # quiesce only when needed: the flush exists to empty the ready
            # queue of slots that are about to move; if no migrating flow
            # is READY, the queue holds no stake in this migration
            moving = np.isin(slot_bucket, np.asarray(buckets, np.int64))
            if (table.ctrl["state"][live[moving]] == 2).any():
                recs = src_rt.dispatcher.flush_queue(now, "migrate")
                for rec in recs:
                    rec.shard = src
                if recs:
                    records.setdefault(src, []).extend(recs)
                # the flush recycles fully-closed READY flows
                # (`mark_predicted`), so the pre-flush snapshot may list
                # freed slots — migrating one would double-free it and
                # index key 0 on the destination; re-snapshot
                live, slot_bucket = live_buckets()
            for b in buckets:
                dst = moves[b]
                slots = live[slot_bucket == b]
                dst_table = self.shards[dst].table
                # both of move_slot's vetoes are prechecked for the whole
                # bucket, so a bucket moves atomically or not at all — a
                # half-moved bucket would strand flows on whichever side
                # the RETA entry does not point to
                if len(dst_table._free) < slots.size:
                    report["buckets_skipped"] += 1
                    continue
                if slots.size and (
                    dst_table._probe_many(
                        table.ctrl["key"][slots].astype(np.uint64)
                    ) >= 0
                ).any():
                    # identity-hash collision with a live destination flow
                    # (~2^-64): refuse the bucket rather than double-track
                    report["buckets_skipped"] += 1
                    continue
                for s in slots:
                    if move_slot(table, dst_table, int(s)) < 0:
                        # unreachable: both vetoes prechecked above
                        raise RuntimeError(
                            "bucket migration veto raced the precheck")
                report["flows_migrated"] += int(slots.size)
                if slots.size:
                    report["flows_out"][src] = (
                        report["flows_out"].get(src, 0) + int(slots.size))
                    report["flows_in"][dst] = (
                        report["flows_in"].get(dst, 0) + int(slots.size))
                self.indirection[b] = dst
                report["buckets_moved"] += 1
        return report

    def hot_swap(self, pipeline: ServingPipeline, now: float) -> dict:
        """Zero-downtime pipeline replacement across the fleet.

        Swaps shard by shard (each worker quiesces and swaps on its own —
        a real fleet staggers this so capacity never halves); the shared
        pipeline handle flips last. Returns {shard: quiesce/ready flush
        records} for the replay clock."""
        out: dict[int, list[BatchRecord]] = {}
        for i, rt in enumerate(self.shards):
            recs = rt.hot_swap(pipeline, now)
            for rec in recs:
                rec.shard = i
            if recs:
                out[i] = recs
        self.pipeline = pipeline
        return out

    # -- facade --------------------------------------------------------------

    @property
    def results(self) -> dict:
        """Merged flow_id -> prediction map. Shards partition the flow
        space, so the union is collision-free by construction."""
        out: dict = {}
        for rt in self.shards:
            out.update(rt.results)
        return out

    @property
    def metrics(self) -> AggregateMetrics:
        return AggregateMetrics([rt.metrics for rt in self.shards],
                                active=list(self.active))

    def ingest_packets(
        self,
        key,
        now,
        rel_ts,
        size,
        direction,
        ttl,
        winsize,
        flags_byte,
        proto,
        s_port,
        d_port,
        flow_id,
        fin,
        *,
        shard: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray, list[BatchRecord]]:
        """Steered block ingest: split a delivery-ordered packet block by
        `shard` (per-packet shard ids, e.g. `steer_stream(...)[fid]`) and
        drive each sub-block through its worker.

        Within a shard, delivery order is preserved (stable partition),
        which is all correctness needs — packets of one flow never cross
        shards. Returned records carry `shard` and block-global
        `flush_idx`; records are grouped by shard, not interleaved in
        global time (the shards are independent clocks).
        """
        shard = np.asarray(shard)
        now = np.asarray(now, np.float64)
        B = len(now)
        statuses = np.zeros(B, np.uint8)
        accumulated = np.zeros(B, bool)
        frozen: Optional[np.ndarray] = None
        recs: list[BatchRecord] = []
        for i, rt in enumerate(self.shards):
            idx = np.flatnonzero(shard == i)
            if not idx.size:
                continue
            st, acc, sub = rt.ingest_packets(
                np.asarray(key)[idx],
                now[idx],
                np.asarray(rel_ts)[idx],
                np.asarray(size)[idx],
                np.asarray(direction)[idx],
                np.asarray(ttl)[idx],
                np.asarray(winsize)[idx],
                np.asarray(flags_byte)[idx],
                np.asarray(proto)[idx],
                np.asarray(s_port)[idx],
                np.asarray(d_port)[idx],
                np.asarray(flow_id)[idx],
                np.asarray(fin)[idx],
            )
            statuses[idx] = st
            accumulated[idx] = acc
            if rt.last_frozen_mask is not None:
                if frozen is None:
                    frozen = np.zeros(B, bool)
                frozen[idx] = rt.last_frozen_mask
            for rec in sub:
                rec.shard = i
                if rec.flush_idx >= 0:
                    rec.flush_idx = int(idx[rec.flush_idx])
                recs.append(rec)
        self.last_frozen_mask = frozen
        return statuses, accumulated, recs

    def ingest_steered(
        self,
        key,
        now,
        rel_ts,
        size,
        direction,
        ttl,
        winsize,
        flags_byte,
        proto,
        s_port,
        d_port,
        flow_id,
        fin,
        *,
        bucket: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray, list[BatchRecord]]:
        """Block ingest steered by RETA bucket rather than final shard id.

        This is the control-plane data path: the caller supplies each
        packet's *bucket* (`sym_hash & (INDIRECTION_SIZE - 1)`), the
        current indirection table resolves the shard, and the key->bucket
        ledger is updated so a later migration can find the flows a
        rewritten entry strands. Callers that steer with a frozen table
        can keep using `ingest_packets(shard=...)`; dynamic rebalancing
        requires this entry point (or an equivalent `note_steering`
        call), since buckets are otherwise unrecoverable."""
        bucket = np.asarray(bucket, np.int64)
        self.note_steering(np.asarray(key), bucket)
        return self.ingest_packets(
            key, now, rel_ts, size, direction, ttl, winsize, flags_byte,
            proto, s_port, d_port, flow_id, fin,
            shard=self.indirection[bucket],
        )

    def poll(self, now: float) -> list[BatchRecord]:
        """Periodic maintenance on every shard (idle eviction, timeouts)."""
        recs: list[BatchRecord] = []
        for i, rt in enumerate(self.shards):
            for rec in rt.poll(now):
                rec.shard = i
                recs.append(rec)
        return recs

    def drain(self, now: float) -> list[BatchRecord]:
        """End of stream: drain every shard's table and pending window."""
        recs: list[BatchRecord] = []
        for i, rt in enumerate(self.shards):
            for rec in rt.drain(now):
                rec.shard = i
                recs.append(rec)
        return recs

"""Sharded multi-worker runtime: RSS-style flow steering (DESIGN.md §8).

Real deployments do not scale a traffic pipeline by making one core
faster — they replicate the per-core pipeline and let NIC receive-side
scaling (RSS) spread flows across queues. This module is that layer:

- `symmetric_tuple_hash64` (flow_table) gives the steering key: both
  directions of a connection hash identically, so a flow's entire packet
  history lands on exactly one worker;
- a 128-entry **indirection table** maps hash -> shard, exactly like the
  NIC's RETA: steering policy is a table rewrite, not a rehash;
- `ShardedRuntime` owns `n_shards` fully independent `StreamingRuntime`
  workers — per-shard `FlowTable`, dispatcher, staging arenas, and
  metrics block — behind the same block-ingest facade, with per-shard
  table sizing (`capacity` is the *aggregate* budget unless
  `capacity_per_shard` overrides it);
- `AggregateMetrics` is the operator view: summed drop/evict counters,
  per-shard occupancy, and the load-imbalance factor (max shard packet
  share over the mean — 1.0 is a perfectly balanced hash).

Sharding only permutes *which* worker serves a flow, never what it
predicts: flows are independent in extraction and inference, so the
sharded runtime is bit-identical to a single worker fed the same
packets (asserted by tests/test_shard.py).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.traffic.pipeline import ServingPipeline

from .dispatch import BatchRecord, StreamingRuntime
from .flow_table import symmetric_tuple_hash64
from .metrics import RuntimeMetrics

__all__ = [
    "AggregateMetrics",
    "ShardedRuntime",
    "INDIRECTION_SIZE",
    "steer_flows",
]


# RETA size: NICs commonly expose 128 indirection entries. Steering is
# `table[sym_hash & 127]`, so rebalancing = rewriting table entries.
INDIRECTION_SIZE = 128


def steer_flows(stream, n_shards: int, indirection=None) -> np.ndarray:
    """Per-flow shard assignment for a `PacketStream` — the pure steering
    function (no runtime needed; callers sizing per-queue buffers use it
    to find the hottest shard before building anything).

    Uses the stream's recorded 5-tuple endpoints when present; streams
    predating the endpoint fields fall back to steering on the
    flow-identity hash (stable, but not direction-symmetric).
    """
    if indirection is None:
        indirection = np.arange(INDIRECTION_SIZE, dtype=np.int64) % n_shards
    if getattr(stream, "s_ip", None) is not None:
        sym = symmetric_tuple_hash64(
            stream.s_ip,
            stream.d_ip,
            stream.s_port.astype(np.int64),
            stream.d_port.astype(np.int64),
            stream.proto.astype(np.int64),
        )
    else:
        sym = np.asarray(stream.key, np.uint64)
    return indirection[sym & np.uint64(INDIRECTION_SIZE - 1)]


class AggregateMetrics:
    """Cross-shard metrics view: per-shard blocks + merged aggregate.

    The per-shard `RuntimeMetrics` stay the single source of truth (the
    hot paths keep mutating plain ints); this object derives the summed
    aggregate and the balance statistics on demand.
    """

    def __init__(self, parts: list[RuntimeMetrics]):
        self.parts = parts

    def merged(self) -> RuntimeMetrics:
        return RuntimeMetrics.merged(self.parts)

    @property
    def drops(self) -> int:
        return sum(p.drops for p in self.parts)

    @property
    def drops_ring(self) -> int:
        return sum(p.drops_ring for p in self.parts)

    @property
    def drops_table(self) -> int:
        return sum(p.drops_table for p in self.parts)

    @property
    def flows_evicted_idle(self) -> int:
        return sum(p.flows_evicted_idle for p in self.parts)

    def per_shard_occupancy(self) -> list[dict]:
        return [p.occupancy_stats() for p in self.parts]

    def load_imbalance(self) -> float:
        """Max shard packet share over the mean share (>= 1.0).

        1.0 means the steering hash split the offered load perfectly;
        the aggregate zero-loss rate degrades by roughly this factor
        because the hottest shard saturates first.
        """
        pkts = np.array([p.pkts_total for p in self.parts], np.float64)
        if pkts.sum() == 0:
            return 1.0
        return float(pkts.max() / pkts.mean())

    def summary(self) -> dict:
        return {
            "n_shards": len(self.parts),
            "load_imbalance": self.load_imbalance(),
            "aggregate": self.merged().summary(),
            "per_shard": [
                {
                    "pkts_total": p.pkts_total,
                    "drops_ring": p.drops_ring,
                    "drops_table": p.drops_table,
                    "flows_seen": p.flows_seen,
                    "flows_predicted": p.flows_predicted,
                    "flows_evicted_idle": p.flows_evicted_idle,
                    "batches": p.batches,
                    "occupancy": p.occupancy_stats(),
                }
                for p in self.parts
            ],
        }


class ShardedRuntime:
    """`n_shards` independent streaming workers behind one ingest facade.

    Steering is the only coupling between shards: a packet's shard is a
    pure function of its symmetric 5-tuple hash, so per-shard state
    (flow table, ready queue, staging arenas, pending window) never
    synchronizes. The pipeline object is shared — jit executables are
    compiled once per shape bucket for the whole fleet, and per-shard
    arenas keep the zero-copy submit lifecycle private to each worker.
    """

    def __init__(
        self,
        pipeline: ServingPipeline,
        *,
        n_shards: int,
        capacity: int = 2048,
        capacity_per_shard: Optional[int] = None,
        max_batch: int = 256,
        min_bucket: int = 8,
        flush_timeout_s: float = 0.05,
        idle_timeout_s: float = 60.0,
        max_pending: int = 2,
        execute: bool = True,
        pkt_depth: Optional[int] = None,
        load_factor: float = 0.5,
        rebuild_tombstone_frac: float = 0.25,
    ):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.n_shards = n_shards
        self.pipeline = pipeline
        # aggregate table budget split evenly unless sized explicitly
        per_shard = (
            capacity_per_shard
            if capacity_per_shard is not None
            else -(-capacity // n_shards)
        )
        self.capacity_per_shard = per_shard
        self.flush_timeout_s = flush_timeout_s
        self.shards = [
            StreamingRuntime(
                pipeline,
                capacity=per_shard,
                max_batch=max_batch,
                min_bucket=min_bucket,
                flush_timeout_s=flush_timeout_s,
                idle_timeout_s=idle_timeout_s,
                max_pending=max_pending,
                execute=execute,
                pkt_depth=pkt_depth,
                load_factor=load_factor,
                rebuild_tombstone_frac=rebuild_tombstone_frac,
            )
            for _ in range(n_shards)
        ]
        # RSS indirection table (RETA): round-robin fill spreads the
        # hash space evenly; rebalancing rewrites entries, not the hash
        self.indirection = np.arange(INDIRECTION_SIZE, dtype=np.int64) % n_shards

    # -- steering ------------------------------------------------------------

    def steer_hash(self, sym_key) -> np.ndarray:
        """Symmetric hash -> shard id via the indirection table."""
        sym_key = np.asarray(sym_key, np.uint64)
        return self.indirection[sym_key & np.uint64(INDIRECTION_SIZE - 1)]

    def steer(self, s_ip, d_ip, s_port, d_port, proto) -> np.ndarray:
        """5-tuple -> shard id; invariant under direction reversal."""
        return self.steer_hash(
            symmetric_tuple_hash64(s_ip, d_ip, s_port, d_port, proto)
        )

    def steer_stream(self, stream) -> np.ndarray:
        """Per-flow shard assignment for a `PacketStream` under this
        fleet's indirection table (see module-level `steer_flows`)."""
        return steer_flows(stream, self.n_shards, self.indirection)

    # -- facade --------------------------------------------------------------

    @property
    def results(self) -> dict:
        """Merged flow_id -> prediction map. Shards partition the flow
        space, so the union is collision-free by construction."""
        out: dict = {}
        for rt in self.shards:
            out.update(rt.results)
        return out

    @property
    def metrics(self) -> AggregateMetrics:
        return AggregateMetrics([rt.metrics for rt in self.shards])

    def ingest_packets(
        self,
        key,
        now,
        rel_ts,
        size,
        direction,
        ttl,
        winsize,
        flags_byte,
        proto,
        s_port,
        d_port,
        flow_id,
        fin,
        *,
        shard: np.ndarray,
    ) -> tuple[np.ndarray, np.ndarray, list[BatchRecord]]:
        """Steered block ingest: split a delivery-ordered packet block by
        `shard` (per-packet shard ids, e.g. `steer_stream(...)[fid]`) and
        drive each sub-block through its worker.

        Within a shard, delivery order is preserved (stable partition),
        which is all correctness needs — packets of one flow never cross
        shards. Returned records carry `shard` and block-global
        `flush_idx`; records are grouped by shard, not interleaved in
        global time (the shards are independent clocks).
        """
        shard = np.asarray(shard)
        now = np.asarray(now, np.float64)
        B = len(now)
        statuses = np.zeros(B, np.uint8)
        accumulated = np.zeros(B, bool)
        recs: list[BatchRecord] = []
        for i, rt in enumerate(self.shards):
            idx = np.flatnonzero(shard == i)
            if not idx.size:
                continue
            st, acc, sub = rt.ingest_packets(
                np.asarray(key)[idx],
                now[idx],
                np.asarray(rel_ts)[idx],
                np.asarray(size)[idx],
                np.asarray(direction)[idx],
                np.asarray(ttl)[idx],
                np.asarray(winsize)[idx],
                np.asarray(flags_byte)[idx],
                np.asarray(proto)[idx],
                np.asarray(s_port)[idx],
                np.asarray(d_port)[idx],
                np.asarray(flow_id)[idx],
                np.asarray(fin)[idx],
            )
            statuses[idx] = st
            accumulated[idx] = acc
            for rec in sub:
                rec.shard = i
                if rec.flush_idx >= 0:
                    rec.flush_idx = int(idx[rec.flush_idx])
                recs.append(rec)
        return statuses, accumulated, recs

    def poll(self, now: float) -> list[BatchRecord]:
        """Periodic maintenance on every shard (idle eviction, timeouts)."""
        recs: list[BatchRecord] = []
        for i, rt in enumerate(self.shards):
            for rec in rt.poll(now):
                rec.shard = i
                recs.append(rec)
        return recs

    def drain(self, now: float) -> list[BatchRecord]:
        """End of stream: drain every shard's table and pending window."""
        recs: list[BatchRecord] = []
        for i, rt in enumerate(self.shards):
            for rec in rt.drain(now):
                rec.shard = i
                recs.append(rec)
        return recs

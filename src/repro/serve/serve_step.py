"""Serving steps: prefill (full-sequence forward) and per-token decode.

`serve_step` advances every sequence in the batch by one token (greedy or
temperature sampling) against the decode cache; `prefill` runs the
full-sequence forward (the same code path as training, minus the loss) —
prefill_32k lowers this, decode shapes lower `serve_step`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward
from repro.models.config import ModelConfig

__all__ = ["make_serve_step", "make_prefill"]


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    def serve_step(params, cache, tokens: jax.Array, rng: Optional[jax.Array] = None):
        logits, cache = decode_step(params, cache, tokens, cfg)
        if temperature > 0.0 and rng is not None:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), cache

    return serve_step


def make_prefill(cfg: ModelConfig):
    def prefill(params, batch: dict):
        return forward(params, batch, cfg)

    return prefill

"""`ServeSession`: the one attachment bundle for a serving run.

Historically each serving entry point grew its own attachment keywords —
``obs=`` on `replay`, ``control=`` + ``obs=`` on `controlled_replay` and
`find_zero_loss_rate`, ``audit=`` + ``tracer=`` on `ControlPlane`,
``audit=`` on `deploy`/`make_swap` — five divergent ways to thread the
same four objects. `ServeSession` is the single carrier: the
observability bundle, the control-loop configuration, the reoptimizer
policy, and (when it must differ from the bundle's) the audit log. Every
entry point accepts ``session=``; the legacy keywords keep working for
one release through `ServeSession.coerce`, which folds them into a
session and emits a `DeprecationWarning`.

Resolution rules (all trivially derivable, no hidden state):

- ``audit``: the explicit `audit` field when set, else the observability
  bundle's log, else a fresh `AuditLog` on demand — one run, one audit
  stream.
- ``tracer`` / ``drift`` / ``slo`` / ``exporter``: always through the
  observability bundle.
- ``control`` / ``reopt``: carried as-is; a session with a `reopt`
  policy but no control config is an error at the point of use (the
  reoptimizer runs on control-step cadence).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

__all__ = ["ServeSession"]


def _deprecated(name: str, instead: str) -> None:
    warnings.warn(
        f"the {name} keyword is deprecated; pass "
        f"session=ServeSession({instead}) instead",
        DeprecationWarning,
        stacklevel=4,
    )


@dataclasses.dataclass
class ServeSession:
    """Everything a serving run carries besides the traffic itself."""

    obs: Optional[object] = None        # repro.serve.obs.Observability
    control: Optional[object] = None    # repro.serve.control.ControlConfig
    reopt: Optional[object] = None      # ...control.ReoptimizerPolicy
    audit: Optional[object] = None      # overrides obs.audit when set

    # -- resolution ----------------------------------------------------------

    @property
    def tracer(self):
        return self.obs.tracer if self.obs is not None else None

    @property
    def drift(self):
        return self.obs.drift if self.obs is not None else None

    @property
    def slo(self):
        """The shared `SLOTracker` (DESIGN.md §14.2), via the bundle."""
        return self.obs.slo if self.obs is not None else None

    @property
    def exporter(self):
        """The bound `MetricsExporter` (DESIGN.md §14.3), via the bundle."""
        return self.obs.exporter if self.obs is not None else None

    def resolve_audit(self):
        """The run's one audit log: explicit field > obs bundle > None."""
        if self.audit is not None:
            return self.audit
        if self.obs is not None:
            return self.obs.audit
        return None

    # -- legacy-keyword shim -------------------------------------------------

    @classmethod
    def coerce(
        cls,
        session: Optional["ServeSession"] = None,
        *,
        control=None,
        obs=None,
        audit=None,
        tracer=None,
        reopt=None,
        warn: bool = True,
    ) -> "ServeSession":
        """Fold legacy per-call keywords into one session.

        Passing both ``session=`` and a legacy keyword is a conflict (the
        caller's intent is ambiguous), so it raises. Legacy keywords alone
        build an equivalent session and warn once per call site; `warn=False`
        is for internal forwarding paths that already warned."""
        legacy = {k: v for k, v in (("control", control), ("obs", obs),
                                    ("audit", audit), ("tracer", tracer),
                                    ("reopt", reopt)) if v is not None}
        if session is not None:
            if legacy:
                raise TypeError(
                    f"pass attachments through session= OR the legacy "
                    f"keywords, not both (got session and {sorted(legacy)})")
            return session
        if legacy and warn:
            _deprecated(" / ".join(f"{k}=" for k in sorted(legacy)),
                        ", ".join(f"{k}=..." for k in sorted(legacy)))
        obs_bundle = obs
        if tracer is not None:
            # a bare tracer has no bundle to live in: wrap it
            if obs_bundle is None:
                from repro.serve.obs import Observability

                obs_bundle = Observability(tracer=tracer)
            elif obs_bundle.tracer is None:
                obs_bundle.tracer = tracer
        return cls(obs=obs_bundle, control=control, reopt=reopt, audit=audit)

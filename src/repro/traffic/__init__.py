"""Traffic-analysis substrate: traces, features, extraction, models, profiler.

This package provides everything below the CATO Optimizer: the packet/flow
data layer (synthetic but statistically structured traces for the paper's two
use cases), the 67-candidate-feature registry with its shared-operation DAG,
the JAX feature-extraction engine (jit-specialized per feature representation
— the XLA analogue of the paper's cfg-macro conditional compilation), model
training, and the Profiler that measures cost(x) and perf(x).
"""
from .synth import TrafficDataset, make_dataset
from .features import FEATURES, FEATURE_NAMES, MINI_FEATURE_NAMES, OPS
from .extraction import extract_features
from .profiler import TrafficProfiler, ProfileResult
from .backends import ProfilerBackend, backend_suite
from .models import train_traffic_model, macro_f1

__all__ = [
    "TrafficDataset",
    "make_dataset",
    "FEATURES",
    "FEATURE_NAMES",
    "MINI_FEATURE_NAMES",
    "OPS",
    "extract_features",
    "TrafficProfiler",
    "ProfileResult",
    "ProfilerBackend",
    "backend_suite",
    "train_traffic_model",
    "macro_f1",
]

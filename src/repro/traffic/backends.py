"""Measurement backends: the profiler's fidelity spectrum, made pluggable.

The paper's Profiler "measures, not models" — but a measurement has a
price, and the price spans three orders of magnitude (DESIGN.md §10.1):

  modeled           analytic op-DAG drain rate (~µs per config): the
                    deterministic cost model used for ground-truth
                    enumeration and as the *cheap* fidelity;
  replayed          zero-loss throughput measured by offered-load replay
                    through a single `StreamingRuntime` worker
                    (bracket + bisection, seconds per config);
  replayed_sharded  the same measurement against an RSS-steered
                    `ShardedRuntime` under the profiler's `scenario` —
                    the serving fleet the config would actually deploy
                    to, and the *measured* fidelity the optimizer's
                    reported front comes from.

Every backend is a view over ONE `TrafficProfiler` instance, so all
fidelities share its feature-matrix cache, trained-model cache
(`perf_f1` — one seeded training per config, reused by every fidelity
and later by `serve.deploy`), service-model calibration cache (replayed
and replayed_sharded share clock constants per config), and memoized
`ProfileResult`s. `backend_suite` returns them cheap-first, which is
exactly the ordering `repro.core.MemoizedEvaluator` expects.

Each backend satisfies `repro.core.MeasurementBackend` (a ``name`` plus
``__call__(x) -> ProfileResult``); anything else with that shape can be
slotted into the suite — e.g. a live-NIC measurement harness.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from .profiler import ProfileResult, TrafficProfiler

__all__ = ["FIDELITY_METRICS", "FIDELITY_ORDER", "ProfilerBackend",
           "backend_suite"]

# fidelity name -> profiler cost metric, cheap -> expensive. All three
# negate throughput for minimization, so objectives are commensurable
# across fidelities (the multi-fidelity surrogate pools them).
FIDELITY_METRICS = {
    "modeled": "throughput",
    "replayed": "throughput_replayed",
    "replayed_sharded": "throughput_replayed_sharded",
}
FIDELITY_ORDER = tuple(FIDELITY_METRICS)


@dataclasses.dataclass
class ProfilerBackend:
    """One fidelity of the measure step, bound to a shared profiler."""

    profiler: TrafficProfiler
    name: str
    metric: str

    def __call__(self, x) -> ProfileResult:
        return self.profiler(x, metric=self.metric)

    def __repr__(self) -> str:  # keep evaluator summaries readable
        return f"ProfilerBackend({self.name!r} -> {self.metric!r})"


def backend_suite(
    profiler: TrafficProfiler,
    fidelities: Iterable[str] = ("modeled", "replayed_sharded"),
) -> dict[str, ProfilerBackend]:
    """Ordered (cheap-first) fidelity -> backend mapping over `profiler`.

    The default pairing — analytic model as the cheap fidelity, sharded
    scenario replay as the measured one — is what `CatoOptimizer
    .run_multi_fidelity` consumes via `MemoizedEvaluator`. Shard count
    and traffic scenario come from the profiler's own configuration
    (`n_shards`, `scenario`), so the measured fidelity is the serving
    fleet the caller configured, not a backend-local guess.
    """
    names = list(fidelities)
    unknown = [f for f in names if f not in FIDELITY_METRICS]
    if unknown:
        raise ValueError(
            f"unknown fidelities {unknown}; pick from {FIDELITY_ORDER}")
    order = sorted(names, key=FIDELITY_ORDER.index)
    if order != names:
        raise ValueError(
            f"fidelities must be ordered cheap -> expensive {FIDELITY_ORDER}, "
            f"got {tuple(names)}")
    return {
        f: ProfilerBackend(profiler, f, FIDELITY_METRICS[f]) for f in names
    }

"""JAX feature-extraction engine, jit-specialized per feature representation.

The paper generates a conditionally-compiled Rust binary per representation
(Fig. 4): every operation is predicated on the features that need it, so the
artifact contains exactly the required work. The XLA-native equivalent is a
``jax.jit`` function whose *static* arguments are the feature tuple and the
connection depth: only the selected columns are computed, shared
sub-expressions (direction masks, parsed fields, packet-count denominators)
are emitted once and CSE'd, and everything else is dead-code-eliminated from
the compiled executable. ``extract_features`` is the public entry point.

A feature tuple lowers first to a **static stats plan** (`stats_plan`): a
tuple of per-feature op descriptors that is hashable and order-preserving.
The plan is the unit of specialization shared by both execution paths —
`_extract` (the standalone XLA extraction stage) and the fused Pallas
pipeline kernel (`repro.kernels.fused_pipeline`) trace the *same* emitter
(`emit_feature_columns`) over it, which is what makes the fused path
bit-identical to the unfused one (DESIGN.md §7).

All statistics are masked segmented reductions over dense
``(flows, max_pkts)`` tensors — the layout the Pallas `feature_extract`
kernel mirrors for the TPU hot path.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .synth import FLAG_NAMES, TrafficDataset

__all__ = [
    "extract_features",
    "extraction_fn",
    "stats_plan",
    "emit_feature_columns",
    "emit_agg_features",
    "merge_stats_plans",
    "emit_merged_columns",
    "emit_merged_agg_features",
    "plan_is_incremental",
    "merged_plan_is_incremental",
    "agg_init",
    "AGG_WIDTH",
]

# python float, not a jnp scalar: weak-typed promotion lands on the same
# float32 value, and the fused Pallas kernel cannot capture array constants
_BIG = 3.4e38


def _masked_sum(v, m):
    return jnp.sum(jnp.where(m, v, 0.0), axis=1)


def _masked_mean(v, m):
    c = jnp.sum(m, axis=1)
    return jnp.where(c > 0, _masked_sum(v, m) / jnp.maximum(c, 1), 0.0)


def _masked_min(v, m):
    r = jnp.min(jnp.where(m, v, _BIG), axis=1)
    return jnp.where(jnp.any(m, axis=1), r, 0.0)


def _masked_max(v, m):
    r = jnp.max(jnp.where(m, v, -_BIG), axis=1)
    return jnp.where(jnp.any(m, axis=1), r, 0.0)


def _masked_std(v, m):
    # two-pass (subtract mean first): the one-pass E[x^2]-E[x]^2 form
    # catastrophically cancels in float32 for ~1e4-scale window sizes
    c = jnp.sum(m, axis=1)
    mean = _masked_sum(v, m) / jnp.maximum(c, 1)
    d = jnp.where(m, v - mean[:, None], 0.0)
    var = jnp.sum(d * d, axis=1) / jnp.maximum(c, 1)
    return jnp.where(c > 0, jnp.sqrt(var), 0.0)


def _masked_median(v, m):
    filled = jnp.where(m, v, _BIG)
    srt = jnp.sort(filled, axis=1)
    c = jnp.sum(m, axis=1)
    lo_i = jnp.maximum((c - 1) // 2, 0)
    hi_i = jnp.maximum(c // 2, 0)
    lo = jnp.take_along_axis(srt, lo_i[:, None], axis=1)[:, 0]
    hi = jnp.take_along_axis(srt, hi_i[:, None], axis=1)[:, 0]
    return jnp.where(c > 0, 0.5 * (lo + hi), 0.0)


_STATS = {
    "sum": _masked_sum,
    "mean": _masked_mean,
    "min": _masked_min,
    "max": _masked_max,
    "med": _masked_median,
    "std": _masked_std,
}

_FLAG_IDX = {n: i for i, n in enumerate(FLAG_NAMES)}


# ---------------------------------------------------------------------------
# static stats plan
# ---------------------------------------------------------------------------

def stats_plan(names: Sequence[str]) -> tuple[tuple, ...]:
    """Lower a feature tuple to a static per-feature op plan.

    Each entry is a small hashable descriptor naming the op family and its
    static parameters; `emit_feature_columns` interprets it at trace time.
    Because the plan is a pure function of the feature names, both the
    standalone `_extract` jit and the fused Pallas kernel specialize on the
    same plan and therefore emit the same op graph (jit-as-conditional-
    compilation, now inside Pallas too).
    """
    plan: list[tuple] = []
    for name in names:
        if name == "dur":
            plan.append(("dur",))
        elif name in ("proto", "s_port", "d_port"):
            plan.append(("meta", name))
        elif name in ("s_load", "d_load"):
            plan.append(("load", name[0]))
        elif name in ("s_pkt_cnt", "d_pkt_cnt"):
            plan.append(("pkt_cnt", name[0]))
        elif name in ("tcp_rtt", "syn_ack", "ack_dat"):
            plan.append(("handshake", name))
        elif name.endswith("_cnt") and name[:-4] in _FLAG_IDX:
            plan.append(("flag_cnt", _FLAG_IDX[name[:-4]]))
        else:
            d, fam, stat = name.split("_")
            if d not in ("s", "d") or fam not in ("bytes", "iat", "winsize",
                                                  "ttl") or stat not in _STATS:
                raise ValueError(f"unknown feature {name!r}")
            plan.append(("stat", d, fam, stat))
    return tuple(plan)


def emit_feature_columns(
    plan: tuple[tuple, ...],
    *,
    ts, size, direction, ttl, winsize, flags, flow_len, proto, s_port, d_port,
    depth: int,
):
    """Trace the plan's feature columns over (rows, P) packet tensors.

    The single source of op emission for both execution paths: `_extract`
    calls it on full-batch tensors, the fused pipeline kernel on per-block
    VMEM tiles. Returns a list of float32 (rows,) columns in plan order.
    """
    P = ts.shape[1]
    idx = jnp.arange(P)[None, :]
    valid = (idx < flow_len[:, None]) & (idx < depth)

    dir_mask = {
        "s": valid & (direction == 0),
        "d": valid & (direction == 1),
    }

    # directional inter-arrival times: ts_i - ts(previous pkt, same dir).
    # ts is monotone within a flow, so the previous same-direction timestamp
    # is an exclusive cumulative max over masked timestamps.
    def dir_iat(m):
        masked_ts = jnp.where(m, ts, -_BIG)
        cm = jax.lax.cummax(masked_ts, axis=1)
        prev = jnp.concatenate(
            [jnp.full((ts.shape[0], 1), -_BIG, ts.dtype), cm[:, :-1]], axis=1
        )
        has_prev = prev > -_BIG / 2
        iat = jnp.where(m & has_prev, ts - prev, 0.0)
        return iat, m & has_prev

    fields = {"bytes": size, "winsize": winsize, "ttl": ttl}
    meta = {"proto": proto, "s_port": s_port, "d_port": d_port}

    def first_ts(cond):
        any_ = jnp.any(cond, axis=1)
        i = jnp.argmax(cond, axis=1)
        return jnp.where(any_, jnp.take_along_axis(ts, i[:, None], axis=1)[:, 0], 0.0)

    cols = []
    for entry in plan:
        kind = entry[0]
        if kind == "dur":
            c = _masked_max(ts, valid) - _masked_min(ts, valid)
        elif kind == "meta":
            c = meta[entry[1]]
        elif kind == "load":
            d = entry[1]
            dur = _masked_max(ts, valid) - _masked_min(ts, valid)
            byt = _masked_sum(size, dir_mask[d])
            c = jnp.where(dur > 0, byt * 8.0 / jnp.maximum(dur, 1e-9), 0.0)
        elif kind == "pkt_cnt":
            c = jnp.sum(dir_mask[entry[1]], axis=1).astype(jnp.float32)
        elif kind == "handshake":
            syn = flags[:, :, _FLAG_IDX["syn"]] > 0
            ack = flags[:, :, _FLAG_IDX["ack"]] > 0
            t_syn = first_ts(valid & syn & ~ack)
            t_synack = first_ts(valid & syn & ack)
            t_ack = first_ts(valid & ack & ~syn)
            if entry[1] == "tcp_rtt":
                c = jnp.maximum(t_ack - t_syn, 0.0)
            elif entry[1] == "syn_ack":
                c = jnp.maximum(t_synack - t_syn, 0.0)
            else:
                c = jnp.maximum(t_ack - t_synack, 0.0)
        elif kind == "flag_cnt":
            c = jnp.sum(
                jnp.where(valid, flags[:, :, entry[1]], 0), axis=1
            ).astype(jnp.float32)
        else:  # ("stat", dir, family, stat)
            _, d, fam, stat = entry
            if fam == "iat":
                v, m = dir_iat(dir_mask[d])
            else:
                v, m = fields[fam], dir_mask[d]
            c = _STATS[stat](v, m)
        cols.append(c.astype(jnp.float32))
    return cols


# ---------------------------------------------------------------------------
# merged multi-tenant plans (DESIGN.md §15)
# ---------------------------------------------------------------------------
# PRETZEL-style white-box sharing: N tenants' stats plans union into ONE
# merged plan, extracted once per flow; each tenant reads its column subset
# through a static index map. A merged column is identified by the
# (op descriptor, connection depth) pair — two tenants at the same depth
# share every common op, while meta columns (proto/ports), which no window
# mask touches, share across all depths (stored with depth 0).


def merge_stats_plans(
    plans: Sequence[tuple[tuple, ...]], depths: Sequence[int]
) -> tuple[tuple[tuple, ...], tuple[tuple[int, ...], ...]]:
    """Union-dedup N tenants' static plans into one merged plan.

    Returns ``(merged, tenant_cols)``: ``merged`` is a hashable tuple of
    ``(entry, depth)`` pairs in first-seen order — the unit of
    specialization for the merged extraction executables, exactly like a
    solo plan — and ``tenant_cols[t][i]`` is the merged column that holds
    position ``i`` of tenant t's own plan. Both are static, so the per-
    tenant gather is a compile-time index map, not a runtime lookup.
    """
    if len(plans) != len(depths):
        raise ValueError("plans and depths must align")
    merged: list[tuple[tuple, int]] = []
    where: dict[tuple[tuple, int], int] = {}
    tenant_cols: list[tuple[int, ...]] = []
    for plan, depth in zip(plans, depths):
        cols = []
        for entry in plan:
            key = (entry, 0 if entry[0] == "meta" else int(depth))
            if key not in where:
                where[key] = len(merged)
                merged.append(key)
            cols.append(where[key])
        tenant_cols.append(tuple(cols))
    return tuple(merged), tuple(tenant_cols)


def emit_merged_columns(
    merged: tuple[tuple, ...],
    *,
    ts, size, direction, ttl, winsize, flags, flow_len, proto, s_port, d_port,
):
    """Trace a merged plan's columns over (rows, P) packet tensors.

    One `emit_feature_columns` call per distinct connection depth, with
    the packet window statically sliced to that depth first: a depth-n
    group then reduces over exactly the (rows, n) tensors a solo tenant's
    table would hold, so every merged column is bit-identical to its solo
    twin even when the shared table is wider (union depth). Returns
    float32 (rows,) columns in merged-plan order.
    """
    groups: dict[int, list[int]] = {}
    for i, (_, d) in enumerate(merged):
        groups.setdefault(int(d), []).append(i)
    out: list = [None] * len(merged)
    for d in sorted(groups):
        idxs = groups[d]
        plan = tuple(merged[i][0] for i in idxs)
        # depth-0 groups hold only meta columns; the window never matters
        dd = min(d, ts.shape[1]) if d else 1
        cols = emit_feature_columns(
            plan,
            ts=ts[:, :dd], size=size[:, :dd], direction=direction[:, :dd],
            ttl=ttl[:, :dd], winsize=winsize[:, :dd],
            flags=flags[:, :dd, :], flow_len=flow_len,
            proto=proto, s_port=s_port, d_port=d_port, depth=dd,
        )
        for i, c in zip(idxs, cols):
            out[i] = c
    return out


def emit_merged_agg_features(merged: tuple[tuple, ...], agg, *,
                             proto, s_port, d_port):
    """Aggregate twin of `emit_merged_columns` (DESIGN.md §12 + §15).

    Running statistics cover the flow's whole lifetime — connection depth
    never clips them — so a merged column's aggregate form is exactly its
    solo `emit_agg_features` column; one emitter call over the deduped
    entry tuple suffices. Returns columns in merged-plan order.
    """
    return emit_agg_features(
        tuple(e for e, _ in merged), agg,
        proto=proto, s_port=s_port, d_port=d_port)


def merged_plan_is_incremental(merged: tuple[tuple, ...]) -> bool:
    """True iff every merged column has an incremental (aggregate) form."""
    return plan_is_incremental(tuple(e for e, _ in merged))


# ---------------------------------------------------------------------------
# incremental aggregate state (DESIGN.md §12)
# ---------------------------------------------------------------------------
# Per-slot running statistics maintained by the flow table on every ingest:
# enough state to reproduce every incrementally-computable `stats_plan`
# column over the flow's WHOLE lifetime (the live view — deliberately not
# clipped to the dispatch window, which is what the classification path
# keeps using). Layout: one float64 row of AGG_WIDTH columns per slot.
#
# Per direction d in {0 (src), 1 (dst)} at base d*AGG_DIR_STRIDE:
#   CNT, then for each of bytes/winsize/ttl: SUM, MIN, MAX, M2 (sum of
#   squared deviations — Welford on the scalar path, Chan merge on the
#   block path), then the inter-arrival block (IAT_CNT, IAT_SUM, IAT_MIN,
#   IAT_MAX, IAT_M2 — the sum telescopes to LAST_TS - FIRST_TS, which is
#   what keeps it exact), then FIRST_TS/LAST_TS (LAST_TS doubles as the
#   previous same-direction timestamp for the next iat sample).
# Globals: TS_MIN/TS_MAX over all valid packets, first-match handshake
# timestamps (monotone ts => first == min, so they merge commutatively),
# and the 8 flag counters.
# Sentinels: min-style cells init to +_BIG, max-style to -_BIG; emission
# maps "never matched" back to the window emitter's 0.0-on-empty.

AGG_DIR_STRIDE = 20
AGG_CNT = 0
AGG_FAM_BASE = {"bytes": 1, "winsize": 5, "ttl": 9}   # +0 SUM +1 MIN +2 MAX +3 M2
AGG_IAT_CNT = 13
AGG_IAT_SUM = 14
AGG_IAT_MIN = 15
AGG_IAT_MAX = 16
AGG_IAT_M2 = 17
AGG_FIRST_TS = 18
AGG_LAST_TS = 19
AGG_TS_MIN = 40
AGG_TS_MAX = 41
AGG_HS_SYN = 42
AGG_HS_SYNACK = 43
AGG_HS_ACK = 44
AGG_FLAGS = 45
AGG_WIDTH = 53

_DIR_OF = {"s": 0, "d": 1}


def agg_init() -> np.ndarray:
    """Pristine per-slot aggregate row (the `_clear_slot` reset value)."""
    v = np.zeros(AGG_WIDTH, np.float64)
    for d in (0, 1):
        b = AGG_DIR_STRIDE * d
        for fb in AGG_FAM_BASE.values():
            v[b + fb + 1] = _BIG
            v[b + fb + 2] = -_BIG
        v[b + AGG_IAT_MIN] = _BIG
        v[b + AGG_IAT_MAX] = -_BIG
        v[b + AGG_FIRST_TS] = _BIG
        v[b + AGG_LAST_TS] = -_BIG
    v[AGG_TS_MIN] = _BIG
    v[AGG_TS_MAX] = -_BIG
    v[AGG_HS_SYN] = _BIG
    v[AGG_HS_SYNACK] = _BIG
    v[AGG_HS_ACK] = _BIG
    return v


AGG_INIT = agg_init()


def plan_is_incremental(plan: tuple[tuple, ...]) -> bool:
    """True iff every plan column is computable from the aggregate row.

    Medians are the one window statistic with no bounded incremental
    form — a plan containing one disables the reuse fast path entirely
    (the runtime falls back to full-window recomputation everywhere).
    """
    return all(not (e[0] == "stat" and e[3] == "med") for e in plan)


def emit_agg_features(plan: tuple[tuple, ...], agg, *, proto, s_port, d_port):
    """Trace the plan's feature columns over (rows, AGG_WIDTH) aggregates.

    The incremental twin of `emit_feature_columns`: same plan, same
    empty-mask semantics (0.0 when a direction/condition never matched),
    but reading the flow table's running statistics instead of the raw
    packet window. Works on numpy arrays (host drift checks, float64) and
    traced jax arrays (the incremental Pallas kernel and its unfused
    reference — both trace THIS emitter, which is what makes them
    bit-identical to each other). Returns float32 (rows,) columns in plan
    order. Raises on a non-incremental plan entry ("med").
    """
    xp = np if isinstance(agg, np.ndarray) else jnp

    def col(i):
        return agg[:, i]

    def dcol(d, i):
        return agg[:, AGG_DIR_STRIDE * d + i]

    cnt = {k: dcol(v, AGG_CNT) for k, v in _DIR_OF.items()}
    n_any = cnt["s"] + cnt["d"]
    dur = xp.where(n_any > 0, col(AGG_TS_MAX) - col(AGG_TS_MIN), 0.0)

    def fam_stat(d, fam, stat):
        di = _DIR_OF[d]
        if fam == "iat":
            c = dcol(di, AGG_IAT_CNT)
            cells = {"sum": AGG_IAT_SUM, "min": AGG_IAT_MIN,
                     "max": AGG_IAT_MAX}
            m2 = dcol(di, AGG_IAT_M2)
        else:
            c = cnt[d]
            fb = AGG_FAM_BASE[fam]
            cells = {"sum": fb, "min": fb + 1, "max": fb + 2}
            m2 = dcol(di, fb + 3)
        if stat == "sum":
            return dcol(di, cells["sum"])
        if stat == "mean":
            return xp.where(
                c > 0, dcol(di, cells["sum"]) / xp.maximum(c, 1.0), 0.0)
        if stat in ("min", "max"):
            return xp.where(c > 0, dcol(di, cells[stat]), 0.0)
        if stat == "std":
            var = m2 / xp.maximum(c, 1.0)
            return xp.where(c > 0, xp.sqrt(xp.maximum(var, 0.0)), 0.0)
        raise ValueError(f"stat {stat!r} has no incremental form")

    def hs(i):
        v = col(i)
        return xp.where(v < _BIG / 2, v, 0.0)

    meta = {"proto": proto, "s_port": s_port, "d_port": d_port}
    cols = []
    for entry in plan:
        kind = entry[0]
        if kind == "dur":
            c = dur
        elif kind == "meta":
            c = meta[entry[1]]
        elif kind == "load":
            byt = dcol(_DIR_OF[entry[1]], AGG_FAM_BASE["bytes"])
            c = xp.where(dur > 0, byt * 8.0 / xp.maximum(dur, 1e-9), 0.0)
        elif kind == "pkt_cnt":
            c = cnt[entry[1]]
        elif kind == "handshake":
            t_syn = hs(AGG_HS_SYN)
            t_synack = hs(AGG_HS_SYNACK)
            t_ack = hs(AGG_HS_ACK)
            if entry[1] == "tcp_rtt":
                c = xp.maximum(t_ack - t_syn, 0.0)
            elif entry[1] == "syn_ack":
                c = xp.maximum(t_synack - t_syn, 0.0)
            else:
                c = xp.maximum(t_ack - t_synack, 0.0)
        elif kind == "flag_cnt":
            c = col(AGG_FLAGS + entry[1])
        else:  # ("stat", dir, family, stat)
            _, d, fam, stat = entry
            c = fam_stat(d, fam, stat)
        cols.append(xp.asarray(c, xp.float32))
    return cols


@functools.partial(jax.jit, static_argnames=("names", "depth", "max_pkts"))
def _extract(
    ts, size, direction, ttl, winsize, flags, flow_len, proto, s_port, d_port,
    *, names: tuple[str, ...], depth: int, max_pkts: int,
):
    cols = emit_feature_columns(
        stats_plan(names),
        ts=ts, size=size, direction=direction, ttl=ttl, winsize=winsize,
        flags=flags, flow_len=flow_len, proto=proto, s_port=s_port,
        d_port=d_port, depth=depth,
    )
    return jnp.stack(cols, axis=1)


def extraction_fn(names: Sequence[str], depth: int, max_pkts: int):
    """Return the jit-specialized extraction callable for (names, depth).

    The returned function is the 'generated pipeline' — its compiled XLA
    executable contains only the ops needed for `names` at `depth`.
    """
    names = tuple(names)

    def run(ds: TrafficDataset):
        # the streaming dispatcher's staging arenas store flags as float32
        # already (DESIGN.md §7); only batch-path uint8 flags pay the convert
        flags = ds.flags if ds.flags.dtype == np.float32 \
            else ds.flags.astype(np.float32)
        return _extract(
            ds.ts, ds.size, ds.direction, ds.ttl, ds.winsize,
            flags, ds.flow_len, ds.proto, ds.s_port,
            ds.d_port, names=names, depth=int(depth), max_pkts=max_pkts,
        )

    return run


def extract_features(
    ds: TrafficDataset, names: Sequence[str], depth: int
) -> np.ndarray:
    """Extract feature matrix (n_flows, len(names)) at connection depth."""
    fn = extraction_fn(tuple(names), int(depth), ds.max_pkts)
    return np.asarray(fn(ds))

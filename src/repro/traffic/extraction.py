"""JAX feature-extraction engine, jit-specialized per feature representation.

The paper generates a conditionally-compiled Rust binary per representation
(Fig. 4): every operation is predicated on the features that need it, so the
artifact contains exactly the required work. The XLA-native equivalent is a
``jax.jit`` function whose *static* arguments are the feature tuple and the
connection depth: only the selected columns are computed, shared
sub-expressions (direction masks, parsed fields, packet-count denominators)
are emitted once and CSE'd, and everything else is dead-code-eliminated from
the compiled executable. ``extract_features`` is the public entry point.

All statistics are masked segmented reductions over dense
``(flows, max_pkts)`` tensors — the layout the Pallas `feature_extract`
kernel mirrors for the TPU hot path.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .synth import FLAG_NAMES, TrafficDataset

__all__ = ["extract_features", "extraction_fn"]

_BIG = jnp.float32(3.4e38)


def _masked_sum(v, m):
    return jnp.sum(jnp.where(m, v, 0.0), axis=1)


def _masked_mean(v, m):
    c = jnp.sum(m, axis=1)
    return jnp.where(c > 0, _masked_sum(v, m) / jnp.maximum(c, 1), 0.0)


def _masked_min(v, m):
    r = jnp.min(jnp.where(m, v, _BIG), axis=1)
    return jnp.where(jnp.any(m, axis=1), r, 0.0)


def _masked_max(v, m):
    r = jnp.max(jnp.where(m, v, -_BIG), axis=1)
    return jnp.where(jnp.any(m, axis=1), r, 0.0)


def _masked_std(v, m):
    # two-pass (subtract mean first): the one-pass E[x^2]-E[x]^2 form
    # catastrophically cancels in float32 for ~1e4-scale window sizes
    c = jnp.sum(m, axis=1)
    mean = _masked_sum(v, m) / jnp.maximum(c, 1)
    d = jnp.where(m, v - mean[:, None], 0.0)
    var = jnp.sum(d * d, axis=1) / jnp.maximum(c, 1)
    return jnp.where(c > 0, jnp.sqrt(var), 0.0)


def _masked_median(v, m):
    filled = jnp.where(m, v, _BIG)
    srt = jnp.sort(filled, axis=1)
    c = jnp.sum(m, axis=1)
    lo_i = jnp.maximum((c - 1) // 2, 0)
    hi_i = jnp.maximum(c // 2, 0)
    lo = jnp.take_along_axis(srt, lo_i[:, None], axis=1)[:, 0]
    hi = jnp.take_along_axis(srt, hi_i[:, None], axis=1)[:, 0]
    return jnp.where(c > 0, 0.5 * (lo + hi), 0.0)


_STATS = {
    "sum": _masked_sum,
    "mean": _masked_mean,
    "min": _masked_min,
    "max": _masked_max,
    "med": _masked_median,
    "std": _masked_std,
}

_FLAG_IDX = {n: i for i, n in enumerate(FLAG_NAMES)}


@functools.partial(jax.jit, static_argnames=("names", "depth", "max_pkts"))
def _extract(
    ts, size, direction, ttl, winsize, flags, flow_len, proto, s_port, d_port,
    *, names: tuple[str, ...], depth: int, max_pkts: int,
):
    P = max_pkts
    idx = jnp.arange(P)[None, :]
    valid = (idx < flow_len[:, None]) & (idx < depth)

    dir_mask = {
        "s": valid & (direction == 0),
        "d": valid & (direction == 1),
    }

    # directional inter-arrival times: ts_i - ts(previous pkt, same dir).
    # ts is monotone within a flow, so the previous same-direction timestamp
    # is an exclusive cumulative max over masked timestamps.
    def dir_iat(m):
        masked_ts = jnp.where(m, ts, -_BIG)
        cm = jax.lax.cummax(masked_ts, axis=1)
        prev = jnp.concatenate(
            [jnp.full((ts.shape[0], 1), -_BIG, ts.dtype), cm[:, :-1]], axis=1
        )
        has_prev = prev > -_BIG / 2
        iat = jnp.where(m & has_prev, ts - prev, 0.0)
        return iat, m & has_prev

    fields = {"bytes": size, "winsize": winsize, "ttl": ttl}

    def first_ts(cond):
        any_ = jnp.any(cond, axis=1)
        i = jnp.argmax(cond, axis=1)
        return jnp.where(any_, jnp.take_along_axis(ts, i[:, None], axis=1)[:, 0], 0.0)

    cols = []
    for name in names:
        if name == "dur":
            c = _masked_max(ts, valid) - _masked_min(ts, valid)
        elif name == "proto":
            c = proto
        elif name == "s_port":
            c = s_port
        elif name == "d_port":
            c = d_port
        elif name in ("s_load", "d_load"):
            d = name[0]
            dur = _masked_max(ts, valid) - _masked_min(ts, valid)
            byt = _masked_sum(size, dir_mask[d])
            c = jnp.where(dur > 0, byt * 8.0 / jnp.maximum(dur, 1e-9), 0.0)
        elif name in ("s_pkt_cnt", "d_pkt_cnt"):
            c = jnp.sum(dir_mask[name[0]], axis=1).astype(jnp.float32)
        elif name in ("tcp_rtt", "syn_ack", "ack_dat"):
            syn = flags[:, :, _FLAG_IDX["syn"]] > 0
            ack = flags[:, :, _FLAG_IDX["ack"]] > 0
            t_syn = first_ts(valid & syn & ~ack)
            t_synack = first_ts(valid & syn & ack)
            t_ack = first_ts(valid & ack & ~syn)
            if name == "tcp_rtt":
                c = jnp.maximum(t_ack - t_syn, 0.0)
            elif name == "syn_ack":
                c = jnp.maximum(t_synack - t_syn, 0.0)
            else:
                c = jnp.maximum(t_ack - t_synack, 0.0)
        elif name.endswith("_cnt") and name[:-4] in _FLAG_IDX:
            f = _FLAG_IDX[name[:-4]]
            c = jnp.sum(jnp.where(valid, flags[:, :, f], 0), axis=1).astype(jnp.float32)
        else:
            d, fam, stat = name.split("_")
            if fam == "iat":
                v, m = dir_iat(dir_mask[d])
            else:
                v, m = fields[fam], dir_mask[d]
            c = _STATS[stat](v, m)
        cols.append(c.astype(jnp.float32))
    return jnp.stack(cols, axis=1)


def extraction_fn(names: Sequence[str], depth: int, max_pkts: int):
    """Return the jit-specialized extraction callable for (names, depth).

    The returned function is the 'generated pipeline' — its compiled XLA
    executable contains only the ops needed for `names` at `depth`.
    """
    names = tuple(names)

    def run(ds: TrafficDataset):
        return _extract(
            ds.ts, ds.size, ds.direction, ds.ttl, ds.winsize,
            ds.flags.astype(np.float32), ds.flow_len, ds.proto, ds.s_port,
            ds.d_port, names=names, depth=int(depth), max_pkts=max_pkts,
        )

    return run


def extract_features(
    ds: TrafficDataset, names: Sequence[str], depth: int
) -> np.ndarray:
    """Extract feature matrix (n_flows, len(names)) at connection depth."""
    fn = extraction_fn(tuple(names), int(depth), ds.max_pkts)
    return np.asarray(fn(ds))

"""The 67-candidate-feature registry and its shared-operation DAG.

Exactly the paper's Appendix A Table 3 feature set. Every feature declares
the chain of per-packet *operations* it needs (parse Ethernet header, parse
IPv4, parse TCP, maintain an accumulator, buffer values for a median, ...).
Shared operations are the crux of the paper's conditional-compilation
argument: computing both `s_winsize_mean` and `ack_cnt` parses each packet
down to the TCP header *once*. The registry makes that DAG explicit so

  - the extraction engine emits each op once per representation
    (XLA additionally CSEs shared arithmetic — the jit analogue of the
    paper's cfg-predicated Rust binary),
  - the modeled cost accounts shared ops once (and the Fig.-8
    "naive cost" ablation deliberately does NOT),
  - zero-loss throughput can be derived from per-packet drain cost.

Unit costs are nanoseconds per packet (per-packet ops) or per flow
(extract-time ops), calibrated to the magnitude of the paper's Table 2
execution times (sub-µs..tens of µs per flow).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "Op",
    "Feature",
    "OPS",
    "FEATURES",
    "FEATURE_NAMES",
    "MINI_FEATURE_NAMES",
    "per_packet_ops",
    "modeled_extraction_cost_ns",
]


@dataclasses.dataclass(frozen=True)
class Op:
    name: str
    cost_ns: float          # per packet unless per_flow
    per_flow: bool = False
    deps: tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class Feature:
    name: str
    ops: tuple[str, ...]            # transitive deps resolved at registry build
    extract_cost_ns: float = 2.0    # per-flow cost at extract() time
    sorting: bool = False           # median features: n log n extract cost


def _mk_ops() -> dict[str, Op]:
    ops = [
        Op("capture", 2.0),
        Op("timestamp", 1.0, deps=("capture",)),
        Op("parse_eth", 1.5, deps=("capture",)),
        Op("parse_ipv4", 2.0, deps=("parse_eth",)),
        Op("parse_tcp", 2.5, deps=("parse_ipv4",)),
        Op("parse_tuple", 30.0, per_flow=True, deps=("parse_ipv4",)),
        # accumulators (per packet)
        Op("acc_pkt_cnt", 0.5, deps=("capture",)),
        Op("acc_dur", 0.5, deps=("timestamp",)),
        Op("acc_handshake", 1.5, deps=("timestamp", "parse_tcp")),
    ]
    for d in ("s", "d"):
        ops += [
            Op(f"dirsplit_{d}", 0.5, deps=("parse_ipv4",)),
            Op(f"acc_{d}_bytes_sum", 1.0, deps=(f"dirsplit_{d}",)),
            Op(f"acc_{d}_bytes_minmax", 1.5, deps=(f"dirsplit_{d}",)),
            Op(f"acc_{d}_bytes_sq", 1.5, deps=(f"dirsplit_{d}",)),
            Op(f"buf_{d}_bytes", 2.0, deps=(f"dirsplit_{d}",)),
            Op(f"acc_{d}_iat_sum", 1.0, deps=(f"dirsplit_{d}", "timestamp")),
            Op(f"acc_{d}_iat_minmax", 1.5, deps=(f"dirsplit_{d}", "timestamp")),
            Op(f"acc_{d}_iat_sq", 1.5, deps=(f"dirsplit_{d}", "timestamp")),
            Op(f"buf_{d}_iat", 2.0, deps=(f"dirsplit_{d}", "timestamp")),
            Op(f"acc_{d}_winsize_sum", 1.0, deps=(f"dirsplit_{d}", "parse_tcp")),
            Op(f"acc_{d}_winsize_minmax", 1.5, deps=(f"dirsplit_{d}", "parse_tcp")),
            Op(f"acc_{d}_winsize_sq", 1.5, deps=(f"dirsplit_{d}", "parse_tcp")),
            Op(f"buf_{d}_winsize", 2.0, deps=(f"dirsplit_{d}", "parse_tcp")),
            Op(f"acc_{d}_ttl_sum", 1.0, deps=(f"dirsplit_{d}", "parse_ipv4")),
            Op(f"acc_{d}_ttl_minmax", 1.5, deps=(f"dirsplit_{d}", "parse_ipv4")),
            Op(f"acc_{d}_ttl_sq", 1.5, deps=(f"dirsplit_{d}", "parse_ipv4")),
            Op(f"buf_{d}_ttl", 2.0, deps=(f"dirsplit_{d}", "parse_ipv4")),
        ]
    for fl in ("cwr", "ece", "urg", "ack", "psh", "rst", "syn", "fin"):
        ops.append(Op(f"acc_flag_{fl}", 1.0, deps=("parse_tcp",)))
    return {o.name: o for o in ops}


OPS: dict[str, Op] = _mk_ops()


def _closure(names: Sequence[str]) -> tuple[str, ...]:
    out: list[str] = []
    stack = list(names)
    seen = set()
    while stack:
        n = stack.pop()
        if n in seen:
            continue
        seen.add(n)
        out.append(n)
        stack.extend(OPS[n].deps)
    return tuple(sorted(out))


def _mk_features() -> dict[str, Feature]:
    feats: list[Feature] = []

    def F(name, direct_ops, extract_ns=2.0, sorting=False):
        feats.append(Feature(name, _closure(direct_ops), extract_ns, sorting))

    F("dur", ["acc_dur"])
    F("proto", ["parse_tuple"], extract_ns=1.0)
    F("s_port", ["parse_tuple"], extract_ns=1.0)
    F("d_port", ["parse_tuple"], extract_ns=1.0)
    F("s_load", ["acc_s_bytes_sum", "acc_dur"], extract_ns=5.0)
    F("d_load", ["acc_d_bytes_sum", "acc_dur"], extract_ns=5.0)
    F("s_pkt_cnt", ["dirsplit_s", "acc_pkt_cnt"])
    F("d_pkt_cnt", ["dirsplit_d", "acc_pkt_cnt"])
    F("tcp_rtt", ["acc_handshake"], extract_ns=3.0)
    F("syn_ack", ["acc_handshake"], extract_ns=3.0)
    F("ack_dat", ["acc_handshake"], extract_ns=3.0)

    for d in ("s", "d"):
        for fam, unit in (("bytes", ""), ("iat", ""), ("winsize", ""), ("ttl", "")):
            F(f"{d}_{fam}_sum", [f"acc_{d}_{fam}_sum"])
            F(f"{d}_{fam}_mean", [f"acc_{d}_{fam}_sum", "acc_pkt_cnt", f"dirsplit_{d}"], extract_ns=4.0)
            F(f"{d}_{fam}_min", [f"acc_{d}_{fam}_minmax"])
            F(f"{d}_{fam}_max", [f"acc_{d}_{fam}_minmax"])
            F(f"{d}_{fam}_med", [f"buf_{d}_{fam}"], extract_ns=10.0, sorting=True)
            F(
                f"{d}_{fam}_std",
                [f"acc_{d}_{fam}_sq", f"acc_{d}_{fam}_sum", "acc_pkt_cnt", f"dirsplit_{d}"],
                extract_ns=8.0,
            )

    for fl in ("cwr", "ece", "urg", "ack", "psh", "rst", "syn", "fin"):
        F(f"{fl}_cnt", [f"acc_flag_{fl}"])

    reg = {f.name: f for f in feats}
    assert len(reg) == 67, f"expected 67 features, got {len(reg)}"
    return reg


FEATURES: dict[str, Feature] = _mk_features()
FEATURE_NAMES: tuple[str, ...] = tuple(FEATURES.keys())

# The paper's 6-feature mini candidate set (Table 3, "In mini cand. set").
MINI_FEATURE_NAMES: tuple[str, ...] = (
    "dur", "s_load", "s_pkt_cnt", "s_bytes_sum", "s_bytes_mean", "s_iat_mean",
)


def per_packet_ops(feature_names: Sequence[str], dedup: bool = True) -> float:
    """Summed per-packet op cost (ns) for a representation.

    dedup=True counts each shared op once (the real pipeline); dedup=False
    sums each feature's chain independently (the Fig.-8 NAIVE COST ablation).
    """
    if dedup:
        ops: set[str] = set()
        for f in feature_names:
            ops.update(FEATURES[f].ops)
        return sum(OPS[o].cost_ns for o in ops if not OPS[o].per_flow)
    total = 0.0
    for f in feature_names:
        total += sum(OPS[o].cost_ns for o in FEATURES[f].ops if not OPS[o].per_flow)
    return total


def per_flow_ops_ns(feature_names: Sequence[str], dedup: bool = True) -> float:
    """Per-flow (extract-time + per-flow op) cost, excluding sort terms."""
    if dedup:
        ops: set[str] = set()
        for f in feature_names:
            ops.update(FEATURES[f].ops)
        base = sum(OPS[o].cost_ns for o in ops if OPS[o].per_flow)
    else:
        base = sum(
            sum(OPS[o].cost_ns for o in FEATURES[f].ops if OPS[o].per_flow)
            for f in feature_names
        )
    return base + sum(FEATURES[f].extract_cost_ns for f in feature_names)


def modeled_extraction_cost_ns(
    feature_names: Sequence[str],
    depth: float,
    dedup: bool = True,
) -> float:
    """Modeled per-flow extraction cost at connection depth `depth` (ns)."""
    c = per_packet_ops(feature_names, dedup) * depth
    c += per_flow_ops_ns(feature_names, dedup)
    n_sort = sum(1 for f in feature_names if FEATURES[f].sorting)
    if n_sort and depth > 1:
        c += n_sort * 0.8 * depth * np.log2(max(depth, 2.0))
    return float(c)

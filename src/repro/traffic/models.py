"""Model training for traffic-analysis pipelines (paper §4, Model Training).

The paper trains scikit-learn models with 5-fold nested CV + grid search and
then re-trains a matching Rust (SmartCore) model for serving. Neither library
exists in this environment, so `repro.core.forest` (our histogram trainer)
plays both roles: the trained `DenseForest` *is* the serving artifact — its
dense level-order layout is what the Pallas `tree_infer` kernel executes.

Hyperparameter search is a validation-split grid over tree depth (the paper
greps depths 3–20; we use a compressed grid for tractability — recorded in
EXPERIMENTS.md §Adaptations).
"""
from __future__ import annotations

import numpy as np

from repro.core.forest import DenseForest, train_forest

__all__ = ["macro_f1", "train_traffic_model", "MODEL_GRIDS"]


def macro_f1(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Macro-averaged F1 over the classes present in y_true."""
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    classes = np.unique(y_true)
    f1s = []
    for c in classes:
        tp = float(np.sum((y_pred == c) & (y_true == c)))
        fp = float(np.sum((y_pred == c) & (y_true != c)))
        fn = float(np.sum((y_pred != c) & (y_true == c)))
        prec = tp / (tp + fp) if tp + fp > 0 else 0.0
        rec = tp / (tp + fn) if tp + fn > 0 else 0.0
        f1s.append(2 * prec * rec / (prec + rec) if prec + rec > 0 else 0.0)
    return float(np.mean(f1s))


# (n_trees, depth grid, max_features) per model type
MODEL_GRIDS = {
    # random forest — iot-class (paper: 100 trees, depth 3..20)
    "rf": dict(n_trees=25, depths=(6, 10), max_features="sqrt"),
    # single decision tree — app-class
    "tree": dict(n_trees=1, depths=(6, 10), max_features=None),
    # fast variants for ground-truth exhaustive enumeration
    "rf-fast": dict(n_trees=12, depths=(8,), max_features="sqrt"),
    "tree-fast": dict(n_trees=1, depths=(8,), max_features=None),
}


def train_traffic_model(
    X_train: np.ndarray,
    y_train: np.ndarray,
    *,
    model: str = "rf",
    val_frac: float = 0.25,
    seed: int = 0,
) -> tuple[DenseForest, float]:
    """Train with a depth grid selected on an internal validation split.

    Returns (best forest retrained on all of X_train, validation F1).
    """
    grid = dict(MODEL_GRIDS[model])
    # feature subsampling only helps with enough columns to subsample
    if X_train.shape[1] <= 8:
        grid["max_features"] = None
    rng = np.random.default_rng(seed)
    n = X_train.shape[0]
    perm = rng.permutation(n)
    n_val = max(1, int(n * val_frac))
    vi, ti = perm[:n_val], perm[n_val:]

    best_depth, best_f1 = grid["depths"][0], -1.0
    if len(grid["depths"]) > 1:
        for depth in grid["depths"]:
            f = train_forest(
                X_train[ti], y_train[ti],
                n_trees=grid["n_trees"], max_depth=depth,
                max_features=grid["max_features"], classification=True,
                rng=np.random.default_rng(seed),
            )
            from repro.core.forest import forest_predict_class

            f1 = macro_f1(y_train[vi], forest_predict_class(f, X_train[vi]))
            if f1 > best_f1:
                best_depth, best_f1 = depth, f1

    final = train_forest(
        X_train, y_train,
        n_trees=grid["n_trees"], max_depth=best_depth,
        max_features=grid["max_features"], classification=True,
        rng=np.random.default_rng(seed + 1),
    )
    return final, best_f1

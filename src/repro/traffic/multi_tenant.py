"""Multi-tenant white-box serving: N models, one fleet (DESIGN.md §15).

A real vantage point runs many analyses over the same packets — app class,
QoS, anomaly, per-customer models. Served black-box, that is N fleets with
N flow tables and N redundant extraction passes. PRETZEL's white-box
argument breaks the model boundary instead: tenants share operators and
state. Here the sharing is structural:

- **Merged extraction plan** (`merge_stats_plans`): the union of every
  tenant's `stats_plan`, deduped on (op, depth), extracted ONCE per flow
  over one `FlowTable` at the union connection depth; each tenant reads
  its column subset through a static index map.
- **One inference pass**: fused mode launches the single multi-forest
  Pallas kernel (`fused_multi_forest_infer` — tenant-stacked forests over
  the shared in-VMEM feature tile); unfused mode gathers each tenant's
  columns from the merged matrix and runs the solo forest kernel per
  tenant. Both are bit-identical, tenant by tenant, to running each
  pipeline alone.
- **Co-optimization**: `MultiTenantRep`/`MultiTenantSpace`/
  `MultiTenantProfiler` expose the joint configuration space to
  `CatoOptimizer` with the union-plan cost (shared ops counted once) —
  the overlap discount that reshapes which configurations are
  Pareto-optimal (CATO's thesis applied to the sharing itself).

`MultiTenantPipeline` is duck-compatible with `ServingPipeline` (its
`rep` is a genuine union `FeatureRep`), so flow tables, dispatch, reuse
gating, hot-swap, sharding, and replay serve it unchanged; `finalize`
returns an ``(n, T)`` per-tenant class matrix and `results[fid]` holds a
length-T vector.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import DenseForest
from repro.core.search_space import FeatureRep, SearchSpace

from .extraction import (
    emit_merged_agg_features,
    emit_merged_columns,
    merge_stats_plans,
    merged_plan_is_incremental,
    stats_plan,
)
from .features import modeled_extraction_cost_ns
from .profiler import ProfileResult, TrafficProfiler
from .synth import TrafficDataset

__all__ = [
    "MultiTenantPipeline",
    "MultiTenantProfiler",
    "MultiTenantRep",
    "MultiTenantSpace",
    "build_multi_tenant_pipeline",
    "union_rep",
]


def union_rep(reps: Sequence[FeatureRep]) -> FeatureRep:
    """The shared-state representation: union features at max depth.

    This is what the fleet's `FlowTable` is sized by — one table holds
    every packet column any tenant needs, to the deepest prefix any
    tenant reads. A genuine `FeatureRep`, so every `pipeline.rep`
    consumer (table sizing, reuse gating, anchors, hot-swap) works
    unchanged."""
    feats: set[str] = set()
    for r in reps:
        feats.update(r.features)
    return FeatureRep(tuple(sorted(feats)), max(int(r.depth) for r in reps))


@functools.partial(jax.jit, static_argnames=("merged",))
def _merged_extract(
    ts, size, direction, ttl, winsize, flags, flow_len, proto, s_port, d_port,
    *, merged,
):
    cols = emit_merged_columns(
        merged,
        ts=ts, size=size, direction=direction, ttl=ttl, winsize=winsize,
        flags=flags, flow_len=flow_len, proto=proto, s_port=s_port,
        d_port=d_port,
    )
    return jnp.stack(cols, axis=1)


@functools.partial(jax.jit, static_argnames=("merged",))
def _merged_agg_extract(agg, proto, s_port, d_port, *, merged):
    cols = emit_merged_agg_features(
        merged, agg, proto=proto, s_port=s_port, d_port=d_port)
    return jnp.stack(cols, axis=1)


@dataclasses.dataclass
class MultiTenantPipeline:
    """N tenants' pipelines fused behind one `ServingPipeline` interface.

    `predict_async` returns stacked per-tenant probability lanes
    ``(n, sum K_t)``; `finalize` maps them to an ``(n, T)`` class matrix
    (column t bit-identical to tenant t's solo `finalize`). `lanes[t]`
    is tenant t's ``(lo, hi)`` probability slice — the observability
    layer uses it for per-tenant attribution."""

    rep: FeatureRep                         # union features @ max depth
    tenant_reps: tuple[FeatureRep, ...]
    forests: tuple[DenseForest, ...]
    merged: tuple                           # merged plan: ((entry, depth), ...)
    tenant_cols: tuple[tuple[int, ...], ...]
    lanes: tuple[tuple[int, int], ...]      # per-tenant prob column spans
    _fn: Callable
    fused: bool = False
    _agg_fn: Optional[Callable] = None

    @property
    def n_tenants(self) -> int:
        return len(self.tenant_reps)

    @property
    def drift_prob_slice(self) -> slice:
        """Tenant 0's probability lane: the slice the drift monitor's
        confidence signal is computed over (per-tenant class id spaces
        must not mix in one histogram — DESIGN.md §15.4)."""
        lo, hi = self.lanes[0]
        return slice(lo, hi)

    def __call__(self, ds: TrafficDataset) -> np.ndarray:
        return self.finalize(self.predict_async(ds))

    @property
    def supports_agg(self) -> bool:
        return self._agg_fn is not None

    def predict_agg(self, agg, proto, s_port, d_port) -> jax.Array:
        if self._agg_fn is None:
            raise ValueError(
                "pipeline has no incremental entry (plan not incremental)")
        return self._agg_fn(agg, proto, s_port, d_port)

    def predict_async(self, ds: TrafficDataset) -> jax.Array:
        return self._fn(ds)

    def probabilities(self, ds: TrafficDataset) -> np.ndarray:
        return np.asarray(self._fn(ds))

    def finalize(self, probs) -> np.ndarray:
        """Block on a `predict_async` result; (n, T) class matrix.

        Per tenant: argmax over its own lane slice, mapped through its
        own class table — the exact solo `finalize`, so column t of the
        result is bitwise the solo prediction vector."""
        p = np.asarray(probs)
        cols = []
        for (lo, hi), f in zip(self.lanes, self.forests):
            idx = np.argmax(p[:, lo:hi], axis=1)
            cols.append(f.classes[idx] if f.classes is not None else idx)
        return np.stack(cols, axis=1)

    def warm(self, buckets: "list[int]") -> None:
        """Pre-compile every dispatch bucket geometry (DESIGN.md §9.3) —
        same zero-batch protocol as `ServingPipeline.warm`, at the union
        connection depth the shared table stages."""
        P = int(self.rep.depth)
        for b in buckets:
            ds = TrafficDataset(
                ts=np.zeros((b, P), np.float32),
                size=np.zeros((b, P), np.float32),
                direction=np.zeros((b, P), np.uint8),
                ttl=np.zeros((b, P), np.float32),
                winsize=np.zeros((b, P), np.float32),
                flags=np.zeros((b, P, 8), np.float32),
                flow_len=np.zeros(b, np.int32),
                proto=np.zeros(b, np.float32),
                s_port=np.zeros(b, np.float32),
                d_port=np.zeros(b, np.float32),
                label=np.zeros(b, np.int32),
                name="warm",
            )
            self.finalize(self.predict_async(ds))


def build_multi_tenant_pipeline(
    reps: Sequence[FeatureRep],
    forests: Sequence[DenseForest],
    *,
    use_kernel: bool = True,
    fused: bool = False,
) -> MultiTenantPipeline:
    """Compile N tenants' (rep, forest) pairs into one shared pipeline.

    ``fused=True`` launches the single multi-forest Pallas kernel (one
    launch: merged columns in VMEM, tenant-stacked traversal); unfused
    gathers per-tenant column subsets from the merged feature matrix and
    runs the solo forest kernel (`use_kernel=True`) or the jnp reference
    per tenant. The incremental (aggregate) entry always takes the
    unfused route — refresh batches are low-rate (DESIGN.md §12)."""
    reps = tuple(reps)
    forests = tuple(forests)
    if len(reps) != len(forests) or not reps:
        raise ValueError("need one forest per tenant rep (and >= 1 tenant)")
    plans = [stats_plan(r.features) for r in reps]
    merged, tenant_cols = merge_stats_plans(plans, [r.depth for r in reps])
    urep = union_rep(reps)
    lanes, k0 = [], 0
    for f in forests:
        k = int(f.leaf.shape[2])
        lanes.append((k0, k0 + k))
        k0 += k

    incremental = merged_plan_is_incremental(merged)
    consts = [(jnp.asarray(f.feature), jnp.asarray(f.threshold),
               jnp.asarray(f.leaf), int(f.depth)) for f in forests]
    col_idx = [np.asarray(c, np.int32) for c in tenant_cols]

    def infer_tenants(X):
        outs = []
        for idx, (ft, tt, lt, fd) in zip(col_idx, consts):
            x = X[:, idx]
            if use_kernel:
                from repro.kernels import ops

                outs.append(ops.forest_infer(x, ft, tt, lt, fd))
            else:
                from repro.kernels import ref

                outs.append(ref.forest_infer_ref(x, ft, tt, lt, fd))
        return jnp.concatenate(outs, axis=1)

    if fused:
        from repro.kernels.fused_pipeline import (
            fused_multi_forest_infer,
            stack_multi_forests,
        )

        feat_all, thr_all, leaf_all, tenants_spec = stack_multi_forests(
            forests, tenant_cols)

        def run(ds: TrafficDataset):
            with warnings.catch_warnings():
                # donation cannot engage on the CPU backend — same scoped
                # suppression as the solo fused path
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                return fused_multi_forest_infer(
                    ds.ts, ds.size, ds.direction, ds.ttl, ds.winsize,
                    ds.flags, ds.flow_len, ds.proto, ds.s_port, ds.d_port,
                    feat_all, thr_all, leaf_all,
                    merged=merged, tenants=tenants_spec,
                )
    else:
        def run(ds: TrafficDataset):
            flags = ds.flags if ds.flags.dtype == np.float32 \
                else ds.flags.astype(np.float32)
            X = _merged_extract(
                ds.ts, ds.size, ds.direction, ds.ttl, ds.winsize, flags,
                ds.flow_len, ds.proto, ds.s_port, ds.d_port, merged=merged)
            return infer_tenants(X)

    run_agg = None
    if incremental:
        def run_agg(agg, proto, s_port, d_port):
            X = _merged_agg_extract(
                jnp.asarray(agg), jnp.asarray(proto), jnp.asarray(s_port),
                jnp.asarray(d_port), merged=merged)
            return infer_tenants(X)

    return MultiTenantPipeline(
        rep=urep, tenant_reps=reps, forests=forests, merged=merged,
        tenant_cols=tenant_cols, lanes=tuple(lanes), _fn=run, fused=fused,
        _agg_fn=run_agg,
    )


# ---------------------------------------------------------------------------
# joint configuration space (DESIGN.md §15.5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MultiTenantRep:
    """Joint config point: one `FeatureRep` per tenant.

    `features`/`depth` present the union view (what the shared table
    costs are a function of), `key()` the per-tenant identity the
    memoized evaluator caches on."""

    reps: tuple[FeatureRep, ...]

    def __post_init__(self):
        object.__setattr__(self, "reps", tuple(self.reps))

    def key(self) -> tuple:
        return tuple(r.key() for r in self.reps)

    @property
    def features(self) -> tuple[str, ...]:
        return union_rep(self.reps).features

    @property
    def depth(self) -> int:
        return max(int(r.depth) for r in self.reps)


@dataclasses.dataclass
class MultiTenantSpace:
    """Product of per-tenant search spaces, optimizer-protocol compatible
    (encode / sample_uniform / mutate — `CatoOptimizer` needs nothing
    else). Encoding is the concatenation of per-tenant encodings, so the
    surrogate sees the joint space; mutation perturbs one tenant at a
    time (the neighborhood a shared-fleet operator actually explores)."""

    spaces: tuple[SearchSpace, ...]

    def __post_init__(self):
        self.spaces = tuple(self.spaces)

    @property
    def dim(self) -> int:
        return sum(s.dim for s in self.spaces)

    @property
    def size(self) -> float:
        out = 1.0
        for s in self.spaces:
            out *= s.size
        return out

    def encode(self, x: MultiTenantRep) -> np.ndarray:
        return np.concatenate(
            [s.encode(r) for s, r in zip(self.spaces, x.reps)])

    def encode_batch(self, xs: Sequence[MultiTenantRep]) -> np.ndarray:
        return np.stack([self.encode(x) for x in xs])

    def decode(self, v: np.ndarray) -> MultiTenantRep:
        reps, off = [], 0
        for s in self.spaces:
            reps.append(s.decode(v[off:off + s.dim]))
            off += s.dim
        return MultiTenantRep(tuple(reps))

    def sample_uniform(
        self, rng: np.random.Generator, n: int
    ) -> list[MultiTenantRep]:
        per = [s.sample_uniform(rng, n) for s in self.spaces]
        return [MultiTenantRep(tuple(p[i] for p in per)) for i in range(n)]

    def mutate(self, rng: np.random.Generator,
               x: MultiTenantRep) -> MultiTenantRep:
        t = int(rng.integers(len(self.spaces)))
        reps = list(x.reps)
        reps[t] = self.spaces[t].mutate(rng, reps[t])
        return MultiTenantRep(tuple(reps))


class MultiTenantProfiler:
    """Joint profiler: perf is the mean per-tenant hold-out macro-F1,
    cost is the modeled shared-fleet cost — ONE union-plan extraction
    pass (shared ops deduped across tenants, the overlap discount) plus
    every tenant's inference. ``shared=False`` is the ablation arm: the
    same tenants billed as independent fleets (sum of solo costs). Both
    arms share the per-tenant profilers' trained-model caches, so a
    joint-vs-independent comparison trains each distinct (tenant, rep)
    at most once.

    Duck-compatible with `TrafficProfiler` as an evaluator: callable
    ``(x, metric) -> ProfileResult`` over `MultiTenantRep` points, so
    `MemoizedEvaluator`/`CatoOptimizer` drive it unchanged.
    """

    def __init__(self, profilers: Sequence[TrafficProfiler], *,
                 shared: bool = True):
        if not profilers:
            raise ValueError("need >= 1 tenant profiler")
        self.profilers = tuple(profilers)
        self.shared = shared
        self.n_profile_calls = 0

    def _depth_eff(self, depth: int) -> float:
        ds = self.profilers[0].test_ds
        return float(np.minimum(ds.flow_len, depth).mean())

    def __call__(self, x: MultiTenantRep,
                 metric: Optional[str] = None) -> ProfileResult:
        self.n_profile_calls += 1
        f1s, infer_ns, indep_ns = [], [], 0.0
        for p, r in zip(self.profilers, x.reps):
            f1, forest = p.perf_f1(r)
            f1s.append(float(f1))
            inf = p._inference_ns(forest)
            infer_ns.append(inf)
            indep_ns += modeled_extraction_cost_ns(
                r.features, self._depth_eff(r.depth)) + inf
        # union-plan extraction: one pass over the shared table, every
        # shared op across tenants counted once, at the union depth
        shared_ns = modeled_extraction_cost_ns(
            x.features, self._depth_eff(x.depth)) + sum(infer_ns)
        cost_ns = shared_ns if self.shared else indep_ns
        return ProfileResult(
            cost=cost_ns / 1e3,
            perf=float(np.mean(f1s)),
            aux={
                "per_tenant_f1": f1s,
                "cost_shared_us": shared_ns / 1e3,
                "cost_independent_us": indep_ns / 1e3,
                "overlap_discount": 1.0 - shared_ns / max(indep_ns, 1e-9),
                "tenant_infer_ns": infer_ns,
            },
        )

"""The generated end-to-end serving pipeline (paper §3.4, Pipeline Generation).

`build_pipeline` takes a Pareto-optimal feature representation selected by
the Optimizer plus its trained model and returns a single compiled callable

    packets (dense flow tensors) -> class predictions

containing exactly the extraction ops for (F, n) (jit specialization ==
conditional compilation, DESIGN.md §3) fused with the dense-forest inference
stage. Two fusion levels exist:

- ``fused=False`` (two launches): the jit-specialized XLA extraction
  executable materializes the ``(N, F)`` feature matrix, then the
  `tree_infer` Pallas kernel (``use_kernel=True``) or the jnp reference
  consumes it.
- ``fused=True`` (one launch): the `fused_pipeline` Pallas kernel computes
  the feature columns from the static stats plan *inside* the flow tile and
  runs the forest traversal on the in-register features — no HBM
  materialization, donated input buffers (DESIGN.md §7). Bit-identical to
  the unfused path: both trace the same column emitter and the same
  traversal/vote order.

This is the deployable artifact — `examples/deploy_pipeline.py` drives it.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import DenseForest
from repro.core.search_space import FeatureRep
from repro.kernels import ops

from .extraction import emit_agg_features, extraction_fn, stats_plan
from .synth import TrafficDataset

__all__ = ["ServingPipeline", "build_pipeline"]


@functools.partial(jax.jit, static_argnames=("plan",))
def _agg_extract(agg, proto, s_port, d_port, *, plan):
    """Feature matrix from incremental aggregate rows (DESIGN.md §12):
    the same static-plan column emitter the window path traces, evaluated
    over per-flow running statistics instead of the raw packet window."""
    cols = emit_agg_features(plan, agg, proto=proto, s_port=s_port,
                             d_port=d_port)
    return jnp.stack(cols, axis=1)


@dataclasses.dataclass
class ServingPipeline:
    rep: FeatureRep
    forest: DenseForest
    _fn: Callable
    fused: bool = False
    _agg_fn: Optional[Callable] = None

    def __call__(self, ds: TrafficDataset) -> np.ndarray:
        """Predicted class ids for every flow in the batch."""
        return self.finalize(self.predict_async(ds))

    @property
    def supports_agg(self) -> bool:
        """True when this pipeline has an incremental (aggregate-block)
        inference entry — i.e. every feature in the plan is maintainable
        as a running statistic (no median-style order stats)."""
        return self._agg_fn is not None

    def predict_agg(self, agg, proto, s_port, d_port) -> jax.Array:
        """Infer from per-flow incremental aggregate rows (n, AGG_WIDTH)
        instead of the raw packet window; resolves via `finalize` like any
        other submission. Bit-identical column semantics to the window
        path for whole-flow windows (both trace the shared stats plan)."""
        if self._agg_fn is None:
            raise ValueError(
                "pipeline has no incremental entry (plan not incremental)")
        return self._agg_fn(agg, proto, s_port, d_port)

    def predict_async(self, ds: TrafficDataset) -> jax.Array:
        """Submit the batch and return the (unresolved) device array.

        JAX dispatch is asynchronous: the caller can keep accumulating the
        next micro-batch while this one runs, and only block in `finalize`.
        The streaming runtime's double-buffered dispatch relies on this.

        Buffer lifetime: the XLA CPU client may alias host numpy buffers
        zero-copy instead of copying at submit, so the caller must NOT
        overwrite `ds`'s arrays until this batch has been finalized — the
        dispatcher guarantees it by rotating `max_pending + 1` staging
        arenas per bucket (DESIGN.md §7.3).
        """
        return self._fn(ds)

    def finalize(self, probs: jax.Array) -> np.ndarray:
        """Block on a `predict_async` result and map to class labels."""
        idx = np.asarray(jnp.argmax(probs, axis=1))
        if self.forest.classes is not None:
            return self.forest.classes[idx]
        return idx

    def probabilities(self, ds: TrafficDataset) -> np.ndarray:
        return np.asarray(self._fn(ds))

    def warm(self, buckets: "list[int]") -> None:
        """Pre-compile this pipeline's executables for the given dispatch
        shape buckets (swap-safe handle, DESIGN.md §9.3).

        A pipeline hot-swap must never pay an XLA compile on the serving
        path: the control plane compiles the replacement in the
        background by warming every batch geometry the dispatcher can
        submit (`min_bucket..max_batch` powers of two). Each call runs a
        zero-filled batch through the real jit entry, so the executable
        cache — keyed on (feature plan, depth, batch shape), disjoint
        per configuration — holds every shape before the swap flips the
        handle. Safe to run while the old pipeline serves: caches are
        keyed by static config, so coexisting pipelines never evict or
        alias each other, and the dummy buffers are donated like any
        other batch."""
        P = int(self.rep.depth)
        for b in buckets:
            ds = TrafficDataset(
                ts=np.zeros((b, P), np.float32),
                size=np.zeros((b, P), np.float32),
                direction=np.zeros((b, P), np.uint8),
                ttl=np.zeros((b, P), np.float32),
                winsize=np.zeros((b, P), np.float32),
                flags=np.zeros((b, P, 8), np.float32),
                flow_len=np.zeros(b, np.int32),
                proto=np.zeros(b, np.float32),
                s_port=np.zeros(b, np.float32),
                d_port=np.zeros(b, np.float32),
                label=np.zeros(b, np.int32),
                name="warm",
            )
            self.finalize(self.predict_async(ds))


def build_pipeline(
    rep: FeatureRep,
    forest: DenseForest,
    max_pkts: int,
    *,
    use_kernel: bool = True,
    fused: bool = False,
) -> ServingPipeline:
    feat_t = jnp.asarray(forest.feature)
    thr_t = jnp.asarray(forest.threshold)
    leaf_t = jnp.asarray(forest.leaf)
    depth = forest.depth

    from .extraction import plan_is_incremental

    plan = stats_plan(rep.features)
    incremental = plan_is_incremental(plan)

    if fused:
        from repro.kernels.fused_pipeline import (
            fused_agg_infer,
            fused_forest_infer,
        )

        conn_depth = int(rep.depth)

        def run(ds: TrafficDataset):
            with warnings.catch_warnings():
                # donation cannot engage on the CPU backend (no aliasable
                # output buffer) and XLA warns once per compile — expected;
                # scoped here so other code's donation warnings survive
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                return fused_forest_infer(
                    ds.ts, ds.size, ds.direction, ds.ttl, ds.winsize,
                    ds.flags, ds.flow_len, ds.proto, ds.s_port, ds.d_port,
                    feat_t, thr_t, leaf_t,
                    plan=plan, depth=conn_depth, forest_depth=depth,
                )

        run_agg = None
        if incremental:
            def run_agg(agg, proto, s_port, d_port):
                return fused_agg_infer(
                    jnp.asarray(agg), jnp.asarray(proto),
                    jnp.asarray(s_port), jnp.asarray(d_port),
                    feat_t, thr_t, leaf_t,
                    plan=plan, forest_depth=depth,
                )

        return ServingPipeline(rep, forest, run, fused=True, _agg_fn=run_agg)

    extract = extraction_fn(rep.features, rep.depth, max_pkts)

    def run(ds: TrafficDataset):
        x = extract(ds)
        if use_kernel:
            return ops.forest_infer(x, feat_t, thr_t, leaf_t, depth)
        from repro.kernels import ref

        return ref.forest_infer_ref(x, feat_t, thr_t, leaf_t, depth)

    run_agg = None
    if incremental:
        def run_agg(agg, proto, s_port, d_port):
            x = _agg_extract(
                jnp.asarray(agg), jnp.asarray(proto), jnp.asarray(s_port),
                jnp.asarray(d_port), plan=plan)
            if use_kernel:
                return ops.forest_infer(x, feat_t, thr_t, leaf_t, depth)
            from repro.kernels import ref

            return ref.forest_infer_ref(x, feat_t, thr_t, leaf_t, depth)

    return ServingPipeline(rep, forest, run, _agg_fn=run_agg)

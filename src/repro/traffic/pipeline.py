"""The generated end-to-end serving pipeline (paper §3.4, Pipeline Generation).

`build_pipeline` takes a Pareto-optimal feature representation selected by
the Optimizer plus its trained model and returns a single compiled callable

    packets (dense flow tensors) -> class predictions

containing exactly the extraction ops for (F, n) (jit specialization ==
conditional compilation, DESIGN.md §3) fused with the dense-forest inference
stage (the `tree_infer` Pallas kernel on TPU; interpret mode here). This is
the deployable artifact — `examples/deploy_pipeline.py` drives it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import DenseForest
from repro.core.search_space import FeatureRep
from repro.kernels import ops

from .extraction import extraction_fn
from .synth import TrafficDataset

__all__ = ["ServingPipeline", "build_pipeline"]


@dataclasses.dataclass
class ServingPipeline:
    rep: FeatureRep
    forest: DenseForest
    _fn: Callable

    def __call__(self, ds: TrafficDataset) -> np.ndarray:
        """Predicted class ids for every flow in the batch."""
        return self.finalize(self.predict_async(ds))

    def predict_async(self, ds: TrafficDataset) -> jax.Array:
        """Submit the batch and return the (unresolved) device array.

        JAX dispatch is asynchronous: the caller can keep accumulating the
        next micro-batch while this one runs, and only block in `finalize`.
        The streaming runtime's double-buffered dispatch relies on this.
        """
        return self._fn(ds)

    def finalize(self, probs: jax.Array) -> np.ndarray:
        """Block on a `predict_async` result and map to class labels."""
        idx = np.asarray(jnp.argmax(probs, axis=1))
        if self.forest.classes is not None:
            return self.forest.classes[idx]
        return idx

    def probabilities(self, ds: TrafficDataset) -> np.ndarray:
        return np.asarray(self._fn(ds))


def build_pipeline(
    rep: FeatureRep,
    forest: DenseForest,
    max_pkts: int,
    *,
    use_kernel: bool = True,
) -> ServingPipeline:
    extract = extraction_fn(rep.features, rep.depth, max_pkts)
    feat_t = jnp.asarray(forest.feature)
    thr_t = jnp.asarray(forest.threshold)
    leaf_t = jnp.asarray(forest.leaf)
    depth = forest.depth

    def run(ds: TrafficDataset):
        x = extract(ds)
        if use_kernel:
            return ops.forest_infer(x, feat_t, thr_t, leaf_t, depth)
        from repro.kernels import ref

        return ref.forest_infer_ref(x, feat_t, thr_t, leaf_t, depth)

    return ServingPipeline(rep, forest, run)

"""The CATO Profiler: measure cost(x) and perf(x) of generated pipelines.

For every feature representation x = (F, n) sampled by the Optimizer, the
Profiler (paper §3.4):

  1. *generates* the serving pipeline — here a jit-specialized XLA executable
     containing exactly the ops for F at depth n (`repro.traffic.extraction`)
     plus the dense-forest inference stage;
  2. *trains a fresh model* on the training split and evaluates macro-F1 on
     a hold-out test set (perf);
  3. *measures* the systems cost under one of four metrics (paper §4):
       exec_time   — per-flow CPU time of the pipeline,
       latency     — end-to-end inference latency incl. time waiting for
                     packets to arrive (inter-arrival dominated),
       throughput  — zero-loss drain rate (negated for minimization),
       throughput_replayed — zero-loss throughput *measured* by replaying
                     the test split as a packet stream through the online
                     serving runtime (`repro.serve.runtime`) and bisecting
                     the highest offered load with zero drops (Fig. 5c as
                     a measurement rather than a model),
       throughput_replayed_sharded — the same measurement against an
                     `n_shards`-worker `ShardedRuntime` with RSS-style
                     symmetric flow steering: the bisection is over the
                     aggregate offered load, and a drop on any shard
                     fails the trial (DESIGN.md §8).

Cost modes:
  measured — wall-clock the compiled extraction + inference on this machine
             (compile excluded, best-of-k). Used for headline runs (Fig. 5).
  modeled  — deterministic op-DAG accounting (shared ops deduplicated),
             calibrated to Table-2 magnitudes. Used for ground-truth
             exhaustive enumeration and the convergence studies, where
             120k+ profiler calls make per-call wall-clocking impractical
             and measurement noise would swamp HVI comparisons.

Fig.-8 ablation variants are exposed as alternative metrics: `naive_cost`
(per-feature costs summed without shared-op dedup), `model_inf_cost`,
`pkt_depth_cost`, `naive_perf` (sum of per-feature MI).

The cheap-modeled vs. expensive-replayed spectrum above is packaged as
pluggable measurement *backends* in `repro.traffic.backends`
(`modeled` / `replayed` / `replayed_sharded`), all views over one
profiler instance: they share its matrix, trained-model, service-model
calibration, and result caches, so the multi-fidelity optimizer and
every baseline pay for each distinct config at most once per fidelity
(DESIGN.md §10.1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.forest import (
    DenseForest,
    forest_apply_np,
    forest_predict_class,
)
from repro.core.mutual_info import mi_scores
from repro.core.search_space import FeatureRep

from .extraction import extract_features, extraction_fn
from .features import (
    FEATURE_NAMES,
    modeled_extraction_cost_ns,
)
from .models import macro_f1, train_traffic_model
from .synth import TrafficDataset

__all__ = ["ProfileResult", "TrafficProfiler"]

_CAPTURE_NS = 2.0  # connection-tracking cost per packet beyond depth n
_TREE_NODE_NS = 1.2  # per level per tree during inference
# frozen-path / tracked-path cost ratio assumed by the modeled fidelity
# before any measured calibration has timed the frozen path (DESIGN.md §12)
_REUSE_DISCOUNT_DEFAULT = 0.5


@dataclasses.dataclass
class ProfileResult:
    cost: float
    perf: float
    aux: dict = dataclasses.field(default_factory=dict)


class TrafficProfiler:
    def __init__(
        self,
        dataset: TrafficDataset,
        feature_names: Sequence[str] = FEATURE_NAMES,
        *,
        model: str = "rf",
        cost_metric: str = "exec_time",   # exec_time | latency | throughput
                                          # | throughput_replayed
                                          # | throughput_replayed_sharded
        cost_mode: str = "modeled",       # modeled | measured
        n_shards: int = 2,                # worker count for the sharded metric
        scenario: str = "uniform",        # arrival process for replayed metrics
        bisect_iters: int = 10,           # zero-loss bisection depth
        test_frac: float = 0.2,
        seed: int = 0,
        cache: bool = True,
        reuse=None,                       # ReuseConfig: replay + model with
                                          # drift-gated prediction reuse on
    ):
        self.dataset = dataset
        self.feature_names = tuple(feature_names)
        self.model = model
        self.cost_metric = cost_metric
        self.cost_mode = cost_mode
        self.n_shards = n_shards
        self.scenario = scenario
        self.reuse = reuse
        self.bisect_iters = bisect_iters
        self.seed = seed
        self.train_ds, self.test_ds = dataset.split(test_frac, seed)
        self._stream_cache = None
        self._service_cache: dict = {}
        self._matrix_cache: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._result_cache: dict = {}
        # trained model + hold-out F1 per canonical config key: every
        # fidelity of the same x shares one trained model (training is
        # seeded-deterministic, so caching is semantics-free), and
        # `serve.deploy` reuses the exact forest the measurement used
        self._perf_cache: dict = {}
        self._cache_enabled = cache
        self._mi_full: Optional[np.ndarray] = None
        self.n_profile_calls = 0
        self.wallclock = {"train_perf": 0.0, "measure_cost": 0.0, "pipeline_gen": 0.0}

    # -- feature matrices (column-sliced from per-depth full extraction) ----
    def matrices_at_depth(self, depth: int) -> tuple[np.ndarray, np.ndarray]:
        if depth not in self._matrix_cache:
            Xtr = extract_features(self.train_ds, self.feature_names, depth)
            Xte = extract_features(self.test_ds, self.feature_names, depth)
            self._matrix_cache[depth] = (Xtr, Xte)
        return self._matrix_cache[depth]

    def columns(self, x: FeatureRep) -> tuple[np.ndarray, np.ndarray]:
        Xtr, Xte = self.matrices_at_depth(x.depth)
        idx = [self.feature_names.index(f) for f in x.features]
        return Xtr[:, idx], Xte[:, idx]

    # -- perf(x): train fresh model, hold-out macro F1 -----------------------
    def perf_f1(self, x: FeatureRep) -> tuple[float, DenseForest]:
        pkey = (x.key(), self.model)
        if self._cache_enabled and pkey in self._perf_cache:
            return self._perf_cache[pkey]
        t0 = time.perf_counter()
        Xtr, Xte = self.columns(x)
        forest, _ = train_traffic_model(
            Xtr, self.train_ds.label, model=self.model, seed=self.seed
        )
        pred = forest_predict_class(forest, Xte)
        f1 = macro_f1(self.test_ds.label, pred)
        self.wallclock["train_perf"] += time.perf_counter() - t0
        if self._cache_enabled:
            self._perf_cache[pkey] = (f1, forest)
        return f1, forest

    # -- cost components ------------------------------------------------------
    def _depth_eff(self, x: FeatureRep) -> float:
        """Mean packets actually processed: min(depth, flow_len)."""
        return float(np.minimum(self.test_ds.flow_len, x.depth).mean())

    def _inference_ns(self, forest: DenseForest) -> float:
        return forest.n_trees * forest.depth * _TREE_NODE_NS + 2.0 * forest.n_out

    def modeled_exec_us(self, x: FeatureRep, forest: DenseForest, dedup=True) -> float:
        ns = modeled_extraction_cost_ns(x.features, self._depth_eff(x), dedup)
        ns += self._inference_ns(forest)
        return ns / 1e3

    def measured_exec_us(self, x: FeatureRep, forest: DenseForest) -> float:
        """Wall-clock the generated pipeline on the test split (per flow)."""
        t0 = time.perf_counter()
        fn = extraction_fn(x.features, x.depth, self.test_ds.max_pkts)
        feats = np.asarray(fn(self.test_ds))  # compile + warm
        self.wallclock["pipeline_gen"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        best = np.inf
        for _ in range(3):
            t1 = time.perf_counter()
            fn(self.test_ds)
            best = min(best, time.perf_counter() - t1)
        t_inf = np.inf
        for _ in range(3):
            t1 = time.perf_counter()
            forest_apply_np(forest, feats)
            t_inf = min(t_inf, time.perf_counter() - t1)
        self.wallclock["measure_cost"] += time.perf_counter() - t0
        n = self.test_ds.n_flows
        return (best + t_inf) / n * 1e6

    def exec_time_us(self, x: FeatureRep, forest: DenseForest) -> float:
        if self.cost_mode == "measured":
            return self.measured_exec_us(x, forest)
        return self.modeled_exec_us(x, forest)

    def latency_s(self, x: FeatureRep, forest: DenseForest) -> float:
        """Wait for n packets (inter-arrival) + pipeline execution time."""
        ds = self.test_ds
        last = np.minimum(ds.flow_len, x.depth) - 1
        wait = ds.ts[np.arange(ds.n_flows), last]
        return float(wait.mean()) + self.exec_time_us(x, forest) / 1e6

    def throughput_gbps(self, x: FeatureRep, forest: DenseForest) -> float:
        """Zero-loss drain rate: bits/flow over CPU-seconds/flow."""
        ds = self.test_ds
        n_eff = self._depth_eff(x)
        mean_len = float(ds.flow_len.mean())
        if self.cost_mode == "measured":
            exec_ns = self.measured_exec_us(x, forest) * 1e3
        else:
            exec_ns = self.modeled_exec_us(x, forest) * 1e3
        # packets past the inference point still transit connection tracking;
        # under reuse they take the cheaper frozen fast path instead
        # (DESIGN.md §12), discounted by the learned frozen/track ratio
        tail_ns = max(0.0, mean_len - n_eff) * _CAPTURE_NS
        drain_ns = exec_ns + tail_ns * self.reuse_discount()
        bytes_per_flow = float((ds.size * ds.valid_mask()).sum() / ds.n_flows)
        return bytes_per_flow * 8.0 / drain_ns  # Gbit/s (bits per ns)

    def reuse_discount(self, reuse="profiler") -> float:
        """Frozen-path discount the modeled fidelity applies to packets past
        the inference point when prediction reuse is on.

        Learned, not guessed, whenever possible: any measured service
        calibration in this profiler's cache that timed the frozen path
        (`calibrate_warm`) contributes its frozen/track ratio — the cheap
        fidelity absorbs the expensive fidelity's measurement, keeping the
        multi-fidelity surrogate's two views of one config commensurable.
        Falls back to the deterministic default before any measurement
        exists, and to 1.0 (no discount) with reuse off."""
        if reuse == "profiler":
            reuse = self.reuse
        if reuse is None or not getattr(reuse, "enabled", False):
            return 1.0
        ratios = [
            sm.pkt_frozen_ns / sm.pkt_track_ns
            for sm in self._service_cache.values()
            if sm.pkt_frozen_ns is not None and sm.pkt_track_ns > 0
        ]
        if ratios:
            return float(min(1.0, sum(ratios) / len(ratios)))
        return _REUSE_DISCOUNT_DEFAULT

    def replayed_throughput_gbps(
        self,
        x: FeatureRep,
        forest: DenseForest,
        *,
        capacity: int = 2048,
        max_batch: int = 128,
        ring_capacity: Optional[int] = None,
        bisect_iters: Optional[int] = None,
        verbose: bool = False,
        fused: bool = True,
        n_shards: int = 1,
        control=None,
        obs=None,
        reuse="profiler",
        calibrate_warm: Optional[bool] = None,
    ):
        """Zero-loss throughput measured through the streaming runtime.

        Replays the held-out split as an offered-load packet stream through
        `repro.serve.runtime` (flow table -> bucketed micro-batch dispatch
        -> this representation's pipeline — by default the single-launch
        fused Pallas kernel, DESIGN.md §7) and bisects the highest rate
        with zero drops. cost_mode selects the replay clock's constants:
        measured (wall-clock calibration on this machine) or modeled
        (feature-op DAG). Returns (gbps, ReplayStats).

        With `n_shards > 1` the DUT is a `ShardedRuntime`: RSS-style
        symmetric steering splits the offered load across workers, and the
        bisection runs over the *aggregate* rate (a drop on any shard
        fails the trial). Each worker queue gets a full-size ring — the
        hardware-RSS provisioning, where every queue owns its own
        descriptor ring — clamped below the hottest shard's sub-trace so
        saturation stays reachable (DESIGN.md §8.3, incl. the buffering
        caveat this implies for aggregate numbers). The flow table budget
        (`capacity`) is split per shard.

        The offered stream follows the profiler's `scenario` (arrival
        process + dataset skew are fixed at dataset construction; see
        `make_scenario_dataset`). With `control` (a
        `repro.serve.control.ControlConfig`) and `n_shards > 1`, the
        measurement runs under the adaptive control plane — dynamic RETA
        rebalancing and friends — instead of the static fleet
        (DESIGN.md §9).

        Pass an `Observability` bundle as `obs` to instrument the final
        zero-loss verification replay (tracing, drift, fleet registry,
        audit — DESIGN.md §11); bisection probes stay uninstrumented so
        the bundle captures exactly one run.

        `reuse` overrides the profiler's own reuse configuration for this
        measurement (a `ReuseConfig` or None; the default inherits
        `self.reuse`). With reuse on, the measured calibration always
        times the steady-state warm paths (`calibrate_warm`) so the
        replay clock charges frozen packets their real amortized cost;
        pass `calibrate_warm=True` to force the honest warm calibration
        for a reuse-off arm too (an apples-to-apples A/B needs both arms
        on measured constants, not one on the legacy 0.25x guess).
        """
        from repro.serve.runtime import (
            PacketStream, ServiceModel, ShardedRuntime, StreamingRuntime,
            find_zero_loss_rate,
        )
        from .pipeline import build_pipeline

        t0 = time.perf_counter()
        pipe = build_pipeline(x, forest, max_pkts=x.depth, fused=fused,
                              use_kernel=False)
        if self._stream_cache is None:
            self._stream_cache = PacketStream.from_dataset(
                self.test_ds, seed=self.seed, scenario=self.scenario)
        stream = self._stream_cache
        if ring_capacity is None:
            # the DUT buffer must be small vs the trace or loss cannot
            # occur. Per-queue ring: every worker queue gets the full
            # ring, exactly as NIC RSS provisions descriptor rings per
            # queue (DESIGN.md §8.3); the binding clamp is the *hottest
            # shard's* steered sub-trace — its queue must not be able to
            # absorb its whole offered load (the same trace-size clamp
            # the single-worker path applies — see the tiny-split
            # regression tests). Explicit ring_capacity values are
            # honored verbatim; find_zero_loss_rate raises loudly if
            # they make saturation unreachable.
            ring_capacity = max(64, min(4096, stream.n_events // 8))
            if n_shards > 1:
                from repro.serve.runtime.shard import steer_flows

                counts = np.bincount(
                    steer_flows(stream, n_shards)[stream.fid],
                    minlength=n_shards)
                events_bound = int(counts.max())
            else:
                events_bound = stream.n_events
            ring_capacity = min(ring_capacity, max(1, events_bound - 1))
        self.wallclock["pipeline_gen"] += time.perf_counter() - t0

        ru = self.reuse if reuse == "profiler" else reuse
        if calibrate_warm is None:
            calibrate_warm = ru is not None and getattr(ru, "enabled", False)

        def make_runtime(execute: bool) -> StreamingRuntime:
            if n_shards > 1:
                return ShardedRuntime(
                    pipe, n_shards=n_shards, capacity=capacity,
                    max_batch=max_batch, flush_timeout_s=0.05,
                    idle_timeout_s=60.0, execute=execute, reuse=ru,
                )
            return StreamingRuntime(
                pipe, capacity=capacity, max_batch=max_batch,
                flush_timeout_s=0.05, idle_timeout_s=60.0, execute=execute,
                reuse=ru,
            )

        t0 = time.perf_counter()
        # one calibration per representation: repeated measurements of the
        # same (F, n) — e.g. a static-vs-controlled comparison — must share
        # clock constants, or calibration jitter masquerades as a
        # configuration effect
        skey = (x.key(), self.cost_mode, calibrate_warm,
                None if ru is None else (getattr(ru, "enabled", False),
                                         getattr(ru, "drift_threshold", 0.0),
                                         getattr(ru, "refresh_every", 0)))
        service = self._service_cache.get(skey)
        if service is None:
            if self.cost_mode == "measured":
                service = ServiceModel.measure(
                    make_runtime(True), stream, calibrate_warm=calibrate_warm)
            else:
                service = ServiceModel.modeled(
                    x, forest, reuse_discount=self.reuse_discount(ru))
            self._service_cache[skey] = service
        session = None
        if control is not None or obs is not None:
            from repro.serve import ServeSession

            session = ServeSession(control=control, obs=obs)
        rate_pps, stats = find_zero_loss_rate(
            stream, make_runtime, service,
            iters=self.bisect_iters if bisect_iters is None else bisect_iters,
            ring_capacity=ring_capacity, verbose=verbose, session=session,
        )
        self.wallclock["measure_cost"] += time.perf_counter() - t0
        return stats.offered_gbps, stats

    def replayed_latency_p99(
        self,
        x: FeatureRep,
        forest: DenseForest,
        *,
        offered_pps: Optional[float] = None,
        capacity: int = 2048,
        max_batch: int = 128,
        ring_capacity: Optional[int] = None,
        n_shards: int = 1,
        obs=None,
    ):
        """p99 enqueue→prediction latency under a *fixed* offered load
        (DESIGN.md §14, ROADMAP "SLO-aware provisioning").

        One replay of the held-out split at `offered_pps` (default: the
        scenario trace's native rate — the load the SLO is stated
        against), through the same runtime geometry as
        `replayed_throughput_gbps` but with no bisection: tail latency
        is a property of one operating point, not of the saturation
        envelope. Clock constants come from the same per-representation
        `ServiceModel` cache, so a throughput and a latency measurement
        of one (F, n) share constants. Returns (p99_s, ReplayStats);
        an `obs` bundle (e.g. with a `LatencyConfig`) instruments the
        run for per-stage decomposition.
        """
        from repro.serve.runtime import (
            PacketStream, ServiceModel, ShardedRuntime, StreamingRuntime,
            replay,
        )
        from .pipeline import build_pipeline

        t0 = time.perf_counter()
        pipe = build_pipeline(x, forest, max_pkts=x.depth, fused=True,
                              use_kernel=False)
        if self._stream_cache is None:
            self._stream_cache = PacketStream.from_dataset(
                self.test_ds, seed=self.seed, scenario=self.scenario)
        stream = self._stream_cache
        if ring_capacity is None:
            ring_capacity = max(64, min(4096, stream.n_events // 8))
        self.wallclock["pipeline_gen"] += time.perf_counter() - t0

        ru = self.reuse
        calibrate_warm = ru is not None and getattr(ru, "enabled", False)

        def make_runtime(execute: bool = False):
            if n_shards > 1:
                return ShardedRuntime(
                    pipe, n_shards=n_shards, capacity=capacity,
                    max_batch=max_batch, flush_timeout_s=0.05,
                    idle_timeout_s=60.0, execute=execute, reuse=ru,
                )
            return StreamingRuntime(
                pipe, capacity=capacity, max_batch=max_batch,
                flush_timeout_s=0.05, idle_timeout_s=60.0, execute=execute,
                reuse=ru,
            )

        t0 = time.perf_counter()
        skey = (x.key(), self.cost_mode, calibrate_warm,
                None if ru is None else (getattr(ru, "enabled", False),
                                         getattr(ru, "drift_threshold", 0.0),
                                         getattr(ru, "refresh_every", 0)))
        service = self._service_cache.get(skey)
        if service is None:
            if self.cost_mode == "measured":
                service = ServiceModel.measure(
                    make_runtime(True), stream, calibrate_warm=calibrate_warm)
            else:
                service = ServiceModel.modeled(
                    x, forest, reuse_discount=self.reuse_discount(ru))
            self._service_cache[skey] = service
        pps = float(offered_pps) if offered_pps is not None else stream.base_pps
        session = None
        if obs is not None:
            from repro.serve import ServeSession

            session = ServeSession(obs=obs)
        stats = replay(stream, make_runtime, pps, service,
                       ring_capacity=ring_capacity, session=session)
        self.wallclock["measure_cost"] += time.perf_counter() - t0
        return stats.latency_p99_s, stats

    # -- ablation metrics (Fig. 8) -------------------------------------------
    def naive_cost_us(self, x: FeatureRep, forest: DenseForest) -> float:
        return self.modeled_exec_us(x, forest, dedup=False)

    def model_inf_cost_us(self, forest: DenseForest) -> float:
        return self._inference_ns(forest) / 1e3

    def naive_perf(self, x: FeatureRep) -> float:
        if self._mi_full is None:
            Xtr, _ = self.matrices_at_depth(self.dataset.max_pkts)
            self._mi_full = mi_scores(Xtr, self.train_ds.label, seed=self.seed)
        idx = [self.feature_names.index(f) for f in x.features]
        return float(self._mi_full[idx].sum())

    # -- main entry ------------------------------------------------------------
    def __call__(self, x: FeatureRep, metric: Optional[str] = None) -> ProfileResult:
        metric = metric or self.cost_metric
        key = (x.key(), metric, self.cost_mode, self.model)
        if self._cache_enabled and key in self._result_cache:
            return self._result_cache[key]
        self.n_profile_calls += 1

        if metric == "naive_perf":
            f1, forest = self.naive_perf(x), None
            # cost stays the real metric (Fig. 8 keeps cost(x) original)
            _, forest = self.perf_f1(x)  # still need a model for exec cost
            cost = self.exec_time_us(x, forest)
            res = ProfileResult(cost=cost, perf=f1, aux={"variant": "naive_perf"})
        else:
            f1, forest = self.perf_f1(x)
            if metric == "exec_time":
                cost = self.exec_time_us(x, forest)
            elif metric == "latency":
                cost = self.latency_s(x, forest)
            elif metric == "throughput":
                cost = -self.throughput_gbps(x, forest)
            elif metric == "throughput_replayed":
                cost = -self.replayed_throughput_gbps(x, forest)[0]
            elif metric == "throughput_replayed_sharded":
                cost = -self.replayed_throughput_gbps(
                    x, forest, n_shards=self.n_shards)[0]
            elif metric == "latency_p99_replayed":
                # tail latency at fixed offered load (DESIGN.md §14): the
                # third objective axis the ROADMAP's SLO-aware provisioning
                # planner optimizes; lower is better, so no negation
                cost = self.replayed_latency_p99(x, forest)[0]
            elif metric == "naive_cost":
                cost = self.naive_cost_us(x, forest)
            elif metric == "model_inf_cost":
                cost = self.model_inf_cost_us(forest)
            elif metric == "pkt_depth_cost":
                cost = float(x.depth)
            else:
                raise ValueError(f"unknown metric {metric!r}")
            res = ProfileResult(
                cost=float(cost),
                perf=float(f1),
                aux={"n_features": len(x.features), "depth": x.depth},
            )
        if self._cache_enabled:
            self._result_cache[key] = res
        return res

    # -- true metrics for post-hoc re-evaluation (Fig. 8 post-processing) ----
    def true_metrics(self, x: FeatureRep) -> ProfileResult:
        f1, forest = self.perf_f1(x)
        if self.cost_metric == "latency":
            cost = self.latency_s(x, forest)
        elif self.cost_metric == "throughput":
            cost = -self.throughput_gbps(x, forest)
        elif self.cost_metric == "throughput_replayed":
            cost = -self.replayed_throughput_gbps(x, forest)[0]
        elif self.cost_metric == "throughput_replayed_sharded":
            cost = -self.replayed_throughput_gbps(
                x, forest, n_shards=self.n_shards)[0]
        elif self.cost_metric == "latency_p99_replayed":
            cost = self.replayed_latency_p99(x, forest)[0]
        else:
            cost = self.exec_time_us(x, forest)
        return ProfileResult(cost=float(cost), perf=float(f1))

"""Synthetic network-trace generator for the paper's two use cases.

There is no NIC or campus tap in this environment, so we synthesize traces
whose *statistical problem shape* matches the paper's setting:

- per-class generative structure over packet sizes, inter-arrival times,
  TTLs, TCP window sizes, flags, ports and flow lengths;
- a protocol-generic TCP handshake prefix (SYN / SYN-ACK / ACK with
  near-constant sizes) so early packets carry little size information while
  static fields (TTL, initial window, ports) are informative from packet 1;
- behavioral statistics (inter-arrival moments, loads, flag mixes) whose
  class signal grows with packet depth — reproducing the Fig.-2 phenomenon
  that the best feature set *changes* with depth;
- class overlap + noise so F1 saturates below 1.0 and depth matters.

Use cases (paper §5.1):
  iot-class  28 device classes (UNSW IoT analogue), random-forest model.
  app-class  7 classes: 6 web applications + "other", decision-tree model.

Packets are materialized as dense per-flow tensors (flows, max_pkts) so the
JAX extraction engine can run masked segmented reductions — the TPU-native
layout (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "TrafficDataset",
    "make_dataset",
    "make_scenario_dataset",
    "scenario_flow_starts",
    "FLAG_NAMES",
    "SCENARIOS",
]

# Adversarial serving workloads (DESIGN.md §9.5). Real traffic is not the
# well-mixed Poisson soup `make_dataset` + a plain exponential arrival
# process produce; these named scenarios break exactly the assumptions a
# static deployment bakes in:
#   uniform — the historical well-behaved baseline;
#   zipf    — elephant-flow skew: flow packet mass ~ bounded Zipf, flow
#             durations equalized so an elephant's *rate* scales with its
#             mass. A handful of flows dominate offered load, so a
#             handful of RETA buckets dominate shard load — the workload
#             dynamic rebalancing exists for;
#   burst   — MMPP on/off flow arrivals: mean rate preserved, but flows
#             arrive in compressed bursts separated by lulls, stressing
#             ring buffering and flush-timeout behavior;
#   drift   — the class mix drifts across the trace (early flows drawn
#             from one end of the class list, late flows from the other),
#             so per-class load — and the bucket histogram under any
#             class-correlated steering — moves under the control plane.
SCENARIOS = ("uniform", "zipf", "burst", "drift")

FLAG_NAMES = ("cwr", "ece", "urg", "ack", "psh", "rst", "syn", "fin")
_F = {n: i for i, n in enumerate(FLAG_NAMES)}


@dataclasses.dataclass
class TrafficDataset:
    """Dense per-flow packet tensors + flow metadata + labels."""

    # per-packet tensors, shape (n_flows, max_pkts)
    ts: np.ndarray        # float32 seconds since flow start (cumulative)
    size: np.ndarray      # float32 bytes on the wire
    direction: np.ndarray # uint8: 0 = src->dst, 1 = dst->src
    ttl: np.ndarray       # float32
    winsize: np.ndarray   # float32
    flags: np.ndarray     # uint8 (n_flows, max_pkts, 8), FLAG_NAMES order
    # per-flow metadata
    flow_len: np.ndarray  # int32 true packet count (<= max_pkts stored)
    proto: np.ndarray     # float32 (6 = TCP)
    s_port: np.ndarray    # float32
    d_port: np.ndarray    # float32
    label: np.ndarray     # int32 class id
    class_names: tuple[str, ...] = ()
    name: str = ""

    @property
    def n_flows(self) -> int:
        return self.ts.shape[0]

    @property
    def max_pkts(self) -> int:
        return self.ts.shape[1]

    def valid_mask(self, depth: int | None = None) -> np.ndarray:
        """(n_flows, max_pkts) bool — packet exists and is within depth."""
        idx = np.arange(self.max_pkts)[None, :]
        m = idx < self.flow_len[:, None]
        if depth is not None:
            m &= idx < depth
        return m

    def split(self, test_frac: float = 0.2, seed: int = 0):
        rng = np.random.default_rng(seed)
        n = self.n_flows
        perm = rng.permutation(n)
        n_test = int(n * test_frac)
        te, tr = perm[:n_test], perm[n_test:]
        return self.take(tr), self.take(te)

    def truncate(self, depth: int) -> "TrafficDataset":
        """View of the first `depth` packet columns (flow_len uncapped:
        the extraction mask min()s it against depth anyway). This is the
        batch-side twin of the streaming flow table's `pkt_depth` storage."""
        return TrafficDataset(
            ts=self.ts[:, :depth], size=self.size[:, :depth],
            direction=self.direction[:, :depth], ttl=self.ttl[:, :depth],
            winsize=self.winsize[:, :depth], flags=self.flags[:, :depth],
            flow_len=self.flow_len, proto=self.proto,
            s_port=self.s_port, d_port=self.d_port,
            label=self.label, class_names=self.class_names, name=self.name,
        )

    def take(self, idx: np.ndarray) -> "TrafficDataset":
        return TrafficDataset(
            ts=self.ts[idx], size=self.size[idx], direction=self.direction[idx],
            ttl=self.ttl[idx], winsize=self.winsize[idx], flags=self.flags[idx],
            flow_len=self.flow_len[idx], proto=self.proto[idx],
            s_port=self.s_port[idx], d_port=self.d_port[idx],
            label=self.label[idx], class_names=self.class_names, name=self.name,
        )


def _class_params(K: int, rng: np.random.Generator, kind: str) -> dict:
    """Draw per-class generative parameters."""
    p = {}
    if kind == "app":
        # web apps: static fields barely discriminate — every app is TLS/443
        # from similar CDNs; clients share OS defaults. Class signal must
        # come from behavioral statistics at depth (like the paper's
        # app-class, where early-packet feature sets still need ~10 pkts).
        p["ttl_s"] = rng.choice([64, 128], K) + rng.integers(-2, 3, K)
        p["ttl_d"] = rng.choice([54, 57, 60], K) + rng.integers(-2, 3, K)
        p["win_base"] = rng.choice([29200, 65535], K) * (
            1 + 0.05 * rng.standard_normal(K)
        )
        p["d_port"] = np.full(K, 443)
    else:
        # IoT devices: distinctive stacks (RTOS TTLs, MQTT/CoAP ports, fixed
        # buffer sizes) — static fields informative from packet 1.
        p["ttl_s"] = rng.choice([32, 64, 64, 128, 255], K) + rng.integers(-3, 4, K)
        p["ttl_d"] = rng.choice([32, 64, 128, 128, 255], K) + rng.integers(-3, 4, K)
        p["win_base"] = rng.choice([8192, 16384, 29200, 65535, 65535 // 2], K) * (
            1 + 0.1 * rng.standard_normal(K)
        )
        p["d_port"] = rng.choice([443, 443, 443, 80, 8883, 1883, 5683], K)
    # behavioral: informative at depth
    p["size_mu_s"] = rng.uniform(4.0, 7.2, K)      # log bytes src->dst
    p["size_mu_d"] = rng.uniform(4.3, 7.3, K)      # log bytes dst->src
    p["size_sigma"] = rng.uniform(0.1, 0.4, K)
    p["iat_mu"] = rng.uniform(-7.0, 1.0, K)        # log seconds
    p["iat_sigma"] = rng.uniform(0.15, 0.6, K)
    p["psh_prob"] = rng.uniform(0.05, 0.6, K)
    p["rst_prob"] = rng.uniform(0.0, 0.05, K)
    p["src_frac"] = rng.uniform(0.2, 0.8, K)       # direction mix
    p["hello_size"] = rng.uniform(120, 1100, K)    # TLS-hello-ish pkt 4 size
    if kind == "iot":
        # IoT devices: mostly short periodic flows, some chatty
        p["len_mean"] = rng.uniform(6, 80, K)
    else:
        # web apps: longer flows (video/conference vs social)
        p["len_mean"] = rng.uniform(15, 160, K)
    return p


def make_dataset(
    use_case: str = "iot-class",
    n_flows: int = 6000,
    max_pkts: int = 128,
    seed: int = 0,
    label_noise: float = 0.02,
    flow_len: np.ndarray | None = None,
) -> TrafficDataset:
    """Generate a dataset for `iot-class` (28 classes) or `app-class` (7).

    `flow_len` overrides the per-class geometric length draw with explicit
    per-flow packet counts (clipped to [3, max_pkts]) — scenario generators
    use it to impose e.g. a Zipf mass distribution while every other
    generative mechanism (handshake, sizes, IATs, FIN placement) stays
    consistent with the lengths.
    """
    if use_case == "iot-class":
        K = 28
        class_names = tuple(f"iot_device_{i:02d}" for i in range(K))
        kind = "iot"
    elif use_case == "app-class":
        K = 7
        class_names = (
            "netflix", "twitch", "zoom", "teams", "facebook", "twitter", "other",
        )
        kind = "app"
    else:
        raise ValueError(f"unknown use case {use_case!r}")

    rng = np.random.default_rng(seed)
    prm = _class_params(K, np.random.default_rng(seed + 1000), kind)

    y = rng.integers(0, K, n_flows)
    P = max_pkts

    # flow lengths: geometric-ish with per-class mean, min 3 (handshake),
    # unless the caller imposes its own distribution (scenario generators)
    if flow_len is None:
        lam = prm["len_mean"][y]
        flow_len = np.clip(
            3 + rng.exponential(lam).astype(np.int64), 3, P
        ).astype(np.int32)
    else:
        flow_len = np.clip(np.asarray(flow_len, np.int64), 3, P).astype(np.int32)
        if len(flow_len) != n_flows:
            raise ValueError("flow_len override must have one entry per flow")

    idx = np.arange(P)[None, :]
    in_flow = idx < flow_len[:, None]

    # ---- direction: pkt0 src (SYN), pkt1 dst (SYN/ACK), pkt2 src (ACK),
    #      then per-class Bernoulli mix
    direction = (rng.random((n_flows, P)) > prm["src_frac"][y][:, None]).astype(np.uint8)
    direction[:, 0] = 0
    direction[:, 1] = 1
    direction[:, 2] = 0

    # ---- sizes: handshake 60/60/52, then an application-layer *message
    #      sequence* — the first ~6 data packets follow a class-specific
    #      size pattern (the GGFAST observation the paper builds on: early
    #      message lengths identify the application), before settling into
    #      the noisier stationary distribution
    mu = np.where(direction == 0, prm["size_mu_s"][y][:, None], prm["size_mu_d"][y][:, None])
    size = np.exp(mu + prm["size_sigma"][y][:, None] * rng.standard_normal((n_flows, P)))
    size = np.clip(size, 40, 1500)
    size[:, 0] = 60 + rng.integers(0, 4, n_flows)
    size[:, 1] = 60 + rng.integers(0, 4, n_flows)
    size[:, 2] = 52 + rng.integers(0, 3, n_flows)
    n_msg = min(6, P - 3)
    if n_msg > 0:
        msg_rng = np.random.default_rng(seed + 2000)
        msg_seq = msg_rng.uniform(80, 1400, (len(class_names), n_msg))
        jit_ = 1 + 0.06 * rng.standard_normal((n_flows, n_msg))
        size[:, 3 : 3 + n_msg] = np.clip(msg_seq[y] * jit_, 40, 1500)

    # ---- inter-arrival times: handshake fast (~RTT), then per-class
    #      "application rounds" in the first few exchanges (class-specific
    #      think-times), then the stationary lognormal
    rtt = np.exp(rng.uniform(-5.5, -2.5, n_flows))  # 4ms..80ms per flow
    iat = np.exp(
        prm["iat_mu"][y][:, None]
        + prm["iat_sigma"][y][:, None] * rng.standard_normal((n_flows, P))
    )
    if P > 3:
        n_r = min(6, P - 3)
        round_rng = np.random.default_rng(seed + 3000)
        round_pat = np.exp(round_rng.uniform(-6.5, -0.5, (len(class_names), n_r)))
        iat[:, 3 : 3 + n_r] = round_pat[y] * (
            1 + 0.15 * np.abs(rng.standard_normal((n_flows, n_r)))
        )
    iat[:, 0] = 0.0
    iat[:, 1] = rtt
    iat[:, 2] = rtt * (1 + 0.1 * rng.random(n_flows))
    ts = np.cumsum(iat * in_flow, axis=1).astype(np.float32)

    # ---- ttl: per-flow constant per direction with small jitter
    ttl_s = prm["ttl_s"][y] + rng.integers(-1, 2, n_flows)
    ttl_d = prm["ttl_d"][y] + rng.integers(-1, 2, n_flows)
    ttl = np.where(direction == 0, ttl_s[:, None], ttl_d[:, None]).astype(np.float32)

    # ---- winsize: slow-start-style ramp to per-class base
    ramp = np.minimum(1.0, (idx + 1) / 8.0)
    winsize = (
        prm["win_base"][y][:, None]
        * ramp
        * (1 + 0.05 * rng.standard_normal((n_flows, P)))
    ).astype(np.float32)

    # ---- flags
    flags = np.zeros((n_flows, P, 8), dtype=np.uint8)
    flags[:, 0, _F["syn"]] = 1
    flags[:, 1, _F["syn"]] = 1
    flags[:, 1, _F["ack"]] = 1
    flags[:, 2:, _F["ack"]] = 1
    data_pkts = (idx >= 3) & in_flow
    flags[:, :, _F["psh"]] = (
        data_pkts & (rng.random((n_flows, P)) < prm["psh_prob"][y][:, None])
    )
    flags[:, :, _F["rst"]] = (
        data_pkts & (rng.random((n_flows, P)) < prm["rst_prob"][y][:, None] * 0.1)
    )
    # FIN on the true last packet for ~80% of flows
    has_fin = rng.random(n_flows) < 0.8
    last = np.minimum(flow_len - 1, P - 1)
    flags[np.arange(n_flows), last, _F["fin"]] = has_fin
    flags &= in_flow[:, :, None].astype(np.uint8)

    # ---- flow metadata
    proto = np.full(n_flows, 6.0, dtype=np.float32)
    s_port = rng.integers(32768, 61000, n_flows).astype(np.float32)
    d_port = prm["d_port"][y].astype(np.float32)

    # zero out beyond flow_len
    for arr in (size, ttl, winsize):
        arr *= in_flow
    ts = ts * in_flow

    # label noise: a fraction of flows get a wrong label (class overlap)
    flip = rng.random(n_flows) < label_noise
    y = np.where(flip, rng.integers(0, K, n_flows), y).astype(np.int32)

    return TrafficDataset(
        ts=ts.astype(np.float32),
        size=size.astype(np.float32),
        direction=direction,
        ttl=ttl,
        winsize=winsize,
        flags=flags,
        flow_len=flow_len,
        proto=proto,
        s_port=s_port,
        d_port=d_port,
        label=y,
        class_names=class_names,
        name=use_case,
    )


# ---------------------------------------------------------------------------
# adversarial serving scenarios (DESIGN.md §9.5)
# ---------------------------------------------------------------------------


def scenario_flow_starts(
    rng: np.random.Generator,
    n_flows: int,
    spacing: float,
    scenario: str = "uniform",
    *,
    burst_factor: float = 10.0,
    burst_mean_on: int = 48,
    burst_on_frac: float = 0.35,
) -> np.ndarray:
    """Flow start times for `n_flows` flows at mean inter-start `spacing`.

    "uniform" (also "zipf"/"drift", whose adversarial structure lives in
    the dataset, not the arrival process) is the historical Poisson
    process. "burst" is a two-state MMPP: ON phases arrive
    `burst_factor`x faster than the mean, OFF phases are stretched so the
    *overall* mean spacing — and therefore the offered rate at any clock
    compression — is preserved; `burst_on_frac` of flows arrive inside ON
    phases of geometric mean length `burst_mean_on` flows. The same `rng`
    drives every branch so "uniform" reproduces the pre-scenario streams
    bit-for-bit.
    """
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; pick from {SCENARIOS}")
    if scenario != "burst":
        return np.cumsum(rng.exponential(spacing, n_flows))
    fast = spacing / burst_factor
    # OFF spacing solves the mean-preservation constraint:
    #   on_frac * fast + (1 - on_frac) * slow == spacing
    slow = (spacing - burst_on_frac * fast) / (1.0 - burst_on_frac)
    gaps = np.empty(n_flows)
    pos = 0
    on = True
    while pos < n_flows:
        if on:
            n_phase = 1 + int(rng.geometric(1.0 / burst_mean_on))
            mean_gap = fast
        else:
            mean_off = burst_mean_on * (1.0 - burst_on_frac) / burst_on_frac
            n_phase = 1 + int(rng.geometric(1.0 / mean_off))
            mean_gap = slow
        n_phase = min(n_phase, n_flows - pos)
        gaps[pos : pos + n_phase] = rng.exponential(mean_gap, n_phase)
        pos += n_phase
        on = not on
    return np.cumsum(gaps)


def make_scenario_dataset(
    use_case: str,
    scenario: str = "uniform",
    n_flows: int = 1500,
    max_pkts: int = 48,
    seed: int = 0,
    *,
    zipf_a: float = 1.3,
    elephant_boost: float = 0.0,
    drift_jitter: float = 0.15,
    **kw,
) -> TrafficDataset:
    """`make_dataset` plus the dataset-level half of a named scenario.

    - "uniform"/"burst": the plain dataset (burst shapes arrivals, which
      happens at `PacketStream.from_dataset(scenario=...)` time).
    - "zipf": flow packet counts follow a bounded Zipf draw (elephants
      clip at `max_pkts`), and every flow's timestamps are rescaled so a
      flow's duration *shrinks* with its mass: per-flow packet rate goes
      as `flow_len ** (1 + elephant_boost)`. A handful of flows then
      carry most of the offered load, so a handful of RETA buckets carry
      most of the shard load — the workload round-robin steering cannot
      survive and dynamic rebalancing exists for.
    - "drift": flows are reordered so the class mix seen by an in-order
      arrival process drifts across the trace (class rank + jitter sort).
    """
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; pick from {SCENARIOS}")
    rng = np.random.default_rng(seed + 77_000)
    if scenario == "zipf":
        lens = 2 + rng.zipf(zipf_a, n_flows)
        ds = make_dataset(use_case, n_flows=n_flows, max_pkts=max_pkts,
                          seed=seed, flow_len=lens, **kw)
        # rescale flow durations around the median so per-flow pps scales
        # as len^(1 + boost): equalized duration alone gives rate ~ len;
        # the boost shortens elephants further (a 24-pkt elephant at
        # boost 1 offers ~64x a 3-pkt mouse's rate)
        last = np.minimum(ds.flow_len, ds.max_pkts) - 1
        dur = ds.ts[np.arange(ds.n_flows), last].astype(np.float64)
        target = float(np.median(dur[dur > 0])) if (dur > 0).any() else 1.0
        med_len = float(np.median(ds.flow_len))
        target_i = target * (med_len / ds.flow_len) ** elephant_boost
        scale = np.where(dur > 0, target_i / np.maximum(dur, 1e-9), 1.0)
        ds.ts = (ds.ts.astype(np.float64) * scale[:, None]).astype(np.float32)
        return ds
    ds = make_dataset(use_case, n_flows=n_flows, max_pkts=max_pkts,
                      seed=seed, **kw)
    if scenario == "drift":
        K = len(ds.class_names)
        score = ds.label / max(K - 1, 1) + drift_jitter * rng.standard_normal(
            ds.n_flows)
        ds = ds.take(np.argsort(score, kind="stable"))
    return ds

"""Training substrate: optimizer, train step, data pipeline, checkpointing."""
from .optimizer import AdamW, cosine_schedule
from .train_step import TrainState, make_train_step, init_state

__all__ = ["AdamW", "cosine_schedule", "TrainState", "make_train_step", "init_state"]

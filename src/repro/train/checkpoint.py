"""Sharded checkpointing with atomic commit, resume, and elastic re-shard.

Layout (one directory per step):

    ckpt_dir/
      step_000123/
        manifest.json        # step, leaf paths, shapes, dtypes, mesh shape
        leaf_00000.npy ...   # one .npy per pytree leaf (host-gathered)
      LATEST                 # atomically-renamed pointer file

Fault-tolerance contract:
  * `save` writes into `step_xxxx.tmp` and renames only after every leaf +
    manifest hit disk — a crash mid-save never corrupts the latest
    checkpoint (restart resumes from the previous LATEST).
  * `restore` takes the *current* mesh/shardings: a checkpoint written on a
    16×16 mesh restores onto 2×16×16 (or a degraded 15-host remnant mesh)
    by resharding on load — this is the elastic-scaling path.
  * `save_async` runs host gather + IO on a background thread so the train
    loop overlaps checkpoint writes with the next step (one outstanding
    save; joins before starting another).

On a real multi-host cluster each host would write only its address-local
shards; this single-process implementation gathers to host (documented
simplification — the manifest format already carries per-leaf metadata).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | pathlib.Path, step: int, tree: Any) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves), "treedef": str(treedef)}
    metas = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        metas.append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest["leaves"] = metas
    (tmp / "manifest.json").write_text(json.dumps(manifest))

    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic commit
    latest_tmp = ckpt_dir / "LATEST.tmp"
    latest_tmp.write_text(str(step))
    latest_tmp.rename(ckpt_dir / "LATEST")
    return final


def latest_step(ckpt_dir: str | pathlib.Path) -> Optional[int]:
    p = pathlib.Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(
    ckpt_dir: str | pathlib.Path,
    step: Optional[int],
    template: Any,
    shardings: Optional[Any] = None,
) -> Any:
    """Restore into the structure of `template`, placed per `shardings`.

    `shardings` may target a different mesh than the one that saved —
    resharding happens in device_put (elastic restart path).
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no LATEST under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    t_leaves, treedef = _flatten(template)
    assert manifest["n_leaves"] == len(t_leaves), "pytree structure changed"
    s_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(t_leaves)
    )
    out = []
    for i, (tl, sh) in enumerate(zip(t_leaves, s_leaves)):
        arr = np.load(d / f"leaf_{i:05d}.npy")
        arr = arr.astype(tl.dtype) if hasattr(tl, "dtype") else arr
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class Checkpointer:
    """Async checkpointer with a single outstanding background save."""

    def __init__(self, ckpt_dir: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any):
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO off-thread
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save(self.dir, step, snapshot)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)


def save_async(ckpt: Checkpointer, step: int, tree: Any):
    ckpt.save_async(step, tree)

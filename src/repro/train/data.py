"""Data pipeline: deterministic synthetic token streams, mesh-sharded.

Real deployments plug a tokenized corpus in here; the interface is an
iterator of global batches already placed with the right sharding
(`jax.device_put` against the batch NamedSharding), so the train loop is
identical either way. Determinism: batch `i` of seed `s` is a pure function
of (i, s) — restarts and elastic re-shards replay identically, which is
what makes checkpoint-resume exactly reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig, ShapeSpec

__all__ = ["SyntheticTokens", "make_batch"]


def _tokens(rng: np.random.Generator, b: int, t: int, vocab: int) -> np.ndarray:
    # zipfian-ish marginal so the loss curve is non-trivial
    z = rng.zipf(1.3, size=(b, t + 1)).astype(np.int64)
    return np.minimum(z - 1, vocab - 1).astype(np.int32)


def make_batch(cfg: ModelConfig, shape: ShapeSpec, step: int, seed: int = 0,
               shardings: Optional[dict] = None) -> dict:
    """Global batch for `step` (pure function of (cfg, shape, step, seed))."""
    rng = np.random.default_rng(hash((seed, step)) % (2 ** 31))
    B, T = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        Te = Td = T // 2
        seqs = _tokens(rng, B, Td, cfg.vocab_size)
        batch = {
            "frames": rng.standard_normal((B, Te, cfg.d_model)).astype(np.float32) * 0.1,
            "tokens": seqs[:, :-1],
            "targets": seqs[:, 1:],
        }
    elif cfg.family == "vlm":
        Np = cfg.num_patches
        Tt = max(T - Np, 1)
        seqs = _tokens(rng, B, Tt, cfg.vocab_size)
        batch = {
            "patches": rng.standard_normal((B, Np, cfg.d_model)).astype(np.float32) * 0.1,
            "tokens": seqs[:, :-1],
            "targets": seqs[:, 1:],
        }
    else:
        seqs = _tokens(rng, B, T, cfg.vocab_size)
        batch = {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}
    if shardings is not None:
        batch = {
            k: jax.device_put(v, shardings[k]) if k in shardings else v
            for k, v in batch.items()
        }
    return batch


@dataclasses.dataclass
class SyntheticTokens:
    cfg: ModelConfig
    shape: ShapeSpec
    seed: int = 0
    shardings: Optional[dict] = None
    start_step: int = 0

    def __iter__(self) -> Iterator[dict]:
        step = self.start_step
        while True:
            yield make_batch(self.cfg, self.shape, step, self.seed, self.shardings)
            step += 1

"""Elastic scaling + straggler mitigation primitives.

Elastic restart path (exercised by tests/test_checkpoint.py and
launch/train.py): checkpoints are mesh-agnostic (host-gathered leaves +
manifest), so a job that loses hosts restarts on the surviving device set —
`plan_remesh` picks the largest (data × model) grid that preserves the
model-parallel degree when possible, and `restore` re-shards on load.

Straggler mitigation: `StragglerMonitor` keeps a per-step EWMA and flags
outliers; at the launcher level the policy is (a) log + alert, (b) after
`evict_after` consecutive flags from the same host, drop it from the mesh
and trigger an elastic restart (the controller loop in launch/train.py
implements (a); (b) requires a cluster controller, stubbed with the same
interface).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["plan_remesh", "StragglerMonitor"]


def plan_remesh(n_devices: int, prefer_model: int) -> tuple[int, int]:
    """Largest (data, model) grid for n_devices keeping model degree if able."""
    model = prefer_model
    while model > 1 and n_devices % model != 0:
        model //= 2
    data = n_devices // model
    return data, model


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    alpha: float = 0.1
    evict_after: int = 5
    _ewma: Optional[float] = None
    flags: int = 0
    consecutive: int = 0

    def observe(self, step_seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        if self._ewma is None:
            self._ewma = step_seconds
            return False
        is_straggler = step_seconds > self.factor * self._ewma
        if is_straggler:
            self.flags += 1
            self.consecutive += 1
        else:
            self.consecutive = 0
        # slow steps should not drag the baseline up
        if not is_straggler:
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_seconds
        return is_straggler

    @property
    def should_evict(self) -> bool:
        return self.consecutive >= self.evict_after

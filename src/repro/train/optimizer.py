"""AdamW with global-norm clipping and ZeRO-1 optimizer-state sharding.

No optax in this environment — implemented directly. Moment tensors are
float32 regardless of parameter dtype. Under a mesh, `zero1_pspecs` extends
each parameter's PartitionSpec with the data-parallel axes on the first
still-replicated, divisible dimension: optimizer state (and its update
math) is then sharded across DP ranks, and GSPMD materializes the classic
ZeRO-1 reduce-scatter(grads) → shard-update → all-gather(params) schedule.
This is what makes the yi-34b / kimi-k2 optimizer states fit (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel import ParallelCtx, current_ctx

__all__ = ["AdamW", "cosine_schedule", "zero1_pspecs"]


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def zero1_pspecs(param_specs, params_shapes, ctx: Optional[ParallelCtx] = None):
    """Extend param specs with DP axes for optimizer-state sharding."""
    ctx = ctx or current_ctx()
    dp = ctx.axes("dp") if ctx.mesh is not None else None
    if not dp:
        return param_specs

    def extend(spec: P, leaf):
        shape = leaf.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        used = set()
        for ax in parts:
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a is not None:
                    used.add(a)
        # only mesh axes not already consumed by the param sharding (e.g.
        # expert weights already use the dp axes for expert parallelism)
        free = tuple(a for a in dp if a not in used)
        if not free:
            return P(*parts)
        size = int(np.prod([ctx.mesh.shape[a] for a in free]))
        for i, (ax, dim) in enumerate(zip(parts, shape)):
            if ax is None and size > 1 and dim % size == 0 and dim >= size:
                parts[i] = free if len(free) > 1 else free[0]
                return P(*parts)
        return P(*parts)  # nothing divisible: stays param-sharded only

    return jax.tree_util.tree_map(
        extend, param_specs, params_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


@dataclasses.dataclass
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    zero1: bool = True

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def init(self, params):
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)

        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def opt_state_pspecs(self, param_specs, params_shapes):
        base = (
            zero1_pspecs(param_specs, params_shapes) if self.zero1 else param_specs
        )
        return {"m": base, "v": base, "step": P()}

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self._lr(step)

        # global-norm clip
        gsq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(grads)
        )
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))

        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + self.eps) + \
                self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state["m"])
        flat_v = jax.tree_util.tree_leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, {
            "grad_norm": gnorm, "lr": lr,
        }

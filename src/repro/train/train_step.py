"""Train step: loss → grads → AdamW, with microbatched gradient accumulation.

`make_train_step(cfg, opt, microbatches)` builds the jit-able step
function. With microbatches > 1 the global batch is split along the batch
axis and gradients are accumulated in a `lax.scan` — each microbatch's
backward emits its reduce-scatter as it completes, so gradient communication
overlaps the next microbatch's compute (the standard accumulation/overlap
trick; the dry-run HLO shows the interleaving).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.models.config import ModelConfig

from .optimizer import AdamW

__all__ = ["TrainState", "init_state", "make_train_step"]

TrainState = dict  # {"params": pytree, "opt": opt_state, "step": scalar}


def init_state(cfg: ModelConfig, key, opt: AdamW) -> TrainState:
    from repro.models import init_params

    params = init_params(cfg, key)
    return {"params": params, "opt": opt.init(params)}


def _split_mb(batch: dict, n: int):
    def sp(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def make_train_step(cfg: ModelConfig, opt: AdamW, microbatches: int = 1):
    def train_step(state: TrainState, batch: dict):
        params = state["params"]

        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        else:
            mbs = _split_mb(batch, microbatches)

            def body(acc, mb):
                mb_loss, g = jax.value_and_grad(loss_fn)(params, mb, cfg)
                acc_l, acc_g = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g
                )
                return (acc_l + mb_loss, acc_g), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zero_g), mbs)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)

        new_params, new_opt, metrics = opt.update(grads, state["opt"], params)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step

"""Minimal stand-in for `hypothesis` when it is not installed.

The property tests in this repo use a small slice of the API — `given` /
`settings`, `strategies.{integers,floats,tuples,just,sampled_from}` and
`hypothesis.extra.numpy.arrays`. This shim implements exactly that slice
as seeded random sampling: each `@given` test runs `max_examples` randomly
drawn examples (deterministic seed, so failures reproduce) and reports the
falsifying example on assertion failure.

It is NOT a replacement for hypothesis (no shrinking, no coverage-guided
generation); it exists so `python -m pytest` collects and runs the full
suite in environments without the dependency. When hypothesis is
available, the real library is used instead (see the try/except imports in
the test modules).
"""
from __future__ import annotations

import inspect
import types

import numpy as np

__all__ = ["given", "settings", "strategies", "hnp"]

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    *,
    allow_nan: bool = False,
    allow_infinity: bool = False,
    width: int = 64,
) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


def tuples(*strategies_) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies_))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(2)))


strategies = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    just=just,
    tuples=tuples,
    sampled_from=sampled_from,
    booleans=booleans,
)


def _np_arrays(dtype, shape, *, elements: _Strategy | None = None) -> _Strategy:
    shape_strat = shape if isinstance(shape, _Strategy) else just(tuple(shape))

    def draw(rng: np.random.Generator):
        shp = shape_strat.draw(rng)
        shp = (shp,) if isinstance(shp, int) else tuple(shp)
        n = int(np.prod(shp)) if shp else 1
        if elements is not None:
            flat = np.array([elements.draw(rng) for _ in range(n)], dtype=dtype)
        else:
            flat = rng.random(n).astype(dtype)
        return flat.reshape(shp)

    return _Strategy(draw)


hnp = types.SimpleNamespace(arrays=_np_arrays)


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        def wrapper(*fixture_args, **fixture_kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(0)
            for i in range(n):
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*fixture_args, **fixture_kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} falsified on example {i}: {drawn!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # hide the strategy params from pytest's fixture resolution: the
        # visible signature keeps only non-strategy (fixture) parameters
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper

    return deco

import numpy as np
import pytest


def pytest_configure(config):
    # the fused pipeline donates its packet buffers; the CPU backend cannot
    # alias them into the output and warns once per compile (expected —
    # donation engages on accelerators only, see kernels/fused_pipeline.py)
    config.addinivalue_line(
        "filterwarnings",
        "ignore:Some donated buffers were not usable:UserWarning",
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

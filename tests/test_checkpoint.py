"""Checkpointing: atomic commit, resume, async writer, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import Checkpointer, latest_step, restore, save


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
        "b": {"c": jnp.asarray(rng.integers(0, 10, (3,)), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, 7, t)
    assert latest_step(tmp_path) == 7
    got = restore(tmp_path, None, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_never_leaves_partial_latest(tmp_path):
    t = _tree()
    save(tmp_path, 1, t)
    # a later partially-written step (simulated crash) must not be visible
    broken = tmp_path / "step_00000002.tmp"
    broken.mkdir()
    (broken / "leaf_00000.npy").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 1
    got = restore(tmp_path, None, t)
    assert got is not None


def test_async_checkpointer_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, _tree(s))
    ck.wait()
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"
    assert latest_step(tmp_path) == 4


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Checkpoint saved unsharded restores under a different device layout."""
    t = _tree()
    save(tmp_path, 3, t)
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = {
        "a": NamedSharding(mesh, P("data")),
        "b": {"c": NamedSharding(mesh, P())},
    }
    got = restore(tmp_path, 3, t, sh)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))
    assert got["a"].sharding == sh["a"]


def test_train_resume_continues_losses(tmp_path):
    """launch.train resumes from checkpoint and keeps improving."""
    from repro.launch.train import main as train_main

    args = ["--arch", "qwen3-8b", "--reduced", "--steps", "6", "--batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
            "--lr", "1e-3"]
    train_main(args)
    # simulate preemption: second run resumes from step 6's checkpoint dir
    losses2 = train_main(args + ["--steps", "8"])
    assert latest_step(tmp_path) is not None
    assert len(losses2) <= 3  # resumed near the end, not from scratch

"""Hierarchical/compressed collectives + the LM serving tuner + elastic."""
import os
import subprocess
import sys

import numpy as np

from repro.core.tuner import ConfigSpace, PipelineTuner, ServingConfig
from repro.train.elastic import StragglerMonitor, plan_remesh


def test_plan_remesh_prefers_model_degree():
    assert plan_remesh(256, 16) == (16, 16)
    assert plan_remesh(128, 16) == (8, 16)
    assert plan_remesh(96, 16) == (6, 16)
    assert plan_remesh(56, 16) == (7, 8)    # 16 doesn't divide 56 -> halve
    assert plan_remesh(7, 16) == (7, 1)


def test_straggler_monitor_flags_and_evicts():
    m = StragglerMonitor(factor=3.0, evict_after=2)
    assert not m.observe(1.0)
    assert not m.observe(1.1)
    assert m.observe(10.0)
    assert not m.should_evict
    assert m.observe(10.0)
    assert m.should_evict
    # baseline not dragged up by stragglers
    assert m._ewma < 2.0


def test_tuner_finds_tradeoff_front():
    from repro import configs

    cfg = configs.get("qwen3-8b")
    tuner = PipelineTuner(cfg, chips=256)
    res = tuner.tune(25, seed=0)
    front = res.pareto_observations()
    assert len(front) >= 2
    # the quality-max point keeps the full window (high quality proxy)
    best_q = max(front, key=lambda o: o.perf)
    assert best_q.x.window == 32768
    assert best_q.perf >= 0.97
    # the cheapest point should truncate the window or use int8 KV
    cheapest = min(front, key=lambda o: o.cost)
    assert cheapest.x.window < 32768 or cheapest.x.kv_dtype == "int8"
    # cost model sanity: int8 KV at same window is never slower
    c_bf = tuner.profile(ServingConfig(kv_dtype="bf16", window=32768))[0]
    c_i8 = tuner.profile(ServingConfig(kv_dtype="int8", window=32768))[0]
    assert c_i8 <= c_bf


def test_config_space_protocol():
    sp = ConfigSpace()
    rng = np.random.default_rng(0)
    xs = sp.sample_uniform(rng, 20)
    assert len({x.key() for x in xs}) > 5
    for x in xs:
        v = sp.encode(x)
        assert v.shape == (5,)
        m = sp.mutate(rng, x)
        assert isinstance(m, ServingConfig)


COLL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.parallel.collectives import compressed_pod_psum, hierarchical_psum

mesh = jax.make_mesh((2, 4), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 100.0

want = 8 * x  # psum over all 8 devices of identical shards

def f(xs):
    return hierarchical_psum(xs, "pod", "data")

got = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)(x)
err = float(jnp.max(jnp.abs(got - want)))
assert err < 1e-5, err
print("hierarchical ok", err)

def g(xs):
    return compressed_pod_psum(xs, "pod", "data")

got_c = shard_map(g, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)(x)
rel = float(jnp.max(jnp.abs(got_c - want)) / jnp.max(jnp.abs(want)))
assert rel < 0.02, rel  # int8 quantization error budget
print("compressed ok", rel)
print("COLL_OK")
"""


def test_hierarchical_and_compressed_psum():
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", COLL_SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "COLL_OK" in r.stdout, r.stdout + "\n" + r.stderr

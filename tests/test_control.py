"""Control-plane invariants (DESIGN.md §9).

The contracts the adaptive layer promises, asserted end to end:

- **migration safety**: a RETA rewrite moves the stranded flow state with
  it — no flow is lost, double-predicted, or misrouted mid-flow, and
  predictions stay bit-identical to an oracle single-worker run;
- **hot-swap safety**: a mid-stream pipeline replacement drops nothing,
  predicts every flow exactly once, and flows that complete under a
  single configuration classify exactly as that configuration's oracle;
- **the acceptance number**: under the Zipf elephant-flow scenario at 4
  shards, the control plane strictly reduces `load_imbalance` and buys
  >= 1.2x the static RETA's zero-loss throughput, zero drops both ways;
- **elastic sizing**: the headroom policy grows the fleet under load and
  retires workers (after evacuating their buckets) when idle;
- **bounded metrics**: `LatencyHistogram` keeps exact bucket counts and
  bounded raw storage, with percentile error within one bucket width.
"""

import numpy as np
import pytest

from repro.core.search_space import FeatureRep
from repro.serve import ServeSession
from repro.serve.control import (
    ControlConfig,
    HeadroomPolicy,
    PipelineSwap,
    plan_rebalance,
    plan_retirement,
)
from repro.serve.runtime import (
    FlowTable,
    LatencyHistogram,
    PacketStream,
    ServiceModel,
    ShardedRuntime,
    StreamingRuntime,
    find_zero_loss_rate,
    move_slot,
    replay,
    stream_buckets,
)
from repro.traffic import extract_features
from repro.traffic.models import train_traffic_model
from repro.traffic.pipeline import build_pipeline
from repro.traffic.synth import make_scenario_dataset

DEPTH_A = 8
DEPTH_B = 12


@pytest.fixture(scope="module")
def ds():
    # pinned draw with strong elephant skew (static 4-shard imbalance ~1.9)
    return make_scenario_dataset("app-class", "zipf", n_flows=120,
                                 max_pkts=256, seed=3)


def _pipe(ds, rep):
    X = extract_features(ds, rep.features, rep.depth)
    forest, _ = train_traffic_model(X, ds.label, model="tree-fast", seed=0)
    return build_pipeline(rep, forest, max_pkts=rep.depth, use_kernel=False)


@pytest.fixture(scope="module")
def pipeline(ds):
    return _pipe(ds, FeatureRep(
        ("dur", "s_load", "s_bytes_mean", "s_iat_mean", "ack_cnt"),
        depth=DEPTH_A))


@pytest.fixture(scope="module")
def pipeline_b(ds):
    return _pipe(ds, FeatureRep(
        ("dur", "s_load", "s_pkt_cnt", "d_bytes_med", "psh_cnt"),
        depth=DEPTH_B))


@pytest.fixture(scope="module")
def stream(ds):
    return PacketStream.from_dataset(ds, seed=0)


@pytest.fixture(scope="module")
def service():
    # deterministic constants at realistic magnitudes: the control-plane
    # overhead accounting (quiesce flushes, migration copies) only means
    # something when packet service and state copies are on real scales
    return ServiceModel(
        pkt_accum_ns=800.0, pkt_track_ns=200.0,
        bucket_ns={8: 3e4, 16: 4e4, 32: 6e4, 64: 1e5},
        gather_ns_per_flow=200.0, source="synthetic",
    )


def fleet(pipeline, n_shards=4, execute=False, **kw):
    return ShardedRuntime(pipeline, n_shards=n_shards, capacity=2048,
                          max_batch=64, execute=execute, **kw)


# ---------------------------------------------------------------------------
# construction validation
# ---------------------------------------------------------------------------


def test_shard_count_validation(pipeline):
    with pytest.raises(ValueError, match=">= 1"):
        ShardedRuntime(pipeline, n_shards=0)
    with pytest.raises(ValueError, match="RETA"):
        ShardedRuntime(pipeline, n_shards=129)
    # 128 workers (one per RETA entry) is the legal maximum
    rt = ShardedRuntime(pipeline, n_shards=128, capacity_per_shard=8,
                        execute=False)
    assert len(np.unique(rt.indirection)) == 128


def test_per_shard_capacity_validation(pipeline):
    with pytest.raises(ValueError, match="capacity"):
        ShardedRuntime(pipeline, n_shards=4, capacity_per_shard=0)
    with pytest.raises(ValueError, match="capacity"):
        ShardedRuntime(pipeline, n_shards=4, capacity_per_shard=-5)


# ---------------------------------------------------------------------------
# flow-state migration primitive
# ---------------------------------------------------------------------------


def _seed_flow(table, key, n_pkts, flow_id=7, fin=False):
    for i in range(n_pkts):
        st, slot = table.observe(
            key, 1.0 + i, float(i) * 0.1, 100.0 + i, i & 1, 64.0,
            1000.0, 0x10, 6.0, 1234.0, 443.0, flow_id,
            fin and i == n_pkts - 1,
        )
    return slot


def test_move_slot_bit_exact_state_transfer():
    src = FlowTable(16, pkt_depth=8)
    dst = FlowTable(16, pkt_depth=8)
    slot = _seed_flow(src, key=991, n_pkts=5)
    before = {
        "ctrl": src.ctrl[slot].copy(),
        "ts": src.ts[slot].copy(), "size": src.size[slot].copy(),
        "direction": src.direction[slot].copy(), "ttl": src.ttl[slot].copy(),
        "winsize": src.winsize[slot].copy(), "flags": src.flags[slot].copy(),
        "proto": src.proto[slot], "s_port": src.s_port[slot],
        "d_port": src.d_port[slot],
    }
    ns = move_slot(src, dst, slot)
    assert ns >= 0
    assert dst.ctrl[ns] == before["ctrl"]
    for f in ("ts", "size", "direction", "ttl", "winsize", "flags"):
        assert (getattr(dst, f)[ns] == before[f]).all()
    for f in ("proto", "s_port", "d_port"):
        assert getattr(dst, f)[ns] == before[f]
    # src slot fully detached: free again, index probe misses
    assert src.n_active == 0
    assert src._probe(991)[0] == -1
    assert dst._probe(991)[0] == ns
    # migration is not a lifecycle event
    assert src.metrics.slots_recycled == 0
    assert src.metrics.flows_migrated_out == 1
    assert dst.metrics.flows_migrated_in == 1
    assert dst.metrics.flows_seen == 0


def test_move_slot_depth_clamp_and_full_destination():
    src = FlowTable(8, pkt_depth=16)
    dst = FlowTable(2, pkt_depth=4)
    slot = _seed_flow(src, key=55, n_pkts=9)
    payload_prefix = src.ts[slot, :4].copy()
    ns = move_slot(src, dst, slot)
    assert int(dst.ctrl["count"][ns]) == 4  # clamped to the new depth
    assert (dst.ts[ns] == payload_prefix).all()
    # fill dst, then a further move must refuse (flow stays put)
    _seed_flow(dst, key=56, n_pkts=1, flow_id=1)
    s2 = _seed_flow(src, key=57, n_pkts=2, flow_id=2)
    assert move_slot(src, dst, s2) == -1
    assert src._probe(57)[0] == s2  # untouched


# ---------------------------------------------------------------------------
# RETA migration through the live facade
# ---------------------------------------------------------------------------


def _drive_steered(rt, stream, *, migrate_at=None, moves=None, block=256):
    """Feed the stream through `ingest_steered` in delivery order,
    optionally rewriting RETA entries mid-stream."""
    fid = stream.fid
    bucket_of_flow = stream_buckets(stream)
    E = stream.n_events
    done_migration = None
    for lo in range(0, E, block):
        hi = min(lo + block, E)
        sl = slice(lo, hi)
        rt.ingest_steered(
            stream.key[fid[sl]], stream.base_t[sl], stream.rel_ts32[sl],
            stream.size[sl], stream.direction[sl], stream.ttl[sl],
            stream.winsize[sl], stream.flags_byte[sl],
            stream.proto[fid[sl]], stream.s_port[fid[sl]],
            stream.d_port[fid[sl]], fid[sl], stream.fin[sl],
            bucket=bucket_of_flow[fid[sl]],
        )
        if migrate_at is not None and lo <= migrate_at < hi:
            done_migration = rt.migrate_buckets(
                moves, float(stream.base_t[hi - 1]))
    rt.drain(float(stream.base_t[-1]) + 1.0)
    return done_migration


def test_migration_no_flow_lost_or_double_predicted(pipeline, stream, ds,
                                                    service):
    """Rewrite a third of the RETA mid-stream; every flow still predicts
    exactly once, bit-identical to a single-worker oracle."""
    single = replay(
        stream,
        lambda: StreamingRuntime(pipeline, capacity=2048, max_batch=64),
        stream.base_pps, service)
    rt = fleet(pipeline, execute=True)
    # move half of shard 0's buckets to shard 3, some of 1's to 2
    moves = {int(b): 3 for b in range(0, 40, 4)}
    moves.update({int(b): 2 for b in range(1, 20, 4)})
    rep = _drive_steered(rt, stream, migrate_at=stream.n_events // 3,
                         moves=moves)
    assert rep is not None and rep["buckets_moved"] > 0
    m = rt.metrics.merged()
    assert m.flows_migrated_out == m.flows_migrated_in
    assert rep["flows_migrated"] == m.flows_migrated_out
    assert m.duplicate_predictions == 0
    assert len(rt.results) == ds.n_flows
    assert rt.results.keys() == single.predictions.keys()
    for f, pred in single.predictions.items():
        assert rt.results[f] == pred


def test_migration_resnapshots_after_quiesce_recycle(pipeline):
    """Regression: the quiesce flush recycles fully-closed READY flows
    (`mark_predicted`), so a pre-flush slot snapshot could 'migrate' a
    freed slot — double-freeing it on the source and indexing key 0 on
    the destination."""
    rt = ShardedRuntime(pipeline, n_shards=2, capacity=64, execute=False)
    bucket = np.zeros(3, np.int64)  # steer one flow through bucket 0
    key = np.full(3, 12345, np.uint64)
    # three packets, FIN in both directions: READY_EOF before depth, and
    # fully closed — exactly what mark_predicted recycles at the flush
    rt.ingest_steered(
        key, np.array([1.0, 1.001, 1.002]), np.zeros(3, np.float32),
        np.full(3, 100.0, np.float32), np.array([0, 1, 0], np.uint8),
        np.full(3, 64.0, np.float32), np.full(3, 1000.0, np.float32),
        np.zeros(3, np.uint8),
        np.full(3, 6.0, np.float32), np.full(3, 1.0, np.float32),
        np.full(3, 2.0, np.float32), np.zeros(3, np.int64),
        np.array([True, True, False]), bucket=bucket,
    )
    src = rt.shards[int(rt.indirection[0])]
    assert len(src.dispatcher._queue) == 1  # READY, waiting for a flush
    rep = rt.migrate_buckets({0: 1 - int(rt.indirection[0])}, now=1.01)
    # the flush classified-and-recycled the flow; nothing left to move
    assert rep["flows_migrated"] == 0
    for shard in rt.shards:
        free = shard.table._free
        assert len(free) == len(set(free))  # no double-free
        assert shard.table._probe(0)[0] == -1  # key 0 never indexed
        live = np.nonzero(shard.table.ctrl["state"] != 0)[0]
        assert live.size == 0
    agg = rt.metrics.merged()
    assert agg.flows_migrated_out == 0 and agg.flows_migrated_in == 0


def test_migration_skips_bucket_when_destination_full(pipeline, stream):
    rt = fleet(pipeline, capacity_per_shard=4, execute=False)
    bucket_of_flow = stream_buckets(stream)
    fid = stream.fid
    sl = slice(0, 2000)
    rt.ingest_steered(
        stream.key[fid[sl]], stream.base_t[sl], stream.rel_ts32[sl],
        stream.size[sl], stream.direction[sl], stream.ttl[sl],
        stream.winsize[sl], stream.flags_byte[sl], stream.proto[fid[sl]],
        stream.s_port[fid[sl]], stream.d_port[fid[sl]], fid[sl],
        stream.fin[sl], bucket=bucket_of_flow[fid[sl]],
    )
    # shard 1's table is tiny; moving every shard-0 bucket there cannot fit
    moves = {int(b): 1 for b in np.flatnonzero(rt.indirection == 0)}
    before = rt.indirection.copy()
    rep = rt.migrate_buckets(moves, float(stream.base_t[1999]))
    assert rep["buckets_skipped"] > 0
    # skipped buckets keep their steering entry (no misrouting)
    skipped = [b for b in moves if rt.indirection[b] == before[b]]
    assert len(skipped) == rep["buckets_skipped"]


# ---------------------------------------------------------------------------
# the acceptance criterion: zipf @ 4 shards
# ---------------------------------------------------------------------------


def test_zipf_acceptance_rebalancing_beats_static(pipeline, stream, ds,
                                                  service):
    """ISSUE 4 acceptance: under the Zipf elephant-flow scenario at 4
    shards the control plane reduces load_imbalance vs the static RETA
    and achieves >= 1.2x its measured zero-loss throughput, with zero
    drops and bit-identical predictions."""
    ring = max(64, stream.n_events // 16)

    def mk(execute=False):
        return fleet(pipeline, execute=execute)

    cfg = ControlConfig(interval_pkts=512, imbalance_trigger=1.04)
    r_st, s_st = find_zero_loss_rate(stream, mk, service, iters=8,
                                     ring_capacity=ring)
    r_dy, s_dy = find_zero_loss_rate(stream, mk, service, iters=8,
                                     ring_capacity=ring,
                                     session=ServeSession(control=cfg))
    assert s_st.drops == 0 and s_dy.drops == 0
    assert s_dy.load_imbalance < s_st.load_imbalance
    assert r_dy >= 1.2 * r_st
    assert s_dy.control["buckets_moved"] > 0
    # the verification replays execute: bitwise parity with a single-worker
    # oracle (fed at its own zero-drop rate — predictions are
    # rate-invariant precisely while nothing drops)
    single = replay(
        stream,
        lambda: StreamingRuntime(pipeline, capacity=2048, max_batch=64),
        stream.base_pps, service)
    assert single.drops == 0
    assert s_dy.predictions == single.predictions
    assert len(s_dy.predictions) == ds.n_flows


def test_controlled_replay_rate_invariant_predictions(pipeline, stream,
                                                      service):
    """Control decisions are packet-cadenced, so predictions (and the
    adaptation trajectory) are offered-rate-invariant — the property the
    timing-only bisection probes rely on."""
    cfg = ControlConfig(interval_pkts=512, imbalance_trigger=1.04)

    def mk():
        return fleet(pipeline, execute=True)

    lo = replay(stream, mk, stream.base_pps, service,
                session=ServeSession(control=cfg))
    hi = replay(stream, mk, stream.base_pps * 3, service,
                session=ServeSession(control=cfg))
    assert lo.predictions == hi.predictions
    assert lo.control["buckets_moved"] == hi.control["buckets_moved"]


# ---------------------------------------------------------------------------
# pipeline hot-swap
# ---------------------------------------------------------------------------


def test_hot_swap_single_runtime_exactly_once(pipeline, pipeline_b, stream):
    """Drain-and-swap on one worker mid-stream: zero drops, every flow
    predicted exactly once, metrics continuous across the swap."""
    rt = StreamingRuntime(pipeline, capacity=2048, max_batch=64)
    fid = stream.fid
    E = stream.n_events
    cut = E // 2
    for lo in range(0, E, 512):
        hi = min(lo + 512, E)
        sl = slice(lo, hi)
        rt.ingest_packets(
            stream.key[fid[sl]], stream.base_t[sl], stream.rel_ts32[sl],
            stream.size[sl], stream.direction[sl], stream.ttl[sl],
            stream.winsize[sl], stream.flags_byte[sl], stream.proto[fid[sl]],
            stream.s_port[fid[sl]], stream.d_port[fid[sl]], fid[sl],
            stream.fin[sl],
        )
        if lo <= cut < hi:
            rt.hot_swap(pipeline_b, float(stream.base_t[hi - 1]))
            assert rt.pipeline is pipeline_b
            assert rt.table.pkt_depth == DEPTH_B
    rt.drain(float(stream.base_t[-1]) + 1.0)
    m = rt.metrics
    assert m.drops == 0
    assert m.duplicate_predictions == 0
    assert len(rt.results) == stream.n_flows
    assert m.flushes_swap >= 0  # quiesce may be empty if queue was drained
    assert m.flows_migrated_in == m.flows_migrated_out  # same metrics block


def test_hot_swap_fleet_parity_with_oracles(pipeline, pipeline_b, stream, ds,
                                            service):
    """Mid-replay fleet swap under the control plane: flows that complete
    under one configuration match that configuration's oracle exactly."""
    svc_b = ServiceModel(
        pkt_accum_ns=900.0, pkt_track_ns=200.0,
        bucket_ns={8: 4e4, 16: 5e4, 32: 7e4, 64: 1.2e5},
        gather_ns_per_flow=200.0, source="synthetic")
    cut = stream.n_events // 2
    cfg = ControlConfig(interval_pkts=512,
                        swap=PipelineSwap(pipeline_b, svc_b, after_pkts=cut))
    swapped = replay(stream, lambda: fleet(pipeline, execute=True),
                     stream.base_pps, service,
                     session=ServeSession(control=cfg))
    assert swapped.drops == 0
    assert swapped.control["swaps"] == 1
    assert swapped.metrics.duplicate_predictions == 0
    assert len(swapped.predictions) == ds.n_flows

    old_oracle = replay(
        stream,
        lambda: StreamingRuntime(pipeline, capacity=2048, max_batch=64),
        stream.base_pps, service)
    new_oracle = replay(
        stream,
        lambda: StreamingRuntime(pipeline_b, capacity=2048, max_batch=64),
        stream.base_pps, svc_b)

    first_pkt = np.full(ds.n_flows, stream.n_events)
    last_pkt = np.zeros(ds.n_flows, np.int64)
    np.minimum.at(first_pkt, stream.fid, np.arange(stream.n_events))
    np.maximum.at(last_pkt, stream.fid, np.arange(stream.n_events))

    # completed under the old configuration: all packets before the swap
    # AND the flow reached depth (so it was READY and the swap's quiesce
    # flush — at the latest — classified it through the old pipeline).
    # A one-directional FIN does *not* complete a flow (fin_mask needs
    # both directions), so short FIN'd flows stay ACTIVE across the swap
    # and legitimately classify under the new configuration.
    pre = (last_pkt < cut) & (ds.flow_len >= DEPTH_A)
    # started after the swap: pure new-configuration flows
    post = first_pkt >= cut
    assert pre.sum() > 0 and post.sum() > 0
    for f in np.nonzero(pre)[0]:
        assert swapped.predictions[f] == old_oracle.predictions[f]
    for f in np.nonzero(post)[0]:
        assert swapped.predictions[f] == new_oracle.predictions[f]


# ---------------------------------------------------------------------------
# elastic scale-out / scale-in
# ---------------------------------------------------------------------------


def test_elastic_scale_out_under_load(pipeline, stream, service):
    cfg = ControlConfig(interval_pkts=512,
                        headroom=HeadroomPolicy(max_workers=8))

    def mk():
        return ShardedRuntime(pipeline, n_shards=2, capacity=4096,
                              max_batch=64, execute=False)

    # per-worker ingest capacity ~1.25M pps at 800ns: 4M pps needs ~5
    hot = replay(stream, mk, 4e6, service,
                 session=ServeSession(control=cfg))
    assert hot.control["workers_added"] > 0
    assert hot.control["active_workers"] > 2
    assert hot.n_shards == 2 + hot.control["workers_added"]
    # the grown fleet absorbed a load two workers could not have served
    added = [p for p in hot.per_shard if p["shard"] >= 2]
    assert sum(p["pkts_total"] for p in added) > 0


def test_elastic_scale_in_when_idle(pipeline, stream, service):
    cfg = ControlConfig(interval_pkts=512,
                        headroom=HeadroomPolicy(max_workers=8))

    def mk():
        return ShardedRuntime(pipeline, n_shards=2, capacity=4096,
                              max_batch=64, execute=True)

    cold = replay(stream, mk, 1e5, service,
                  session=ServeSession(control=cfg))
    assert cold.control["workers_retired"] >= 1
    assert cold.control["active_workers"] == 1
    # retirement evacuated state: nothing lost, predictions complete
    assert cold.drops == 0
    assert len(cold.predictions) == stream.n_flows
    # retired workers own no RETA entries and hold no flows
    rtd = [p["shard"] for p in cold.per_shard if not p["active"]]
    assert rtd


# ---------------------------------------------------------------------------
# planner unit behavior
# ---------------------------------------------------------------------------


def test_plan_rebalance_reduces_imbalance():
    rng = np.random.default_rng(0)
    rates = rng.exponential(1.0, 128)
    rates[5] = 60.0  # one elephant bucket
    ind = np.arange(128, dtype=np.int64) % 4
    active = [True] * 4

    def imb(i):
        loads = np.bincount(i, weights=rates, minlength=4)
        return loads.max() / loads.mean()

    moves = plan_rebalance(rates, ind, active, max_moves=16, trigger=1.02)
    assert moves
    after = ind.copy()
    for b, d in moves.items():
        after[b] = d
    assert imb(after) < imb(ind)


def test_plan_rebalance_noop_when_balanced():
    rates = np.ones(128)
    ind = np.arange(128, dtype=np.int64) % 4
    assert plan_rebalance(rates, ind, [True] * 4, trigger=1.05) == {}
    # single active worker: nothing to plan
    assert plan_rebalance(rates, np.zeros(128, np.int64), [True]) == {}


def test_plan_retirement_spreads_and_empties_worker():
    rates = np.random.default_rng(1).exponential(1.0, 128)
    ind = np.arange(128, dtype=np.int64) % 4
    moves = plan_retirement(rates, ind, worker=2, active=[True] * 4)
    assert set(moves) == set(np.flatnonzero(ind == 2).tolist())
    assert all(d != 2 for d in moves.values())
    with pytest.raises(ValueError):
        plan_retirement(rates, np.zeros(128, np.int64), 0, [True, False])


def test_headroom_policy_hysteresis():
    pol = HeadroomPolicy(target_util=0.7, scale_in_util=0.5, max_workers=8)
    # 4M pps at 1.25M/worker: need ceil(4/0.875) = 5
    assert pol.desired_workers(4e6, 1.25e6, current=2) == 5
    # mild overshoot below scale-in threshold keeps the current fleet
    assert pol.desired_workers(2.4e6, 1.25e6, current=4) == 4
    # deep idle shrinks
    assert pol.desired_workers(1e5, 1.25e6, current=4) == 1
    assert pol.desired_workers(1e9, 1.25e6, current=2) == 8  # capped


# ---------------------------------------------------------------------------
# bounded latency histogram (reservoir + exact buckets)
# ---------------------------------------------------------------------------


def test_latency_histogram_exact_below_cap():
    h = LatencyHistogram(max_samples=512)
    x = np.random.default_rng(0).exponential(0.01, 400)
    h.record_many(x)
    assert h.n == 400
    assert h.percentile(50) == pytest.approx(float(np.percentile(x, 50)),
                                             rel=1e-12)
    assert h.percentile(99) == pytest.approx(float(np.percentile(x, 99)),
                                             rel=1e-12)


def test_latency_histogram_bounded_memory_and_error():
    h = LatencyHistogram(max_samples=256)
    rng = np.random.default_rng(1)
    all_x = []
    for _ in range(40):
        x = rng.lognormal(-6.0, 1.0, 1000)
        h.record_many(x)
        all_x.append(x)
    x = np.concatenate(all_x)
    assert h.n == len(x)
    assert h._reservoir.size == 256  # storage never grew
    # bucket counts stay exact
    idx = np.searchsorted(h.edges, x, side="right")
    assert (h.counts() == np.bincount(idx, minlength=len(h.edges) + 1)).all()
    # percentile error bounded by the containing bucket's width
    for q in (50, 90, 99):
        est = h.percentile(q)
        true = float(np.percentile(x, q))
        b = int(np.searchsorted(h.edges, true, side="right"))
        lo = 0.0 if b == 0 else float(h.edges[b - 1])
        hi = float(h.edges[b]) if b < len(h.edges) else true
        assert abs(est - true) <= (hi - lo) + 1e-12
    assert h._min <= h.percentile(0.001) and h.percentile(99.999) <= h._max


def test_latency_histogram_merge_exact_when_small():
    a, b = LatencyHistogram(), LatencyHistogram()
    xa = np.random.default_rng(2).exponential(0.01, 300)
    xb = np.random.default_rng(3).exponential(0.02, 500)
    a.record_many(xa)
    b.record_many(xb)
    a.merge_from(b)
    both = np.concatenate([xa, xb])
    assert a.n == 800
    assert a.percentile(90) == pytest.approx(float(np.percentile(both, 90)),
                                             rel=1e-12)
    idx = np.searchsorted(a.edges, both, side="right")
    assert (a.counts() == np.bincount(idx, minlength=len(a.edges) + 1)).all()


def test_latency_histogram_merge_stays_capped():
    a = LatencyHistogram(max_samples=128)
    b = LatencyHistogram(max_samples=128)
    a.record_many(np.full(1000, 0.001))
    b.record_many(np.full(1000, 0.1))
    a.merge_from(b)
    assert a.n == 2000
    assert a._n_res <= 128
    # bucket-interpolated percentiles still separate the two modes
    assert a.percentile(20) < 0.01 < a.percentile(80)
